"""Paper Table 3: per-strategy analytical projections for the paper's models.

Emits the oracle's comp/comm/memory per strategy for ResNet-50, VGG16 and
CosmoFlow on the paper's V100 cluster model, at the paper's scales. Each
model's full strategy set is evaluated as ONE vectorized sweep call
(core/sweep.py); the per-row time is the lattice time amortized per point.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import OracleConfig, PAPER_V100_CLUSTER, TimeModel, stats_for
from repro.core.sweep import sweep
from repro.models.cnn import CosmoFlowConfig, RESNET50, VGGConfig

from .common import emit, note

MODELS = {
    "resnet50": (RESNET50, 1_281_167, 2048),
    "vgg16": (VGGConfig(), 1_281_167, 1024),
    "cosmoflow": (CosmoFlowConfig(img=128), 1584, 64),
}
STRATS = ("data", "spatial", "pipeline", "filter", "channel", "df")


def run():
    rows = []
    tm = TimeModel(PAPER_V100_CLUSTER)
    p = 64
    for name, (mc, D, B) in MODELS.items():
        stats = stats_for(mc)
        # two sweeps per model: the overlap model (what the tuner ranks
        # with) and the paper's serial accounting (--no-overlap), so the
        # table records how much comm each strategy actually exposes
        for tag, cfg in (("", OracleConfig(B=B, D=D)),
                         ("/nooverlap", OracleConfig(B=B, D=D,
                                                     overlap=False))):
            t0 = time.perf_counter()
            res = sweep(stats, tm, cfg, [p], strategies=STRATS)
            us = (time.perf_counter() - t0) * 1e6 / max(len(res), 1)
            for strat in STRATS:
                sub = res.for_strategy(strat)
                if not len(sub):
                    continue
                # the paper's Table-3 hybrid point is the 16×4 split
                i = (int(np.flatnonzero((sub.p1 == 16) & (sub.p2 == 4))[0])
                     if strat in ("df", "ds") else 0)
                it = max(float(sub.iterations[i]), 1.0)
                rows.append((
                    f"table3/{name}/{strat}/p{p}{tag}", us,
                    f"comp_ms={float(sub.comp_s[i])/it*1e3:.2f};"
                    f"comm_ms={float(sub.comm_s[i])/it*1e3:.2f};"
                    f"mem_GiB={float(sub.mem_bytes[i])/2**30:.2f};"
                    f"feasible={bool(sub.feasible[i])};"
                    f"bottleneck={sub.bottleneck[i]}"))
    return rows


def main():
    note("Table 3 — analytical per-iteration projections, paper V100 cluster")
    note("rows without a suffix use the comm/compute overlap model "
         "(DESIGN.md §10); '/nooverlap' rows are the paper's serial "
         "accounting")
    emit(run())


if __name__ == "__main__":
    main()
