"""Paper Table 3: per-strategy analytical projections for the paper's models.

Emits the oracle's comp/comm/memory per strategy for ResNet-50, VGG16 and
CosmoFlow on the paper's V100 cluster model, at the paper's scales.
"""
from __future__ import annotations

import time

from repro.core import (OracleConfig, PAPER_V100_CLUSTER, TimeModel, project,
                        stats_for)
from repro.models.cnn import CosmoFlowConfig, RESNET50, VGGConfig

from .common import emit, note

MODELS = {
    "resnet50": (RESNET50, 1_281_167, 2048),
    "vgg16": (VGGConfig(), 1_281_167, 1024),
    "cosmoflow": (CosmoFlowConfig(img=128), 1584, 64),
}
STRATS = ("data", "spatial", "pipeline", "filter", "channel", "df")


def run():
    rows = []
    tm = TimeModel(PAPER_V100_CLUSTER)
    for name, (mc, D, B) in MODELS.items():
        stats = stats_for(mc)
        cfg = OracleConfig(B=B, D=D)
        for strat in STRATS:
            p = 64
            t0 = time.perf_counter()
            kw = dict(p1=16, p2=4) if strat in ("df", "ds") else {}
            proj = project(strat, stats, tm, cfg, p, **kw)
            us = (time.perf_counter() - t0) * 1e6
            it = proj.per_iteration()
            rows.append((
                f"table3/{name}/{strat}/p{p}", us,
                f"comp_ms={it['comp_s']*1e3:.2f};comm_ms={it['comm_s']*1e3:.2f};"
                f"mem_GiB={proj.mem_bytes/2**30:.2f};feasible={proj.feasible}"))
    return rows


def main():
    note("Table 3 — analytical per-iteration projections, paper V100 cluster")
    emit(run())


if __name__ == "__main__":
    main()
