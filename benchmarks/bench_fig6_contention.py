"""Paper Fig. 6 / §4.3: contention coefficient φ and congested outliers.

The paper observed congested runs up to 4× the φ=1 prediction. We sweep φ
over the df hybrid's gradient exchange and report the slowdown curve — the
model the paper fits its outliers against (plus the φ=2 value used for the
df results in Fig. 3).
"""
from __future__ import annotations

import time

from repro.core import OracleConfig, PAPER_V100_CLUSTER, TimeModel, project, stats_for
from repro.models.cnn import RESNET50

from .common import emit, note


def run():
    stats = stats_for(RESNET50)
    tm = TimeModel(PAPER_V100_CLUSTER)
    rows = []
    base = None
    for phi in (1.0, 2.0, 3.0, 4.0):
        cfg = OracleConfig(B=2048, D=1_281_167, phi_hybrid=phi)
        t0 = time.perf_counter()
        proj = project("df", stats, tm, cfg, 512, p1=128, p2=4)
        us = (time.perf_counter() - t0) * 1e6
        if base is None:
            base = proj.comm_ge_s
        rows.append((f"fig6/resnet50/df/phi{phi:.0f}", us,
                     f"ge_ms={proj.comm_ge_s/proj.iterations*1e3:.3f};"
                     f"slowdown={proj.comm_ge_s/base:.2f}x"))
    return rows


def main():
    note("Fig 6 — contention penalty sweep (paper's 4x congestion outliers)")
    emit(run())


if __name__ == "__main__":
    main()
