"""Paper Fig. 6 / §4.3: contention coefficient φ — projected and measured.

The paper observed congested runs up to 4× the φ=1 prediction. Two parts:

  * projection — sweep φ over the df hybrid's gradient exchange through the
    ``Oracle`` session facade and report the slowdown curve (the model the
    paper fits its outliers against, plus the φ=2 value used for Fig. 3);
  * measurement — with > 1 (virtual) host device, time one saturating
    allreduce alone vs two concurrent flows (``core.calibration.
    measure_contention``) and fit φ per mesh axis via
    ``ClusterSpec.fitted_from`` — the same records
    ``python -m repro.api --calibrate`` writes into
    experiments/cluster_fit.json.
"""
from __future__ import annotations

import time

from repro.api import Oracle

from .common import emit, note


def run():
    rows = []
    base = None
    for phi in (1.0, 2.0, 3.0, 4.0):
        ses = Oracle("resnet50", "train_4k", "paper", batch=2048,
                     dataset=1_281_167, phi_hybrid=phi)
        t0 = time.perf_counter()
        proj = ses.project("df", 512, p1=128, p2=4)
        us = (time.perf_counter() - t0) * 1e6
        if base is None:
            base = proj.comm_ge_s
        rows.append((f"fig6/resnet50/df/phi{phi:.0f}", us,
                     f"ge_ms={proj.comm_ge_s/proj.iterations*1e3:.3f};"
                     f"slowdown={proj.comm_ge_s/base:.2f}x"))
    return rows


def run_measured():
    """Measured self-contention per mesh axis (skips on 1 device)."""
    import jax
    if len(jax.devices()) < 2:
        note("fig6 measured φ: single device — skipping (run under "
             "XLA_FLAGS=--xla_force_host_platform_device_count=8)")
        return []
    from repro.core.calibration import measure_contention
    from repro.core.cluster import ClusterSpec
    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh()
    rows = []
    for axis in mesh.shape:
        if mesh.shape[axis] <= 1:
            continue
        t0 = time.perf_counter()
        m = measure_contention(mesh, axis)
        us = (time.perf_counter() - t0) * 1e6
        phi = dict(ClusterSpec.fitted_from([m], base="host").phi)[axis]
        rows.append((f"fig6/measured/{axis}", us,
                     f"alone_ms={m.alone_s*1e3:.3f};"
                     f"shared_ms={m.shared_s*1e3:.3f};phi_fit={phi:.2f}"))
    return rows


def main():
    note("Fig 6 — contention penalty sweep (paper's 4x congestion outliers)")
    emit(run())
    emit(run_measured())


if __name__ == "__main__":
    main()
