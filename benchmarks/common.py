"""Shared helpers for the benchmark harness."""
from __future__ import annotations

import time


def emit(rows):
    """rows: iterable of (name, us_per_call, derived). Prints the CSV."""
    for name, us, derived in rows:
        print(f"{name},{us:.3f},{derived}")


def note(msg: str):
    print(f"# {msg}")


def timed(fn, *args, iters=3, warmup=1):
    import jax
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters
