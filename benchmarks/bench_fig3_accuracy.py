"""Paper Fig. 3: oracle projection vs measured runs, per strategy.

Measured on the available (virtual) host devices with a reduced LM + the
paper's accuracy metric (1 − |proj − meas|/meas). The paper reports 86.74%
mean on a real 1024-GPU system; here the "cluster" is 8 time-shared host
devices — the harness and metric are identical, the hardware is not.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.layer_stats import stats_for
from repro.core.validation import accuracy_report, validate
from repro.models import LMConfig, TransformerLM
from repro.nn import AttentionConfig, FFNConfig

from .common import emit, note


def run():
    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh()
    # 8 layers: one per virtual device when the pipeline strategy turns
    # all 8 PEs into GPipe stages (the last Table-3 row measured — ISSUE 3)
    cfg = LMConfig(name="bench", vocab=256, d_model=128, n_layers=8,
                   attn=AttentionConfig(128, 4, 4, 32, dtype=jnp.float32),
                   ffn=FFNConfig(128, 512, dtype=jnp.float32),
                   dtype=jnp.float32)
    model = TransformerLM(cfg)
    B, S = 16, 128
    key = jax.random.PRNGKey(0)
    batch = {"tokens": jax.random.randint(key, (B, S), 0, 256)}
    stats = stats_for(cfg, S)
    flops = sum(s.flops_fwd for s in stats)
    strategies = ["data", "filter", "channel", "df", "ds", "pipeline"]
    pts = validate(model, cfg, batch, mesh, strategies,
                   flops_per_sample=flops, B=B, S=S)
    note(accuracy_report(pts).replace("\n", "\n# "))
    rows = []
    for pt in pts:
        rows.append((f"fig3/{pt.strategy}/p{pt.p}", pt.measured_s * 1e6,
                     f"projected_us={pt.projected_s*1e6:.1f};"
                     f"accuracy={pt.accuracy*100:.1f}%;"
                     f"serial_us={pt.projected_serial_s*1e6:.1f};"
                     f"accuracy_serial={pt.accuracy_serial*100:.1f}%"))
    import numpy as np
    mean_acc = float(np.mean([pt.accuracy for pt in pts]))
    rows.append(("fig3/mean_accuracy", 0.0, f"accuracy={mean_acc*100:.2f}%"))
    return rows


def main():
    note("Fig 3 — oracle vs measured (8 virtual host devices)")
    emit(run())


if __name__ == "__main__":
    main()
