import os

# benchmarks measure on 8 virtual host devices (the dry-run uses its own
# process with 512); must be set before any jax import.
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

"""Benchmark harness — one module per paper table/figure (+ roofline).

Prints ``name,us_per_call,derived`` CSV (commentary lines prefixed '#').
"""
import sys
import time
import traceback


def main() -> None:
    from . import (bench_fig3_accuracy, bench_fig4_cosmoflow,
                   bench_fig5_scaling, bench_fig6_contention,
                   bench_fig7_weight_update, bench_fig8_filter_breakdown,
                   bench_kernels, bench_roofline, bench_sweep, bench_table3)
    benches = [
        ("table3", bench_table3),
        ("sweep", bench_sweep),
        ("fig3_accuracy", bench_fig3_accuracy),
        ("fig4_cosmoflow", bench_fig4_cosmoflow),
        ("fig5_scaling", bench_fig5_scaling),
        ("fig6_contention", bench_fig6_contention),
        ("fig7_weight_update", bench_fig7_weight_update),
        ("fig8_filter_breakdown", bench_fig8_filter_breakdown),
        ("kernels", bench_kernels),
        ("roofline", bench_roofline),
    ]
    print("name,us_per_call,derived")
    failures = []
    for name, mod in benches:
        t0 = time.time()
        try:
            mod.main()
            print(f"# [{name}] done in {time.time()-t0:.1f}s", flush=True)
        except Exception as e:  # noqa: BLE001
            failures.append(name)
            print(f"# [{name}] FAILED: {e!r}")
            traceback.print_exc(limit=3, file=sys.stderr)
    if failures:
        raise SystemExit(f"benchmarks failed: {failures}")


if __name__ == "__main__":
    main()
