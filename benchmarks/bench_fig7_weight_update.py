"""Paper Fig. 7 / §5.3.3: the weight update is non-trivial for large models.

Measured WU share of a real train step for a small/large-ish pair on this
host, plus the oracle's projected share for the paper's models (VGG16 ≈ 15%
in the paper) and for qwen3-32b with AdamW (transformers: 'up to 45%').
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import OracleConfig, PAPER_V100_CLUSTER, TimeModel, project, stats_for
from repro.data.pipeline import DataConfig, ShardedLoader
from repro.models import LMConfig, TransformerLM
from repro.nn import AttentionConfig, FFNConfig
from repro.nn.module import NULL_CTX, tree_init
from repro.optim.optimizers import OptimizerConfig, apply_update
from repro.training.steps import make_train_step, train_state_spec

from .common import emit, note, timed


def _measured_share(d_model, d_ff, n_layers, vocab=512):
    cfg = LMConfig(name="b", vocab=vocab, d_model=d_model, n_layers=n_layers,
                   attn=AttentionConfig(d_model, 4, 4, d_model // 4,
                                        dtype=jnp.float32),
                   ffn=FFNConfig(d_model, d_ff, dtype=jnp.float32),
                   dtype=jnp.float32)
    model = TransformerLM(cfg)
    opt = OptimizerConfig(name="adamw", zero1=False)
    state = tree_init(train_state_spec(model, opt), jax.random.PRNGKey(0))
    loader = ShardedLoader(DataConfig("lm", batch=4, seq_len=64, vocab=vocab))
    batch = loader.batch_at(0)
    kw = dict(attn_impl="plain", scan_layers=False, remat=False)
    full = jax.jit(make_train_step(model, opt, NULL_CTX, **kw))
    t_full = timed(full, state, batch)
    grads = jax.jit(jax.grad(lambda p, b: model.loss_fn(p, b, **kw)[0]))(
        state["params"], batch)
    wu = jax.jit(lambda p, g, o, s: apply_update(opt, p, g, o, s)[0])
    t_wu = timed(wu, state["params"], grads, state["opt"], state["step"])
    return t_wu / t_full, t_full


def run():
    rows = []
    share_small, t_small = _measured_share(64, 128, 2)
    share_big, t_big = _measured_share(256, 1024, 4)
    rows.append(("fig7/measured/small_lm", t_small * 1e6,
                 f"wu_share={share_small*100:.1f}%"))
    rows.append(("fig7/measured/bigger_lm", t_big * 1e6,
                 f"wu_share={share_big*100:.1f}%"))
    # oracle projections at the paper's scale
    tm = TimeModel(PAPER_V100_CLUSTER)
    for name, stats, B in [
            ("vgg16", stats_for(__import__("repro.models.cnn",
             fromlist=["VGGConfig"]).VGGConfig()), 1024),
            ("qwen3-32b", stats_for(get_config("qwen3-32b").model, 4096), 256)]:
        cfg = OracleConfig(B=B, D=B * 4)
        proj = project("data", stats, tm, cfg, 64)
        wu = sum(tm.wu(s) for s in stats) * proj.iterations
        share = wu / proj.comp_s if proj.comp_s else 0.0
        rows.append((f"fig7/projected/{name}", 0.0,
                     f"wu_share={share*100:.1f}%"))
    return rows


def main():
    note("Fig 7 — weight-update share of compute (measured + projected)")
    emit(run())


if __name__ == "__main__":
    main()
