"""§Roofline table (beyond paper): per (arch × shape × mesh) terms from the
dry-run artifacts in experiments/dryrun/."""
from __future__ import annotations

import json
from pathlib import Path

from .common import emit, note

DRYRUN = Path("experiments/dryrun")


def run():
    rows = []
    for f in sorted(DRYRUN.glob("*.json")):
        rec = json.loads(f.read_text())
        rl = rec.get("roofline", {})
        if not rl:
            continue
        name = f"roofline/{rec['arch']}/{rec['shape']}/{rec['mesh']}"
        if rec.get("tag"):
            name += f"/{rec['tag']}"
        rows.append((
            name, rl["step_time_bound_s"] * 1e6,
            f"compute_ms={rl['compute_s']*1e3:.2f};"
            f"memory_ms={rl['memory_s']*1e3:.2f};"
            f"collective_ms={rl['collective_s']*1e3:.2f};"
            f"dominant={rl['dominant']};useful={rl['useful_ratio']:.2f};"
            f"frac={rl['roofline_fraction']:.3f};"
            f"fits_hbm={rl['fits_hbm']}"))
    if not rows:
        rows.append(("roofline/missing", 0.0,
                     "run: PYTHONPATH=src python -m repro.launch.dryrun --all"))
    return rows


def main():
    note("Roofline terms per (arch x shape x mesh) from dry-run artifacts")
    emit(run())


if __name__ == "__main__":
    main()
