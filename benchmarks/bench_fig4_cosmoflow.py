"""Paper Fig. 4: CosmoFlow Data+Spatial (ds) prediction accuracy.

The paper's flagship case: 3-D samples too large for anything but ds.
Measured with a reduced CosmoFlow on host devices + oracle projection.
"""
from __future__ import annotations

import jax

from repro.core.layer_stats import stats_for
from repro.core.validation import accuracy_report, validate
from repro.models.cnn import CosmoFlow, CosmoFlowConfig

from .common import emit, note


def run():
    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh()
    mc = CosmoFlowConfig(img=32, n_conv=3, width=8)
    model = CosmoFlow(mc)
    B = 8
    key = jax.random.PRNGKey(0)
    batch = {"images": jax.random.normal(key, (B, 32, 32, 32, 4)),
             "targets": jax.random.normal(key, (B, 4))}
    stats = stats_for(mc)
    flops = sum(s.flops_fwd for s in stats)
    pts = validate(model, mc, batch, mesh, ["ds", "data"],
                   flops_per_sample=flops, B=B)
    note(accuracy_report(pts).replace("\n", "\n# "))
    return [(f"fig4/cosmoflow/{pt.strategy}", pt.measured_s * 1e6,
             f"projected_us={pt.projected_s*1e6:.1f};"
             f"accuracy={pt.accuracy*100:.1f}%") for pt in pts]


def main():
    note("Fig 4 — CosmoFlow ds accuracy (reduced, host devices)")
    emit(run())


if __name__ == "__main__":
    main()
