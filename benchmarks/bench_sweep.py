"""Sweep engine: vectorized lattice evaluation vs the scalar project() loop.

Two lattices for ResNet-50 on the paper's cluster model:
  * pow2  — p ∈ {1, 2, …, 1024}, the classic Fig-5 grid;
  * dense — EVERY p ∈ 1..1024 with every divisor split (the search space the
    pow2-only path silently dropped; ~27k points).
Both are evaluated with one sweep() call and with the equivalent per-point
project() loop. Acceptance floor: vectorized ≥ 10× faster.

The timing rows land in ``BENCH_sweep.json`` at the repo root (``--out``
redirects to a scratch file) so the sweep-engine wall-clock is a committed
trajectory like BENCH_kernels.json: scripts/check.sh diffs a fresh run
against it with scripts/bench_compare.py. The lattice now fans summa over
every (p2r, p2c) factorization (ISSUE 9) — the committed artifact records
the 2D-widened lattice, and a fresh full sweep must stay within the
tolerance band of it.
"""
from __future__ import annotations

import argparse
import json
import os
import time

from repro.core import (OracleConfig, PAPER_V100_CLUSTER, STRATEGY_NAMES,
                        TimeModel, project, stats_for)
from repro.core.sweep import sweep
from repro.models.cnn import RESNET50

from .common import emit, note

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ARTIFACT = os.path.join(_ROOT, "BENCH_sweep.json")

GRIDS = {
    "pow2": tuple(2 ** k for k in range(11)),
    "dense": tuple(range(1, 1025)),
}


def _time_both(stats, tm, cfg, grid, reps):
    cap = tm.system.mem_capacity
    res = sweep(stats, tm, cfg, grid, mem_cap=cap)    # warmup
    t0 = time.perf_counter()
    for _ in range(reps):
        res = sweep(stats, tm, cfg, grid, mem_cap=cap)
    t_vec = (time.perf_counter() - t0) / reps

    points = [(str(res.strategy[i]), int(res.p[i]), int(res.p1[i]),
               int(res.p2[i]), int(res.p2r[i]), int(res.p2c[i]))
              for i in range(len(res))]
    t0 = time.perf_counter()
    for s, p, p1, p2, p2r, p2c in points:             # equivalent scalar loop
        project(s, stats, tm, cfg, p, p1=p1, p2=p2, p2r=p2r, p2c=p2c)
    t_scalar = time.perf_counter() - t0
    return len(res), t_vec, t_scalar


def run():
    stats = stats_for(RESNET50)
    tm = TimeModel(PAPER_V100_CLUSTER)
    cfg = OracleConfig(B=2048, D=1_281_167)
    rows = []
    for name, grid in GRIDS.items():
        n, t_vec, t_scalar = _time_both(stats, tm, cfg, grid,
                                        reps=5 if name == "pow2" else 2)
        speedup = t_scalar / t_vec if t_vec else float("inf")
        rows += [
            (f"sweep/resnet50/{name}/vectorized", t_vec * 1e6,
             f"points={n};strategies={len(STRATEGY_NAMES)}"),
            (f"sweep/resnet50/{name}/scalar_loop", t_scalar * 1e6,
             f"points={n}"),
            (f"sweep/resnet50/{name}/speedup", 0.0,
             f"x{speedup:.1f};target>=10x;pass={speedup >= 10.0}"),
        ]
    return rows


def write_artifact(rows, out: "str | None" = None) -> str:
    # only the timing rows enter the trajectory: the synthetic speedup row
    # carries us_per_call=0, which bench_compare would read as a vanished
    # baseline — its pass/fail already lives in the derived column above
    rec = {"timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
           "smoke": False,
           "rows": [{"name": n, "us_per_call": round(us, 3), "derived": d}
                    for n, us, d in rows if us > 0.0]}
    path = out or ARTIFACT
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
        f.write("\n")
    return path


def main(argv=None):
    ap = argparse.ArgumentParser(prog="python -m benchmarks.bench_sweep")
    ap.add_argument("--out", default=None,
                    help="write the artifact to this path instead of the "
                         "committed BENCH_sweep.json — scripts/check.sh "
                         "lands a fresh run in a scratch file and diffs it "
                         "against the committed trajectory with "
                         "scripts/bench_compare.py")
    # parse_known_args: benchmarks.run invokes main() programmatically —
    # a foreign sys.argv flag must not SystemExit the whole suite
    args, _ = ap.parse_known_args(argv)
    note("Sweep engine — vectorized lattice vs scalar project() loop")
    rows = run()
    emit(rows)
    note(f"wrote {write_artifact(rows, out=args.out)}")


if __name__ == "__main__":
    main()
