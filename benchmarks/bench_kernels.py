"""Kernel micro-benchmarks: Pallas (interpret) vs jnp reference wall time.

interpret-mode timings are NOT TPU performance (the kernels target TPU; this
box is CPU) — but they ARE a regression signal for the kernel bodies
themselves, so every Pallas kernel is timed here alongside its reference,
and the run lands in ``BENCH_kernels.json`` at the repo root so the perf
trajectory records across PRs. The derived column carries the FLOPs/bytes
the kernel would execute, which the roofline converts to TPU projections.

``--smoke`` shrinks shapes/iters for the CI gate (scripts/check.sh): it
still runs every kernel (a kernel that stops compiling fails the gate) and
still writes the artifact.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp

from repro.kernels import (attention_ref, conv2d_gemm, conv2d_ref,
                           flash_attention, rmsnorm, rmsnorm_ref, ssd_chunk,
                           ssd_ref)
from repro.kernels.autotune import load_tiles

from .common import emit, note, timed

_ROOT = os.path.dirname(os.path.dirname(__file__))
ARTIFACT = os.path.join(_ROOT, "BENCH_kernels.json")
# --smoke shapes/iters are incomparable with full runs, so the CI gate
# writes its own (gitignored) artifact and never clobbers the committed
# perf trajectory
SMOKE_ARTIFACT = os.path.join(_ROOT, "BENCH_kernels_smoke.json")


def run(smoke: bool = False):
    key = jax.random.PRNGKey(0)
    rows = []
    it = dict(iters=1, warmup=1) if smoke else dict(iters=3, warmup=1)
    # tuned blocks from the committed autotune artifact (no fingerprint
    # gate: the bench compares default vs tuned rows under whatever the
    # artifact holds; smoke shapes land in untuned buckets → defaults)
    tiles = load_tiles()

    def tuned(kernel, dims):
        b = tiles.blocks_for(kernel, dims)
        return b, (";".join(f"{k}={v}" for k, v in sorted(b.items()))
                   if b else "untuned(defaults)")

    # flash attention — ref AND the Pallas kernel (interpret)
    B, H, S, D = (1, 2, 128, 32) if smoke else (1, 4, 512, 64)
    q, k, v = (jax.random.normal(jax.random.fold_in(key, i), (B, H, S, D))
               for i in range(3))
    flops = 4 * B * H * S * S * D / 2
    t_ref = timed(lambda: attention_ref(q, k, v), **it)
    rows.append((f"kernels/flash_attention/ref/S{S}", t_ref * 1e6,
                 f"flops={flops:.3e};tpu_proj_us={flops/197e12*1e6:.2f}"))
    t_k = timed(lambda: flash_attention(q, k, v, causal=True, interpret=True),
                **it)
    rows.append((f"kernels/flash_attention/pallas_interpret/S{S}", t_k * 1e6,
                 f"flops={flops:.3e};ref_ratio={t_k/t_ref:.2f}x"))
    fb, ftag = tuned("flash_attention",
                     dict(B=B, H=H, S=S, D=D, causal=1, e=4))
    t_t = timed(lambda: flash_attention(q, k, v, causal=True, interpret=True,
                                        **fb), **it)
    rows.append((f"kernels/flash_attention/pallas_interpret_tuned/S{S}",
                 t_t * 1e6, f"{ftag};vs_default={t_t/t_k:.2f}x"))

    # ssd — naive recurrence, chunk kernel (interpret)
    Bs, Ss, Hs, P, N = (1, 128, 2, 8, 16) if smoke else (1, 512, 4, 16, 32)
    x = jax.random.normal(key, (Bs, Ss, Hs, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(key, (Bs, Ss, Hs)))
    A = -jnp.exp(jax.random.normal(key, (Hs,)) * 0.3)
    Bm = jax.random.normal(key, (Bs, Ss, Hs, N)) * 0.5
    Cm = jax.random.normal(key, (Bs, Ss, Hs, N)) * 0.5
    t_ref = timed(lambda: ssd_ref(x, dt, A, Bm, Cm), **it)
    rows.append((f"kernels/ssd/naive_ref/S{Ss}", t_ref * 1e6, "recurrence"))
    t_k = timed(lambda: ssd_chunk(x, dt, A, Bm, Cm, chunk=64, interpret=True),
                **it)
    rows.append((f"kernels/ssd/chunk_interpret/S{Ss}", t_k * 1e6,
                 f"speedup_vs_naive={t_ref/t_k:.2f}x"))
    # the paired rows compare tuned blocks against the kernel's OWN default
    # call (the chunk=64 row above pins an explicit chunk, not the default)
    sb, stag = tuned("ssd_scan", dict(B=Bs, S=Ss, H=Hs, P=P, N=N, e=4))
    t_def = timed(lambda: ssd_chunk(x, dt, A, Bm, Cm, interpret=True), **it)
    t_t = timed(lambda: ssd_chunk(x, dt, A, Bm, Cm, interpret=True, **sb),
                **it)
    rows.append((f"kernels/ssd/chunk_interpret_tuned/S{Ss}", t_t * 1e6,
                 f"{stag};vs_default={t_t/t_def:.2f}x"))

    # conv2d implicit GEMM — the CNN hot path: stride-1, ResNet's stride-2
    # bottleneck shape, and the halo-aware entry (pre-exchanged tile)
    HWC = (4, 16, 16, 32) if smoke else (4, 32, 32, 64)
    F = 64 if smoke else 128
    xc = jax.random.normal(key, HWC)
    wc = jax.random.normal(key, (3, 3, HWC[-1], F)) * 0.1
    flops = 2 * HWC[0] * HWC[1] * HWC[2] * HWC[3] * F * 9
    t_ref = timed(lambda: conv2d_ref(xc, wc), **it)
    shape_tag = "x".join(str(d) for d in HWC[1:]) + f"x{F}"
    rows.append((f"kernels/conv2d/ref/{shape_tag}", t_ref * 1e6,
                 f"flops={flops:.3e};tpu_proj_us={flops/197e12*1e6:.2f}"))
    t_k = timed(lambda: conv2d_gemm(xc, wc, interpret=True), **it)
    rows.append((f"kernels/conv2d/gemm_interpret/{shape_tag}", t_k * 1e6,
                 f"flops={flops:.3e};ref_ratio={t_k/t_ref:.2f}x"))
    cb, ctag = tuned("conv2d_gemm",
                     dict(B=HWC[0], H=HWC[1], W=HWC[2], C=HWC[3], F=F,
                          kh=3, kw=3, sh=1, sw=1, e=4))
    t_t = timed(lambda: conv2d_gemm(xc, wc, interpret=True, **cb), **it)
    rows.append((f"kernels/conv2d/gemm_interpret_tuned/{shape_tag}",
                 t_t * 1e6,
                 f"{ctag};ref_ratio={t_t/t_ref:.2f}x"
                 f";vs_default={t_t/t_k:.2f}x"))
    t_s2 = timed(lambda: conv2d_gemm(xc, wc, strides=(2, 2), interpret=True),
                 **it)
    rows.append((f"kernels/conv2d/gemm_interpret_s2/{shape_tag}", t_s2 * 1e6,
                 f"flops={flops/4:.3e};resnet_bottleneck_stride2"))
    xh = jax.random.normal(key, (HWC[0], HWC[1] + 2, HWC[2], HWC[3]))
    t_h = timed(lambda: conv2d_gemm(xh, wc, pad_h=False, interpret=True),
                **it)
    rows.append((f"kernels/conv2d/gemm_interpret_halo/{shape_tag}",
                 t_h * 1e6, "pad_h=False;consumes pre-exchanged tile"))

    # rmsnorm — ref AND kernel
    R, Dm = (512, 256) if smoke else (4096, 1024)
    xr = jax.random.normal(key, (R, Dm))
    sc = jnp.ones((Dm,))
    nbytes = xr.size * 4 * 2
    t_ref = timed(lambda: rmsnorm_ref(xr, sc), **it)
    rows.append((f"kernels/rmsnorm/ref/{R}x{Dm}", t_ref * 1e6,
                 f"bytes={nbytes:.3e};tpu_proj_us={nbytes/819e9*1e6:.2f}"))
    t_k = timed(lambda: rmsnorm(xr, sc, interpret=True), **it)
    rows.append((f"kernels/rmsnorm/pallas_interpret/{R}x{Dm}", t_k * 1e6,
                 f"bytes={nbytes:.3e};ref_ratio={t_k/t_ref:.2f}x"))
    rb, rtag = tuned("rmsnorm", dict(R=R, D=Dm, e=4))
    t_t = timed(lambda: rmsnorm(xr, sc, interpret=True, **rb), **it)
    rows.append((f"kernels/rmsnorm/pallas_interpret_tuned/{R}x{Dm}",
                 t_t * 1e6, f"{rtag};vs_default={t_t/t_k:.2f}x"))
    return rows


def write_artifact(rows, smoke: bool, out: str | None = None) -> str:
    rec = {"timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
           "backend": jax.default_backend(), "smoke": smoke,
           "rows": [{"name": n, "us_per_call": round(us, 3), "derived": d}
                    for n, us, d in rows]}
    path = out or (SMOKE_ARTIFACT if smoke else ARTIFACT)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
        f.write("\n")
    return path


def main(argv=None):
    ap = argparse.ArgumentParser(prog="python -m benchmarks.bench_kernels")
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes, 1 timed iter (CI gate); still runs "
                         "every Pallas kernel, writes the side artifact "
                         "(the committed trajectory records full runs only)")
    ap.add_argument("--out", default=None,
                    help="write the artifact to this path instead of the "
                         "default — scripts/bench_compare.py uses this to "
                         "land a fresh full run in a scratch file and diff "
                         "it against the committed trajectory")
    # parse_known_args: benchmarks.run invokes main() programmatically —
    # a foreign sys.argv flag must not SystemExit the whole suite
    args, _ = ap.parse_known_args(argv)
    note("kernel micro-benchmarks (CPU wall incl. Pallas interpret mode; "
         "TPU projections in derived)")
    rows = run(smoke=args.smoke)
    emit(rows)
    note(f"wrote {write_artifact(rows, args.smoke, out=args.out)}")


if __name__ == "__main__":
    main()
