"""Kernel micro-benchmarks: Pallas (interpret) vs jnp reference wall time.

interpret-mode timings are NOT TPU performance (the kernels target TPU; this
box is CPU) — the derived column reports the ref wall time and the FLOPs the
kernel would execute, which the roofline converts to TPU projections.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import (attention_ref, conv2d_ref, rmsnorm_ref, ssd_chunk, ssd_ref)

from .common import emit, note, timed


def run():
    key = jax.random.PRNGKey(0)
    rows = []
    # flash attention
    B, H, S, D = 1, 4, 512, 64
    q, k, v = (jax.random.normal(jax.random.fold_in(key, i), (B, H, S, D))
               for i in range(3))
    t_ref = timed(lambda: attention_ref(q, k, v))
    flops = 4 * B * H * S * S * D / 2
    rows.append((f"kernels/flash_attention/ref/S{S}", t_ref * 1e6,
                 f"flops={flops:.3e};tpu_proj_us={flops/197e12*1e6:.2f}"))
    # ssd
    Bs, Ss, Hs, P, N = 1, 512, 4, 16, 32
    x = jax.random.normal(key, (Bs, Ss, Hs, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(key, (Bs, Ss, Hs)))
    A = -jnp.exp(jax.random.normal(key, (Hs,)) * 0.3)
    Bm = jax.random.normal(key, (Bs, Ss, Hs, N)) * 0.5
    Cm = jax.random.normal(key, (Bs, Ss, Hs, N)) * 0.5
    t_ref = timed(lambda: ssd_ref(x, dt, A, Bm, Cm))
    rows.append((f"kernels/ssd/naive_ref/S{Ss}", t_ref * 1e6, "recurrence"))
    t_k = timed(lambda: ssd_chunk(x, dt, A, Bm, Cm, chunk=64, interpret=True))
    rows.append((f"kernels/ssd/chunk_interpret/S{Ss}", t_k * 1e6,
                 f"speedup_vs_naive={t_ref/t_k:.2f}x"))
    # conv
    xc = jax.random.normal(key, (4, 32, 32, 64))
    wc = jax.random.normal(key, (3, 3, 64, 128)) * 0.1
    t_ref = timed(lambda: conv2d_ref(xc, wc))
    flops = 2 * 4 * 32 * 32 * 64 * 128 * 9
    rows.append(("kernels/conv2d/ref/32x32x64x128", t_ref * 1e6,
                 f"flops={flops:.3e};tpu_proj_us={flops/197e12*1e6:.2f}"))
    # rmsnorm
    xr = jax.random.normal(key, (4096, 1024))
    sc = jnp.ones((1024,))
    t_ref = timed(lambda: rmsnorm_ref(xr, sc))
    rows.append(("kernels/rmsnorm/ref/4096x1024", t_ref * 1e6,
                 f"bytes={xr.size*4*2:.3e};"
                 f"tpu_proj_us={xr.size*4*2/819e9*1e6:.2f}"))
    return rows


def main():
    note("kernel micro-benchmarks (CPU wall; TPU projections in derived)")
    emit(run())


if __name__ == "__main__":
    main()
