"""Paper Fig. 8 / §5.3.3: filter-parallel compute does not scale perfectly.

The paper found conv kernels + split/concat overheads keep filter-parallel
compute from scaling 1/p. We measure the filter-sharded step on host devices
vs p=1, and report the efficiency the oracle would have assumed perfect.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.validation import measure_step
from repro.models import LMConfig, TransformerLM
from repro.nn import AttentionConfig, FFNConfig
from repro.nn.module import NULL_CTX, tree_init
from repro.optim.optimizers import OptimizerConfig
from repro.training.steps import make_train_step, train_state_spec

from .common import emit, note, timed


def run():
    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh()
    p = 1
    for v in mesh.shape.values():
        p *= v
    cfg = LMConfig(name="b", vocab=256, d_model=128, n_layers=4,
                   attn=AttentionConfig(128, 8, 8, 16, dtype=jnp.float32),
                   ffn=FFNConfig(128, 512, dtype=jnp.float32),
                   dtype=jnp.float32)
    model = TransformerLM(cfg)
    key = jax.random.PRNGKey(0)
    batch = {"tokens": jax.random.randint(key, (8, 128), 0, 256)}
    opt = OptimizerConfig(name="sgd", zero1=False)
    kw = dict(attn_impl="plain", scan_layers=False, remat=False)
    serial = jax.jit(make_train_step(model, opt, NULL_CTX, **kw))
    state = tree_init(train_state_spec(model, opt), key)
    t1 = timed(serial, state, batch)
    tp = measure_step(model, cfg, batch, mesh, "filter")
    # on time-shared virtual devices ideal tp == t1 (compute conserved);
    # overhead factor isolates the split/concat + collective cost (Fig 8)
    overhead = tp / t1
    return [("fig8/filter/serial", t1 * 1e6, "baseline"),
            (f"fig8/filter/p{p}", tp * 1e6,
             f"overhead_vs_ideal={overhead:.2f}x")]


def main():
    note("Fig 8 — filter-parallel compute overhead (measured, host devices)")
    emit(run())


if __name__ == "__main__":
    main()
