"""Paper Fig. 5: spatial+data (ds) scaling for CosmoFlow.

One vectorized sweep (core/sweep.py) over p = 4 … 1024 projects ds at EVERY
divisor factorization p1·p2 against pure spatial at equal p on the paper's
cluster model — the paper's 'perfect scaling' curve. Derived values = best
ds split, its speedup over spatial, and the engine's crossover point (the
smallest p where ds overtakes spatial).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import OracleConfig, PAPER_V100_CLUSTER, TimeModel, stats_for
from repro.core.sweep import sweep
from repro.models.cnn import CosmoFlowConfig

from .common import emit, note


def run():
    stats = stats_for(CosmoFlowConfig(img=128))
    tm = TimeModel(PAPER_V100_CLUSTER)
    rows = []
    n_points = 0
    t0 = time.perf_counter()
    for p in (4, 16, 64, 256, 1024):
        B = max(p // 4, 4)    # weak scaling: 0.25 samples/GPU (paper §5.1)
        cfg = OracleConfig(B=B, D=1584)
        # spatial saturates at min spatial extent; compare at equal batch
        p_sp = min(p, 64)
        res = sweep(stats, tm, cfg, sorted({p_sp, p}),
                    strategies=("spatial", "ds"),
                    mem_cap=tm.system.mem_capacity)
        n_points += len(res)
        spatial = res.best_per_p("spatial", require_ok=False)
        sp_of = {int(q): float(t) for q, t in zip(spatial.p, spatial.total_s)}
        ds = res.best_per_p("ds", require_ok=False)
        i = int(np.flatnonzero(ds.p == p)[0])
        speedup = sp_of[p_sp] / float(ds.total_s[i]) if ds.total_s[i] else 0.0
        it = max(float(ds.iterations[i]), 1.0)
        rows.append((f"fig5/cosmoflow/ds/p{p}", 0.0,
                     f"ds_iter_ms={float(ds.total_s[i])/it*1e3:.2f};"
                     f"split={int(ds.p1[i])}x{int(ds.p2[i])};"
                     f"speedup_vs_spatial={speedup:.2f};"
                     f"feasible={bool(ds.feasible[i])};"
                     f"bottleneck={ds.bottleneck[i]}"))
    us = (time.perf_counter() - t0) * 1e6
    rows = [(name, us / max(n_points, 1), derived) for name, _, derived in rows]
    # crossover under one weak-scaling lattice (B varies with p per §5.1)
    batch_of = lambda p: max(p // 4, 4)   # noqa: E731
    wk = sweep(stats, tm, OracleConfig(B=batch_of(1024), D=1584),
               (4, 16, 64, 256, 1024), strategies=("spatial", "ds"),
               batch_for_p=batch_of, mem_cap=tm.system.mem_capacity)
    rows.append(("fig5/cosmoflow/crossover_spatial_to_ds", us,
                 f"p={wk.crossover('spatial', 'ds')};"
                 f"lattice_points={n_points + len(wk)}"))
    return rows


def main():
    note("Fig 5 — CosmoFlow ds scaling (weak scaling, vectorized sweep)")
    emit(run())


if __name__ == "__main__":
    main()
