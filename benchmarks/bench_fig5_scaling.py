"""Paper Fig. 5: spatial+data (ds) scaling for CosmoFlow.

Oracle projection of ds vs pure-spatial speedup at p = 4 … 1024 on the
paper's cluster model — the paper's 'perfect scaling' curve. Derived value =
speedup of ds over pure spatial at equal p (paper's labels).
"""
from __future__ import annotations

import time

from repro.core import OracleConfig, PAPER_V100_CLUSTER, TimeModel, project, stats_for
from repro.models.cnn import CosmoFlowConfig

from .common import emit, note


def run():
    stats = stats_for(CosmoFlowConfig(img=128))
    tm = TimeModel(PAPER_V100_CLUSTER)
    rows = []
    for p in (4, 16, 64, 256, 1024):
        B = max(p // 4, 4)  # weak scaling: 0.25 samples/GPU (paper §5.1)
        cfg = OracleConfig(B=B, D=1584)
        t0 = time.perf_counter()
        spatial = project("spatial", stats, tm, cfg, min(p, 64))
        ds = project("ds", stats, tm, cfg, p, p1=max(p // 4, 1), p2=min(p, 4))
        us = (time.perf_counter() - t0) * 1e6
        speedup = spatial.total_s / ds.total_s if ds.total_s else 0.0
        rows.append((f"fig5/cosmoflow/ds/p{p}", us,
                     f"ds_iter_ms={ds.per_iteration()['total_s']*1e3:.2f};"
                     f"speedup_vs_spatial={speedup:.2f};"
                     f"feasible={ds.feasible}"))
    return rows


def main():
    note("Fig 5 — CosmoFlow ds scaling (weak scaling, oracle projection)")
    emit(run())


if __name__ == "__main__":
    main()
