"""Chaos harness: elastic recovery under injected faults (DESIGN.md §12).

The scenario matrix runs in subprocesses with 8 virtual host devices
(tests/helpers/chaos_checks.py) and is marked ``chaos`` — excluded from the
tier-1 fast path, run by ``pytest -m chaos`` / scripts/check.sh's
chaos-gate. The FaultPlan unit tests below are cheap and unmarked, so the
injection helper itself stays covered by tier-1.
"""
import os
import subprocess
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "helpers"))

HELPER = os.path.join(os.path.dirname(__file__), "helpers",
                      "chaos_checks.py")


def run_scenario(name: str, timeout: int = 420):
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, HELPER, name], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert "CHECK-PASSED" in out.stdout, \
        f"{name} failed:\nstdout:{out.stdout[-2000:]}\nstderr:{out.stderr[-3000:]}"


@pytest.mark.chaos
@pytest.mark.parametrize("scenario", ["kill_midrun", "straggler_burst",
                                      "torn_checkpoint", "transient_spaced"])
def test_chaos_scenario(scenario):
    """Kill-at-step / straggler-burst / torn-checkpoint / spaced-transients,
    each pinning the recovery ≡ planned-reshape contract bit for bit."""
    run_scenario(scenario)


# ---------------------------------------------------------------------------
# FaultPlan unit tests (in-process, tier-1)
# ---------------------------------------------------------------------------

def test_fault_plan_faults_fire_once():
    from fault_plan import FaultPlan

    from repro.runtime.fault_tolerance import SliceLost
    fp = FaultPlan(kill_at={3: 1}, fail_at=(5,), straggle={7: 4.2})
    inject = fp.injector()
    with pytest.raises(SliceLost) as e:
        inject(3)
    assert e.value.dim == 1 and e.value.step == 3
    assert inject(3) is None          # replaying the step: no re-fire
    with pytest.raises(RuntimeError):
        inject(5)
    assert inject(5) is None
    assert inject(7) == 4.2           # straggle: simulated step seconds
    assert inject(0) is None


def test_fault_plan_tear_needs_checkpointer():
    from fault_plan import FaultPlan
    with pytest.raises(ValueError):
        FaultPlan(kill_at={1: 0}, tear_on_kill=True).injector()


def test_tear_latest_unmarks_newest(tmp_path, key):
    import jax

    from fault_plan import tear_latest

    from repro.checkpoint.checkpointing import Checkpointer
    ck = Checkpointer(tmp_path, keep=10)
    state = {"w": jax.random.normal(key, (4,))}
    ck.save(state, 4)
    ck.save(state, 8)
    assert tear_latest(ck) == 8
    # the torn checkpoint is invisible; recovery falls back to 4
    assert ck.latest_step() == 4
    _, step = ck.restore(state)
    assert step == 4


def test_tear_latest_requires_a_checkpoint(tmp_path):
    from fault_plan import tear_latest

    from repro.checkpoint.checkpointing import Checkpointer
    with pytest.raises(FileNotFoundError):
        tear_latest(Checkpointer(tmp_path))
