"""Checkpointing: bit-exact restore, atomicity, retention, config guard."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointing import Checkpointer


def _state(key):
    return {"params": {"w": jax.random.normal(key, (4, 4)),
                       "layers": [jnp.arange(3.0), jnp.arange(5.0)]},
            "step": jnp.int32(7)}


def test_save_restore_bit_exact(tmp_path, key):
    ck = Checkpointer(tmp_path)
    state = _state(key)
    ck.save(state, 10)
    restored, step = ck.restore(state)
    assert step == 10
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b),
                 state, restored)


def test_async_save(tmp_path, key):
    ck = Checkpointer(tmp_path)
    ck.save(_state(key), 5, blocking=False)
    ck.wait()
    assert ck.latest_step() == 5


def test_incomplete_checkpoint_ignored(tmp_path, key):
    ck = Checkpointer(tmp_path)
    ck.save(_state(key), 10)
    # simulate a crash mid-write: directory without .complete marker
    bad = tmp_path / "step_00000020"
    bad.mkdir()
    (bad / "manifest.json").write_text("{}")
    assert ck.latest_step() == 10


def test_retention_gc(tmp_path, key):
    ck = Checkpointer(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        ck.save(_state(key), s)
    assert ck.completed_steps() == [3, 4]


def test_config_tag_guard(tmp_path, key):
    ck = Checkpointer(tmp_path, config_tag="modelA")
    ck.save(_state(key), 1)
    ck2 = Checkpointer(tmp_path, config_tag="modelB")
    with pytest.raises(ValueError):
        ck2.restore(_state(key))


def test_torn_write_falls_back_to_previous_complete(tmp_path, key):
    """Crash consistency: a checkpoint whose arrays and manifest landed
    but whose .complete marker did not (the crash hit mid-commit) is
    invisible — latest_step/restore fall back to the previous complete
    one, bit-exact."""
    ck = Checkpointer(tmp_path)
    state = _state(key)
    ck.save(state, 10)
    ck.save(state, 20)
    assert (tmp_path / "step_00000020" / "arrays.npz").exists()
    (tmp_path / "step_00000020" / ".complete").unlink()   # torn write
    assert ck.completed_steps() == [10]
    assert ck.latest_step() == 10
    restored, step = ck.restore(state)
    assert step == 10
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b),
                 state, restored)


def test_torn_write_with_explicit_step_overwritten_by_next_save(tmp_path,
                                                                key):
    """A torn directory is not left to rot: the next save of the same step
    replaces it atomically and the checkpoint becomes visible again."""
    ck = Checkpointer(tmp_path)
    state = _state(key)
    ck.save(state, 10)
    (tmp_path / "step_00000010" / ".complete").unlink()
    assert ck.latest_step() is None
    ck.save(state, 10)
    assert ck.latest_step() == 10


def test_async_gc_thread_safe_vs_concurrent_reads(tmp_path, key):
    """Async saves run retention GC in a background thread while the train
    loop polls completed_steps/latest_step and (on a failure) restores.
    The shared lock must guarantee that whatever latest_step returns is
    restorable — the GC can never delete a checkpoint mid-read."""
    ck = Checkpointer(tmp_path, keep=2)
    state = _state(key)
    for n in range(1, 13):
        ck.save(state, n, blocking=False)
        for _ in range(25):
            latest = ck.latest_step()
            if latest is None:
                continue
            restored, got = ck.restore(state, step=latest)
            assert got == latest
    ck.wait()
    assert ck.latest_step() == 12
    assert len(ck.completed_steps()) == 2
