"""Checkpointing: bit-exact restore, atomicity, retention, config guard."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointing import Checkpointer


def _state(key):
    return {"params": {"w": jax.random.normal(key, (4, 4)),
                       "layers": [jnp.arange(3.0), jnp.arange(5.0)]},
            "step": jnp.int32(7)}


def test_save_restore_bit_exact(tmp_path, key):
    ck = Checkpointer(tmp_path)
    state = _state(key)
    ck.save(state, 10)
    restored, step = ck.restore(state)
    assert step == 10
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b),
                 state, restored)


def test_async_save(tmp_path, key):
    ck = Checkpointer(tmp_path)
    ck.save(_state(key), 5, blocking=False)
    ck.wait()
    assert ck.latest_step() == 5


def test_incomplete_checkpoint_ignored(tmp_path, key):
    ck = Checkpointer(tmp_path)
    ck.save(_state(key), 10)
    # simulate a crash mid-write: directory without .complete marker
    bad = tmp_path / "step_00000020"
    bad.mkdir()
    (bad / "manifest.json").write_text("{}")
    assert ck.latest_step() == 10


def test_retention_gc(tmp_path, key):
    ck = Checkpointer(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        ck.save(_state(key), s)
    assert ck.completed_steps() == [3, 4]


def test_config_tag_guard(tmp_path, key):
    ck = Checkpointer(tmp_path, config_tag="modelA")
    ck.save(_state(key), 1)
    ck2 = Checkpointer(tmp_path, config_tag="modelB")
    with pytest.raises(ValueError):
        ck2.restore(_state(key))
