import os
import sys

# Tests must see the default single host device (the dry-run sets its own
# XLA_FLAGS in a separate process); never leak a device-count override here.
os.environ.pop("XLA_FLAGS", None)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import pytest  # noqa: E402


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: multi-device subprocess checks (minutes)")
    config.addinivalue_line(
        "markers", "chaos: elastic-training chaos scenarios (subprocess, "
        "virtual devices) — excluded from the tier-1 fast path; run with "
        "'pytest -m chaos' or scripts/check.sh's chaos-gate")


def pytest_collection_modifyitems(config, items):
    # chaos scenarios stay out of the tier-1 fast path: they only run when
    # selected explicitly (-m chaos) or by the CI chaos-gate (RUN_CHAOS=1)
    markexpr = config.getoption("-m", default="") or ""
    if "chaos" in markexpr or os.environ.get("RUN_CHAOS"):
        return
    skip = pytest.mark.skip(
        reason="chaos scenario: run with -m chaos (check.sh chaos-gate)")
    for item in items:
        if "chaos" in item.keywords:
            item.add_marker(skip)


@pytest.fixture()
def key():
    return jax.random.PRNGKey(0)
