import os
import sys

# Tests must see the default single host device (the dry-run sets its own
# XLA_FLAGS in a separate process); never leak a device-count override here.
os.environ.pop("XLA_FLAGS", None)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import pytest  # noqa: E402


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: multi-device subprocess checks (minutes)")


@pytest.fixture()
def key():
    return jax.random.PRNGKey(0)
