"""Attention units: flash == plain across shapes/masks; decode caches."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.nn import (Attention, AttentionConfig, MLAttention, MLAConfig,
                      flash_attention, plain_attention)
from repro.nn.module import tree_init


@pytest.mark.parametrize("window", [None, 24])
@pytest.mark.parametrize("unroll", [False, True])
@pytest.mark.parametrize("qc,kc", [(16, 16), (32, 8), (64, 64)])
def test_flash_equals_plain(key, window, unroll, qc, kc):
    B, S, H, D = 2, 64, 4, 16
    q = jax.random.normal(key, (B, S, H, D), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, H, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, H, D))
    ref = plain_attention(q, k, v, causal=True, window=window)
    out = flash_attention(q, k, v, causal=True, window=window,
                          q_chunk=qc, kv_chunk=kc, unroll=unroll)
    np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)


def test_flash_softcap_and_noncausal(key):
    B, S, H, D = 1, 32, 2, 8
    q, k, v = (jax.random.normal(jax.random.fold_in(key, i), (B, S, H, D))
               for i in range(3))
    for causal in (False, True):
        ref = plain_attention(q, k, v, causal=causal, softcap=10.0)
        out = flash_attention(q, k, v, causal=causal, softcap=10.0,
                              q_chunk=8, kv_chunk=8)
        np.testing.assert_allclose(out, ref, rtol=3e-5, atol=3e-5)


def test_flash_auto_chunk_non_divisible(key):
    # whisper encoder: S=1500 does not divide 1024 — auto-fit must handle
    B, S, H, D = 1, 30, 2, 8
    q, k, v = (jax.random.normal(jax.random.fold_in(key, i), (B, S, H, D))
               for i in range(3))
    out = flash_attention(q, k, v, causal=False, q_chunk=16, kv_chunk=16)
    ref = plain_attention(q, k, v, causal=False)
    np.testing.assert_allclose(out, ref, rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("shards", [1, 4])
def test_gqa_decode_matches_full(key, shards):
    B, S = 2, 32
    cfg = AttentionConfig(d_model=32, n_heads=4, n_kv_heads=2, head_dim=8,
                          qk_norm=True, use_bias=True)
    att = Attention(cfg)
    p = tree_init(att.params_spec(), key)
    x = jax.random.normal(key, (B, S, 32))
    full = att.apply(p, x, impl="plain")
    cache = jax.tree.map(jnp.zeros_like,
                         tree_init(att.cache_spec(B, S, shards=shards,
                                                  dtype=jnp.float32), key))
    outs = []
    for t in range(S):
        y, cache = att.decode(p, x[:, t:t + 1], cache, t)
        outs.append(y)
    np.testing.assert_allclose(jnp.concatenate(outs, 1), full,
                               rtol=2e-4, atol=2e-4)


def test_windowed_ring_buffer_decode(key):
    B, S, W = 2, 64, 16
    cfg = AttentionConfig(d_model=32, n_heads=4, n_kv_heads=4, head_dim=8,
                          window=W)
    att = Attention(cfg)
    p = tree_init(att.params_spec(), key)
    x = jax.random.normal(key, (B, S, 32))
    full = att.apply(p, x, impl="plain")
    cache = jax.tree.map(jnp.zeros_like,
                         tree_init(att.cache_spec(B, W, shards=2,
                                                  dtype=jnp.float32), key))
    outs = []
    for t in range(S):
        y, cache = att.decode(p, x[:, t:t + 1], cache, t)
        outs.append(y)
    np.testing.assert_allclose(jnp.concatenate(outs, 1), full,
                               rtol=2e-4, atol=2e-4)


def test_mla_decode_matches_full(key):
    B, S = 2, 32
    cfg = MLAConfig(d_model=64, n_heads=4, q_lora_rank=32, kv_lora_rank=16,
                    qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16)
    mla = MLAttention(cfg)
    p = tree_init(mla.params_spec(), key)
    x = jax.random.normal(key, (B, S, 64))
    full = mla.apply(p, x, impl="plain")
    cache = jax.tree.map(jnp.zeros_like,
                         tree_init(mla.cache_spec(B, S, dtype=jnp.float32), key))
    outs = []
    for t in range(S):
        y, cache = mla.decode(p, x[:, t:t + 1], cache, t)
        outs.append(y)
    np.testing.assert_allclose(jnp.concatenate(outs, 1), full,
                               rtol=3e-4, atol=3e-4)


def test_cross_attention(key):
    cfg = AttentionConfig(d_model=32, n_heads=4, n_kv_heads=4, head_dim=8,
                          use_bias=True, out_bias=True, rope=False,
                          causal=False)
    att = Attention(cfg)
    p = tree_init(att.params_spec(), key)
    x = jax.random.normal(key, (2, 8, 32))
    enc = jax.random.normal(jax.random.fold_in(key, 1), (2, 16, 32))
    k, v = att.kv(p, enc)
    y1 = att.apply_cross(p, x, k, v, impl="plain")
    y2 = att.apply_cross(p, x, k, v, impl="chunked", q_chunk=4, kv_chunk=4)
    assert y1.shape == (2, 8, 32)
    np.testing.assert_allclose(y1, y2, rtol=3e-5, atol=3e-5)
