"""Module system: init determinism, path ordering, sharding resolution."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="property tests need hypothesis (not in image)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.nn.module import (Rules, param, spec_to_pspec, tree_abstract,
                             tree_init, tree_num_bytes, tree_num_params)


def test_tree_init_deterministic(key):
    spec = {"a": param((4, 8), ("embed", "mlp")),
            "b": [param((2,), ("mlp",)) for _ in range(3)]}
    t1 = tree_init(spec, key)
    t2 = tree_init(spec, key)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b), t1, t2)


def test_tree_init_long_list_ordering(key):
    """Regression: >10 list entries must init in index order (path-sort bug)."""
    spec = {"convs": [param((1,), (None,),
                            init=lambda k, s, d, i=i: jnp.full(s, float(i)))
                      for i in range(13)]}
    t = tree_init(spec, key)
    for i, leaf in enumerate(t["convs"]):
        assert float(leaf[0]) == float(i)


def test_num_params_bytes():
    spec = {"w": param((4, 8), ("embed", "mlp"), dtype=jnp.bfloat16)}
    assert tree_num_params(spec) == 32
    assert tree_num_bytes(spec) == 64


def _mesh11():
    from repro.launch.compat import make_mesh
    return make_mesh((1, 1), ("data", "model"))


def test_spec_to_pspec_divisibility_fallback():
    mesh = _mesh11()
    rules = Rules.of({"heads": "model", "mlp": "model"})
    # size-1 axes: anything shards trivially; exercise resolution machinery
    ps = spec_to_pspec(("heads", None), rules, mesh, (8, 4))
    assert ps == jax.sharding.PartitionSpec("model", None)


def test_spec_to_pspec_axis_used_once():
    mesh = _mesh11()
    rules = Rules.of({"seq": "model", "heads": "model"})
    ps = spec_to_pspec(("seq", "heads"), rules, mesh, (8, 8))
    # first dim claims the axis; second must fall back to None
    assert ps[0] == "model" and ps[1] is None


def test_rules_unknown_axis_rejected():
    with pytest.raises(ValueError):
        Rules.of({"bogus": "model"})


@given(dim=st.integers(1, 64))
@settings(max_examples=20, deadline=None)
def test_abstract_matches_init_shapes(dim):
    spec = {"w": param((dim, 2 * dim), ("embed", "mlp"))}
    ab = tree_abstract(spec)
    real = tree_init(spec, jax.random.PRNGKey(0))
    assert ab["w"].shape == real["w"].shape
    assert ab["w"].dtype == real["w"].dtype


def test_strategies_resolve_for_all_archs():
    """Every (strategy × arch param tree) resolves without error."""
    from repro.configs import ASSIGNED_ARCHS, get_config
    from repro.launch.build import build_model
    from repro.parallel.strategies import list_strategies, make_rules
    mesh = _mesh11()
    for arch in ASSIGNED_ARCHS:
        model = build_model(get_config(arch), smoke=True)
        spec = model.params_spec()
        for strat in list_strategies():
            rules = make_rules(strat)
            from repro.nn.module import tree_shardings
            tree_shardings(spec, mesh, rules)  # must not raise
