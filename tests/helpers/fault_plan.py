"""FaultPlan — deterministic fault injection for the chaos harness.

A ``FaultPlan`` declares *what breaks and when*; ``injector()`` compiles it
into the ``inject`` hook ``run_with_recovery`` / ``run_elastic`` call with
the step index before each step executes. Faults fire exactly once per
declared step, so replayed steps (the loop revisits step indices after a
restore) do not re-trigger them — matching real failures, which do not
reappear just because the clock rewound.

Fault kinds:

* ``kill_at``: step → torus dim. Raises ``SliceLost`` — abrupt slice
  death: live state and the killed devices are gone; the elastic
  controller must re-plan on the survivors and reshard from the
  checkpoint.
* ``fail_at``: steps raising a transient ``RuntimeError`` once each — a
  node flake; ``run_with_recovery`` restores-and-replays on the same mesh.
* ``straggle``: step → simulated duration in seconds, returned to the
  loop in place of the wall-clock step time (a deterministic slow host
  for the ``StepTimer`` → patience-escalation path).
* ``tear_on_kill``: when a kill fires, first tear the newest checkpoint
  (``tear_latest`` — arrays present, ``.complete`` missing), so recovery
  must fall back to the previous complete one: the crash-consistency
  contract under a failure that interrupts a save.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.runtime.fault_tolerance import SliceLost


def tear_latest(ckpt) -> int:
    """Simulate a torn write: the newest checkpoint loses its commit
    marker (arrays and manifest still present, ``.complete`` gone), as if
    the failure landed mid-save. Returns the torn step."""
    steps = ckpt.completed_steps()
    if not steps:
        raise FileNotFoundError(f"no complete checkpoint in {ckpt.dir}")
    (ckpt.dir / f"step_{steps[-1]:08d}" / ".complete").unlink()
    return steps[-1]


@dataclass
class FaultPlan:
    kill_at: dict = field(default_factory=dict)     # step -> torus dim
    fail_at: tuple = ()                             # transient RuntimeErrors
    straggle: dict = field(default_factory=dict)    # step -> fake seconds
    tear_on_kill: bool = False

    def injector(self, ckpt=None):
        """The ``inject(step)`` hook. ``ckpt`` is only needed when
        ``tear_on_kill`` is set (the kill must reach into the store)."""
        if self.tear_on_kill and ckpt is None:
            raise ValueError("tear_on_kill needs the Checkpointer")
        fired: set = set()

        def inject(step: int):
            if step in self.kill_at and ("kill", step) not in fired:
                fired.add(("kill", step))
                if self.tear_on_kill:
                    ckpt.wait()
                    tear_latest(ckpt)
                raise SliceLost(step, dim=self.kill_at[step],
                                reason=f"injected slice death at step {step}")
            if step in self.fail_at and ("fail", step) not in fired:
                fired.add(("fail", step))
                raise RuntimeError(f"injected node failure at step {step}")
            return self.straggle.get(step)

        return inject
