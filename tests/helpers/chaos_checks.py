"""Chaos scenarios: the elastic loop under injected faults, 8 virtual devices.

Usage: python tests/helpers/chaos_checks.py <scenario-name>
Prints CHECK-PASSED on success (asserted by tests/test_chaos.py and run by
scripts/check.sh's chaos-gate).

Every scenario drives ``run_elastic`` on a tiny uniform LM over a (2, 4)
torus (model axis confined to dim 1) with a ``FaultPlan`` injector, and
pins the recovery contract bit for bit:

* the prefix of the loss trajectory — steps that completed before the
  fault and were never replayed — equals an uninterrupted baseline run;
* the suffix equals a *planned* degraded continuation: restore the
  baseline's own checkpoint under the re-tuned plan's shardings and run a
  plain (no fault machinery) step loop on the surviving mesh. Recovery
  must be indistinguishable from having planned the reshape;
* the re-tuned plan is valid on the shrunken topology (p1·p2 = surviving
  PE count and the torus ``split_mask`` accepts the factorization);
* final parameters match the reference continuation exactly.

Steps replayed after a restore overwrite their trajectory slot — the loss
recorded for a step index is the one the surviving run computed, which is
what the reference continuation reproduces.
"""
import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

V, D, L, B, S = 64, 32, 2, 8, 32
FWD = dict(attn_impl="plain", scan_layers=False, remat=False)


def _setup():
    """(session, data_cfg, model, opt): tiny LM on a (2,4)-torus host."""
    from dataclasses import replace

    from repro.api import Oracle
    from repro.configs.base import SHAPES, ArchConfig, ShapeSpec
    from repro.core.cluster import ClusterSpec, Torus
    from repro.data.pipeline import DataConfig
    from repro.models import LMConfig, TransformerLM
    from repro.nn import AttentionConfig, FFNConfig
    from repro.optim.optimizers import OptimizerConfig
    mc = LMConfig(name="t", vocab=V, d_model=D, n_layers=L,
                  attn=AttentionConfig(D, 4, 2, 8, dtype=jnp.float32),
                  ffn=FFNConfig(D, 2 * D, dtype=jnp.float32),
                  dtype=jnp.float32)
    model = TransformerLM(mc)
    SHAPES["train_tiny"] = ShapeSpec("train_tiny", S, B, "train")
    acfg = ArchConfig(name="chaos-test", family="lm", model=mc,
                      smoke_model=mc, source="test", strategy="df")
    cluster = replace(ClusterSpec.of("host"),
                      topology=Torus((2, 4), model_dims=(1,)))
    ses = Oracle(acfg, "train_tiny", cluster, batch=B, seq=S)
    data_cfg = DataConfig("lm", batch=B, seq_len=S, vocab=V)
    opt = OptimizerConfig(lr=1e-2, name="adamw", zero1=False)
    return ses, data_cfg, model, opt


def _run(ses, data_cfg, model, opt, ckpt, n_steps, fault=None, **kw):
    """One elastic run; returns (traj, events, host params)."""
    from repro.runtime.elastic import run_elastic
    traj = {}
    inject = fault.injector(ckpt) if fault is not None else None
    state, step, events = run_elastic(
        ses, data_cfg, ckpt, n_steps=n_steps, model=model, opt=opt,
        ckpt_every=4, inject=inject, fwd_kw=FWD, seed=0,
        on_metrics=lambda s, m: traj.__setitem__(s, float(m["loss"])), **kw)
    assert step == n_steps, (step, n_steps)
    params = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                          state["params"])
    return traj, events, params


def _reference_continuation(ses, data_cfg, model, opt, ck_base, resume_step,
                            n_steps, p_survive, dim):
    """A PLANNED degraded run: re-tune on the degraded ClusterSpec, restore
    the baseline's checkpoint under the new plan's shardings, and run a
    plain step loop — no fault machinery anywhere. Returns (traj, params,
    plan, degraded cluster)."""
    from repro.runtime.elastic import bind_plan
    from repro.training.steps import train_state_spec
    degraded = ses.cluster.degraded(dim=dim)
    assert degraded.topology.size == p_survive, degraded.topology
    b = bind_plan(ses.with_cluster(degraded), jax.devices()[:p_survive],
                  data_cfg, model, opt, FWD)
    st, s0 = ck_base.restore(train_state_spec(model, opt), step=resume_step,
                             shardings=b.shardings)
    traj = {}
    for s in range(s0, n_steps):
        st, m = b.step_fn(st, b.loader.batch_at(s))
        traj[s] = float(m["loss"])
    params = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                          st["params"])
    return traj, params, b.plan, degraded


def _assert_replan_valid(ev, degraded, p_survive):
    """The re-tuned plan must be deployable on the shrunken topology."""
    p1, p2 = ev.mesh_shape
    assert ev.p_after == p_survive and p1 * p2 == p_survive, ev
    assert bool(degraded.topology.split_mask(p_survive, p1, p2,
                                             ev.strategy)), \
        (ev, degraded.topology)


def _assert_bit_exact(traj, ref, lo, hi, what):
    for s in range(lo, hi):
        assert traj[s] == ref[s], \
            f"{what}: step {s} diverged: {traj[s]!r} != {ref[s]!r}"


def check_kill_midrun():
    """Slice death at step 10 of 16 (torus dim 0: (2,4) → (4,)): re-plan
    on the survivors, reshard from the checkpoint at 8, resume — prefix
    and suffix bit-exact, final params == the planned-reshape reference."""
    import tempfile

    from fault_plan import FaultPlan
    from repro.checkpoint.checkpointing import Checkpointer
    ses, data_cfg, model, opt = _setup()
    N = 16
    with tempfile.TemporaryDirectory() as da, \
            tempfile.TemporaryDirectory() as db:
        ck_a, ck_b = Checkpointer(da, keep=10), Checkpointer(db, keep=10)
        traj_a, ev_a, _ = _run(ses, data_cfg, model, opt, ck_a, N)
        assert ev_a == []
        traj_b, ev_b, params_b = _run(ses, data_cfg, model, opt, ck_b, N,
                                      fault=FaultPlan(kill_at={10: 0}))
        assert len(ev_b) == 1 and ev_b[0].cause == "failure", ev_b
        ev = ev_b[0]
        assert ev.p_before == 8 and ev.resumed_from == 8, ev
        ref, ref_params, plan2, degraded = _reference_continuation(
            ses, data_cfg, model, opt, ck_a, 8, N, 4, dim=0)
        _assert_replan_valid(ev, degraded, 4)
        assert (plan2.p1, plan2.p2) == ev.mesh_shape, (plan2, ev)
        _assert_bit_exact(traj_b, traj_a, 0, 8, "prefix vs baseline")
        _assert_bit_exact(traj_b, ref, 8, N, "suffix vs planned reshape")
        jax.tree.map(np.testing.assert_array_equal, params_b, ref_params)


def check_straggler_burst():
    """Two consecutive straggler alerts (simulated 9.9s steps at 9 and 10
    vs a millisecond median) exhaust patience=2: the loop checkpoints the
    healthy state at step 11 and escalates to SliceLost(straggler); the
    controller remeshes around the slow host. Graceful: NO step is lost or
    replayed — the whole pre-escalation trajectory matches the baseline,
    and the continuation matches a planned reshape from the baseline's
    state at step 11."""
    import tempfile

    from fault_plan import FaultPlan
    from repro.checkpoint.checkpointing import Checkpointer
    from repro.runtime.elastic import bind_plan
    from repro.runtime.fault_tolerance import remesh_state
    from repro.training.steps import train_state_spec
    ses, data_cfg, model, opt = _setup()
    N = 16
    with tempfile.TemporaryDirectory() as da, \
            tempfile.TemporaryDirectory() as db:
        ck_a, ck_b = Checkpointer(da, keep=10), Checkpointer(db, keep=10)
        traj_a, _, _ = _run(ses, data_cfg, model, opt, ck_a, N)
        traj_b, ev_b, params_b = _run(
            ses, data_cfg, model, opt, ck_b, N,
            fault=FaultPlan(straggle={9: 9.9, 10: 9.9}),
            straggler_patience=2)
        assert len(ev_b) == 1 and ev_b[0].cause == "straggler", ev_b
        ev = ev_b[0]
        # escalation fires AFTER the second straggling step completes, so
        # the state was saved at step 11 and nothing needs replaying
        assert ev.resumed_from == 11, ev
        _assert_bit_exact(traj_b, traj_a, 0, 11, "pre-escalation vs baseline")
        # reference: baseline state at 11 (plain steps from its ckpt@8),
        # remeshed in memory onto the degraded plan, then run plainly
        degraded = ses.cluster.degraded(dim=0)
        _assert_replan_valid(ev, degraded, 4)
        b1 = bind_plan(ses, jax.devices(), data_cfg, model, opt, FWD)
        st, s0 = ck_a.restore(train_state_spec(model, opt), step=8,
                              shardings=b1.shardings)
        for s in range(s0, 11):
            st, _ = b1.step_fn(st, b1.loader.batch_at(s))
        b2 = bind_plan(ses.with_cluster(degraded), jax.devices()[:4],
                       data_cfg, model, opt, FWD)
        st = remesh_state(st, shardings=b2.shardings)
        ref = {}
        for s in range(11, N):
            st, m = b2.step_fn(st, b2.loader.batch_at(s))
            ref[s] = float(m["loss"])
        _assert_bit_exact(traj_b, ref, 11, N, "suffix vs planned reshape")
        jax.tree.map(np.testing.assert_array_equal, params_b,
                     jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                  st["params"]))


def check_torn_checkpoint():
    """Slice death at step 10 that also tears the newest checkpoint (the
    save at 8 loses its .complete marker, as if the failure landed
    mid-write): recovery must fall back to the previous complete
    checkpoint at 4 and still land bit-exact on the planned-reshape
    trajectory from there."""
    import tempfile

    from fault_plan import FaultPlan
    from repro.checkpoint.checkpointing import Checkpointer
    ses, data_cfg, model, opt = _setup()
    N = 16
    with tempfile.TemporaryDirectory() as da, \
            tempfile.TemporaryDirectory() as db:
        ck_a, ck_b = Checkpointer(da, keep=10), Checkpointer(db, keep=10)
        traj_a, _, _ = _run(ses, data_cfg, model, opt, ck_a, N)
        traj_b, ev_b, params_b = _run(
            ses, data_cfg, model, opt, ck_b, N,
            fault=FaultPlan(kill_at={10: 0}, tear_on_kill=True))
        assert len(ev_b) == 1, ev_b
        ev = ev_b[0]
        # the torn step-8 checkpoint must NOT be restored from
        assert ev.resumed_from == 4, ev
        ref, ref_params, _, degraded = _reference_continuation(
            ses, data_cfg, model, opt, ck_a, 4, N, 4, dim=0)
        _assert_replan_valid(ev, degraded, 4)
        _assert_bit_exact(traj_b, traj_a, 0, 4, "prefix vs baseline")
        _assert_bit_exact(traj_b, ref, 4, N, "suffix vs planned reshape")
        jax.tree.map(np.testing.assert_array_equal, params_b, ref_params)


def check_transient_spaced():
    """Four transient node failures spread across 20 steps, restart budget
    max_restarts=3: forward progress (a fresh checkpoint between failures)
    resets the budget, so the run completes on the SAME mesh with zero
    elastic events — and every replayed step recomputes the identical
    loss, so the whole trajectory and the final params match the
    uninterrupted baseline bit for bit."""
    import tempfile

    from fault_plan import FaultPlan
    from repro.checkpoint.checkpointing import Checkpointer
    ses, data_cfg, model, opt = _setup()
    N = 20
    with tempfile.TemporaryDirectory() as da, \
            tempfile.TemporaryDirectory() as db:
        ck_a, ck_b = Checkpointer(da, keep=10), Checkpointer(db, keep=10)
        traj_a, _, params_a = _run(ses, data_cfg, model, opt, ck_a, N)
        traj_b, ev_b, params_b = _run(
            ses, data_cfg, model, opt, ck_b, N,
            fault=FaultPlan(fail_at=(5, 9, 13, 17)), max_restarts=3)
        assert ev_b == [], ev_b   # transient faults never trigger a re-plan
        _assert_bit_exact(traj_b, traj_a, 0, N, "trajectory vs baseline")
        jax.tree.map(np.testing.assert_array_equal, params_b, params_a)


CHECKS = {
    "kill_midrun": check_kill_midrun,
    "straggler_burst": check_straggler_burst,
    "torn_checkpoint": check_torn_checkpoint,
    "transient_spaced": check_transient_spaced,
}

if __name__ == "__main__":
    name = sys.argv[1]
    CHECKS[name]()
    print("CHECK-PASSED")
