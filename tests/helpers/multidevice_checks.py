"""Multi-device checks, run in a subprocess with 8 virtual host devices.

Usage: python tests/helpers/multidevice_checks.py <check-name>
Prints CHECK-PASSED on success (asserted by tests/test_distributed.py).
"""
import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402


def mesh24():
    from repro.launch.compat import make_mesh
    return make_mesh((2, 4), ("data", "model"))


def check_pipeline():
    from repro.parallel import gpipe, make_stage_fn, stack_stages
    mesh = mesh24()
    key = jax.random.PRNGKey(0)
    L, D, MB, S = 8, 16, 4, 4
    w = jax.random.normal(key, (L, D, D)) * 0.3

    def block(lp, h):
        return jnp.tanh(h @ lp)

    x = jax.random.normal(key, (S, MB, D))
    seq = x
    for i in range(L):
        seq = block(w[i], seq)
    out = gpipe(make_stage_fn(block), stack_stages(w, 4), x, mesh, "model")
    np.testing.assert_allclose(np.asarray(out), np.asarray(seq), rtol=2e-5,
                               atol=2e-5)

    def loss_pipe(sp):
        return jnp.mean(gpipe(make_stage_fn(block), sp, x, mesh, "model") ** 2)

    def loss_seq(wf):
        h = x
        for i in range(L):
            h = block(wf[i], h)
        return jnp.mean(h ** 2)

    g_pipe = jax.grad(loss_pipe)(stack_stages(w, 4)).reshape(L, D, D)
    g_seq = jax.grad(loss_seq)(w)
    np.testing.assert_allclose(np.asarray(g_pipe), np.asarray(g_seq),
                               rtol=2e-4, atol=2e-4)


def check_halo():
    from repro.parallel import spatial_conv2d
    mesh = mesh24()
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (2, 32, 16, 3))
    w = jax.random.normal(jax.random.fold_in(key, 1), (3, 3, 3, 8)) * 0.2
    dn = jax.lax.conv_dimension_numbers(x.shape, w.shape,
                                        ("NHWC", "HWIO", "NHWC"))
    ref = jax.lax.conv_general_dilated(x, w, (1, 1), "SAME",
                                       dimension_numbers=dn)
    got = spatial_conv2d(x, w, mesh, axis="model")
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=2e-5,
                               atol=2e-5)


def check_dp_numerics():
    """Sharded df train step == unsharded step (same seed/batch)."""
    from repro.models import LMConfig, TransformerLM
    from repro.nn import AttentionConfig, FFNConfig
    from repro.nn.module import NULL_CTX, ShardingCtx, tree_init
    from repro.optim.optimizers import OptimizerConfig
    from repro.parallel.strategies import make_rules
    from repro.training.steps import make_train_step, train_state_spec
    cfg = LMConfig(name="t", vocab=64, d_model=32, n_layers=2,
                   attn=AttentionConfig(32, 4, 2, 8, dtype=jnp.float32),
                   ffn=FFNConfig(32, 64, dtype=jnp.float32), dtype=jnp.float32)
    model = TransformerLM(cfg)
    opt = OptimizerConfig(name="sgd", zero1=False, grad_clip=1e9)
    mesh = mesh24()
    key = jax.random.PRNGKey(0)
    state = tree_init(train_state_spec(model, opt), key)
    toks = jax.random.randint(key, (8, 32), 0, 64)
    kw = dict(attn_impl="plain", scan_layers=False, remat=False)
    ref_step = jax.jit(make_train_step(model, opt, NULL_CTX, **kw))
    ref, _ = ref_step(state, {"tokens": toks})
    ctx = ShardingCtx(mesh, make_rules("df"))
    sh_step = jax.jit(make_train_step(model, opt, ctx, **kw))
    got, _ = sh_step(state, {"tokens": toks})
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a, np.float32), np.asarray(b, np.float32),
        rtol=5e-4, atol=5e-4), ref["params"], got["params"])


def check_oracle_validation():
    """Fig-3 methodology end-to-end: accuracy must be > 40% for data/df."""
    from repro.core.validation import accuracy_report, validate
    from repro.models import LMConfig, TransformerLM
    from repro.nn import AttentionConfig, FFNConfig
    from repro.core.layer_stats import stats_for
    cfg = LMConfig(name="t", vocab=256, d_model=128, n_layers=4,
                   attn=AttentionConfig(128, 4, 4, 32, dtype=jnp.float32),
                   ffn=FFNConfig(128, 512, dtype=jnp.float32),
                   dtype=jnp.float32)
    model = TransformerLM(cfg)
    mesh = mesh24()
    B, S = 16, 128
    key = jax.random.PRNGKey(0)
    batch = {"tokens": jax.random.randint(key, (B, S), 0, 256)}
    stats = stats_for(cfg, S)
    flops = sum(s.flops_fwd for s in stats)
    pts = validate(model, cfg, batch, mesh, ["data", "df"],
                   flops_per_sample=flops, B=B, S=S)
    print(accuracy_report(pts))
    # timing-based under possible CPU contention: assert on the mean and a
    # loose per-strategy floor (standalone this reports ~75-85%)
    mean = sum(pt.accuracy for pt in pts) / len(pts)
    assert mean > 0.45, f"mean accuracy {mean:.2f}"
    for pt in pts:
        assert pt.accuracy > 0.2, f"{pt.strategy}: {pt.accuracy:.2f}"


def check_compressed_allreduce():
    from repro.optim.compress import compressed_mean
    mesh = mesh24()
    key = jax.random.PRNGKey(0)
    g = jax.random.normal(key, (8, 64))

    def spmd(gl):
        mean, _ = compressed_mean({"g": gl}, "data")
        return mean["g"]

    from repro.launch.compat import shard_map
    out = shard_map(spmd, mesh=mesh, in_specs=P("data", None),
                    out_specs=P("data", None), check_vma=False)(g)
    # mesh data axis = 2 shards of 4 rows: out[j] == out[j+4] == mean of the
    # two shards' row j, to within one quantization step (shared scale)
    got = np.asarray(out)
    want = np.asarray((g[:4] + g[4:]) / 2.0)
    np.testing.assert_allclose(got[:4], want, atol=0.05)
    np.testing.assert_allclose(got[4:], want, atol=0.05)


CHECKS = {
    "pipeline": check_pipeline,
    "halo": check_halo,
    "dp_numerics": check_dp_numerics,
    "oracle_validation": check_oracle_validation,
    "compressed_allreduce": check_compressed_allreduce,
}

if __name__ == "__main__":
    name = sys.argv[1]
    CHECKS[name]()
    print("CHECK-PASSED")
