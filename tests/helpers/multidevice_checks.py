"""Multi-device checks, run in a subprocess with 8 virtual host devices.

Usage: python tests/helpers/multidevice_checks.py <check-name>
Prints CHECK-PASSED on success (asserted by tests/test_distributed.py).
"""
import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402


def mesh24():
    from repro.launch.compat import make_mesh
    return make_mesh((2, 4), ("data", "model"))


def check_pipeline():
    from repro.parallel import gpipe, make_stage_fn, stack_stages
    mesh = mesh24()
    key = jax.random.PRNGKey(0)
    L, D, MB, S = 8, 16, 4, 4
    w = jax.random.normal(key, (L, D, D)) * 0.3

    def block(lp, h):
        return jnp.tanh(h @ lp)

    x = jax.random.normal(key, (S, MB, D))
    seq = x
    for i in range(L):
        seq = block(w[i], seq)
    out = gpipe(make_stage_fn(block), stack_stages(w, 4), x, mesh, "model")
    np.testing.assert_allclose(np.asarray(out), np.asarray(seq), rtol=2e-5,
                               atol=2e-5)

    def loss_pipe(sp):
        return jnp.mean(gpipe(make_stage_fn(block), sp, x, mesh, "model") ** 2)

    def loss_seq(wf):
        h = x
        for i in range(L):
            h = block(wf[i], h)
        return jnp.mean(h ** 2)

    g_pipe = jax.grad(loss_pipe)(stack_stages(w, 4)).reshape(L, D, D)
    g_seq = jax.grad(loss_seq)(w)
    np.testing.assert_allclose(np.asarray(g_pipe), np.asarray(g_seq),
                               rtol=2e-4, atol=2e-4)


def _uniform_lm(n_layers=4, d=32, vocab=64):
    from repro.models import LMConfig, TransformerLM
    from repro.nn import AttentionConfig, FFNConfig
    cfg = LMConfig(name="t", vocab=vocab, d_model=d, n_layers=n_layers,
                   attn=AttentionConfig(d, 4, 2, d // 4, dtype=jnp.float32),
                   ffn=FFNConfig(d, 2 * d, dtype=jnp.float32),
                   dtype=jnp.float32)
    return TransformerLM(cfg), cfg


def check_pipeline_step_parity():
    """GPipe train step == serial jit step: same loss, same grads/params
    (the ISSUE-3 gradient-parity acceptance, at full train-step level)."""
    from repro.nn.module import NULL_CTX, ShardingCtx, tree_init
    from repro.optim.optimizers import OptimizerConfig
    from repro.parallel import make_pipeline_train_step, make_rules
    from repro.training.steps import make_train_step, train_state_spec
    model, cfg = _uniform_lm(n_layers=4)
    opt = OptimizerConfig(name="sgd", zero1=False, grad_clip=1e9)
    mesh = mesh24()
    key = jax.random.PRNGKey(0)
    state = tree_init(train_state_spec(model, opt), key)
    toks = jax.random.randint(key, (8, 32), 0, cfg.vocab)
    pipe = jax.jit(make_pipeline_train_step(model, opt,
                                            ShardingCtx(mesh, make_rules("pipeline")),
                                            segments=4, attn_impl="plain"))
    ref = jax.jit(make_train_step(model, opt, NULL_CTX, attn_impl="plain",
                                  scan_layers=False, remat=False))
    got, gm = pipe(state, {"tokens": toks})
    want, wm = ref(state, {"tokens": toks})
    np.testing.assert_allclose(float(gm["loss"]), float(wm["loss"]),
                               rtol=1e-5)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a, np.float32), np.asarray(b, np.float32),
        rtol=2e-4, atol=2e-4), want["params"], got["params"])
    # non-uniform cuts (5 layers on 4 stages) stay gradient-exact too
    model5, cfg5 = _uniform_lm(n_layers=5)
    state5 = tree_init(train_state_spec(model5, opt), key)
    pipe5 = jax.jit(make_pipeline_train_step(
        model5, opt, ShardingCtx(mesh, make_rules("pipeline")),
        segments=4, attn_impl="plain"))
    ref5 = jax.jit(make_train_step(model5, opt, NULL_CTX, attn_impl="plain",
                                   scan_layers=False, remat=False))
    got5, _ = pipe5(state5, {"tokens": toks})
    want5, _ = ref5(state5, {"tokens": toks})
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a, np.float32), np.asarray(b, np.float32),
        rtol=2e-4, atol=2e-4), want5["params"], got5["params"])


def check_schedule_parity(schedule: str):
    """Each pipeline schedule's train step == the serial jit step — same
    loss and updated params — on (a) a uniform LM stack, (b) a non-uniform
    cut (more layers than divide evenly into stages/chunks), and (c) a
    heterogeneous CNN trunk (CosmoFlow stem/conv/head blocks via per-stage
    program specialization). CosmoFlow has no batch-norm, so CNN parity is
    exact; see make_pipeline_train_step's docstring for the ResNet/VGG
    per-microbatch BN caveat."""
    from repro.models.cnn import CosmoFlow, CosmoFlowConfig
    from repro.nn.module import NULL_CTX, ShardingCtx, tree_init
    from repro.optim.optimizers import OptimizerConfig
    from repro.parallel import make_pipeline_train_step, make_rules
    from repro.training.steps import make_train_step, train_state_spec
    opt = OptimizerConfig(name="sgd", zero1=False, grad_clip=1e9)
    mesh = mesh24()
    key = jax.random.PRNGKey(0)
    ctx = ShardingCtx(mesh, make_rules("pipeline"))
    v = 2 if schedule == "interleaved" else 1

    def assert_match(model, batch, pipe_kw, ref_kw):
        state = tree_init(train_state_spec(model, opt), key)
        pipe = jax.jit(make_pipeline_train_step(
            model, opt, ctx, schedule=schedule, **pipe_kw))
        ref = jax.jit(make_train_step(model, opt, NULL_CTX, **ref_kw))
        got, gm = pipe(state, batch)
        want, wm = ref(state, batch)
        np.testing.assert_allclose(float(gm["loss"]), float(wm["loss"]),
                                   rtol=1e-5)
        assert int(gm["pipeline_segments"]) >= 1   # resolved S surfaced
        jax.tree.map(lambda a, b: np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            rtol=2e-4, atol=2e-4), want["params"], got["params"])

    lm_ref = dict(attn_impl="plain", scan_layers=False, remat=False)
    # (a) uniform stack: 8 layers on 4 stages (v·4 chunks for interleaved)
    model, cfg = _uniform_lm(n_layers=8)
    toks = jax.random.randint(key, (8, 32), 0, cfg.vocab)
    assert_match(model, {"tokens": toks},
                 dict(segments=8, virtual_stages=v, attn_impl="plain"),
                 lm_ref)
    # (b) non-uniform cut: layer count that does not divide the chunk count
    n_odd = 10 if schedule == "interleaved" else 5   # 10 on 8 / 5 on 4
    model_o, cfg_o = _uniform_lm(n_layers=n_odd)
    assert_match(model_o, {"tokens": toks},
                 dict(segments=8, virtual_stages=v, attn_impl="plain"),
                 lm_ref)
    # (c) heterogeneous CNN trunk: 4 blocks (stem-less conv×3 + head) on 4
    # stages; interleaved runs v=1 here (v·p chunks must fit 4 blocks)
    ccfg = CosmoFlowConfig(img=16, n_conv=3, width=8)
    cmodel = CosmoFlow(ccfg)
    cbatch = {"images": jax.random.normal(key, (8, 16, 16, 16, 4)),
              "targets": jax.random.normal(jax.random.fold_in(key, 1),
                                           (8, 4))}
    assert_match(cmodel, cbatch, dict(segments=4, virtual_stages=1), {})


def check_pipeline_deploy():
    """ISSUE-3 acceptance: the tuner emits a strategy='pipeline' plan that
    build_cell(strategy='auto') deploys and trains for one step."""
    from repro.configs.base import ArchConfig, SHAPES, ShapeSpec
    from repro.core import OracleConfig, TimeModel, cpu_host_model, stats_for
    from repro.core.autotune import autotune
    from repro.launch.build import build_cell
    from repro.launch.compat import make_mesh
    from repro.nn.module import tree_init
    from repro.training.steps import train_state_spec
    model, cfg = _uniform_lm(n_layers=8)
    SHAPES["train_tiny"] = ShapeSpec("train_tiny", 32, 8, "train")
    acfg = ArchConfig(name="pipe-test", family="lm", model=cfg,
                      smoke_model=cfg, source="test", strategy="df")
    mesh = make_mesh((1, 8), ("data", "model"))
    stats = stats_for(cfg, 32)
    plan = autotune(stats, TimeModel(cpu_host_model()),
                    OracleConfig(B=8, D=8, segments=4), 8,
                    strategies=("pipeline",), max_stages=cfg.n_layers,
                    model_width=8)
    assert plan.strategy == "pipeline" and (plan.p1, plan.p2) == (1, 8), plan
    assert plan.exec_strategy("train") == "pipeline"
    cell = build_cell(acfg, "train_tiny", mesh, "auto", plan=plan,
                      scan_layers=False)
    assert cell.strategy == "pipeline"
    assert cell.meta["plan"] is plan
    state = tree_init(train_state_spec(model, cell.meta["opt"]),
                      jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, cfg.vocab)
    new_state, metrics = jax.jit(cell.step_fn)(state, {"tokens": toks})
    assert np.isfinite(float(metrics["loss"])), metrics
    assert int(new_state["step"]) == 1
    changed = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                           state["params"], new_state["params"])
    assert max(jax.tree.leaves(changed)) > 0.0   # it actually trained


def check_pipeline_validation(write_path=None):
    """validate(strategies=['pipeline']) returns a measured ValidationPoint
    (no EXEC_SKIP path) with sane accuracy; optionally writes the
    oracle-vs-measured artifact consumed by experiments/make_report.py."""
    from repro.core.layer_stats import stats_for
    from repro.core.validation import accuracy_report, validate
    model, cfg = _uniform_lm(n_layers=8, d=128, vocab=256)
    mesh = mesh24()
    B, S = 16, 128
    key = jax.random.PRNGKey(0)
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab)}
    stats = stats_for(cfg, S)
    flops = sum(s.flops_fwd for s in stats)
    pts = validate(model, cfg, batch, mesh, ["pipeline", "data"],
                   flops_per_sample=flops, B=B, S=S)
    print(accuracy_report(pts))
    by = {pt.strategy: pt for pt in pts}
    assert "pipeline" in by, "pipeline was skipped, not measured"
    assert by["pipeline"].measured_s > 0
    # timing on a shared CPU box is too noisy for an accuracy floor (a
    # contended run can push even the data baseline negative); the stable
    # invariant is the projection landing within a small factor of the
    # measurement — same spirit as make_report's 3x cross-check tolerance
    ratio = by["pipeline"].projected_s / by["pipeline"].measured_s
    assert 0.2 <= ratio <= 5.0, by["pipeline"]
    if write_path:
        import json
        rec = {"mesh": {k: int(v) for k, v in mesh.shape.items()},
               "B": B, "S": S, "model": "uniform-lm-8L-d128",
               "points": [{"strategy": pt.strategy, "p": pt.p,
                           "measured_s": pt.measured_s,
                           "projected_s": pt.projected_s,
                           "accuracy": pt.accuracy} for pt in pts]}
        with open(write_path, "w") as f:
            json.dump(rec, f, indent=1)
        print(f"wrote {write_path}")


def check_tuner_loop():
    """ROADMAP 'measured auto-tuner validation': the tuner's pick and the
    runner-up both run under core/validation.py; the pick must measure no
    slower (loose tolerance — virtual-device timing on a shared core)."""
    import dataclasses
    from repro.core import OracleConfig, TimeModel, cpu_host_model
    from repro.core.autotune import autotune
    from repro.core.layer_stats import stats_for
    from repro.core.validation import measure_step
    from repro.core.calibration import calibrate_host_system
    from repro.nn.module import tree_init
    model, cfg = _uniform_lm(n_layers=8, d=128, vocab=256)
    mesh = mesh24()
    B, S = 16, 128
    key = jax.random.PRNGKey(0)
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab)}
    stats = stats_for(cfg, S)
    flops_step = sum(s.flops_fwd for s in stats) * B
    sysm = calibrate_host_system(
        lambda p, b: model.loss_fn(p, b),
        tree_init(model.params_spec(), jax.random.PRNGKey(0)), batch,
        flops_step, mesh=mesh)
    p = 8
    sysm = dataclasses.replace(sysm, peak_flops=sysm.peak_flops / p)
    ocfg = OracleConfig(B=B, D=B)
    tm = TimeModel(sysm)
    # strategies this mesh can actually measure (df needs the 2x4 split)
    cand = ("data", "df", "filter", "channel")
    pick = autotune(stats, tm, ocfg, p, strategies=cand, switches=None,
                    model_width=mesh.shape["model"])
    runner = autotune(stats, tm, ocfg, p, switches=None,
                      strategies=tuple(s for s in cand
                                       if s != pick.strategy),
                      model_width=mesh.shape["model"])
    t_pick = measure_step(model, cfg, batch, mesh, pick.strategy)
    t_run = measure_step(model, cfg, batch, mesh, runner.strategy)
    print(f"pick {pick.strategy}: {t_pick*1e3:.1f}ms  "
          f"runner-up {runner.strategy}: {t_run*1e3:.1f}ms")
    assert pick.total_s <= runner.total_s
    # the projected order must hold in measurement (1.3x timing slack)
    assert t_pick <= t_run * 1.3, (pick.strategy, t_pick, runner.strategy,
                                   t_run)


def check_halo():
    from repro.parallel import spatial_conv2d
    mesh = mesh24()
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (2, 32, 16, 3))
    w = jax.random.normal(jax.random.fold_in(key, 1), (3, 3, 3, 8)) * 0.2
    dn = jax.lax.conv_dimension_numbers(x.shape, w.shape,
                                        ("NHWC", "HWIO", "NHWC"))
    ref = jax.lax.conv_general_dilated(x, w, (1, 1), "SAME",
                                       dimension_numbers=dn)
    got = spatial_conv2d(x, w, mesh, axis="model")
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=2e-5,
                               atol=2e-5)


def _same_conv(x, w):
    nd = x.ndim - 2
    sp = "DHW"[-nd:]
    dn = jax.lax.conv_dimension_numbers(
        x.shape, w.shape, (f"N{sp}C", f"{sp}IO", f"N{sp}C"))
    return jax.lax.conv_general_dilated(x, w, (1,) * nd, "SAME",
                                        dimension_numbers=dn)


def check_halo_overlap():
    """ISSUE-4 overlap parity gate: the overlapped interior/boundary-split
    halo conv is BIT-EXACT vs both the serial exchange-then-conv pipeline
    and the unsharded SAME conv — 2-D and 3-D, with bias, through the
    deployed HaloConv layer under the ds rules, and (to kernel tolerance)
    through the Pallas halo-aware path."""
    from repro.nn.module import NULL_CTX, ShardingCtx, tree_init
    from repro.parallel import HaloConv, spatial_conv2d
    from repro.parallel.strategies import make_rules
    mesh = mesh24()
    key = jax.random.PRNGKey(0)
    for shape, k, F in [((2, 32, 16, 3), 3, 8), ((2, 16, 8, 8, 4), 3, 6)]:
        nd = len(shape) - 2
        x = jax.random.normal(key, shape)
        w = jax.random.normal(jax.random.fold_in(key, k),
                              (k,) * nd + (shape[-1], F)) * 0.2
        b = jax.random.normal(jax.random.fold_in(key, 7), (F,)) * 0.1
        ref = _same_conv(x, w) + b
        over = spatial_conv2d(x, w, mesh, "model", bias=b, overlap=True)
        serial = spatial_conv2d(x, w, mesh, "model", bias=b, overlap=False)
        assert bool(jnp.all(over == ref)), "overlapped != unsharded"
        assert bool(jnp.all(over == serial)), "overlapped != serial pipeline"
    # deployed path: HaloConv inside a jitted fn under the ds rules table
    hc = HaloConv(3, 8, (3, 3), use_bias=True)
    params = tree_init(hc.params_spec(), key)
    x = jax.random.normal(key, (4, 32, 16, 3))
    ctx = ShardingCtx(mesh, make_rules("ds"))
    got = jax.jit(lambda p, v: hc.apply(p, v, ctx))(params, x)
    want = hc.apply(params, x, NULL_CTX)
    assert bool(jnp.all(got == want)), "HaloConv(ds) != HaloConv(unsharded)"
    # Pallas halo-aware kernel consumes the exchanged tile (interpret mode)
    ctx_pl = ShardingCtx(mesh, make_rules("ds"), use_pallas=True)
    got_pl = jax.jit(lambda p, v: hc.apply(p, v, ctx_pl))(params, x)
    np.testing.assert_allclose(np.asarray(got_pl), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def check_halo_edge(case: str):
    """Halo edge cases (ISSUE-4 satellite): thin shards raise, even kernel
    widths split their halo asymmetrically but stay bit-exact, p=1
    degenerates to the serial conv, strides are rejected loudly."""
    from repro.launch.compat import make_mesh
    from repro.parallel import spatial_conv2d
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (2, 8, 16, 3))     # H_local = 2 on 4 shards
    mesh = mesh24()
    if case == "thin":
        # H_local=2 < halo=3 (k=7): one-hop exchange cannot serve it
        w = jax.random.normal(key, (7, 7, 3, 8)) * 0.2
        try:
            spatial_conv2d(x, w, mesh, "model")
        except ValueError as e:
            assert "too thin" in str(e), e
        else:
            raise AssertionError("thin shard did not raise")
        # H_local == halo still works (neighbour ships its whole shard)
        w5 = jax.random.normal(key, (5, 5, 3, 8)) * 0.2
        got = spatial_conv2d(x, w5, mesh, "model")
        assert bool(jnp.all(got == _same_conv(x, w5)))
        # H_local == kh−1 (empty interior) must take the serial fallback —
        # regression: the overlap branch fed a zero-row interior to Pallas
        w3 = jax.random.normal(key, (3, 3, 3, 8)) * 0.2   # H_local=2=kh−1
        for pl in (False, True):
            got = spatial_conv2d(x, w3, mesh, "model", use_pallas=pl)
            ref = _same_conv(x, w3)
            if pl:
                np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                           rtol=1e-5, atol=1e-5)
            else:
                assert bool(jnp.all(got == ref))
    elif case == "even":
        for k in (2, 4):
            w = jax.random.normal(jax.random.fold_in(key, k),
                                  (k, k, 3, 8)) * 0.2
            got = spatial_conv2d(x, w, mesh, "model")
            assert bool(jnp.all(got == _same_conv(x, w))), f"k={k}"
            # the Pallas path must survive the lo=0 empty top boundary
            # (regression: zero-row tile reaching pallas_call)
            got_pl = spatial_conv2d(x, w, mesh, "model", use_pallas=True)
            np.testing.assert_allclose(np.asarray(got_pl),
                                       np.asarray(_same_conv(x, w)),
                                       rtol=1e-5, atol=1e-5)
    elif case == "padding":
        # non-SAME padding must NEVER take the halo path (the exchange IS
        # the SAME padding): HaloConv falls back to the plain conv and
        # matches the unsharded result exactly
        from repro.nn.module import NULL_CTX, ShardingCtx, tree_init
        from repro.parallel import HaloConv
        from repro.parallel.strategies import make_rules
        hc = HaloConv(3, 8, (3, 3), padding="VALID", use_bias=False)
        params = tree_init(hc.params_spec(), key)
        xv = jax.random.normal(key, (4, 32, 16, 3))
        want = hc.apply(params, xv, NULL_CTX)
        assert want.shape == (4, 30, 14, 8)
        ctx = ShardingCtx(mesh, make_rules("ds"))
        got = jax.jit(lambda p, v: hc.apply(p, v, ctx))(params, xv)
        assert got.shape == want.shape
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)
    elif case == "p1":
        mesh1 = make_mesh((8, 1), ("data", "model"))
        w = jax.random.normal(key, (3, 3, 3, 8)) * 0.2
        got = spatial_conv2d(x, w, mesh1, "model")
        assert bool(jnp.all(got == _same_conv(x, w)))
    elif case == "stride":
        w = jax.random.normal(key, (3, 3, 3, 8)) * 0.2
        try:
            spatial_conv2d(x, w, mesh, "model", strides=(2, 2))
        except ValueError as e:
            assert "stride-1 only" in str(e), e
        else:
            raise AssertionError("stride != 1 did not raise")
    else:
        raise KeyError(case)


def check_spatial_overlap_validation(write_path=None):
    """ISSUE-4 acceptance: the measured data+spatial (``ds`` — how the
    spatial strategy deploys, EXEC_STRATEGY) step lands closer to the
    oracle's overlap model than to the paper's serial-comm accounting.

    σ is an empirical per-system parameter, exactly like the α–β and
    compute terms the host validation already calibrates (paper §4.4;
    ROADMAP "φ/σ FITTING"): the literature defaults describe clusters,
    not a timeshared CPU. So the check follows the paper's own
    calibrate-then-validate methodology — ONE calibration, then the ds
    step measured at TWO batch sizes back-to-back (load-paired): σ̂ is
    fitted on the B=2 point (the overlap projection is affine in σ, so
    the fit is closed-form, clamped to [0, 1]) and VALIDATED on the held-
    out B=4 point, against the serial model. The model is chosen so the
    φ=2-charged gradient exchange dominates communication (fat fc, thin
    conv trunk). σ̂=0 degenerates to the serial model itself, so the
    comparison can only be won or tied by construction on the fit point —
    the bite is on the held-out point, where a mis-fitted σ̂ would LOSE.
    A retry repeats the FULL procedure (fresh calibration, measurements,
    fit); the assertion itself is never relaxed. Optionally writes the
    EXPERIMENTS.md overlap table artifact."""
    import dataclasses
    from repro.core.calibration import calibrate_host_system
    from repro.core.layer_stats import stats_for
    from repro.core.oracle import OracleConfig, TimeModel, project
    from repro.core.validation import ValidationPoint, measure_step
    from repro.models.cnn import CosmoFlow, CosmoFlowConfig
    cfg = CosmoFlowConfig(img=16, n_conv=1, width=192)
    model = CosmoFlow(cfg)
    mesh = mesh24()
    p = 8
    key = jax.random.PRNGKey(0)

    def batch_of(B):
        return {"images": jax.random.normal(key, (B, 16, 16, 16, 4)),
                "targets": jax.random.normal(jax.random.fold_in(key, 1),
                                             (B, 4))}

    stats = stats_for(cfg)
    flops = sum(s.flops_fwd for s in stats)

    def proj(B, **kw):
        ocfg = OracleConfig(B=B, D=B, **kw)
        return project("ds", stats, tm, ocfg, p, p1=2, p2=4).total_s

    pt = None
    for attempt in range(3):
        from repro.nn.module import tree_init
        sysm = calibrate_host_system(
            lambda prm, b: model.loss_fn(prm, b),
            tree_init(model.params_spec(), key), batch_of(2), flops * 2,
            mesh=mesh)
        sysm = dataclasses.replace(sysm, peak_flops=sysm.peak_flops / p)
        tm = TimeModel(sysm)
        meas_fit = measure_step(model, cfg, batch_of(2), mesh, "spatial")
        meas_val = measure_step(model, cfg, batch_of(4), mesh, "spatial")
        # fit σ̂ on B=2: proj(σ) = serial − σ·(serial − proj(σ=1)), affine
        serial_fit = proj(2, overlap=False)
        floor_fit = proj(2, sigma_levels={"model": 1.0, "data": 1.0})
        span = serial_fit - floor_fit
        sig = (serial_fit - meas_fit) / span if span > 0 else 0.0
        sig = min(max(sig, 0.0), 1.0)
        fitted = {"model": sig, "data": sig}
        # validate on the held-out B=4 point
        pt = ValidationPoint("spatial(ds)", p, meas_val,
                             proj(4, sigma_levels=fitted),
                             proj(4, overlap=False))
        err_overlap = abs(pt.projected_s - pt.measured_s)
        err_serial = abs(pt.projected_serial_s - pt.measured_s)
        print(f"fit B=2: meas {meas_fit*1e3:.1f}ms serial "
              f"{serial_fit*1e3:.1f}ms floor {floor_fit*1e3:.1f}ms "
              f"→ σ̂={sig:.3f}")
        print(f"validate B=4: meas {meas_val*1e3:.1f}ms  σ̂-model "
              f"{pt.projected_s*1e3:.1f}ms (err {err_overlap*1e3:.1f})  "
              f"serial {pt.projected_serial_s*1e3:.1f}ms "
              f"(err {err_serial*1e3:.1f})")
        if err_overlap <= err_serial:
            break
        print(f"attempt {attempt + 1} failed — full redo")
    assert abs(pt.projected_s - pt.measured_s) \
        <= abs(pt.projected_serial_s - pt.measured_s), \
        (pt.projected_s, pt.projected_serial_s, pt.measured_s)
    if write_path:
        import json
        rec = {"mesh": {k: int(v) for k, v in mesh.shape.items()},
               "B": 4, "model": f"cosmoflow-img{cfg.img}-c{cfg.n_conv}"
                                f"-w{cfg.width}",
               "sigma_fitted": sig,
               "estimator": "sigma fitted on the B=2 point, validated on "
                            "the held-out B=4 point (one calibration, "
                            "load-paired measurements)",
               "points": [{"strategy": pt.strategy, "p": pt.p,
                           "measured_s": pt.measured_s,
                           "projected_s": pt.projected_s,
                           "projected_serial_s": pt.projected_serial_s,
                           "accuracy": pt.accuracy,
                           "accuracy_serial": pt.accuracy_serial}]}
        with open(write_path, "w") as f:
            json.dump(rec, f, indent=1)
        print(f"wrote {write_path}")


def check_schedule_validation(write_path=None):
    """ISSUE-7 acceptance: the measured bubble fraction at p=8 shrinks
    under 1F1B and interleaved vs GPipe at equal S, and the oracle's
    schedule axis picks the measured per-(model, p) winner.

    Methodology (core/validation.measure_schedule_bubble): run each
    schedule at two microbatch counts with a fixed per-microbatch size,
    fit t(S) = a·S + b, and read the bubble off the intercept. On
    timeshared virtual devices idle ranks burn real wall-time, so the
    fill/drain bubble is visible even on one CPU core. A retry repeats the
    FULL procedure (fresh calibration + measurements); assertions are
    never relaxed."""
    import dataclasses
    from repro.core import OracleConfig, TimeModel
    from repro.core.calibration import calibrate_host_system
    from repro.core.layer_stats import stats_for
    from repro.core.validation import measure_schedule_bubble, schedule_winner
    from repro.nn.module import tree_init
    from repro.parallel.schedules import SCHEDULE_NAMES
    model, cfg = _uniform_lm(n_layers=16)
    p = 8
    from repro.launch.compat import make_mesh
    mesh = make_mesh((1, p), ("data", "model"))
    key = jax.random.PRNGKey(0)
    S_small, S_large = 8, 16     # interleaved needs S % p == 0

    def make_batch(B):
        return {"tokens": jax.random.randint(key, (B, 32), 0, cfg.vocab)}

    stats = stats_for(cfg, 32)
    flops_step = sum(s.flops_fwd for s in stats) * S_large
    ok = False
    for attempt in range(3):
        sysm = calibrate_host_system(
            lambda prm, b: model.loss_fn(prm, b),
            tree_init(model.params_spec(), key), make_batch(S_large),
            flops_step, mesh=mesh)
        sysm = dataclasses.replace(sysm, peak_flops=sysm.peak_flops / p)
        ocfg = OracleConfig(B=S_large, D=S_large, segments=S_large)
        oracle_pick = schedule_winner(stats, TimeModel(sysm), ocfg, p)
        bubbles = {}
        for sched in SCHEDULE_NAMES:
            bubbles[sched] = measure_schedule_bubble(
                model, cfg, make_batch, mesh, schedule=sched,
                virtual_stages=2, S_small=S_small, S_large=S_large)
            b = bubbles[sched]
            print(f"{sched:12s} t({S_small})={b['t_small_s']*1e3:7.1f}ms "
                  f"t({S_large})={b['t_large_s']*1e3:7.1f}ms "
                  f"bubble={b['bubble_fraction']*100:5.1f}%")
        measured_pick = min(bubbles, key=lambda s: bubbles[s]["t_large_s"])
        print(f"oracle winner: {oracle_pick}  measured winner: "
              f"{measured_pick}")
        ok = (bubbles["one_f_one_b"]["bubble_fraction"]
              < bubbles["gpipe"]["bubble_fraction"]
              and bubbles["interleaved"]["bubble_fraction"]
              < bubbles["gpipe"]["bubble_fraction"]
              and oracle_pick == measured_pick)
        if ok:
            break
        print(f"attempt {attempt + 1} failed — full redo")
    assert bubbles["one_f_one_b"]["bubble_fraction"] \
        < bubbles["gpipe"]["bubble_fraction"], bubbles
    assert bubbles["interleaved"]["bubble_fraction"] \
        < bubbles["gpipe"]["bubble_fraction"], bubbles
    assert oracle_pick == measured_pick, (oracle_pick, measured_pick)
    if write_path:
        import json
        rec = {"p": p, "S_small": S_small, "S_large": S_large,
               "model": "uniform-lm-16L-d32",
               "oracle_winner": oracle_pick,
               "measured_winner": measured_pick,
               "schedules": bubbles}
        with open(write_path, "w") as f:
            json.dump(rec, f, indent=1)
        print(f"wrote {write_path}")


def check_dp_numerics():
    """Sharded df train step == unsharded step (same seed/batch)."""
    from repro.models import LMConfig, TransformerLM
    from repro.nn import AttentionConfig, FFNConfig
    from repro.nn.module import NULL_CTX, ShardingCtx, tree_init
    from repro.optim.optimizers import OptimizerConfig
    from repro.parallel.strategies import make_rules
    from repro.training.steps import make_train_step, train_state_spec
    cfg = LMConfig(name="t", vocab=64, d_model=32, n_layers=2,
                   attn=AttentionConfig(32, 4, 2, 8, dtype=jnp.float32),
                   ffn=FFNConfig(32, 64, dtype=jnp.float32), dtype=jnp.float32)
    model = TransformerLM(cfg)
    opt = OptimizerConfig(name="sgd", zero1=False, grad_clip=1e9)
    mesh = mesh24()
    key = jax.random.PRNGKey(0)
    state = tree_init(train_state_spec(model, opt), key)
    toks = jax.random.randint(key, (8, 32), 0, 64)
    kw = dict(attn_impl="plain", scan_layers=False, remat=False)
    ref_step = jax.jit(make_train_step(model, opt, NULL_CTX, **kw))
    ref, _ = ref_step(state, {"tokens": toks})
    ctx = ShardingCtx(mesh, make_rules("df"))
    sh_step = jax.jit(make_train_step(model, opt, ctx, **kw))
    got, _ = sh_step(state, {"tokens": toks})
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a, np.float32), np.asarray(b, np.float32),
        rtol=5e-4, atol=5e-4), ref["params"], got["params"])


def check_oracle_validation():
    """Fig-3 methodology end-to-end: accuracy must be > 40% for data/df."""
    from repro.core.validation import accuracy_report, validate
    from repro.models import LMConfig, TransformerLM
    from repro.nn import AttentionConfig, FFNConfig
    from repro.core.layer_stats import stats_for
    cfg = LMConfig(name="t", vocab=256, d_model=128, n_layers=4,
                   attn=AttentionConfig(128, 4, 4, 32, dtype=jnp.float32),
                   ffn=FFNConfig(128, 512, dtype=jnp.float32),
                   dtype=jnp.float32)
    model = TransformerLM(cfg)
    mesh = mesh24()
    B, S = 16, 128
    key = jax.random.PRNGKey(0)
    batch = {"tokens": jax.random.randint(key, (B, S), 0, 256)}
    stats = stats_for(cfg, S)
    flops = sum(s.flops_fwd for s in stats)
    pts = validate(model, cfg, batch, mesh, ["data", "df"],
                   flops_per_sample=flops, B=B, S=S)
    print(accuracy_report(pts))
    # timing-based under possible CPU contention: assert on the mean and a
    # loose per-strategy floor (standalone this reports ~75-85%)
    mean = sum(pt.accuracy for pt in pts) / len(pts)
    assert mean > 0.45, f"mean accuracy {mean:.2f}"
    for pt in pts:
        assert pt.accuracy > 0.2, f"{pt.strategy}: {pt.accuracy:.2f}"


def check_summa_parity():
    """ISSUE-9 tentpole gate: the 2D SUMMA tensor-parallel path is
    gradient-exact on a (2 data, 2 row, 2 col) grid mesh — summa_matmul
    against the plain einsum (forward + both cotangents), and a FULL train
    step under the ``summa`` rules table against the unsharded step."""
    from repro.launch.compat import make_mesh
    from repro.nn.module import NULL_CTX, ShardingCtx, tree_init
    from repro.optim.optimizers import OptimizerConfig
    from repro.parallel import summa as sm
    from repro.parallel.strategies import make_rules
    from repro.training.steps import make_train_step, train_state_spec
    mesh = make_mesh((2, 2, 2), ("data", "model_r", "model_c"))
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (4, 8, 32))
    w = jax.random.normal(jax.random.fold_in(key, 1), (32, 48)) * 0.1
    got, vjp = jax.vjp(lambda a, b: sm.summa_matmul(a, b, mesh), x, w)
    want, vjp_ref = jax.vjp(lambda a, b: jnp.einsum("bsk,kn->bsn", a, b),
                            x, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    ct = jax.random.normal(jax.random.fold_in(key, 2), got.shape)
    for g, r in zip(vjp(ct), vjp_ref(ct)):
        np.testing.assert_allclose(np.asarray(g), np.asarray(r),
                                   rtol=5e-4, atol=5e-4)
    # full train step; first prove the grid path actually engages (a silent
    # fallback to the plain constrain path would make the parity vacuous)
    model, cfg = _uniform_lm()
    ctx = ShardingCtx(mesh, make_rules("summa"))
    assert sm.summa_axes(ctx), "summa rules did not opt in on the grid mesh"
    assert sm.ffn_ok(cfg.ffn, mesh, (8, 32, cfg.d_model))
    assert sm.qkv_ok(cfg.attn, mesh, (8, 32, cfg.d_model))
    assert sm.out_ok(cfg.attn, mesh, (8, 32, cfg.attn.n_heads,
                                      cfg.attn.head_dim))
    opt = OptimizerConfig(name="sgd", zero1=False, grad_clip=1e9)
    state = tree_init(train_state_spec(model, opt), key)
    toks = jax.random.randint(key, (8, 32), 0, cfg.vocab)
    kw = dict(attn_impl="plain", scan_layers=False, remat=False)
    ref, _ = jax.jit(make_train_step(model, opt, NULL_CTX, **kw))(
        state, {"tokens": toks})
    got_s, _ = jax.jit(make_train_step(model, opt, ctx, **kw))(
        state, {"tokens": toks})
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a, np.float32), np.asarray(b, np.float32),
        rtol=5e-4, atol=5e-4), ref["params"], got_s["params"])


def check_tensor2d_validation(write_path=None):
    """ISSUE-9 acceptance: on the 8-device host mesh the tuned plan for a
    weight-heavy / batch-light LM selects a 2D (SUMMA) lattice point, and
    the oracle's winner between that plan and the best data-parallel plan
    is also the measured winner.

    The model is chosen so the comparison is structural, not a timing
    coin-flip: ~8.6M params vs ~0.5MB of residual activations per layer
    means 8-way DP moves the full gradient every step while SUMMA moves
    (r−1)/r weight panels over one grid ring plus tiny activation gathers
    (the priced seq-parallel comm) over the other. A retry repeats the
    FULL procedure (fresh calibration, tune, both measurements); the
    winner assertion is never relaxed. Optionally writes the EXPERIMENTS.md
    "2D tensor validation" artifact."""
    import dataclasses
    from repro.core import OracleConfig, TimeModel
    from repro.core.autotune import autotune
    from repro.core.calibration import calibrate_host_system
    from repro.core.layer_stats import stats_for
    from repro.core.validation import measure_step
    from repro.launch.compat import make_mesh
    from repro.models import LMConfig, TransformerLM
    from repro.nn import AttentionConfig, FFNConfig
    from repro.nn.module import tree_init
    cfg = LMConfig(name="t2d", vocab=512, d_model=512, n_layers=2,
                   attn=AttentionConfig(512, 8, 8, 64, dtype=jnp.float32),
                   ffn=FFNConfig(512, 2048, dtype=jnp.float32),
                   dtype=jnp.float32)
    model = TransformerLM(cfg)
    B, S, p = 8, 32, 8
    key = jax.random.PRNGKey(0)
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab)}
    stats = stats_for(cfg, S)
    flops_step = sum(s.flops_fwd for s in stats) * B
    dp_mesh = make_mesh((8, 1), ("data", "model"))
    ok = False
    for attempt in range(3):
        sysm = calibrate_host_system(
            lambda prm, b: model.loss_fn(prm, b),
            tree_init(model.params_spec(), key), batch, flops_step,
            mesh=dp_mesh)
        sysm = dataclasses.replace(sysm, peak_flops=sysm.peak_flops / p)
        tm = TimeModel(sysm)
        ocfg = OracleConfig(B=B, D=B)
        pick = autotune(stats, tm, ocfg, p, switches=None,
                        strategies=("data", "summa"))
        alt = autotune(stats, tm, ocfg, p, switches=None,
                       strategies=("data",) if pick.strategy == "summa"
                       else ("summa",))
        print(f"oracle pick: {pick.describe()}  "
              f"(proj {pick.total_s*1e3:.1f}ms)  "
              f"alt: {alt.describe()} (proj {alt.total_s*1e3:.1f}ms)")
        if not (pick.strategy == "summa" and pick.p2 > 1):
            print(f"attempt {attempt + 1}: tuner did not pick a 2D point "
                  f"— full redo")
            continue
        t_summa = measure_step(model, cfg, batch, dp_mesh, "summa",
                               grid=(pick.p2r, pick.p2c))
        t_data = measure_step(model, cfg, batch, dp_mesh, "data")
        measured_winner = "summa" if t_summa <= t_data else "data"
        print(f"measured: summa {t_summa*1e3:.1f}ms  data "
              f"{t_data*1e3:.1f}ms  → winner {measured_winner}")
        ok = measured_winner == pick.strategy
        if ok:
            break
        print(f"attempt {attempt + 1} failed — full redo")
    assert pick.strategy == "summa" and pick.p2 > 1, pick
    assert ok, ("oracle winner != measured winner",
                pick.describe(), t_summa, t_data)
    if write_path:
        import json
        rec = {"p": p, "B": B, "S": S,
               "model": "lm-2L-d512-ffn2048-v512 (weight-heavy)",
               "plan": {"strategy": pick.strategy, "p1": pick.p1,
                        "p2r": pick.p2r, "p2c": pick.p2c,
                        "projected_s": pick.total_s},
               "alt": {"strategy": alt.strategy, "p1": alt.p1,
                       "p2": alt.p2, "projected_s": alt.total_s},
               "measured": {"summa_s": t_summa, "data_s": t_data},
               "oracle_winner": pick.strategy,
               "measured_winner": measured_winner}
        with open(write_path, "w") as f:
            json.dump(rec, f, indent=1)
        print(f"wrote {write_path}")


def check_serving_validation(write_path=None):
    """ISSUE-10 acceptance: the serving oracle's throughput/latency ranking
    between the two serving layouts at equal model width matches a measured
    engine replay, and the sharded paged engine emits exactly the tokens of
    the dense single-device decode path.

    serve_tp vs serve_seqkv at p2=2 is the structural comparison: both
    halve per-device compute and KV identically, but serve_seqkv pays one
    extra collective per layer (the sequence-shard LSE merge) — the oracle
    prices that third collective, so its winner must also be the measured
    winner. A retry repeats the FULL procedure (both warmed measurements);
    the winner assertion is never relaxed. Optionally writes the
    EXPERIMENTS.md "Serving validation" artifact."""
    from repro.core.cluster import ClusterSpec
    from repro.core.validation import measure_serving
    from repro.launch.compat import make_mesh
    from repro.models import LMConfig, TransformerLM
    from repro.nn import AttentionConfig, FFNConfig
    from repro.nn.module import tree_init
    from repro.serve import ServeConfig, TrafficModel, price_serving
    # sized so the per-layer collective gap dominates host dispatch noise:
    # at d256/L6/B8 the seqkv step measures ~45% slower than serve_tp on
    # the virtual-device host — far above the ~3% replay jitter
    cfg = LMConfig(name="srv", vocab=512, d_model=256, n_layers=6,
                   attn=AttentionConfig(256, 8, 2, 32, dtype=jnp.float32),
                   ffn=FFNConfig(256, 1024, dtype=jnp.float32),
                   dtype=jnp.float32)
    model = TransformerLM(cfg)
    traffic = TrafficModel(rate=50.0, prompt_len=16, gen_len=8, spread=0.0)
    trace = traffic.trace(6, cfg.vocab, seed=0)
    max_len, p2 = 64, 2
    mesh = make_mesh((1, p2), ("data", "model"))
    cluster = ClusterSpec.of("host")
    configs = {
        "serve_tp": (1, ServeConfig(max_len=max_len, max_batch=8,
                                    block_tokens=16, prefill_chunk=16,
                                    kv_shards=1, dtype=jnp.float32)),
        "serve_seqkv": (p2, ServeConfig(max_len=max_len, max_batch=8,
                                        block_tokens=16, prefill_chunk=16,
                                        kv_shards=p2, dtype=jnp.float32)),
    }
    rows = {s: price_serving(cfg, cluster, s, 1, p2, kv, c.max_batch,
                             traffic, max_len=max_len, dtype_bytes=4)
            for s, (kv, c) in configs.items()}
    for s, r in rows.items():
        assert r.feasible, (s, r.limit)
        print("oracle:   " + r.describe())
    oracle_winner = max(rows, key=lambda s: rows[s].tok_per_s)

    # dense single-device greedy reference for request 0 (paged + sharded
    # must be bit-exact against it under BOTH rules tables)
    req = trace[0]
    key = jax.random.PRNGKey(0)
    params = tree_init(model.params_spec(), key)
    cache = jax.tree.map(jnp.zeros_like,
                         tree_init(model.cache_spec(1, max_len,
                                                    dtype=jnp.float32), key))
    lg, cache = model.prefill(params, jnp.asarray(req.prompt[None]), cache,
                              attn_impl="plain")
    ref = [int(np.argmax(np.asarray(lg[0, 0])))]
    for i in range(req.max_new - 1):
        lg, cache = model.decode_step(params, jnp.asarray([[ref[-1]]]),
                                      cache, len(req.prompt) + i)
        ref.append(int(np.argmax(np.asarray(lg[0, 0]))))

    ok = False
    for attempt in range(3):
        reports = {s: measure_serving(model, mesh, s, c, trace,
                                      params=params)
                   for s, (kv, c) in configs.items()}
        for s, rep in reports.items():
            print(f"measured: {s:<11} tok/s={rep.tok_per_s:8.1f} "
                  f"p50={rep.percentile(50) * 1e3:7.1f}ms")
            got = next(r.tokens for r in rep.requests if r.rid == req.rid)
            assert got == ref, (
                f"{s}: paged sharded tokens diverge from dense reference",
                got, ref)
        measured_winner = max(reports, key=lambda s: reports[s].tok_per_s)
        print(f"oracle winner {oracle_winner}, measured {measured_winner}")
        ok = measured_winner == oracle_winner
        if ok:
            break
        print(f"attempt {attempt + 1} failed — full redo")
    assert ok, ("oracle winner != measured winner", oracle_winner,
                {s: r.tok_per_s for s, r in reports.items()})
    if write_path:
        import json
        rec = {"p2": p2, "max_len": max_len,
               "model": "lm-6L-d256-h8kv2 (serving check)",
               "traffic": {"rate": traffic.rate,
                           "prompt_len": traffic.prompt_len,
                           "gen_len": traffic.gen_len,
                           "requests": len(trace)},
               "oracle": {s: {"tok_per_s": rows[s].tok_per_s,
                              "latency_p99_s": rows[s].latency_p99,
                              "t_decode_s": rows[s].t_decode}
                          for s in rows},
               "measured": {s: {"tok_per_s": reports[s].tok_per_s,
                                "latency_p50_s": reports[s].percentile(50)}
                            for s in reports},
               "oracle_winner": oracle_winner,
               "measured_winner": measured_winner,
               "tokens_bit_exact_vs_dense": True}
        with open(write_path, "w") as f:
            json.dump(rec, f, indent=1)
        print(f"wrote {write_path}")


def check_compressed_allreduce():
    from repro.optim.compress import compressed_mean
    mesh = mesh24()
    key = jax.random.PRNGKey(0)
    g = jax.random.normal(key, (8, 64))

    def spmd(gl):
        mean, _ = compressed_mean({"g": gl}, "data")
        return mean["g"]

    from repro.launch.compat import shard_map
    out = shard_map(spmd, mesh=mesh, in_specs=P("data", None),
                    out_specs=P("data", None), check_vma=False)(g)
    # mesh data axis = 2 shards of 4 rows: out[j] == out[j+4] == mean of the
    # two shards' row j, to within one quantization step (shared scale)
    got = np.asarray(out)
    want = np.asarray((g[:4] + g[4:]) / 2.0)
    np.testing.assert_allclose(got[:4], want, atol=0.05)
    np.testing.assert_allclose(got[4:], want, atol=0.05)


CHECKS = {
    "pipeline": check_pipeline,
    "pipeline_step_parity": check_pipeline_step_parity,
    "schedule_parity": check_schedule_parity,
    "schedule_validation": check_schedule_validation,
    "pipeline_deploy": check_pipeline_deploy,
    "pipeline_validation": check_pipeline_validation,
    "tuner_loop": check_tuner_loop,
    "halo": check_halo,
    "halo_overlap": check_halo_overlap,
    "halo_edge": check_halo_edge,
    "spatial_overlap_validation": check_spatial_overlap_validation,
    "dp_numerics": check_dp_numerics,
    "summa_parity": check_summa_parity,
    "tensor2d_validation": check_tensor2d_validation,
    "serving_validation": check_serving_validation,
    "oracle_validation": check_oracle_validation,
    "compressed_allreduce": check_compressed_allreduce,
}

if __name__ == "__main__":
    name = sys.argv[1]
    rest = sys.argv[2:]
    if rest and rest[0] == "--write":
        CHECKS[name](write_path=rest[1])
    elif rest:
        CHECKS[name](*rest)      # e.g. halo_edge <case>
    else:
        CHECKS[name]()
    print("CHECK-PASSED")
