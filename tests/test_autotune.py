"""Auto-tuner regression pins + advise() parity + plan plumbing.

Pins the tuner's selections for the paper's models at p ∈ {8, 64, 1024}
(Table 3 / Fig. 5 regimes: pure data parallelism while the gradient
exchange is cheap, hybrids once it dominates), checks that with the memory
switches pinned the tuner and the scalar-backed ``advise()`` agree on the
shared grid, and that ``build_cell(strategy="auto")`` deploys exactly the
sweep's cheapest feasible point.
"""
import numpy as np
import pytest

from repro.core import (OracleConfig, PAPER_V100_CLUSTER, TimeModel,
                        stats_for)
from repro.core.advisor import advise
from repro.core.autotune import (DEPLOYABLE_STRATEGIES, ORACLE_OF_EXEC,
                                 autotune, plan_for_arch)
from repro.core.sweep import all_switch_combos, sweep
from repro.models.cnn import RESNET50, CosmoFlowConfig

TM = TimeModel(PAPER_V100_CLUSTER)
CAP = TM.system.mem_capacity


def _weak(p, per_pe=2.0):
    B = max(int(round(per_pe * p)), 4)
    return OracleConfig(B=B, D=max(1_281_167, B))


# ---------------------------------------------------------------------------
# regression pins: paper-consistent winners (Table 3 / Fig. 5)
# ---------------------------------------------------------------------------

# the paper's Table-3/Fig-5 strategy set — summa postdates it, so the
# historical pins run with it excluded; the 2D winners get their own pins
NO_SUMMA = tuple(s for s in DEPLOYABLE_STRATEGIES if s != "summa")


@pytest.mark.parametrize("p,want_strategy,want_split", [
    (8, "data", (8, 1)),        # Table 3: data wins while GE is cheap
    (64, "data", (64, 1)),
    (1024, "df", (512, 2)),     # Fig. 5 regime: hybrid df past the p=512
])                              # data→df crossover (test_sweep golden)
def test_autotune_resnet50_pins(p, want_strategy, want_split):
    # CNN trunks cannot stack uniform stages, so the realistic call bars
    # pipeline exactly as plan_for_arch does for cnn-family archs
    plan = autotune(stats_for(RESNET50), TM, _weak(p), p, mem_cap=CAP,
                    fallback="data", allow_pipeline=False,
                    strategies=NO_SUMMA)
    assert plan.feasible and plan.source == "sweep"
    assert plan.strategy == want_strategy
    assert (plan.p1, plan.p2) == want_split
    assert plan.p1 * plan.p2 == p


@pytest.mark.parametrize("p,want,want_grid", [
    (8, "data", None),            # GE still cheap: the grid can't beat DP
    (64, "data", None),
    (1024, "summa", (2, 2)),      # past the crossover the 2D grid's panel
])                                # collectives undercut df's full-width fb
def test_autotune_resnet50_2d_pins(p, want, want_grid):
    """ISSUE-9 regression pins: with the full strategy set the tuner keeps
    data while it wins and hands the large-p regime to a summa grid."""
    plan = autotune(stats_for(RESNET50), TM, _weak(p), p, mem_cap=CAP,
                    fallback="data", allow_pipeline=False)
    assert plan.feasible and plan.strategy == want, plan.describe()
    if want_grid is not None:
        assert (plan.p2r, plan.p2c) == want_grid, plan.describe()
        assert plan.mesh_spec() == ((plan.p1,) + want_grid,
                                    ("data", "model_r", "model_c"))


@pytest.mark.parametrize("p,want_strategy", [
    (8, "spatial"),   # B = p/4 < p: pure data infeasible, spatial wins
    (64, "ds"),       # paper Fig. 4/5: data+spatial once DP groups help
                      # (with the zero1 switch axis this pick holds under
                      # BOTH comm models; the overlap model's spatial→ds
                      # crossover shift, 64→128, is pinned at the raw
                      # strategy-table level in test_oracle_overlap.py)
    (1024, "df"),     # beyond the paper grid the model favours df's
])                    # sharded gradient exchange (regression pin)
def test_autotune_cosmoflow_pins(p, want_strategy):
    B = max(int(round(0.25 * p)), 1)    # Fig-5 setting: 0.25 samples/PE
    for overlap in (False, True):
        cfg = OracleConfig(B=B, D=max(1584, B), overlap=overlap)
        plan = autotune(stats_for(CosmoFlowConfig(img=128)), TM, cfg, p,
                        mem_cap=CAP, fallback="ds", allow_pipeline=False,
                        strategies=NO_SUMMA)
        assert plan.feasible, plan
        assert plan.strategy == want_strategy, (overlap, plan.describe())
        assert plan.p1 * plan.p2 == p


def test_autotune_is_cheapest_feasible_point():
    """The plan must equal the raw sweep's min over deployable ok points."""
    cfg = _weak(64)
    plan = autotune(stats_for(RESNET50), TM, cfg, 64, mem_cap=CAP)
    res = sweep(stats_for(RESNET50), TM, cfg, [64],
                tuple(s for s in DEPLOYABLE_STRATEGIES if s != "serial"),
                mem_cap=CAP, switches="all")
    assert np.isclose(plan.total_s, res.total_s[res.ok].min(), rtol=1e-12)
    # and the chosen point's switch combo really is in the 16-combo axis
    assert (plan.remat, plan.zero1, plan.zero3,
            plan.seq_parallel) in all_switch_combos()


def test_memory_switch_axis_unlocks_tight_caps():
    """With a cap only ZeRO/remat configurations satisfy, the tuner must
    flip switches on rather than fall back — but only switches the chosen
    strategy's rules table can actually deploy."""
    stats = stats_for(RESNET50)
    cfg = OracleConfig(B=2048, D=1_281_167)
    base = autotune(stats, TM, cfg, 64, mem_cap=CAP, switches=None,
                    strategies=("data",))
    tight = base.mem_bytes * 0.7     # below the no-switch footprint
    plan = autotune(stats, TM, cfg, 64, mem_cap=tight, strategies=("data",))
    assert plan.feasible
    assert plan.n_switches_on > 0
    assert plan.mem_bytes <= tight
    # data rules can't shard params (zero3) or the residual stream
    assert not plan.zero3 and not plan.seq_parallel


def test_deployable_switch_mask_bars_unrealizable_combos():
    from repro.core.autotune import deployable_switch_mask
    res = sweep(stats_for(RESNET50), TM, OracleConfig(B=2048, D=1_281_167),
                [64], ("data", "df"), switches="all")
    m = deployable_switch_mask(res, allow_remat=False)
    assert not res.remat[m].any()                              # remat barred
    assert not (res.zero3[m] & (res.strategy[m] == "data")).any()
    assert (res.zero3[m] & (res.strategy[m] == "df")).any()    # df keeps it
    assert not (res.seq_parallel[m] & (res.strategy[m] == "data")).any()


def test_cnn_plans_never_claim_remat_or_undeployable_switches():
    """CNN forwards have no checkpointing: plan_for_arch must never claim
    a CNN configuration fits via remat (or any switch its rules table
    can't turn on)."""
    from repro.configs import get_config
    for arch in ("resnet50", "cosmoflow"):
        plan = plan_for_arch(get_config(arch), "train_4k", 64)
        assert not plan.remat, plan.describe()
        if plan.strategy not in ("df", "ep"):
            assert not plan.zero3


def test_model_width_constrains_hybrid_splits():
    """With the mesh already shaped, hybrid plans must land on its model
    width — never a split the rules can't realize."""
    stats = stats_for(RESNET50)
    cfg = OracleConfig(B=2048, D=1_281_167)
    plan = autotune(stats, TM, cfg, 64, mem_cap=CAP, strategies=("df",),
                    model_width=4)
    assert (plan.p1, plan.p2) == (16, 4)
    with pytest.raises(ValueError, match="filtered out"):
        autotune(stats, TM, cfg, 64, mem_cap=CAP, strategies=("df",),
                 model_width=5)   # 5 does not divide 64: nothing realizable


def test_autotune_empty_filter_raises_diagnosable_error():
    with pytest.raises(ValueError, match="filtered out"):
        # remat-only combo requested while remat is barred: mask drops all
        autotune(stats_for(RESNET50), TM, OracleConfig(B=64, D=6400), 8,
                 mem_cap=CAP, switches=[(True, False, False, False)],
                 allow_remat=False)


def test_autotune_fallback_when_nothing_fits():
    plan = autotune(stats_for(RESNET50), TM, _weak(64), 64,
                    mem_cap=1.0, fallback="data")   # 1 byte: nothing fits
    assert not plan.feasible and plan.source == "fallback"
    assert plan.strategy == "data"   # the requested fallback absorbed it


def test_autotune_tie_prefers_config_strategy():
    """At p=1 every strategy costs the same; the config's strategy wins."""
    plan = autotune(stats_for(RESNET50), TM, OracleConfig(B=64, D=6400), 1,
                    mem_cap=CAP, fallback="channel")
    assert plan.strategy == "channel"
    no_pref = autotune(stats_for(RESNET50), TM, OracleConfig(B=64, D=6400),
                       1, mem_cap=CAP)
    assert no_pref.strategy == "serial"   # canonical preference order


# ---------------------------------------------------------------------------
# parity with the scalar-backed advisor on the shared grid
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("p", [8, 48, 64])
def test_autotune_matches_advise_with_pinned_switches(p):
    """With the switch axis pinned to the config's combo, the tuner answers
    exactly what advise() ranks best over the same strategies."""
    stats = stats_for(RESNET50)
    cfg = OracleConfig(B=2048, D=1_281_167)
    strategies = ("data", "spatial", "filter", "channel", "df", "ds", "ep")
    plan = autotune(stats, TM, cfg, p, mem_cap=CAP, switches=None,
                    strategies=strategies)
    rec = advise(stats, TM, cfg, p, mem_cap=CAP, strategies=strategies)
    assert rec.best is not None
    assert plan.strategy == rec.best.strategy
    assert (plan.p1, plan.p2) == (rec.best.p1, rec.best.p2)
    assert np.isclose(plan.total_s, rec.best.total_s, rtol=1e-12)


# ---------------------------------------------------------------------------
# plan plumbing: exec mapping + build_cell(strategy="auto")
# ---------------------------------------------------------------------------

def test_exec_strategy_roundtrips_into_rules_tables():
    from repro.parallel.strategies import STRATEGIES
    plan = autotune(stats_for(RESNET50), TM, _weak(64), 64, mem_cap=CAP)
    for kind in ("train", "prefill", "decode"):
        assert plan.exec_strategy(kind) in STRATEGIES
    # every deployable oracle strategy must map into an executable table
    for exec_name, oracle_name in ORACLE_OF_EXEC.items():
        assert exec_name in STRATEGIES
        assert oracle_name in DEPLOYABLE_STRATEGIES


def test_zero1_exec_name_follows_switches():
    stats = stats_for(RESNET50)
    cfg = OracleConfig(B=2048, D=1_281_167)
    plan = autotune(stats, TM, cfg, 64, mem_cap=CAP, strategies=("df",),
                    switches=[(False, True, False, False)])
    assert plan.strategy == "df" and plan.zero1
    assert plan.exec_strategy("train") == "df_zero1"
    plan = autotune(stats, TM, cfg, 64, mem_cap=CAP, strategies=("df",),
                    switches=[(False, False, False, False)])
    assert plan.exec_strategy("train") == "df"


def test_build_cell_auto_deploys_the_tuned_plan():
    """Acceptance: build_cell(strategy='auto') returns a cell whose
    (strategy, mesh split, memory switches, optimizer) match the sweep's
    cheapest feasible point for that arch × shape × device count."""
    from repro.configs import get_config
    from repro.launch.build import build_cell, mesh_device_count
    from repro.launch.mesh import make_host_mesh

    cfg = get_config("qwen1.5-4b")
    mesh = make_host_mesh()
    cell = build_cell(cfg, "train_4k", mesh, "auto", smoke=True)
    plan = cell.meta["plan"]
    want = plan_for_arch(cfg, "train_4k", mesh_device_count(mesh), smoke=True,
                         model_width=mesh.shape.get("model"))
    assert plan == want                       # deterministic re-derivation
    # a hybrid plan's split is always realizable on the given mesh
    assert plan.p2 == mesh.shape.get("model") or plan.strategy not in (
        "df", "ds", "ep")
    assert cell.strategy == want.exec_strategy("train")
    assert plan.mesh_shape == (want.p1, want.p2)
    # bugfix: ZeRO-1 comes from the plan's switches, not name matching
    assert cell.meta["opt"].zero1 == want.zero1
    assert cell.kind == "train"


def test_plan_for_arch_smoke_models_all_families():
    """Every registered arch family resolves a plan (or falls back) without
    raising — the tuner is usable from any launch entry point."""
    from repro.configs import get_config
    for arch in ("qwen1.5-4b", "whisper-medium", "paligemma-3b", "resnet50"):
        plan = plan_for_arch(get_config(arch), "train_4k", 8, smoke=True)
        assert plan.p == 8
        assert plan.exec_strategy("train")
