"""Comm/compute overlap model (ISSUE 4, DESIGN.md §10).

Acceptance pins:
  * ``overlap=False`` reproduces the pre-overlap oracle bit-for-bit
    (hard-coded seed values, ≤ 1e-12 relative);
  * with overlap on, comm-carrying strategies get cheaper, never costlier,
    and the exposed comm is bounded below by the full-overlap floor
    ``T_comm − σ·window``;
  * the tuner's spatial-vs-data/ds crossovers shift measurably (the
    cosmoflow spatial→ds handoff moves from p=64 to p=128);
  * sweep/scalar parity holds under the overlap model too (test_sweep
    already runs the whole lattice with the overlap-on default).
"""
import numpy as np
import pytest

from repro.core import (OracleConfig, PAPER_V100_CLUSTER, TimeModel, project,
                        stats_for)
from repro.core.oracle import SIGMA_DEFAULTS
from repro.core.cluster import parse_sigma_table
from repro.core.sweep import sweep
from repro.models.cnn import RESNET50, CosmoFlowConfig, VGGConfig

TM = TimeModel(PAPER_V100_CLUSTER)

STATS = {"resnet50": lambda: stats_for(RESNET50),
         "cosmoflow": lambda: stats_for(CosmoFlowConfig(img=128)),
         "vgg16": lambda: stats_for(VGGConfig())}
CFGS = {"resnet50": dict(B=2048, D=1_281_167),
        "cosmoflow": dict(B=64, D=1584),
        "vgg16": dict(B=1024, D=1_281_167)}

# total_s of the SEED oracle (pre-overlap, PR 3) at these exact points —
# captured before this PR's change; overlap=False must reproduce them.
SEED_TOTALS = [
    ("resnet50", "data", 64, None, None, 17.717688568713932),
    ("resnet50", "spatial", 8, None, None, 130.83503134527038),
    ("resnet50", "ds", 64, 16, 4, 25.201475262273775),
    ("resnet50", "df", 64, 16, 4, 215.69785131118573),
    ("resnet50", "filter", 16, None, None, 4057.0648010982854),
    ("resnet50", "pipeline", 8, None, None, 267.9387961854857),
    ("cosmoflow", "data", 64, None, None, 0.10918292916105143),
    ("cosmoflow", "spatial", 8, None, None, 0.4342121355284114),
    ("cosmoflow", "ds", 64, 16, 4, 0.14470936428105144),
    ("cosmoflow", "df", 64, 16, 4, 1.0624493250010516),
    ("cosmoflow", "filter", 16, None, None, 20.092509570004207),
    ("cosmoflow", "pipeline", 8, None, None, 20.08302239396389),
    ("vgg16", "data", 64, None, None, 102.43584695960134),
    ("vgg16", "spatial", 8, None, None, 458.3659308441157),
    ("vgg16", "ds", 64, 16, 4, 161.43786360482852),
    ("vgg16", "df", 64, 16, 4, 314.83667451658596),
    ("vgg16", "filter", 16, None, None, 5058.80612177751),
    ("vgg16", "pipeline", 8, None, None, 2357.09044051548),
]


def _project(model, strat, p, p1, p2, **cfg_kw):
    cfg = OracleConfig(**CFGS[model], **cfg_kw)
    kw = {} if p1 is None else dict(p1=p1, p2=p2)
    return project(strat, STATS[model](), TM, cfg, p, **kw)


@pytest.mark.parametrize("model,strat,p,p1,p2,want", SEED_TOTALS)
def test_no_overlap_reproduces_seed_oracle(model, strat, p, p1, p2, want):
    got = _project(model, strat, p, p1, p2, overlap=False).total_s
    assert abs(got - want) <= 1e-12 * want, (got, want)


@pytest.mark.parametrize("model,strat,p,p1,p2,want", SEED_TOTALS)
def test_overlap_never_costlier_and_comp_invariant(model, strat, p, p1, p2,
                                                   want):
    on = _project(model, strat, p, p1, p2)
    off = _project(model, strat, p, p1, p2, overlap=False)
    assert on.total_s <= off.total_s + 1e-15
    assert on.comp_s == off.comp_s          # overlap discounts comm only
    assert on.mem_bytes == off.mem_bytes
    # FB collectives and pipeline P2P stay serial (data-dependent)
    assert on.comm_fb_s == off.comm_fb_s
    assert on.comm_p2p_s == off.comm_p2p_s


def test_exposed_comm_matches_closed_form():
    """exposed = T_comm − σ·min(window, T_comm): check the halo and GE terms
    against the definition, via σ=0 / σ=1 runs that bracket the default."""
    full = _project("cosmoflow", "spatial", 8, None, None, overlap=False)
    zero = _project("cosmoflow", "spatial", 8, None, None,
                    sigma_levels={"model": 0.0, "data": 0.0})
    one = _project("cosmoflow", "spatial", 8, None, None,
                   sigma_levels={"model": 1.0, "data": 1.0})
    dflt = _project("cosmoflow", "spatial", 8, None, None)
    # σ=0 with overlap "on" is the serial model
    assert np.isclose(zero.total_s, full.total_s, rtol=1e-15)
    # defaults interpolate between the σ=1 floor and the serial ceiling
    assert one.comm_halo_s <= dflt.comm_halo_s <= full.comm_halo_s
    assert one.comm_ge_s <= dflt.comm_ge_s <= full.comm_ge_s
    # σ=1 on a comm term smaller than its window exposes nothing
    if full.comm_halo_s <= full.comp_s:
        assert one.comm_halo_s <= 1e-15 * full.total_s
    # default σ line up with SIGMA_DEFAULTS exactly
    w_halo = full.comm_halo_s - one.comm_halo_s      # min(window, comm)
    assert np.isclose(dflt.comm_halo_s,
                      full.comm_halo_s - SIGMA_DEFAULTS["model"] * w_halo,
                      rtol=1e-12)


def test_sigma_monotone_in_levels():
    prev = None
    for s in (0.0, 0.25, 0.5, 0.75, 1.0):
        t = _project("resnet50", "ds", 64, 16, 4,
                     sigma_levels={"model": s, "data": s}).total_s
        if prev is not None:
            assert t <= prev + 1e-15
        prev = t


def test_overlap_shifts_cosmoflow_spatial_ds_crossover():
    """The tentpole's measurable re-ranking: with the halo exchange hidden
    under interior compute, pure spatial stays ahead of the ds hybrid
    longer — the crossover moves from p=64 (paper/serial accounting) to
    p=128 under the overlap model (0.25 samples/PE weak scaling)."""
    stats = stats_for(CosmoFlowConfig(img=128))
    batch_of = lambda p: max(int(round(0.25 * p)), 1)   # noqa: E731
    grid = [2 ** k for k in range(11)]
    cap = TM.system.mem_capacity
    res_serial = sweep(stats, TM,
                       OracleConfig(B=batch_of(1024), D=1584, overlap=False),
                       grid, batch_for_p=batch_of, mem_cap=cap)
    res_overlap = sweep(stats, TM,
                        OracleConfig(B=batch_of(1024), D=1584),
                        grid, batch_for_p=batch_of, mem_cap=cap)
    assert res_serial.crossover("spatial", "ds") == 64
    assert res_overlap.crossover("spatial", "ds") == 128


def test_overlap_preserves_resnet_data_df_crossover():
    """GE overlap discounts data AND df alike: the resnet50 data→df
    crossover stays at p=512 (test_sweep's golden) under both models."""
    stats = stats_for(RESNET50)
    batch_of = lambda p: max(2 * p, 4)   # noqa: E731
    grid = [2 ** k for k in range(11)]
    for overlap in (False, True):
        res = sweep(stats, TM,
                    OracleConfig(B=batch_of(1024), D=1_281_167,
                                 overlap=overlap),
                    grid, batch_for_p=batch_of,
                    mem_cap=TM.system.mem_capacity)
        assert res.crossover("data", "df") == 512, overlap


def test_parse_sigma_table_and_rejects_unknown_levels():
    assert parse_sigma_table(None) is None
    assert parse_sigma_table("model=0.5,data=0.25") == (("model", 0.5),
                                                        ("data", 0.25))
    with pytest.raises(ValueError, match="--sigma"):
        parse_sigma_table("pod=0.5")
    cfg = OracleConfig(B=8, D=8, sigma_levels=(("model", 2.0),))
    assert cfg.sigma_for("model") == 1.0        # clamped into [0, 1]
    assert cfg.sigma_for("data") == SIGMA_DEFAULTS["data"]
    off = OracleConfig(B=8, D=8, overlap=False,
                       sigma_levels=(("model", 0.9),))
    assert off.sigma_for("model") == 0.0        # overlap off wins


def test_roofline_overlap_bounds():
    from repro.core.roofline import Roofline
    r = Roofline(compute_s=1.0, memory_s=0.4, collective_s=0.5,
                 collective_by_axis={}, model_flops=1.0, hlo_flops_total=1.0,
                 chips=1, temp_bytes=0, fits_hbm=True)
    assert r.serial_s == pytest.approx(1.9)
    assert r.step_time_s == pytest.approx(1.0)
    assert r.overlapped_s(1.0) == pytest.approx(1.0)    # full overlap
    assert r.overlapped_s(0.0) == pytest.approx(1.5)    # coll fully exposed
    assert r.step_time_s <= r.overlapped_s(0.8) <= r.serial_s
    assert "overlapped_s" in r.to_json() and "serial_s" in r.to_json()
