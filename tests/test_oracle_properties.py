"""Property-based tests of the oracle's invariants (hypothesis)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="property tests need hypothesis (not in image)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (OracleConfig, TimeModel, cpu_host_model, project,
                        stats_for)
from repro.core.hardware import Level
from repro.core.layer_stats import LayerStat
from repro.models.cnn import RESNET50

SYS = cpu_host_model(alpha=1e-5, beta=1e-9, flops=1e12)
STATS = stats_for(RESNET50)


def mk_cfg(B=256, **kw):
    return OracleConfig(B=B, D=B * 4, **kw)


# ---------------------------------------------------------------------------
# Collective formulas (paper §4.3)
# ---------------------------------------------------------------------------
@given(p=st.integers(2, 1024), m=st.integers(1, 10 ** 9))
@settings(max_examples=60, deadline=None)
def test_ring_allreduce_formula(p, m):
    lvl = Level("t", alpha=1e-6, beta=1e-10)
    t = lvl.allreduce_ring(p, m)
    assert np.isclose(t, 2 * (p - 1) * (1e-6 + m / p * 1e-10))
    # allgather is half of allreduce's ring traffic
    assert lvl.allgather_ring(p, m) <= t


@given(p=st.integers(2, 512), m1=st.integers(1, 10 ** 8),
       m2=st.integers(1, 10 ** 8))
@settings(max_examples=40, deadline=None)
def test_collective_monotone_in_message(p, m1, m2):
    lvl = Level("t", alpha=1e-6, beta=1e-10)
    lo, hi = sorted((m1, m2))
    assert lvl.allreduce(p, lo) <= lvl.allreduce(p, hi) + 1e-12


@given(phi=st.floats(1.0, 8.0))
@settings(max_examples=20, deadline=None)
def test_contention_penalty_scales_bandwidth_term(phi):
    lvl = Level("t", alpha=0.0, beta=1e-10)
    base = lvl.allreduce_ring(16, 1 << 20)
    assert np.isclose(lvl.allreduce_ring(16, 1 << 20, phi=phi), base * phi)


# ---------------------------------------------------------------------------
# Table-3 projections
# ---------------------------------------------------------------------------
@given(p=st.sampled_from([2, 4, 8, 16, 32, 64, 128, 256]))
@settings(max_examples=20, deadline=None)
def test_data_parallel_memory_decreases_with_p(p):
    tm = TimeModel(SYS)
    m1 = project("data", STATS, tm, mk_cfg(), p).mem_bytes
    m2 = project("data", STATS, tm, mk_cfg(), 2 * p).mem_bytes
    assert m2 <= m1


@given(p=st.sampled_from([2, 4, 8, 16, 32, 64]))
@settings(max_examples=10, deadline=None)
def test_compute_scales_inversely(p):
    tm = TimeModel(SYS)
    c1 = project("data", STATS, tm, mk_cfg(), p).comp_s
    c2 = project("data", STATS, tm, mk_cfg(), 2 * p).comp_s
    assert c2 < c1


def test_filter_channel_memory_shards_weights_not_acts():
    tm = TimeModel(SYS)
    cfg = mk_cfg()
    f = project("filter", STATS, tm, cfg, 16)
    d = project("data", STATS, tm, cfg, 16)
    serial = project("serial", STATS, tm, cfg, 1)
    # paper §5.3.2: filter keeps full activations (memory redundancy)
    assert f.mem_bytes > d.mem_bytes * 0.5
    assert f.mem_bytes < serial.mem_bytes  # but weights did shard


def test_scaling_limits_enforced():
    tm = TimeModel(SYS)
    cfg = mk_cfg(B=32)
    assert not project("data", STATS, tm, cfg, 64).feasible  # p > B
    assert not project("filter", STATS, tm, cfg, 2048).feasible  # > min F
    assert not project("pipeline", STATS, tm, cfg, 10 ** 4).feasible  # > G
    assert project("df", STATS, tm, cfg, 64, p1=16, p2=4).feasible


def test_pipeline_matches_schedule_simulation():
    """Table-3 'Layer' row == the GPipe fill/drain closed form over the DP
    partitioner's bottleneck stage, and that closed form upper-bounds a
    discrete-event simulation of the actual (non-uniform) schedule."""
    from repro.core.oracle import pipeline_stage_terms, precompute
    from repro.core.partition import min_max_partition, stage_sums
    tm = TimeModel(SYS)
    cfg = mk_cfg(B=64)
    p, S = 4, cfg.segments
    proj = project("pipeline", STATS, tm, cfg, p)
    T = precompute(STATS, tm)
    mfw, mbw, mwu, *_ = pipeline_stage_terms(T, p)
    stage_max = (mfw + mbw) * (cfg.B / S)   # bottleneck stage per microbatch
    sim_iter = (p + S - 1) * stage_max      # paper's fill-drain makespan
    sim_epoch = sim_iter * proj.iterations + proj.iterations * mwu
    assert np.isclose(proj.comp_s, sim_epoch, rtol=1e-6)
    # the DP bottleneck can never beat the perfectly balanced lower bound
    FW = sum(tm.fw(s) for s in STATS)
    BW = sum(tm.bw(s) for s in STATS)
    assert mfw + mbw >= (FW + BW) / p - 1e-18
    # event-driven makespan of the real non-uniform schedule: the closed
    # form must be a (tight-ish) upper bound
    bounds = min_max_partition(T.fw + T.bw, p).bounds
    st = stage_sums(T.fw + T.bw, bounds) * (cfg.B / S)
    finish = np.zeros((p, S))
    for i in range(p):
        for m in range(S):
            prev_mb = finish[i, m - 1] if m else 0.0
            prev_st = finish[i - 1, m] if i else 0.0
            finish[i, m] = max(prev_mb, prev_st) + st[i]
    assert finish[-1, -1] <= sim_iter + 1e-18


@given(seed=st.integers(0, 10))
@settings(max_examples=5, deadline=None)
def test_df_comm_between_pure_strategies(seed):
    """df's GE shrinks vs data (weights /p2); its FB term shrinks vs filter."""
    tm = TimeModel(SYS)
    cfg = mk_cfg(B=1024)
    p = 64
    data = project("data", STATS, tm, cfg, p)
    filt = project("filter", STATS, tm, cfg, p)
    df = project("df", STATS, tm, cfg, p, p1=16, p2=4)
    assert df.comm_fb_s < filt.comm_fb_s
    # df's allreduce involves fewer ranks and less data but pays contention φ;
    # it must still beat pure-data GE at equal p for this model
    assert df.comm_ge_s < data.comm_ge_s * cfg.phi_hybrid


def test_spatial_infeasible_for_recurrent_seq():
    ssm_stat = LayerStat("s", "ssm", 64, 64, 1024, 1e6, F=4, C=4, spatial=64,
                         seq_recurrent=True)
    tm = TimeModel(SYS)
    proj = project("spatial", [ssm_stat], tm, mk_cfg(), 4)
    assert not proj.feasible


# ---------------------------------------------------------------------------
# Memory-model extensions (beyond paper)
# ---------------------------------------------------------------------------
def test_remat_and_zero3_reduce_memory():
    tm = TimeModel(SYS)
    base = project("df", STATS, tm, mk_cfg(), 64, p1=16, p2=4).mem_bytes
    remat = project("df", STATS, tm, mk_cfg(remat=True), 64, p1=16,
                    p2=4).mem_bytes
    zero3 = project("df", STATS, tm, mk_cfg(remat=True, zero3=True), 64,
                    p1=16, p2=4).mem_bytes
    assert remat < base
    assert zero3 < remat


def test_seq_parallel_comm_charged_and_ideal_interconnect_recovers():
    """ISSUE-9 satellite: seq_parallel is no longer a free memory switch.
    On a real system each residual-sharded block pays 4 ring collectives
    per step (allgather in, reduce-scatter out, mirrored in backward),
    σ-overlapped against the forward window — so fb comm and the total
    strictly grow while memory still shrinks. With an ideal interconnect
    (α = β = 0) the term vanishes and the old memory-only totals are
    recovered exactly."""
    tm = TimeModel(SYS)
    cfg, cfg_sp = mk_cfg(), mk_cfg(seq_parallel=True)
    lattice = (("filter", {}), ("df", dict(p1=4, p2=4)),
               ("summa", dict(p1=2, p2=8, p2r=2, p2c=4)))
    for s, kw in lattice:
        base = project(s, STATS, tm, cfg, 16, **kw)
        sp = project(s, STATS, tm, cfg_sp, 16, **kw)
        assert base.feasible and sp.feasible, s
        assert sp.mem_bytes < base.mem_bytes, s       # the switch still pays
        assert sp.comm_fb_s > base.comm_fb_s, s       # ...but comm is charged
        assert sp.total_s > base.total_s, s
    tmi = TimeModel(cpu_host_model(alpha=0.0, beta=0.0, flops=1e12))
    for s, kw in lattice:
        base = project(s, STATS, tmi, cfg, 16, **kw)
        sp = project(s, STATS, tmi, cfg_sp, 16, **kw)
        assert sp.comm_fb_s == base.comm_fb_s, s
        assert sp.total_s == base.total_s, s
        assert sp.mem_bytes < base.mem_bytes, s


def test_gradient_compression_quantization_error_bounded(key=None):
    import jax, jax.numpy as jnp
    from repro.optim.compress import dequantize_int8, quantize_int8
    g = jax.random.normal(jax.random.PRNGKey(0), (128,)) * 3.0
    q, scale, res = quantize_int8(g)
    err = jnp.abs(dequantize_int8(q, scale) + res - g)
    assert float(jnp.max(err)) < 1e-5  # error feedback captures all residue
