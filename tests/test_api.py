"""Session facade (repro.api.Oracle) + ClusterSpec (DESIGN.md §11).

Pins the ISSUE-5 acceptance surface:
  * session ↔ legacy parity: project / sweep / tune answers within 1e-12
    of the loose-object call signatures they replace,
  * topology constraints: a (4,2)-torus rejects model axes spanning both
    dims, and a constrained ClusterSpec provably changes the tuner's plan
    vs the unconstrained one,
  * ``ClusterSpec.fitted_from`` round-trips synthetic measurements (α/β
    recovered by the Hockney fit, φ/σ exactly),
  * the deduplicated CLI wiring (ClusterSpec.from_cli_args); the PR-5
    sweep.parse_*_table deprecation shims are retired for good.
"""
import argparse

import numpy as np
import pytest

from repro.api import Oracle
from repro.core import (OracleConfig, PAPER_V100_CLUSTER, TimeModel,
                        stats_for)
from repro.core.autotune import autotune, plan_for_arch
from repro.core.cluster import (ClusterSpec, Measurement, Torus,
                                add_cluster_args, parse_phi_table,
                                parse_sigma_table)
from repro.core.hardware import Level
from repro.core.oracle import project
from repro.core.sweep import sweep
from repro.models.cnn import RESNET50, CosmoFlowConfig

TM = TimeModel(PAPER_V100_CLUSTER)


# ---------------------------------------------------------------------------
# session ↔ legacy parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("p", [8, 64, 1024])
def test_session_project_matches_legacy(p):
    stats = stats_for(RESNET50)
    cfg = OracleConfig(B=2 * p, D=1_281_167)
    ses = Oracle("resnet50", "train_4k", "paper", batch=2 * p,
                 dataset=1_281_167)
    for s in ("data", "spatial", "filter", "channel", "df", "ds"):
        a = project(s, stats, TM, cfg, p)
        b = ses.project(s, p)
        assert np.isclose(a.total_s, b.total_s, rtol=1e-12, atol=0)
        assert np.isclose(a.mem_bytes, b.mem_bytes, rtol=1e-12, atol=0)
        assert (a.p1, a.p2, a.feasible) == (b.p1, b.p2, b.feasible)


def test_session_sweep_matches_legacy():
    stats = stats_for(RESNET50)
    cfg = OracleConfig(B=128, D=1_281_167)
    ses = Oracle("resnet50", "train_4k", "paper", batch=128,
                 dataset=1_281_167)
    a = sweep(stats, TM, cfg, [1, 2, 8, 12, 64])
    b = ses.sweep([1, 2, 8, 12, 64])
    assert len(a) == len(b)
    np.testing.assert_allclose(a.total_s, b.total_s, rtol=1e-12)
    np.testing.assert_allclose(a.mem_bytes, b.mem_bytes, rtol=1e-12)
    assert (a.feasible == b.feasible).all()


@pytest.mark.parametrize("p", [8, 64])
def test_session_tune_matches_plan_for_arch(p):
    from repro.configs import get_config
    want = plan_for_arch(get_config("resnet50"), "train_4k", p)
    got = Oracle("resnet50", "train_4k").tune(p)
    assert want == got


def test_session_cluster_swap_is_one_argument():
    """The multi-cluster scenario the redesign exists for: same session
    question, different machine, different answer."""
    ses_gpu = Oracle("resnet50", "train_4k", "paper", batch=2048)
    ses_tpu = ses_gpu.with_cluster("tpu")
    a, b = ses_gpu.project("data", 64), ses_tpu.project("data", 64)
    assert a.total_s != b.total_s          # different α–β/peak actually used
    assert ses_tpu.cluster.name == "tpu-v5e-256"
    # with_cluster leaves the original session untouched
    assert ses_gpu.cluster.name == "v100-abci"


# ---------------------------------------------------------------------------
# topology constraints
# ---------------------------------------------------------------------------

def test_torus_rejects_model_axis_spanning_dims():
    t = Torus((4, 2))
    assert t.model_widths() == (1, 2, 4)
    assert not t.split_mask(8, 1, 8)      # p2=8 would span both dims
    assert t.split_mask(8, 2, 4)          # ring of 4 in dim 0
    assert t.split_mask(8, 4, 2)
    assert t.split_mask(8, 1, 8, strategy="pipeline")   # chains may snake
    assert not t.split_mask(6, 3, 2)      # 6 does not tile the 8-PE torus
    # model axis confined to the extent-2 dim
    t2 = Torus((4, 2), model_dims=(1,))
    assert t2.model_widths() == (1, 2)
    assert not t2.split_mask(8, 2, 4)


def test_sweep_prunes_topology_infeasible_splits():
    stats = stats_for(CosmoFlowConfig(img=128))
    cfg = OracleConfig(B=2, D=1584)
    cluster = ClusterSpec.from_system(
        PAPER_V100_CLUSTER, topology=Torus((4, 2)))
    res = sweep(stats, TM, cfg, [8], cluster=cluster)
    free = sweep(stats, TM, cfg, [8])
    # spatial at p=8 needs a model ring of 8 — pruned on the (4,2) torus
    sp = res.select((res.strategy == "spatial"))
    assert not sp.feasible.any()
    assert "topology" in str(sp.limit[0])
    assert free.select(free.strategy == "spatial").feasible.any()
    # and the surviving ring widths are exactly the torus divisors —
    # except pipeline (stage chain may snake across dims) and summa, whose
    # (r × c) grid legitimately embeds its two rings in two DISTINCT dims
    ok = res.select(res.ok & (res.strategy != "pipeline")
                    & (res.strategy != "summa"))
    assert set(np.unique(ok.p2)) <= {1, 2, 4}
    sm = res.select(res.ok & (res.strategy == "summa"))
    assert 8 in sm.p2                     # the 4×2 grid fills the torus
    for r_, c_ in zip(sm.p2r, sm.p2c):
        r_, c_ = int(r_), int(c_)
        assert (4 % r_ == 0 and 2 % c_ == 0) \
            or (2 % r_ == 0 and 4 % c_ == 0), (r_, c_)
    pipe = res.select(res.ok & (res.strategy == "pipeline"))
    assert 8 in pipe.p2                   # the chain exemption is real
    # the α–β numbers themselves are untouched — only feasibility moved
    np.testing.assert_allclose(res.total_s, free.total_s, rtol=1e-12)


def test_topology_changes_the_chosen_plan_pinned():
    """Acceptance pin: a topology-constrained ClusterSpec provably changes
    the tuner's plan vs the unconstrained one (1D strategies — summa is
    excluded here because its 2D grid legitimately EMBEDS in the torus,
    which the second half pins)."""
    from repro.core.autotune import DEPLOYABLE_STRATEGIES
    no_summa = tuple(s for s in DEPLOYABLE_STRATEGIES if s != "summa")
    stats = stats_for(CosmoFlowConfig(img=128))
    cfg = OracleConfig(B=2, D=1584)
    free = autotune(stats, TM, cfg, 8, fallback="ds", allow_pipeline=False,
                    strategies=no_summa)
    assert (free.strategy, free.p2) == ("spatial", 8)   # test_autotune pin
    cluster = ClusterSpec.from_system(
        PAPER_V100_CLUSTER, topology=Torus((4, 2)))
    bound = autotune(stats, TM, cfg, 8, fallback="ds", allow_pipeline=False,
                     cluster=cluster, strategies=no_summa)
    assert bound.feasible
    assert (bound.strategy, bound.p2) != (free.strategy, free.p2)
    assert bound.strategy == "ds" and bound.p2 in (2, 4)
    # summa's (r × c) grid rides TWO torus dims, so the same constraint
    # does NOT displace it: the full-set winner keeps its plan, grid
    # embedded with each ring in its own dim
    free_2d = autotune(stats, TM, cfg, 8, fallback="ds",
                       allow_pipeline=False)
    bound_2d = autotune(stats, TM, cfg, 8, fallback="ds",
                        allow_pipeline=False, cluster=cluster)
    assert free_2d.strategy == "summa" and bound_2d == free_2d
    # the same constraint through the session facade
    ses = Oracle("cosmoflow", "train_4k", cluster, batch=2, dataset=1584,
                 mem_cap=TM.system.mem_capacity)
    plan = ses.tune(8)
    assert plan.strategy == "summa" and (plan.p2r, plan.p2c) == (4, 1)


def test_exhausted_model_dims_force_pure_data():
    """resnet50 @ p=1024 tunes to df (512×2) among the 1D strategies
    (test_autotune pin; the full set now prefers a summa grid); a torus
    with no model-capable dim must fall back to pure DP — summa included,
    since BOTH its rings need a model dim."""
    from repro.core.autotune import DEPLOYABLE_STRATEGIES
    no_summa = tuple(s for s in DEPLOYABLE_STRATEGIES if s != "summa")
    stats = stats_for(RESNET50)
    cfg = OracleConfig(B=2048, D=2048)
    free = autotune(stats, TM, cfg, 1024, fallback="data",
                    allow_pipeline=False, strategies=no_summa)
    assert (free.strategy, free.p1, free.p2) == ("df", 512, 2)
    full = autotune(stats, TM, cfg, 1024, fallback="data",
                    allow_pipeline=False)
    assert full.strategy == "summa" and full.total_s <= free.total_s
    cluster = ClusterSpec.from_system(
        PAPER_V100_CLUSTER, topology=Torus((1024,), model_dims=()))
    bound = autotune(stats, TM, cfg, 1024, fallback="data",
                     allow_pipeline=False, cluster=cluster)
    assert bound.feasible
    assert (bound.strategy, bound.p1, bound.p2) == ("data", 1024, 1)


def test_plan_for_arch_prunes_via_cluster():
    from repro.configs import get_config
    cluster = ClusterSpec.from_system(
        PAPER_V100_CLUSTER, topology=Torus((4, 2)))
    plan = plan_for_arch(get_config("cosmoflow"), "train_4k", 8,
                         cluster=cluster)
    assert plan.p2 in (1, 2, 4), plan.describe()
    # ClusterSpec also rides the legacy ``system`` parameter
    plan2 = plan_for_arch(get_config("cosmoflow"), "train_4k", 8,
                          system=cluster)
    assert plan == plan2


# ---------------------------------------------------------------------------
# fitted_from + artifact round-trip
# ---------------------------------------------------------------------------

def _synthetic_measurements(lvl: Level, level: str = "data", p: int = 8):
    out = []
    for pattern, factor in (("ar", 2 * (p - 1)), ("ag", p - 1)):
        sizes = (1 << 12, 1 << 16, 1 << 20, 1 << 23)
        secs = tuple(factor * (lvl.alpha + n / p * lvl.beta) for n in sizes)
        out.append(Measurement(level=level, kind="collective",
                               pattern=pattern, p=p, nbytes=sizes,
                               seconds=secs))
    out.append(Measurement(level=level, kind="contention",
                           alone_s=0.01, shared_s=0.017, flows=2))
    out.append(Measurement(level=level, kind="overlap",
                           comp_s=0.02, comm_s=0.01, both_s=0.022))
    return out


def test_fitted_from_roundtrips_synthetic_measurements():
    true = Level("syn", alpha=2e-5, beta=1 / 7e9)
    ms = _synthetic_measurements(true)
    spec = ClusterSpec.fitted_from(ms, base="host")
    got = spec.level("data")
    assert np.isclose(got.alpha, true.alpha, rtol=1e-6)
    assert np.isclose(got.beta, true.beta, rtol=1e-6)
    assert np.isclose(dict(spec.phi)["data"], 1.7, rtol=1e-12)
    assert np.isclose(dict(spec.sigma)["data"], 0.8, rtol=1e-12)
    # noiseless fit → residual ~0; residuals are reported either way
    assert dict(spec.fit_residuals)["data/alpha_beta"] < 1e-9
    # dict-shaped measurements (the JSON artifact) fit identically
    spec2 = ClusterSpec.fitted_from([m.to_json() for m in ms], base="host")
    assert spec2.level("data") == got
    # and the full spec round-trips through its JSON artifact form
    spec3 = ClusterSpec.from_json(spec.to_json())
    assert spec3 == spec
    assert spec3.fit_residuals == spec.fit_residuals


def test_fitted_phi_sigma_are_clamped():
    ms = [Measurement(level="data", kind="contention",
                      alone_s=0.01, shared_s=0.05, flows=2),   # >2x
          Measurement(level="model", kind="overlap",
                      comp_s=0.02, comm_s=0.01, both_s=0.035)]  # "negative"
    spec = ClusterSpec.fitted_from(ms, base="host")
    assert dict(spec.phi)["data"] == 2.0          # clamped to flows
    assert dict(spec.sigma)["model"] == 0.0       # clamped to [0, 1]


def test_calibrate_closes_the_loop_into_projections():
    """Oracle.calibrate(): fitted φ/σ/α/β must actually reach the
    session's next projection (synthetic measurements — no timing)."""
    ses = Oracle("resnet50", "train_4k", "paper", batch=128)
    before = ses.project("df", 64).total_s
    true = Level("syn", alpha=5e-4, beta=1 / 1e9)   # much slower wire
    spec = ClusterSpec.fitted_from(
        _synthetic_measurements(true), base=ses.cluster)
    ses2 = ses.with_cluster(spec)
    after = ses2.project("df", 64)
    assert after.total_s > before                  # slower fitted data level
    assert ses2.cfg.phi_levels == spec.phi
    assert ses2.cfg.sigma_levels == spec.sigma


# ---------------------------------------------------------------------------
# CLI dedup + deprecation shims
# ---------------------------------------------------------------------------

def _parse(argv, default_system="paper"):
    ap = argparse.ArgumentParser()
    add_cluster_args(ap, default_system=default_system)
    return ap.parse_args(argv)


def test_from_cli_args_is_the_one_wiring():
    a = _parse(["--phi", "data=2.0,model=1.2", "--sigma", "model=0.5",
                "--topology", "4x2", "--model-dims", "1"])
    spec = ClusterSpec.from_cli_args(a)
    assert spec.phi == (("data", 2.0), ("model", 1.2))
    assert spec.sigma == (("model", 0.5),)
    assert spec.topology == Torus((4, 2), model_dims=(1,))
    assert spec.system == PAPER_V100_CLUSTER
    cfg = spec.oracle_config(B=64)
    assert cfg.phi_levels == spec.phi and cfg.sigma_levels == spec.sigma
    # defaults: no tables, no topology — bit-identical legacy behavior
    bare = ClusterSpec.from_cli_args(_parse([]))
    assert bare.phi is None and bare.sigma is None and bare.topology is None


def test_session_tune_uses_the_sessions_stats():
    """A session seq override must reach tune(): the plan ranks exactly
    the stats project()/sweep() report, not shape.seq_len recomputes."""
    ses = Oracle("qwen1.5-4b", "train_4k", "paper", smoke=True, seq=64,
                 batch=8)
    from repro.parallel.pipeline import pipeline_supported
    mc = ses.model_cfg
    want = autotune(ses.stats, ses.tm, ses.cfg, 8,
                    fallback=ses.arch_cfg.strategy_for("train_4k"),
                    cluster=ses.cluster,
                    allow_remat=True,
                    allow_pipeline=pipeline_supported(mc) is None,
                    max_stages=mc.n_layers)
    got = ses.tune(8)
    assert want == got
    # and a default-seq session differs (the override is load-bearing)
    other = Oracle("qwen1.5-4b", "train_4k", "paper", smoke=True,
                   batch=8).tune(8)
    assert other.total_s != got.total_s


def test_model_dims_without_topology_is_rejected(tmp_path):
    with pytest.raises(ValueError, match="--model-dims requires"):
        ClusterSpec.from_cli_args(
            _parse(["--model-dims", "0"]))
    # but it may re-constrain a topology carried by a --cluster artifact
    import json
    spec = ClusterSpec.from_system(PAPER_V100_CLUSTER,
                                   topology=Torus((4, 2)))
    art = tmp_path / "fit.json"
    art.write_text(json.dumps(spec.to_json()))
    got = ClusterSpec.from_cli_args(
        _parse(["--cluster", str(art), "--model-dims", ""]))
    assert got.topology == Torus((4, 2), model_dims=())
    assert got.topology.model_widths() == (1,)


def test_parse_tables_reject_unknown_levels():
    assert parse_phi_table(None) is None
    assert parse_sigma_table("model=0.5") == (("model", 0.5),)
    with pytest.raises(ValueError, match="not consumed"):
        parse_phi_table("pod=2.0")
    with pytest.raises(ValueError, match="LEVEL=VALUE"):
        parse_sigma_table("model")


def test_both_clis_share_the_cluster_flags():
    """sweep.__main__ and autotune.__main__ must expose the same --phi/
    --sigma/--topology wiring (the satellite dedup) and agree on what the
    flags mean."""
    from importlib.util import find_spec
    for name in ("repro.core.sweep", "repro.core.autotune"):
        src = open(find_spec(name).origin).read()
        assert "add_cluster_args(ap" in src, name
        assert "ClusterSpec.from_cli_args" in src, name
        # the copy-pasted table parsers are gone for good (no shims either)
        assert "def _parse_level_table" not in src, name


def test_sweep_shims_are_retired():
    """The PR-5 transition shims are gone: core.sweep no longer exports the
    parser names at all — core.cluster is the one home."""
    from repro.core import sweep as sweep_mod
    for name in ("parse_phi_table", "parse_sigma_table"):
        assert not hasattr(sweep_mod, name), name


# ---------------------------------------------------------------------------
# deployment plumbing
# ---------------------------------------------------------------------------

def test_session_build_deploys_the_tuned_plan():
    """Oracle(...).tune(p) → .build(mesh): the built cell carries exactly
    the session's plan (strategy, split, switches, optimizer)."""
    from repro.launch.build import mesh_device_count
    from repro.launch.mesh import make_host_mesh
    ses = Oracle("qwen1.5-4b", "train_4k", "host", smoke=True)
    mesh = make_host_mesh()
    cell = ses.build(mesh)
    plan = cell.meta["plan"]
    want = ses.tune(mesh_device_count(mesh),
                    model_width=mesh.shape.get("model"))
    assert plan == want
    assert cell.strategy == want.exec_strategy("train")
    assert cell.meta["opt"].zero1 == want.zero1
    assert cell.kind == "train"


def test_session_validate_smoke():
    """validate() measures the reduced model on the (single-device) host
    mesh and projects the same point — the Fig-3 loop as one method."""
    from repro.launch.mesh import make_host_mesh
    ses = Oracle("qwen1.5-4b", "train_4k", "host", smoke=True)
    pts = ses.validate(make_host_mesh(), ("data",), batch_size=4, seq=32)
    assert len(pts) == 1 and pts[0].strategy == "data"
    assert pts[0].measured_s > 0 and pts[0].projected_s > 0
