"""Model-level parity: scan==unrolled; prefill+decode == full forward."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.nn import AttentionConfig, FFNConfig, MoEConfig, RGLRUConfig
from repro.nn.module import tree_init
from repro.models import (EncDecConfig, EncDecLM, LMConfig, TransformerLM,
                          VLM, VLMConfig)

B, S, V, D = 2, 32, 64, 32


def mk_dense(n_layers=4, **kw):
    return LMConfig(
        name="tiny", vocab=V, d_model=D, n_layers=n_layers,
        attn=AttentionConfig(D, 4, 2, 8, qk_norm=True, dtype=jnp.float32),
        ffn=FFNConfig(D, 64, dtype=jnp.float32), dtype=jnp.float32, **kw)


def test_dense_scan_equals_unrolled(key):
    lm = TransformerLM(mk_dense())
    p = tree_init(lm.params_spec(), key)
    toks = jax.random.randint(key, (B, S), 0, V)
    a, _ = lm.apply(p, toks, scan_layers=True, attn_impl="plain")
    b, _ = lm.apply(p, toks, scan_layers=False, attn_impl="plain")
    np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4)


def test_dense_prefill_decode(key):
    lm = TransformerLM(mk_dense())
    p = tree_init(lm.params_spec(), key)
    toks = jax.random.randint(key, (B, S), 0, V)
    full, _ = lm.apply(p, toks, attn_impl="plain")
    cache = jax.tree.map(jnp.zeros_like,
                         tree_init(lm.cache_spec(B, S, dtype=jnp.float32), key))
    lg, cache = lm.prefill(p, toks[:, :S // 2], cache, attn_impl="plain")
    np.testing.assert_allclose(lg[:, 0], full[:, S // 2 - 1], rtol=2e-3,
                               atol=2e-3)
    lg, cache = lm.decode_step(p, toks[:, S // 2:S // 2 + 1], cache, S // 2)
    np.testing.assert_allclose(lg[:, 0], full[:, S // 2], rtol=2e-3, atol=2e-3)


def test_moe_lm_with_lead_and_mtp(key):
    cfg = LMConfig(
        name="tinymoe", vocab=V, d_model=D, n_layers=4, pattern=("moe",),
        attn=AttentionConfig(D, 4, 2, 8, dtype=jnp.float32),
        ffn=FFNConfig(D, 64, dtype=jnp.float32),
        moe=MoEConfig(D, 32, n_experts=4, top_k=2, n_shared=1,
                      capacity_factor=2.0, dtype=jnp.float32),
        first_k_dense=1, mtp_heads=1, dtype=jnp.float32)
    lm = TransformerLM(cfg)
    p = tree_init(lm.params_spec(), key)
    toks = jax.random.randint(key, (B, S), 0, V)
    loss, m = lm.loss_fn(p, {"tokens": toks}, attn_impl="plain")
    assert np.isfinite(loss) and "mtp_ce" in m
    full, _ = lm.apply(p, toks, attn_impl="plain")
    cache = jax.tree.map(jnp.zeros_like,
                         tree_init(lm.cache_spec(B, S, dtype=jnp.float32), key))
    _, cache = lm.prefill(p, toks[:, :16], cache, attn_impl="plain")
    lg, _ = lm.decode_step(p, toks[:, 16:17], cache, 16)
    np.testing.assert_allclose(lg[:, 0], full[:, 16], rtol=3e-3, atol=3e-3)


def test_hybrid_pattern_with_remainder(key):
    cfg = LMConfig(
        name="tinyhy", vocab=V, d_model=D, n_layers=8,
        pattern=("rec", "rec", "local_attn"),
        local_attn=AttentionConfig(D, 4, 1, 8, window=8, dtype=jnp.float32),
        rglru=RGLRUConfig(D, 64, n_blocks=4),
        ffn=FFNConfig(D, 64, activation="gelu", dtype=jnp.float32),
        dtype=jnp.float32)
    lm = TransformerLM(cfg)
    p = tree_init(lm.params_spec(), key)
    toks = jax.random.randint(key, (B, S), 0, V)
    full, _ = lm.apply(p, toks, attn_impl="plain")
    cache = jax.tree.map(jnp.zeros_like,
                         tree_init(lm.cache_spec(B, S, dtype=jnp.float32), key))
    _, cache = lm.prefill(p, toks[:, :16], cache, attn_impl="plain")
    lg, _ = lm.decode_step(p, toks[:, 16:17], cache, 16)
    np.testing.assert_allclose(lg[:, 0], full[:, 16], rtol=5e-3, atol=5e-3)


def test_encdec_parity(key):
    cfg = EncDecConfig("tinyed", vocab=V, d_model=D, n_enc_layers=2,
                       n_dec_layers=2, n_heads=4, d_ff=64,
                       max_source_positions=16, max_target_positions=S,
                       dtype=jnp.float32)
    ed = EncDecLM(cfg)
    p = tree_init(ed.params_spec(), key)
    frames = jax.random.normal(key, (B, 16, D))
    toks = jax.random.randint(key, (B, S), 0, V)
    enc = ed.encode(p, frames, attn_impl="plain")
    full = ed.decode_train(p, toks, enc, attn_impl="plain")
    cache = jax.tree.map(jnp.zeros_like,
                         tree_init(ed.cache_spec(B, S, dtype=jnp.float32), key))
    _, cache = ed.prefill(p, frames, cache)
    outs = []
    for t in range(4):
        lg, cache = ed.decode_step(p, toks[:, t:t + 1], cache, t)
        outs.append(lg)
    np.testing.assert_allclose(jnp.concatenate(outs, 1), full[:, :4],
                               rtol=3e-3, atol=3e-3)


def test_vlm_loss_and_masking(key):
    cfg = VLMConfig(lm=mk_dense(n_layers=2, tie_embeddings=True,
                                embed_scale=True), d_vision=24, n_patches=8)
    vlm = VLM(cfg)
    p = tree_init(vlm.params_spec(), key)
    toks = jax.random.randint(key, (B, S), 0, V)
    patches = jax.random.normal(key, (B, 8, 24))
    loss, _ = vlm.loss_fn(p, {"patches": patches, "tokens": toks},
                          attn_impl="plain")
    assert np.isfinite(loss)


def test_logit_softcap_bounds(key):
    cfg = mk_dense(n_layers=1)
    import dataclasses
    cfg = dataclasses.replace(cfg, final_logit_softcap=5.0)
    lm = TransformerLM(cfg)
    p = tree_init(lm.params_spec(), key)
    toks = jax.random.randint(key, (B, S), 0, V)
    logits, _ = lm.apply(p, toks, attn_impl="plain")
    assert np.all(np.abs(np.asarray(logits)) <= 5.0 + 1e-4)
