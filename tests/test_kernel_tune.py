"""Kernel autotuner (kernels/autotune; DESIGN.md §13).

Three layers under test:

* **space** — the analytic pruner is pure arithmetic: candidates resolve to
  divisors, VMEM-infeasible tilings are rejected, the kernel default always
  survives (the measure loop needs its row), and shape buckets round size
  dims to the NEAREST power of two so a halo tile (H + kh − 1 rows) shares
  its base shape's entry.
* **cache** — the artifact lifecycle: round-trip, stale-fingerprint
  invalidation (machine description changed ⇒ warn + kernel defaults, never
  silently deploy), corrupt/wrong-version artifacts degrade the same way.
* **deployment** — tuned blocks actually reach the kernels: a cache entry
  with a distinctive block_f is observed arriving at ``pl.pallas_call``'s
  grid through HaloConv, and ``build_cell(use_pallas=True)`` resolves tiles
  from the explicit argument / the plan / the committed artifact in that
  order.
"""
import json
import warnings

import numpy as np
import pytest

from repro.core.cluster import ClusterSpec
from repro.core.roofline import HardwareSpec
from repro.kernels.autotune import (KernelTuneCache, bucket,
                                    enumerate_candidates, load_tiles, prune,
                                    tune_kernels)
from repro.kernels.autotune.tune import SMOKE_SHAPES
from repro.kernels.util import largest_divisor, resolve_block_rows

TPU = ClusterSpec.of("tpu")
HW = HardwareSpec.from_cluster(TPU)

CONV_DIMS = dict(B=1, H=8, W=8, C=8, F=16, kh=3, kw=3, sh=1, sw=1, e=4)


# ---------------------------------------------------------------------------
# shared divisor helpers (the satellite bugfixes ride on these)
# ---------------------------------------------------------------------------

def test_largest_divisor():
    assert largest_divisor(512, 128) == 128     # divides: cap wins
    assert largest_divisor(100, 128) == 100     # cap clamps to n
    assert largest_divisor(100, 64) == 50       # largest divisor ≤ cap
    assert largest_divisor(96, 36) == 32
    assert largest_divisor(37, 16) == 1         # prime: only 1 fits
    assert largest_divisor(1, 128) == 1


def test_resolve_block_rows_divisor_path():
    assert resolve_block_rows(4096, 256) == (256, 4096)
    assert resolve_block_rows(100, 64) == (50, 100)    # 50 ≥ min_block
    assert resolve_block_rows(8, 256) == (8, 8)        # br == cap: no pad


def test_resolve_block_rows_pads_pathological_rows():
    # prime row count: every proper divisor is 1 — pad instead of
    # serializing the grid to R single-row programs
    br, rp = resolve_block_rows(37, 16)
    assert (br, rp) == (16, 48) and rp % br == 0
    br, rp = resolve_block_rows(8209, 256)             # prime > block
    assert (br, rp) == (256, 8448) and rp % br == 0


# ---------------------------------------------------------------------------
# search space + analytic pruner
# ---------------------------------------------------------------------------

def test_bucket_rounds_size_dims_keeps_structure():
    base = bucket("conv2d_gemm", CONV_DIMS)
    assert "F16" in base and "C8" in base and "kh3" in base
    # halo tile: H + kh − 1 = 10 rounds to 8 → SAME bucket as the base shape
    halo = bucket("conv2d_gemm", {**CONV_DIMS, "H": 10})
    assert halo == base
    # structural dims are exact: a different F is a different bucket
    assert bucket("conv2d_gemm", {**CONV_DIMS, "F": 32}) != base


def test_candidates_resolve_to_divisors():
    for kernel, dims in SMOKE_SHAPES:
        for c in enumerate_candidates(kernel, dims, HW):
            for name, v in c.blocks:
                n = {"block_f": dims.get("F"), "block_q": dims.get("S"),
                     "block_k": dims.get("S"), "chunk": dims.get("S"),
                     "block_rows": None}[name]
                if n is not None:
                    assert n % v == 0, (kernel, name, v, n)


def test_prune_rejects_vmem_and_keeps_default():
    tiny = HardwareSpec(vmem_bytes=2**20)   # 1 MiB: only small blocks fit
    dims = dict(R=4096, D=1024, e=4)
    full = enumerate_candidates("rmsnorm", dims, HW)
    assert any(c.vmem_bytes > 0.9 * tiny.vmem_bytes for c in full)
    kept = prune("rmsnorm", dims, tiny)
    assert kept and all(
        c.vmem_bytes <= 0.9 * tiny.vmem_bytes for c in kept)
    for kernel, dims in SMOKE_SHAPES:
        assert any(c.is_default for c in prune(kernel, dims, HW)), kernel


def test_prune_orders_by_predicted_time():
    for kernel, dims in SMOKE_SHAPES:
        kept = prune(kernel, dims, HW)
        preds = [c.predicted_s for c in kept if not c.is_default]
        assert preds == sorted(preds)
        assert all(c.predicted_s > 0 for c in kept)


# ---------------------------------------------------------------------------
# cache lifecycle
# ---------------------------------------------------------------------------

def _cache_with_entry(fp="fp-a", block_f=4):
    cache = KernelTuneCache(fingerprint=fp, backend="cpu", cluster_name="t")
    cache.put("conv2d_gemm", bucket("conv2d_gemm", CONV_DIMS),
              blocks={"block_f": block_f}, measured_us=10.0, default_us=20.0,
              predicted_us=12.0, trials=3)
    return cache


def test_cache_roundtrip(tmp_path):
    path = str(tmp_path / "kt.json")
    cache = _cache_with_entry()
    cache.save(path)
    again = KernelTuneCache.load(path, fingerprint="fp-a")
    assert again.entries == cache.entries
    assert again.fingerprint == "fp-a"
    tiles = again.tiles()
    assert tiles.blocks_for("conv2d_gemm", CONV_DIMS) == {"block_f": 4}
    assert tiles.conv_block_f(**{k: CONV_DIMS[k] for k in
                                 ("B", "H", "W", "C", "F", "kh", "kw")}) == 4
    # unknown bucket → kernel default
    assert tiles.blocks_for("conv2d_gemm", {**CONV_DIMS, "F": 64}) == {}
    assert tiles.conv_block_f(B=1, H=8, W=8, C=8, F=64, kh=3, kw=3) == 128


def test_cache_stale_fingerprint_warns_and_resets(tmp_path):
    path = str(tmp_path / "kt.json")
    _cache_with_entry(fp="fp-a").save(path)
    with pytest.warns(UserWarning, match="stale"):
        fresh = KernelTuneCache.load(path, fingerprint="fp-b")
    assert fresh.entries == {} and fresh.fingerprint == "fp-b"
    # deployment view: stale artifact ⇒ empty tiles ⇒ kernel defaults
    with pytest.warns(UserWarning, match="stale"):
        tiles = load_tiles(path, cluster=TPU)
    assert len(tiles) == 0
    assert tiles.conv_block_f(**{k: CONV_DIMS[k] for k in
                                 ("B", "H", "W", "C", "F", "kh", "kw")}) == 128


def test_cache_corrupt_and_wrong_version_warn(tmp_path):
    path = str(tmp_path / "kt.json")
    path2 = str(tmp_path / "kt2.json")
    with open(path, "w") as f:
        f.write("{not json")
    with pytest.warns(UserWarning, match="corrupt"):
        fresh = KernelTuneCache.load(path, fingerprint="fp")
    assert fresh.entries == {}
    d = _cache_with_entry().to_json()
    d["version"] = 99
    with open(path2, "w") as f:
        json.dump(d, f)
    with pytest.warns(UserWarning, match="version"):
        fresh = KernelTuneCache.load(path2, fingerprint="fp-a")
    assert fresh.entries == {}
    # missing file: silently fresh (first run), no warning
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        fresh = KernelTuneCache.load(str(tmp_path / "absent.json"))
    assert fresh.entries == {}


def test_tune_kernels_end_to_end(tmp_path):
    """The measure loop on one tiny shape: artifact written, winner never
    slower than the measured default (argmin includes the default row)."""
    path = str(tmp_path / "kt.json")
    shapes = (("rmsnorm", dict(R=128, D=128, e=4)),)
    cache = tune_kernels(TPU, shapes=shapes, path=path, iters=1, warmup=1)
    assert len(cache.entries) == 1
    (entry,) = cache.entries.values()
    assert entry["measured_us"] <= entry["default_us"] + 1e-9
    assert entry["trials"] >= 1 and entry["blocks"]
    tiles = load_tiles(path, cluster=TPU)       # fingerprint matches
    assert tiles.blocks_for("rmsnorm", dict(R=128, D=128, e=4)) \
        == entry["blocks"]
    # a different machine description invalidates the artifact
    other = ClusterSpec.of("paper")
    assert other.fingerprint() != TPU.fingerprint()
    with pytest.warns(UserWarning, match="stale"):
        assert len(load_tiles(path, cluster=other)) == 0


# ---------------------------------------------------------------------------
# deployment threading
# ---------------------------------------------------------------------------

def test_tuned_block_reaches_pallas_call(monkeypatch):
    """Acceptance pin: a cache entry's block_f arrives at pl.pallas_call's
    grid when HaloConv deploys through ShardingCtx.kernel_tiles."""
    import importlib

    import jax
    # the package attribute "conv2d_gemm" is shadowed by the function
    # re-export in kernels/__init__, so fetch the module via importlib
    cg = importlib.import_module("repro.kernels.conv2d_gemm.conv2d_gemm")
    from repro.nn.module import ShardingCtx, tree_init
    from repro.parallel.halo import HaloConv
    from repro.parallel.strategies import make_rules

    seen = {}
    real = cg.pl.pallas_call

    def spy(kernel, *, grid, **kw):
        seen["grid"] = grid
        return real(kernel, grid=grid, **kw)

    monkeypatch.setattr(cg.pl, "pallas_call", spy)
    conv = HaloConv(in_channels=8, out_channels=16, kernel=(3, 3))
    params = tree_init(conv.params_spec(), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 8, 8))
    tiles = _cache_with_entry(block_f=4).tiles()
    rules = make_rules("data")
    ctx = ShardingCtx(mesh=None, rules=rules, use_pallas=True,
                      kernel_tiles=tiles)
    y = conv.apply(params, x, ctx)
    assert seen["grid"] == (1, 16 // 4)         # tuned block_f=4 deployed
    ctx0 = ShardingCtx(mesh=None, rules=rules, use_pallas=True)
    y0 = conv.apply(params, x, ctx0)
    assert seen["grid"] == (1, 1)               # default 128 → divisor 16
    np.testing.assert_allclose(np.asarray(y), np.asarray(y0),
                               rtol=1e-5, atol=1e-5)


def test_build_cell_resolution_order(monkeypatch):
    """build_cell(use_pallas=True): explicit kernel_tiles > plan.kernel_tiles
    > the committed artifact (fingerprint-checked via ``system``)."""
    import dataclasses

    import repro.kernels.autotune as at
    from repro.configs import get_config
    from repro.core.autotune import plan_for_arch
    from repro.launch import build as build_mod
    from repro.launch.mesh import make_host_mesh

    seen = {}
    real_ctx = build_mod.ShardingCtx

    def ctx_spy(*a, **kw):
        ctx = real_ctx(*a, **kw)
        seen["tiles"] = ctx.kernel_tiles
        return ctx

    monkeypatch.setattr(build_mod, "ShardingCtx", ctx_spy)
    cfg = get_config("resnet50")
    mesh = make_host_mesh()
    explicit = _cache_with_entry(block_f=8).tiles()

    # 1. explicit argument wins
    build_mod.build_cell(cfg, "train_4k", mesh, "data", smoke=True,
                         use_pallas=True, kernel_tiles=explicit)
    assert seen["tiles"] is explicit

    # 2. the plan's tiles deploy when no explicit arg
    from_plan = _cache_with_entry(block_f=2).tiles()
    plan = dataclasses.replace(
        plan_for_arch(cfg, "train_4k", int(mesh.size), smoke=True),
        kernel_tiles=from_plan)
    build_mod.build_cell(cfg, "train_4k", mesh, "auto", smoke=True,
                         plan=plan, use_pallas=True)
    assert seen["tiles"] is from_plan

    # 3. fallback: the committed artifact via load_tiles
    from_disk = _cache_with_entry(block_f=16).tiles()
    monkeypatch.setattr(at, "load_tiles", lambda *a, **kw: from_disk)
    build_mod.build_cell(cfg, "train_4k", mesh, "data", smoke=True,
                         use_pallas=True)
    assert seen["tiles"] is from_disk

    # use_pallas=False: no tiles, no artifact read
    build_mod.build_cell(cfg, "train_4k", mesh, "data", smoke=True)
    assert seen["tiles"] is None


def test_oracle_session_tiles_lifecycle(tmp_path):
    """Oracle.tune_kernels attaches tiles to subsequent plans; rebinding the
    cluster (the fingerprint changes) drops them."""
    from repro.api import Oracle

    ses = Oracle("resnet50", "train_4k", "tpu", smoke=True)
    path = str(tmp_path / "kt.json")
    cache = ses.tune_kernels(shapes=(("rmsnorm", dict(R=128, D=128, e=4)),),
                             path=path, iters=1, warmup=1)
    assert cache.fingerprint == ses.cluster.fingerprint()
    plan = ses.tune(8)
    assert plan.kernel_tiles is not None and len(plan.kernel_tiles) == 1
    ses2 = ses.with_cluster("paper")
    assert ses2.tune(8).kernel_tiles is None    # stale tiles never survive
