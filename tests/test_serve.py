"""Serving subsystem: paged KV cache, continuous batching, serving oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import LMConfig, TransformerLM
from repro.nn import AttentionConfig, FFNConfig
from repro.nn.module import NULL_CTX, tree_init
from repro.serve import (BlockAllocator, Engine, Request, ServeConfig,
                         TrafficModel, cache_geometry, gather_view,
                         max_abs_diff, pool_spec, price_serving,
                         scatter_blocks, serve_tune)

V, D = 64, 32


def mk_lm(n_layers=2):
    cfg = LMConfig(
        name="tiny", vocab=V, d_model=D, n_layers=n_layers,
        attn=AttentionConfig(D, 4, 2, 8, qk_norm=True, dtype=jnp.float32),
        ffn=FFNConfig(D, 64, dtype=jnp.float32), dtype=jnp.float32)
    return TransformerLM(cfg)


def solo_greedy(lm, params, key, prompt, max_new, max_len):
    """Dense-cache single-sequence greedy decode (the engine's reference)."""
    cache = jax.tree.map(jnp.zeros_like,
                         tree_init(lm.cache_spec(1, max_len,
                                                 dtype=jnp.float32), key))
    lg, cache = lm.prefill(params, jnp.asarray(prompt[None]), cache,
                           attn_impl="plain")
    toks = [int(np.argmax(np.asarray(lg[0, 0])))]
    for i in range(max_new - 1):
        lg, cache = lm.decode_step(params, jnp.asarray([[toks[-1]]]), cache,
                                   len(prompt) + i)
        toks.append(int(np.argmax(np.asarray(lg[0, 0]))))
    return toks


def test_paged_vs_dense_exact(key):
    """Chunked prefill through the paged pool is bit-exact vs the dense
    cache — logits AND cache contents, every chunk."""
    lm = mk_lm()
    params = tree_init(lm.params_spec(), key)
    S, max_len, C = 16, 32, 8
    toks = jax.random.randint(key, (1, S), 0, V)
    full, _ = lm.apply(params, toks, attn_impl="plain")
    geo = cache_geometry(lm, max_len, block_tokens=8, dtype=jnp.float32)
    pool = tree_init(pool_spec(lm, geo, 9, jnp.float32), key)
    tables = jnp.asarray(np.array([[1, 2, 3, 4]], np.int32))
    dense = jax.tree.map(jnp.zeros_like,
                         tree_init(lm.cache_spec(1, max_len,
                                                 dtype=jnp.float32), key))
    for k in range(S // C):
        p0 = jnp.asarray([k * C], jnp.int32)
        chunk = toks[:, k * C:(k + 1) * C]
        lgr, dense = lm.decode_step(params, chunk, dense, p0)
        view = gather_view(pool, tables)
        lgp, view = lm.decode_step(params, chunk, view, p0)
        jidx = ((p0 % geo.span) // geo.bspan)[:, None] \
            + jnp.arange(C // geo.bspan)[None]
        pool = scatter_blocks(pool, tables, view, jidx)
        assert float(jnp.max(jnp.abs(lgp - lgr))) == 0.0
        assert float(jnp.max(jnp.abs(lgr - full[:, k * C:(k + 1) * C]))) == 0.0
        assert max_abs_diff(pool, tables, dense, geo, (k + 1) * C) == 0.0


def test_block_allocator():
    a = BlockAllocator(5)                    # block 0 reserved
    assert a.capacity == 4
    got = a.alloc(3)
    assert got == [1, 2, 3]
    assert a.alloc(2) is None                # OOM: only 1 block left
    assert a.alloc(1) == [4]
    a.free([2, 3])
    assert sorted(a.alloc(2)) == [2, 3]      # freed blocks are reused
    with pytest.raises(ValueError):
        a.free([2, 2])                       # double free
    with pytest.raises(ValueError):
        a.free([0])                          # the null block is never freed
    with pytest.raises(ValueError):
        BlockAllocator(1)


def test_engine_admission_control(key):
    lm = mk_lm()
    params = tree_init(lm.params_spec(), key)
    cfg = ServeConfig(max_len=32, max_batch=3, block_tokens=8,
                      prefill_chunk=8, num_blocks=9, dtype=jnp.float32)
    eng = Engine(lm, params, NULL_CTX, cfg)
    with pytest.raises(ValueError):          # can never fit: 40+8 > 32 slots
        eng.submit(Request(0, np.ones(33, np.int32), 8))
    # r0 (2 blocks) + r1 (4 blocks) leave only 2 of the pool's 8 blocks
    # free; r2 needs 4, so despite a free decode slot its admission waits
    # until r0 finishes — FIFO back-off instead of deadlock
    r0 = Request(0, np.arange(1, 9, dtype=np.int32), 4)
    r1 = Request(1, np.arange(1, 25, dtype=np.int32), 8)
    r2 = Request(2, np.arange(1, 25, dtype=np.int32), 8)
    for r in (r0, r1, r2):
        eng.submit(r)
    rep = eng.run([], honor_arrivals=False)
    assert [r.rid for r in rep.requests] == [0, 1, 2]
    assert [len(r.tokens) for r in rep.requests] == [4, 8, 8]
    assert eng.alloc.free_blocks == eng.alloc.capacity  # all blocks freed


def test_continuous_batching_matches_solo(key):
    """Sequences joining/leaving the shared batch emit exactly the tokens
    they emit when decoded alone."""
    lm = mk_lm()
    params = tree_init(lm.params_spec(), key)
    max_len = 40
    cfg = ServeConfig(max_len=max_len, max_batch=3, block_tokens=8,
                      prefill_chunk=8, dtype=jnp.float32)
    eng = Engine(lm, params, NULL_CTX, cfg)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(1, V, size=L, dtype=np.int32),
                    max_new=6)
            for i, L in enumerate([5, 11, 3, 16])]   # multi-chunk prompts too
    rep = eng.run(reqs, honor_arrivals=False)
    assert len(rep.requests) == 4
    for s in rep.requests:
        ref = solo_greedy(lm, params, key, reqs[s.rid].prompt, 6, max_len)
        assert s.tokens == ref, (s.rid, s.tokens, ref)


def test_serve_project_monotone_in_rate():
    lm = mk_lm()
    from repro.core.hardware import cpu_host_model
    sysm = cpu_host_model()
    traffic = [TrafficModel(r, 64, 16) for r in (0.5, 1, 2, 4, 8, 16)]
    rows = [price_serving(lm.cfg, sysm, "serve_tp", 1, 1, 1, 4, t,
                          max_len=128, dtype_bytes=4) for t in traffic]
    assert all(r.rho <= s.rho for r, s in zip(rows, rows[1:]))
    feas = [r for r in rows if r.feasible]
    assert feas, "every rate overloaded the host model"
    for a, b in zip(feas, feas[1:]):
        assert b.latency_p99 >= a.latency_p99      # queueing only grows
        assert b.ttft_p99 >= a.ttft_p99
    # overload is reported, not hidden
    overloaded = price_serving(lm.cfg, sysm, "serve_tp", 1, 1, 1, 1,
                               TrafficModel(1e9, 64, 16), max_len=128,
                               dtype_bytes=4)
    assert not overloaded.feasible and overloaded.rho >= 1.0


def test_serve_tune_ranks_and_meets_slo():
    lm = mk_lm()
    from repro.core.hardware import cpu_host_model
    sysm = cpu_host_model()
    traffic = TrafficModel(2.0, 64, 16)
    plan = serve_tune(lm.cfg, sysm, 4, traffic, slo_p99=1e3,
                      max_len=128, dtype_bytes=4)
    assert plan.meets_slo and plan.winner.latency_p99 <= 1e3
    # the winner dominates every other row it was ranked against
    assert all(plan.winner.tok_per_s >= r.tok_per_s for r in plan.rows)
    # an impossible SLO still yields a deployable least-bad plan
    miss = serve_tune(lm.cfg, sysm, 4, traffic, slo_p99=1e-9,
                      max_len=128, dtype_bytes=4)
    assert not miss.meets_slo and miss.winner.feasible


def test_serve_tune_cli_smoke(capsys):
    from repro.api import main
    rc = main(["--serve-tune", "--arch", "qwen3-32b", "--p", "8",
               "--rate", "4", "--prompt", "256", "--gen", "64",
               "--slo-ms", "60000"])
    out = capsys.readouterr().out
    assert rc == 0 and "OK" in out and "serve_tp" in out
