"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import (attention_ref, conv2d_gemm, conv2d_ref,
                           flash_attention, rmsnorm, rmsnorm_ref, ssd_chunk,
                           ssd_ref)


@pytest.mark.parametrize("S,D,bq,bk", [(128, 32, 32, 32), (256, 64, 64, 128),
                                       (64, 16, 64, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(key, S, D, bq, bk, dtype, causal):
    B, H = 2, 2
    q, k, v = (jax.random.normal(jax.random.fold_in(key, i), (B, H, S, D)
                                 ).astype(dtype) for i in range(3))
    out = flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bk,
                          interpret=True)
    ref = attention_ref(q, k, v, causal=causal)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(out.astype(jnp.float32),
                               ref.astype(jnp.float32), rtol=tol, atol=tol)


@pytest.mark.parametrize("S,H,P,N,chunk", [(64, 4, 8, 16, 16),
                                           (128, 2, 16, 8, 32)])
def test_ssd_chunk_sweep(key, S, H, P, N, chunk):
    B = 2
    x = jax.random.normal(key, (B, S, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 1),
                                           (B, S, H)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 2), (H,)) * 0.3)
    Bm = jax.random.normal(jax.random.fold_in(key, 3), (B, S, H, N)) * 0.5
    Cm = jax.random.normal(jax.random.fold_in(key, 4), (B, S, H, N)) * 0.5
    y, st = ssd_chunk(x, dt, A, Bm, Cm, chunk=chunk, interpret=True)
    yr, sr = ssd_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(y, yr, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(st, sr, rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("HW,C,F,k", [((16, 12), 32, 64, 3), ((8, 8), 16, 16, 1),
                                      ((12, 16), 8, 128, 5)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_conv2d_gemm_sweep(key, HW, C, F, k, dtype):
    H, W = HW
    x = jax.random.normal(key, (2, H, W, C)).astype(dtype)
    w = (jax.random.normal(jax.random.fold_in(key, 1), (k, k, C, F)) * 0.1
         ).astype(dtype)
    out = conv2d_gemm(x, w, interpret=True)
    ref = conv2d_ref(x, w)
    tol = 2e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(out.astype(jnp.float32),
                               ref.astype(jnp.float32), rtol=tol, atol=tol)


@pytest.mark.parametrize("shape", [(4, 37, 128), (2, 256), (1, 8, 8, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_sweep(key, shape, dtype):
    x = jax.random.normal(key, shape).astype(dtype)
    sc = jax.random.normal(jax.random.fold_in(key, 1), (shape[-1],))
    out = rmsnorm(x, sc, interpret=True)
    ref = rmsnorm_ref(x, sc)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(out.astype(jnp.float32),
                               ref.astype(jnp.float32), rtol=tol, atol=tol)
