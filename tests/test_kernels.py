"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import (attention_ref, conv2d_gemm, conv2d_ref,
                           flash_attention, rmsnorm, rmsnorm_ref, ssd_chunk,
                           ssd_ref)


@pytest.mark.parametrize("S,D,bq,bk", [(128, 32, 32, 32), (256, 64, 64, 128),
                                       (64, 16, 64, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(key, S, D, bq, bk, dtype, causal):
    B, H = 2, 2
    q, k, v = (jax.random.normal(jax.random.fold_in(key, i), (B, H, S, D)
                                 ).astype(dtype) for i in range(3))
    out = flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bk,
                          interpret=True)
    ref = attention_ref(q, k, v, causal=causal)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(out.astype(jnp.float32),
                               ref.astype(jnp.float32), rtol=tol, atol=tol)


@pytest.mark.parametrize("S,H,P,N,chunk", [(64, 4, 8, 16, 16),
                                           (128, 2, 16, 8, 32)])
def test_ssd_chunk_sweep(key, S, H, P, N, chunk):
    B = 2
    x = jax.random.normal(key, (B, S, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 1),
                                           (B, S, H)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 2), (H,)) * 0.3)
    Bm = jax.random.normal(jax.random.fold_in(key, 3), (B, S, H, N)) * 0.5
    Cm = jax.random.normal(jax.random.fold_in(key, 4), (B, S, H, N)) * 0.5
    y, st = ssd_chunk(x, dt, A, Bm, Cm, chunk=chunk, interpret=True)
    yr, sr = ssd_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(y, yr, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(st, sr, rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("HW,C,F,k", [((16, 12), 32, 64, 3), ((8, 8), 16, 16, 1),
                                      ((12, 16), 8, 128, 5)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_conv2d_gemm_sweep(key, HW, C, F, k, dtype):
    H, W = HW
    x = jax.random.normal(key, (2, H, W, C)).astype(dtype)
    w = (jax.random.normal(jax.random.fold_in(key, 1), (k, k, C, F)) * 0.1
         ).astype(dtype)
    out = conv2d_gemm(x, w, interpret=True)
    ref = conv2d_ref(x, w)
    tol = 2e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(out.astype(jnp.float32),
                               ref.astype(jnp.float32), rtol=tol, atol=tol)


# ResNet-50's strided conv shapes: the 3×3 stride-2 bottleneck entries of
# stages 1–3 and the 1×1 stride-2 projection (ISSUE-4 acceptance: ≤ 1e-5)
RESNET50_STRIDE2 = [((56, 56), 64, 64, 3), ((28, 28), 128, 128, 3),
                    ((14, 14), 256, 256, 3), ((56, 56), 256, 512, 1)]


@pytest.mark.parametrize("HW,C,F,k", RESNET50_STRIDE2)
def test_conv2d_gemm_stride2_resnet50_shapes(key, HW, C, F, k):
    H, W = HW
    x = jax.random.normal(key, (2, H, W, C))
    w = jax.random.normal(jax.random.fold_in(key, 1), (k, k, C, F)) * 0.1
    out = conv2d_gemm(x, w, strides=(2, 2), interpret=True)
    ref = conv2d_ref(x, w, strides=(2, 2))
    assert out.shape == ref.shape == (2, H // 2, W // 2, F)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("HW,C,F,k,s", [((15, 13), 8, 16, 5, 2),
                                        ((16, 12), 8, 16, 2, 2),
                                        ((32, 32), 16, 32, 3, 4)])
def test_conv2d_gemm_strided_odd_shapes(key, HW, C, F, k, s):
    """Non-dividing extents and even kernels keep the XLA SAME semantics."""
    H, W = HW
    x = jax.random.normal(key, (2, H, W, C))
    w = jax.random.normal(jax.random.fold_in(key, 1), (k, k, C, F)) * 0.1
    out = conv2d_gemm(x, w, strides=(s, s), interpret=True)
    ref = conv2d_ref(x, w, strides=(s, s))
    assert out.shape == ref.shape
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_conv2d_gemm_halo_aware_consumes_padded_tile(key):
    """pad_h=False: the tile already carries its kh−1 boundary rows (the
    halo exchange delivered them) — VALID over H, SAME over W."""
    H, W, C, F, k = 12, 16, 8, 16, 3
    x = jax.random.normal(key, (2, H + k - 1, W, C))
    w = jax.random.normal(jax.random.fold_in(key, 1), (k, k, C, F)) * 0.1
    out = conv2d_gemm(x, w, pad_h=False, interpret=True)
    assert out.shape == (2, H, W, F)
    dn = jax.lax.conv_dimension_numbers(x.shape, w.shape,
                                        ("NHWC", "HWIO", "NHWC"))
    ref = jax.lax.conv_general_dilated(
        x, w, (1, 1), ((0, 0), (k // 2, k // 2)), dimension_numbers=dn)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_conv2d_gemm_halo_aware_rejects_strides(key):
    x = jax.random.normal(key, (1, 10, 8, 4))
    w = jax.random.normal(key, (3, 3, 4, 8))
    with pytest.raises(ValueError, match="stride-1 only"):
        conv2d_gemm(x, w, strides=(2, 2), pad_h=False, interpret=True)


@pytest.mark.parametrize("shape", [(4, 37, 128), (2, 256), (1, 8, 8, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_sweep(key, shape, dtype):
    x = jax.random.normal(key, shape).astype(dtype)
    sc = jax.random.normal(jax.random.fold_in(key, 1), (shape[-1],))
    out = rmsnorm(x, sc, interpret=True)
    ref = rmsnorm_ref(x, sc)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(out.astype(jnp.float32),
                               ref.astype(jnp.float32), rtol=tol, atol=tol)


@pytest.mark.parametrize("S", [100, 37, 96])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_non_dividing_seq(key, S, causal):
    """Block sizes that do not divide S fall back to the largest divisor
    (S=37 is prime → single-block grid) instead of raising."""
    B, H, D = 2, 2, 32
    q, k, v = (jax.random.normal(jax.random.fold_in(key, i), (B, H, S, D))
               for i in range(3))
    out = flash_attention(q, k, v, causal=causal, interpret=True)
    ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("R", [37, 149, 257])
def test_rmsnorm_prime_rows_pad_to_block(key, R):
    """Prime row counts (ragged last microbatch) pad up to the block and
    slice back instead of degrading to R single-row programs."""
    D = 128
    x = jax.random.normal(key, (R, D))
    sc = jax.random.normal(jax.random.fold_in(key, 1), (D,))
    out = rmsnorm(x, sc, block_rows=64, interpret=True)
    assert out.shape == (R, D)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(rmsnorm_ref(x, sc)),
                               rtol=2e-5, atol=2e-5)
