"""Vectorized sweep engine vs scalar project(): parity + golden behaviors.

The acceptance bar (ISSUE 1): sweep() must match per-point project() within
1e-9 relative on every (strategy, p ∈ {1,2,4,…,1024}, p1·p2 split) lattice
point for a CNN and an LM config, plus golden tests for crossover-point and
bottleneck classification.
"""
import numpy as np
import pytest

from repro.core import (OracleConfig, PAPER_V100_CLUSTER, STRATEGY_NAMES,
                        TimeModel, project, stats_for)
from repro.core.advisor import _split_candidates, advise
from repro.core.hardware import Level, SystemModel
from repro.core.sweep import factor_pairs, parse_p_grid, sweep
from repro.models.cnn import RESNET50, CosmoFlowConfig

POW2_TO_1024 = [2 ** k for k in range(11)]
NON_POW2 = [3, 6, 12, 48, 100]

TM = TimeModel(PAPER_V100_CLUSTER)


def _lm_stats():
    """Small MoE LM (covers attn/ffn/moe kinds incl. the ep strategy)."""
    import jax.numpy as jnp
    from repro.models.transformer import LMConfig
    from repro.nn.attention import AttentionConfig
    from repro.nn.ffn import FFNConfig, MoEConfig
    cfg = LMConfig(
        name="sweep-test", vocab=512, d_model=128, n_layers=4,
        pattern=("moe",),
        attn=AttentionConfig(128, 4, 2, 32, dtype=jnp.float32),
        ffn=FFNConfig(128, 256, dtype=jnp.float32),
        moe=MoEConfig(128, 256, n_experts=8, top_k=2, dtype=jnp.float32))
    return stats_for(cfg, S=256)


CASES = {
    "cnn": (lambda: stats_for(RESNET50), OracleConfig(B=2048, D=1_281_167)),
    "lm": (_lm_stats, OracleConfig(B=256, D=25600, zero1=True, remat=True)),
}


@pytest.mark.parametrize("case", sorted(CASES))
def test_sweep_matches_scalar_project_everywhere(case):
    mk_stats, cfg = CASES[case]
    stats = mk_stats()
    res = sweep(stats, TM, cfg, POW2_TO_1024 + NON_POW2,
                mem_cap=TM.system.mem_capacity)
    assert len(res) > 200   # exhaustive splits: more than pow2-only lattice
    fields = ("comp_s", "comm_ge_s", "comm_fb_s", "comm_halo_s",
              "comm_p2p_s", "mem_bytes")
    for i in range(len(res)):
        pr = project(str(res.strategy[i]), stats, TM, cfg, int(res.p[i]),
                     p1=int(res.p1[i]), p2=int(res.p2[i]),
                     p2r=int(res.p2r[i]), p2c=int(res.p2c[i]))
        assert bool(res.feasible[i]) == pr.feasible, (case, i)
        assert str(res.limit[i]) == pr.limit, (case, i)
        for f in fields:
            got = float(getattr(res, f)[i])
            want = getattr(pr, f)
            assert abs(got - want) <= 1e-9 * max(abs(want), 1e-30), \
                (case, str(res.strategy[i]), int(res.p[i]), f, got, want)


def test_sweep_covers_all_strategies_and_all_splits():
    res = sweep(stats_for(RESNET50), TM, OracleConfig(B=2048, D=1_281_167),
                [12])
    # pure strategies once each (no serial at p>1), hybrids per divisor pair
    assert set(res.strategy) == set(STRATEGY_NAMES) - {"serial"}
    df = res.for_strategy("df")
    assert sorted(zip(df.p1, df.p2)) == factor_pairs(12)


@pytest.mark.parametrize("case", sorted(CASES))
def test_sweep_grid_and_seq_comm_parity(case):
    """ISSUE-9 satellite: the sweep↔scalar parity extends to the new
    lattice axes — (p2r, p2c) grid factorizations of the summa rows and
    the seq-parallel comm term of every row — at ≤1e-12 relative."""
    mk_stats, cfg = CASES[case]
    import dataclasses
    cfg_sp = dataclasses.replace(cfg, seq_parallel=True)
    stats = mk_stats()
    res = sweep(stats, TM, cfg_sp, [8, 12, 64],
                mem_cap=TM.system.mem_capacity)
    # summa fans over EVERY (p2r, p2c) factorization of every p2 | p
    sm = res.for_strategy("summa")
    got = {(int(a), int(b), int(c))
           for a, b, c in zip(sm.p2, sm.p2r, sm.p2c)}
    for p2 in {int(v) for v in sm.p2}:
        for r_, c_ in factor_pairs(p2):
            assert (p2, r_, c_) in got, (p2, r_, c_)
    rng = np.random.default_rng(0)
    for i in rng.choice(len(res), size=min(len(res), 200), replace=False):
        pr = project(str(res.strategy[i]), stats, TM, cfg_sp, int(res.p[i]),
                     p1=int(res.p1[i]), p2=int(res.p2[i]),
                     p2r=int(res.p2r[i]), p2c=int(res.p2c[i]))
        got_t, want_t = float(res.total_s[i]), pr.total_s
        assert abs(got_t - want_t) <= 1e-12 * max(abs(want_t), 1e-30), \
            (case, str(res.strategy[i]), int(res.p[i]), got_t, want_t)


def test_weak_scaling_batch_per_point():
    res = sweep(stats_for(RESNET50), TM, OracleConfig(B=2048, D=1_281_167),
                [4, 16], strategies=("data",), batch_for_p=lambda p: 2 * p)
    assert list(res.B) == [8, 32]
    # each point must equal project() under ITS batch
    for i in range(len(res)):
        cfg_i = OracleConfig(B=int(res.B[i]), D=1_281_167)
        pr = project("data", stats_for(RESNET50), TM, cfg_i, int(res.p[i]))
        assert np.isclose(float(res.total_s[i]), pr.total_s, rtol=1e-12)


# ---------------------------------------------------------------------------
# golden: crossover + bottleneck classification
# ---------------------------------------------------------------------------

def test_crossover_data_to_df_resnet50_weak_scaling():
    """Golden: under the paper's V100 model with 2 samples/PE weak scaling,
    df's gradient-exchange advantage overtakes pure data at p = 512."""
    batch_of = lambda p: max(2 * p, 4)   # noqa: E731
    cfg = OracleConfig(B=batch_of(1024), D=1_281_167)
    res = sweep(stats_for(RESNET50), TM, cfg, POW2_TO_1024,
                batch_for_p=batch_of, mem_cap=TM.system.mem_capacity)
    assert res.crossover("data", "df") == 512
    # and data is strictly better before the crossover
    best_data = res.best_per_p("data")
    best_df = res.best_per_p("df")
    t_data = {int(p): t for p, t in zip(best_data.p, best_data.total_s)}
    t_df = {int(p): t for p, t in zip(best_df.p, best_df.total_s)}
    assert t_data[64] < t_df[64]
    assert t_df[1024] < t_data[1024]


def test_bottleneck_classification():
    stats = stats_for(RESNET50)
    cfg = OracleConfig(B=2048, D=1_281_167)
    res = sweep(stats, TM, cfg, [1, 64])     # no memory cap

    def point(strategy, p):
        sub = res.select((res.strategy == strategy) & (res.p == p))
        return sub.bottleneck[0]

    assert point("serial", 1) == "comp-bound"       # no comm at p=1
    assert point("filter", 64) == "FB-bound"        # layer-wise collectives
    assert point("data", 64) == "comp-bound"        # 32 samples/PE at B=2048
    assert point("spatial", 64) == "scale-infeasible"   # p > min spatial 49
    # a strategy that violates the memory cap is classified as such
    tiny = sweep(stats, TM, cfg, [64], strategies=("filter",),
                 mem_cap=1 * 2 ** 30)
    assert tiny.bottleneck[0] == "memory-infeasible"
    assert tiny.feasible[0] and not tiny.fits[0]


def test_halo_bound_classification():
    """Spatial on a fat-halo CNN with a slow model-level link is halo-bound."""
    slow_model_link = SystemModel(
        name="slow-halo", peak_flops=125e12, hbm_bw=900e9, mem_capacity=16e9,
        compute_efficiency=0.35,
        levels=(("model", Level("nv", alpha=5e-4, beta=1 / 0.05e9)),
                ("data", Level("ib", alpha=15e-6, beta=1 / 12.5e9)),
                ("pod", Level("ib2", alpha=25e-6, beta=1 / 4.2e9))))
    res = sweep(stats_for(CosmoFlowConfig(img=128)), TimeModel(slow_model_link),
                OracleConfig(B=64, D=1584), [16], strategies=("spatial",))
    assert res.bottleneck[0] == "halo-bound"


def test_pareto_frontier_strictly_improves():
    batch_of = lambda p: max(2 * p, 4)   # noqa: E731
    res = sweep(stats_for(RESNET50), TM,
                OracleConfig(B=batch_of(1024), D=1_281_167), POW2_TO_1024,
                batch_for_p=batch_of, mem_cap=TM.system.mem_capacity)
    front = res.pareto()
    assert len(front) >= 1
    assert np.all(front.ok)
    ps, ts = list(front.p), list(front.total_s)
    assert ps == sorted(ps)
    assert all(t2 < t1 for t1, t2 in zip(ts, ts[1:]))   # time strictly falls


# ---------------------------------------------------------------------------
# advisor + helpers
# ---------------------------------------------------------------------------

def test_split_candidates_exhaustive_divisors():
    assert _split_candidates(12) == [(1, 12), (2, 6), (3, 4), (4, 3),
                                     (6, 2), (12, 1)]
    assert _split_candidates(7) == [(1, 7), (7, 1)]
    assert (3, 4) in _split_candidates(12)     # non-pow2 p1 no longer skipped


def test_advise_considers_non_pow2_splits():
    """The old pow2-only _split_candidates silently skipped p1 ∉ {2^k}; the
    sweep-backed advisor must find the true best df split over ALL divisors
    of p — here the scalar-verified optimum has a non-pow2 p1."""
    stats = stats_for(RESNET50)
    cfg = OracleConfig(B=96, D=9600)
    best = min((project("df", stats, TM, cfg, 48, p1=a, p2=b)
                for a, b in factor_pairs(48)), key=lambda r: r.total_s)
    assert best.p1 not in (1, 2, 4, 8, 16, 32)   # pow2-only would miss it
    rec = advise(stats, TM, cfg, 48, mem_cap=64e9)
    df = next(r for r in rec.ranked if r.strategy == "df")
    assert (df.p1, df.p2) == (best.p1, best.p2)
    assert np.isclose(df.total_s, best.total_s, rtol=1e-12)


def test_advise_matches_scalar_ranking():
    """The sweep-backed advisor still ranks by per-point project() totals."""
    cfg = OracleConfig(B=2048, D=1_281_167)
    rec = advise(stats_for(RESNET50), TM, cfg, 64)
    assert rec.best is not None
    totals = [r.total_s for r in rec.ranked]
    assert totals == sorted(totals)
    for r in rec.ranked:
        pr = project(r.strategy, stats_for(RESNET50), TM, cfg, r.p,
                     p1=r.p1, p2=r.p2, p2r=r.p2r, p2c=r.p2c)
        assert np.isclose(r.total_s, pr.total_s, rtol=1e-12)


def test_factor_pairs_and_parse_p_grid():
    assert factor_pairs(1) == [(1, 1)]
    assert factor_pairs(16) == [(1, 16), (2, 8), (4, 4), (8, 2), (16, 1)]
    assert parse_p_grid("1..1024") == POW2_TO_1024
    assert parse_p_grid("4..16:4") == [4, 8, 12, 16]
    assert parse_p_grid("4,6,12,6") == [4, 6, 12]
    assert parse_p_grid("2..8,100") == [2, 4, 8, 100]


def test_to_projections_roundtrip():
    res = sweep(stats_for(RESNET50), TM, OracleConfig(B=256, D=2560), [8])
    projs = res.to_projections()
    assert len(projs) == len(res)
    for i, pr in enumerate(projs):
        assert np.isclose(pr.total_s, float(res.total_s[i]), rtol=0)
        assert pr.strategy == str(res.strategy[i])


def test_cli_smoke_and_table(capsys):
    from repro.core.sweep import main
    assert main(["--smoke"]) == 0
    assert main(["--model", "resnet50", "--p", "1,8", "--batch", "64"]) == 0
    out = capsys.readouterr().out
    assert "crossover" in out and "strategy" in out
