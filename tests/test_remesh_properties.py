"""Property test: ``remesh_state`` is pure data movement (DESIGN.md §12).

The elastic resharding contract — any source (mesh, Rules) placement → any
target pair the Rules tables cover — must be bit-exact for every leaf:
``device_get`` reassembles the full array from whatever sharding it had,
``device_put`` lays it out under the new one, and no float ever changes.

Multi-device meshes need virtual host devices, which must be configured
before jax initializes — so the property loop runs in ONE subprocess (this
file re-invoked with ``--run`` under XLA_FLAGS=8). Inside it, hypothesis
drives random (mesh factorization × strategy) source→target pairs over a
full train-state tree; when hypothesis is absent the pytest entry skips
(like the other property modules), and a manual
``python tests/test_remesh_properties.py --run`` still exercises a
deterministic covering grid of the same property.
"""
import os
import subprocess
import sys

HERE = os.path.dirname(os.path.abspath(__file__))


def test_remesh_roundtrip_property():
    import pytest
    pytest.importorskip("hypothesis",
                        reason="property tests need hypothesis (not in image)")
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    out = subprocess.run([sys.executable, os.path.abspath(__file__), "--run"],
                         env=env, capture_output=True, text=True, timeout=420)
    assert out.returncode == 0 and "PROPERTY-PASSED" in out.stdout, \
        f"stdout:{out.stdout[-2000:]}\nstderr:{out.stderr[-3000:]}"


def _run():
    sys.path.insert(0, os.path.join(HERE, "..", "src"))
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.launch.compat import make_mesh
    from repro.models import LMConfig, TransformerLM
    from repro.nn import AttentionConfig, FFNConfig
    from repro.optim.optimizers import OptimizerConfig
    from repro.parallel.strategies import STRATEGIES
    from repro.parallel.strategies import make_rules
    from repro.runtime.fault_tolerance import remesh_state
    from repro.training.steps import train_state_spec

    # a full train state (params + adamw moments + step scalar) covering
    # the interesting logical axes: embed/vocab/mlp/heads/layers
    mc = LMConfig(name="t", vocab=64, d_model=32, n_layers=2,
                  attn=AttentionConfig(32, 4, 2, 8, dtype=jnp.float32),
                  ffn=FFNConfig(32, 64, dtype=jnp.float32),
                  dtype=jnp.float32)
    model = TransformerLM(mc)
    sspec = train_state_spec(model, OptimizerConfig(name="adamw"))
    from repro.nn.module import tree_init
    ref = tree_init(sspec, jax.random.PRNGKey(0))
    ref_np = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), ref)
    devs = jax.devices()

    splits = [(p1, p // p1) for p in (1, 2, 4, 8)
              for p1 in (1, 2, 4, 8) if p % p1 == 0]
    names = sorted(STRATEGIES)

    def prop(src, dst, s_src, s_dst):
        m_src = make_mesh(src, ("data", "model"),
                          devices=devs[:src[0] * src[1]])
        m_dst = make_mesh(dst, ("data", "model"),
                          devices=devs[:dst[0] * dst[1]])
        placed = remesh_state(ref, sspec, m_src, make_rules(s_src))
        moved = remesh_state(placed, sspec, m_dst, make_rules(s_dst))
        back = remesh_state(moved, sspec, m_src, make_rules(s_src))
        for name, tree in (("moved", moved), ("back", back)):
            got = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
            for a, b in zip(jax.tree.leaves(ref_np), jax.tree.leaves(got)):
                np.testing.assert_array_equal(a, b, err_msg=(
                    f"{name}: {src}/{s_src} -> {dst}/{s_dst}"))

    try:
        from hypothesis import given, settings
        from hypothesis import strategies as st
    except ImportError:
        # deterministic covering grid: every strategy appears as source and
        # target at least once, across distinct mesh factorizations
        cases = [(splits[i % len(splits)], splits[(i * 3 + 1) % len(splits)],
                  names[i % len(names)], names[(i + 5) % len(names)])
                 for i in range(2 * len(names))]
        for src, dst, s_src, s_dst in cases:
            prop(src, dst, s_src, s_dst)
    else:
        @settings(max_examples=40, deadline=None)
        @given(src=st.sampled_from(splits), dst=st.sampled_from(splits),
               s_src=st.sampled_from(names), s_dst=st.sampled_from(names))
        def wrapped(src, dst, s_src, s_dst):
            prop(src, dst, s_src, s_dst)

        wrapped()
    print("PROPERTY-PASSED")


if __name__ == "__main__":
    if "--run" in sys.argv:
        _run()
    else:
        sys.exit("usage: python tests/test_remesh_properties.py --run")
