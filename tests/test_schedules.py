"""Schedule-diverse pipeline engine: unit tests for the schedules package
(parallel/schedules) and the oracle's schedule axis (single device; the
multi-device gradient-parity checks live in test_distributed.py)."""
import warnings

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.layer_stats import LayerStat, stats_for
from repro.core.oracle import (PIPELINE_SCHEDULES, OracleConfig, TimeModel,
                               project)
from repro.core.sweep import PAPER_V100_CLUSTER, sweep
from repro.models.cnn import RESNET50, CosmoFlowConfig, VGGConfig
from repro.parallel.schedules import (SCHEDULE_NAMES, block_costs_from_stats,
                                      clip_segments, pipeline_block_count,
                                      resolve_segments,
                                      stack_virtual_stage_bounds)


# ---------------------------------------------------------------------------
# resolve_segments (satellite: surface silent S degradation)
# ---------------------------------------------------------------------------

def test_resolve_segments_exact_fit_is_silent():
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert resolve_segments(32, 8) == 8


def test_resolve_segments_clips_when_segments_exceed_batch():
    with pytest.warns(UserWarning, match="clipped"):
        assert resolve_segments(4, 8) == 4


def test_resolve_segments_non_dividing_batch_warns():
    # 12 % 8 != 0 → largest divisor ≤ 8 is 6
    with pytest.warns(UserWarning, match="requested 8, running S=6"):
        assert resolve_segments(12, 8) == 6


def test_resolve_segments_prime_batch_serializes_with_warning():
    with pytest.warns(UserWarning, match="fully serialized"):
        assert resolve_segments(7, 4) == 1


def test_resolve_segments_multiple_of_constraint():
    # interleaved needs S % p == 0: batch 32, p=4 → 8 works silently
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert resolve_segments(32, 8, multiple_of=4) == 8
    # batch 20, requested 8: the largest divisor ≤ 8 is 5, but 5 % 4 != 0,
    # so the constraint pushes S down to 4 — with a warning naming it
    with pytest.warns(UserWarning, match="multiple of p=4"):
        assert resolve_segments(20, 8, multiple_of=4) == 4


def test_resolve_segments_impossible_raises():
    # no S ≤ 4 is both a divisor of 6 and a multiple of 4
    with pytest.raises(ValueError, match="multiple of 4"):
        resolve_segments(6, 4, multiple_of=4)


def test_clip_segments_matches_resolve_without_constraint():
    for batch, seg in [(32, 8), (12, 8), (7, 4), (1, 8), (8, 1)]:
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            assert clip_segments(batch, seg) == resolve_segments(batch, seg)


# ---------------------------------------------------------------------------
# block costs (satellite: exact backward FLOPs, not bw = 2×fw)
# ---------------------------------------------------------------------------

def test_block_costs_use_exact_backward_flops_when_present():
    stats = [
        LayerStat("L0.conv", "conv", 10, 10, 5, flops_fwd=100.0,
                  flops_bwd_exact=350.0),
        LayerStat("L1.conv", "conv", 10, 10, 5, flops_fwd=100.0),  # no exact
    ]
    costs = block_costs_from_stats(stats, 2)
    assert costs[0] == pytest.approx(100.0 + 350.0)      # fw + exact bwd
    assert costs[1] == pytest.approx(100.0 + 200.0)      # fw + 2×fw fallback


def test_conv_stats_record_exact_backward():
    stats = stats_for(RESNET50)
    conv = [s for s in stats if s.kind == "conv"]
    assert conv and all(s.flops_bwd_exact > 0 for s in conv)
    # the strided stem undercounts under bw = 2×fw: dL/dx runs over the
    # (4× larger) input extent, so exact > 2×fw there
    stem = next(s for s in stats if s.name == "stem")
    assert stem.flops_bwd_exact > 2.0 * stem.flops_fwd
    # the pinned oracle property is untouched: flops_bwd stays 2×fw
    assert stem.flops_bwd == pytest.approx(2.0 * stem.flops_fwd)


def test_pipeline_block_count_per_family():
    assert pipeline_block_count(RESNET50) == 2 + sum(RESNET50.stage_sizes)
    assert pipeline_block_count(VGGConfig()) == 14       # 13 convs + head
    assert pipeline_block_count(CosmoFlowConfig(img=16, n_conv=3)) == 4
    assert pipeline_block_count(object()) is None


# ---------------------------------------------------------------------------
# virtual-stage restacking
# ---------------------------------------------------------------------------

def test_stack_virtual_stage_bounds_shapes_and_mask():
    L, p, v = 10, 4, 2
    w = {"k": jnp.arange(L * 3, dtype=jnp.float32).reshape(L, 3)}
    bounds = [0, 2, 3, 5, 6, 7, 8, 9, 10]        # 8 chunks, sizes 2..1
    stacked, mask = stack_virtual_stage_bounds(w, bounds, p, v)
    m = max(b - a for a, b in zip(bounds, bounds[1:]))
    assert stacked["k"].shape == (p, v, m, 3)
    assert mask.shape == (p, v, m)
    # chunk j lands on rank j % p, virtual slot j // p; mask counts its size
    sizes = np.array(bounds[1:]) - np.array(bounds[:-1])
    for j in range(p * v):
        r, q = j % p, j // p
        assert int(mask[r, q].sum()) == sizes[j]
        # real rows are the chunk's own layers, in order
        rows = np.asarray(stacked["k"][r, q])[np.asarray(mask[r, q],
                                                         bool)]
        want = np.asarray(w["k"])[bounds[j]:bounds[j + 1]]
        np.testing.assert_array_equal(rows, want)


# ---------------------------------------------------------------------------
# oracle schedule axis
# ---------------------------------------------------------------------------

def test_schedule_name_registries_agree():
    assert PIPELINE_SCHEDULES == SCHEDULE_NAMES


def _proj(schedule, p=8, B=64, S=8, **kw):
    stats = stats_for(RESNET50)
    tm = TimeModel(PAPER_V100_CLUSTER)
    cfg = OracleConfig(B=B, D=B, segments=S, schedule=schedule, **kw)
    return project("pipeline", stats, tm, cfg, p)


def test_gpipe_default_unchanged():
    # cfg without a schedule field set → identical to explicit gpipe
    stats = stats_for(RESNET50)
    tm = TimeModel(PAPER_V100_CLUSTER)
    a = project("pipeline", stats, tm, OracleConfig(B=64, D=64), 8)
    b = _proj("gpipe", B=64)
    assert a.total_s == b.total_s and a.mem_bytes == b.mem_bytes


def test_one_f_one_b_same_time_less_activation_memory():
    g = _proj("gpipe", p=4, S=16)
    o = _proj("one_f_one_b", p=4, S=16)
    assert o.total_s == pytest.approx(g.total_s)   # same clock, same comm
    assert o.mem_bytes < g.mem_bytes               # ≤ p in-flight, not S


def test_interleaved_shrinks_bubble_term():
    # comp carries the bubble: (vS+p−1)/(v·S) per-stage-chunk work beats
    # (S+p−1)/S whole-stage work for v>1, p>1
    g = _proj("gpipe")
    i = _proj("interleaved")
    assert i.comp_s < g.comp_s
    # but pays v× the p2p launches
    assert i.comm_p2p_s > g.comm_p2p_s


def test_unknown_schedule_raises():
    with pytest.raises(ValueError, match="schedule"):
        _proj("alternating")


def test_sweep_schedule_column_threading():
    stats = stats_for(RESNET50)
    tm = TimeModel(PAPER_V100_CLUSTER)
    cfg = OracleConfig(B=64, D=64)
    # default: pipeline rows carry cfg.schedule, others "-"
    res = sweep(stats, tm, cfg, [4], strategies=("data", "pipeline"))
    assert set(res.schedule[res.strategy == "pipeline"]) == {"gpipe"}
    assert set(res.schedule[res.strategy != "pipeline"]) == {"-"}
    # schedules="all": one pipeline row block per schedule
    res = sweep(stats, tm, cfg, [4], strategies=("pipeline",),
                schedules="all")
    assert set(res.schedule) == set(PIPELINE_SCHEDULES)
    with pytest.raises(ValueError, match="unknown schedules"):
        sweep(stats, tm, cfg, [4], schedules=("nope",))


def test_autotune_plan_carries_schedule_and_gates_interleaved():
    from repro.core.autotune import autotune, deployable_schedule_mask
    stats = stats_for(RESNET50)
    tm = TimeModel(PAPER_V100_CLUSTER)
    cfg = OracleConfig(B=64, D=64)
    plan = autotune(stats, tm, cfg, 8, strategies=("pipeline",),
                    max_stages=18)
    assert plan.strategy == "pipeline"
    assert plan.schedule in PIPELINE_SCHEDULES
    assert plan.virtual_stages == cfg.virtual_stages
    # interleaved rows whose v·p2 overflow the block stack are masked
    res = sweep(stats, tm, cfg, [16], strategies=("pipeline",),
                schedules="all")
    m = deployable_schedule_mask(res, cfg, max_stages=18)
    il = res.schedule == "interleaved"
    assert not m[il].any()            # 2·16 = 32 chunks > 18 blocks
    assert m[~il].all()
    # without a stage bound, interleaved is still gated on S % p2 == 0
    # being resolvable: B=64 has no segment count ≤ 8 that is a multiple
    # of 16... (16 > 8), so the p2=16 interleaved row stays masked
    m2 = deployable_schedule_mask(res, cfg)
    assert not m2[il].any()
