"""Multi-device behaviour (8 virtual host devices via subprocess)."""
import os
import subprocess
import sys

import pytest

HELPER = os.path.join(os.path.dirname(__file__), "helpers",
                      "multidevice_checks.py")


def run_check(name: str, timeout: int = 420):
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, HELPER, name], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert "CHECK-PASSED" in out.stdout, \
        f"{name} failed:\nstdout:{out.stdout[-2000:]}\nstderr:{out.stderr[-3000:]}"


@pytest.mark.slow
def test_pipeline_parallel():
    run_check("pipeline")


@pytest.mark.slow
def test_halo_spatial_conv():
    run_check("halo")


@pytest.mark.slow
def test_dp_tp_numerics_match_single_device():
    run_check("dp_numerics")


@pytest.mark.slow
def test_oracle_validation_harness():
    run_check("oracle_validation")


@pytest.mark.slow
def test_compressed_gradient_allreduce():
    run_check("compressed_allreduce")
