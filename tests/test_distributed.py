"""Multi-device behaviour (8 virtual host devices via subprocess)."""
import os
import subprocess
import sys

import pytest

HELPER = os.path.join(os.path.dirname(__file__), "helpers",
                      "multidevice_checks.py")


def run_check(name: str, timeout: int = 420, retries: int = 0, args=()):
    """Run one multidevice check in a subprocess.

    ``retries``: timing-based checks (calibrate-then-measure on a
    CPU-quota-throttled container) can skew when the box stalls mid-check;
    a retry must still pass the FULL check — assertions are never relaxed.
    ``args``: extra argv for parametrized checks (e.g. halo_edge cases).
    """
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    for attempt in range(retries + 1):
        out = subprocess.run([sys.executable, HELPER, name, *args], env=env,
                             capture_output=True, text=True, timeout=timeout)
        if "CHECK-PASSED" in out.stdout:
            return
        if attempt < retries:
            print(f"{name}: attempt {attempt + 1} failed, retrying "
                  f"(timing-sensitive check)")
    assert "CHECK-PASSED" in out.stdout, \
        f"{name} failed:\nstdout:{out.stdout[-2000:]}\nstderr:{out.stderr[-3000:]}"


@pytest.mark.slow
def test_pipeline_parallel():
    run_check("pipeline")


@pytest.mark.slow
def test_pipeline_train_step_gradient_parity():
    run_check("pipeline_step_parity")


@pytest.mark.slow
@pytest.mark.parametrize("schedule", ["gpipe", "one_f_one_b", "interleaved"])
def test_schedule_matches_serial_step(schedule):
    """Each pipeline schedule is gradient-exact vs the serial jit step on
    uniform and non-uniform LM cuts and on a heterogeneous CNN trunk."""
    run_check("schedule_parity", args=(schedule,))


@pytest.mark.slow
def test_schedule_bubble_and_oracle_winner():
    """Measured bubble fraction shrinks under 1F1B/interleaved vs GPipe at
    equal S, and the oracle's schedule axis picks the measured winner
    (ISSUE-7 acceptance). Timing-sensitive: retries re-run the FULL check."""
    run_check("schedule_validation", timeout=560, retries=2)


@pytest.mark.slow
def test_pipeline_plan_deploys_and_trains():
    run_check("pipeline_deploy")


@pytest.mark.slow
def test_pipeline_validation_measures():
    run_check("pipeline_validation", retries=1)


@pytest.mark.slow
def test_tuner_pick_beats_runner_up_measured():
    run_check("tuner_loop", retries=1)


@pytest.mark.slow
def test_halo_spatial_conv():
    run_check("halo")


@pytest.mark.slow
def test_halo_overlap_bit_exact():
    """Overlapped interior/boundary-split halo conv == serial pipeline ==
    unsharded SAME conv, bit-exact, incl. the deployed HaloConv + Pallas."""
    run_check("halo_overlap")


@pytest.mark.slow
@pytest.mark.parametrize("case", ["thin", "even", "p1", "stride", "padding"])
def test_halo_edge_cases(case):
    """H_local < halo raises; even kernel widths (asymmetric halos, incl.
    the Pallas path's empty lo=0 boundary) and p=1 stay bit-exact; strides
    are rejected with a clear error; non-SAME padding falls back to the
    plain conv instead of silently computing SAME."""
    run_check("halo_edge", args=(case,))


@pytest.mark.slow
def test_spatial_overlap_validation():
    """The measured ds (spatial-hybrid) step lands closer to the overlap
    oracle than to the serial-comm model (ISSUE-4 acceptance). Doubly
    timing-sensitive (calibrate-then-measure × model comparison), so it
    gets the widest retry budget; every retry re-runs the FULL check."""
    run_check("spatial_overlap_validation", timeout=560, retries=2)


@pytest.mark.slow
def test_dp_tp_numerics_match_single_device():
    run_check("dp_numerics")


@pytest.mark.slow
def test_summa_2d_gradient_parity():
    """SUMMA matmul + full train step under the summa rules on a
    (2,2,2) grid mesh are gradient-exact vs unsharded (ISSUE-9 tentpole)."""
    run_check("summa_parity")


@pytest.mark.slow
def test_tensor2d_oracle_winner_measured():
    """The tuned plan for a weight-heavy LM picks a 2D SUMMA lattice point
    and the oracle's winner is the measured winner (ISSUE-9 acceptance).
    Calibrate-then-measure: timing-sensitive, retries re-run the FULL
    check."""
    run_check("tensor2d_validation", timeout=560, retries=2)


@pytest.mark.slow
def test_serving_oracle_winner_measured():
    """Paged-cache serving under serve_tp and serve_seqkv on a 2-device
    mesh stays bit-exact vs the dense single-device reference, and the
    serving oracle's throughput winner is the measured winner (ISSUE-10
    acceptance). Timing-sensitive: retries re-run the FULL check."""
    run_check("serving_validation", timeout=560, retries=2)


@pytest.mark.slow
def test_oracle_validation_harness():
    run_check("oracle_validation", retries=1)


@pytest.mark.slow
def test_compressed_gradient_allreduce():
    run_check("compressed_allreduce")
