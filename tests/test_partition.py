"""DP stage partitioner: brute-force parity, balanced-split dominance, and
the oracle's non-uniform pipeline row built on top of it.

Acceptance (ISSUE 3): the DP partition must match brute-force enumeration on
≤12-layer tables, and on a skewed layer table the projected pipeline time
with non-uniform stages must be strictly below the balanced(-layer-count)
stage projection.
"""
import itertools

import numpy as np
import pytest

from repro.core import OracleConfig, TimeModel, cpu_host_model, project
from repro.core.layer_stats import LayerStat
from repro.core.oracle import pipeline_stage_terms, precompute
from repro.core.partition import (balanced_partition, cut_values,
                                  min_max_partition, stage_sums)

SYS = cpu_host_model(alpha=1e-5, beta=1e-9, flops=1e12)


def brute_force_max(costs, k):
    """Min over ALL contiguous k-partitions of the max stage sum."""
    n = len(costs)
    best = np.inf
    for cuts in itertools.combinations(range(1, n), k - 1):
        bounds = (0,) + cuts + (n,)
        best = min(best, float(stage_sums(costs, bounds).max()))
    return best


@pytest.mark.parametrize("seed", range(6))
@pytest.mark.parametrize("k", [1, 2, 3, 5])
def test_dp_matches_brute_force(seed, k):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(k, 13))        # ≤ 12 layers: exhaustible
    costs = rng.uniform(0.1, 10.0, n)
    part = min_max_partition(costs, k)
    assert part.bounds[0] == 0 and part.bounds[-1] == n
    assert all(b < a for b, a in zip(part.bounds, part.bounds[1:]))
    got = float(stage_sums(costs, part.bounds).max())
    assert np.isclose(got, part.max_cost, rtol=1e-12)
    assert np.isclose(got, brute_force_max(costs, k), rtol=1e-12)


def test_dp_never_worse_than_balanced_counts():
    rng = np.random.default_rng(7)
    for _ in range(20):
        n = int(rng.integers(4, 40))
        k = int(rng.integers(2, min(n, 9)))
        costs = rng.uniform(0.0, 5.0, n)
        dp = min_max_partition(costs, k)
        bal = balanced_partition(n, k)
        assert dp.max_cost <= float(stage_sums(costs, bal.bounds).max()) + 1e-15


def test_dp_strictly_beats_balanced_on_skew():
    """Skewed costs: one fat layer at the head; equal-count stages pair it
    with neighbours while the DP isolates it."""
    costs = np.array([10.0, 1, 1, 1, 1, 1, 1, 1])
    dp = min_max_partition(costs, 4)
    bal = balanced_partition(8, 4)
    assert dp.max_cost == 10.0
    assert float(stage_sums(costs, bal.bounds).max()) == 11.0
    assert dp.max_cost < float(stage_sums(costs, bal.bounds).max())


def test_cut_values_picks_boundary_layers():
    y = np.array([5.0, 7.0, 2.0, 9.0, 1.0])
    assert list(cut_values(y, (0, 2, 4, 5))) == [7.0, 9.0]
    assert cut_values(y, (0, 5)).size == 0
    assert balanced_partition(5, 2).counts() == (3, 2)


def test_partition_rejects_bad_inputs():
    with pytest.raises(ValueError):
        min_max_partition(np.ones(3), 4)     # more stages than layers
    with pytest.raises(ValueError):
        min_max_partition(np.array([1.0, -1.0]), 1)
    with pytest.raises(ValueError):
        balanced_partition(3, 0)


# ---------------------------------------------------------------------------
# oracle integration: the pipeline row rides the DP cuts
# ---------------------------------------------------------------------------

def _skewed_stats():
    """8 uniform-ish layers with one dominant head layer and one fat
    activation in the middle (so cut placement matters for p2p too)."""
    mk = lambda name, flops, y: LayerStat(   # noqa: E731
        name, "conv", x=1024, y=y, w=4096, flops_fwd=flops, F=64, C=64,
        spatial=32)
    return [mk("l0", 8e9, 1024), mk("l1", 1e9, 1024), mk("l2", 1e9, 65536),
            mk("l3", 1e9, 1024), mk("l4", 1e9, 1024), mk("l5", 1e9, 1024),
            mk("l6", 1e9, 1024), mk("l7", 1e9, 1024)]


def test_oracle_pipeline_uses_dp_cuts_not_balanced():
    """Acceptance: projected pipeline time with DP stages strictly below the
    balanced-layer-count stage projection on a skewed table."""
    stats = _skewed_stats()
    tm = TimeModel(SYS)
    cfg = OracleConfig(B=64, D=640)
    p, S = 4, cfg.segments
    proj = project("pipeline", stats, tm, cfg, p)
    T = precompute(stats, tm)
    mfw, mbw, mwu, ycut, *_ = pipeline_stage_terms(T, p)
    # DP bottleneck == what the oracle projected
    want_comp = cfg.D * (p + S - 1) / S * (mfw + mbw) \
        + proj.iterations * mwu
    assert np.isclose(proj.comp_s, want_comp, rtol=1e-12)
    # balanced-count projection is strictly worse on this table
    bal = balanced_partition(T.n, p)
    bal_fw = float(stage_sums(T.fw, bal.bounds).max())
    bal_bw = float(stage_sums(T.bw, bal.bounds).max())
    bal_comp = cfg.D * (p + S - 1) / S * (bal_fw + bal_bw) \
        + proj.iterations * float(stage_sums(T.wu, bal.bounds).max())
    assert proj.comp_s < bal_comp
    # boundary activations come from the ACTUAL cut points of the deployed
    # partition — not the global max layer output the old row used
    bounds = min_max_partition(T.fw + T.bw, p).bounds
    assert ycut == float(cut_values(T.y, bounds).max())


def test_oracle_pipeline_p2p_zero_at_single_stage():
    stats = _skewed_stats()
    proj = project("pipeline", stats, TimeModel(SYS), OracleConfig(B=64, D=640), 1)
    assert proj.comm_p2p_s == 0.0


def test_phi_levels_table_overrides_defaults():
    """Per-interconnect φ: a {'data': φ} entry rescales the hybrid gradient
    exchange; no table preserves the phi_hybrid constant exactly."""
    stats = _skewed_stats()
    tm = TimeModel(SYS)
    base = OracleConfig(B=256, D=2560)
    same = OracleConfig(B=256, D=2560, phi_levels={"data": base.phi_hybrid})
    up = OracleConfig(B=256, D=2560, phi_levels={"data": 4.0})
    a = project("df", stats, tm, base, 16, p1=4, p2=4)
    b = project("df", stats, tm, same, 16, p1=4, p2=4)
    c = project("df", stats, tm, up, 16, p1=4, p2=4)
    assert a.comm_ge_s == b.comm_ge_s
    assert c.comm_ge_s > a.comm_ge_s
    # model-level φ scales the FB bandwidth term (α part unchanged)
    m = OracleConfig(B=256, D=2560, phi_levels={"model": 2.0})
    f1 = project("filter", stats, tm, base, 8)
    f2 = project("filter", stats, tm, m, 8)
    assert f2.comm_fb_s > f1.comm_fb_s
    assert same.phi_for("model") == 1.0 and up.phi_for("data", 2.0) == 4.0
