"""MoE / SSD / RG-LRU block semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.nn import (FFN, FFNConfig, MoE, MoEConfig, RecurrentBlock,
                      RGLRUConfig, SSDBlock, SSMConfig)
from repro.nn.module import tree_init


def test_moe_routing_mass_and_shapes(key):
    cfg = MoEConfig(d_model=32, d_ff=64, n_experts=8, top_k=2, n_shared=1,
                    capacity_factor=2.0, n_groups=2)
    moe = MoE(cfg)
    p = tree_init(moe.params_spec(), key)
    x = jax.random.normal(key, (2, 32, 32))
    y, aux = moe.apply(p, x)
    assert y.shape == x.shape
    assert np.isfinite(aux) and aux >= 0
    # top-k weights normalized
    ids, w, _ = moe._route(p, x.reshape(-1, 32))
    np.testing.assert_allclose(jnp.sum(w, -1), 1.0, rtol=1e-5)


def test_moe_capacity_drops_tokens(key):
    # capacity_factor tiny → overflow tokens dropped, output stays finite
    cfg = MoEConfig(d_model=16, d_ff=16, n_experts=2, top_k=1,
                    capacity_factor=0.1, n_groups=1)
    moe = MoE(cfg)
    p = tree_init(moe.params_spec(), key)
    x = jax.random.normal(key, (1, 64, 16))
    y, _ = moe.apply(p, x)
    assert np.all(np.isfinite(y))
    # with cap ~4 of 64 tokens, most outputs are exactly zero (dropped)
    zero_rows = np.mean(np.all(np.asarray(y) == 0, axis=-1))
    assert zero_rows > 0.5


def test_ssd_chunked_equals_stepwise(key):
    cfg = SSMConfig(d_model=32, d_state=16, head_dim=8, expand=2, chunk=16)
    ssd = SSDBlock(cfg)
    p = tree_init(ssd.params_spec(), key)
    x = jax.random.normal(key, (2, 64, 32)) * 0.5
    y = ssd.apply(p, x)
    cache = jax.tree.map(jnp.zeros_like, tree_init(ssd.cache_spec(2), key))
    outs = []
    for t in range(64):
        yt, cache = ssd.decode(p, x[:, t:t + 1], cache, t)
        outs.append(yt)
    np.testing.assert_allclose(jnp.concatenate(outs, 1), y, rtol=2e-3,
                               atol=2e-3)


@pytest.mark.parametrize("chunk", [8, 32, 64])
def test_ssd_chunk_invariance(key, chunk):
    import dataclasses
    cfg = SSMConfig(d_model=16, d_state=8, head_dim=8, expand=2, chunk=chunk)
    ssd = SSDBlock(cfg)
    p = tree_init(ssd.params_spec(), key)
    x = jax.random.normal(key, (1, 64, 16)) * 0.5
    y = ssd.apply(p, x)
    ssd_ref = SSDBlock(dataclasses.replace(cfg, chunk=64))
    np.testing.assert_allclose(y, ssd_ref.apply(p, x), rtol=2e-3, atol=2e-3)


def test_rglru_scan_equals_stepwise(key):
    cfg = RGLRUConfig(d_model=32, lru_width=64, n_blocks=4)
    rec = RecurrentBlock(cfg)
    p = tree_init(rec.params_spec(), key)
    x = jax.random.normal(key, (2, 48, 32)) * 0.5
    y = rec.apply(p, x)
    cache = jax.tree.map(jnp.zeros_like, tree_init(rec.cache_spec(2), key))
    outs = []
    for t in range(48):
        yt, cache = rec.decode(p, x[:, t:t + 1], cache, t)
        outs.append(yt)
    np.testing.assert_allclose(jnp.concatenate(outs, 1), y, rtol=2e-3,
                               atol=2e-3)


def test_rglru_decay_in_range(key):
    cfg = RGLRUConfig(d_model=8, lru_width=16, n_blocks=2)
    rec = RecurrentBlock(cfg)
    p = tree_init(rec.params_spec(), key)
    x = jax.random.normal(key, (1, 4, 16))
    a, _ = rec._gates(p, x)
    assert np.all(np.asarray(a) > 0) and np.all(np.asarray(a) < 1)


def test_ffn_glu_bias(key):
    ffn = FFN(FFNConfig(16, 32, activation="gelu", glu=True, use_bias=True))
    p = tree_init(ffn.params_spec(), key)
    y = ffn.apply(p, jax.random.normal(key, (2, 4, 16)))
    assert y.shape == (2, 4, 16) and np.all(np.isfinite(y))
