"""Per-arch smoke tests (deliverable f): reduced config of the same family,
one forward/train step on CPU, output shapes + no NaNs; serve step where the
family has one."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, PAPER_CNNS, get_config
from repro.data.pipeline import ShardedLoader
from repro.launch.build import build_model
from repro.launch.train import data_config_for
from repro.nn.module import NULL_CTX, tree_init
from repro.optim.optimizers import OptimizerConfig
from repro.training.steps import (make_decode_step, make_prefill_step,
                                  make_train_step, train_state_spec)

B, S = 2, 32


def _batch_for(cfg, mc):
    dcfg = data_config_for(mc, B, S, seed=0)
    return ShardedLoader(dcfg).batch_at(0)


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS + PAPER_CNNS)
def test_smoke_train_step(arch):
    cfg = get_config(arch)
    model = build_model(cfg, smoke=True)
    mc = cfg.smoke_model
    opt = OptimizerConfig(name="sgd", zero1=False)
    kw = {}
    if cfg.family in ("lm", "vlm"):
        kw = dict(attn_impl="plain", scan_layers=True, remat=False)
    step = jax.jit(make_train_step(model, opt, NULL_CTX, **kw))
    state = tree_init(train_state_spec(model, opt), jax.random.PRNGKey(0))
    batch = _batch_for(cfg, mc)
    state, metrics = step(state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss), f"{arch}: non-finite loss"
    # params updated and finite
    leaf = jax.tree.leaves(state["params"])[0]
    assert np.all(np.isfinite(np.asarray(leaf, dtype=np.float32)))


@pytest.mark.parametrize("arch", [a for a in ASSIGNED_ARCHS
                                  if get_config(a).family in ("lm", "vlm")])
def test_smoke_serve_step(arch):
    cfg = get_config(arch)
    model = build_model(cfg, smoke=True)
    mc = cfg.smoke_model
    lm_cfg = mc.lm if cfg.family == "vlm" else mc
    key = jax.random.PRNGKey(0)
    params = tree_init(model.params_spec(), key)
    cache = jax.tree.map(jnp.zeros_like,
                         tree_init(model.cache_spec(B, S), key))
    prefill = make_prefill_step(model, NULL_CTX, scan_layers=True,
                                q_chunk=8, kv_chunk=8)
    decode = make_decode_step(model, NULL_CTX, scan_layers=True)
    toks = jax.random.randint(key, (B, S // 2), 0, lm_cfg.vocab)
    if cfg.family == "vlm":
        patches = jax.random.normal(key, (B, mc.n_patches, mc.d_vision))
        logits, cache = prefill(params, {"patches": patches, "tokens": toks},
                                cache)
        pos = mc.n_patches + S // 2
    else:
        logits, cache = prefill(params, {"tokens": toks}, cache)
        pos = S // 2
    assert np.all(np.isfinite(np.asarray(logits, dtype=np.float32)))
    lg, cache = decode(params, toks[:, :1], cache, jnp.int32(pos))
    assert lg.shape[0] == B and lg.shape[-1] == lm_cfg.vocab
    assert np.all(np.isfinite(np.asarray(lg, dtype=np.float32)))


def test_smoke_encdec_serve():
    cfg = get_config("whisper-medium")
    model = build_model(cfg, smoke=True)
    mc = cfg.smoke_model
    key = jax.random.PRNGKey(0)
    params = tree_init(model.params_spec(), key)
    cache = jax.tree.map(jnp.zeros_like,
                         tree_init(model.cache_spec(B, S), key))
    frames = jax.random.normal(key, (B, mc.max_source_positions, mc.d_model))
    _, cache = model.prefill(params, frames, cache)
    tok = jnp.zeros((B, 1), jnp.int32)
    lg, cache = model.decode_step(params, tok, cache, 0)
    assert np.all(np.isfinite(np.asarray(lg, dtype=np.float32)))


def test_paper_cnn_param_counts():
    """Paper Table 5 sanity: ResNet-50 ≈25M, ResNet-152 ≈58M, VGG16 ≈138M."""
    from repro.models.cnn import RESNET50, RESNET152, ResNet, VGG, VGGConfig
    r50 = ResNet(RESNET50).num_params()
    r152 = ResNet(RESNET152).num_params()
    vgg = VGG(VGGConfig()).num_params()
    assert 24e6 < r50 < 27e6, r50
    assert 55e6 < r152 < 62e6, r152
    assert 130e6 < vgg < 145e6, vgg


def test_assigned_arch_param_counts():
    """Full configs land near their nameplate sizes."""
    from repro.nn.module import tree_num_params
    expect = {"mamba2-780m": (0.7e9, 0.9e9), "qwen3-32b": (30e9, 34e9),
              "qwen1.5-4b": (3.5e9, 4.3e9), "deepseek-67b": (64e9, 70e9),
              "grok-1-314b": (300e9, 330e9),
              "deepseek-v3-671b": (640e9, 700e9),
              "recurrentgemma-9b": (8e9, 10.5e9),
              "paligemma-3b": (2.4e9, 3.2e9)}
    for arch, (lo, hi) in expect.items():
        model = build_model(get_config(arch))
        n = tree_num_params(model.params_spec())
        assert lo < n < hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]"
