"""jax version-compat shim (launch/compat.py).

The shim must build identical Auto-axis meshes whether or not the running
jax exposes ``jax.sharding.AxisType`` — both branches are exercised here by
stubbing the attribute in or out, plus a functional build on the real jax
(whichever branch this image takes).
"""
import jax

from repro.launch import compat


def test_make_mesh_works_on_this_jax():
    mesh = compat.make_mesh((1, 1), ("data", "model"))
    assert mesh.axis_names == ("data", "model")
    assert dict(mesh.shape) == {"data": 1, "model": 1}


def test_axis_type_kwargs_without_axistype(monkeypatch):
    monkeypatch.delattr(jax.sharding, "AxisType", raising=False)
    assert compat.axis_type_kwargs(2) == {}


def test_axis_type_kwargs_with_axistype(monkeypatch):
    class FakeAxisType:
        Auto = "auto-sentinel"

    monkeypatch.setattr(jax.sharding, "AxisType", FakeAxisType,
                        raising=False)
    kw = compat.axis_type_kwargs(3)
    assert kw == {"axis_types": ("auto-sentinel",) * 3}


def test_make_mesh_passes_axis_types_only_when_supported(monkeypatch):
    """Whatever axis_type_kwargs yields is forwarded verbatim to
    jax.make_mesh — the shim never hardcodes a branch."""
    seen = {}

    def fake_make_mesh(shape, axes, **kwargs):
        seen.update(kwargs, shape=shape, axes=axes)
        return "mesh-sentinel"

    monkeypatch.setattr(jax, "make_mesh", fake_make_mesh)
    monkeypatch.delattr(jax.sharding, "AxisType", raising=False)
    assert compat.make_mesh((2, 4), ("data", "model")) == "mesh-sentinel"
    assert seen == {"shape": (2, 4), "axes": ("data", "model")}

    class FakeAxisType:
        Auto = "auto-sentinel"

    monkeypatch.setattr(jax.sharding, "AxisType", FakeAxisType,
                        raising=False)
    seen.clear()
    compat.make_mesh((2, 4), ("data", "model"))
    assert seen["axis_types"] == ("auto-sentinel", "auto-sentinel")


def test_axis_size_matches_mesh_axis():
    """compat.axis_size inside shard_map returns the mesh axis extent."""
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    mesh = compat.make_mesh((1, 1), ("data", "model"))
    out = compat.shard_map(
        lambda x: x * compat.axis_size("data"),
        mesh=mesh, in_specs=P(), out_specs=P(), check_vma=False,
    )(jnp.ones(()))
    assert float(out) == 1.0


def test_shard_map_runs_on_this_jax():
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P

    mesh = compat.make_mesh((1, 1), ("data", "model"))
    fn = compat.shard_map(lambda x: x + 1, mesh=mesh,
                          in_specs=P(), out_specs=P())
    np.testing.assert_array_equal(np.asarray(fn(jnp.zeros((3,)))),
                                  np.ones((3,)))
