"""Integration: loss decreases, bit-exact resume, fault injection, stragglers."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointing import Checkpointer
from repro.data.pipeline import DataConfig, ShardedLoader
from repro.models import LMConfig, TransformerLM
from repro.nn import AttentionConfig, FFNConfig
from repro.nn.module import NULL_CTX, tree_init
from repro.optim.optimizers import OptimizerConfig
from repro.runtime.fault_tolerance import (StepTimer, StragglerAlert,
                                           run_with_recovery)
from repro.training.steps import make_train_step, train_state_spec

V = 128


def tiny_lm():
    cfg = LMConfig(name="t", vocab=V, d_model=32, n_layers=2,
                   attn=AttentionConfig(32, 4, 2, 8, dtype=jnp.float32),
                   ffn=FFNConfig(32, 64, dtype=jnp.float32),
                   dtype=jnp.float32)
    return TransformerLM(cfg)


def setup(seed=0, lr=1e-2):
    model = tiny_lm()
    opt = OptimizerConfig(lr=lr, name="adamw", zero1=False)
    step = jax.jit(make_train_step(model, opt, NULL_CTX, attn_impl="plain",
                                   scan_layers=False, remat=False))
    state = tree_init(train_state_spec(model, opt), jax.random.PRNGKey(seed))
    loader = ShardedLoader(DataConfig("lm", batch=8, seq_len=32, vocab=V))
    return step, state, loader


def test_loss_decreases():
    step, state, loader = setup()
    losses = []
    for t in range(30):
        state, m = step(state, loader.batch_at(t))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) * 0.8


def test_resume_bit_exact(tmp_path):
    step, state, loader = setup()
    ck = Checkpointer(tmp_path)
    # run 10, checkpoint, run 10 more
    for t in range(10):
        state, _ = step(state, loader.batch_at(t))
    ck.save(state, 10)
    cont = state
    for t in range(10, 20):
        cont, _ = step(cont, loader.batch_at(t))
    # restore and replay — must match bit-exactly (deterministic loader)
    restored, s0 = ck.restore(state)
    assert s0 == 10
    for t in range(10, 20):
        restored, _ = step(restored, loader.batch_at(t))
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b),
                 cont["params"], restored["params"])


def test_injected_failure_recovers(tmp_path):
    step, state, loader = setup()
    ck = Checkpointer(tmp_path)
    final, nstep = run_with_recovery(
        step, state, loader, ck, n_steps=25, ckpt_every=5, async_ckpt=False,
        inject_failure_at=12)
    assert nstep == 25
    assert ck.latest_step() == 25


def test_straggler_alert():
    timer = StepTimer(threshold=2.0)
    for i in range(10):
        timer.observe(i, 0.1)
    with pytest.raises(StragglerAlert):
        timer.observe(10, 1.0)


def test_straggler_outlier_kept_out_of_baseline():
    """Regression: the straggler sample used to be appended to the window
    before raising, so a run of slow steps dragged the median up until the
    detector stopped firing. Every one of a burst of stragglers must
    alert, and the median baseline must not move."""
    timer = StepTimer(window=8, threshold=3.0)
    for i in range(8):
        timer.observe(i, 0.1)
    for i in range(8, 16):   # 0.35 > 3 × 0.1 — every step is a straggler
        with pytest.raises(StragglerAlert):
            timer.observe(i, 0.35)
        assert timer.median == pytest.approx(0.1)  # baseline unpolluted


def test_step_timer_reset_clears_baseline():
    timer = StepTimer(threshold=2.0)
    for i in range(10):
        timer.observe(i, 0.1)
    timer.reset()
    assert timer.median == 0.0
    # a fresh window needs 8 samples before alerting again — a re-meshed
    # plan's first (compile-heavy) step must not trip the old baseline
    timer.observe(0, 5.0)


def test_spaced_failures_do_not_exhaust_restart_budget(tmp_path):
    """Regression: the restart budget never reset, so 4 transient failures
    spread across a long run killed it even though each was followed by
    plenty of forward progress. The budget counts CONSECUTIVE failures —
    a checkpoint newer than the previous failure's resets it."""
    step, state, loader = setup()
    ck = Checkpointer(tmp_path)
    final, nstep = run_with_recovery(
        step, state, loader, ck, n_steps=40, ckpt_every=5, async_ckpt=False,
        inject_failure_at=(7, 13, 22, 33), max_restarts=3)
    assert nstep == 40
    assert ck.latest_step() == 40


def test_restart_budget_still_bounds_crash_loops(tmp_path):
    """A fault that recurs every time the same step replays (no forward
    progress, no checkpoint) must still exhaust the budget and surface."""
    step, state, loader = setup()
    ck = Checkpointer(tmp_path)

    def inject(s):
        if s == 3:
            raise RuntimeError("deterministic fault at step 3")

    with pytest.raises(RuntimeError, match="deterministic fault"):
        run_with_recovery(step, state, loader, ck, n_steps=10,
                          ckpt_every=100, async_ckpt=False, inject=inject,
                          max_restarts=3)


def test_grad_accumulation_matches_full_batch():
    model = tiny_lm()
    opt = OptimizerConfig(lr=1e-2, name="sgd", momentum=0.0, zero1=False,
                          grad_clip=1e9)
    s1 = jax.jit(make_train_step(model, opt, NULL_CTX, accum=1,
                                 attn_impl="plain", remat=False))
    s4 = jax.jit(make_train_step(model, opt, NULL_CTX, accum=4,
                                 attn_impl="plain", remat=False))
    state = tree_init(train_state_spec(model, opt), jax.random.PRNGKey(0))
    loader = ShardedLoader(DataConfig("lm", batch=8, seq_len=16, vocab=V))
    batch = loader.batch_at(0)
    a, _ = s1(state, batch)
    b, _ = s4(state, batch)
    jax.tree.map(lambda x, y: np.testing.assert_allclose(x, y, rtol=2e-5,
                                                         atol=2e-5),
                 a["params"], b["params"])
