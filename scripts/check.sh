#!/usr/bin/env bash
# Single gate for code and docs PRs: tier-1 tests + sweep smoke + lint.
# Usage: scripts/check.sh  (from anywhere; cd's to the repo root)
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== hygiene: no committed bytecode =="
if git ls-files | grep -E '(^|/)__pycache__(/|$)|\.py[co]$' >/dev/null; then
    echo "committed __pycache__/bytecode files found:" >&2
    git ls-files | grep -E '(^|/)__pycache__(/|$)|\.py[co]$' >&2
    exit 1
fi

echo "== tier-1 pytest =="
python -m pytest -x -q

echo "== oracle sweep smoke =="
python -m repro.core.sweep --smoke

echo "== auto-tuner smoke =="
python -m repro.core.autotune --smoke

echo "== session API parity gate =="
# the retired PR-5 sweep.parse_*_table shims must STAY gone, and the
# Oracle session facade must answer within 1e-12 of the legacy
# project/sweep/advise/autotune/plan_for_arch signatures (DESIGN.md §11)
python -m repro.api --parity

echo "== session API smoke =="
# project → tune → build → dryrun on cpu_host_model through the session
python -m repro.api --smoke

echo "== pipeline deploy+validate smoke =="
# deploys a TunedPlan[strategy=pipeline] through build_cell and trains one
# step, then measures the GPipe executor against the oracle's DP-partitioned
# pipeline row (writes the EXPERIMENTS.md artifact)
python tests/helpers/multidevice_checks.py pipeline_deploy
python tests/helpers/multidevice_checks.py pipeline_validation \
    --write experiments/pipeline_validation.json

echo "== schedule parity + bubble validation =="
# every pipeline schedule must stay gradient-exact vs the serial jit step
# on uniform LM, non-uniform LM, and heterogeneous CNN cuts
for sched in gpipe one_f_one_b interleaved; do
    python tests/helpers/multidevice_checks.py schedule_parity "$sched"
done
# and the measured bubble must shrink under 1F1B/interleaved vs GPipe at
# equal S, with the oracle's schedule axis picking the measured winner
# (writes the EXPERIMENTS.md artifact). Calibrate-then-measure on a
# timeshared core: a retry repeats the FULL check, assertions unrelaxed
for attempt in 1 2 3; do
    if python tests/helpers/multidevice_checks.py schedule_validation \
        --write experiments/schedule_validation.json; then
        break
    elif [ "$attempt" = 3 ]; then
        echo "schedule_validation failed on all attempts" >&2
        exit 1
    else
        echo "schedule_validation: retry $attempt (timing-sensitive)"
    fi
done

echo "== overlap parity smoke =="
# the overlapped interior/boundary-split halo conv must stay BIT-EXACT vs
# the serial pipeline and the unsharded SAME conv on the multi-device CPU
# mesh (incl. the deployed HaloConv and the Pallas halo-aware kernel)
python tests/helpers/multidevice_checks.py halo_overlap
# and the measured ds (spatial-hybrid) step must land closer to the overlap
# oracle than to the serial-comm model (writes the EXPERIMENTS.md artifact).
# Calibrate-then-measure on a timeshared core: like the retried checks in
# tests/test_distributed.py, a retry repeats the FULL check — the
# assertion itself is never relaxed
for attempt in 1 2 3; do
    if python tests/helpers/multidevice_checks.py spatial_overlap_validation \
        --write experiments/spatial_overlap_validation.json; then
        break
    elif [ "$attempt" = 3 ]; then
        echo "spatial_overlap_validation failed on all attempts" >&2
        exit 1
    else
        echo "spatial_overlap_validation: retry $attempt (timing-sensitive)"
    fi
done

echo "== 2D tensor (SUMMA) parity + validation =="
# the shard_map SUMMA matmul must stay gradient-exact vs the serial einsum
# and the NULL_CTX train step on the (data, model_r, model_c) grid mesh —
# deterministic, no retry
python tests/helpers/multidevice_checks.py summa_parity
# and the tuner's 2D pick must beat pure data WHERE MEASURED: oracle winner
# == measured winner on the 8-device host mesh (writes the EXPERIMENTS.md
# "2D tensor validation" artifact). Calibrate-then-measure on a timeshared
# core: a retry repeats the FULL check, assertions unrelaxed
for attempt in 1 2 3; do
    if python tests/helpers/multidevice_checks.py tensor2d_validation \
        --write experiments/tensor2d_validation.json; then
        break
    elif [ "$attempt" = 3 ]; then
        echo "tensor2d_validation failed on all attempts" >&2
        exit 1
    else
        echo "tensor2d_validation: retry $attempt (timing-sensitive)"
    fi
done

echo "== serving engine smoke =="
# continuous-batching engine end-to-end on the single-host backend: paged
# cache, chunked prefill, closed-loop replay — any token-path breakage
# shows up here in seconds
python -m repro.launch.serve --arch qwen3-32b --smoke --requests 4 \
    --prompt-len 16 --gen 8 --closed-loop
# and the serving oracle's sweep must return a plan meeting the stated
# p99 SLO for the full (non-smoke) model — analytic, deterministic
python -m repro.api --serve-tune --arch qwen3-32b --p 8 --rate 4 \
    --prompt 256 --gen 64 --slo-ms 60000

echo "== serving validation =="
# paged sharded serving under serve_tp AND serve_seqkv must stay bit-exact
# vs the dense single-device reference, and the serving oracle's
# throughput winner must be the measured winner on the 2-device mesh
# (writes the EXPERIMENTS.md artifact). Calibrate-then-measure on a
# timeshared core: a retry repeats the FULL check, assertions unrelaxed
for attempt in 1 2 3; do
    if python tests/helpers/multidevice_checks.py serving_validation \
        --write experiments/serving_validation.json; then
        break
    elif [ "$attempt" = 3 ]; then
        echo "serving_validation failed on all attempts" >&2
        exit 1
    else
        echo "serving_validation: retry $attempt (timing-sensitive)"
    fi
done

echo "== chaos-gate: elastic recovery on virtual devices =="
# slice death mid-run: the survivors' ClusterSpec is re-tuned, the
# checkpoint is resharded plan-to-plan, and the resumed loss trajectory is
# bit-exact vs the planned-reshape reference (DESIGN.md §12). The full
# scenario matrix (straggler burst, torn checkpoint, spaced transients)
# lives behind the chaos marker — kept out of the tier-1 fast path
python -m repro.api --chaos
python -m pytest -q -m chaos tests/test_chaos.py

echo "== kernel autotune smoke =="
# prune → measure → cache on tiny shapes (interpret mode). The gate inside
# asserts the cached winner is never slower than the measured default —
# true by construction (the default is always among the measured
# candidates), so a failure means the tuner's selection logic broke, not
# timing noise. Writes a scratch artifact, never the committed one.
python -m repro.api --tune-kernels --tune-shapes smoke \
    --out /tmp/kernel_tune_smoke.json

echo "== kernel bench smoke =="
# every Pallas kernel must run (interpret mode); a kernel that stops
# compiling fails the gate. The smoke writes its own (gitignored) side
# artifact — the committed BENCH_kernels.json perf trajectory records
# full runs only
python -m benchmarks.bench_kernels --smoke
# perf trajectory gate: a fresh FULL run must stay within 25% of the
# committed BENCH_kernels.json per kernel. Interpret-mode wall time on a
# timeshared core is noisy, hence the wide band plus retries — a real
# regression fails every attempt, a scheduler stall does not
for attempt in 1 2 3; do
    python -m benchmarks.bench_kernels --out /tmp/bench_fresh.json
    if python scripts/bench_compare.py BENCH_kernels.json \
        /tmp/bench_fresh.json; then
        break
    elif [ "$attempt" = 3 ]; then
        echo "kernel bench regressed vs committed trajectory" >&2
        exit 1
    else
        echo "bench_compare: retry $attempt (timing noise)"
    fi
done

echo "== sweep bench trajectory =="
# a fresh full sweep over the 2D-widened lattice (ISSUE 9: summa fans p2
# over every (p2r, p2c) factorization) must stay within 2x the committed
# BENCH_sweep.json wall-clock — pure-python timings on a timeshared core,
# hence the wide band plus retries; a real engine regression fails every
# attempt
for attempt in 1 2 3; do
    python -m benchmarks.bench_sweep --out /tmp/bench_sweep_fresh.json
    if python scripts/bench_compare.py BENCH_sweep.json \
        /tmp/bench_sweep_fresh.json --tol 1.0; then
        break
    elif [ "$attempt" = 3 ]; then
        echo "sweep bench regressed vs committed trajectory" >&2
        exit 1
    else
        echo "bench_compare: retry $attempt (timing noise)"
    fi
done

echo "== serve bench trajectory =="
# a fresh closed-loop engine replay must stay within 2x the committed
# BENCH_serve.json µs-per-token — host wall-clock on a timeshared core,
# hence the wide band plus retries; a real engine regression (a dropped
# donation, a full-cache copy per step) fails every attempt
for attempt in 1 2 3; do
    python scripts/bench_serve.py --out /tmp/bench_serve_fresh.json
    if python scripts/bench_compare.py BENCH_serve.json \
        /tmp/bench_serve_fresh.json --tol 1.0; then
        break
    elif [ "$attempt" = 3 ]; then
        echo "serve bench regressed vs committed trajectory" >&2
        exit 1
    else
        echo "bench_compare: retry $attempt (timing noise)"
    fi
done

echo "== docs references =="
# every DESIGN.md reference in src/ must have a DESIGN.md to resolve into
if grep -rqn "DESIGN.md" src/ && [ ! -f DESIGN.md ]; then
    echo "src/ references DESIGN.md but it does not exist" >&2
    exit 1
fi

if command -v ruff >/dev/null 2>&1; then
    echo "== ruff =="
    ruff check src tests benchmarks examples experiments
else
    echo "== ruff not installed; skipping lint =="
fi

echo "OK"
