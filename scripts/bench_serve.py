#!/usr/bin/env python
"""Serving-engine throughput trajectory (BENCH_serve.json).

Replays one synthetic Poisson trace (serve/traffic.py) through the
continuous-batching engine closed-loop on the host backend and records
µs-per-generated-token at decode-batch widths 1 and 4 — the width-4 row
is the continuous-batching win the engine exists for, and both rows are
a committed perf trajectory: scripts/check.sh lands a fresh run in a
scratch file and diffs it against the committed BENCH_serve.json with
scripts/bench_compare.py (wide band — host wall-clock on a timeshared
core is noisy; a real engine regression fails every retry).

Usage:
    python scripts/bench_serve.py                 # refresh the artifact
    python scripts/bench_serve.py --out /tmp/x.json   # scratch run
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_ROOT, "src"))

ARTIFACT = os.path.join(_ROOT, "BENCH_serve.json")


def run_rows():
    import jax
    import jax.numpy as jnp
    from repro.models import LMConfig, TransformerLM
    from repro.nn import AttentionConfig, FFNConfig
    from repro.nn.module import NULL_CTX, tree_init
    from repro.serve import Engine, ServeConfig, TrafficModel

    cfg = LMConfig(
        name="bench", vocab=512, d_model=64, n_layers=4,
        attn=AttentionConfig(64, 4, 2, 16, dtype=jnp.float32),
        ffn=FFNConfig(64, 256, dtype=jnp.float32), dtype=jnp.float32)
    model = TransformerLM(cfg)
    params = tree_init(model.params_spec(), jax.random.PRNGKey(0))
    traffic = TrafficModel(rate=100.0, prompt_len=32, gen_len=16, spread=0.5)
    trace = traffic.trace(12, cfg.vocab, seed=0)

    rows = []
    for width in (1, 4):
        sc = ServeConfig(max_len=64, max_batch=width, block_tokens=16,
                         prefill_chunk=16, dtype=jnp.float32)
        eng = Engine(model, params, NULL_CTX, sc)
        eng.run(trace, honor_arrivals=False)      # compile + warm caches
        eng.reset()
        rep = eng.run(trace, honor_arrivals=False)
        assert rep.n_tokens == sum(r.max_new for r in trace), \
            "bench replay dropped tokens"
        rows.append((f"serve/closed_loop/batch{width}",
                     1e6 * rep.wall_s / rep.n_tokens,
                     f"tok_per_s={rep.tok_per_s:.1f};"
                     f"latency_p50_s={rep.percentile(50):.4f};"
                     f"latency_p99_s={rep.percentile(99):.4f};"
                     f"ttft_p50_s={rep.percentile(50, 'ttft'):.4f}"))
    return rows


def write_artifact(rows, out: "str | None" = None) -> str:
    rec = {"timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
           "smoke": False,
           "rows": [{"name": n, "us_per_call": round(us, 1), "derived": d}
                    for n, us, d in rows]}
    path = out or ARTIFACT
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
        f.write("\n")
    return path


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python scripts/bench_serve.py")
    ap.add_argument("--out", default=None,
                    help="write the artifact here instead of the committed "
                         "BENCH_serve.json")
    args = ap.parse_args(argv)
    rows = run_rows()
    for n, us, d in rows:
        print(f"{n:32s} {us:10.1f} us/token   {d}")
    print(f"wrote {write_artifact(rows, out=args.out)}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
