#!/usr/bin/env python
"""Tolerance-banded compare of a fresh kernel-bench run vs the committed
perf trajectory (BENCH_kernels.json).

A kernel row regresses when its fresh wall time exceeds the committed one by
more than the tolerance band (default 25%). Interpret-mode timings on a
timeshared CPU are noisy, so the band is wide and the check.sh gate wraps
this in a retry loop — a genuine regression fails every attempt, a
scheduler stall does not. Speedups never fail; they just print, and the
trajectory is refreshed by committing the fresh artifact in the PR that
earned them.

Usage:
    python -m benchmarks.bench_kernels --out /tmp/bench_fresh.json
    python scripts/bench_compare.py BENCH_kernels.json /tmp/bench_fresh.json

Exit 0 when every shared row is inside the band, 1 otherwise. Rows present
only in the baseline fail too (a kernel bench that silently disappears is a
coverage regression, not noise); rows present only in the fresh run are
reported and pass (new kernels enter the trajectory when committed).
"""
from __future__ import annotations

import argparse
import json
import sys


def load_rows(path: str) -> dict[str, float]:
    with open(path) as f:
        rec = json.load(f)
    if rec.get("smoke"):
        raise SystemExit(f"{path}: smoke artifact — smoke shapes are "
                         "incomparable with the committed trajectory; "
                         "re-run without --smoke")
    return {r["name"]: float(r["us_per_call"]) for r in rec["rows"]}


def compare(baseline: dict[str, float], fresh: dict[str, float],
            tol: float) -> tuple[list[str], list[str]]:
    """Returns (report lines, failure lines)."""
    lines, failures = [], []
    for name in sorted(set(baseline) | set(fresh)):
        if name not in fresh:
            failures.append(f"{name}: present in baseline but missing from "
                            "the fresh run (kernel bench disappeared)")
            continue
        if name not in baseline:
            lines.append(f"  NEW    {name}: {fresh[name]:10.1f} us "
                         "(no baseline; enters the trajectory on commit)")
            continue
        b, f = baseline[name], fresh[name]
        ratio = f / b if b > 0 else float("inf")
        verdict = "ok" if ratio <= 1.0 + tol else "REGRESSED"
        lines.append(f"  {verdict:9s} {name}: {b:10.1f} -> {f:10.1f} us "
                     f"({ratio:5.2f}x, band <= {1.0 + tol:.2f}x)")
        if verdict != "ok":
            failures.append(f"{name}: {ratio:.2f}x vs committed "
                            f"(> {1.0 + tol:.2f}x tolerance)")
    return lines, failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python scripts/bench_compare.py")
    ap.add_argument("baseline", help="committed trajectory artifact "
                                     "(BENCH_kernels.json)")
    ap.add_argument("fresh", help="fresh full-run artifact "
                                  "(bench_kernels --out ...)")
    ap.add_argument("--tol", type=float, default=0.25,
                    help="relative regression band (default 0.25 = fail "
                         "above 1.25x the committed time)")
    args = ap.parse_args(argv)
    lines, failures = compare(load_rows(args.baseline), load_rows(args.fresh),
                              args.tol)
    print(f"bench_compare: {args.fresh} vs {args.baseline} "
          f"(tol {args.tol:.0%})")
    print("\n".join(lines))
    if failures:
        print(f"bench_compare: {len(failures)} regression(s):",
              file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        return 1
    print("bench_compare: all kernels inside the tolerance band")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
