"""Config for --arch paligemma-3b (see lm_archs.py for the definition)."""
from .base import get_config


def config():
    return get_config("paligemma-3b")
