"""Config for --arch command-r-35b (see lm_archs.py for the definition)."""
from .base import get_config


def config():
    return get_config("command-r-35b")
