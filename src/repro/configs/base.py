"""Config plumbing: arch descriptors, input shapes, and the registry."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable


@dataclass(frozen=True)
class ShapeSpec:
    """One assigned (input-shape) cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


@dataclass(frozen=True)
class ArchConfig:
    """A registered architecture: full config + reduced smoke config.

    ``family``: lm | encdec | vlm | cnn.
    ``sub_quadratic``: True when long_500k is runnable (SSM / hybrid window).
    ``strategy``: default parallel strategy for the dry-run (see
    parallel/strategies.py); per-shape overrides in ``shape_strategy``.
    """

    name: str
    family: str
    model: Any
    smoke_model: Any
    source: str                    # provenance tag from the assignment
    sub_quadratic: bool = False
    strategy: str = "df_zero3"
    shape_strategy: dict = field(default_factory=dict)
    serve_kv_shards: int = 1   # sequence-sharded KV layout when kv heads
                               # cannot shard over the model axis (§Perf)
    notes: str = ""

    def strategy_for(self, shape: str) -> str:
        return self.shape_strategy.get(shape, self.strategy)

    def shapes(self) -> list[str]:
        if self.family == "cnn":
            return []
        out = ["train_4k", "prefill_32k", "decode_32k"]
        if self.sub_quadratic:
            out.append("long_500k")
        return out

    def skipped_shapes(self) -> dict[str, str]:
        if self.family == "cnn":
            return {}
        if not self.sub_quadratic:
            return {"long_500k": "full attention is O(S²); 500k-token decode "
                                 "requires sub-quadratic mixing (DESIGN.md "
                                 "§Arch-applicability)"}
        return {}


_REGISTRY: dict[str, Callable[[], ArchConfig]] = {}


def register(name: str):
    def deco(fn):
        _REGISTRY[name] = fn
        return fn
    return deco


def get_config(name: str) -> ArchConfig:
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def list_archs() -> list[str]:
    return sorted(_REGISTRY)
