"""Config for --arch deepseek-67b (see lm_archs.py for the definition)."""
from .base import get_config


def config():
    return get_config("deepseek-67b")
