"""Config for --arch deepseek-v3-671b (see lm_archs.py for the definition)."""
from .base import get_config


def config():
    return get_config("deepseek-v3-671b")
