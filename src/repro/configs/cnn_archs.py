"""The paper's own evaluation models (Table 5) as registered configs."""
from __future__ import annotations

from ..models.cnn import (RESNET50, RESNET152, CosmoFlowConfig, ResNetConfig,
                          VGGConfig)
from .base import ArchConfig, register


@register("resnet50")
def resnet50() -> ArchConfig:
    return ArchConfig(
        name="resnet50", family="cnn", model=RESNET50,
        smoke_model=ResNetConfig("resnet50-smoke", (1, 1, 1, 1), n_classes=10),
        source="[paper Table 5; He et al. 2016]", strategy="data")


@register("resnet152")
def resnet152() -> ArchConfig:
    return ArchConfig(
        name="resnet152", family="cnn", model=RESNET152,
        smoke_model=ResNetConfig("resnet152-smoke", (1, 2, 2, 1), n_classes=10),
        source="[paper Table 5; He et al. 2016]", strategy="data")


@register("vgg16")
def vgg16() -> ArchConfig:
    return ArchConfig(
        name="vgg16", family="cnn", model=VGGConfig(),
        smoke_model=VGGConfig(name="vgg16-smoke", n_classes=10, img=32),
        source="[paper Table 5; Simonyan & Zisserman 2015]", strategy="data")


@register("cosmoflow")
def cosmoflow() -> ArchConfig:
    return ArchConfig(
        name="cosmoflow", family="cnn", model=CosmoFlowConfig(img=128),
        smoke_model=CosmoFlowConfig(img=16, n_conv=2, width=8),
        source="[paper Table 5; Mathuriya et al. 2018]", strategy="ds",
        notes="paper: sample too large for anything but data+spatial (ds)")
