"""Config for --arch qwen1.5-4b (see lm_archs.py for the definition)."""
from .base import get_config


def config():
    return get_config("qwen1.5-4b")
