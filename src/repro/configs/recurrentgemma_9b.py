"""Config for --arch recurrentgemma-9b (see lm_archs.py for the definition)."""
from .base import get_config


def config():
    return get_config("recurrentgemma-9b")
