from . import cnn_archs, lm_archs  # noqa: F401  (populate the registry)
from .base import SHAPES, ArchConfig, ShapeSpec, get_config, list_archs

ASSIGNED_ARCHS = (
    "mamba2-780m", "qwen3-32b", "command-r-35b", "qwen1.5-4b", "deepseek-67b",
    "whisper-medium", "deepseek-v3-671b", "grok-1-314b", "recurrentgemma-9b",
    "paligemma-3b",
)
PAPER_CNNS = ("resnet50", "resnet152", "vgg16", "cosmoflow")
