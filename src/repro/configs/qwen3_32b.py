"""Config for --arch qwen3-32b (see lm_archs.py for the definition)."""
from .base import get_config


def config():
    return get_config("qwen3-32b")
