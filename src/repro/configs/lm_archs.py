"""The ten assigned architectures, exact configs from the assignment block.

Each ``<id>.py``-style factory lives here (one function per arch, registered
under its assigned id; separate files re-export for the configs/<id>.py layout
the deliverables ask for). Sources are tagged as given: [hf]/[arXiv]/[unverified].
"""
from __future__ import annotations

import jax.numpy as jnp

from ..models.encdec import EncDecConfig
from ..models.transformer import LMConfig
from ..models.vlm import VLMConfig
from ..nn.attention import AttentionConfig, MLAConfig
from ..nn.ffn import FFNConfig, MoEConfig
from ..nn.rglru import RGLRUConfig
from ..nn.ssm import SSMConfig
from .base import ArchConfig, register

BF16 = jnp.bfloat16


# ---------------------------------------------------------------------------
# mamba2-780m — SSD, attention-free [arXiv:2405.21060; unverified]
# ---------------------------------------------------------------------------
@register("mamba2-780m")
def mamba2_780m() -> ArchConfig:
    def mk(d_model, n_layers, vocab, d_state, chunk=256):
        return LMConfig(
            name="mamba2-780m", vocab=vocab, d_model=d_model, n_layers=n_layers,
            pattern=("ssm",),
            ssm=SSMConfig(d_model, d_state=d_state, head_dim=64, expand=2,
                          chunk=chunk, dtype=BF16),
            tie_embeddings=True, dtype=BF16)
    return ArchConfig(
        name="mamba2-780m", family="lm",
        model=mk(1536, 48, 50280, 128),
        smoke_model=mk(64, 4, 512, 16, chunk=16),
        source="[arXiv:2405.21060; unverified]", sub_quadratic=True,
        strategy="df_zero1",
        notes="attention-free; sequence parallelism inapplicable to the scan "
              "(DESIGN.md §Arch-applicability); d_inner heads shard as filters")


# ---------------------------------------------------------------------------
# qwen3-32b — dense GQA + qk_norm [hf:Qwen/Qwen3-8B; hf]
# ---------------------------------------------------------------------------
@register("qwen3-32b")
def qwen3_32b() -> ArchConfig:
    def mk(d, L, H, KV, hd, ff, vocab):
        return LMConfig(
            name="qwen3-32b", vocab=vocab, d_model=d, n_layers=L,
            attn=AttentionConfig(d, H, KV, hd, qk_norm=True, rope_base=1e6,
                                 dtype=BF16),
            ffn=FFNConfig(d, ff, activation="silu", glu=True, dtype=BF16),
            dtype=BF16)
    return ArchConfig(
        name="qwen3-32b", family="lm",
        model=mk(5120, 64, 64, 8, 128, 25600, 151936),
        smoke_model=mk(64, 2, 4, 2, 16, 128, 512),
        source="[hf:Qwen/Qwen3-8B; hf]")


# ---------------------------------------------------------------------------
# command-r-35b — dense GQA, no-bias [hf:CohereForAI/c4ai-command-r-v01]
# ---------------------------------------------------------------------------
@register("command-r-35b")
def command_r_35b() -> ArchConfig:
    def mk(d, L, H, KV, hd, ff, vocab):
        return LMConfig(
            name="command-r-35b", vocab=vocab, d_model=d, n_layers=L,
            attn=AttentionConfig(d, H, KV, hd, rope_base=8e6, dtype=BF16),
            ffn=FFNConfig(d, ff, activation="silu", glu=True, dtype=BF16),
            norm="layernorm_nobias", tie_embeddings=True, dtype=BF16)
    return ArchConfig(
        name="command-r-35b", family="lm",
        model=mk(8192, 40, 64, 8, 128, 22528, 256000),
        smoke_model=mk(64, 2, 4, 2, 16, 128, 512),
        source="[hf:CohereForAI/c4ai-command-r-v01; unverified]")


# ---------------------------------------------------------------------------
# qwen1.5-4b — dense, QKV bias, kv=heads (MHA) [hf:Qwen/Qwen1.5-0.5B; hf]
# ---------------------------------------------------------------------------
@register("qwen1.5-4b")
def qwen15_4b() -> ArchConfig:
    def mk(d, L, H, KV, hd, ff, vocab):
        return LMConfig(
            name="qwen1.5-4b", vocab=vocab, d_model=d, n_layers=L,
            attn=AttentionConfig(d, H, KV, hd, use_bias=True, dtype=BF16),
            ffn=FFNConfig(d, ff, activation="silu", glu=True, dtype=BF16),
            dtype=BF16)
    return ArchConfig(
        name="qwen1.5-4b", family="lm",
        model=mk(2560, 40, 20, 20, 128, 6912, 151936),
        smoke_model=mk(64, 2, 4, 4, 16, 128, 512),
        source="[hf:Qwen/Qwen1.5-0.5B; hf]",
        shape_strategy={"decode_32k": "serve_seqkv"}, serve_kv_shards=16,
        notes="kv=20 heads: filter-parallel scaling limit p<=20 on attention "
              "(paper Table 3 last column); heads fall back to partial shard")


# ---------------------------------------------------------------------------
# deepseek-67b — dense llama-arch, 95 layers [arXiv:2401.02954; hf]
# ---------------------------------------------------------------------------
@register("deepseek-67b")
def deepseek_67b() -> ArchConfig:
    def mk(d, L, H, KV, hd, ff, vocab):
        return LMConfig(
            name="deepseek-67b", vocab=vocab, d_model=d, n_layers=L,
            attn=AttentionConfig(d, H, KV, hd, dtype=BF16),
            ffn=FFNConfig(d, ff, activation="silu", glu=True, dtype=BF16),
            dtype=BF16)
    return ArchConfig(
        name="deepseek-67b", family="lm",
        model=mk(8192, 95, 64, 8, 128, 22016, 102400),
        smoke_model=mk(64, 3, 4, 2, 16, 128, 512),
        source="[arXiv:2401.02954; hf]",
        notes="95 layers: the best pipeline-parallel candidate (paper §3.4)")


# ---------------------------------------------------------------------------
# whisper-medium — enc-dec, conv frontend stubbed [arXiv:2212.04356]
# ---------------------------------------------------------------------------
@register("whisper-medium")
def whisper_medium() -> ArchConfig:
    full = EncDecConfig(
        name="whisper-medium", vocab=51865, d_model=1024, n_enc_layers=24,
        n_dec_layers=24, n_heads=16, d_ff=4096, max_source_positions=1500,
        max_target_positions=4096, dtype=BF16)
    smoke = EncDecConfig(
        name="whisper-medium", vocab=512, d_model=64, n_enc_layers=2,
        n_dec_layers=2, n_heads=4, d_ff=128, max_source_positions=32,
        max_target_positions=64, dtype=BF16)
    return ArchConfig(
        name="whisper-medium", family="encdec", model=full, smoke_model=smoke,
        source="[arXiv:2212.04356; unverified]",
        notes="conv frontend is a stub: input_specs() provides frame "
              "embeddings; decoder positions clamp at the learned table edge "
              "for the 32k serve shapes")


# ---------------------------------------------------------------------------
# deepseek-v3-671b — MLA + 256-expert MoE + MTP [arXiv:2412.19437; hf]
# ---------------------------------------------------------------------------
@register("deepseek-v3-671b")
def deepseek_v3_671b() -> ArchConfig:
    def mk(d, L, H, vocab, n_exp, d_ff_moe, d_ff_dense, q_rank, kv_rank,
           first_dense, groups):
        return LMConfig(
            name="deepseek-v3-671b", vocab=vocab, d_model=d, n_layers=L,
            pattern=("moe",),
            mla=MLAConfig(d, H, q_lora_rank=q_rank, kv_lora_rank=kv_rank,
                          qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
                          dtype=BF16),
            ffn=FFNConfig(d, d_ff_dense, activation="silu", glu=True, dtype=BF16),
            moe=MoEConfig(d, d_ff_moe, n_experts=n_exp, top_k=8, n_shared=1,
                          shared_d_ff=d_ff_moe, capacity_factor=1.25,
                          router_softmax=False, n_groups=groups, dtype=BF16),
            first_k_dense=first_dense, mtp_heads=1, dtype=BF16)
    return ArchConfig(
        name="deepseek-v3-671b", family="lm",
        model=mk(7168, 61, 128, 129280, 256, 2048, 18432, 1536, 512, 3, 4096),
        smoke_model=LMConfig(
            name="deepseek-v3-671b", vocab=512, d_model=64, n_layers=3,
            pattern=("moe",),
            mla=MLAConfig(64, 4, q_lora_rank=32, kv_lora_rank=16, qk_nope_dim=16,
                          qk_rope_dim=8, v_head_dim=16, dtype=BF16),
            ffn=FFNConfig(64, 128, dtype=BF16),
            moe=MoEConfig(64, 32, n_experts=4, top_k=2, n_shared=1,
                          shared_d_ff=32, capacity_factor=2.0,
                          router_softmax=False, n_groups=2, dtype=BF16),
            first_k_dense=1, mtp_heads=1, dtype=BF16),
        source="[arXiv:2412.19437; hf]", strategy="ep_df",
        notes="MLA latent decode cache; expert parallelism (beyond-paper "
              "strategy) carries the MoE FFN; MTP head depth 1")


# ---------------------------------------------------------------------------
# grok-1-314b — 8-expert top-2 MoE [hf:xai-org/grok-1; unverified]
# ---------------------------------------------------------------------------
@register("grok-1-314b")
def grok_1_314b() -> ArchConfig:
    def mk(d, L, H, KV, hd, ff, vocab, n_exp, groups):
        return LMConfig(
            name="grok-1-314b", vocab=vocab, d_model=d, n_layers=L,
            pattern=("moe",),
            attn=AttentionConfig(d, H, KV, hd, logit_softcap=30.0, dtype=BF16),
            ffn=FFNConfig(d, ff, activation="gelu", glu=True, dtype=BF16),
            moe=MoEConfig(d, ff, n_experts=n_exp, top_k=2,
                          capacity_factor=1.25, activation="gelu", glu=True,
                          n_groups=groups, dtype=BF16),
            final_logit_softcap=30.0, embed_scale=True, tie_embeddings=True,
            dtype=BF16)
    return ArchConfig(
        name="grok-1-314b", family="lm",
        model=mk(6144, 64, 48, 8, 128, 32768, 131072, 8, 4096),
        smoke_model=mk(64, 2, 4, 2, 16, 128, 512, 4, 2),
        source="[hf:xai-org/grok-1; unverified]", strategy="ep_df",
        notes="8 experts: expert-parallel limit p<=8 on the model axis "
              "(paper Table 3 scaling-limit analog); EP8xTP2 folding")


# ---------------------------------------------------------------------------
# recurrentgemma-9b — RG-LRU + local attention 1:2 [arXiv:2402.19427]
# ---------------------------------------------------------------------------
@register("recurrentgemma-9b")
def recurrentgemma_9b() -> ArchConfig:
    def mk(d, L, H, KV, hd, ff, vocab, lru, window, nb):
        return LMConfig(
            name="recurrentgemma-9b", vocab=vocab, d_model=d, n_layers=L,
            pattern=("rec", "rec", "local_attn"),
            local_attn=AttentionConfig(d, H, KV, hd, window=window, dtype=BF16),
            rglru=RGLRUConfig(d, lru, n_blocks=nb, dtype=BF16),
            ffn=FFNConfig(d, ff, activation="gelu_tanh", glu=True, dtype=BF16),
            tie_embeddings=True, embed_scale=True, dtype=BF16)
    return ArchConfig(
        name="recurrentgemma-9b", family="lm",
        model=mk(4096, 38, 16, 1, 256, 12288, 256000, 4096, 2048, 16),
        smoke_model=mk(64, 5, 4, 1, 16, 128, 512, 64, 16, 4),
        source="[arXiv:2402.19427; unverified]", sub_quadratic=True,
        notes="window-2048 ring cache + O(1) RG-LRU state make long_500k "
              "runnable; recurrence serializes seq (no sequence parallelism)")


# ---------------------------------------------------------------------------
# paligemma-3b — SigLIP stub + gemma backbone [arXiv:2407.07726; hf]
# ---------------------------------------------------------------------------
@register("paligemma-3b")
def paligemma_3b() -> ArchConfig:
    def mk_lm(d, L, H, KV, hd, ff, vocab):
        return LMConfig(
            name="paligemma-3b", vocab=vocab, d_model=d, n_layers=L,
            attn=AttentionConfig(d, H, KV, hd, dtype=BF16),
            ffn=FFNConfig(d, ff, activation="gelu_tanh", glu=True, dtype=BF16),
            tie_embeddings=True, embed_scale=True, dtype=BF16)
    return ArchConfig(
        name="paligemma-3b", family="vlm",
        model=VLMConfig(lm=mk_lm(2048, 18, 8, 1, 256, 16384, 257216),
                        d_vision=1152, n_patches=256),
        smoke_model=VLMConfig(lm=mk_lm(64, 2, 4, 1, 16, 128, 512),
                              d_vision=48, n_patches=8),
        source="[arXiv:2407.07726; hf]",
        shape_strategy={"decode_32k": "serve_seqkv"}, serve_kv_shards=16,
        notes="SigLIP tower stubbed: input_specs() supplies patch embeddings; "
              "MQA (kv=1) → KV replicated across the model axis, cost modeled "
              "by the oracle")
