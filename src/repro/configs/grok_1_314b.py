"""Config for --arch grok-1-314b (see lm_archs.py for the definition)."""
from .base import get_config


def config():
    return get_config("grok-1-314b")
