"""Config for --arch whisper-medium (see lm_archs.py for the definition)."""
from .base import get_config


def config():
    return get_config("whisper-medium")
