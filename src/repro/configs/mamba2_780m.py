"""Config for --arch mamba2-780m (see lm_archs.py for the definition)."""
from .base import get_config


def config():
    return get_config("mamba2-780m")
