"""Session facade: ``Oracle(arch, shape, cluster)`` — one object from
calibration to deployment (DESIGN.md §11).

The paper's workflow is a loop: describe the machine, project strategies,
pick a plan, deploy it, measure, and feed the measurements back into the
machine description. Before this module each arrow was a differently-shaped
function call (stats + TimeModel + OracleConfig threaded positionally
through ``project``/``sweep``/``autotune``/``build_cell``/``validate``);
the session binds (arch × input shape × ClusterSpec) once and exposes the
loop as methods:

    from repro.api import Oracle
    ses  = Oracle("resnet50", "train_4k", "paper")
    proj = ses.project("df", 64)          # one Table-3 row
    res  = ses.sweep([8, 64, 1024])       # the vectorized lattice
    plan = ses.tune(64)                   # cheapest deployable TunedPlan
    cell = ses.build(mesh)                # deploy the plan on a mesh
    pts  = ses.validate(mesh)             # measured vs projected (Fig. 3)
    fit  = ses.calibrate(mesh)            # fitted ClusterSpec (α/β/φ/σ) —
                                          # applied to the session, so the
                                          # next .project() uses it

Swapping machines is one argument: ``Oracle(arch, shape, "tpu")`` vs a
fitted ``ClusterSpec.from_json("experiments/cluster_fit.json")`` vs a
topology-constrained ``replace(spec, topology=Torus((4, 2)))`` — and the
tuner prunes p1·p2 factorizations the torus cannot host instead of
deploying them.

Everything delegates to the same engines the legacy entry points use
(core/oracle, core/sweep, core/autotune, core/validation, launch/build),
so session results are bit-identical (≤1e-12) to the legacy calls —
enforced by ``python -m repro.api --parity`` and tests/test_api.py.

CLI:  python -m repro.api --smoke        # project→tune→build→dryrun smoke
      python -m repro.api --parity       # session ↔ legacy parity gate
      python -m repro.api --calibrate --out experiments/cluster_fit.json
      python -m repro.api --tune-kernels # Pallas block-size autotune
      python -m repro.api --calibrate --tune-kernels   # fit, then tune
                                         # under the fitted ClusterSpec

Module-level imports stay jax-free so the CLI can set XLA_FLAGS (virtual
host devices) before any platform initialization.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from .core.cluster import ClusterSpec, Torus  # noqa: F401 (re-export)

_SES_DEFAULT_CLUSTER = "tpu"     # the deployment target plan_for_arch assumes


class Oracle:
    """One oracle session over (arch × input shape × ClusterSpec).

    ``arch``: a registered arch name (``repro.configs.get_config``) or an
    ``ArchConfig``. ``shape``: a ``SHAPES`` name (default ``train_4k``) or
    a ``ShapeSpec``. ``cluster``: a ClusterSpec | preset name
    ("paper"/"tpu"/"host") | SystemModel; defaults to the TPU deployment
    target, matching ``plan_for_arch``. ``batch``/``dataset`` override the
    shape's global batch / samples-per-epoch (both default to one
    iteration per epoch, so projections rank per-iteration time);
    remaining keywords (``overlap``, ``segments``, ``zero1`` …) flow into
    the session's ``OracleConfig``.
    """

    def __init__(self, arch, shape: str = "train_4k", cluster=None, *,
                 smoke: bool = False, batch: int | None = None,
                 dataset: int | None = None, seq: int | None = None,
                 mem_cap: float | None = None, **oracle_kw):
        from .configs.base import SHAPES
        from .core.autotune import stats_for_model
        self.arch_cfg = self._resolve_arch(arch)
        self.shape = SHAPES[shape] if isinstance(shape, str) else shape
        self.smoke = smoke
        self.model_cfg = (self.arch_cfg.smoke_model if smoke
                          else self.arch_cfg.model)
        self.seq = seq or self.shape.seq_len
        self.stats = stats_for_model(self.model_cfg, self.seq)
        self.B = batch or self.shape.global_batch
        self.D = dataset or self.B
        self.mem_cap = mem_cap
        self._oracle_kw = dict(oracle_kw)
        self._bind(ClusterSpec.coerce(cluster) or
                   ClusterSpec.of(_SES_DEFAULT_CLUSTER))

    @staticmethod
    def _resolve_arch(arch):
        from .configs import get_config
        return get_config(arch) if isinstance(arch, str) else arch

    def _bind(self, cluster: ClusterSpec) -> None:
        """(Re)derive the projection state from a machine description —
        the one place TimeModel/OracleConfig are built."""
        from .core.oracle import TimeModel
        self.cluster = cluster
        self.tm = TimeModel(cluster.system)
        self.cfg = cluster.oracle_config(B=self.B, D=self.D,
                                         **self._oracle_kw)
        # tuned Pallas tiles are fingerprint-keyed to the machine: any
        # rebind (calibrate/with_cluster) invalidates the session's copy —
        # tune_kernels() on the new description repopulates it
        self._kernel_tiles = None

    def with_cluster(self, cluster) -> "Oracle":
        """A new session on a different machine — everything else shared."""
        ses = object.__new__(Oracle)
        ses.__dict__.update(self.__dict__)
        ses._oracle_kw = dict(self._oracle_kw)
        ses._bind(ClusterSpec.coerce(cluster))
        return ses

    # -- projection ----------------------------------------------------------

    def project(self, strategy: str, p: int, p1: int | None = None,
                p2: int | None = None):
        """One Table-3 row at p PEs (oracle.project on the session state)."""
        from .core.oracle import project
        return project(strategy, self.stats, self.tm, self.cfg, p,
                       p1=p1, p2=p2)

    def project_all(self, p: int, strategies=None):
        from .core.oracle import STRATEGY_NAMES, project_all
        return project_all(self.stats, self.tm, self.cfg, p,
                           strategies or STRATEGY_NAMES)

    def sweep(self, p_grid, strategies=None, **kw):
        """The vectorized strategy × p × p1·p2 lattice; the session's
        cluster topology prunes unhostable splits (sweep(cluster=...))."""
        from .core.oracle import STRATEGY_NAMES
        from .core.sweep import sweep
        kw.setdefault("cluster", self.cluster)
        return sweep(self.stats, self.tm, self.cfg, p_grid,
                     strategies or STRATEGY_NAMES, **kw)

    def advise(self, p: int, **kw):
        from .core.advisor import advise
        kw.setdefault("mem_cap", self.mem_cap)
        kw.setdefault("cluster", self.cluster)
        return advise(self.stats, self.tm, self.cfg, p, **kw)

    def roofline_hw(self):
        """This cluster as a roofline HardwareSpec (dry-run cross-checks)."""
        from .core.roofline import HardwareSpec
        return HardwareSpec.from_cluster(self.cluster)

    # -- serving -------------------------------------------------------------

    def serve_project(self, traffic, p: int, *, strategy: str = "serve_tp",
                      p2: int | None = None, kv_shards: int | None = None,
                      max_batch: int = 8, **kw):
        """One serving row priced under ``traffic`` (a TrafficModel):
        TTFT / latency p50/p99 and token throughput from the session's
        machine description (serve/oracle.py, DESIGN.md §15)."""
        from .serve.oracle import price_serving
        p2 = p2 or p
        kv = kv_shards if kv_shards is not None else (
            1 if strategy == "serve_tp" else p2)
        return price_serving(self.model_cfg, self.cluster, strategy,
                             p // p2, p2, kv, max_batch, traffic, **kw)

    def serve_sweep(self, traffic, p: int, **kw):
        """Every (strategy, p1·p2, kv_shards, max_batch) serving row."""
        from .serve.oracle import serve_sweep
        return serve_sweep(self.model_cfg, self.cluster, p, traffic, **kw)

    def serve_tune(self, traffic, p: int, slo_p99: float, **kw):
        """Highest-throughput serving plan meeting the p99 SLO (ServePlan;
        ``meets_slo=False`` + least-bad row when nothing does)."""
        from .serve.oracle import serve_tune
        return serve_tune(self.model_cfg, self.cluster, p, traffic,
                          slo_p99, **kw)

    # -- decision ------------------------------------------------------------

    def tune(self, p: int, *, switches="all",
             model_width: int | None = None,
             allow_pipeline: bool | None = None):
        """Cheapest deployable (strategy, p1·p2, switches, schedule)
        TunedPlan at p, honoring the cluster's torus topology (infeasible
        factorizations are pruned, not silently deployed). Pipeline plans
        carry the priced schedule (gpipe / 1F1B / interleaved) in
        ``plan.schedule``. ``allow_pipeline=False`` bars the pipeline
        strategy (the elastic controller's rebind path deploys plain SPMD
        steps only — runtime/elastic.py)."""
        from .core.autotune import plan_for_arch
        plan = plan_for_arch(self.arch_cfg, self.shape.name, p,
                             cluster=self.cluster, cfg=self.cfg,
                             stats=self.stats,
                             smoke=self.smoke, mem_cap=self.mem_cap,
                             switches=switches, model_width=model_width,
                             allow_pipeline=allow_pipeline)
        if self._kernel_tiles is not None:
            # tuned blocks ride with the plan so deploy (build_cell →
            # ShardingCtx → HaloConv) uses what the tuner measured
            import dataclasses
            plan = dataclasses.replace(plan, kernel_tiles=self._kernel_tiles)
        return plan

    def tune_kernels(self, *, shapes="full", path=None, **kw):
        """Tune Pallas block sizes for THIS cluster (kernels/autotune):
        analytic prune from ``HardwareSpec.from_cluster``, measure the
        survivors, persist winners to ``path`` (default the committed
        experiments/kernel_tune.json; "" skips persisting) stamped with
        the cluster fingerprint. The session keeps the resulting
        ``KernelTiles`` so subsequent ``tune()`` plans carry them into
        deployment; re-binding the cluster (``calibrate``/``with_cluster``)
        drops them — stale tiles never outlive the machine description."""
        from .kernels.autotune import tune_kernels
        cache = tune_kernels(self.cluster, shapes=shapes, path=path, **kw)
        self._kernel_tiles = cache.tiles() if cache.entries else None
        return cache

    # -- deployment ----------------------------------------------------------

    def build(self, mesh, plan=None, **kw):
        """Deploy a plan (default: ``tune()`` at the mesh's device count,
        constrained to its model width) as a BuiltCell — step fn + sharded
        abstract inputs, via launch.build.build_cell."""
        from .launch.build import build_cell, mesh_device_count
        if plan is None:
            plan = self.tune(mesh_device_count(mesh),
                             model_width=None if mesh is None
                             else mesh.shape.get("model"))
        # passing the cluster lets build_cell fingerprint-check any tuned
        # kernel-tile artifact it falls back to loading
        kw.setdefault("system", self.cluster)
        return build_cell(self.arch_cfg, self.shape.name, mesh, "auto",
                          smoke=self.smoke, plan=plan, **kw)

    def dryrun(self, mesh=None, plan=None, **kw):
        """Build, lower and compile the cell (proves the plan deploys);
        returns the plan + compiled memory analysis."""
        import jax
        from .launch.mesh import make_host_mesh
        mesh = mesh if mesh is not None else make_host_mesh()
        cell = self.build(mesh, plan=plan, **kw)
        compiled = jax.jit(cell.step_fn).lower(*cell.args).compile()
        ma = compiled.memory_analysis()
        return {
            "arch": cell.arch, "shape": cell.shape, "kind": cell.kind,
            "strategy": cell.strategy, "plan": cell.meta.get("plan"),
            "mesh": {k: int(v) for k, v in mesh.shape.items()},
            "memory": {"args_gib": ma.argument_size_in_bytes / 2 ** 30,
                       "temp_gib": ma.temp_size_in_bytes / 2 ** 30,
                       "out_gib": ma.output_size_in_bytes / 2 ** 30},
        }

    # -- measurement (closing the loop) --------------------------------------

    def _measured_setup(self, mesh, batch_size=None, seq=None):
        """Reduced model + synthetic batch for measured runs (always the
        smoke config — full configs don't fit host devices)."""
        from .core.autotune import stats_for_model
        from .data.pipeline import ShardedLoader
        from .launch.build import build_model
        from .launch.train import data_config_for
        mc = self.arch_cfg.smoke_model
        model = build_model(self.arch_cfg, smoke=True)
        b = batch_size or max(int(mesh.size), 8)
        S = seq or min(self.seq, 128)
        loader = ShardedLoader(data_config_for(mc, b, S), mesh)
        batch = loader.batch_at(0)
        stats = stats_for_model(mc, S)
        flops = float(sum(s.flops_fwd for s in stats))
        return model, mc, batch, b, S, flops

    def validate(self, mesh, strategies=("data",), *, batch_size=None,
                 seq=None, use_cluster: bool = False):
        """Measure vs project each strategy at p = mesh size (paper Fig. 3)
        on the reduced model. Default recalibrates the host in place (the
        legacy path); ``use_cluster=True`` projects with THIS session's
        cluster instead — pair with ``calibrate()`` to check the fitted
        description against fresh measurements."""
        from .core.validation import validate
        model, mc, batch, b, S, flops = self._measured_setup(
            mesh, batch_size, seq)
        # project under the SAME model the session's projections use: the
        # cluster's φ/σ tables plus any per-session OracleConfig overrides
        # (overlap=False, phi_hybrid, segments, ...)
        kw = {**self.cluster.oracle_kw(), **self._oracle_kw}
        return validate(model, mc, batch, mesh, strategies,
                        flops_per_sample=flops, B=b, S=S,
                        oracle_cfg_kw=kw,
                        cluster=self.cluster if use_cluster else None)

    def calibrate(self, mesh=None, *, apply: bool = True,
                  compute: bool = True, batch_size: int = 8,
                  seq: int | None = None):
        """Run the measurement harness (core/calibration.calibrate_cluster)
        on a mesh: α/β per axis, contention φ, overlap σ — and compute
        efficiency from a serial step of the reduced model when
        ``compute``. Returns the fitted ClusterSpec; with ``apply`` (the
        default) the session rebinds to it, so subsequent projections use
        the measured machine. The raw measurements are kept on
        ``self.last_measurements`` for the JSON artifact."""
        from .core.calibration import calibrate_cluster
        from .launch.mesh import make_host_mesh
        mesh = mesh if mesh is not None else make_host_mesh()
        kw = {}
        if compute:
            import jax
            from .nn.module import tree_init
            model, mc, batch, b, S, flops = self._measured_setup(
                mesh, batch_size, seq)
            params = tree_init(model.params_spec(), jax.random.PRNGKey(0))
            kw = dict(loss_fn=lambda p_, b_: model.loss_fn(p_, b_),
                      params=params, batch=batch,
                      flops_per_step=flops * b)
        spec, ms = calibrate_cluster(mesh, base=self.cluster, **kw)
        self.last_measurements = ms
        if apply:
            self._bind(spec)
        return spec

    def describe(self) -> str:
        return (f"Oracle[{self.arch_cfg.name} × {self.shape.name}"
                f"{' (smoke)' if self.smoke else ''}] B={self.cfg.B} "
                f"D={self.cfg.D}\n{self.cluster.describe()}")


# ---------------------------------------------------------------------------
# CLI: smoke / parity / calibrate
# ---------------------------------------------------------------------------

def _smoke(devices: int) -> int:
    """Session smoke (check.sh gate): project → tune → build → dryrun on
    the cpu_host_model cluster, virtual host devices."""
    ses = Oracle("qwen1.5-4b", "train_4k", "host", smoke=True,
                 batch=8, seq=128)
    print(ses.describe())
    p = devices
    proj = ses.project("data", p)
    assert proj.total_s > 0 and proj.feasible, proj
    plan = ses.tune(p)
    print(plan.describe())
    assert plan.p == p and plan.p1 * plan.p2 == p
    # the sweep sees the same numbers the per-point path printed
    import numpy as np
    res = ses.sweep([p], ("data",), switches=None)
    i = int(np.flatnonzero((res.p1 == proj.p1) & (res.p2 == proj.p2))[0])
    assert abs(res.total_s[i] - proj.total_s) <= 1e-12 * abs(proj.total_s)
    out = ses.dryrun()   # host mesh; compiles the deployed step
    print(f"dryrun: strategy={out['strategy']} mesh={out['mesh']} "
          f"args={out['memory']['args_gib']:.3f}GiB "
          f"temp={out['memory']['temp_gib']:.3f}GiB")
    assert out["plan"] is not None and out["kind"] == "train"
    print("repro.api --smoke OK")
    return 0


def _parity() -> int:
    """Legacy ↔ session parity gate (check.sh): the PR-5 deprecation shims
    are fully retired (the parsers live in core.cluster only), and session
    results match the legacy signatures to ≤1e-12."""
    import numpy as np

    from .core import advisor, oracle, sweep as sweep_mod
    from .core.autotune import autotune, plan_for_arch
    from .core.hardware import PAPER_V100_CLUSTER
    from .core.layer_stats import stats_for
    from .core.sweep import sweep as legacy_sweep
    from .models.cnn import RESNET50

    # 1. the PR-5 shims are gone for good: sweep must NOT re-grow the
    # parser names, and the canonical core.cluster parsers behave
    for name in ("parse_phi_table", "parse_sigma_table"):
        assert not hasattr(sweep_mod, name), \
            f"retired shim sweep.{name} came back"
    from .core.cluster import parse_phi_table, parse_sigma_table
    assert parse_phi_table("data=2.0,model=1.2") == (("data", 2.0),
                                                     ("model", 1.2))
    assert parse_sigma_table("model=0.5") == (("model", 0.5),)

    # 2. numeric parity: session vs legacy call signatures
    stats = stats_for(RESNET50)
    tm = oracle.TimeModel(PAPER_V100_CLUSTER)
    worst = 0.0
    for p in (8, 64, 1024):
        cfg = oracle.OracleConfig(B=2 * p, D=1_281_167)
        ses = Oracle("resnet50", "train_4k", "paper", batch=2 * p,
                     dataset=1_281_167)
        for s in ("data", "df", "filter", "spatial"):
            a = oracle.project(s, stats, tm, cfg, p).total_s
            b = ses.project(s, p).total_s
            worst = max(worst, abs(a - b) / max(abs(a), 1e-30))
        ra = legacy_sweep(stats, tm, cfg, [p])
        rb = ses.sweep([p])
        assert len(ra) == len(rb)
        worst = max(worst, float(np.max(
            np.abs(ra.total_s - rb.total_s) /
            np.maximum(np.abs(ra.total_s), 1e-30))))
        reca = advisor.advise(stats, tm, cfg, p)
        recb = ses.advise(p)
        assert reca.best.strategy == recb.best.strategy
        worst = max(worst, abs(reca.best.total_s - recb.best.total_s)
                    / abs(reca.best.total_s))
        # the legacy tuner and the session agree on the same cfg
        pa = autotune(stats, tm, cfg, p, allow_pipeline=False)
        pb = autotune(stats, tm, cfg, p, allow_pipeline=False,
                      cluster=ses.cluster)
        assert pa == pb, (pa, pb)
    # 3. tune parity against the legacy plan_for_arch signature
    from .configs import get_config
    for p in (8, 64):
        want = plan_for_arch(get_config("resnet50"), "train_4k", p)
        got = Oracle("resnet50", "train_4k").tune(p)
        assert want == got, (want, got)
    assert worst <= 1e-12, f"session/legacy drift {worst:.2e}"
    print(f"repro.api --parity OK (max rel drift {worst:.2e})")
    return 0


def _chaos(devices: int) -> int:
    """Chaos smoke (check.sh chaos-gate; DESIGN.md §12): kill a torus slice
    mid-run and prove the elastic loop end-to-end — the tuner re-plans on
    the surviving ClusterSpec, the checkpoint reshards plan-to-plan, and
    the resumed loss trajectory is bit-exact vs an uninterrupted baseline
    (prefix) and vs a clean continuation planned on the degraded machine
    (suffix): recovery ≡ planned reshape, bit for bit.

    Self-contained (no tests/ imports): a tiny uniform LM on the virtual
    host mesh, a (2,4) torus losing dim 0 → a (4)-torus at step 10 of 16.
    The richer scenario matrix lives in tests/test_chaos.py.
    """
    import tempfile
    from dataclasses import replace as _replace

    import jax
    import jax.numpy as jnp
    import numpy as np

    from .checkpoint.checkpointing import Checkpointer
    from .configs.base import SHAPES, ArchConfig, ShapeSpec
    from .data.pipeline import DataConfig
    from .models import LMConfig, TransformerLM
    from .nn import AttentionConfig, FFNConfig
    from .optim.optimizers import OptimizerConfig
    from .runtime.elastic import bind_plan, run_elastic
    from .runtime.fault_tolerance import SliceLost
    from .training.steps import train_state_spec

    V, D, L, B, S, N, KILL = 64, 32, 2, 8, 32, 16, 10
    mc = LMConfig(name="t", vocab=V, d_model=D, n_layers=L,
                  attn=AttentionConfig(D, 4, 2, 8, dtype=jnp.float32),
                  ffn=FFNConfig(D, 2 * D, dtype=jnp.float32),
                  dtype=jnp.float32)
    model = TransformerLM(mc)
    SHAPES["train_tiny"] = ShapeSpec("train_tiny", S, B, "train")
    acfg = ArchConfig(name="chaos-smoke", family="lm", model=mc,
                      smoke_model=mc, source="chaos", strategy="df")
    cluster = _replace(ClusterSpec.of("host"),
                       topology=Torus((2, 4), model_dims=(1,)))
    ses = Oracle(acfg, "train_tiny", cluster, batch=B, seq=S)
    data_cfg = DataConfig("lm", batch=B, seq_len=S, vocab=V)
    opt = OptimizerConfig(lr=1e-2, name="adamw", zero1=False)
    fwd = dict(attn_impl="plain", scan_layers=False, remat=False)

    def run(inject, ckpt):
        traj = {}
        state, step, events = run_elastic(
            ses, data_cfg, ckpt, n_steps=N, model=model, opt=opt,
            ckpt_every=4, inject=inject, fwd_kw=fwd, seed=0,
            on_metrics=lambda s, m: traj.__setitem__(s, float(m["loss"])))
        params = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                              state["params"])
        return traj, events, params

    fired = set()

    def kill(step):
        if step == KILL and step not in fired:
            fired.add(step)
            raise SliceLost(step, dim=0, reason="injected slice death")

    with tempfile.TemporaryDirectory() as da, \
            tempfile.TemporaryDirectory() as db:
        ck_a, ck_b = Checkpointer(da, keep=10), Checkpointer(db, keep=10)
        traj_a, ev_a, _ = run(None, ck_a)          # uninterrupted baseline
        traj_b, ev_b, params_b = run(kill, ck_b)   # chaos run
        assert ev_a == [] and len(ev_b) == 1, (ev_a, ev_b)
        ev = ev_b[0]
        assert (ev.p_before, ev.p_after) == (8, 4), ev
        # the re-tuned plan is valid on the shrunken topology
        degraded = cluster.degraded(dim=0)
        assert degraded.topology.size == 4
        p1, p2 = ev.mesh_shape
        assert p1 * p2 == 4, ev
        assert bool(degraded.topology.split_mask(4, p1, p2, ev.strategy)), ev
        resumed = ev.resumed_from
        assert 0 < resumed <= KILL and resumed % 4 == 0, ev
        # prefix: bit-exact vs the uninterrupted run (same mesh, same plan)
        for s in range(resumed):
            assert traj_b[s] == traj_a[s], (s, traj_b[s], traj_a[s])
        # suffix: bit-exact vs a PLANNED degraded continuation from the
        # baseline's own checkpoint — recovery ≡ planned reshape
        b2 = bind_plan(ses.with_cluster(degraded), jax.devices()[:4],
                       data_cfg, model, opt, fwd)
        st, s0 = ck_a.restore(train_state_spec(model, opt), step=resumed,
                              shardings=b2.shardings)
        for s in range(s0, N):
            st, m = b2.step_fn(st, b2.loader.batch_at(s))
            assert traj_b[s] == float(m["loss"]), (s, traj_b[s],
                                                   float(m["loss"]))
        jax.tree.map(
            np.testing.assert_array_equal, params_b,
            jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                         st["params"]))
    print(f"repro.api --chaos OK (slice death @ step {KILL}: p 8→4 on "
          f"{degraded.topology}, re-tuned {ev.strategy} {p1}x{p2}, resumed "
          f"@ {resumed}; trajectory + final params bit-exact vs planned "
          f"reshape)")
    return 0


def _calibrate(out: str | None, devices: int) -> int:
    import platform

    import jax

    from .launch.mesh import make_host_mesh
    mesh = make_host_mesh()
    ses = Oracle("resnet50", "train_4k", "host", smoke=True)
    spec = ses.calibrate(mesh)
    print(spec.describe())
    print("fit residuals:", dict(spec.fit_residuals))
    if out:
        rec = spec.to_json()
        rec["meta"] = {
            "harness": "python -m repro.api --calibrate",
            "mesh": {k: int(v) for k, v in mesh.shape.items()},
            "devices": devices, "backend": jax.default_backend(),
            "host": platform.machine(),
            "jax": jax.__version__,
        }
        rec["measurements"] = [m.to_json() for m in ses.last_measurements]
        with open(out, "w") as f:
            json.dump(rec, f, indent=1)
        print(f"wrote {out}")
        # the artifact round-trips into a usable ClusterSpec
        again = ClusterSpec.from_json(out)
        assert again.level("data").alpha == spec.level("data").alpha
    return 0


def _tune_kernels(shapes: str, out: str | None, devices: int,
                  calibrate: bool) -> int:
    """--tune-kernels gate: prune → measure → cache, then assert the
    artifact's invariant (winner never worse than the measured default —
    holds by construction, pinned here so CI notices if it ever breaks)."""
    ses = Oracle("resnet50", "train_4k", smoke=True)   # default: tpu target
    if calibrate:
        # compose: fit the machine description first, tune under the fit
        # (the session rebind drops any stale tiles before tuning)
        from .launch.mesh import make_host_mesh
        spec = ses.calibrate(make_host_mesh())
        print(f"calibrated {spec.name}: fingerprint {spec.fingerprint()}")
    cache = ses.tune_kernels(shapes=shapes, path=out, verbose=True)
    for key, e in sorted(cache.entries.items()):
        assert e["measured_us"] <= e["default_us"] + 1e-9, \
            f"tuned slower than default for {key}: {e}"
        print(f"  {key}: {e['blocks']} "
              f"{e['measured_us']:.1f}us (default {e['default_us']:.1f}us)")
    from .kernels.autotune import DEFAULT_TUNE_PATH
    print(f"repro.api --tune-kernels OK ({len(cache.entries)} entries, "
          f"cluster {cache.cluster_name} fp {cache.fingerprint}, "
          f"wrote {out or DEFAULT_TUNE_PATH})")
    return 0


def _serve_tune(arch: str, p: int, rate: float, prompt: int, gen: int,
                slo_ms: float, max_len: int | None, cluster: str) -> int:
    """--serve-tune gate: price the serving sweep and print the plan; exit
    non-zero when no configuration meets the stated p99 SLO."""
    from .serve.traffic import TrafficModel
    # pricing is analytic — the FULL model config costs nothing to price
    ses = Oracle(arch, cluster=cluster)
    traffic = TrafficModel(rate=rate, prompt_len=prompt, gen_len=gen)
    plan = ses.serve_tune(traffic, p, slo_ms / 1e3, max_len=max_len)
    print(f"serving sweep: {ses.arch_cfg.name} on {ses.cluster.name}, "
          f"p={p}, rate={rate}/s, prompt={prompt}, gen={gen}")
    print(plan.describe())
    shown = 0
    for row in plan.rows:
        if row is plan.winner or row is plan.runner_up:
            continue
        print("  " + row.describe())
        shown += 1
        if shown >= 8:
            break
    print(f"repro.api --serve-tune {'OK' if plan.meets_slo else 'SLO-MISS'}")
    return 0 if plan.meets_slo else 1


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.api",
        description="Oracle session facade utilities (DESIGN.md §11).")
    ap.add_argument("--smoke", action="store_true",
                    help="project→tune→build→dryrun on cpu_host_model "
                         "(CI gate)")
    ap.add_argument("--parity", action="store_true",
                    help="shim-retirement + session↔legacy 1e-12 parity "
                         "gate (CI gate)")
    ap.add_argument("--calibrate", action="store_true",
                    help="run the measurement harness on the host mesh and "
                         "fit a ClusterSpec (α/β, φ, σ per level)")
    ap.add_argument("--chaos", action="store_true",
                    help="elastic-training chaos smoke: kill a simulated "
                         "torus slice mid-run, re-tune on the surviving "
                         "ClusterSpec, reshard plan-to-plan, and pin the "
                         "resumed trajectory bit-exact (DESIGN.md §12)")
    ap.add_argument("--tune-kernels", action="store_true",
                    help="tune Pallas block sizes for the session cluster "
                         "(kernels/autotune): analytic prune → measure → "
                         "cache winners keyed by cluster fingerprint. "
                         "Composes with --calibrate (fit first, tune under "
                         "the fitted ClusterSpec)")
    ap.add_argument("--tune-shapes", choices=("full", "smoke"),
                    default="full",
                    help="--tune-kernels shape set: 'full' = the bench "
                         "shapes (the committed artifact), 'smoke' = tiny "
                         "CI shapes")
    ap.add_argument("--out", default=None,
                    help="output JSON path: the fitted-cluster artifact "
                         "(--calibrate) or the tuned-kernel artifact "
                         "(--tune-kernels; default "
                         "experiments/kernel_tune.json)")
    ap.add_argument("--devices", type=int, default=8,
                    help="virtual host device count for --smoke/--calibrate/"
                         "--chaos")
    ap.add_argument("--serve-tune", action="store_true",
                    help="price the serving sweep (serve/oracle.py) and "
                         "print the cheapest plan meeting --slo-ms; exits "
                         "1 on an SLO miss (DESIGN.md §15)")
    ap.add_argument("--arch", default="qwen3-32b",
                    help="--serve-tune arch (any registered config)")
    ap.add_argument("--p", type=int, default=8,
                    help="--serve-tune deployment size (PEs)")
    ap.add_argument("--rate", type=float, default=8.0,
                    help="--serve-tune arrival rate, requests/s")
    ap.add_argument("--prompt", type=int, default=512,
                    help="--serve-tune mean prompt length")
    ap.add_argument("--gen", type=int, default=128,
                    help="--serve-tune generation length")
    ap.add_argument("--slo-ms", type=float, default=30000.0,
                    help="--serve-tune p99 request-latency SLO (ms)")
    ap.add_argument("--max-len", type=int, default=None,
                    help="--serve-tune KV capacity per sequence "
                         "(default: prompt+gen rounded up)")
    ap.add_argument("--cluster", default="tpu",
                    help="--serve-tune machine description preset "
                         "(tpu | paper | host | a ClusterSpec JSON path)")
    args = ap.parse_args(argv)
    if args.serve_tune:
        return _serve_tune(args.arch, args.p, args.rate, args.prompt,
                           args.gen, args.slo_ms, args.max_len,
                           args.cluster)
    if args.smoke or args.calibrate or args.chaos or args.tune_kernels:
        # must precede any jax import (the module header stays jax-free)
        os.environ.setdefault(
            "XLA_FLAGS",
            f"--xla_force_host_platform_device_count={args.devices}")
    if args.parity:
        return _parity()
    if args.tune_kernels:
        return _tune_kernels(args.tune_shapes, args.out, args.devices,
                             args.calibrate)
    if args.calibrate:
        return _calibrate(args.out, args.devices)
    if args.chaos:
        return _chaos(args.devices)
    if args.smoke:
        return _smoke(args.devices)
    ap.print_help()
    return 2


if __name__ == "__main__":
    sys.exit(main())
