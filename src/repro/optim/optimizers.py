"""Optimizers: SGD(+momentum) and AdamW, with ZeRO-1 sharded states.

States are declared as ParamSpec trees (same logical axes as their params) so
they ride the same rules tables. Under ZeRO-1 the states claim the *data*
axis on their first free dimension: XLA then reduce-scatters gradients into
the state sharding, updates locally, and all-gathers fresh params — the
paper's §5.3.3 "shard the weight update among GPUs" ([52] Xu et al.)
realized through shardings alone.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from ..nn.module import ParamSpec, Rules, param, tree_map_spec


@dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"            # "adamw" | "sgd"
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    momentum: float = 0.9          # sgd
    grad_clip: float = 1.0
    zero1: bool = True


def state_spec(opt: OptimizerConfig, params_spec):
    """ParamSpec tree(s) for optimizer state, fp32, same logical axes."""

    def clone(s: ParamSpec) -> ParamSpec:
        return param(s.shape, s.axes, init=lambda k, sh, d: jnp.zeros(sh, d),
                     dtype=jnp.float32)

    if opt.name == "adamw":
        return {"m": tree_map_spec(clone, params_spec),
                "v": tree_map_spec(clone, params_spec)}
    if opt.name == "sgd":
        return {"mom": tree_map_spec(clone, params_spec)}
    raise ValueError(opt.name)


def zero1_rules(rules: Rules) -> Rules:
    """Extend strategy rules so optimizer states shard over the data axis.

    State tensors reuse the parameter logical axes; mapping the axes that are
    free under the base strategy onto "data" shards the states p-ways (ZeRO-1).
    """
    extra = {}
    for ax in ("embed", "vocab", "mlp", "heads", "conv_in", "conv_k", "layers"):
        if rules.get(ax) is None:
            extra[ax] = "data"
    return rules.merged(extra)


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def apply_update(opt: OptimizerConfig, params, grads, state, step):
    """Pure update: returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, opt.grad_clip)
    count = step.astype(jnp.float32) + 1.0

    if opt.name == "adamw":
        b1, b2 = opt.b1, opt.b2

        def upd(p, g, m, v):
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            mhat = m / (1 - b1 ** count)
            vhat = v / (1 - b2 ** count)
            step_ = opt.lr * (mhat / (jnp.sqrt(vhat) + opt.eps)
                              + opt.weight_decay * p.astype(jnp.float32))
            return (p.astype(jnp.float32) - step_).astype(p.dtype), m, v

        out = jax.tree.map(upd, params, grads, state["m"], state["v"])
        new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"m": new_m, "v": new_v}, {"grad_norm": gnorm}

    if opt.name == "sgd":
        def upd(p, g, mom):
            mom = opt.momentum * mom + g
            return (p.astype(jnp.float32) - opt.lr * mom).astype(p.dtype), mom

        out = jax.tree.map(upd, params, grads, state["mom"])
        new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_mom = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"mom": new_mom}, {"grad_norm": gnorm}

    raise ValueError(opt.name)
