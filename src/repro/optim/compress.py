"""Gradient compression for the DP all-reduce (beyond-paper trick).

int8 quantization with per-tensor scale and error feedback (residual carried
to the next step — 1-bit-SGD lineage, paper ref [43] Seide et al.). Halves →
quarters the GE wire bytes the oracle's data-parallel row charges; the
EXPERIMENTS.md §Perf log quantifies the effect on the collective term.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..launch.compat import axis_size


def quantize_int8(g, residual=None):
    """→ (q int8, scale, new_residual). Error feedback keeps the quantization
    noise from biasing the update."""
    gf = g.astype(jnp.float32)
    if residual is not None:
        gf = gf + residual
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    new_res = gf - q.astype(jnp.float32) * scale
    return q, scale, new_res


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compressed_mean(tree, axis_name: str, residuals=None):
    """psum of int8-compressed gradients over ``axis_name`` (inside shard_map).

    Accumulates in int32 (no overflow below ~2^23 summands), then rescales.
    Returns (mean_tree, residual_tree).
    """
    n = axis_size(axis_name)

    def one(g, r):
        gf = g.astype(jnp.float32) + (r if r is not None else 0.0)
        # agree on one scale across ranks, THEN quantize: the int32 sum is
        # exact, so the only error is the (error-fed-back) rounding step
        gmax = jax.lax.pmax(jnp.max(jnp.abs(gf)), axis_name)
        scale = jnp.maximum(gmax, 1e-12) / 127.0
        q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
        res = gf - q.astype(jnp.float32) * scale
        tot = jax.lax.psum(q.astype(jnp.int32), axis_name)
        return (tot.astype(jnp.float32) * scale / n).astype(g.dtype), res

    if residuals is None:
        residuals = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), tree)
    out = jax.tree.map(one, tree, residuals)
    mean = jax.tree.map(lambda t: t[0], out,
                        is_leaf=lambda x: isinstance(x, tuple))
    res = jax.tree.map(lambda t: t[1], out,
                       is_leaf=lambda x: isinstance(x, tuple))
    return mean, res
