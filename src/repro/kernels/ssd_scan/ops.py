from __future__ import annotations

from functools import partial

import jax

from .ssd_scan import ssd_chunk as _ssd_chunk
from .ref import ssd_ref


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_chunk(x, dt, A, Bm, Cm, *, chunk: int = 128, interpret: bool = False):
    return _ssd_chunk(x, dt, A, Bm, Cm, chunk=chunk, interpret=interpret)


__all__ = ["ssd_chunk", "ssd_ref"]
