"""Pure-jnp oracle for the SSD chunk kernel: naive per-token recurrence."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(x, dt, A, Bm, Cm):
    """Naive SSD recurrence.

    x: (B,S,H,P); dt: (B,S,H) (post-softplus); A: (H,) negative;
    Bm/Cm: (B,S,H,N). Returns (y (B,S,H,P), final_state (B,H,P,N)).
    """
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]

    def step(state, inp):
        xt, dtt, bt, ct = inp
        dA = jnp.exp(dtt * A)                             # (B,H)
        state = state * dA[:, :, None, None] + \
            jnp.einsum("bh,bhn,bhp->bhpn", dtt, bt, xt)
        y = jnp.einsum("bhn,bhpn->bhp", ct, state)
        return state, y

    state0 = jnp.zeros((Bsz, H, P, N), jnp.float32)
    xs = (x.transpose(1, 0, 2, 3), dt.transpose(1, 0, 2),
          Bm.transpose(1, 0, 2, 3), Cm.transpose(1, 0, 2, 3))
    final, ys = jax.lax.scan(step, state0, xs)
    return ys.transpose(1, 0, 2, 3), final
