"""Pallas TPU kernel for the Mamba-2 SSD chunk computation.

Per (batch, chunk) program: the quadratic intra-chunk term and the chunk
state — the compute hot spot of the SSD algorithm [arXiv:2405.21060]. The
inter-chunk (length S/Q) linear recurrence is left to an associative scan in
ops.py: it is O(S/Q) tiny tensors and not kernel-worthy.

VMEM budget per program (mamba2-780m, Q=128, H=48, P=64, N=128):
x (Q,H·P) bf16 0.8 MiB + B/C (Q,H·N) 1.5 MiB + scores/L (H,Q,Q) fp32
6 MiB — comfortably inside ~128 MiB, MXU-aligned contractions (N=128,
Q multiples of 128 on target).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..util import largest_divisor


def _ssd_chunk_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref,
                      y_ref, state_ref, decay_ref, *, q: int, h: int,
                      p: int, n: int):
    x = x_ref[...].astype(jnp.float32).reshape(q, h, p)
    dt = dt_ref[...].astype(jnp.float32)                 # (Q, H)
    A = a_ref[...].astype(jnp.float32)                   # (H,)
    Bm = b_ref[...].astype(jnp.float32).reshape(q, h, n)
    Cm = c_ref[...].astype(jnp.float32).reshape(q, h, n)

    dA = dt * A                                          # (Q, H)
    cum = jnp.cumsum(dA, axis=0)                         # (Q, H)

    # scores (H, Qi, Qj) = C_i · B_j
    Ch = Cm.transpose(1, 0, 2)                           # (H, Q, N)
    Bh = Bm.transpose(1, 0, 2)
    scores = jax.lax.dot_general(Ch, Bh, (((2,), (2,)), ((0,), (0,))))
    diff = cum.T[:, :, None] - cum.T[:, None, :]         # (H, Qi, Qj)
    iota_i = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    iota_j = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    L = jnp.where(iota_i[None] >= iota_j[None], jnp.exp(diff), 0.0)
    w = scores * L * dt.T[:, None, :]                    # (H, Qi, Qj)
    xh = x.transpose(1, 0, 2)                            # (H, Q, P)
    y = jax.lax.dot_general(w, xh, (((2,), (1,)), ((0,), (0,))))  # (H,Q,P)
    y_ref[...] = y.transpose(1, 0, 2).reshape(q, h * p).astype(y_ref.dtype)

    # chunk state (H, P, N) = Σ_j decay_end_j · dt_j · x_j ⊗ B_j
    decay_end = jnp.exp(cum[-1][None, :] - cum)          # (Q, H)
    xw = (xh * (decay_end * dt).T[:, :, None])           # (H, Q, P)
    st = jax.lax.dot_general(xw, Bh, (((1,), (1,)), ((0,), (0,))))  # (H,P,N)
    state_ref[...] = st.reshape(h, p * n).astype(state_ref.dtype)
    decay_ref[...] = jnp.exp(cum[-1]).astype(decay_ref.dtype)       # (H,)


def ssd_chunk(x, dt, A, Bm, Cm, *, chunk: int = 128, interpret: bool = False):
    """Chunked SSD via the Pallas kernel + associative inter-chunk scan.

    x: (B,S,H,P); dt: (B,S,H); A: (H,); Bm/Cm: (B,S,H,N) (groups pre-repeated).
    Returns (y (B,S,H,P), final_state (B,H,P,N)).
    """
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    Q = largest_divisor(S, chunk)
    nC = S // Q

    kernel = functools.partial(_ssd_chunk_kernel, q=Q, h=H, p=P, n=N)
    y, states, decays = pl.pallas_call(
        kernel,
        grid=(Bsz, nC),
        in_specs=[
            pl.BlockSpec((None, Q, H * P), lambda b, c: (b, c, 0)),
            pl.BlockSpec((None, Q, H), lambda b, c: (b, c, 0)),
            pl.BlockSpec((H,), lambda b, c: (0,)),
            pl.BlockSpec((None, Q, H * N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((None, Q, H * N), lambda b, c: (b, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, Q, H * P), lambda b, c: (b, c, 0)),
            pl.BlockSpec((None, None, H, P * N), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((None, None, H), lambda b, c: (b, c, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bsz, nC * Q, H * P), jnp.float32),
            jax.ShapeDtypeStruct((Bsz, nC, H, P * N), jnp.float32),
            jax.ShapeDtypeStruct((Bsz, nC, H), jnp.float32),
        ],
        interpret=interpret,
    )(x.reshape(Bsz, S, H * P), dt, A,
      Bm.reshape(Bsz, S, H * N), Cm.reshape(Bsz, S, H * N))

    y_intra = y.reshape(Bsz, nC, Q, H, P)
    states = states.reshape(Bsz, nC, H, P, N)
    # inter-chunk associative scan (host-side jnp; O(nC) small tensors)
    dec = jnp.moveaxis(decays, 1, 0)                    # (nC, B, H)
    st = jnp.moveaxis(states, 1, 0)

    def assoc(a, b):
        da, sa = a
        db, sb = b
        return da * db, sb + sa * db[..., None, None]

    dec_c, st_c = jax.lax.associative_scan(assoc, (dec, st), axis=0)
    prev = jnp.concatenate([jnp.zeros_like(st_c[:1]), st_c[:-1]], axis=0)
    prev = jnp.moveaxis(prev, 0, 1)                     # (B, nC, H, P, N)

    dt_c = dt.reshape(Bsz, nC, Q, H)
    A_c = dt_c * A
    in_decay = jnp.exp(jnp.cumsum(A_c, axis=2))
    Cc = Cm.reshape(Bsz, nC, Q, H, N)
    y_inter = jnp.einsum("bcjh,bcjhn,bchpn->bcjhp", in_decay, Cc, prev)
    y_total = (y_intra + y_inter).reshape(Bsz, S, H, P)
    return y_total, jnp.moveaxis(st_c, 0, 1)[:, -1]
