"""Pallas TPU kernels for the compute hot spots (validated in interpret mode).

flash_attention/ - FlashAttention-2-style fused attention
ssd_scan/        - Mamba-2 SSD chunk kernel
conv2d_gemm/     - implicit-GEMM convolution (the paper's CNN hot spot)
rmsnorm/         - fused RMSNorm
"""
from .flash_attention.ops import attention_ref, flash_attention
from .ssd_scan.ops import ssd_chunk, ssd_ref
from .conv2d_gemm.ops import conv2d_gemm, conv2d_ref
from .rmsnorm.ops import rmsnorm, rmsnorm_ref
