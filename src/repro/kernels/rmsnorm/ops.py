from __future__ import annotations

from functools import partial

import jax

from .rmsnorm import rmsnorm as _rmsnorm
from .ref import rmsnorm_ref


@partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm(x, scale, *, eps: float = 1e-6, block_rows: int = 256,
            interpret: bool = False):
    return _rmsnorm(x, scale, eps=eps, block_rows=block_rows,
                    interpret=interpret)


__all__ = ["rmsnorm", "rmsnorm_ref"]
