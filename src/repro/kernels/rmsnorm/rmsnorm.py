"""Fused RMSNorm Pallas kernel: one HBM round-trip per row block.

Grid over row blocks; each block loads (BR, D) into VMEM, reduces in fp32 on
the VPU, scales, writes back. D is lane-aligned (multiple of 128) for every
assigned arch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, scale_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps)
                  * scale_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm(x, scale, *, eps: float = 1e-6, block_rows: int = 256,
            interpret: bool = False):
    """x: (..., D); scale: (D,)."""
    orig_shape = x.shape
    D = x.shape[-1]
    xf = x.reshape(-1, D)
    R = xf.shape[0]
    br = min(block_rows, R)
    while R % br:
        br -= 1
    kernel = functools.partial(_rmsnorm_kernel, eps=eps)
    out = pl.pallas_call(
        kernel,
        grid=(R // br,),
        in_specs=[pl.BlockSpec((br, D), lambda i: (i, 0)),
                  pl.BlockSpec((D,), lambda i: (0,))],
        out_specs=pl.BlockSpec((br, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, D), x.dtype),
        interpret=interpret,
    )(xf, scale)
    return out.reshape(orig_shape)
