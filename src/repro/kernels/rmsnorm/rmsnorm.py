"""Fused RMSNorm Pallas kernel: one HBM round-trip per row block.

Grid over row blocks; each block loads (BR, D) into VMEM, reduces in fp32 on
the VPU, scales, writes back. D is lane-aligned (multiple of 128) for every
assigned arch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..util import resolve_block_rows


def _rmsnorm_kernel(x_ref, scale_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps)
                  * scale_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm(x, scale, *, eps: float = 1e-6, block_rows: int = 256,
            interpret: bool = False):
    """x: (..., D); scale: (D,).

    The row block resolves to the largest divisor of R ≤ ``block_rows``
    (O(√R)); when every divisor is pathologically small (prime row counts —
    a ragged last microbatch used to serialize the grid to R single-row
    programs), the rows are padded up to a multiple of the requested block
    instead and the pad rows sliced off (rows are independent).
    """
    orig_shape = x.shape
    D = x.shape[-1]
    xf = x.reshape(-1, D)
    R = xf.shape[0]
    br, Rp = resolve_block_rows(R, block_rows)
    if Rp != R:
        xf = jnp.pad(xf, ((0, Rp - R), (0, 0)))
    kernel = functools.partial(_rmsnorm_kernel, eps=eps)
    out = pl.pallas_call(
        kernel,
        grid=(Rp // br,),
        in_specs=[pl.BlockSpec((br, D), lambda i: (i, 0)),
                  pl.BlockSpec((D,), lambda i: (0,))],
        out_specs=pl.BlockSpec((br, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Rp, D), x.dtype),
        interpret=interpret,
    )(xf, scale)
    if Rp != R:
        out = out[:R]
    return out.reshape(orig_shape)
