"""Block-size resolution shared by the Pallas kernels and their autotuner.

Every kernel grids over fixed-size blocks, so a requested block must be
reconciled with the actual extent. The old per-kernel idiom
(``while N % b: b -= 1``) is O(N) and collapses to b=1 for prime extents —
a 4099-row ragged microbatch would silently serialize the rmsnorm grid.
These helpers do it right once: largest divisor in O(√N), plus a
pad-to-block escape hatch for extents whose divisors are all pathological.
The autotuner (kernels/autotune/space.py) calls the same functions so its
candidate tilings are exactly what the kernels will deploy.
"""
from __future__ import annotations

import math

# a divisor smaller than this serializes the grid badly enough that padding
# to the requested block (and wasting the pad rows) is cheaper
MIN_BLOCK_ROWS = 16


def largest_divisor(n: int, cap: int) -> int:
    """Largest divisor of ``n`` that is ≤ ``cap`` (O(√n); cap clamped to
    [1, n])."""
    n = int(n)
    cap = max(1, min(int(cap), n))
    if n % cap == 0:
        return cap
    best = 1
    for d in range(2, math.isqrt(n) + 1):
        if n % d == 0:
            if d <= cap and d > best:
                best = d
            q = n // d
            if q <= cap and q > best:
                best = q
    return best


def resolve_block_rows(rows: int, block: int,
                       min_block: int = MIN_BLOCK_ROWS) -> tuple[int, int]:
    """Resolve a row-block request against ``rows`` independent rows.

    Returns ``(block_rows, padded_rows)``: the block to grid over and the
    extent to pad the rows to (== ``rows`` when no padding is needed).
    Preference order:

      1. the largest divisor of ``rows`` ≤ ``block`` — exact grid, no waste;
      2. when that divisor is pathologically small (< ``min_block``, e.g.
         a prime row count from a ragged last microbatch), pad up to a
         multiple of the requested block instead: the pad rows are wasted
         bandwidth, but the grid stays parallel instead of serializing
         to ``rows`` single-row programs.

    Only valid for row-independent kernels (rmsnorm): padded rows compute
    garbage that the caller slices off.
    """
    cap = max(1, min(int(block), int(rows)))
    br = largest_divisor(rows, cap)
    if br == cap or br >= min_block:
        return br, rows
    return cap, -(-rows // cap) * cap
