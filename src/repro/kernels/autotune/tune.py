"""Tune loop: analytic prune → measure survivors → cache winners.

``tune_kernels(cluster)`` is the whole pipeline: for every (kernel, shape)
in the tuning set, enumerate candidate block sizes, kill the ones the
``HardwareSpec.from_cluster`` arithmetic rejects (VMEM capacity, roofline
knee), time the survivors, and persist the per-bucket winner to
``experiments/kernel_tune.json`` stamped with the cluster fingerprint.

The winner is the argmin over *measured* times and the default blocks are
always among the measured candidates, so ``measured_us ≤ default_us`` holds
by construction in every entry — the property scripts/check.sh gates on.

``DEFAULT_SHAPES`` mirrors benchmarks/bench_kernels.py exactly so the tuned
bench rows hit tuned buckets; ``SMOKE_SHAPES`` are the tiny CI equivalents.
"""
from __future__ import annotations

from .cache import DEFAULT_TUNE_PATH, KernelTuneCache
from .measure import _inputs, time_candidate
from .space import prune

#: (kernel, dims) pairs — full shapes = the bench_kernels.py full suite
DEFAULT_SHAPES = (
    ("conv2d_gemm", dict(B=4, H=32, W=32, C=64, F=128,
                         kh=3, kw=3, sh=1, sw=1, e=4)),
    ("flash_attention", dict(B=1, H=4, S=512, D=64, causal=1, e=4)),
    ("rmsnorm", dict(R=4096, D=1024, e=4)),
    ("ssd_scan", dict(B=1, S=512, H=4, P=16, N=32, e=4)),
)

#: tiny CI shapes: same kernels, seconds not minutes in interpret mode
SMOKE_SHAPES = (
    ("conv2d_gemm", dict(B=1, H=8, W=8, C=8, F=16,
                         kh=3, kw=3, sh=1, sw=1, e=4)),
    ("flash_attention", dict(B=1, H=2, S=64, D=16, causal=1, e=4)),
    ("rmsnorm", dict(R=128, D=128, e=4)),
    ("ssd_scan", dict(B=1, S=64, H=2, P=4, N=8, e=4)),
)

SHAPE_SETS = {"full": DEFAULT_SHAPES, "smoke": SMOKE_SHAPES}


def _detect_backend() -> str:
    import jax
    try:
        return jax.default_backend()
    except Exception:
        return "cpu"


def tune_kernels(cluster, *, shapes="full", path: str | None = None,
                 iters: int = 3, warmup: int = 1, slack: float = 2.0,
                 top_k: int = 4, backend: str | None = None,
                 verbose: bool = False) -> KernelTuneCache:
    """Run the full prune→measure→cache pipeline for ``cluster``.

    ``shapes``: "full" | "smoke" | explicit ((kernel, dims), ...).
    ``path``: artifact destination; None ⇒ the committed default; "" ⇒ don't
    persist (tests that only want the in-memory cache).
    """
    from ...core.roofline import HardwareSpec
    from .space import bucket

    hw = HardwareSpec.from_cluster(cluster)
    if isinstance(shapes, str):
        shapes = SHAPE_SETS[shapes]
    if backend is None:
        backend = _detect_backend()
    cache = KernelTuneCache(fingerprint=cluster.fingerprint(),
                            backend=backend, cluster_name=cluster.name)
    for kernel, dims in shapes:
        survivors = prune(kernel, dims, hw, slack=slack, top_k=top_k)
        inputs = _inputs(kernel, dims)      # shared across candidates
        timed = []
        for cand in survivors:
            t = time_candidate(kernel, dims, cand.blocks_dict,
                               backend=backend, iters=iters, warmup=warmup,
                               inputs=inputs)
            timed.append((t, cand))
            if verbose:
                print(f"  {kernel} {cand.blocks_dict} "
                      f"predicted {cand.predicted_s * 1e6:9.1f}us "
                      f"measured {t * 1e6:9.1f}us"
                      f"{'  [default]' if cand.is_default else ''}")
        best_t, best = min(timed, key=lambda tc: tc[0])
        default_t = min(t for t, c in timed if c.is_default)
        cand_rows = [{"blocks": c.blocks_dict,
                      "predicted_us": round(c.predicted_s * 1e6, 3),
                      "measured_us": round(t * 1e6, 3),
                      "is_default": bool(c.is_default)}
                     for t, c in sorted(timed, key=lambda tc: tc[0])]
        cache.put(kernel, bucket(kernel, dims), blocks=best.blocks_dict,
                  measured_us=best_t * 1e6, default_us=default_t * 1e6,
                  predicted_us=best.predicted_s * 1e6, trials=len(timed),
                  candidates=cand_rows)
        if verbose:
            print(f"{kernel}: winner {best.blocks_dict} "
                  f"{best_t * 1e6:.1f}us vs default {default_t * 1e6:.1f}us "
                  f"({len(timed)} candidates measured)")
    if path is None:
        path = DEFAULT_TUNE_PATH
    if path:
        cache.save(path)
    return cache
