"""Search space + analytic pruner for Pallas block sizes (DESIGN.md §13).

The discipline follows the paper's oracle: enumerate the candidate tilings,
reject the ones arithmetic alone can kill, and only measure what survives.
Per kernel the knobs are the block sizes its wrapper already exposes:

    conv2d_gemm      block_f       (filter-block width of the implicit GEMM)
    flash_attention  block_q/block_k
    rmsnorm          block_rows
    ssd_scan         chunk         (intra-chunk quadratic extent)

Two analytic filters, both read off ``HardwareSpec.from_cluster``:

* **VMEM capacity** — a candidate whose per-program working set exceeds
  ``VMEM_FRACTION`` of ``hw.vmem_bytes`` cannot be scheduled; reject.
* **Roofline knee** — predicted time is
  ``max(compute_s, memory_s) + programs · DISPATCH_S`` with MXU utilization
  ``min(block, hw.mxu)/hw.mxu`` scaling the compute term; candidates worse
  than ``slack ×`` the best prediction are off the knee and not worth
  measuring.

This module is pure arithmetic (numpy-free, jax-free) so it is unit-testable
without an accelerator and importable before XLA_FLAGS are set.
"""
from __future__ import annotations

from dataclasses import dataclass

from ..util import largest_divisor, resolve_block_rows

DISPATCH_S = 2e-6          # per-program launch overhead charged to the grid
VMEM_FRACTION = 0.9        # usable fraction of hw.vmem_bytes per program

KERNELS = ("conv2d_gemm", "flash_attention", "rmsnorm", "ssd_scan")

#: the literals the kernel wrappers default to — always kept as candidates so
#: the measure loop records a default row to compare the winner against.
DEFAULT_BLOCKS = {
    "conv2d_gemm": {"block_f": 128},
    "flash_attention": {"block_q": 128, "block_k": 128},
    "rmsnorm": {"block_rows": 256},
    "ssd_scan": {"chunk": 128},
}

_BLOCK_CHOICES = {
    "conv2d_gemm": (16, 32, 64, 128, 256, 512),
    "flash_attention": (32, 64, 128, 256, 512),
    "rmsnorm": (32, 64, 128, 256, 512, 1024),
    "ssd_scan": (16, 32, 64, 128, 256),
}

# dims whose magnitude (not structure) drives the tiling choice: bucketed to
# the nearest power of two so nearby shapes share a cache entry.  Everything
# else (channels, heads, head_dim, kernel extent, strides, itemsize) changes
# the kernel structurally and stays exact.  Note "H" is spatial for conv but
# heads for flash/ssd — hence per-kernel sets.
_SIZE_DIMS = {
    "conv2d_gemm": ("B", "H", "W"),
    "flash_attention": ("B", "S"),
    "rmsnorm": ("R",),
    "ssd_scan": ("B", "S"),
}


def _nearest_pow2(n: int) -> int:
    n = max(1, int(n))
    lo = 1 << (n.bit_length() - 1)
    hi = lo << 1
    return lo if n * n <= lo * hi else hi    # geometric midpoint


def bucket(kernel: str, dims: dict) -> str:
    """Stable shape-bucket string: size dims → nearest power of two,
    structural dims exact. Nearest (not ceil) so a halo tile carrying its
    kh−1 boundary rows (e.g. H=34) lands in the bucket of its base shape."""
    size = _SIZE_DIMS[kernel]
    parts = []
    for k in sorted(dims):
        v = dims[k]
        if k in size:
            v = _nearest_pow2(v)
        parts.append(f"{k}{v}")
    return ",".join(parts)


@dataclass(frozen=True)
class Candidate:
    """One point of the search space, priced by the analytic model."""
    kernel: str
    blocks: tuple                 # sorted ((name, value), ...) — resolved
    predicted_s: float
    vmem_bytes: int
    programs: int
    is_default: bool = False
    rejected: str = ""            # "" = survives; else the pruning reason

    @property
    def blocks_dict(self) -> dict:
        return dict(self.blocks)


def _mk(kernel, blocks, compute_s, memory_s, vmem, programs, default):
    return Candidate(
        kernel=kernel, blocks=tuple(sorted(blocks.items())),
        predicted_s=max(compute_s, memory_s) + programs * DISPATCH_S,
        vmem_bytes=int(vmem), programs=int(programs), is_default=default)


def _util(block: int, mxu: int) -> float:
    return min(block, mxu) / mxu


# ---------------------------------------------------------------------------
# per-kernel models: enumerate resolved candidates and price each one
# ---------------------------------------------------------------------------

def _conv_candidates(dims, hw):
    B, H, W, C, F = (dims[k] for k in "BHWCF")
    kh, kw, sh, sw, e = dims["kh"], dims["kw"], dims["sh"], dims["sw"], dims["e"]
    Ho, Wo = -(-H // sh), -(-W // sw)
    Hp, Wp = (kh - 1) + sh * Ho, (kw - 1) + sw * Wo
    flops = 2.0 * B * Ho * Wo * kh * kw * C * F
    out = []
    dbf = largest_divisor(F, DEFAULT_BLOCKS["conv2d_gemm"]["block_f"])
    for bf in _resolved(_BLOCK_CHOICES["conv2d_gemm"], F, dbf):
        programs = B * (F // bf)
        # x tile is re-read once per filter block; weights/output are read
        # exactly once regardless of bf — larger bf ⇒ less x traffic.
        traffic = (programs * Hp * Wp * C * e          # x tiles
                   + B * kh * kw * C * F * e           # weight blocks
                   + B * Ho * Wo * F * e)              # output
        vmem = (Hp * Wp * C * e + kh * kw * C * bf * e
                + Ho * Wo * bf * 4 + Ho * Wo * bf * e)
        compute = flops / (hw.peak_bf16 * _util(bf, hw.mxu))
        out.append(_mk("conv2d_gemm", {"block_f": bf}, compute,
                       traffic / hw.hbm_bw, vmem, programs, bf == dbf))
    return out


def _flash_candidates(dims, hw):
    B, Hh, S, D, e = dims["B"], dims["H"], dims["S"], dims["D"], dims["e"]
    causal = bool(dims.get("causal", 1))
    kv_frac = 0.5 if causal else 1.0       # causal programs skip ~half the KV
    flops = 4.0 * B * Hh * S * S * D * kv_frac          # QKᵀ + PV
    dq = largest_divisor(S, DEFAULT_BLOCKS["flash_attention"]["block_q"])
    dk = largest_divisor(S, DEFAULT_BLOCKS["flash_attention"]["block_k"])
    out, seen = [], set()
    for rq in _resolved(_BLOCK_CHOICES["flash_attention"], S, dq):
        for rk in _resolved(_BLOCK_CHOICES["flash_attention"], S, dk):
            if (rq, rk) in seen:
                continue
            seen.add((rq, rk))
            programs = B * Hh * (S // rq)
            # each program streams the (causal-truncated) KV; q/out once
            traffic = (programs * kv_frac * 2 * S * D * e
                       + 2 * B * Hh * S * D * e)
            vmem = (rq * D * e + 2 * S * D * e          # q block + full K,V
                    + rq * rk * 4 + rq * D * 4)         # logits + fp32 acc
            compute = flops / (hw.peak_bf16
                               * _util(min(rq, rk), hw.mxu) * _util(D, hw.mxu))
            out.append(_mk("flash_attention", {"block_q": rq, "block_k": rk},
                           compute, traffic / hw.hbm_bw, vmem, programs,
                           (rq, rk) == (dq, dk)))
    return out


def _rmsnorm_candidates(dims, hw):
    R, D, e = dims["R"], dims["D"], dims["e"]
    out, seen = [], set()
    dbr, _ = resolve_block_rows(R, DEFAULT_BLOCKS["rmsnorm"]["block_rows"])
    for req in _BLOCK_CHOICES["rmsnorm"]:
        br, Rp = resolve_block_rows(R, req)
        if (br, Rp) in seen:
            continue
        seen.add((br, Rp))
        programs = Rp // br
        # memory-bound VPU op: rows in + rows out (+ per-program scale
        # re-read); padding waste shows up as Rp > R traffic.
        traffic = 2 * Rp * D * e + programs * D * e
        vmem = 2 * br * D * e + br * D * 4
        compute = 3.0 * Rp * D / hw.peak_bf16           # negligible by design
        out.append(_mk("rmsnorm", {"block_rows": br}, compute,
                       traffic / hw.hbm_bw, vmem, programs, br == dbr))
    return out


def _ssd_candidates(dims, hw):
    B, S, Hh, P, N, e = (dims[k] for k in ("B", "S", "H", "P", "N", "e"))
    out = []
    for Q in _resolved(_BLOCK_CHOICES["ssd_scan"], S,
                       largest_divisor(S, DEFAULT_BLOCKS["ssd_scan"]["chunk"])):
        programs = B * (S // Q)
        # intra-chunk quadratic term grows with Q — a genuine knee, unlike
        # the monotone kernels above: scores/L are O(Q²) per chunk.
        flops = 2.0 * B * Hh * S * (Q * (N + P) + P * N)
        traffic = (B * S * (Hh * P + Hh + 2 * Hh * N) * e   # x, dt, B, C in
                   + B * S * Hh * P * 4                      # y out (fp32)
                   + programs * Hh * (P * N + 1) * 4)        # states + decays
        vmem = (Q * Hh * P * e + 2 * Q * Hh * N * e
                + 3 * Hh * Q * Q * 4                         # scores, L, w
                + Q * Hh * P * 4 + Hh * P * N * 4)
        compute = flops / (hw.peak_bf16 * _util(min(Q, N), hw.mxu))
        out.append(_mk("ssd_scan", {"chunk": Q}, compute,
                       traffic / hw.hbm_bw, vmem, programs,
                       Q == largest_divisor(S, 128)))
    return out


def _resolved(choices, n, default_resolved):
    """Resolve each requested block against n (largest divisor ≤ request),
    dedup, and make sure the wrapper's resolved default is present."""
    vals = {largest_divisor(n, c) for c in choices if c <= max(n, min(choices))}
    vals.add(default_resolved)
    return sorted(vals)


_ENUM = {"conv2d_gemm": _conv_candidates, "flash_attention": _flash_candidates,
         "rmsnorm": _rmsnorm_candidates, "ssd_scan": _ssd_candidates}


def enumerate_candidates(kernel: str, dims: dict, hw) -> list:
    """All resolved candidates for (kernel, dims), priced — none rejected."""
    return _ENUM[kernel](dims, hw)


def prune(kernel: str, dims: dict, hw, *, slack: float = 2.0,
          top_k: int = 4) -> list:
    """Survivors worth measuring, best-predicted first.

    Rejects candidates whose per-program working set exceeds the VMEM budget,
    then keeps the ``top_k`` best-predicted within ``slack ×`` the best; the
    resolved default always survives (the measure loop needs its row)."""
    cands = enumerate_candidates(kernel, dims, hw)
    budget = VMEM_FRACTION * hw.vmem_bytes
    fit = [c for c in cands if c.vmem_bytes <= budget]
    if not fit:                      # degenerate budget: keep the smallest
        fit = [min(cands, key=lambda c: c.vmem_bytes)]
    fit.sort(key=lambda c: c.predicted_s)
    best = fit[0].predicted_s
    keep = [c for c in fit if c.predicted_s <= slack * best][:top_k]
    if not any(c.is_default for c in keep):
        keep += [c for c in fit if c.is_default][:1]
    return keep
