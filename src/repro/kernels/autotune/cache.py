"""Tuned-block cache: experiments/kernel_tune.json (DESIGN.md §13).

Winners are keyed ``kernel|bucket|backend`` and stamped with the
``ClusterSpec.fingerprint()`` they were measured under.  A cache whose
fingerprint no longer matches the session's cluster is *stale* — the machine
description changed, so the block-size optima may have moved — and is ignored
with a warning rather than deployed silently.  Corrupt or
version-incompatible artifacts degrade the same way: warn, start fresh.

jax-free (stdlib only): importable from ``core``-adjacent code and before
XLA_FLAGS are set.
"""
from __future__ import annotations

import json
import os
import warnings
from dataclasses import dataclass, field

CACHE_VERSION = 1

#: committed artifact — same directory the calibration JSONs live in
DEFAULT_TUNE_PATH = os.path.join(
    os.path.dirname(os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))))),
    "experiments", "kernel_tune.json")


def entry_key(kernel: str, bucket: str, backend: str) -> str:
    return f"{kernel}|{bucket}|{backend}"


@dataclass(frozen=True)
class KernelTiles:
    """Read-only deployment view of a tune cache.

    Frozen + hashable so it can ride inside ``ShardingCtx`` / ``TunedPlan``
    (both frozen).  ``entries`` maps key → sorted ((block, value), ...)."""
    entries: tuple = ()                  # ((key, ((name, val), ...)), ...)
    fingerprint: str = ""
    backend: str = "cpu"

    def blocks_for(self, kernel: str, dims: dict) -> dict:
        """Tuned blocks for (kernel, dims) or {} when untuned."""
        from .space import bucket        # local: keeps cache.py import-light
        key = entry_key(kernel, bucket(kernel, dims), self.backend)
        for k, blocks in self.entries:
            if k == key:
                return dict(blocks)
        return {}

    def conv_block_f(self, *, B, H, W, C, F, kh, kw, sh=1, sw=1,
                     e=4, default: int = 128) -> int:
        """The one lookup the CNN deployment path makes (parallel/halo.py)."""
        blocks = self.blocks_for("conv2d_gemm", dict(
            B=B, H=H, W=W, C=C, F=F, kh=kh, kw=kw, sh=sh, sw=sw, e=e))
        return int(blocks.get("block_f", default))

    def __len__(self):
        return len(self.entries)


@dataclass
class KernelTuneCache:
    """Mutable tune-loop side: accumulate winners, persist, reload."""
    fingerprint: str = ""
    backend: str = "cpu"
    cluster_name: str = ""
    entries: dict = field(default_factory=dict)   # key -> entry dict

    def put(self, kernel: str, bucket: str, *, blocks: dict,
            measured_us: float, default_us: float, predicted_us: float,
            trials: int, candidates: list | None = None) -> None:
        self.entries[entry_key(kernel, bucket, self.backend)] = {
            "kernel": kernel, "bucket": bucket, "backend": self.backend,
            "blocks": {k: int(v) for k, v in blocks.items()},
            "measured_us": round(float(measured_us), 3),
            "default_us": round(float(default_us), 3),
            "predicted_us": round(float(predicted_us), 3),
            "trials": int(trials),
            # full predicted-vs-measured table of the survivors, so
            # experiments/make_report.py can regenerate the EXPERIMENTS.md
            # section without re-running the tune
            "candidates": list(candidates or []),
        }

    def lookup(self, kernel: str, bucket: str) -> dict | None:
        e = self.entries.get(entry_key(kernel, bucket, self.backend))
        return dict(e["blocks"]) if e else None

    def tiles(self) -> KernelTiles:
        return KernelTiles(
            entries=tuple(sorted(
                (k, tuple(sorted(e["blocks"].items())))
                for k, e in self.entries.items())),
            fingerprint=self.fingerprint, backend=self.backend)

    # -- persistence ---------------------------------------------------------

    def to_json(self) -> dict:
        return {"version": CACHE_VERSION, "fingerprint": self.fingerprint,
                "backend": self.backend, "cluster": self.cluster_name,
                "entries": dict(sorted(self.entries.items()))}

    def save(self, path: str = DEFAULT_TUNE_PATH) -> str:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=2, sort_keys=True)
            f.write("\n")
        return path

    @classmethod
    def load(cls, path: str = DEFAULT_TUNE_PATH, *, fingerprint: str = "",
             backend: str = "cpu", cluster_name: str = "") -> "KernelTuneCache":
        """Load iff the artifact is readable, version-compatible, and (when a
        fingerprint is given) was tuned under the same machine description.
        Every failure mode warns and returns a fresh empty cache."""
        fresh = cls(fingerprint=fingerprint, backend=backend,
                    cluster_name=cluster_name)
        if not os.path.exists(path):
            return fresh
        try:
            with open(path) as f:
                d = json.load(f)
            entries = d["entries"]
            if not isinstance(entries, dict):
                raise ValueError("entries is not a dict")
        except (json.JSONDecodeError, KeyError, ValueError, OSError) as exc:
            warnings.warn(f"kernel tune cache {path} is corrupt "
                          f"({exc!r}); ignoring it", stacklevel=2)
            return fresh
        if d.get("version") != CACHE_VERSION:
            warnings.warn(
                f"kernel tune cache {path} has version {d.get('version')!r} "
                f"(want {CACHE_VERSION}); ignoring it", stacklevel=2)
            return fresh
        if fingerprint and d.get("fingerprint") != fingerprint:
            warnings.warn(
                f"kernel tune cache {path} is stale: tuned under cluster "
                f"fingerprint {d.get('fingerprint')!r}, session cluster is "
                f"{fingerprint!r} — re-tune with --tune-kernels",
                stacklevel=2)
            return fresh
        return cls(fingerprint=d.get("fingerprint", fingerprint),
                   backend=d.get("backend", backend),
                   cluster_name=d.get("cluster", cluster_name),
                   entries=dict(entries))


def load_tiles(path: str = DEFAULT_TUNE_PATH, *, cluster=None,
               backend: str | None = None) -> KernelTiles:
    """Deployment-side convenience: artifact → ``KernelTiles``.

    With ``cluster`` the artifact must match its fingerprint (stale caches
    resolve to empty tiles, i.e. kernel defaults).  Without it the artifact
    is trusted as-is (benchmarks comparing default vs tuned rows)."""
    fp = cluster.fingerprint() if cluster is not None else ""
    cache = KernelTuneCache.load(path, fingerprint=fp)
    if backend is not None:
        cache.backend = backend
    return cache.tiles()
