"""Pallas block-size autotuner (DESIGN.md §13).

Three layers, one machine description:

* ``space``   — search space + analytic pruner (pure arithmetic; reads
  ``HardwareSpec.from_cluster(ClusterSpec)`` for VMEM/roofline limits)
* ``measure`` + ``tune`` — time the survivors, cache the winners to
  ``experiments/kernel_tune.json`` keyed by (kernel, shape bucket, backend)
  and stamped with the cluster fingerprint
* ``cache``   — the jax-free artifact layer; ``KernelTiles`` is the frozen
  deployment view that ``ShardingCtx`` / ``TunedPlan`` carry so
  ``build_cell(use_pallas=True)`` and HaloConv deploy tuned blocks
"""
from .cache import (DEFAULT_TUNE_PATH, KernelTiles, KernelTuneCache,
                    entry_key, load_tiles)
from .space import (DEFAULT_BLOCKS, DISPATCH_S, KERNELS, VMEM_FRACTION,
                    Candidate, bucket, enumerate_candidates, prune)
from .tune import DEFAULT_SHAPES, SMOKE_SHAPES, tune_kernels

__all__ = [
    "DEFAULT_TUNE_PATH", "KernelTiles", "KernelTuneCache", "entry_key",
    "load_tiles", "DEFAULT_BLOCKS", "DISPATCH_S", "KERNELS", "VMEM_FRACTION",
    "Candidate", "bucket", "enumerate_candidates", "prune",
    "DEFAULT_SHAPES", "SMOKE_SHAPES", "tune_kernels",
]
