"""Measure loop for pruner survivors: time each candidate, pick the winner.

Off-TPU (this box) kernels run in Pallas interpret mode, so absolute numbers
are CPU-emulation times — still a real ranking signal for grid/launch
overheads and traffic shape, and the discipline (analytic prune → measure →
cache) is identical on hardware: on a TPU backend the same loop compiles the
candidates natively.

Timing: jit with the block sizes closed over (they are static — each
candidate is its own executable), ``warmup`` compile+run calls, then the min
over ``iters`` timed calls with ``block_until_ready``.
"""
from __future__ import annotations

import time


def _inputs(kernel: str, dims: dict):
    import jax
    import jax.numpy as jnp

    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    if kernel == "conv2d_gemm":
        x = jax.random.normal(ks[0], (dims["B"], dims["H"], dims["W"],
                                      dims["C"]), jnp.float32)
        w = jax.random.normal(ks[1], (dims["kh"], dims["kw"], dims["C"],
                                      dims["F"]), jnp.float32) * 0.1
        return (x, w)
    if kernel == "flash_attention":
        shp = (dims["B"], dims["H"], dims["S"], dims["D"])
        return tuple(jax.random.normal(k, shp, jnp.float32) for k in ks[:3])
    if kernel == "rmsnorm":
        x = jax.random.normal(ks[0], (dims["R"], dims["D"]), jnp.float32)
        scale = jnp.ones((dims["D"],), jnp.float32)
        return (x, scale)
    if kernel == "ssd_scan":
        B, S, H, P, N = (dims[k] for k in ("B", "S", "H", "P", "N"))
        x = jax.random.normal(ks[0], (B, S, H, P), jnp.float32)
        dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H), jnp.float32))
        A = -jnp.exp(jax.random.normal(ks[2], (H,), jnp.float32))
        Bm = jax.random.normal(ks[3], (B, S, H, N), jnp.float32)
        Cm = jax.random.normal(ks[4], (B, S, H, N), jnp.float32)
        return (x, dt, A, Bm, Cm)
    raise KeyError(kernel)


def _callable(kernel: str, dims: dict, blocks: dict, interpret: bool):
    import jax

    if kernel == "conv2d_gemm":
        from ..conv2d_gemm.conv2d_gemm import conv2d_gemm
        strides = (dims["sh"], dims["sw"])

        def fn(x, w):
            return conv2d_gemm(x, w, strides=strides, interpret=interpret,
                               **blocks)
    elif kernel == "flash_attention":
        from ..flash_attention.flash_attention import flash_attention_fwd
        causal = bool(dims.get("causal", 1))

        def fn(q, k, v):
            return flash_attention_fwd(q, k, v, causal=causal,
                                       interpret=interpret, **blocks)
    elif kernel == "rmsnorm":
        from ..rmsnorm.rmsnorm import rmsnorm

        def fn(x, scale):
            return rmsnorm(x, scale, interpret=interpret, **blocks)
    elif kernel == "ssd_scan":
        from ..ssd_scan.ssd_scan import ssd_chunk

        def fn(x, dt, A, Bm, Cm):
            return ssd_chunk(x, dt, A, Bm, Cm, interpret=interpret, **blocks)
    else:
        raise KeyError(kernel)
    return jax.jit(fn)       # blocks are closed over ⇒ static per candidate


def time_candidate(kernel: str, dims: dict, blocks: dict, *,
                   backend: str = "cpu", iters: int = 3,
                   warmup: int = 1, inputs=None) -> float:
    """Best-of-``iters`` wall time in seconds for one (kernel, blocks)."""
    import jax

    interpret = backend != "tpu"
    if inputs is None:
        inputs = _inputs(kernel, dims)
    fn = _callable(kernel, dims, blocks, interpret)
    for _ in range(max(1, warmup)):
        jax.block_until_ready(fn(*inputs))
    best = float("inf")
    for _ in range(max(1, iters)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*inputs))
        best = min(best, time.perf_counter() - t0)
    return best
