"""Jitted public wrapper for the flash attention kernel."""
from __future__ import annotations

from functools import partial

import jax

from .flash_attention import flash_attention_fwd
from .ref import attention_ref


@partial(jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False):
    return flash_attention_fwd(q, k, v, causal=causal, block_q=block_q,
                               block_k=block_k, interpret=interpret)


__all__ = ["flash_attention", "attention_ref"]
