"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def attention_ref(q, k, v, *, causal: bool = True):
    """q,k,v: (B, H, S, D) → (B, H, S, D). fp32 softmax, full matrix."""
    D = q.shape[-1]
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / np.sqrt(D)
    if causal:
        S, T = s.shape[-2], s.shape[-1]
        mask = jnp.tril(jnp.ones((S, T), bool), k=T - S)
        s = jnp.where(mask, s, -jnp.inf)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)
