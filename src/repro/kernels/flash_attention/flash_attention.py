"""Pallas TPU flash attention (forward), FlashAttention-2 style.

Grid: (B·H, S/BQ). Each program streams KV blocks from HBM-resident refs
while q stays in VMEM; running max / sum / output accumulator live in VMEM
scratch. Block shapes are MXU-aligned (BQ×D, BK×D with D a multiple of 128
for full MXU utilization on the TARGET TPU; interpret=True validates the
same body on CPU).

Hardware adaptation note (DESIGN.md §6): the CUDA flash kernel tiles for SRAM +
warps; here tiling is VMEM-sized (BQ·D + 2·BK·D + BQ·BK fp32 ≪ ~128 MiB)
and the contraction shapes feed the 128×128 MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from ..util import largest_divisor

NEG_INF = -1e30


def _flash_fwd_kernel(q_ref, k_ref, v_ref, o_ref, *, bq: int, bk: int,
                      seq_len: int, causal: bool, scale: float):
    qi = pl.program_id(1)
    q = q_ref[...].astype(jnp.float32) * scale            # (BQ, D)

    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    s0 = jnp.zeros((bq,), jnp.float32)
    o0 = jnp.zeros((bq, q.shape[-1]), jnp.float32)
    nk = seq_len // bk
    # causal: skip KV blocks strictly past this q block
    nk_eff = jnp.minimum(nk, (qi + 1) * bq // bk + (1 if bq % bk else 0)) \
        if causal else nk

    def body(ki, carry):
        m, s, o = carry
        k = pl.load(k_ref, (pl.dslice(ki * bk, bk), slice(None))
                    ).astype(jnp.float32)                  # (BK, D)
        v = pl.load(v_ref, (pl.dslice(ki * bk, bk), slice(None))
                    ).astype(jnp.float32)
        logits = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (BQ,BK)
        if causal:
            qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            logits = jnp.where(qpos >= kpos, logits, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
        p = jnp.exp(logits - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        s_new = s * alpha + jnp.sum(p, axis=-1)
        o_new = o * alpha[:, None] + jax.lax.dot(p.astype(v.dtype), v)
        return m_new, s_new, o_new

    m, s, o = jax.lax.fori_loop(0, nk_eff, body, (m0, s0, o0))
    o_ref[...] = (o / jnp.maximum(s, 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, *, causal: bool = True, block_q: int = 128,
                        block_k: int = 128, interpret: bool = False):
    """q,k,v: (B, H, S, D) → (B, H, S, D).

    Block sizes that do not divide S fall back to the largest divisor ≤ the
    request (as rmsnorm does), so odd sequence lengths run instead of
    crashing — the grid and the KV loop both need exact tiling.
    """
    B, H, S, D = q.shape
    bq = largest_divisor(S, block_q)
    bk = largest_divisor(S, block_k)
    scale = 1.0 / np.sqrt(D)
    qf = q.reshape(B * H, S, D)
    kf = k.reshape(B * H, S, D)
    vf = v.reshape(B * H, S, D)

    kernel = functools.partial(_flash_fwd_kernel, bq=bq, bk=bk, seq_len=S,
                               causal=causal, scale=scale)
    out = pl.pallas_call(
        kernel,
        grid=(B * H, S // bq),
        in_specs=[
            pl.BlockSpec((None, bq, D), lambda b, i: (b, i, 0)),
            pl.BlockSpec((None, S, D), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((None, S, D), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, bq, D), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, D), q.dtype),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, S, D)
