"""Implicit-GEMM conv2d Pallas kernel (the paper's CNN compute hot spot).

Hardware adaptation (DESIGN.md §6): cuDNN's implicit GEMM tiles for SMs/shared
memory; on TPU the conv is re-expressed as kh·kw shifted (H·W, C) × (C, F)
matmuls accumulated in fp32 — each contraction feeds the 128×128 MXU, the
image tile + filter block live in VMEM. Grid: (batch, F/BF). Input is
pre-padded in ops.py so the kernel body is branch-free.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _conv_kernel(x_ref, w_ref, o_ref, *, H: int, W: int, kh: int, kw: int,
                 c: int, bf: int):
    x = x_ref[...]                      # (H+kh-1, W+kw-1, C) padded tile
    acc = jnp.zeros((H * W, bf), jnp.float32)
    for di in range(kh):
        for dj in range(kw):
            patch = jax.lax.dynamic_slice(x, (di, dj, 0), (H, W, c))
            mat = patch.reshape(H * W, c)
            wk = w_ref[di, dj]          # (C, BF)
            acc += jax.lax.dot(mat, wk, preferred_element_type=jnp.float32)
    o_ref[...] = acc.reshape(H, W, bf).astype(o_ref.dtype)


def conv2d_gemm(x, w, *, block_f: int = 128, interpret: bool = False):
    """Stride-1 SAME conv. x: (B,H,W,C); w: (kh,kw,C,F) → (B,H,W,F)."""
    B, H, W, C = x.shape
    kh, kw, _, F = w.shape
    bf = min(block_f, F)
    while F % bf:
        bf -= 1
    ph, pw = kh // 2, kw // 2
    xp = jnp.pad(x, ((0, 0), (ph, kh - 1 - ph), (pw, kw - 1 - pw), (0, 0)))

    kernel = functools.partial(_conv_kernel, H=H, W=W, kh=kh, kw=kw, c=C, bf=bf)
    return pl.pallas_call(
        kernel,
        grid=(B, F // bf),
        in_specs=[
            pl.BlockSpec((None, H + kh - 1, W + kw - 1, C),
                         lambda b, f: (b, 0, 0, 0)),
            pl.BlockSpec((kh, kw, C, bf), lambda b, f: (0, 0, 0, f)),
        ],
        out_specs=pl.BlockSpec((None, H, W, bf), lambda b, f: (b, 0, 0, f)),
        out_shape=jax.ShapeDtypeStruct((B, H, W, F), x.dtype),
        interpret=interpret,
    )(xp, w)
