"""Implicit-GEMM conv2d Pallas kernel (the paper's CNN compute hot spot).

Hardware adaptation (DESIGN.md §6): cuDNN's implicit GEMM tiles for SMs/shared
memory; on TPU the conv is re-expressed as kh·kw shifted (Ho·Wo, C) × (C, F)
matmuls accumulated in fp32 — each contraction feeds the 128×128 MXU, the
image tile + filter block live in VMEM. Grid: (batch, F/BF). Input is
pre-padded in the wrapper so the kernel body is branch-free.

Strided convolutions (ResNet's stride-2 bottlenecks) decimate each shifted
patch with a slice-then-reshape — `(sh·Ho, …) → (Ho, sh, …)[:, 0]` — static
shapes only, no gather, so the same body serves every stride.

The halo-aware entry (``pad_h=False``) consumes a tile whose leading spatial
dim ALREADY carries its kh−1 boundary rows (the spatial-parallel halo
exchange delivered them — parallel/halo.py); only the W dim is padded here,
so the sharded path pays no second `jnp.pad` round-trip over H.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..util import largest_divisor


def _decimate(patch, Ho: int, Wo: int, sh: int, sw: int, c: int):
    """Keep every (sh, sw)-th pixel of a (sh·Ho, sw·Wo, C) patch."""
    if sh > 1:
        patch = patch.reshape(Ho, sh, patch.shape[1], c)[:, 0]
    if sw > 1:
        patch = patch.reshape(Ho, Wo, sw, c)[:, :, 0]
    return patch


def _conv_kernel(x_ref, w_ref, o_ref, *, Ho: int, Wo: int, kh: int, kw: int,
                 sh: int, sw: int, c: int, bf: int):
    x = x_ref[...]                      # (Hp, Wp, C) padded tile
    acc = jnp.zeros((Ho * Wo, bf), jnp.float32)
    for di in range(kh):
        for dj in range(kw):
            patch = jax.lax.dynamic_slice(x, (di, dj, 0),
                                          (sh * Ho, sw * Wo, c))
            mat = _decimate(patch, Ho, Wo, sh, sw, c).reshape(Ho * Wo, c)
            wk = w_ref[di, dj]          # (C, BF)
            acc += jax.lax.dot(mat, wk, preferred_element_type=jnp.float32)
    o_ref[...] = acc.reshape(Ho, Wo, bf).astype(o_ref.dtype)


def conv2d_gemm(x, w, *, strides=(1, 1), block_f: int = 128,
                pad_h: bool = True, interpret: bool = False):
    """SAME conv with arbitrary strides. x: (B,H,W,C); w: (kh,kw,C,F).

    ``pad_h=False`` is the halo-aware variant: H is treated as pre-padded —
    the tile already holds its kh−1 boundary rows (stride 1 only; the
    spatial executor never strides a halo conv) and the output has
    H − kh + 1 rows (VALID over H, SAME over W).
    """
    B, H, W, C = x.shape
    kh, kw, _, F = w.shape
    sh, sw = (strides, strides) if isinstance(strides, int) else strides
    if not pad_h and (sh, sw) != (1, 1):
        raise ValueError(f"halo-aware conv2d_gemm is stride-1 only, "
                         f"got strides={(sh, sw)}")
    Ho = H - kh + 1 if not pad_h else -(-H // sh)
    Wo = -(-W // sw)
    bf = largest_divisor(F, block_f)
    # padded extents cover the largest shifted patch, di + sh·Ho ≤ Hp
    Hp = (kh - 1) + sh * Ho
    Wp = (kw - 1) + sw * Wo
    if pad_h:
        lo_h = max((Ho - 1) * sh + kh - H, 0) // 2   # XLA SAME convention
        pads_h = (lo_h, Hp - H - lo_h)
    else:
        pads_h = (0, Hp - H)                          # Hp == H: no-op
    lo_w = max((Wo - 1) * sw + kw - W, 0) // 2
    xp = jnp.pad(x, ((0, 0), pads_h, (lo_w, Wp - W - lo_w), (0, 0)))

    kernel = functools.partial(_conv_kernel, Ho=Ho, Wo=Wo, kh=kh, kw=kw,
                               sh=sh, sw=sw, c=C, bf=bf)
    return pl.pallas_call(
        kernel,
        grid=(B, F // bf),
        in_specs=[
            pl.BlockSpec((None, Hp, Wp, C), lambda b, f: (b, 0, 0, 0)),
            pl.BlockSpec((kh, kw, C, bf), lambda b, f: (0, 0, 0, f)),
        ],
        out_specs=pl.BlockSpec((None, Ho, Wo, bf), lambda b, f: (b, 0, 0, f)),
        out_shape=jax.ShapeDtypeStruct((B, Ho, Wo, F), x.dtype),
        interpret=interpret,
    )(xp, w)
