from __future__ import annotations

from functools import partial

import jax

from .conv2d_gemm import conv2d_gemm as _conv2d_gemm
from .ref import conv2d_ref


@partial(jax.jit, static_argnames=("strides", "block_f", "pad_h", "interpret"))
def conv2d_gemm(x, w, *, strides=(1, 1), block_f: int = 128,
                pad_h: bool = True, interpret: bool = False):
    return _conv2d_gemm(x, w, strides=strides, block_f=block_f,
                        pad_h=pad_h, interpret=interpret)


__all__ = ["conv2d_gemm", "conv2d_ref"]
