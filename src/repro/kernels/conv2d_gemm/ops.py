from __future__ import annotations

from functools import partial

import jax

from .conv2d_gemm import conv2d_gemm as _conv2d_gemm
from .ref import conv2d_ref


@partial(jax.jit, static_argnames=("block_f", "interpret"))
def conv2d_gemm(x, w, *, block_f: int = 128, interpret: bool = False):
    return _conv2d_gemm(x, w, block_f=block_f, interpret=interpret)


__all__ = ["conv2d_gemm", "conv2d_ref"]
