"""Pure-jnp oracle: stride-1 SAME 2-D convolution (channels-last)."""
import jax


def conv2d_ref(x, w):
    """x: (B, H, W, C); w: (kh, kw, C, F) → (B, H, W, F)."""
    dn = jax.lax.conv_dimension_numbers(x.shape, w.shape,
                                        ("NHWC", "HWIO", "NHWC"))
    return jax.lax.conv_general_dilated(x, w, (1, 1), "SAME",
                                        dimension_numbers=dn)
