"""Pure-jnp oracle: SAME 2-D convolution, any stride (channels-last)."""
import jax


def conv2d_ref(x, w, strides=(1, 1)):
    """x: (B, H, W, C); w: (kh, kw, C, F) → (B, ⌈H/sh⌉, ⌈W/sw⌉, F)."""
    sh, sw = (strides, strides) if isinstance(strides, int) else strides
    dn = jax.lax.conv_dimension_numbers(x.shape, w.shape,
                                        ("NHWC", "HWIO", "NHWC"))
    return jax.lax.conv_general_dilated(x, w, (sh, sw), "SAME",
                                        dimension_numbers=dn)
