"""Checkpointing: sharded pytree save/restore with a manifest + atomicity.

Layout:  <dir>/step_<n>/
           manifest.json    (step, tree structure, shapes/dtypes, config hash)
           arrays.npz       (leaves, addressable data)
           .complete        (commit marker — written last; readers ignore
                             checkpoints without it, so a crash mid-write
                             never corrupts restore)

``save`` can run in a background thread (async checkpointing: the train loop
donates nothing and continues while the host thread serializes), and
``latest_step``/``restore`` implement the fault-tolerant restart contract
used by runtime/fault_tolerance.py. Directory mutation (commit-rename and
retention GC) and the read paths share one lock, so an async save's GC can
never yank a checkpoint out from under a concurrent ``completed_steps`` /
``restore`` — the elastic controller reads while saves are in flight.
"""
from __future__ import annotations

import hashlib
import json
import shutil
import threading
from pathlib import Path

import jax
import numpy as np


def _flatten(tree, prefix=""):
    if isinstance(tree, dict):
        for k in sorted(tree):
            yield from _flatten(tree[k], f"{prefix}/{k}")
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            yield from _flatten(v, f"{prefix}/{i}")
    elif tree is None:
        return
    else:
        yield prefix, tree


def _unflatten_into(skeleton, leaves: dict, prefix=""):
    if isinstance(skeleton, dict):
        return {k: _unflatten_into(skeleton[k], leaves, f"{prefix}/{k}")
                for k in sorted(skeleton)}
    if isinstance(skeleton, (list, tuple)):
        out = [_unflatten_into(v, leaves, f"{prefix}/{i}")
               for i, v in enumerate(skeleton)]
        return type(skeleton)(out) if isinstance(skeleton, tuple) else out
    if skeleton is None:
        return None
    return leaves[prefix]


def config_hash(obj) -> str:
    return hashlib.sha256(repr(obj).encode()).hexdigest()[:16]


class Checkpointer:
    def __init__(self, directory: str | Path, keep: int = 3,
                 config_tag: str = ""):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.config_tag = config_tag
        self._thread: threading.Thread | None = None
        # serializes directory mutation (commit, GC) against readers; RLock
        # because _gc runs under save's commit section which already holds it
        self._lock = threading.RLock()

    # -- write ------------------------------------------------------------
    def save(self, state, step: int, blocking: bool = True) -> Path:
        leaves = {p: np.asarray(jax.device_get(v))
                  for p, v in _flatten(state)}
        manifest = {
            "step": int(step),
            "config_tag": self.config_tag,
            "leaves": {p: {"shape": list(v.shape), "dtype": str(v.dtype)}
                       for p, v in leaves.items()},
        }

        def write():
            path = self.dir / f"step_{step:08d}"
            tmp = self.dir / f".tmp_step_{step:08d}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            np.savez(tmp / "arrays.npz",
                     **{p.replace("/", "|"): v for p, v in leaves.items()})
            (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
            (tmp / ".complete").write_text("ok")
            with self._lock:
                if path.exists():
                    shutil.rmtree(path)
                tmp.rename(path)
                self._gc()

        if blocking:
            write()
        else:
            self.wait()
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        return self.dir / f"step_{step:08d}"

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        with self._lock:
            steps = sorted(self.completed_steps())
            for s in steps[:-self.keep]:
                shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # -- read -------------------------------------------------------------
    def completed_steps(self) -> list[int]:
        with self._lock:
            out = []
            for p in self.dir.glob("step_*"):
                if (p / ".complete").exists():
                    out.append(int(p.name.split("_")[1]))
            return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.completed_steps()
        return steps[-1] if steps else None

    def restore(self, skeleton, step: int | None = None, shardings=None):
        """Restore into the structure of ``skeleton``; optionally re-shard
        (elastic restart onto a different mesh)."""
        # the lock pins the chosen step until its leaves are fully in
        # memory — a concurrent async save's GC cannot remove it mid-read
        with self._lock:
            step = step if step is not None else self.latest_step()
            if step is None:
                raise FileNotFoundError(
                    f"no complete checkpoint in {self.dir}")
            path = self.dir / f"step_{step:08d}"
            manifest = json.loads((path / "manifest.json").read_text())
            if self.config_tag and manifest["config_tag"] and \
                    manifest["config_tag"] != self.config_tag:
                raise ValueError(
                    f"checkpoint config_tag {manifest['config_tag']} != "
                    f"{self.config_tag}: refusing to restore a mismatched "
                    f"model")
            npz = np.load(path / "arrays.npz")
            leaves = {k.replace("|", "/"): npz[k] for k in npz.files}
        tree = _unflatten_into(skeleton, leaves)
        if shardings is not None:
            tree = jax.tree.map(
                lambda x, s: jax.device_put(x, s), tree, shardings)
        return tree, step
