"""Deterministic sharded data pipeline.

Synthetic token / image sources with per-step seeding: batch t of run seed s
is a pure function of (s, t) — so a restarted job resumes the exact stream
(fault-tolerance requirement), and each host materializes only its shard
(addressable-device feeding at scale; on this box the mesh is local so the
global batch is device_put against the batch sharding).

A real deployment swaps ``TokenSource`` for a file-backed reader with the
same (seed, step) → batch contract; everything downstream is unchanged.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclass(frozen=True)
class DataConfig:
    kind: str                 # "lm" | "encdec" | "vlm" | "image" | "volume"
    batch: int
    seq_len: int = 0
    vocab: int = 0
    image: int = 0
    channels: int = 3
    frames: int = 0
    d_frames: int = 0
    n_patches: int = 0
    d_vision: int = 0
    classes: int = 0
    n_targets: int = 0
    seed: int = 0


class TokenSource:
    """Synthetic LM stream with Zipf-ish marginals + a learnable bigram
    structure (so tiny-model training loss visibly decreases)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab
        self._next = rng.integers(0, v, size=(v,), dtype=np.int32)

    def batch_at(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        first = rng.integers(0, cfg.vocab, size=(cfg.batch, 1), dtype=np.int32)
        toks = [first[:, 0]]
        noise = rng.random((cfg.batch, cfg.seq_len - 1)) < 0.15
        for t in range(cfg.seq_len - 1):
            nxt = self._next[toks[-1]]
            rand = rng.integers(0, cfg.vocab, size=(cfg.batch,), dtype=np.int32)
            toks.append(np.where(noise[:, t], rand, nxt).astype(np.int32))
        return {"tokens": np.stack(toks, axis=1)}


class SyntheticSource:
    """Gaussian images / volumes / frame-embeddings with labeled targets."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def batch_at(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step, 7))
        if cfg.kind == "image":
            return {"images": rng.standard_normal(
                        (cfg.batch, cfg.image, cfg.image, cfg.channels),
                        dtype=np.float32),
                    "labels": rng.integers(0, cfg.classes, (cfg.batch,),
                                           dtype=np.int32)}
        if cfg.kind == "volume":
            x = rng.standard_normal(
                (cfg.batch, cfg.image, cfg.image, cfg.image, cfg.channels),
                dtype=np.float32)
            # CosmoFlow-style targets: a fixed linear functional of the volume
            t = np.stack([x[:, ::2].mean((1, 2, 3, 4)),
                          x[:, :, ::2].std((1, 2, 3, 4)),
                          x.mean((1, 2, 3, 4)),
                          x.std((1, 2, 3, 4))], axis=1)[:, :cfg.n_targets]
            return {"images": x, "targets": t.astype(np.float32)}
        if cfg.kind == "encdec":
            tok = TokenSource(cfg).batch_at(step)
            frames = rng.standard_normal(
                (cfg.batch, cfg.frames, cfg.d_frames), dtype=np.float32)
            return {"frames": frames, **tok}
        if cfg.kind == "vlm":
            tok = TokenSource(cfg).batch_at(step)
            patches = rng.standard_normal(
                (cfg.batch, cfg.n_patches, cfg.d_vision), dtype=np.float32)
            return {"patches": patches, **tok}
        raise ValueError(cfg.kind)


def make_source(cfg: DataConfig):
    return TokenSource(cfg) if cfg.kind == "lm" else SyntheticSource(cfg)


class ShardedLoader:
    """Iterates (seed, step)-addressable batches, placed per batch sharding."""

    def __init__(self, cfg: DataConfig, mesh: Mesh | None = None,
                 batch_axes: tuple = ("pod", "data")):
        self.cfg = cfg
        self.source = make_source(cfg)
        self.mesh = mesh
        self.batch_axes = batch_axes

    def _place(self, batch: dict) -> dict:
        if self.mesh is None:
            return {k: jnp.asarray(v) for k, v in batch.items()}
        axes = tuple(a for a in self.batch_axes if a in self.mesh.shape)
        out = {}
        for k, v in batch.items():
            spec = P(axes, *([None] * (v.ndim - 1)))
            out[k] = jax.device_put(v, NamedSharding(self.mesh, spec))
        return out

    def batch_at(self, step: int) -> dict:
        return self._place(self.source.batch_at(step))

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
