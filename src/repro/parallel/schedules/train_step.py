"""The deployable pipeline train step, schedule- and model-polymorphic.

``make_pipeline_train_step`` matches the ``make_train_step`` contract
((state, batch) → (state, metrics)) so every launch entry point can deploy
it, and routes between two realizations:

  * **stacked fast path** — uniform-pattern TransformerLM: the (L, ...)
    stacked block params shard over the stage axis (stages.py layouts), the
    embed and head run replicated outside the pipe;
  * **hetero path** — CNN trunks (ResNet/VGG/CosmoFlow, stem through head
    inside the pipe) and mixed LM patterns: per-stage program
    specialization over replicated params with a flat activation buffer
    (hetero.py).

Either path runs any of the three schedule executors (runtime.py):
``gpipe``, ``one_f_one_b``, ``interleaved``. Gradient-exactness vs the
serial step holds for every schedule and both paths, with one caveat:
ResNet/VGG BatchNorm computes batch statistics per *microbatch* under the
pipe (paper §4.5.2 local-BN semantics), so their gradients match a serial
step at the microbatch size, not the full batch — CosmoFlow (no BN) and
all LMs match the full-batch serial step bit-for-bit at matched precision.
"""
from __future__ import annotations

import warnings

import jax
import jax.numpy as jnp

from .runtime import SCHEDULE_NAMES, gpipe, interleaved, one_f_one_b
from .stages import (make_masked_stage_fn, make_virtual_stage_fn,
                     stack_stage_bounds, stack_virtual_stage_bounds)
from .hetero import (boundary_shapes, from_buffer, make_switch_stage_fns,
                     model_pipe_blocks)


def pipeline_supported(model_or_cfg) -> str | None:
    """None when a pipeline schedule can deploy this model, else the reason.

    The stacked executors cover uniform TransformerLM patterns; per-stage
    program specialization (hetero.py) extends deployment to mixed LM
    patterns (incl. ``first_k_dense`` leads) and the CNN trunks. Still out:
    MoE (aux losses do not flow through the stage schedule), MTP heads
    (branch off mid-trunk hidden), and model families with no block
    decomposition.
    """
    from ...models.cnn import CosmoFlowConfig, ResNetConfig, VGGConfig
    from ...models.transformer import LMConfig
    cfg = getattr(model_or_cfg, "cfg", model_or_cfg)
    if isinstance(cfg, (ResNetConfig, VGGConfig, CosmoFlowConfig)):
        return None
    if not isinstance(cfg, LMConfig):
        return (f"{type(cfg).__name__}: no pipeline block decomposition "
                f"(TransformerLM trunks and the paper's CNNs pipeline)")
    if "moe" in cfg.block_kinds():
        return "MoE aux losses do not flow through the stage schedule"
    if cfg.mtp_heads:
        return "MTP heads branch off the mid-trunk hidden state"
    return None


def clip_segments(batch: int, segments: int) -> int:
    """Largest microbatch-segment count ≤ ``segments`` dividing ``batch``."""
    s = max(min(int(segments), int(batch)), 1)
    while batch % s:
        s -= 1
    return s


def resolve_segments(batch: int, segments: int,
                     multiple_of: int = 1) -> int:
    """``clip_segments`` that surfaces silent degradation.

    Returns the largest S ≤ ``segments`` that divides ``batch`` (and is a
    multiple of ``multiple_of`` — the interleaved schedule's S % p == 0
    constraint), warning when the pipe runs with fewer microbatches than
    requested: a prime batch clips all the way to S=1, which serializes the
    pipeline (bubble (p−1)/S = p−1 stages idle per stage-tick).
    """
    batch, m = int(batch), max(int(multiple_of), 1)
    s = max(min(int(segments), batch), 1)
    while s > 0 and (batch % s or s % m):
        s -= 1
    if s < 1:
        raise ValueError(
            f"no segment count ≤ {segments} divides batch {batch} and is a "
            f"multiple of {m} (the interleaved schedule needs S % p == 0)")
    if s < int(segments):
        warnings.warn(
            f"pipeline segments clipped: requested {segments}, running "
            f"S={s} (batch {batch}"
            + (f", S must be a multiple of p={m}" if m > 1 else "")
            + (") — the pipe is fully serialized" if s == 1 else ")"),
            stacklevel=2)
    return s


def _run_schedule(schedule, stage_fn, stage_params, mbs, mesh, axis,
                  virtual_stages, shard_params):
    if schedule == "gpipe":
        return gpipe(stage_fn, stage_params, mbs, mesh, axis,
                     shard_params=shard_params)
    if schedule == "one_f_one_b":
        return one_f_one_b(stage_fn, stage_params, mbs, mesh, axis,
                           shard_params=shard_params)
    return interleaved(stage_fn, stage_params, mbs, mesh, axis,
                       virtual_stages=virtual_stages,
                       shard_params=shard_params)


def make_pipeline_train_step(model, opt, ctx, segments: int = 8,
                             block_costs=None, axis: str = "model",
                             schedule: str = "gpipe",
                             virtual_stages: int = 2, **fwd_kw):
    """Pipeline train step: (state, batch) → (state, metrics).

    Stages = the mesh's ``axis`` extent; cuts come from the DP min-max
    partition (core/partition.py) of ``block_costs`` — per-block fw+bw
    costs, e.g. ``pipeline_block_costs`` over the oracle's layer table —
    defaulting to the decomposition's own weights (uniform when no stats
    were attached). ``segments`` is the *requested* microbatch count; the
    step clips it to the batch (and, for ``interleaved``, to a multiple of
    the stage count), warns on degradation, and reports the running value
    as ``metrics["pipeline_segments"]``. ``schedule`` picks the executor
    (``gpipe`` / ``one_f_one_b`` / ``interleaved``, DESIGN.md §4);
    ``virtual_stages`` is the interleaved v. Extra kwargs are filtered to
    the attention kwargs of ``Block.apply`` (attn_impl / q_chunk /
    kv_chunk) — callers may pass their full forward-kwarg dict.
    """
    import numpy as np
    from ...core.partition import min_max_partition
    from ...models.cnn import CosmoFlow, ResNet, VGG, _softmax_xent
    from ...models.transformer import TransformerLM
    from ...optim.optimizers import apply_update

    if schedule not in SCHEDULE_NAMES:
        raise ValueError(f"unknown schedule {schedule!r}; "
                         f"pick one of {SCHEDULE_NAMES}")
    reason = pipeline_supported(model)
    if reason is not None:
        raise NotImplementedError(f"pipeline cannot deploy: {reason}")
    mesh = ctx.mesh
    if mesh is None or axis not in mesh.shape:
        raise ValueError(f"pipeline needs a mesh with a {axis!r} axis")
    n_stages = int(mesh.shape[axis])
    v = int(virtual_stages) if schedule == "interleaved" else 1
    if v < 1:
        raise ValueError(f"virtual_stages must be >= 1, got {v}")
    n_chunks = n_stages * v
    seg_multiple = n_stages if schedule == "interleaved" else 1

    c = model.cfg
    uniform_lm = (isinstance(model, TransformerLM)
                  and len(c.pattern) == 1 and not c.first_k_dense)

    if uniform_lm:
        L = c.n_layers
    else:
        blocks = model_pipe_blocks(model, None, **fwd_kw)
        L = len(blocks)
    if n_chunks > L:
        raise ValueError(
            f"{n_stages} stages × {v} virtual exceed {L} blocks")
    if block_costs is None:
        block_costs = (np.ones(L) if uniform_lm
                       else np.asarray([b.cost for b in blocks]))
    if len(block_costs) != L:
        raise ValueError(f"{len(block_costs)} block costs for {L} blocks")
    bounds = min_max_partition(block_costs, n_chunks).bounds

    def xent_of(params, logits, tokens, batch):
        from ...models.transformer import _xent
        targets = batch.get("targets")
        if targets is None:
            targets = jnp.pad(tokens[:, 1:], ((0, 0), (0, 1)))
        mask_t = batch.get("mask", jnp.ones(tokens.shape, jnp.float32))
        ce = jnp.sum(_xent(logits, targets) * mask_t) / \
            jnp.maximum(jnp.sum(mask_t), 1.0)
        return ce, {"ce": ce}

    if uniform_lm:
        from ...models.transformer import Block
        from ...nn.module import NULL_CTX
        blk = Block(c, c.pattern[0])
        kw = {k: vv for k, vv in fwd_kw.items()
              if k in ("attn_impl", "q_chunk", "kv_chunk")}

        def block_apply(bp, h):
            # NULL_CTX: no sharding constraints inside the shard_map body
            y, _aux = blk.apply(bp, h, NULL_CTX, **kw)
            return y

        stage_fn = make_masked_stage_fn(block_apply)
        vstage_fn = make_virtual_stage_fn(block_apply)

        def pipe(params, x, S):
            B = x.shape[0]
            mb = x.reshape(S, B // S, *x.shape[1:])
            if schedule == "interleaved":
                stages, mask = stack_virtual_stage_bounds(
                    params["stacks"][0], bounds, n_stages, v)
                out = _run_schedule(schedule, vstage_fn,
                                    {"layers": stages, "mask": mask},
                                    mb, mesh, axis, v, True)
            else:
                stages, mask = stack_stage_bounds(params["stacks"][0],
                                                  bounds)
                out = _run_schedule(schedule, stage_fn,
                                    {"layers": stages, "mask": mask},
                                    mb, mesh, axis, v, True)
            return out.reshape(B, *out.shape[2:]).astype(x.dtype)

        def loss_of(params, batch, S):
            tokens = batch["tokens"]
            h = model._embed(params, tokens, ctx)
            h2 = pipe(params, h, S)
            logits = model._logits(params, h2, ctx)
            return xent_of(params, logits, tokens, batch)

        batch_of = lambda batch: batch["tokens"].shape[0]  # noqa: E731
    else:
        is_cnn = isinstance(model, (ResNet, VGG, CosmoFlow))

        def pipe(params, x, S):
            B = x.shape[0]
            shapes = boundary_shapes(blocks, params, x)
            stage_fn, vstage_fn, K = make_switch_stage_fns(
                blocks, bounds, shapes, axis, n_stages)
            flat = x.reshape(S, B // S, -1)
            if flat.shape[-1] < K:
                flat = jnp.pad(
                    flat, ((0, 0), (0, 0), (0, K - flat.shape[-1])))
            fn = vstage_fn if schedule == "interleaved" else stage_fn
            out = _run_schedule(schedule, fn, params, flat, mesh, axis,
                                v, False)
            return from_buffer(out.reshape(B, K), shapes[-1], x.dtype)

        if is_cnn:
            def loss_of(params, batch, S):
                out = pipe(params, batch["images"], S)
                if isinstance(model, CosmoFlow):
                    mse = jnp.mean((out - batch["targets"]) ** 2)
                    return mse, {"mse": mse}
                ce = _softmax_xent(out, batch["labels"])
                return ce, {"ce": ce}

            batch_of = lambda batch: batch["images"].shape[0]  # noqa: E731
        else:
            def loss_of(params, batch, S):
                tokens = batch["tokens"]
                h = model._embed(params, tokens, ctx)
                h2 = pipe(params, h, S)
                logits = model._logits(params, h2, ctx)
                return xent_of(params, logits, tokens, batch)

            batch_of = lambda batch: batch["tokens"].shape[0]  # noqa: E731

    def train_step(state, batch):
        B = batch_of(batch)
        S = resolve_segments(B, segments, seg_multiple)

        (loss, metrics), grads = jax.value_and_grad(
            loss_of, has_aux=True)(state["params"], batch, S)
        new_params, new_opt, om = apply_update(opt, state["params"], grads,
                                               state["opt"], state["step"])
        metrics = dict(metrics, loss=loss,
                       pipeline_segments=jnp.asarray(S), **om)
        return {"params": new_params, "opt": new_opt,
                "step": state["step"] + 1}, metrics

    return train_step
