"""Pipeline parallelism, schedule-diverse — paper §3.4 "Layer" strategy.

The package splits the old ``parallel/pipeline.py`` into the three layers a
schedule engine actually has:

  * ``runtime``  — the executors (``gpipe`` / ``one_f_one_b`` /
    ``interleaved``): pure shard_map+ppermute clockings over an opaque
    stage function;
  * ``stages``   — stacked parameter layouts for uniform TransformerLM
    trunks (equal, DP-cut, and interleaved-virtual chunkings);
  * ``hetero``   — per-stage program specialization for CNN trunks and
    mixed LM patterns (PipeBlock decomposition + lax.switch stage
    programs over a flat activation buffer);
  * ``train_step`` — the deployable step that routes a model onto the
    right layout and executor.

``repro.parallel.pipeline`` remains importable as a compatibility shim.
"""
from .hetero import (PipeBlock, model_pipe_blocks, pipeline_block_costs,
                     pipeline_block_count)
from .runtime import (SCHEDULE_NAMES, SCHEDULES, gpipe, interleaved,
                      one_f_one_b)
from .stages import (block_costs_from_stats, make_masked_stage_fn,
                     make_stage_fn, make_virtual_stage_fn, stack_stage_bounds,
                     stack_stages, stack_virtual_stage_bounds)
from .train_step import (clip_segments, make_pipeline_train_step,
                         pipeline_supported, resolve_segments)

__all__ = [
    "PipeBlock",
    "SCHEDULES",
    "SCHEDULE_NAMES",
    "block_costs_from_stats",
    "clip_segments",
    "gpipe",
    "interleaved",
    "make_masked_stage_fn",
    "make_pipeline_train_step",
    "make_stage_fn",
    "make_virtual_stage_fn",
    "model_pipe_blocks",
    "one_f_one_b",
    "pipeline_block_costs",
    "pipeline_block_count",
    "pipeline_supported",
    "resolve_segments",
    "stack_stage_bounds",
    "stack_stages",
    "stack_virtual_stage_bounds",
]
