"""Per-stage program specialization for heterogeneous pipeline trunks.

Uniform TransformerLM stacks pipeline by sharding their stacked block params
over the stage axis (stages.py). CNN trunks and mixed LM patterns cannot:
blocks differ in parameter structure AND activation shape (spatial
downsampling), so there is no stacked-leaf layout to shard. Instead each
model decomposes into an ordered list of :class:`PipeBlock` closures over
the *full* (replicated) parameter tree, activations travel the pipe as a
flat padded buffer sized to the largest stage boundary, and every rank runs
a ``lax.switch`` on its axis index that selects its specialized stage
program — SPMD-valid (one program), while each branch unflattens its own
input shape, applies its contiguous block slice, and reflattens.

Gradients are exact: ``lax.switch`` routes cotangents only through the
selected branch, and the shard_map transpose psums the per-rank (zero
except own-stage) parameter cotangents into the full gradient.

The trade against the stacked path: parameters are replicated across ranks
(each rank touches only its slice, but holds all of them) — the right
realization for the host executor; a memory-sharded variant would gather
per-stage subsets instead.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class PipeBlock:
    """One schedulable unit of a heterogeneous trunk.

    ``apply(full_params, x) -> y`` maps a batched activation through the
    block; ``cost`` is the fw+bw FLOP weight the DP partitioner cuts on.
    """
    name: str
    apply: Callable
    cost: float = 1.0


def model_pipe_blocks(model, stats=None, **fwd_kw) -> list[PipeBlock]:
    """Decompose a model into pipeline blocks (full forward for CNNs —
    stem through head; trunk layers only for LMs, whose embed/head run
    replicated outside the pipe).

    ``stats`` (the oracle's per-layer table) supplies per-block fw+bw costs
    — exact backward FLOPs when the extractor recorded them
    (``flops_bwd_exact``), else the 2×fw approximation; uniform costs
    without stats.
    """
    from ...models.cnn import CosmoFlow, ResNet, VGG
    from ...models.transformer import TransformerLM
    if isinstance(model, ResNet):
        return _resnet_blocks(model, stats)
    if isinstance(model, VGG):
        return _vgg_blocks(model, stats)
    if isinstance(model, CosmoFlow):
        return _cosmoflow_blocks(model, stats)
    if isinstance(model, TransformerLM):
        return _lm_layer_blocks(model, stats, **fwd_kw)
    raise NotImplementedError(
        f"{type(model).__name__}: no pipeline block decomposition")


def pipeline_block_count(cfg) -> int | None:
    """Schedulable block count for a model config (the executor's stage
    ceiling — distinct from the oracle's stat-layer count G), or None when
    the model cannot pipeline."""
    from ...models.cnn import CosmoFlowConfig, ResNetConfig, VGGConfig
    from ...models.transformer import LMConfig
    if isinstance(cfg, ResNetConfig):
        return 2 + sum(cfg.stage_sizes)          # stem + bottlenecks + head
    if isinstance(cfg, VGGConfig):
        from ...models.cnn import _VGG16_LAYOUT
        return sum(1 for x in _VGG16_LAYOUT if x != "M") + 1   # convs + head
    if isinstance(cfg, CosmoFlowConfig):
        return cfg.n_conv + 1                    # conv blocks + head
    if isinstance(cfg, LMConfig):
        return cfg.n_layers                      # embed/head stay outside
    return None


def pipeline_block_costs(model, stats=None, **fwd_kw):
    """Per-block fw+bw cost vector for the DP stage partitioner — the
    model's pipeline decomposition weighted by the oracle's layer stats
    (exact backward FLOPs when recorded)."""
    import numpy as np
    return np.asarray(
        [b.cost for b in model_pipe_blocks(model, stats, **fwd_kw)])


def _stat_cost(st) -> float:
    return st.flops_fwd + (st.flops_bwd_exact or 2.0 * st.flops_fwd)


def _grouped_costs(names: list[str], stats) -> list[float]:
    """Sum stat costs onto blocks by longest-prefix name match; blocks with
    no matching stats (or no stats at all) get uniform weight 1."""
    if stats is None:
        return [1.0] * len(names)
    costs = [0.0] * len(names)
    for st in stats:
        best = None
        for i, nm in enumerate(names):
            if st.name == nm or st.name.startswith(nm):
                if best is None or len(names[best]) < len(nm):
                    best = i
        if best is not None:
            costs[best] += _stat_cost(st)
    return costs if any(costs) else [1.0] * len(names)


def _resnet_blocks(model, stats) -> list[PipeBlock]:
    from ...models.cnn import BatchNorm, Dense, HaloConv, global_avg_pool, \
        max_pool
    from ...nn.module import NULL_CTX
    c = model.cfg

    def stem(params, x):
        h = HaloConv(3, c.width, (7, 7), strides=(2, 2), use_bias=False,
                     dtype=c.dtype).apply(params["stem"], x, NULL_CTX)
        h = jax.nn.relu(
            BatchNorm(c.width).apply(params["bn_stem"], h, NULL_CTX, True))
        return max_pool(h, (3, 3), (2, 2), "SAME")

    def head(params, x):
        h = global_avg_pool(x)
        return Dense(512 * 4, c.n_classes, use_bias=True, in_axis="mlp",
                     out_axis="vocab", dtype=c.dtype).apply(
                         params["head"], h, NULL_CTX)

    names, applies = ["stem"], [stem]
    bottlenecks = model._blocks()
    i = 0
    for stage, n in enumerate(c.stage_sizes):
        for bb in range(n):
            blk = bottlenecks[i]
            applies.append(lambda params, x, blk=blk, i=i: blk.apply(
                params["blocks"][i], x, NULL_CTX, True))
            names.append(f"s{stage}b{bb}")
            i += 1
    names.append("head")
    applies.append(head)
    costs = _grouped_costs(names, stats)
    return [PipeBlock(nm, ap, ct)
            for nm, ap, ct in zip(names, applies, costs)]


def _vgg_blocks(model, stats) -> list[PipeBlock]:
    from ...models.cnn import _VGG16_LAYOUT, Dense, max_pool
    from ...nn.module import NULL_CTX
    c = model.cfg
    convs = [x for x in model._convs() if x != "M"]
    pool_after = []
    ci = -1
    for x in _VGG16_LAYOUT:
        if x == "M":
            pool_after[ci] = True
        else:
            ci += 1
            pool_after.append(False)

    names, applies = [], []
    for i, conv in enumerate(convs):
        def conv_block(params, x, conv=conv, i=i, pool=pool_after[i]):
            h = jax.nn.relu(conv.apply(params["convs"][i], x, NULL_CTX))
            return max_pool(h, (2, 2), (2, 2), "VALID") if pool else h
        names.append(f"conv{i}")
        applies.append(conv_block)

    feat = c.img // 32

    def head(params, x):
        h = x.reshape(x.shape[0], -1)
        h = jax.nn.relu(Dense(512 * feat * feat, 4096, use_bias=True,
                              in_axis="mlp", out_axis="embed",
                              dtype=c.dtype).apply(params["fc1"], h, NULL_CTX))
        h = jax.nn.relu(Dense(4096, 4096, use_bias=True, in_axis="embed",
                              out_axis="mlp", dtype=c.dtype).apply(
                                  params["fc2"], h, NULL_CTX))
        return Dense(4096, c.n_classes, use_bias=True, in_axis="mlp",
                     out_axis="vocab", dtype=c.dtype).apply(
                         params["fc3"], h, NULL_CTX)

    names.append("fc")
    applies.append(head)
    costs = _grouped_costs(names, stats)
    return [PipeBlock(nm, ap, ct)
            for nm, ap, ct in zip(names, applies, costs)]


def _cosmoflow_blocks(model, stats) -> list[PipeBlock]:
    from ...models.cnn import Dense, max_pool
    from ...nn.module import NULL_CTX
    c = model.cfg
    names, applies = [], []
    for i, conv in enumerate(model._convs()):
        def conv_block(params, x, conv=conv, i=i):
            h = jax.nn.leaky_relu(conv.apply(params["convs"][i], x, NULL_CTX))
            return max_pool(h, (2, 2, 2), (2, 2, 2), "VALID")
        names.append(f"conv{i}")
        applies.append(conv_block)

    def head(params, x):
        h = x.reshape(x.shape[0], -1)
        h = jax.nn.leaky_relu(
            Dense(model._flat_dim(), 128, use_bias=True, in_axis="mlp",
                  out_axis="embed", dtype=c.dtype).apply(
                      params["fc1"], h, NULL_CTX))
        h = jax.nn.leaky_relu(
            Dense(128, 64, use_bias=True, in_axis="embed", out_axis="mlp",
                  dtype=c.dtype).apply(params["fc2"], h, NULL_CTX))
        return Dense(64, c.n_targets, use_bias=True, in_axis="mlp",
                     out_axis=None, dtype=c.dtype).apply(
                         params["out"], h, NULL_CTX)

    names.append("fc")
    applies.append(head)
    costs = _grouped_costs(names, stats)
    return [PipeBlock(nm, ap, ct)
            for nm, ap, ct in zip(names, applies, costs)]


def _lm_layer_blocks(model, stats, **fwd_kw) -> list[PipeBlock]:
    """Mixed-pattern trunks: one PipeBlock per layer, each closing over the
    layer's position in the lead/stacks/tail parameter layout."""
    from ...models.transformer import Block
    from ...nn.module import NULL_CTX
    from .stages import block_costs_from_stats
    c = model.cfg
    period, n_groups, rem = model._groups()
    kw = {k: v for k, v in fwd_kw.items()
          if k in ("attn_impl", "q_chunk", "kv_chunk")}

    def layer_block(j: int) -> PipeBlock:
        if j < c.first_k_dense:
            kind, get = "attn", (lambda p, j=j: p["lead"][j])
        else:
            i = j - c.first_k_dense
            g, pos = divmod(i, period)
            if g < n_groups:
                kind = c.pattern[pos]
                get = lambda p, g=g, pos=pos: jax.tree.map(  # noqa: E731
                    lambda x: x[g], p["stacks"][pos])
            else:
                r = i - n_groups * period
                kind, get = rem[r], (lambda p, r=r: p["tail"][r])
        blk = Block(c, kind)

        def run(params, h):
            y, _aux = blk.apply(get(params), h, NULL_CTX, **kw)
            return y

        return PipeBlock(f"L{j}.{kind}", run, 1.0)

    blocks = [layer_block(j) for j in range(c.n_layers)]
    if stats is not None:
        costs = block_costs_from_stats(stats, c.n_layers)
        blocks = [PipeBlock(b.name, b.apply, float(ct))
                  for b, ct in zip(blocks, costs)]
    return blocks


# ---------------------------------------------------------------------------
# Flat activation buffer + switch-specialized stage programs
# ---------------------------------------------------------------------------

def boundary_shapes(blocks: list[PipeBlock], params, x0) -> list[tuple]:
    """Per-sample activation shape entering each block, plus the final
    output shape (len(blocks)+1 entries). Shape-only evaluation — works on
    tracers and concrete params alike."""
    aparams = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), params)
    x = jax.ShapeDtypeStruct(x0.shape, x0.dtype)
    shapes = [tuple(x.shape[1:])]
    for blk in blocks:
        x = jax.eval_shape(blk.apply, aparams, x)
        shapes.append(tuple(x.shape[1:]))
    return shapes


def make_switch_stage_fns(blocks: list[PipeBlock], bounds, shapes,
                          axis: str, n_stages: int):
    """Specialized stage programs for a heterogeneous trunk.

    Returns (stage_fn, virtual_stage_fn, K): ``stage_fn(params, buf)``
    switches on the rank index (gpipe / 1F1B over p = len(bounds)−1
    stages); ``virtual_stage_fn(params, buf, q)`` switches on the global
    chunk index q·p + rank (interleaved). K is the flat buffer width — the
    largest per-sample boundary activation, zero-padded so one ppermute
    carrier shape serves every stage boundary.
    """
    bounds = tuple(int(b) for b in bounds)
    sizes = [int(math.prod(s)) for s in shapes]
    K = max(sizes[b] for b in bounds) if bounds else max(sizes)
    K = max(K, sizes[-1])

    def branch(b0: int, b1: int):
        ishape, isize = shapes[b0], sizes[b0]

        def run(params, buf):
            mb = buf.shape[0]
            x = buf[:, :isize].reshape(mb, *ishape)
            for blk in blocks[b0:b1]:
                x = blk.apply(params, x)
            y = x.reshape(mb, -1)
            if y.shape[1] < K:
                y = jnp.pad(y, ((0, 0), (0, K - y.shape[1])))
            return y.astype(buf.dtype)

        return run

    branches = [branch(bounds[j], bounds[j + 1])
                for j in range(len(bounds) - 1)]

    def stage_fn(params, buf):
        idx = jax.lax.axis_index(axis)
        return jax.lax.switch(idx, branches, params, buf)

    def virtual_stage_fn(params, buf, q):
        idx = jax.lax.axis_index(axis)
        return jax.lax.switch(q * n_stages + idx, branches, params, buf)

    return stage_fn, virtual_stage_fn, K


def to_buffer(x, K: int):
    """Batched activation → (B, K) zero-padded flat buffer."""
    flat = x.reshape(x.shape[0], -1)
    if flat.shape[1] < K:
        flat = jnp.pad(flat, ((0, 0), (0, K - flat.shape[1])))
    return flat


def from_buffer(buf, shape: tuple, dtype=None):
    """(B, K) flat buffer → batched activation of per-sample ``shape``."""
    n = int(math.prod(shape))
    out = buf[:, :n].reshape(buf.shape[0], *shape)
    return out.astype(dtype) if dtype is not None else out
