"""Stage parameter layouts for the schedule executors.

Uniform TransformerLM stacks shard their (L, ...) stacked block params over
the stage axis: ``stack_stages`` (equal cuts), ``stack_stage_bounds`` (the DP
partitioner's non-uniform cuts, padded + masked) and
``stack_virtual_stage_bounds`` (v·p round-robin chunks for the interleaved
schedule). ``make_stage_fn`` / ``make_masked_stage_fn`` /
``make_virtual_stage_fn`` turn a per-block apply into the matching stage
program. Heterogeneous trunks (CNNs, mixed LM patterns) use per-stage
program specialization instead — see ``hetero.py``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def stack_stages(layer_params_stacked, n_stages: int):
    """(L, ...) stacked layer params → (n_stages, L/n_stages, ...)."""

    def reshape(x):
        L = x.shape[0]
        if L % n_stages:
            raise ValueError(f"{L} layers do not divide {n_stages} stages")
        return x.reshape(n_stages, L // n_stages, *x.shape[1:])

    return jax.tree.map(reshape, layer_params_stacked)


def make_stage_fn(block_apply):
    """Stage = scan over the layers owned by this stage.

    block_apply(one_layer_params, x) -> y
    """

    def stage_fn(stage_params, x):
        def body(h, lp):
            return block_apply(lp, h), None

        y, _ = jax.lax.scan(body, x, stage_params)
        return y

    return stage_fn


# ---------------------------------------------------------------------------
# Non-uniform stages (DP partitioner cuts)
# ---------------------------------------------------------------------------

def stack_stage_bounds(layer_params_stacked, bounds):
    """(L, ...) stacked layer params + partition bounds → the SPMD stage
    layout: ((n_stages, m, ...) padded stacks, (n_stages, m) validity mask),
    m = max stage length.

    Stages may own unequal layer counts (core/partition.py DP cuts); padded
    slots repeat the stage's last layer so every rank scans identical shapes,
    and the mask turns padded slots into identity in the stage scan (their
    parameters receive exactly-zero gradients through the ``where``).
    """
    bounds = tuple(int(b) for b in bounds)
    k = len(bounds) - 1
    counts = [bounds[i + 1] - bounds[i] for i in range(k)]
    if min(counts) < 1:
        raise ValueError(f"empty stage in bounds {bounds}")
    m = max(counts)
    # one gather per leaf, NOT concat-of-slices: under jit, XLA's SPMD
    # partitioner miscompiles a concat/stack of slices feeding a shard_map
    # with P(stage) in_specs (jax 0.4.37 — values silently wrong); a single
    # take lowers to a clean gather that reshards correctly. Padded slots
    # clamp to the stage's last layer; the mask keeps their cotangents at
    # exactly zero, so the duplicated layer sees no spurious gradient.
    idx = jnp.asarray([min(bounds[i] + j, bounds[i + 1] - 1)
                       for i in range(k) for j in range(m)])
    mask = jnp.array([[j < c for j in range(m)] for c in counts])
    restack = lambda x: jnp.take(x, idx, axis=0).reshape(k, m, *x.shape[1:])
    return jax.tree.map(restack, layer_params_stacked), mask


def stack_virtual_stage_bounds(layer_params_stacked, bounds,
                               n_stages: int, virtual_stages: int):
    """(L, ...) stacked layer params + v·p chunk bounds → the interleaved
    SPMD layout: ((p, v, m, ...) padded stacks, (p, v, m) validity mask).

    Chunk j = q·p + r of the contiguous DP partition goes to rank r,
    virtual slot q — the round-robin assignment the interleaved schedule's
    ring permute expects. Same single-gather restack (and the same jax
    0.4.37 concat-of-slices caveat) as ``stack_stage_bounds``.
    """
    bounds = tuple(int(b) for b in bounds)
    p, v = int(n_stages), int(virtual_stages)
    k = len(bounds) - 1
    if k != p * v:
        raise ValueError(f"{k} chunks in bounds for p={p}, v={v}")
    counts = [bounds[i + 1] - bounds[i] for i in range(k)]
    if min(counts) < 1:
        raise ValueError(f"empty chunk in bounds {bounds}")
    m = max(counts)
    idx = jnp.asarray([min(bounds[q * p + r] + j, bounds[q * p + r + 1] - 1)
                       for r in range(p) for q in range(v) for j in range(m)])
    mask = jnp.array([[[j < counts[q * p + r] for j in range(m)]
                       for q in range(v)] for r in range(p)])
    restack = lambda x: jnp.take(x, idx, axis=0).reshape(
        p, v, m, *x.shape[1:])
    return jax.tree.map(restack, layer_params_stacked), mask


def make_masked_stage_fn(block_apply):
    """Stage = masked scan over the (padded) layer slots this stage owns;
    stage params are the ``stack_stage_bounds`` layout:
    {"layers": (m, ...) pytree, "mask": (m,) bool}."""

    def stage_fn(stage_params, x):
        def body(h, slot):
            lp, valid = slot
            return jnp.where(valid, block_apply(lp, h), h), None

        y, _ = jax.lax.scan(body, x,
                            (stage_params["layers"], stage_params["mask"]))
        return y

    return stage_fn


def make_virtual_stage_fn(block_apply):
    """Interleaved stage program over the ``stack_virtual_stage_bounds``
    layout: select virtual chunk q (a traced index) out of the rank's
    (v, m, ...) slots, then run the masked stage scan over it."""
    inner = make_masked_stage_fn(block_apply)

    def stage_fn(rank_params, x, q):
        chunk = jax.tree.map(
            lambda a: jax.lax.dynamic_index_in_dim(a, q, axis=0,
                                                   keepdims=False),
            rank_params)
        return inner(chunk, x)

    return stage_fn


def block_costs_from_stats(stats, n_layers: int):
    """Per-BLOCK fw+bw FLOP cost vector from oracle layer stats.

    ``lm_stats`` names per-layer entries ``L{i}.<part>`` (attn/ffn/...);
    each block's cost is the sum over its parts. Backward FLOPs come from
    the stat's exact per-layer value when the extractor recorded one
    (``LayerStat.flops_bwd_exact`` — CNN stride/pool layers break the
    bw ≈ 2×fw rule), falling back to the 2×fw approximation (3×fw total)
    only when absent. Embed and head entries carry no ``L{i}.`` prefix and
    are excluded — they run replicated outside the stage schedule. Falls
    back to uniform costs if the stats carry no per-block entries.
    """
    import re
    import numpy as np
    costs = np.zeros(n_layers)
    for st in stats:
        m = re.match(r"L(\d+)\.", st.name)
        if m and int(m.group(1)) < n_layers:
            bwd = st.flops_bwd_exact or 2.0 * st.flops_fwd
            costs[int(m.group(1))] += st.flops_fwd + bwd
    return costs if costs.any() else np.ones(n_layers)
