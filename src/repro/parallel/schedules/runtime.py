"""Pipeline schedule executors — paper §3.4 "Layer" parallelism, three ways.

Every executor runs a stage function over the mesh's stage axis under
``shard_map``; microbatch activations hop stages via ``collective_permute``
(the paper's P2P transfers). All three are plain differentiable JAX (scan +
permute), so one schedule serves forward and backward, and all three are
gradient-exact against the serial step — they differ in *clocking*:

``gpipe``
    The classic fill/drain: T = S + p − 1 ticks, every microbatch's forward
    completes before any backward starts — S microbatches of activations in
    flight, bubble (p−1)/S.

``one_f_one_b``
    Same forward clock as GPipe (the forward pipeline of 1F1B is identical —
    stage r starts microbatch m at tick m + r), but the microbatch stream is
    scanned in windows of ≤ p with the window body ``jax.checkpoint``-ed:
    the backward recomputes one window at a time, so at most p microbatches
    of saved activations are live (vs S under GPipe's scan residuals). This
    is the schedule's steady-state ≤p in-flight property, realized through
    windowed rematerialization — on a real cluster 1F1B schedules each
    microbatch's backward eagerly instead of recomputing; the memory
    signature is the same.

``interleaved``
    Megatron-style virtual stages: the stack is cut into v·p chunks assigned
    round-robin (chunk j → rank j mod p); microbatches advance in groups of
    p and activations ring-permute around the mesh (rank p−1 wraps to rank 0
    for the next virtual round). T = v·S + p − 1 chunk-ticks at ~1/v the
    per-tick cost, so the fill/drain bubble shrinks to (p−1)/(v·S) — paid
    for with v× the stage-boundary traffic. Requires S % p == 0 (microbatch
    groups of p, as in Megatron).

The oracle prices each clocking in `core/oracle.py` (`OracleConfig.schedule`);
`core/validation.py` measures the real bubble per schedule (DESIGN.md §9).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from ...launch.compat import shard_map

SCHEDULE_NAMES = ("gpipe", "one_f_one_b", "interleaved")


def _rank_params(params_local, shard_params: bool):
    # sharded stacks carry a leading stage dim of extent 1 per rank;
    # replicated (per-stage-specialized) params pass through whole
    if shard_params:
        return jax.tree.map(lambda x: x[0], params_local)
    return params_local


def _run(spmd, stage_params, microbatches, mesh, axis, shard_params):
    pspec = jax.tree.map(
        lambda _: P(axis) if shard_params else P(), stage_params)
    fn = shard_map(spmd, mesh=mesh,
                   in_specs=(pspec, P()), out_specs=P(),
                   check_vma=False)
    return fn(stage_params, microbatches)


def gpipe(stage_fn, stage_params, microbatches, mesh: Mesh,
          axis: str = "model", shard_params: bool = True):
    """Run a GPipe pipeline.

    stage_fn(params_for_one_stage, x) -> y (same shape as x)
    stage_params: pytree with leading dim n_stages (sharded over ``axis``),
        or — with ``shard_params=False`` — a replicated pytree the stage_fn
        specializes per rank itself (lax.switch on the axis index)
    microbatches: (S, mb, ...) array (replicated)
    Returns: (S, mb, ...) outputs of the final stage (replicated).
    """
    n_stages = mesh.shape[axis]
    S = microbatches.shape[0]
    T = S + n_stages - 1
    perm = [(i, i + 1) for i in range(n_stages - 1)]

    def spmd(params_local, mbs):
        idx = jax.lax.axis_index(axis)
        params_one = _rank_params(params_local, shard_params)

        def step(carry, t):
            state = carry  # activation entering this rank at step t
            # stage 0 ingests microbatch t (only meaningful while t < S)
            mb_t = jax.lax.dynamic_index_in_dim(
                mbs, jnp.clip(t, 0, S - 1), axis=0, keepdims=False)
            inp = jnp.where(idx == 0, mb_t.astype(state.dtype), state)
            out = stage_fn(params_one, inp)
            # ship to the next stage; what the last stage computed is emitted
            nxt = jax.lax.ppermute(out, axis, perm)
            return nxt, out

        state0 = jnp.zeros(microbatches.shape[1:], microbatches.dtype)
        _, outs = jax.lax.scan(step, state0, jnp.arange(T))
        # rank r computed microbatch (t - r) at step t; final stage results
        # live at steps n_stages-1 … T-1
        final = jax.lax.dynamic_slice_in_dim(outs, n_stages - 1, S, axis=0)
        mine = jnp.where(idx == n_stages - 1, final, jnp.zeros_like(final))
        return jax.lax.psum(mine, axis)

    return _run(spmd, stage_params, microbatches, mesh, axis, shard_params)


def one_f_one_b(stage_fn, stage_params, microbatches, mesh: Mesh,
                axis: str = "model", shard_params: bool = True):
    """Run a 1F1B pipeline (same contract as ``gpipe``).

    The forward clock is GPipe's (1F1B's forward schedule is identical —
    T = S + p − 1 ticks, padded to a multiple of the window); the tick
    stream is scanned in checkpointed windows of w = min(p, S) ticks whose
    pipeline state carries across windows, so the backward holds at most
    one window of interior activations plus the window-boundary states:
    the schedule's ≤ p in-flight memory property, realized as windowed
    rematerialization. Structurally this is GPipe's single scan with
    remat windows folded in — the fill/drain clock (and hence the
    measured bubble intercept) is GPipe's; the recompute cost rides the
    per-microbatch slope.
    """
    n_stages = int(mesh.shape[axis])
    S = microbatches.shape[0]
    w = min(n_stages, S)
    T = S + n_stages - 1
    n_win = -(-T // w)          # ceil: pad the tick stream, not the batch
    Tp = n_win * w
    perm = [(i, i + 1) for i in range(n_stages - 1)]

    def spmd(params_local, mbs):
        idx = jax.lax.axis_index(axis)
        params_one = _rank_params(params_local, shard_params)

        def tick(state, t):
            # identical to gpipe's tick: stage 0 ingests microbatch t
            # (clipped past S — padded ticks recompute garbage harmlessly)
            mb_t = jax.lax.dynamic_index_in_dim(
                mbs, jnp.clip(t, 0, S - 1), axis=0, keepdims=False)
            inp = jnp.where(idx == 0, mb_t.astype(state.dtype), state)
            out = stage_fn(params_one, inp)
            return jax.lax.ppermute(out, axis, perm), out

        def window(state, ts):
            return jax.lax.scan(tick, state, ts)

        ticks = jnp.arange(Tp).reshape(n_win, w)
        state0 = jnp.zeros(mbs.shape[1:], mbs.dtype)
        _, wouts = jax.lax.scan(jax.checkpoint(window), state0, ticks)
        outs = wouts.reshape(Tp, *wouts.shape[2:])
        final = jax.lax.dynamic_slice_in_dim(outs, n_stages - 1, S, axis=0)
        mine = jnp.where(idx == n_stages - 1, final, jnp.zeros_like(final))
        return jax.lax.psum(mine, axis)

    return _run(spmd, stage_params, microbatches, mesh, axis, shard_params)


def interleaved(stage_fn, stage_params, microbatches, mesh: Mesh,
                axis: str = "model", virtual_stages: int = 2,
                shard_params: bool = True):
    """Run an interleaved-virtual-stage pipeline.

    stage_fn(rank_params, x, q) -> y — q is the (traced) virtual-stage index
    this rank applies at the current tick; the rank's params carry all v of
    its chunks (leading dim v after the sharded stage dim, or replicated
    with ``shard_params=False``).

    Clocking: microbatches advance in groups of p; rank r at tick t works
    schedule position u = t − r, decomposed u = i + p·(q + v·g) → microbatch
    m = g·p + i at virtual stage q. Activations ring-permute (rank p−1 wraps
    to rank 0, carrying the activation into its next virtual round); rank 0
    ingests a fresh microbatch exactly when its q == 0, which also discards
    the (already emitted) final outputs the wrap carries.
    """
    p = int(mesh.shape[axis])
    S = microbatches.shape[0]
    v = int(virtual_stages)
    if v < 1:
        raise ValueError(f"virtual_stages must be >= 1, got {v}")
    if S % p:
        raise ValueError(
            f"interleaved schedule needs S % p == 0 (microbatch groups of "
            f"p, as in Megatron); got S={S}, p={p}")
    T = v * S + p - 1
    ring = [(i, (i + 1) % p) for i in range(p)]

    def spmd(params_local, mbs):
        idx = jax.lax.axis_index(axis)
        rank_params = _rank_params(params_local, shard_params)

        def tick(state, t):
            u = jnp.clip(t - idx, 0, v * S - 1)   # fill/drain ranks idle-spin
            i = u % p
            qg = u // p
            q = qg % v
            g = qg // v
            mb_t = jax.lax.dynamic_index_in_dim(
                mbs, jnp.clip(g * p + i, 0, S - 1), axis=0, keepdims=False)
            fresh = (idx == 0) & (q == 0)
            inp = jnp.where(fresh, mb_t.astype(state.dtype), state)
            out = stage_fn(rank_params, inp, q)
            nxt = jax.lax.ppermute(out, axis, ring)
            return nxt, out

        state0 = jnp.zeros(mbs.shape[1:], mbs.dtype)
        _, outs = jax.lax.scan(tick, state0, jnp.arange(T))
        # microbatch m completes on rank p−1 (final chunk v·p−1) at tick
        # (p−1) + (m mod p) + p·((v−1) + v·(m div p)) — non-contiguous
        # across groups, so gather with a static index vector
        t_idx = jnp.asarray([(p - 1) + (m % p) + p * ((v - 1) + v * (m // p))
                             for m in range(S)])
        final = jnp.take(outs, t_idx, axis=0)
        mine = jnp.where(idx == p - 1, final, jnp.zeros_like(final))
        return jax.lax.psum(mine, axis)

    return _run(spmd, stage_params, microbatches, mesh, axis, shard_params)


SCHEDULES = {"gpipe": gpipe, "one_f_one_b": one_f_one_b,
             "interleaved": interleaved}
