"""Layer (pipeline) parallelism — paper §3.4, GPipe schedule [17].

``gpipe`` runs a stage function over ``n_stages`` mesh shards with the
classic (p + S − 1)-step fill/drain schedule the paper's Table-3 "Layer" row
models:

    T_comp ≈ D(p+S−1)/S · (max FW_Gi + max BW_Gi)
    T_comm ≈ 2D(p+S−2)/B · max(α + B/S·|y_Gi|·δβ)

Implementation: ``shard_map`` over the stage axis; each rank owns one stage's
parameters (leading stage dim sharded); microbatch activations hop stages via
``collective_permute`` (the paper's P2P transfers). Differentiable (scan +
permute), so the same schedule serves forward and backward.

Beyond the schedule primitive, this module makes pipeline a DEPLOYABLE
strategy (ISSUE 3):

  * non-uniform stages — ``stack_stage_bounds`` + ``make_masked_stage_fn``
    realize the DP partitioner's unequal layer counts under SPMD (each stage
    scans max-stage-length padded slots with a validity mask);
  * a full train step — ``make_pipeline_train_step`` runs embed → GPipe over
    the uniform block stack → head/loss → optimizer update for any
    uniform-pattern TransformerLM, gradient-exact vs the serial step;
  * a capability probe — ``pipeline_supported`` names the reason a model
    cannot pipeline (heterogeneous CNN trunks, MoE aux losses, …), consumed
    by the auto-tuner's deployability gate.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from ..launch.compat import shard_map


def gpipe(stage_fn, stage_params, microbatches, mesh: Mesh, axis: str = "model"):
    """Run a GPipe pipeline.

    stage_fn(params_for_one_stage, x) -> y (same shape as x)
    stage_params: pytree with leading dim n_stages (sharded over ``axis``)
    microbatches: (S, mb, ...) array (replicated)
    Returns: (S, mb, ...) outputs of the final stage (replicated).
    """
    n_stages = mesh.shape[axis]
    S = microbatches.shape[0]
    T = S + n_stages - 1
    perm = [(i, i + 1) for i in range(n_stages - 1)]

    def spmd(params_local, mbs):
        idx = jax.lax.axis_index(axis)
        params_one = jax.tree.map(lambda x: x[0], params_local)

        def step(carry, t):
            state = carry  # activation entering this rank at step t
            # stage 0 ingests microbatch t (only meaningful while t < S)
            mb_t = jax.lax.dynamic_index_in_dim(
                mbs, jnp.clip(t, 0, S - 1), axis=0, keepdims=False)
            inp = jnp.where(idx == 0, mb_t.astype(state.dtype), state)
            out = stage_fn(params_one, inp)
            # ship to the next stage; what the last stage computed is emitted
            nxt = jax.lax.ppermute(out, axis, perm)
            return nxt, out

        state0 = jnp.zeros(microbatches.shape[1:], microbatches.dtype)
        _, outs = jax.lax.scan(step, state0, jnp.arange(T))
        # rank r computed microbatch (t - r) at step t; final stage results
        # live at steps n_stages-1 … T-1
        final = jax.lax.dynamic_slice_in_dim(outs, n_stages - 1, S, axis=0)
        mine = jnp.where(idx == n_stages - 1, final, jnp.zeros_like(final))
        return jax.lax.psum(mine, axis)

    pspec_params = jax.tree.map(lambda _: P(axis), stage_params)
    fn = shard_map(spmd, mesh=mesh,
                   in_specs=(pspec_params, P()), out_specs=P(),
                   check_vma=False)
    return fn(stage_params, microbatches)


def stack_stages(layer_params_stacked, n_stages: int):
    """(L, ...) stacked layer params → (n_stages, L/n_stages, ...)."""

    def reshape(x):
        L = x.shape[0]
        if L % n_stages:
            raise ValueError(f"{L} layers do not divide {n_stages} stages")
        return x.reshape(n_stages, L // n_stages, *x.shape[1:])

    return jax.tree.map(reshape, layer_params_stacked)


def make_stage_fn(block_apply):
    """Stage = scan over the layers owned by this stage.

    block_apply(one_layer_params, x) -> y
    """

    def stage_fn(stage_params, x):
        def body(h, lp):
            return block_apply(lp, h), None

        y, _ = jax.lax.scan(body, x, stage_params)
        return y

    return stage_fn


# ---------------------------------------------------------------------------
# Non-uniform stages (DP partitioner cuts) + the deployable train step
# ---------------------------------------------------------------------------

def stack_stage_bounds(layer_params_stacked, bounds):
    """(L, ...) stacked layer params + partition bounds → the SPMD stage
    layout: ((n_stages, m, ...) padded stacks, (n_stages, m) validity mask),
    m = max stage length.

    Stages may own unequal layer counts (core/partition.py DP cuts); padded
    slots repeat the stage's last layer so every rank scans identical shapes,
    and the mask turns padded slots into identity in the stage scan (their
    parameters receive exactly-zero gradients through the ``where``).
    """
    bounds = tuple(int(b) for b in bounds)
    k = len(bounds) - 1
    counts = [bounds[i + 1] - bounds[i] for i in range(k)]
    if min(counts) < 1:
        raise ValueError(f"empty stage in bounds {bounds}")
    m = max(counts)
    # one gather per leaf, NOT concat-of-slices: under jit, XLA's SPMD
    # partitioner miscompiles a concat/stack of slices feeding a shard_map
    # with P(stage) in_specs (jax 0.4.37 — values silently wrong); a single
    # take lowers to a clean gather that reshards correctly. Padded slots
    # clamp to the stage's last layer; the mask keeps their cotangents at
    # exactly zero, so the duplicated layer sees no spurious gradient.
    idx = jnp.asarray([min(bounds[i] + j, bounds[i + 1] - 1)
                       for i in range(k) for j in range(m)])
    mask = jnp.array([[j < c for j in range(m)] for c in counts])
    restack = lambda x: jnp.take(x, idx, axis=0).reshape(k, m, *x.shape[1:])
    return jax.tree.map(restack, layer_params_stacked), mask


def make_masked_stage_fn(block_apply):
    """Stage = masked scan over the (padded) layer slots this stage owns;
    stage params are the ``stack_stage_bounds`` layout:
    {"layers": (m, ...) pytree, "mask": (m,) bool}."""

    def stage_fn(stage_params, x):
        def body(h, slot):
            lp, valid = slot
            return jnp.where(valid, block_apply(lp, h), h), None

        y, _ = jax.lax.scan(body, x,
                            (stage_params["layers"], stage_params["mask"]))
        return y

    return stage_fn


def pipeline_supported(model_or_cfg) -> str | None:
    """None when the GPipe executor can deploy this model, else the reason.

    The schedule needs a uniform stack of identically-shaped blocks to shard
    over the stage axis: a single-kind TransformerLM pattern qualifies;
    heterogeneous CNN trunks and models whose blocks emit side outputs
    (MoE aux losses) do not — those stay analytics-only (DESIGN.md §4).
    """
    from ..models.transformer import LMConfig, TransformerLM
    cfg = model_or_cfg.cfg if isinstance(model_or_cfg, TransformerLM) \
        else model_or_cfg
    if not isinstance(cfg, LMConfig):
        return (f"{type(cfg).__name__}: only uniform stacked-block models "
                f"(TransformerLM) can shard stages over a mesh axis")
    if len(cfg.pattern) != 1:
        return f"pattern {cfg.pattern} is not a uniform stack"
    if cfg.pattern[0] == "moe":
        return "MoE aux losses do not flow through the stage schedule"
    if cfg.first_k_dense or cfg.mtp_heads:
        return "leading dense layers / MTP heads break the uniform stack"
    return None


def clip_segments(batch: int, segments: int) -> int:
    """Largest microbatch-segment count ≤ ``segments`` dividing ``batch``."""
    s = max(min(int(segments), int(batch)), 1)
    while batch % s:
        s -= 1
    return s


def block_costs_from_stats(stats, n_layers: int):
    """Per-BLOCK fw+bw FLOP cost vector from oracle layer stats.

    ``lm_stats`` names per-layer entries ``L{i}.<part>`` (attn/ffn/...);
    each block's cost is the sum over its parts (fw + 2×fw for bw). Embed
    and head entries carry no ``L{i}.`` prefix and are excluded — they run
    replicated outside the stage schedule. Falls back to uniform costs if
    the stats carry no per-block entries.
    """
    import re
    import numpy as np
    costs = np.zeros(n_layers)
    for st in stats:
        m = re.match(r"L(\d+)\.", st.name)
        if m and int(m.group(1)) < n_layers:
            costs[int(m.group(1))] += 3.0 * st.flops_fwd
    return costs if costs.any() else np.ones(n_layers)


def make_pipeline_train_step(model, opt, ctx, segments: int = 8,
                             block_costs=None, axis: str = "model",
                             **fwd_kw):
    """GPipe train step: (state, batch) → (state, metrics), matching the
    ``make_train_step`` contract so every launch entry point can deploy it.

    Stages = the mesh's ``axis`` extent; cuts come from the DP min-max
    partition (core/partition.py) of ``block_costs`` — per-block fw+bw
    costs, e.g. ``block_costs_from_stats`` over the oracle's layer table —
    defaulting to uniform costs (equivalent for the uniform stacks the
    executor supports today). The embed and head run replicated on every
    rank (they are the oracle's first/last stat layers but carry no
    stage-boundary traffic worth a dedicated stage); the block stack runs
    the fill/drain schedule with ``segments`` microbatches. Extra kwargs
    are filtered to the attention kwargs of ``Block.apply``
    (attn_impl / q_chunk / kv_chunk) — callers may pass their full
    forward-kwarg dict.
    """
    import numpy as np
    from ..core.partition import min_max_partition
    from ..models.transformer import Block, _xent
    from ..nn.module import NULL_CTX
    from ..optim.optimizers import apply_update

    reason = pipeline_supported(model)
    if reason is not None:
        raise NotImplementedError(f"pipeline cannot deploy: {reason}")
    mesh = ctx.mesh
    if mesh is None or axis not in mesh.shape:
        raise ValueError(f"pipeline needs a mesh with a {axis!r} axis")
    n_stages = int(mesh.shape[axis])
    c = model.cfg
    L = c.n_layers
    if n_stages > L:
        raise ValueError(f"{n_stages} stages exceed {L} layers")
    if block_costs is None:
        block_costs = np.ones(L)
    if len(block_costs) != L:
        raise ValueError(f"{len(block_costs)} block costs for {L} layers")
    bounds = min_max_partition(block_costs, n_stages).bounds
    blk = Block(c, c.pattern[0])
    kw = {k: v for k, v in fwd_kw.items()
          if k in ("attn_impl", "q_chunk", "kv_chunk")}

    def block_apply(bp, h):
        # NULL_CTX: no sharding constraints inside the shard_map body
        y, _aux = blk.apply(bp, h, NULL_CTX, **kw)
        return y

    stage_fn = make_masked_stage_fn(block_apply)

    def train_step(state, batch):
        tokens = batch["tokens"]
        B = tokens.shape[0]
        S = clip_segments(B, segments)

        def loss_of(params):
            h = model._embed(params, tokens, ctx)
            stages, mask = stack_stage_bounds(params["stacks"][0], bounds)
            mb = h.reshape(S, B // S, *h.shape[1:])
            out = gpipe(stage_fn, {"layers": stages, "mask": mask}, mb,
                        mesh, axis)
            h2 = out.reshape(B, *out.shape[2:]).astype(h.dtype)
            logits = model._logits(params, h2, ctx)
            targets = batch.get("targets")
            if targets is None:
                targets = jnp.pad(tokens[:, 1:], ((0, 0), (0, 1)))
            mask_t = batch.get("mask", jnp.ones(tokens.shape, jnp.float32))
            ce = jnp.sum(_xent(logits, targets) * mask_t) / \
                jnp.maximum(jnp.sum(mask_t), 1.0)
            return ce, {"ce": ce}

        (loss, metrics), grads = jax.value_and_grad(
            loss_of, has_aux=True)(state["params"])
        new_params, new_opt, om = apply_update(opt, state["params"], grads,
                                               state["opt"], state["step"])
        metrics = dict(metrics, loss=loss, **om)
        return {"params": new_params, "opt": new_opt,
                "step": state["step"] + 1}, metrics

    return train_step
