"""Layer (pipeline) parallelism — paper §3.4, GPipe schedule [17].

``gpipe`` runs a stage function over ``n_stages`` mesh shards with the
classic (p + S − 1)-step fill/drain schedule the paper's Table-3 "Layer" row
models:

    T_comp ≈ D(p+S−1)/S · (max FW_Gi + max BW_Gi)
    T_comm ≈ 2D(p+S−2)/B · max(α + B/S·|y_Gi|·δβ)

Implementation: ``shard_map`` over the stage axis; each rank owns one stage's
parameters (leading stage dim sharded); microbatch activations hop stages via
``collective_permute`` (the paper's P2P transfers). Differentiable (scan +
permute), so the same schedule serves forward and backward.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from ..launch.compat import shard_map


def gpipe(stage_fn, stage_params, microbatches, mesh: Mesh, axis: str = "model"):
    """Run a GPipe pipeline.

    stage_fn(params_for_one_stage, x) -> y (same shape as x)
    stage_params: pytree with leading dim n_stages (sharded over ``axis``)
    microbatches: (S, mb, ...) array (replicated)
    Returns: (S, mb, ...) outputs of the final stage (replicated).
    """
    n_stages = mesh.shape[axis]
    S = microbatches.shape[0]
    T = S + n_stages - 1
    perm = [(i, i + 1) for i in range(n_stages - 1)]

    def spmd(params_local, mbs):
        idx = jax.lax.axis_index(axis)
        params_one = jax.tree.map(lambda x: x[0], params_local)

        def step(carry, t):
            state = carry  # activation entering this rank at step t
            # stage 0 ingests microbatch t (only meaningful while t < S)
            mb_t = jax.lax.dynamic_index_in_dim(
                mbs, jnp.clip(t, 0, S - 1), axis=0, keepdims=False)
            inp = jnp.where(idx == 0, mb_t.astype(state.dtype), state)
            out = stage_fn(params_one, inp)
            # ship to the next stage; what the last stage computed is emitted
            nxt = jax.lax.ppermute(out, axis, perm)
            return nxt, out

        state0 = jnp.zeros(microbatches.shape[1:], microbatches.dtype)
        _, outs = jax.lax.scan(step, state0, jnp.arange(T))
        # rank r computed microbatch (t - r) at step t; final stage results
        # live at steps n_stages-1 … T-1
        final = jax.lax.dynamic_slice_in_dim(outs, n_stages - 1, S, axis=0)
        mine = jnp.where(idx == n_stages - 1, final, jnp.zeros_like(final))
        return jax.lax.psum(mine, axis)

    pspec_params = jax.tree.map(lambda _: P(axis), stage_params)
    fn = shard_map(spmd, mesh=mesh,
                   in_specs=(pspec_params, P()), out_specs=P(),
                   check_vma=False)
    return fn(stage_params, microbatches)


def stack_stages(layer_params_stacked, n_stages: int):
    """(L, ...) stacked layer params → (n_stages, L/n_stages, ...)."""

    def reshape(x):
        L = x.shape[0]
        if L % n_stages:
            raise ValueError(f"{L} layers do not divide {n_stages} stages")
        return x.reshape(n_stages, L // n_stages, *x.shape[1:])

    return jax.tree.map(reshape, layer_params_stacked)


def make_stage_fn(block_apply):
    """Stage = scan over the layers owned by this stage.

    block_apply(one_layer_params, x) -> y
    """

    def stage_fn(stage_params, x):
        def body(h, lp):
            return block_apply(lp, h), None

        y, _ = jax.lax.scan(body, x, stage_params)
        return y

    return stage_fn
