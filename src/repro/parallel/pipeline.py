"""Compatibility shim — the pipeline layer now lives in
``repro.parallel.schedules`` (runtime / stages / hetero / train_step).

Everything the old module exported is re-exported here so existing imports
keep working; new code should import from ``repro.parallel.schedules``.
"""
from .schedules import (  # noqa: F401
    SCHEDULES,
    SCHEDULE_NAMES,
    block_costs_from_stats,
    clip_segments,
    gpipe,
    interleaved,
    make_masked_stage_fn,
    make_pipeline_train_step,
    make_stage_fn,
    make_virtual_stage_fn,
    model_pipe_blocks,
    one_f_one_b,
    pipeline_block_costs,
    pipeline_block_count,
    pipeline_supported,
    resolve_segments,
    stack_stage_bounds,
    stack_stages,
    stack_virtual_stage_bounds,
)
