"""2D (SUMMA-style) tensor parallelism over a (row × col) model grid.

The 1D model strategies (filter/channel/df) shard ONE hidden dimension per
matmul and pay a full-width collective on the other. SUMMA [van de Geijn &
Watts '97; Xu et al. 2D tensor parallelism in ColossalAI] block-distributes
every operand over a (r × c) grid instead, so per-device collectives shrink
to panels: for ``y = x @ w`` with x:(B, S, K) and w:(K, N),

  * x lives as (B, S/r, K/c) blocks, w as (K/r, N/c) blocks, y as
    (B, S/r, N/c) blocks — the residual stream is 2D-sharded (seq over grid
    rows = built-in sequence parallelism, hidden over grid columns);
  * forward: allgather the x panels along the grid COLUMNS (full K per
    device, c−1 hops of the small activation block), then r ring steps
    along the grid ROWS — each step multiplies the matching K-slice of the
    gathered x with the locally-held w panel and ``ppermute``s the panel to
    the next row (same one-hop ring discipline as parallel/halo.py and the
    pipeline's stage hops);
  * backward: jax transposes the graph exactly — the allgather's transpose
    is the reduce-scatter of the dx partials, the ppermute ring reverses,
    so gradients are exact to accumulation order (partials accumulate in
    fp32 via ``preferred_element_type``).

The oracle prices this path as the "summa" strategy row (core/oracle.py):
(c−1) activation-panel hops + (r−1) weight-panel hops per matmul, with the
row hops charged at the ClusterSpec's "model2" level when the grid's second
dim rides a slower interconnect.

Deployment: the ``strategies.py`` "summa" rules table places seq on
``model_r`` and every hidden/filter axis on ``model_c``; ``summa_axes``
detects that table + a grid mesh, and ``nn/ffn.py`` / ``nn/attention.py``
route their projections through ``summa_matmul`` when it applies (falling
back to the plain GSPMD path whenever a shape does not divide the grid —
the rules table alone is always safe to deploy).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..launch.compat import shard_map
from ..nn.module import ShardingCtx

ROW_AXIS = "model_r"   # shards seq (activations) / K (weights): p2r devices
COL_AXIS = "model_c"   # shards hidden/filter dims: p2c devices
GRID_AXES = (ROW_AXIS, COL_AXIS)


def summa_axes(ctx: ShardingCtx) -> tuple[str, str] | None:
    """(row, col) mesh axis names when ``ctx`` deploys the 2D grid, else None.

    Opt-in = a mesh carrying both grid axes AND the "summa" rules table
    (the only table that puts the residual's seq dim on the grid rows and
    its embed dim on the grid columns).
    """
    mesh = ctx.mesh
    if mesh is None or ROW_AXIS not in mesh.shape or COL_AXIS not in mesh.shape:
        return None
    if ctx.rules.get("seq") != ROW_AXIS or ctx.rules.get("act_embed") != COL_AXIS:
        return None
    return GRID_AXES


def grid_shape(mesh) -> tuple[int, int]:
    """(r, c) extents of the model grid."""
    return mesh.shape[ROW_AXIS], mesh.shape[COL_AXIS]


def _dp_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def matmul_ok(mesh, x_shape, k: int, n: int) -> bool:
    """True when summa_matmul's shard_map specs divide (B, S, k) @ (k, n)
    exactly — callers fall back to the plain GSPMD path otherwise."""
    r, c = grid_shape(mesh)
    dp = 1
    for a in _dp_axes(mesh):
        dp *= mesh.shape[a]
    return (x_shape[0] % dp == 0 and x_shape[1] % r == 0
            and k % (r * c) == 0 and n % c == 0)


def summa_matmul(x, w, mesh, *, bias=None, accum_dtype=jnp.float32):
    """``x @ w (+ bias)`` executed as SUMMA on the model grid.

    x: (B, S, K) sharded P(dp, model_r, model_c); w: (K, N) sharded
    P(model_r, model_c) — GSPMD reshards at entry when the stored layout
    differs (e.g. FFN's w_out, stored transposed by the rules table).
    Returns (B, S, N) sharded P(dp, model_r, model_c).
    """
    r, c = grid_shape(mesh)
    K = x.shape[-1]
    Kr = K // r
    dp = _dp_axes(mesh) or None
    io = P(dp, ROW_AXIS, COL_AXIS)
    perm = [(i, (i + 1) % r) for i in range(r)]

    def local(xl, wl):
        # 1. gather the activation panels along the grid columns: full K
        #    per device, blocks concatenated in col order (= K order).
        xf = jax.lax.all_gather(xl, COL_AXIS, axis=2, tiled=True)
        # 2. ring-broadcast the weight panels along the grid rows. After t
        #    shifts of i→i+1, row j holds panel (j − t) mod r; each step
        #    contracts that panel with its K-slice of the gathered x.
        row = jax.lax.axis_index(ROW_AXIS)
        acc = jnp.zeros(xl.shape[:2] + (wl.shape[1],), accum_dtype)
        panel = wl
        for t in range(r):
            src = (row - t) % r
            xs = jax.lax.dynamic_slice_in_dim(xf, src * Kr, Kr, axis=2)
            acc = acc + jnp.einsum("bsk,kn->bsn", xs, panel,
                                   preferred_element_type=accum_dtype)
            if t + 1 < r:
                panel = jax.lax.ppermute(panel, ROW_AXIS, perm)
        return acc.astype(x.dtype)

    fn = shard_map(local, mesh=mesh, in_specs=(io, P(ROW_AXIS, COL_AXIS)),
                   out_specs=io, check_vma=False)
    y = fn(x, w)
    if bias is not None:
        y = y + bias
    return y


# ---------------------------------------------------------------------------
# Layer entry points (lazily imported by nn/ffn.py and nn/attention.py)
# ---------------------------------------------------------------------------

def ffn_ok(cfg, mesh, x_shape) -> bool:
    return (matmul_ok(mesh, x_shape, cfg.d_model, cfg.d_ff)
            and matmul_ok(mesh, x_shape, cfg.d_ff, cfg.d_model))


def ffn_apply(cfg, params, x, act, ctx: ShardingCtx):
    """Dense FFN body on the grid. The first matmul's output blocks are
    exactly the second's input blocks, so the chain needs no resharding."""
    mesh = ctx.mesh
    h = summa_matmul(x, params["w_in"], mesh,
                     bias=params.get("b_in") if cfg.use_bias else None)
    h = act(h)
    if cfg.glu:
        h = h * summa_matmul(x, params["w_gate"], mesh)
    return summa_matmul(h, params["w_out"], mesh,
                        bias=params.get("b_out") if cfg.use_bias else None)


def qkv_ok(cfg, mesh, x_shape) -> bool:
    r, c = grid_shape(mesh)
    return (matmul_ok(mesh, x_shape, cfg.d_model, cfg.q_dim)
            and cfg.kv_dim % c == 0
            and cfg.n_heads % c == 0 and cfg.n_kv_heads % c == 0)


def attn_qkv(cfg, params, x, ctx: ShardingCtx):
    """q/k/v projections on the grid: (B, S, D) → (B, S, H, head_dim).

    The head axes flatten into the matmul's N dim (c | n_heads is gated by
    ``qkv_ok`` so the un-flatten is shard-local); bias/norm/rope stay in
    the caller."""
    mesh = ctx.mesh
    B, S, D = x.shape
    q = summa_matmul(x, params["wq"].reshape(D, cfg.q_dim), mesh)
    k = summa_matmul(x, params["wk"].reshape(D, cfg.kv_dim), mesh)
    v = summa_matmul(x, params["wv"].reshape(D, cfg.kv_dim), mesh)
    return (q.reshape(B, S, cfg.n_heads, cfg.head_dim),
            k.reshape(B, S, cfg.n_kv_heads, cfg.head_dim),
            v.reshape(B, S, cfg.n_kv_heads, cfg.head_dim))


def out_ok(cfg, mesh, o_shape) -> bool:
    return matmul_ok(mesh, o_shape, cfg.q_dim, cfg.d_model)


def attn_out(cfg, params, o, ctx: ShardingCtx):
    """Output projection: (B, S, H, head_dim) → (B, S, D) 2D-residual.

    Entering the shard_map re-scatters seq onto the grid rows — the
    reduce-scatter half of the sequence-parallel pair the oracle's
    seq-comm term prices."""
    B, S = o.shape[:2]
    wo = params["wo"].reshape(cfg.q_dim, cfg.d_model)
    return summa_matmul(o.reshape(B, S, cfg.q_dim), wo, ctx.mesh)
