"""Spatial parallelism with halo exchange — paper §3.2 / [13].

Convolutions whose input is sharded along a spatial dim need K//2 boundary
rows from logically-neighbouring PEs. ``halo_exchange`` performs the paper's
FB-Halo transfers with ``ppermute`` (P2P — the paper measured this to be a
non-trivial 60%-of-allreduce cost on MPI; on ICI the neighbours are physical
neighbours so α is one hop); ``spatial_conv2d`` wraps a channels-last conv
with exchange + VALID local windows, matching the unsharded op exactly for
stride 1.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from ..launch.compat import axis_size, shard_map


def halo_exchange(x, halo: int, axis: str):
    """Exchange ``halo`` rows (dim 1) with ring neighbours inside shard_map.

    x: (B, H_local, ..., C). Returns (B, halo + H_local + halo, ..., C) with
    zero padding at the global boundary.
    """
    if halo == 0:
        return x
    p = axis_size(axis)
    idx = jax.lax.axis_index(axis)
    top = x[:, :halo]          # rows this shard sends UP (to idx-1)
    bot = x[:, -halo:]         # rows this shard sends DOWN (to idx+1)
    from_up = jax.lax.ppermute(bot, axis, [(i, i + 1) for i in range(p - 1)])
    from_down = jax.lax.ppermute(top, axis, [(i + 1, i) for i in range(p - 1)])
    from_up = jnp.where(idx == 0, jnp.zeros_like(from_up), from_up)
    from_down = jnp.where(idx == p - 1, jnp.zeros_like(from_down), from_down)
    return jnp.concatenate([from_up, x, from_down], axis=1)


def spatial_conv2d(x, w, mesh: Mesh, axis: str = "model", bias=None):
    """2-D conv (stride 1, SAME) with the H dim sharded over ``axis``.

    x: (B, H, W, C) with H sharded; w: (kh, kw, C, F). Matches the unsharded
    SAME conv bit-exactly.
    """
    kh = w.shape[0]
    halo = kh // 2

    def local(xl, wl, bl):
        xl = halo_exchange(xl, halo, axis)
        dn = jax.lax.conv_dimension_numbers(xl.shape, wl.shape,
                                            ("NHWC", "HWIO", "NHWC"))
        # H is VALID (halo supplies the boundary); W stays SAME
        y = jax.lax.conv_general_dilated(
            xl, wl, window_strides=(1, 1),
            padding=((0, 0), (w.shape[1] // 2, w.shape[1] // 2)),
            dimension_numbers=dn)
        if bl is not None:
            y = y + bl
        return y

    in_specs = (P(None, axis, None, None), P(), P() if bias is not None else P())
    args = (x, w, bias if bias is not None else jnp.zeros((w.shape[-1],), x.dtype))
    fn = shard_map(local, mesh=mesh,
                   in_specs=in_specs,
                   out_specs=P(None, axis, None, None), check_vma=False)
    return fn(*args)
