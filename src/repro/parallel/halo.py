"""Spatial parallelism with overlapped halo exchange — paper §3.2 / [13].

Convolutions whose input is sharded along a spatial dim need boundary rows
from logically-neighbouring PEs. The paper's FB-Halo transfers cost ~60% of
an allreduce on its MPI cluster and its oracle charges them SERIALLY; Dryden
et al. show they can be almost fully hidden under interior compute. This
module implements that overlap:

  ``spatial_conv2d`` launches the ``ppermute`` halo transfers FIRST, computes
  the interior VALID convolution — the output rows whose windows touch only
  local data — while the exchange is in flight, then computes just the
  2·(K−1) boundary rows from the received halos and stitches. Every output
  row is the same reduction over the same window as the unsharded SAME conv,
  so the result is bit-exact (asserted by the ``halo_overlap`` multidevice
  check), and the interior conv carries no data dependency on the permutes,
  so XLA is free to run the DMA under it.

``HaloConv`` deploys this through the strategy rules tables: a drop-in
``nn.layers.Conv`` whose apply routes to the overlapped sharded path when
the ctx's rules shard the leading spatial dim (the ``spatial``/``ds``
tables), and to the plain conv otherwise. With ``ctx.use_pallas`` the local
convolutions run on the implicit-GEMM Pallas kernel — the boundary/interior
tiles feed its halo-aware ``pad_h=False`` entry directly, no second
``jnp.pad`` round-trip (DESIGN.md §6).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from ..launch.compat import axis_size, shard_map
from ..nn.layers import Conv
from ..nn.module import NULL_CTX, ShardingCtx, spec_to_pspec


def _halo_sizes(kh: int) -> tuple[int, int]:
    """(rows needed from the upper neighbour, rows from the lower) for a
    SAME conv of width kh — XLA's SAME convention: pad_lo = (kh−1)//2,
    pad_hi = kh//2, so even widths split asymmetrically."""
    return (kh - 1) // 2, kh // 2


def halo_exchange(x, halo: int | tuple[int, int], axis: str):
    """Exchange halo rows (dim 1) with ring neighbours inside shard_map.

    ``halo`` is (lo, hi) — rows fetched from the upper / lower neighbour —
    or a single int for a symmetric exchange. x: (B, H_local, ..., C);
    returns (B, lo + H_local + hi, ..., C) with zeros at the global
    boundary (= the unsharded op's SAME zero padding).
    """
    lo, hi = (halo, halo) if isinstance(halo, int) else halo
    if lo == 0 and hi == 0:
        return x
    if x.shape[1] < max(lo, hi):
        raise ValueError(
            f"shard too thin for the halo: H_local={x.shape[1]} < "
            f"halo={max(lo, hi)} (p={axis_size(axis)}) — one-hop neighbour "
            f"exchange cannot serve this kernel; use fewer spatial shards")
    from_up, from_down = _exchange(x, lo, hi, axis)
    return jnp.concatenate([from_up, x, from_down], axis=1)


def _exchange(x, lo: int, hi: int, axis: str):
    """The two ppermute transfers: returns (rows from up, rows from down),
    zero-filled at the global edges. Issued before any compute that uses
    them so the DMA can overlap the interior convolution."""
    p = axis_size(axis)
    idx = jax.lax.axis_index(axis)
    empty = x[:, :0]
    from_up = from_down = empty
    if lo:
        bot = x[:, -lo:]           # rows this shard sends DOWN (to idx+1)
        from_up = jax.lax.ppermute(bot, axis,
                                   [(i, i + 1) for i in range(p - 1)])
        from_up = jnp.where(idx == 0, jnp.zeros_like(from_up), from_up)
    if hi:
        top = x[:, :hi]            # rows this shard sends UP (to idx-1)
        from_down = jax.lax.ppermute(top, axis,
                                     [(i + 1, i) for i in range(p - 1)])
        from_down = jnp.where(idx == p - 1, jnp.zeros_like(from_down),
                              from_down)
    return from_up, from_down


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _tuned_block_f(ctx, x_shape, w_shape, strides=(1, 1), p: int = 1) -> int:
    """Tuned conv2d_gemm filter block for this site, or the 128 default.

    ``p`` divides the leading spatial dim for the sharded path so the lookup
    uses the per-shard tile height the kernel actually sees (the bucket's
    nearest-pow2 rounding absorbs the kh−1 halo rows)."""
    tiles = getattr(ctx, "kernel_tiles", None)
    if tiles is None:
        return 128
    B, H, W, C = x_shape
    kh, kw, _, F = w_shape
    return tiles.conv_block_f(B=B, H=H // p, W=W, C=C, F=F, kh=kh, kw=kw,
                              sh=strides[0], sw=strides[1], e=4)


def _local_conv(xl, wl, trail_pads, *, use_pallas: bool, interpret: bool,
                block_f: int = 128):
    """VALID-over-dim-1 conv of a local tile (trailing spatial dims SAME).

    The Pallas path is 2-D only and consumes the tile through the
    halo-aware kernel entry (H pre-padded by the exchange)."""
    nd = xl.ndim - 2
    if use_pallas and nd == 2:
        from ..kernels import conv2d_gemm
        return conv2d_gemm(xl, wl, pad_h=False, interpret=interpret,
                           block_f=block_f)
    spatial = "DHW"[-nd:]
    dn = jax.lax.conv_dimension_numbers(
        xl.shape, wl.shape, (f"N{spatial}C", f"{spatial}IO", f"N{spatial}C"))
    return jax.lax.conv_general_dilated(
        xl, wl, (1,) * nd, ((0, 0),) + trail_pads, dimension_numbers=dn)


def spatial_conv2d(x, w, mesh: Mesh, axis: str = "model", bias=None, *,
                   strides: Sequence[int] | None = None, overlap: bool = True,
                   batch_axes=None, use_pallas: bool = False,
                   interpret: bool | None = None, block_f: int = 128):
    """N-D conv (stride 1, SAME) with the leading spatial dim sharded.

    x: (B, H, *spatial, C) with H sharded over ``axis``; w: (kh, *k, C, F).
    Matches the unsharded SAME conv bit-exactly — including even kernel
    widths (asymmetric halos) and p = 1 (degenerates to the serial conv).

    ``overlap=True`` (default) computes the interior rows while the halo
    transfers are in flight; ``overlap=False`` keeps the serial
    exchange-then-conv pipeline (same values, reference for parity checks).
    ``batch_axes`` names the mesh axes the batch dim is sharded over (the
    DP axes under ``ds``) so the wrapped region preserves data parallelism.
    Spatial parallelism cannot stride the sharded dim (shard boundaries
    would fall between stride phases), so any stride ≠ 1 raises.
    """
    nd = x.ndim - 2
    if strides is not None and tuple(strides) != (1,) * nd:
        raise ValueError(
            f"spatial_conv2d is stride-1 only (got strides={tuple(strides)});"
            f" strided convs cannot split the sharded spatial dim — keep "
            f"them on the unsharded path (HaloConv falls back automatically)")
    kh = w.shape[0]
    lo, hi = _halo_sizes(kh)
    trail_pads = tuple(_halo_sizes(k) for k in w.shape[1:nd])
    interpret = not _on_tpu() if interpret is None else interpret

    def local(xl, wl, bl):
        # (shards thinner than the halo raise inside halo_exchange — every
        # too-thin case takes the serial branch below)
        H = xl.shape[1]
        conv = lambda t: _local_conv(t, wl, trail_pads,       # noqa: E731
                                     use_pallas=use_pallas,
                                     interpret=interpret, block_f=block_f)
        if not overlap or H <= lo + hi:
            # serial reference path (also the thin-shard fallback where the
            # interior would be empty — H == lo+hi included: a zero-row
            # interior is illegal for the Pallas call): full exchange, one
            # conv
            y = conv(halo_exchange(xl, (lo, hi), axis))
        else:
            # 1. launch the halo transfers
            from_up, from_down = _exchange(xl, lo, hi, axis)
            # 2. interior rows [lo, H−hi) depend only on local data — this
            #    conv overlaps the exchange
            interior = conv(xl)
            # 3. boundary rows from the received halos, then stitch. An
            #    even kernel has lo = 0 (XLA SAME pads below only): that
            #    side contributes no rows and must not reach the conv —
            #    a zero-row tile is illegal for the Pallas path.
            pieces = [interior]
            if lo:
                pieces.insert(0, conv(jnp.concatenate(
                    [from_up, xl[:, :lo + hi]], axis=1)))
            if hi:
                pieces.append(conv(jnp.concatenate(
                    [xl[:, H - (lo + hi):], from_down], axis=1)))
            y = jnp.concatenate(pieces, axis=1) if len(pieces) > 1 \
                else interior
        if bl is not None:
            y = y + bl
        return y

    spec = [None] * (nd + 2)
    spec[0], spec[1] = batch_axes, axis
    io_spec = P(*spec)
    if bias is None:     # no dead all-replicated bias arg: two real arities
        fn = shard_map(lambda xl, wl: local(xl, wl, None), mesh=mesh,
                       in_specs=(io_spec, P()), out_specs=io_spec,
                       check_vma=False)
        return fn(x, w)
    fn = shard_map(local, mesh=mesh, in_specs=(io_spec, P(), P()),
                   out_specs=io_spec, check_vma=False)
    return fn(x, w, bias)


# ---------------------------------------------------------------------------
# HaloConv: the deployable layer (models/cnn.py uses it for its K>1 convs)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class HaloConv(Conv):
    """``Conv`` that executes as the overlapped halo pipeline when sharded.

    Same ``params_spec``; ``apply`` inspects the ctx: when the rules shard
    the model's "spatial" logical axis onto ONE mesh axis that evenly
    divides the input's leading spatial dim (the ``spatial``/``ds`` tables),
    the conv runs inside ``spatial_conv2d``'s shard_map with the halo
    transfers overlapped under the interior compute. Anything the explicit
    path cannot take — strides, grouped convs, non-SAME padding, thin
    shards, a multi-axis or non-dividing sharding — falls back to the plain
    (GSPMD-partitioned) conv, so the layer is always safe to deploy.
    """

    overlap: bool = True

    def _spatial_sharding(self, ctx: ShardingCtx, x):
        """(mesh axis name, batch axes) when the explicit halo path applies,
        else None."""
        if ctx.mesh is None:
            return None
        nd = len(self.kernel)
        if nd < 2 or self.feature_group_count != 1 or self.kernel[0] <= 1:
            return None
        if self.padding != "SAME":   # the halo exchange IS the SAME padding
            return None
        if self.strides is not None and tuple(self.strides) != (1,) * nd:
            return None
        axes = ("batch", "spatial") + (None,) * (nd - 1) + ("conv_out",)
        pspec = spec_to_pspec(axes, ctx.rules, ctx.mesh, x.shape)
        sp = pspec[1]
        if sp is None or isinstance(sp, tuple):
            return None
        p = ctx.mesh.shape[sp]
        lo, hi = _halo_sizes(self.kernel[0])
        if p <= 1 or x.shape[1] % p or x.shape[1] // p < max(lo, hi):
            return None
        return sp, pspec[0]

    def apply(self, params, x, ctx: ShardingCtx = NULL_CTX):
        sharded = self._spatial_sharding(ctx, x)
        if sharded is None:
            if ctx.use_pallas and len(self.kernel) == 2 \
                    and self.feature_group_count == 1 \
                    and self.padding == "SAME":
                from ..kernels import conv2d_gemm
                strides = tuple(self.strides or (1, 1))
                y = conv2d_gemm(x, params["w"], strides=strides,
                                interpret=not _on_tpu(),
                                block_f=_tuned_block_f(
                                    ctx, x.shape, params["w"].shape, strides))
                if self.use_bias:
                    y = y + params["b"]
                return y
            return super().apply(params, x, ctx)
        axis, batch_axes = sharded
        return spatial_conv2d(
            x, params["w"], ctx.mesh, axis,
            bias=params["b"] if self.use_bias else None,
            overlap=self.overlap, batch_axes=batch_axes,
            use_pallas=ctx.use_pallas and len(self.kernel) == 2,
            block_f=_tuned_block_f(ctx, x.shape, params["w"].shape,
                                   p=ctx.mesh.shape[axis]))
