"""Parallel strategies as logical-axis → mesh-axis rule tables.

This is the executable form of the paper's §3 taxonomy. Each strategy is one
``Rules`` table; swapping tables re-parallelizes every model with no model
code changes. Mesh axes: ("data", "model") single-pod, ("pod", "data",
"model") multi-pod; the DP group spans ("pod", "data").

paper §3.1 data      → batch over every axis, params replicated
paper §3.2 spatial   → seq (or image H/W) over model; params replicated ("ds"
                       when combined with batch over data)
paper §3.3 filter    → heads/mlp/filters (output channels) over model
paper §3.3 channel   → embed/input channels over model (row-parallel)
paper §3.4 layer     → pipeline stages: params shard over "layers", the
                       GPipe schedule itself is parallel/pipeline.py's
                       make_pipeline_train_step (deployable since ISSUE 3)
paper §3.5 hybrid    → df / ds compositions
beyond-paper         → ZeRO-1/3 (optimizer/param sharding over data),
                       expert parallelism, sequence-parallel residual stream
"""
from __future__ import annotations


from ..nn.module import Rules

# DP axes: "pod" is a prefix axis that only exists in the multi-pod mesh.
# Rules name both; spec_to_pspec skips axes missing from the mesh.
DP = ("pod", "data")
ALL = ("pod", "data", "model")


def _act_common(seq_parallel: bool = True):
    """Activation axes shared by the hybrid strategies."""
    table = {
        "batch": DP,
        "act_heads": "model",
        "act_kv": "model",
        "act_mlp": "model",
    }
    if seq_parallel:
        table["seq"] = "model"  # residual stream sequence-parallel (Megatron-SP)
    return table


STRATEGIES: dict[str, dict] = {
    # --- pure strategies (paper §3.1–3.4) --------------------------------
    "data": {"batch": ALL},
    "spatial": {"spatial": "model", "seq": "model", "batch": DP},
    # layer (pipeline): stage SCHEDULING lives in parallel/pipeline.py
    # (make_pipeline_train_step); the rules table only places the stacked
    # block parameters — their leading "layers" axis shards over the model
    # axis so each rank holds its stages' weights, everything else
    # replicates. Activations hop stages via gpipe's collective_permute.
    "pipeline": {"layers": "model"},
    "filter": {**_act_common(), "heads": ("data", "model"),
               "kv_heads": ("data", "model"), "mlp": ("data", "model"),
               "conv_out": ("data", "model"), "batch": ("pod",)},
    "channel": {**_act_common(), "embed": ("data", "model"),
                "conv_in": ("data", "model"), "batch": ("pod",)},
    # --- hybrids (paper §3.5) ---------------------------------------------
    "df": {**_act_common(), "heads": "model", "kv_heads": "model",
           "mlp": "model", "experts": "model", "conv_out": "model",
           "vocab": "model"},
    "ds": {"batch": DP, "seq": "model", "spatial": "model"},
    # --- beyond paper -------------------------------------------------------
    # df + ZeRO-3: parameters additionally sharded over the data axis on
    # their embed/vocab dims (gathered on the fly by the partitioner).
    "df_zero3": {**_act_common(), "heads": "model", "kv_heads": "model",
                 "mlp": "model", "experts": "model", "conv_out": "model",
                 "embed": "data", "vocab": "model", "state": None,
                 "qk_rank": "model", "kv_rank": "model"},
    # df + ZeRO-1 (optimizer states sharded in optim/, params replicated
    # over data)
    "df_zero1": {**_act_common(), "heads": "model", "kv_heads": "model",
                 "mlp": "model", "experts": "model", "conv_out": "model",
                 "vocab": "model"},
    # expert parallelism for MoE + df for attention + ZeRO-3
    "ep_df": {**_act_common(), "experts": "model", "heads": "model",
              "kv_heads": "model", "mlp": None, "embed": "data",
              "vocab": "model", "qk_rank": "model", "kv_rank": "model"},
    # 2D (SUMMA) tensor grid: the model axis factors as model_r × model_c.
    # seq + weight K-dims ride the rows, hidden/filter dims ride the
    # columns → the residual stream is 2D-sharded (sequence parallelism is
    # built in). parallel/summa.py detects this table (seq→model_r,
    # act_embed→model_c is the opt-in marker) and routes FFN/attention
    # projections through the explicit ppermute SUMMA matmul; on a mesh
    # without the grid axes the table degrades to fully-replicated (safe).
    "summa": {"batch": DP, "seq": "model_r",
              "act_embed": "model_c", "act_mlp": "model_c",
              "act_heads": "model_c", "act_kv": "model_c",
              "embed": "model_r", "mlp": "model_c",
              "heads": "model_c", "kv_heads": "model_c",
              "conv_in": "model_r", "conv_out": "model_c",
              "vocab": "model_c"},
    # serving: no ZeRO (weights gathered once, latency-critical), TP on model
    "serve_tp": {**_act_common(seq_parallel=False), "heads": "model",
                 "kv_heads": "model", "mlp": "model", "experts": "model",
                 "vocab": "model", "seq": "model"},
    # serving with the sequence-sharded (flash-decoding) KV cache layout:
    # the cache's shard dim claims the model axis ("seq"), heads replicate.
    "serve_seqkv": {"batch": DP, "seq": "model", "heads": "model",
                    "kv_heads": "model", "mlp": "model", "experts": "model",
                    "vocab": "model", "act_mlp": "model", "act_heads": None,
                    "act_kv": None},
}


def make_rules(strategy: str) -> Rules:
    if strategy not in STRATEGIES:
        raise KeyError(f"unknown strategy {strategy!r}; "
                       f"known: {sorted(STRATEGIES)}")
    return Rules.of({k: v for k, v in STRATEGIES[strategy].items()
                     if v is not None})


def list_strategies() -> list[str]:
    return sorted(STRATEGIES)
