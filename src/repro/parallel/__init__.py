from .strategies import STRATEGIES, list_strategies, make_rules
from .schedules import (SCHEDULE_NAMES, SCHEDULES, block_costs_from_stats,
                        clip_segments, gpipe, interleaved,
                        make_masked_stage_fn, make_pipeline_train_step,
                        make_stage_fn, make_virtual_stage_fn, one_f_one_b,
                        pipeline_block_costs, pipeline_block_count,
                        pipeline_supported, resolve_segments,
                        stack_stage_bounds, stack_stages,
                        stack_virtual_stage_bounds)
from .halo import HaloConv, halo_exchange, spatial_conv2d
