from .strategies import STRATEGIES, list_strategies, make_rules
from .pipeline import gpipe, make_stage_fn, stack_stages
from .halo import halo_exchange, spatial_conv2d
