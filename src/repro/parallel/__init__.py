from .strategies import STRATEGIES, list_strategies, make_rules
from .pipeline import (block_costs_from_stats, clip_segments, gpipe,
                       make_masked_stage_fn, make_pipeline_train_step,
                       make_stage_fn, pipeline_supported, stack_stage_bounds,
                       stack_stages)
from .halo import HaloConv, halo_exchange, spatial_conv2d
