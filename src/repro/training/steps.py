"""Step builders: train_step / prefill_step / decode_step as pure jittables.

``make_train_step`` returns (step_fn, state_spec): the state spec is a
ParamSpec tree usable for real initialization (tree_init), abstract dry-run
lowering (tree_abstract) and checkpoint layout — one source of truth.

Gradient accumulation (microbatching) is a first-class option: the global
batch is split into ``accum`` microbatches scanned sequentially with gradient
averaging — the paper's weak-scaling knob when memory binds before compute.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from ..nn.module import ShardingCtx, param
from ..optim.optimizers import OptimizerConfig, apply_update, state_spec


def train_state_spec(model, opt: OptimizerConfig):
    pspec = model.params_spec()
    return {
        "params": pspec,
        "opt": state_spec(opt, pspec),
        "step": param((), (), init=lambda k, s, d: jnp.zeros(s, d),
                      dtype=jnp.int32),
    }


def make_train_step(model, opt: OptimizerConfig, ctx: ShardingCtx,
                    accum: int = 1, **fwd_kw) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics)."""

    def loss_of(params, batch):
        loss, metrics = model.loss_fn(params, batch, ctx, **fwd_kw)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_of, has_aux=True)

    def train_step(state, batch):
        params = state["params"]
        if accum == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            def micro(carry, mb):
                acc, = carry
                (l, m), g = grad_fn(params, mb)
                acc = jax.tree.map(lambda a, b: a + b.astype(a.dtype), acc, g)
                return (acc,), (l, m)

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            mbs = jax.tree.map(
                lambda x: x.reshape(accum, x.shape[0] // accum, *x.shape[1:]),
                batch)
            (gsum,), (ls, ms) = jax.lax.scan(micro, (zeros,), mbs)
            grads = jax.tree.map(lambda g: g / accum, gsum)
            loss = jnp.mean(ls)
            metrics = jax.tree.map(jnp.mean, ms)
        new_params, new_opt, om = apply_update(opt, params, grads,
                                               state["opt"], state["step"])
        metrics = dict(metrics, loss=loss, **om)
        return {"params": new_params, "opt": new_opt,
                "step": state["step"] + 1}, metrics

    return train_step


def make_prefill_step(model, ctx: ShardingCtx, **kw) -> Callable:
    """(params, batch, cache) -> (logits, cache). batch carries the prompt.

    Returned un-jitted. Callers that jit it MUST donate the cache —
    ``jax.jit(step, donate_argnums=(2,))`` — or every prefill materializes
    a second full KV cache just to update it (the serving engine and
    launch/dryrun.py both donate; keep new call sites consistent)."""

    def prefill_step(params, batch, cache):
        if hasattr(model, "prefill"):
            if "frames" in batch:  # enc-dec
                _, cache = model.prefill(params, batch["frames"], cache, ctx, **kw)
                return jnp.zeros((batch["frames"].shape[0], 1)), cache
            if "patches" in batch:  # vlm
                return model.prefill(params, batch, cache, ctx, **kw)
            return model.prefill(params, batch["tokens"], cache, ctx, **kw)
        raise TypeError(f"{type(model)} has no prefill")

    return prefill_step


def make_decode_step(model, ctx: ShardingCtx, **kw) -> Callable:
    """(params, token, cache, pos) -> (logits, cache). One new token.

    Same donation contract as ``make_prefill_step``: jit with
    ``donate_argnums=(2,)`` so the per-token cache update happens in place
    instead of copying the whole cache every step."""

    def decode_step(params, token, cache, pos):
        return model.decode_step(params, token, cache, pos, ctx, **kw)

    return decode_step


def make_eval_step(model, ctx: ShardingCtx, **fwd_kw) -> Callable:
    def eval_step(params, batch):
        loss, metrics = model.loss_fn(params, batch, ctx, **fwd_kw)
        return dict(metrics, loss=loss)

    return eval_step
