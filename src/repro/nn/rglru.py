"""Griffin/RecurrentGemma recurrent block: conv1d + RG-LRU gated linear recurrence.

RG-LRU [arXiv:2402.19427]:
    r_t = sigmoid(W_a x_t + b_a)          (recurrence gate)
    i_t = sigmoid(W_x x_t + b_x)          (input gate)
    a_t = exp(-c * softplus(Λ) * r_t)     (diagonal decay, c = 8)
    h_t = a_t h_{t-1} + sqrt(1 - a_t²) * (i_t * x_t)

The recurrence is diagonal in the channel dim → paper-style *filter*
parallelism applies cleanly (shard channels over the model axis); the seq dim
serializes (no spatial/sequence parallelism), evaluated with an associative
scan for training and O(1) state for decode.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from .module import NULL_CTX, ShardingCtx, fan_in_init, param

_C = 8.0  # RG-LRU decay sharpness constant


@dataclass(frozen=True)
class RGLRUConfig:
    d_model: int
    lru_width: int
    d_conv: int = 4
    n_blocks: int = 16  # block-diagonal gate layers (RecurrentGemma style)
    dtype: Any = None


@dataclass(frozen=True)
class RecurrentBlock:
    """linear→conv1d→RG-LRU branch ⊙ linear→GeLU branch → linear out."""

    cfg: RGLRUConfig

    def params_spec(self):
        c = self.cfg
        fi = fan_in_init((0,))
        z = lambda k, s, d: jnp.zeros(s, d)

        def lam_init(key, shape, dtype):
            # a in [0.9, 0.999]:  Λ = softplus^-1(-log(a)/c)
            u = jax.random.uniform(key, shape, jnp.float32, 0.9, 0.999)
            val = -jnp.log(u) / _C
            return jnp.log(jnp.expm1(val)).astype(dtype)

        return {
            "w_rec": param((c.d_model, c.lru_width), ("embed", "mlp"), init=fi,
                           dtype=c.dtype),
            "w_gate_branch": param((c.d_model, c.lru_width), ("embed", "mlp"),
                                   init=fi, dtype=c.dtype),
            "conv_w": param((c.d_conv, c.lru_width), ("conv_k", "mlp"),
                            init=fan_in_init((0,)), dtype=c.dtype),
            "conv_b": param((c.lru_width,), ("mlp",), init=z, dtype=c.dtype),
            "w_a": param((c.n_blocks, c.lru_width // c.n_blocks,
                          c.lru_width // c.n_blocks), ("mlp", None, None),
                         init=fan_in_init((1,)), dtype=c.dtype),
            "b_a": param((c.lru_width,), ("mlp",), init=z, dtype=jnp.float32),
            "w_x": param((c.n_blocks, c.lru_width // c.n_blocks,
                          c.lru_width // c.n_blocks), ("mlp", None, None),
                         init=fan_in_init((1,)), dtype=c.dtype),
            "b_x": param((c.lru_width,), ("mlp",), init=z, dtype=jnp.float32),
            "lam": param((c.lru_width,), ("mlp",), init=lam_init, dtype=jnp.float32),
            "w_out": param((c.lru_width, c.d_model), ("mlp", "embed"), init=fi,
                           dtype=c.dtype),
        }

    def _conv(self, params, x):
        c = self.cfg
        pad = jnp.pad(x, ((0, 0), (c.d_conv - 1, 0), (0, 0)))
        out = sum(pad[:, i:i + x.shape[1], :] * params["conv_w"][i]
                  for i in range(c.d_conv))
        return out + params["conv_b"]

    def _blockdiag(self, x, w):
        c = self.cfg
        nb = c.n_blocks
        xs = x.reshape(*x.shape[:-1], nb, c.lru_width // nb)
        y = jnp.einsum("...nw,nwv->...nv", xs, w)
        return y.reshape(*x.shape)

    def _gates(self, params, x):
        r = jax.nn.sigmoid(self._blockdiag(x, params["w_a"]).astype(jnp.float32)
                           + params["b_a"])
        i = jax.nn.sigmoid(self._blockdiag(x, params["w_x"]).astype(jnp.float32)
                           + params["b_x"])
        log_a = -_C * jax.nn.softplus(params["lam"]) * r   # (B,S,W) fp32
        a = jnp.exp(log_a)
        gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * i * x.astype(jnp.float32)
        return a, gated

    def apply(self, params, u, ctx: ShardingCtx = NULL_CTX):
        c = self.cfg
        u = ctx.constrain(u, ("batch", None, "act_embed"))
        x = u @ params["w_rec"]
        x = ctx.constrain(x, ("batch", None, "act_mlp"))
        x = self._conv(params, x)
        a, gated = self._gates(params, x)

        def assoc(p, q):
            ap, hp = p
            aq, hq = q
            return ap * aq, hq + hp * aq

        _, h = jax.lax.associative_scan(assoc, (a, gated), axis=1)
        h = h.astype(u.dtype)
        gate = jax.nn.gelu(u @ params["w_gate_branch"])
        y = (h * gate) @ params["w_out"]
        return ctx.constrain(y, ("batch", "seq", "act_embed"))

    def cache_spec(self, batch: int, dtype=jnp.float32):
        c = self.cfg
        z = lambda k, s, d: jnp.zeros(s, d)
        return {
            "h": param((batch, c.lru_width), ("batch", "act_mlp"), init=z,
                       dtype=dtype),
            "conv": param((batch, c.d_conv - 1, c.lru_width),
                          ("batch", None, "act_mlp"), init=z, dtype=dtype),
        }

    def decode(self, params, u, cache, pos, ctx: ShardingCtx = NULL_CTX):
        c = self.cfg
        x = (u @ params["w_rec"])[:, 0]  # (B, W)
        conv_buf = jnp.concatenate(
            [cache["conv"], x[:, None].astype(cache["conv"].dtype)], axis=1)
        x = jnp.einsum("bkc,kc->bc", conv_buf.astype(u.dtype),
                       params["conv_w"]) + params["conv_b"]
        a, gated = self._gates(params, x[:, None])
        h = a[:, 0] * cache["h"] + gated[:, 0]
        gate = jax.nn.gelu(u @ params["w_gate_branch"])
        y = (h.astype(u.dtype)[:, None] * gate) @ params["w_out"]
        new_cache = {"h": h.astype(cache["h"].dtype), "conv": conv_buf[:, 1:]}
        return ctx.constrain(y, ("batch", "seq", "act_embed")), new_cache
