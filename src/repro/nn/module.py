"""Lightweight functional module system with logical-axis partitioning.

No flax on this box, so the framework rolls its own parameter system, in the
style of MaxText/T5X logical axes:

* A module is a frozen dataclass holding config. It exposes
  ``params_spec() -> tree of ParamSpec`` and pure ``apply(params, ...)``.
* ``ParamSpec`` records shape, dtype, initializer and *logical* axis names
  ("embed", "mlp", "heads", ...).
* A parallelism strategy is a ``Rules`` table mapping logical axes to mesh
  axes. ``tree_shardings`` turns a spec tree + mesh + rules into
  ``NamedSharding``s; ``tree_init`` materializes parameters;
  ``tree_abstract`` produces allocation-free ``ShapeDtypeStruct`` stand-ins
  for the multi-pod dry-run.

The logical→mesh indirection is what lets the same model definition run under
every parallel strategy of the paper (data / spatial / filter / channel /
pipeline / hybrids) by swapping a rules table instead of editing the model.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass
from typing import Any, Callable, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# ---------------------------------------------------------------------------
# Logical axis vocabulary (documented; anything else is rejected early).
# ---------------------------------------------------------------------------
LOGICAL_AXES = frozenset(
    {
        # activations
        "batch", "seq", "act_embed", "act_mlp", "act_heads", "act_kv",
        # parameters
        "embed", "mlp", "heads", "kv_heads", "head_dim", "vocab", "layers",
        "experts", "state", "conv_k", "conv_in", "conv_out", "spatial",
        "qk_rank", "kv_rank",  # MLA low-rank dims
        "unsharded",
    }
)

Initializer = Callable[[jax.Array, Sequence[int], Any], jax.Array]


def _normal(stddev: float) -> Initializer:
    def init(key, shape, dtype):
        return (jax.random.normal(key, shape, jnp.float32) * stddev).astype(dtype)
    return init


def fan_in_init(fan_axes: Sequence[int] | None = None) -> Initializer:
    """LeCun-normal style: stddev = 1/sqrt(fan_in over the given axes)."""

    def init(key, shape, dtype):
        if fan_axes is None:
            fan = shape[0] if len(shape) >= 1 else 1
        else:
            fan = int(np.prod([shape[a] for a in fan_axes]))
        stddev = 1.0 / np.sqrt(max(fan, 1))
        return (jax.random.normal(key, shape, jnp.float32) * stddev).astype(dtype)

    return init


def zeros_init() -> Initializer:
    return lambda key, shape, dtype: jnp.zeros(shape, dtype)


def ones_init() -> Initializer:
    return lambda key, shape, dtype: jnp.ones(shape, dtype)


@dataclass(frozen=True)
class ParamSpec:
    """Declarative parameter: shape + logical axes + initializer."""

    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    init: Initializer | None = None  # default: fan-in normal over axis 0
    dtype: Any = None  # None -> use the tree-level default

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"shape {self.shape} / axes {self.axes} rank mismatch")
        for a in self.axes:
            if a is not None and a not in LOGICAL_AXES:
                raise ValueError(f"unknown logical axis {a!r}; add it to LOGICAL_AXES")


def param(shape: Sequence[int], axes: Sequence[str | None],
          init: Initializer | None = None, dtype: Any = None) -> ParamSpec:
    return ParamSpec(tuple(int(s) for s in shape), tuple(axes), init, dtype)


# ---------------------------------------------------------------------------
# Rules: logical axis -> mesh axis (str | tuple[str, ...] | None)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class Rules:
    """Mapping from logical axes to mesh axes for one parallel strategy."""

    table: tuple[tuple[str, Any], ...]

    @staticmethod
    def of(mapping: Mapping[str, Any]) -> "Rules":
        for k in mapping:
            if k not in LOGICAL_AXES:
                raise ValueError(f"unknown logical axis {k!r} in rules")
        return Rules(tuple(sorted(mapping.items())))

    def get(self, axis: str | None):
        if axis is None:
            return None
        for k, v in self.table:
            if k == axis:
                return v
        return None

    def merged(self, extra: Mapping[str, Any]) -> "Rules":
        d = dict(self.table)
        d.update(extra)
        return Rules.of(d)


def spec_to_pspec(spec_axes: Sequence[str | None], rules: Rules, mesh: Mesh,
                  shape: Sequence[int] | None = None) -> P:
    """Resolve logical axes to a PartitionSpec.

    Guarantees validity: a mesh axis is used at most once, and sharded dims
    must divide evenly by the mesh-axis size (otherwise that dim falls back
    to replication — the partitioner cannot handle uneven shards portably).
    """
    used: set[str] = set()
    out = []
    for i, ax in enumerate(spec_axes):
        mesh_axes = rules.get(ax)
        if mesh_axes is None:
            out.append(None)
            continue
        if isinstance(mesh_axes, str):
            mesh_axes = (mesh_axes,)
        picked = []
        size = 1
        for m in mesh_axes:
            if m in used or m not in mesh.shape:
                continue
            picked.append(m)
            size *= mesh.shape[m]
        if not picked:
            out.append(None)
            continue
        if shape is not None and shape[i] % size != 0:
            # try a prefix of the requested axes that divides
            picked2, size2 = [], 1
            for m in picked:
                if shape[i] % (size2 * mesh.shape[m]) == 0:
                    picked2.append(m)
                    size2 *= mesh.shape[m]
            picked = picked2
            if not picked:
                out.append(None)
                continue
        used.update(picked)
        out.append(tuple(picked) if len(picked) > 1 else picked[0])
    return P(*out)


# ---------------------------------------------------------------------------
# Tree utilities
# ---------------------------------------------------------------------------

def _is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def _path_key(key: jax.Array, path: str) -> jax.Array:
    """Deterministic per-parameter key derived from the tree path."""
    digest = hashlib.sha256(path.encode()).digest()
    fold = int.from_bytes(digest[:4], "little")
    return jax.random.fold_in(key, fold)


def _iter_paths(tree, prefix=""):
    if _is_spec(tree):
        yield prefix, tree
    elif isinstance(tree, Mapping):
        for k in sorted(tree):
            yield from _iter_paths(tree[k], f"{prefix}/{k}")
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            yield from _iter_paths(v, f"{prefix}/{i}")
    elif tree is None:
        return
    else:
        raise TypeError(f"unexpected leaf {type(tree)} at {prefix}")


def tree_map_spec(fn, tree):
    return jax.tree.map(fn, tree, is_leaf=_is_spec)


def tree_init(spec_tree, key: jax.Array, default_dtype=jnp.float32):
    """Materialize a parameter tree (real arrays), keyed by tree path."""

    def init_one(path: str, spec: ParamSpec):
        dtype = spec.dtype or default_dtype
        init = spec.init or fan_in_init()
        return init(_path_key(key, path), spec.shape, dtype)

    def go(tree, prefix):
        if _is_spec(tree):
            return init_one(prefix, tree)
        if isinstance(tree, Mapping):
            return {k: go(tree[k], f"{prefix}/{k}") for k in tree}
        if isinstance(tree, (list, tuple)):
            out = [go(v, f"{prefix}/{i}") for i, v in enumerate(tree)]
            return type(tree)(out) if isinstance(tree, tuple) else out
        if tree is None:
            return None
        raise TypeError(f"unexpected leaf {type(tree)} at {prefix}")

    return go(spec_tree, "")


def tree_abstract(spec_tree, default_dtype=jnp.float32, mesh: Mesh | None = None,
                  rules: Rules | None = None):
    """ShapeDtypeStruct stand-ins (no allocation) — dry-run entry point."""

    def one(spec: ParamSpec):
        dtype = spec.dtype or default_dtype
        if mesh is not None and rules is not None:
            pspec = spec_to_pspec(spec.axes, rules, mesh, spec.shape)
            return jax.ShapeDtypeStruct(spec.shape, dtype,
                                        sharding=NamedSharding(mesh, pspec))
        return jax.ShapeDtypeStruct(spec.shape, dtype)

    return tree_map_spec(one, spec_tree)


def tree_shardings(spec_tree, mesh: Mesh, rules: Rules):
    def one(spec: ParamSpec):
        return NamedSharding(mesh, spec_to_pspec(spec.axes, rules, mesh, spec.shape))

    return tree_map_spec(one, spec_tree)


def tree_num_params(spec_tree) -> int:
    return sum(int(np.prod(s.shape)) for _, s in _iter_paths(spec_tree))


def tree_num_bytes(spec_tree, default_dtype=jnp.float32) -> int:
    total = 0
    for _, s in _iter_paths(spec_tree):
        dt = jnp.dtype(s.dtype or default_dtype)
        total += int(np.prod(s.shape)) * dt.itemsize
    return total


# ---------------------------------------------------------------------------
# Activation sharding helper
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ShardingCtx:
    """Mesh + rules, closed over by model apply fns for activation constraints.

    ``use_pallas`` routes the CNN hot path (HaloConv / conv2d) through the
    implicit-GEMM Pallas kernel (kernels/conv2d_gemm) instead of
    ``lax.conv`` — interpret-mode off-TPU, so it is correct (if slow)
    everywhere and MXU-shaped where it matters.

    ``kernel_tiles`` (a ``kernels.autotune.KernelTiles``, typed loosely to
    keep nn jax-import-order-clean) carries tuned block sizes down to the
    kernel call sites; None ⇒ the kernels' built-in defaults.
    """

    mesh: Mesh | None
    rules: Rules
    use_pallas: bool = False
    kernel_tiles: Any = None

    def constrain(self, x: jax.Array, axes: Sequence[str | None]) -> jax.Array:
        if self.mesh is None:
            return x
        pspec = spec_to_pspec(tuple(axes), self.rules, self.mesh, x.shape)
        return jax.lax.with_sharding_constraint(x, NamedSharding(self.mesh, pspec))


NULL_CTX = ShardingCtx(mesh=None, rules=Rules.of({}))


@jax.custom_vjp
def grad_barrier(x):
    """Identity whose COTANGENT is cast to x's dtype.

    Applied at block boundaries so residual-stream gradients cross sharding
    constraints in bf16 — without it, fp32 attention/softmax internals leak
    fp32 cotangents into the per-layer model-axis all-reduces, doubling
    their wire bytes (EXPERIMENTS.md §Perf, qwen3 iteration 1).
    """
    return x


def _gb_fwd(x):
    # residuals must be JAX types: carry the dtype as a zero-size array
    return x, jnp.zeros((0,), x.dtype)


def _gb_bwd(res, ct):
    return (ct.astype(res.dtype),)


grad_barrier.defvjp(_gb_fwd, _gb_bwd)
