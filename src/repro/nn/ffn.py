"""Feed-forward blocks: dense (GLU or plain) and Mixture-of-Experts.

MoE uses the GShard/MaxText einsum dispatch formulation: tokens are grouped,
routed with top-k + capacity, and dispatched/combined with one-hot einsums.
Under expert parallelism ("experts" → model axis) + data parallelism
("batch" → data axis) the SPMD partitioner materializes the all-to-all pair
the paper's communication analysis would assign to a channel/filter-style
horizontal split (paper §3.3) — experts are "filters at layer granularity".
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from .module import NULL_CTX, ShardingCtx, fan_in_init, param

_ACTS = {
    "silu": jax.nn.silu,
    "gelu": jax.nn.gelu,
    "relu": jax.nn.relu,
    "gelu_tanh": lambda x: jax.nn.gelu(x, approximate=True),
}


@dataclass(frozen=True)
class FFNConfig:
    d_model: int
    d_ff: int
    activation: str = "silu"
    glu: bool = True
    use_bias: bool = False
    dtype: Any = None


@dataclass(frozen=True)
class FFN:
    cfg: FFNConfig

    def params_spec(self):
        c = self.cfg
        fi = fan_in_init((0,))
        spec = {"w_in": param((c.d_model, c.d_ff), ("embed", "mlp"), init=fi,
                              dtype=c.dtype),
                "w_out": param((c.d_ff, c.d_model), ("mlp", "embed"), init=fi,
                               dtype=c.dtype)}
        if c.glu:
            spec["w_gate"] = param((c.d_model, c.d_ff), ("embed", "mlp"), init=fi,
                                   dtype=c.dtype)
        if c.use_bias:
            z = lambda k, s, d: jnp.zeros(s, d)
            spec["b_in"] = param((c.d_ff,), ("mlp",), init=z, dtype=c.dtype)
            spec["b_out"] = param((c.d_model,), ("embed",), init=z, dtype=c.dtype)
        return spec

    def apply(self, params, x, ctx: ShardingCtx = NULL_CTX):
        c = self.cfg
        act = _ACTS[c.activation]
        from ..parallel import summa  # lazy: nn stays import-light
        if summa.summa_axes(ctx) and summa.ffn_ok(c, ctx.mesh, x.shape):
            y = summa.ffn_apply(c, params, x, act, ctx)
            return ctx.constrain(y, ("batch", "seq", "act_embed"))
        x = ctx.constrain(x, ("batch", None, "act_embed"))
        h = x @ params["w_in"]
        if c.use_bias:
            h = h + params["b_in"]
        h = act(h)
        if c.glu:
            h = h * (x @ params["w_gate"])
        h = ctx.constrain(h, ("batch", None, "act_mlp"))
        y = h @ params["w_out"]
        if c.use_bias:
            y = y + params["b_out"]
        return ctx.constrain(y, ("batch", "seq", "act_embed"))


# ---------------------------------------------------------------------------
# Mixture of Experts
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class MoEConfig:
    d_model: int
    d_ff: int                  # per-expert hidden
    n_experts: int
    top_k: int
    n_shared: int = 0          # deepseek shared experts (always-on)
    shared_d_ff: int | None = None
    capacity_factor: float = 1.25
    activation: str = "silu"
    glu: bool = True
    router_softmax: bool = True   # False → sigmoid+normalize (DeepSeek-V3)
    aux_loss_coef: float = 0.001
    n_groups: int = 1          # token groups for dispatch (per data shard)
    dtype: Any = None


@dataclass(frozen=True)
class MoE:
    cfg: MoEConfig

    def params_spec(self):
        c = self.cfg
        fi = fan_in_init((1,))
        spec = {
            "router": param((c.d_model, c.n_experts), ("embed", None),
                            init=fan_in_init((0,)), dtype=jnp.float32),
            "w_in": param((c.n_experts, c.d_model, c.d_ff),
                          ("experts", "embed", "mlp"), init=fi, dtype=c.dtype),
            "w_out": param((c.n_experts, c.d_ff, c.d_model),
                           ("experts", "mlp", "embed"), init=fi, dtype=c.dtype),
        }
        if c.glu:
            spec["w_gate"] = param((c.n_experts, c.d_model, c.d_ff),
                                   ("experts", "embed", "mlp"), init=fi,
                                   dtype=c.dtype)
        if c.n_shared:
            shared = FFN(FFNConfig(c.d_model, (c.shared_d_ff or c.d_ff) * c.n_shared,
                                   c.activation, c.glu, dtype=c.dtype))
            spec["shared"] = shared.params_spec()
        return spec

    def _route(self, params, x):
        """x: (T, d) → top-k expert ids, weights, aux loss."""
        c = self.cfg
        logits = (x.astype(jnp.float32) @ params["router"])  # (T, E)
        if c.router_softmax:
            probs = jax.nn.softmax(logits, axis=-1)
        else:  # DeepSeek-V3 sigmoid scoring
            probs = jax.nn.sigmoid(logits)
        weights, ids = jax.lax.top_k(probs, c.top_k)  # (T, k)
        weights = weights / jnp.maximum(jnp.sum(weights, -1, keepdims=True), 1e-9)
        # Switch-style load-balance aux loss
        pe = jnp.mean(jax.nn.softmax(logits, axis=-1), axis=0)           # (E,)
        fe = jnp.mean(jax.nn.one_hot(ids[:, 0], c.n_experts), axis=0)     # (E,)
        aux = c.n_experts * jnp.sum(pe * fe) * c.aux_loss_coef
        return ids, weights, aux

    def apply(self, params, x, ctx: ShardingCtx = NULL_CTX):
        """x: (B, S, d). Returns (y, aux_loss)."""
        c = self.cfg
        B, S, D = x.shape
        T = B * S
        # largest group count <= n_groups that divides the token count
        # (decode steps have T == batch, much smaller than the train target)
        G = math.gcd(T, c.n_groups)
        tg = T // G
        cap = int(np.ceil(c.top_k * tg / c.n_experts * c.capacity_factor))
        cap = max(cap, 1)
        xg = x.reshape(G, tg, D)
        xg = ctx.constrain(xg, ("batch", None, "act_embed"))

        ids, weights, aux = self._route(params, x.reshape(T, D))
        ids = ids.reshape(G, tg, c.top_k)
        weights = weights.reshape(G, tg, c.top_k)

        # position of each (token, choice) within its expert's capacity buffer
        onehot = jax.nn.one_hot(ids, c.n_experts, dtype=jnp.float32)  # (G,t,k,E)
        flat = onehot.reshape(G, tg * c.top_k, c.n_experts)
        ranks = jnp.cumsum(flat, axis=1) * flat  # 1-based rank within expert
        pos_in_e = jnp.sum(ranks.reshape(G, tg, c.top_k, c.n_experts), -1) - 1.0
        keep = (pos_in_e >= 0) & (pos_in_e < cap)  # (G,t,k)
        pos_idx = jnp.clip(pos_in_e, 0, cap - 1).astype(jnp.int32)
        pos_oh = jax.nn.one_hot(pos_idx, cap, dtype=jnp.float32) * keep[..., None]
        # dispatch mask (G, t, E, C): 1 where token t goes to slot (E, C).
        # Cast to the compute dtype and pin the sharding BEFORE the big
        # dispatch einsums: without the constraint the SPMD partitioner
        # replicate-reduces them as fp32 model-axis all-reduces
        # (EXPERIMENTS.md §Perf, deepseek-v3 iteration log).
        dispatch = jnp.einsum("gtke,gtkc->gtec", onehot, pos_oh).astype(x.dtype)
        combine = jnp.einsum("gtke,gtkc,gtk->gtec", onehot, pos_oh,
                             weights).astype(jnp.float32)
        dispatch = ctx.constrain(dispatch, ("batch", None, "experts", None))
        combine = ctx.constrain(combine, ("batch", None, "experts", None))

        expert_in = jnp.einsum("gtec,gtd->egcd", dispatch, xg)
        expert_in = ctx.constrain(expert_in, ("experts", "batch", None, "act_embed"))
        act = _ACTS[c.activation]
        h = jnp.einsum("egcd,edf->egcf", expert_in, params["w_in"])
        h = act(h)
        if c.glu:
            h = h * jnp.einsum("egcd,edf->egcf", expert_in, params["w_gate"])
        h = ctx.constrain(h, ("experts", "batch", None, "act_mlp"))
        out = jnp.einsum("egcf,efd->egcd", h, params["w_out"])
        out = ctx.constrain(out, ("experts", "batch", None, "act_embed"))
        y = jnp.einsum("gtec,egcd->gtd", combine.astype(x.dtype), out)
        y = y.reshape(B, S, D)
        if c.n_shared:
            shared = FFN(FFNConfig(c.d_model, (c.shared_d_ff or c.d_ff) * c.n_shared,
                                   c.activation, c.glu, dtype=c.dtype))
            y = y + shared.apply(params["shared"], x, ctx)
        return ctx.constrain(y, ("batch", "seq", "act_embed")), aux
