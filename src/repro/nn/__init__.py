from .module import (LOGICAL_AXES, NULL_CTX, ParamSpec, Rules, ShardingCtx,
                     fan_in_init, ones_init, param, spec_to_pspec, tree_abstract,
                     tree_init, tree_num_bytes, tree_num_params, tree_shardings,
                     zeros_init)
from .layers import (BatchNorm, Conv, Dense, Embedding, LayerNorm, RMSNorm,
                     avg_pool, global_avg_pool, max_pool)
from .attention import (Attention, AttentionConfig, MLAttention, MLAConfig,
                        flash_attention, plain_attention)
from .ffn import FFN, FFNConfig, MoE, MoEConfig
from .ssm import SSDBlock, SSMConfig
from .rglru import RecurrentBlock, RGLRUConfig
