"""Attention: GQA/MQA (+qk-norm, bias, logit softcap, sliding window) and MLA.

Three execution paths:
  * ``plain``       — full score matrix; reference + small shapes.
  * ``chunked``     — flash-style online softmax over (q-chunk, kv-chunk)
                      blocks. ``unroll=True`` emits a static Python loop that
                      *skips fully-masked causal blocks* (exact flash FLOPs in
                      the lowered HLO — used by the dry-run cost extraction);
                      ``unroll=False`` uses ``lax.scan`` (small HLO — used by
                      the full-step compile and real training).
  * ``decode``      — single-token attention against a KV cache. The cache
                      carries a leading ``shards`` dim so it can be laid out
                      either per-kv-head (shards=1) or sequence-sharded
                      (flash-decoding style, shards=mesh model size) with a
                      log-sum-exp merge — the §Perf decode optimization.

The per-head semantics follow the paper's filter-parallel scheme: heads are
the "filters" of the attention layer; sharding heads over the model axis is
exactly paper-§3.3 filter parallelism applied to attention.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from .layers import RMSNorm
from .module import NULL_CTX, ShardingCtx, fan_in_init, param
from .rotary import apply_rope

NEG_INF = -2.0e38  # large negative for masking in fp32


def _softcap(x, cap):
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------------------
# Config
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class AttentionConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    use_bias: bool = False          # qwen1.5: bias on QKV only
    out_bias: bool = False
    qk_norm: bool = False           # qwen3
    rope: bool = True
    rope_base: float = 10000.0
    window: int | None = None       # sliding-window (recurrentgemma local attn)
    logit_softcap: float | None = None  # grok-1 style
    causal: bool = True
    dtype: Any = None

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim


# ---------------------------------------------------------------------------
# Core attention math (shared by all paths)
# ---------------------------------------------------------------------------

def _block_attn(q, k, v, qpos, kpos, scale, causal, window, softcap):
    """One (q-block, kv-block) flash step. q:(B,H,Q,D) k:(B,H,K,D) v:(B,H,K,D).

    Returns un-normalized outputs plus row max/sum for online softmax merge:
    (o_unnorm (B,H,Q,D) fp32, m (B,H,Q) fp32, s (B,H,Q) fp32).
    """
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32) * scale
    s = _softcap(s, softcap)
    mask = jnp.ones(s.shape[-2:], bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window is not None:
        mask &= qpos[:, None] - kpos[None, :] < window
    s = jnp.where(mask, s, NEG_INF)
    m = jnp.max(s, axis=-1)
    p = jnp.exp(s - m[..., None])
    p = jnp.where(jnp.isfinite(m)[..., None], p, 0.0)
    o = jnp.einsum("bhqk,bhkd->bhqd", p.astype(v.dtype), v).astype(jnp.float32)
    return o, m, jnp.sum(p, axis=-1)


def _merge_blocks(partials):
    """LSE-merge of flash partials along a leading block axis."""
    o, m, s = partials  # o:(T,B,H,Q,D) m,s:(T,B,H,Q)
    m_all = jnp.max(m, axis=0)
    scale = jnp.exp(m - m_all[None])
    scale = jnp.where(jnp.isfinite(m), scale, 0.0)
    s_all = jnp.sum(s * scale, axis=0)
    o_all = jnp.sum(o * scale[..., None], axis=0)
    return o_all / jnp.maximum(s_all, 1e-30)[..., None]


def flash_attention(q, k, v, *, causal=True, window=None, softcap=None,
                    q_chunk=1024, kv_chunk=1024, unroll=False, base_pos=0):
    """Chunked flash attention. q,k,v: (B, S, H, D) / (B, Skv, H, D).

    ``unroll=True``: static loops + causal block skipping (exact FLOPs in
    HLO, used by dry-run cost bodies). ``unroll=False``: lax.scan over q
    blocks with an inner scan over kv blocks.
    """
    B, Sq, H, D = q.shape
    Skv = k.shape[1]
    scale = 1.0 / np.sqrt(D)
    Dv = v.shape[-1]
    def _fit(chunk, S):
        chunk = min(chunk, S)
        while S % chunk:
            chunk -= 1
        return chunk

    q_chunk = _fit(q_chunk, Sq)
    kv_chunk = _fit(kv_chunk, Skv)
    nq, nk = Sq // q_chunk, Skv // kv_chunk
    kv_off = Skv - Sq  # decode-style alignment: query i sits at kv pos kv_off+i
    # pre-blocked views (n_blocks, B, H, chunk, D): static indexing instead of
    # dynamic_slice — fuses cleanly and keeps HLO byte accounting honest.
    qb = q.reshape(B, nq, q_chunk, H, D).transpose(1, 0, 3, 2, 4)
    kb = k.reshape(B, nk, kv_chunk, H, D).transpose(1, 0, 3, 2, 4)
    vb = v.reshape(B, nk, kv_chunk, H, Dv).transpose(1, 0, 3, 2, 4)

    if unroll:
        outs = []
        for iq in range(nq):
            qpos = base_pos + kv_off + iq * q_chunk + jnp.arange(q_chunk)
            parts = []
            for ik in range(nk):
                k_start = ik * kv_chunk
                # static causal/window block skipping — flash FLOP parity
                if causal and k_start > kv_off + (iq + 1) * q_chunk - 1:
                    continue
                if window is not None and \
                        k_start + kv_chunk - 1 < kv_off + iq * q_chunk - window + 1:
                    continue
                kpos = base_pos + k_start + jnp.arange(kv_chunk)
                parts.append(_block_attn(qb[iq], kb[ik], vb[ik], qpos, kpos,
                                         scale, causal, window, softcap))
            stacked = tuple(jnp.stack(x) for x in zip(*parts))
            outs.append(_merge_blocks(stacked))
        o = jnp.stack(outs)  # (nq,B,H,qc,Dv)
    else:
        def q_step(_, inp):
            qi, iq = inp
            qpos = base_pos + kv_off + iq * q_chunk + jnp.arange(q_chunk)

            def kv_step(carry, kv_inp):
                o_acc, m_acc, s_acc = carry
                ki, vi, ik = kv_inp
                kpos = base_pos + ik * kv_chunk + jnp.arange(kv_chunk)
                o, m, s = _block_attn(qi, ki, vi, qpos, kpos, scale, causal,
                                      window, softcap)
                m_new = jnp.maximum(m_acc, m)
                sc_old = jnp.where(jnp.isfinite(m_acc), jnp.exp(m_acc - m_new), 0.0)
                sc_new = jnp.where(jnp.isfinite(m), jnp.exp(m - m_new), 0.0)
                return (o_acc * sc_old[..., None] + o * sc_new[..., None],
                        m_new, s_acc * sc_old + s * sc_new), None

            o0 = jnp.zeros(qi.shape[:-1] + (Dv,), jnp.float32)
            m0 = jnp.full(qi.shape[:-1], NEG_INF, jnp.float32)
            s0 = jnp.zeros(qi.shape[:-1], jnp.float32)
            (o, m, s), _ = jax.lax.scan(kv_step, (o0, m0, s0),
                                        (kb, vb, jnp.arange(nk)))
            return None, o / jnp.maximum(s, 1e-30)[..., None]

        _, o = jax.lax.scan(q_step, None, (qb, jnp.arange(nq)))  # (nq,B,H,qc,Dv)
    o = o.transpose(1, 0, 3, 2, 4).reshape(B, Sq, H, Dv)
    return o.astype(q.dtype)  # (B,S,H,Dv)


def plain_attention(q, k, v, *, causal=True, window=None, softcap=None, base_pos=0):
    """Reference full-matrix attention (tests / tiny shapes)."""
    B, Sq, H, D = q.shape
    Skv = k.shape[1]
    kv_off = Skv - Sq
    qpos = base_pos + kv_off + jnp.arange(Sq)
    kpos = base_pos + jnp.arange(Skv)
    o, m, s = _block_attn(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                          v.transpose(0, 2, 1, 3), qpos, kpos,
                          1.0 / np.sqrt(D), causal, window, softcap)
    o = o / jnp.maximum(s, 1e-30)[..., None]
    return o.transpose(0, 2, 1, 3).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA attention module
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class Attention:
    cfg: AttentionConfig

    def params_spec(self):
        c = self.cfg
        spec = {
            "wq": param((c.d_model, c.n_heads, c.head_dim),
                        ("embed", "heads", "head_dim"), init=fan_in_init((0,)),
                        dtype=c.dtype),
            "wk": param((c.d_model, c.n_kv_heads, c.head_dim),
                        ("embed", "kv_heads", "head_dim"), init=fan_in_init((0,)),
                        dtype=c.dtype),
            "wv": param((c.d_model, c.n_kv_heads, c.head_dim),
                        ("embed", "kv_heads", "head_dim"), init=fan_in_init((0,)),
                        dtype=c.dtype),
            "wo": param((c.n_heads, c.head_dim, c.d_model),
                        ("heads", "head_dim", "embed"), init=fan_in_init((0, 1)),
                        dtype=c.dtype),
        }
        if c.use_bias:
            spec["bq"] = param((c.n_heads, c.head_dim), ("heads", "head_dim"),
                               init=lambda k, s, d: jnp.zeros(s, d), dtype=c.dtype)
            spec["bk"] = param((c.n_kv_heads, c.head_dim), ("kv_heads", "head_dim"),
                               init=lambda k, s, d: jnp.zeros(s, d), dtype=c.dtype)
            spec["bv"] = param((c.n_kv_heads, c.head_dim), ("kv_heads", "head_dim"),
                               init=lambda k, s, d: jnp.zeros(s, d), dtype=c.dtype)
        if c.out_bias:
            spec["bo"] = param((c.d_model,), ("embed",),
                               init=lambda k, s, d: jnp.zeros(s, d), dtype=c.dtype)
        if c.qk_norm:
            spec["q_norm"] = RMSNorm(c.head_dim, axis_name="head_dim").params_spec()
            spec["k_norm"] = RMSNorm(c.head_dim, axis_name="head_dim").params_spec()
        return spec

    # -- shared projection helpers ---------------------------------------
    def _qkv(self, params, x, positions, ctx: ShardingCtx):
        c = self.cfg
        from ..parallel import summa  # lazy: nn stays import-light
        if summa.summa_axes(ctx) and summa.qkv_ok(c, ctx.mesh, x.shape):
            # 2D grid: SUMMA projections off the 2D-sharded residual; the
            # act_heads/act_kv constraints below gather seq off the grid
            # rows for the head-sharded attention core (Megatron-SP, with
            # the gather now priced by the oracle's seq-comm term).
            q, k, v = summa.attn_qkv(c, params, x, ctx)
        else:
            # Megatron-SP: gather the (smaller) residual stream over the
            # model axis once, then compute head-sharded projections locally.
            x = ctx.constrain(x, ("batch", None, "act_embed"))
            q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
            k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
            v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
        if c.use_bias:
            q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
        if c.qk_norm:
            qn = RMSNorm(c.head_dim, axis_name="head_dim")
            q = qn.apply(params["q_norm"], q)
            k = qn.apply(params["k_norm"], k)
        if c.rope:
            q = apply_rope(q, positions, c.rope_base)
            k = apply_rope(k, positions, c.rope_base)
        q = ctx.constrain(q, ("batch", None, "act_heads", None))
        k = ctx.constrain(k, ("batch", None, "act_kv", None))
        v = ctx.constrain(v, ("batch", None, "act_kv", None))
        return q, k, v

    def _out(self, params, o, ctx: ShardingCtx):
        from ..parallel import summa  # lazy: nn stays import-light
        if summa.summa_axes(ctx) and summa.out_ok(self.cfg, ctx.mesh, o.shape):
            y = summa.attn_out(self.cfg, params, o, ctx)
        else:
            y = jnp.einsum("bshk,hkd->bsd", o, params["wo"])
        if self.cfg.out_bias:
            y = y + params["bo"]
        return ctx.constrain(y, ("batch", "seq", "act_embed"))

    def _expand_kv(self, k):
        rep = self.cfg.n_heads // self.cfg.n_kv_heads
        return jnp.repeat(k, rep, axis=2) if rep > 1 else k

    # -- training / prefill forward ---------------------------------------
    def apply(self, params, x, ctx: ShardingCtx = NULL_CTX, positions=None,
              impl: str = "chunked", q_chunk: int = 1024, kv_chunk: int = 1024,
              unroll: bool = False):
        c = self.cfg
        B, S, _ = x.shape
        if positions is None:
            positions = jnp.arange(S)[None, :]
        q, k, v = self._qkv(params, x, positions, ctx)
        k, v = self._expand_kv(k), self._expand_kv(v)
        kwargs = dict(causal=c.causal, window=c.window, softcap=c.logit_softcap)
        if impl == "plain":
            o = plain_attention(q, k, v, **kwargs)
        else:
            o = flash_attention(q, k, v, q_chunk=q_chunk, kv_chunk=kv_chunk,
                                unroll=unroll, **kwargs)
        return self._out(params, o, ctx)

    # -- cross attention (enc-dec) ------------------------------------------
    def kv(self, params, enc_out, ctx: ShardingCtx = NULL_CTX):
        """Precompute cross-attention K/V from encoder output (no rope)."""
        c = self.cfg
        k = jnp.einsum("bsd,dhk->bshk", enc_out, params["wk"])
        v = jnp.einsum("bsd,dhk->bshk", enc_out, params["wv"])
        if c.use_bias:
            k, v = k + params["bk"], v + params["bv"]
        k = ctx.constrain(k, ("batch", None, "act_kv", None))
        v = ctx.constrain(v, ("batch", None, "act_kv", None))
        return k, v

    def apply_cross(self, params, x, k, v, ctx: ShardingCtx = NULL_CTX,
                    impl: str = "chunked", q_chunk: int = 1024,
                    kv_chunk: int = 1024, unroll: bool = False):
        """Cross-attention: queries from x, given K/V (non-causal, no rope)."""
        c = self.cfg
        x = ctx.constrain(x, ("batch", None, "act_embed"))
        q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
        if c.use_bias:
            q = q + params["bq"]
        q = ctx.constrain(q, ("batch", None, "act_heads", None))
        k, v = self._expand_kv(k), self._expand_kv(v)
        if impl == "plain" or q.shape[1] == 1:
            o = plain_attention(q, k, v, causal=False)
        else:
            o = flash_attention(q, k, v, causal=False, q_chunk=q_chunk,
                                kv_chunk=kv_chunk, unroll=unroll)
        return self._out(params, o, ctx)

    # -- KV cache -----------------------------------------------------------
    def cache_spec(self, batch: int, max_len: int, shards: int = 1, dtype=jnp.bfloat16):
        """Cache as ParamSpec tree: (B, shards, max_len/shards, KV, HD).

        shards=1 → classic per-head layout; shards=model-size → sequence-
        sharded flash-decoding layout (each chip holds a slice of *all* heads).
        """
        c = self.cfg
        if max_len % shards:
            raise ValueError("max_len must divide shards")
        shape = (batch, shards, max_len // shards, c.n_kv_heads, c.head_dim)
        axes = ("batch", "seq", None, "act_kv", None)
        return {
            "k": param(shape, axes, init=lambda k, s, d: jnp.zeros(s, d), dtype=dtype),
            "v": param(shape, axes, init=lambda k, s, d: jnp.zeros(s, d), dtype=dtype),
        }

    def decode(self, params, x, cache, pos, ctx: ShardingCtx = NULL_CTX):
        """Incremental step against the KV cache.

        x: (B, C, d_model) — C new tokens per sequence (C=1: classic decode;
        C>1: a chunked-prefill step, the serving engine's prefill phase).
        pos: scalar int32 OR (B,) int32 — the index of each sequence's first
        new token, so a continuous batch can hold sequences at different
        depths. Token j of row b lands at position pos[b]+j.

        Returns (y, new_cache). Window attention uses a ring-buffer write;
        with C > 1 a ring write may evict keys still inside an earlier
        chunk-token's window, so chunked callers must keep C=1 on windowed
        layers (the serving engine enforces this).
        """
        c = self.cfg
        B, C, _ = x.shape
        p0 = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1), (B,))
        positions = p0[:, None] + jnp.arange(C, dtype=jnp.int32)  # (B, C)
        q, k_new, v_new = self._qkv(params, x, positions, ctx)
        shards = cache["k"].shape[1]
        span = cache["k"].shape[2]
        total = shards * span
        write = positions % total if c.window is not None else positions

        # one-hot masked write instead of dynamic_update_slice: a traced
        # index into a sharded dim forces the SPMD partitioner to re-gather
        # the cache (§Perf iteration log); the mask is elementwise and keeps
        # the cache fully sharded. With C tokens the mask is (B,C,shards,span)
        # and the einsum places each token exactly once (positions within a
        # chunk are distinct mod total for C <= total).
        slot = jnp.arange(total, dtype=jnp.int32).reshape(shards, span)
        M = write[:, :, None, None] == slot[None, None]   # (B,C,shards,span)
        touched = M.any(axis=1)                           # (B,shards,span)

        def upd(buf, new):
            # new: (B, C, KV, D) → scatter to (B, shards, span, KV, D);
            # the one-hot product is exact (0/1 factors), so this matches
            # a direct masked write bit for bit.
            contrib = jnp.einsum("bcnk,bchd->bnkhd", M.astype(new.dtype), new)
            return jnp.where(touched[..., None, None],
                             contrib.astype(buf.dtype), buf)

        cache = {"k": upd(cache["k"], k_new), "v": upd(cache["v"], v_new)}

        # attend against every shard, LSE-merge (flash-decoding)
        rep = c.n_heads // c.n_kv_heads
        kc = cache["k"].astype(q.dtype)  # (B, shards, span, KV, D)
        vc = cache["v"].astype(q.dtype)
        if rep > 1:
            kc = jnp.repeat(kc, rep, axis=3)
            vc = jnp.repeat(vc, rep, axis=3)
        scale = 1.0 / np.sqrt(c.head_dim)
        qh = q.transpose(0, 2, 1, 3)  # (B,H,C,D)

        # token index currently held by each cache slot (ring-aware when
        # windowed: relative to the LAST token written, which owns the ring)
        if c.window is not None:
            p_last = positions[:, -1][:, None, None]       # (B,1,1)
            kpos = p_last - ((p_last - slot[None]) % total)  # (B,shards,span)
        else:
            kpos = jnp.broadcast_to(slot[None], (B, shards, span))
        qpos = positions[:, :, None, None]                 # (B,C,1,1)
        valid = (kpos[:, None] <= qpos) & (kpos[:, None] >= 0)
        if c.window is not None:
            valid &= kpos[:, None] > qpos - c.window       # (B,C,shards,span)

        s = jnp.einsum("bhcd,bnkhd->bhcnk", qh, kc).astype(jnp.float32) * scale
        s = _softcap(s, c.logit_softcap)
        s = jnp.where(valid[:, None], s, NEG_INF)
        m = jnp.max(s, axis=(-2, -1), keepdims=True)
        p = jnp.exp(s - m)
        p = jnp.where(jnp.isfinite(m), p, 0.0)
        o = jnp.einsum("bhcnk,bnkhd->bhcd", p.astype(q.dtype), vc).astype(jnp.float32)
        o = o / jnp.maximum(jnp.sum(p, axis=(-2, -1)), 1e-30)[..., None]
        o = o.transpose(0, 2, 1, 3).astype(q.dtype)  # (B,C,H,D)
        return self._out(params, o, ctx), cache


# ---------------------------------------------------------------------------
# MLA — Multi-head Latent Attention (DeepSeek-V3)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class MLAConfig:
    d_model: int
    n_heads: int
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    rope_base: float = 10000.0
    dtype: Any = None

    @property
    def qk_head_dim(self):
        return self.qk_nope_dim + self.qk_rope_dim


@dataclass(frozen=True)
class MLAttention:
    """DeepSeek-V3 MLA: low-rank compressed Q and KV with decoupled RoPE keys.

    Decode cache = per-token latent c_kv (kv_lora_rank) + rope key — the
    memory win the paper's "Redundancy in Memory" section anticipates (§5.3.2:
    split weights AND activations; MLA compresses the activation cache).
    """

    cfg: MLAConfig

    def params_spec(self):
        c = self.cfg
        fi = fan_in_init((0,))
        return {
            "wq_a": param((c.d_model, c.q_lora_rank), ("embed", "qk_rank"), init=fi,
                          dtype=c.dtype),
            "q_norm": RMSNorm(c.q_lora_rank, axis_name="qk_rank").params_spec(),
            "wq_b": param((c.q_lora_rank, c.n_heads, c.qk_head_dim),
                          ("qk_rank", "heads", "head_dim"), init=fi, dtype=c.dtype),
            "wkv_a": param((c.d_model, c.kv_lora_rank + c.qk_rope_dim),
                           ("embed", "kv_rank"), init=fi, dtype=c.dtype),
            "kv_norm": RMSNorm(c.kv_lora_rank, axis_name="kv_rank").params_spec(),
            "wkv_b": param((c.kv_lora_rank, c.n_heads, c.qk_nope_dim + c.v_head_dim),
                           ("kv_rank", "heads", "head_dim"), init=fi, dtype=c.dtype),
            "wo": param((c.n_heads, c.v_head_dim, c.d_model),
                        ("heads", "head_dim", "embed"), init=fan_in_init((0, 1)),
                        dtype=c.dtype),
        }

    def _project(self, params, x, positions, ctx: ShardingCtx = NULL_CTX):
        c = self.cfg
        x = ctx.constrain(x, ("batch", None, "act_embed"))
        q = jnp.einsum("bsd,dr->bsr", x, params["wq_a"])
        q = RMSNorm(c.q_lora_rank, axis_name="qk_rank").apply(params["q_norm"], q)
        q = jnp.einsum("bsr,rhk->bshk", q, params["wq_b"])
        q_nope, q_rope = q[..., :c.qk_nope_dim], q[..., c.qk_nope_dim:]
        q_rope = apply_rope(q_rope, positions, c.rope_base)
        kv = jnp.einsum("bsd,dr->bsr", x, params["wkv_a"])
        c_kv, k_rope = kv[..., :c.kv_lora_rank], kv[..., c.kv_lora_rank:]
        c_kv = RMSNorm(c.kv_lora_rank, axis_name="kv_rank").apply(params["kv_norm"], c_kv)
        k_rope = apply_rope(k_rope[:, :, None, :], positions, c.rope_base)  # 1 shared head
        return q_nope, q_rope, c_kv, k_rope[:, :, 0, :]

    def apply(self, params, x, ctx: ShardingCtx = NULL_CTX, positions=None,
              impl: str = "chunked", q_chunk: int = 1024, kv_chunk: int = 1024,
              unroll: bool = False):
        c = self.cfg
        B, S, _ = x.shape
        if positions is None:
            positions = jnp.arange(S)[None, :]
        q_nope, q_rope, c_kv, k_rope = self._project(params, x, positions, ctx)
        kv = jnp.einsum("bsr,rhk->bshk", c_kv, params["wkv_b"])
        k_nope, v = kv[..., :c.qk_nope_dim], kv[..., c.qk_nope_dim:]
        k_rope_h = jnp.broadcast_to(k_rope[:, :, None, :],
                                    (B, S, c.n_heads, c.qk_rope_dim))
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        k = jnp.concatenate([k_nope, k_rope_h], axis=-1)
        q = ctx.constrain(q, ("batch", None, "act_heads", None))
        k = ctx.constrain(k, ("batch", None, "act_heads", None))
        v = ctx.constrain(v, ("batch", None, "act_heads", None))
        if impl == "plain":
            o = plain_attention(q, k, v, causal=True)
        else:
            # pad v head dim up to qk dim not needed: flash handles D mismatch
            o = flash_attention(q, k, v, causal=True, q_chunk=q_chunk,
                                kv_chunk=kv_chunk, unroll=unroll)
        y = jnp.einsum("bshk,hkd->bsd", o, params["wo"])
        return ctx.constrain(y, ("batch", "seq", "act_embed"))

    def cache_spec(self, batch: int, max_len: int, shards: int = 1,
                   dtype=jnp.bfloat16):
        c = self.cfg
        return {
            "c_kv": param((batch, max_len, c.kv_lora_rank),
                          ("batch", "seq", None),
                          init=lambda k, s, d: jnp.zeros(s, d), dtype=dtype),
            "k_rope": param((batch, max_len, c.qk_rope_dim),
                            ("batch", "seq", None),
                            init=lambda k, s, d: jnp.zeros(s, d), dtype=dtype),
        }

    def decode(self, params, x, cache, pos, ctx: ShardingCtx = NULL_CTX):
        """Latent-cache decode: attend in the compressed space (absorbed form)."""
        c = self.cfg
        B = x.shape[0]
        positions = jnp.full((B, 1), pos, jnp.int32)
        q_nope, q_rope, c_kv_new, k_rope_new = self._project(params, x, positions, ctx)
        cache = {
            "c_kv": jax.lax.dynamic_update_slice(
                cache["c_kv"], c_kv_new.astype(cache["c_kv"].dtype), (0, pos, 0)),
            "k_rope": jax.lax.dynamic_update_slice(
                cache["k_rope"], k_rope_new.astype(cache["k_rope"].dtype), (0, pos, 0)),
        }
        c_all = cache["c_kv"].astype(x.dtype)      # (B, T, R)
        kr_all = cache["k_rope"].astype(x.dtype)   # (B, T, rope)
        w_k = params["wkv_b"][..., :c.qk_nope_dim]   # (R, H, nope)
        w_v = params["wkv_b"][..., c.qk_nope_dim:]   # (R, H, v)
        # absorb: q_nope^T k_nope = (q_nope^T w_k) c_kv
        q_abs = jnp.einsum("bshk,rhk->bshr", q_nope, w_k)  # (B,1,H,R)
        s = jnp.einsum("bshr,btr->bhst", q_abs, c_all)
        s = s + jnp.einsum("bshk,btk->bhst", q_rope, kr_all)
        s = s.astype(jnp.float32) / np.sqrt(c.qk_head_dim)
        valid = jnp.arange(c_all.shape[1]) <= pos
        s = jnp.where(valid[None, None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1).astype(x.dtype)
        o_lat = jnp.einsum("bhst,btr->bshr", p, c_all)        # (B,1,H,R)
        o = jnp.einsum("bshr,rhk->bshk", o_lat, w_v)          # (B,1,H,v)
        y = jnp.einsum("bshk,hkd->bsd", o, params["wo"])
        return ctx.constrain(y, ("batch", "seq", "act_embed")), cache
