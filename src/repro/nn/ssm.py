"""Mamba-2 (SSD — state-space duality) block.

Chunked SSD algorithm [arXiv:2405.21060]: within a chunk the output is a
masked (decay-weighted) attention-like quadratic form; across chunks a linear
recurrence on the (heads, head_dim, state) tensor, evaluated with
``lax.associative_scan``. The recurrence runs along *seq*, which is why
sequence (paper: spatial) parallelism is inapplicable to this family
(DESIGN.md §Arch-applicability); heads/d_inner shard like paper filters.

The input projection is kept as separate z/x/B/C/dt matrices (mathematically
identical to the fused in_proj of the reference implementation) so that
filter-parallelism shards d_inner cleanly without slicing across shard
boundaries of a fused output dim.

Decode keeps O(1) state: (B, H, P, N) SSM state + (B, d_conv-1, ·) conv
tails — the reason ``long_500k`` is feasible for this arch.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from .layers import RMSNorm
from .module import NULL_CTX, ShardingCtx, fan_in_init, param


@dataclass(frozen=True)
class SSMConfig:
    d_model: int
    d_state: int = 128
    head_dim: int = 64          # P
    expand: int = 2
    d_conv: int = 4
    n_groups: int = 1
    chunk: int = 256
    dt_min: float = 0.001
    dt_max: float = 0.1
    dtype: Any = None

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.head_dim

    @property
    def bc_dim(self) -> int:
        return self.n_groups * self.d_state


@dataclass(frozen=True)
class SSDBlock:
    cfg: SSMConfig

    def params_spec(self):
        c = self.cfg
        fi = fan_in_init((0,))
        z = lambda k, s, d: jnp.zeros(s, d)

        def dt_bias_init(key, shape, dtype):
            u = jax.random.uniform(key, shape, jnp.float32)
            dt = jnp.exp(u * (np.log(c.dt_max) - np.log(c.dt_min)) + np.log(c.dt_min))
            return jnp.log(jnp.expm1(dt)).astype(dtype)  # inverse softplus

        def a_log_init(key, shape, dtype):
            return jnp.log(jnp.arange(1, shape[0] + 1, dtype=jnp.float32)).astype(dtype)

        return {
            "w_z": param((c.d_model, c.d_inner), ("embed", "mlp"), init=fi,
                         dtype=c.dtype),
            "w_x": param((c.d_model, c.d_inner), ("embed", "mlp"), init=fi,
                         dtype=c.dtype),
            "w_B": param((c.d_model, c.bc_dim), ("embed", "state"), init=fi,
                         dtype=c.dtype),
            "w_C": param((c.d_model, c.bc_dim), ("embed", "state"), init=fi,
                         dtype=c.dtype),
            "w_dt": param((c.d_model, c.n_heads), ("embed", "heads"), init=fi,
                          dtype=c.dtype),
            "conv_x": param((c.d_conv, c.d_inner), ("conv_k", "mlp"),
                            init=fan_in_init((0,)), dtype=c.dtype),
            "conv_B": param((c.d_conv, c.bc_dim), ("conv_k", "state"),
                            init=fan_in_init((0,)), dtype=c.dtype),
            "conv_C": param((c.d_conv, c.bc_dim), ("conv_k", "state"),
                            init=fan_in_init((0,)), dtype=c.dtype),
            "conv_b_x": param((c.d_inner,), ("mlp",), init=z, dtype=c.dtype),
            "conv_b_B": param((c.bc_dim,), ("state",), init=z, dtype=c.dtype),
            "conv_b_C": param((c.bc_dim,), ("state",), init=z, dtype=c.dtype),
            "dt_bias": param((c.n_heads,), ("heads",), init=dt_bias_init,
                             dtype=jnp.float32),
            "a_log": param((c.n_heads,), ("heads",), init=a_log_init,
                           dtype=jnp.float32),
            "d_skip": param((c.n_heads,), ("heads",),
                            init=lambda k, s, d: jnp.ones(s, d), dtype=jnp.float32),
            "norm": RMSNorm(c.d_inner, axis_name="mlp").params_spec(),
            "out_proj": param((c.d_inner, c.d_model), ("mlp", "embed"), init=fi,
                              dtype=c.dtype),
        }

    # ------------------------------------------------------------------
    @staticmethod
    def _causal_conv(x, w, b, act=True):
        """Depthwise causal conv along seq. x: (B, S, C); w: (K, C)."""
        K = w.shape[0]
        pad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
        out = sum(pad[:, i:i + x.shape[1], :] * w[i] for i in range(K))
        out = out + b
        return jax.nn.silu(out) if act else out

    def _ssd(self, x, dt, A, Bm, Cm, init_state=None):
        """Chunked SSD. x:(B,S,H,P) dt:(B,S,H) A:(H,) Bm/Cm:(B,S,G,N).

        Returns (y (B,S,H,P), final_state (B,H,P,N)).
        """
        c = self.cfg
        B_, S, H, P = x.shape
        G, N = Bm.shape[2], Bm.shape[3]
        Q = min(c.chunk, S)
        if S % Q:
            raise ValueError(f"seq {S} must divide chunk {Q}")
        nC = S // Q
        rep = H // G
        xc = x.reshape(B_, nC, Q, H, P)
        dtc = dt.reshape(B_, nC, Q, H)
        Bc = jnp.repeat(Bm.reshape(B_, nC, Q, G, N), rep, axis=3)
        Cc = jnp.repeat(Cm.reshape(B_, nC, Q, G, N), rep, axis=3)
        dA = dtc * A                      # (B,nC,Q,H) log-decay (A negative)
        cum = jnp.cumsum(dA, axis=2)

        # intra-chunk (quadratic, attention-like)
        diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]
        causal = jnp.tril(jnp.ones((Q, Q), bool))
        Lmask = jnp.where(causal[None, None, :, :, None], jnp.exp(diff), 0.0)
        scores = jnp.einsum("bcihn,bcjhn->bcijh", Cc, Bc)
        y_intra = jnp.einsum("bcijh,bcjh,bcijh,bcjhp->bcihp",
                             scores, dtc, Lmask, xc)

        # chunk states
        decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)
        states = jnp.einsum("bcjh,bcjh,bcjhn,bcjhp->bchpn",
                            decay_to_end, dtc, Bc, xc)

        # inter-chunk recurrence (associative scan over chunk axis)
        chunk_decay = jnp.exp(cum[:, :, -1, :])
        dec = jnp.moveaxis(chunk_decay, 1, 0)
        st = jnp.moveaxis(states, 1, 0)

        def assoc(a, b):
            da, sa = a
            db, sb = b
            return da * db, sb + sa * db[..., None, None]

        dec_c, st_c = jax.lax.associative_scan(assoc, (dec, st), axis=0)
        if init_state is not None:
            st_c = st_c + dec_c[..., None, None] * init_state[None]
        prev = jnp.concatenate([
            (init_state[None] if init_state is not None
             else jnp.zeros_like(st_c[:1])), st_c[:-1]], axis=0)
        prev = jnp.moveaxis(prev, 0, 1)

        in_decay = jnp.exp(cum)
        y_inter = jnp.einsum("bcjh,bcjhn,bchpn->bcjhp", in_decay, Cc, prev)
        y = (y_intra + y_inter).reshape(B_, S, H, P)
        final = jnp.moveaxis(st_c, 0, 1)[:, -1]
        return y, final

    # ------------------------------------------------------------------
    def _project(self, params, u, ctx):
        c = self.cfg
        u = ctx.constrain(u, ("batch", None, "act_embed"))
        z = u @ params["w_z"]
        x = u @ params["w_x"]
        Bm = u @ params["w_B"]
        Cm = u @ params["w_C"]
        dt = u @ params["w_dt"]
        z = ctx.constrain(z, ("batch", None, "act_mlp"))
        x = ctx.constrain(x, ("batch", None, "act_mlp"))
        return z, x, Bm, Cm, dt

    def apply(self, params, u, ctx: ShardingCtx = NULL_CTX):
        """u: (B, S, d_model) → (B, S, d_model)."""
        c = self.cfg
        B_, S, _ = u.shape
        z, x, Bm, Cm, dt = self._project(params, u, ctx)
        x = self._causal_conv(x, params["conv_x"], params["conv_b_x"])
        Bm = self._causal_conv(Bm, params["conv_B"], params["conv_b_B"])
        Cm = self._causal_conv(Cm, params["conv_C"], params["conv_b_C"])
        x = x.reshape(B_, S, c.n_heads, c.head_dim)
        x = ctx.constrain(x, ("batch", None, "act_heads", None))
        Bm = Bm.reshape(B_, S, c.n_groups, c.d_state)
        Cm = Cm.reshape(B_, S, c.n_groups, c.d_state)
        dtf = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
        A = -jnp.exp(params["a_log"])
        y, _ = self._ssd(x.astype(jnp.float32), dtf, A, Bm.astype(jnp.float32),
                         Cm.astype(jnp.float32))
        y = y + x.astype(jnp.float32) * params["d_skip"][None, None, :, None]
        y = y.reshape(B_, S, c.d_inner).astype(u.dtype)
        y = y * jax.nn.silu(z)
        y = RMSNorm(c.d_inner, axis_name="mlp").apply(params["norm"], y)
        y = y @ params["out_proj"]
        return ctx.constrain(y, ("batch", "seq", "act_embed"))

    # ------------------------------------------------------------------
    def cache_spec(self, batch: int, dtype=jnp.float32):
        c = self.cfg
        z = lambda k, s, d: jnp.zeros(s, d)
        return {
            "state": param((batch, c.n_heads, c.head_dim, c.d_state),
                           ("batch", "act_heads", None, "state"), init=z,
                           dtype=dtype),
            "conv_x": param((batch, c.d_conv - 1, c.d_inner),
                            ("batch", None, "act_mlp"), init=z, dtype=dtype),
            "conv_B": param((batch, c.d_conv - 1, c.bc_dim),
                            ("batch", None, None), init=z, dtype=dtype),
            "conv_C": param((batch, c.d_conv - 1, c.bc_dim),
                            ("batch", None, None), init=z, dtype=dtype),
        }

    @staticmethod
    def _conv_step(buf, new, w, b, act=True):
        """One-token depthwise conv using the (K-1)-tail buffer."""
        full = jnp.concatenate([buf, new[:, None].astype(buf.dtype)], axis=1)
        out = jnp.einsum("bkc,kc->bc", full.astype(new.dtype), w) + b
        out = jax.nn.silu(out) if act else out
        return out, full[:, 1:]

    def decode(self, params, u, cache, pos, ctx: ShardingCtx = NULL_CTX):
        """Single-token recurrent step. u: (B, 1, d_model)."""
        c = self.cfg
        B_ = u.shape[0]
        z, x, Bm, Cm, dt = self._project(params, u, ctx)
        x, conv_x = self._conv_step(cache["conv_x"], x[:, 0], params["conv_x"],
                                    params["conv_b_x"])
        Bm, conv_B = self._conv_step(cache["conv_B"], Bm[:, 0], params["conv_B"],
                                     params["conv_b_B"])
        Cm, conv_C = self._conv_step(cache["conv_C"], Cm[:, 0], params["conv_C"],
                                     params["conv_b_C"])
        x = x.reshape(B_, c.n_heads, c.head_dim).astype(jnp.float32)
        Bm = Bm.reshape(B_, c.n_groups, c.d_state).astype(jnp.float32)
        Cm = Cm.reshape(B_, c.n_groups, c.d_state).astype(jnp.float32)
        rep = c.n_heads // c.n_groups
        Bh = jnp.repeat(Bm, rep, axis=1)
        Ch = jnp.repeat(Cm, rep, axis=1)
        dt1 = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + params["dt_bias"])
        A = -jnp.exp(params["a_log"])
        dA = jnp.exp(dt1 * A)
        state = cache["state"] * dA[:, :, None, None] + \
            jnp.einsum("bh,bhn,bhp->bhpn", dt1, Bh, x)
        y = jnp.einsum("bhn,bhpn->bhp", Ch, state)
        y = y + x * params["d_skip"][None, :, None]
        y = y.reshape(B_, 1, c.d_inner).astype(u.dtype)
        y = y * jax.nn.silu(z)
        y = RMSNorm(c.d_inner, axis_name="mlp").apply(params["norm"], y)
        y = y @ params["out_proj"]
        new_cache = {"state": state.astype(cache["state"].dtype),
                     "conv_x": conv_x, "conv_B": conv_B, "conv_C": conv_C}
        return ctx.constrain(y, ("batch", "seq", "act_embed")), new_cache
