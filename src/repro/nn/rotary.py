"""Rotary position embeddings (RoPE), partial-dim capable (MLA-style)."""
from __future__ import annotations

import jax.numpy as jnp


def rope_frequencies(dim: int, base: float = 10000.0) -> jnp.ndarray:
    """Inverse frequencies for a (possibly partial) rotary dim."""
    if dim % 2:
        raise ValueError(f"rotary dim must be even, got {dim}")
    return 1.0 / (base ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, base: float = 10000.0,
               rot_dim: int | None = None) -> jnp.ndarray:
    """Rotate the first ``rot_dim`` features of ``x``.

    x: (..., seq, heads, head_dim); positions: broadcastable to (..., seq).
    Uses the split-half convention (first half/second half pairs), matching
    Llama/Qwen reference implementations.
    """
    head_dim = x.shape[-1]
    rot = rot_dim or head_dim
    inv_freq = rope_frequencies(rot, base)
    angles = positions[..., None].astype(jnp.float32) * inv_freq  # (..., seq, rot/2)
    angles = angles[..., None, :]  # broadcast over heads
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    x1, x2 = jnp.split(x_rot.astype(jnp.float32), 2, axis=-1)
    rotated = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return jnp.concatenate([rotated.astype(x.dtype), x_pass], axis=-1)
