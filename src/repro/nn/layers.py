"""Core layers: Dense, Embedding, norms, convolutions (1/2/3-D), pooling.

Every layer follows the module.py contract:
  * ``params_spec()`` — declarative ParamSpec tree with logical axes,
  * ``apply(params, x, ctx)`` — pure function; ``ctx: ShardingCtx`` carries the
    mesh + parallel-strategy rules for activation sharding constraints.

Convolutions use ``jax.lax.conv_general_dilated`` with channels-last layout
(TPU-native). The CNN stack (ResNet/VGG/CosmoFlow) builds on these and is what
the paper's six parallel strategies were originally defined over.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .module import (NULL_CTX, ShardingCtx, fan_in_init, ones_init, param, zeros_init)


# ---------------------------------------------------------------------------
# Dense
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class Dense:
    """y = x @ w (+ b). Logical axes configurable for column/row parallel."""

    in_dim: int
    out_dim: int
    use_bias: bool = False
    in_axis: str | None = "embed"
    out_axis: str | None = "mlp"
    dtype: Any = None

    def params_spec(self):
        spec = {
            "w": param((self.in_dim, self.out_dim), (self.in_axis, self.out_axis),
                       init=fan_in_init((0,)), dtype=self.dtype)
        }
        if self.use_bias:
            spec["b"] = param((self.out_dim,), (self.out_axis,), init=zeros_init(),
                              dtype=self.dtype)
        return spec

    def apply(self, params, x, ctx: ShardingCtx = NULL_CTX):
        y = x @ params["w"]
        if self.use_bias:
            y = y + params["b"]
        return y


# ---------------------------------------------------------------------------
# Embedding
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class Embedding:
    vocab_size: int
    features: int
    dtype: Any = None

    def params_spec(self):
        return {"table": param((self.vocab_size, self.features), ("vocab", "embed"),
                               init=fan_in_init((1,)), dtype=self.dtype)}

    def apply(self, params, ids, ctx: ShardingCtx = NULL_CTX):
        return jnp.take(params["table"], ids, axis=0)

    def attend(self, params, x):
        """Tied-weight logits: x @ table.T"""
        return x @ params["table"].T


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class RMSNorm:
    dim: int
    eps: float = 1e-6
    axis_name: str | None = "embed"

    def params_spec(self):
        return {"scale": param((self.dim,), (self.axis_name,), init=ones_init())}

    def apply(self, params, x, ctx: ShardingCtx = NULL_CTX):
        dtype = x.dtype
        xf = x.astype(jnp.float32)
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + self.eps)
        return (y * params["scale"].astype(jnp.float32)).astype(dtype)


@dataclass(frozen=True)
class LayerNorm:
    dim: int
    eps: float = 1e-5
    use_bias: bool = True
    axis_name: str | None = "embed"

    def params_spec(self):
        spec = {"scale": param((self.dim,), (self.axis_name,), init=ones_init())}
        if self.use_bias:
            spec["bias"] = param((self.dim,), (self.axis_name,), init=zeros_init())
        return spec

    def apply(self, params, x, ctx: ShardingCtx = NULL_CTX):
        dtype = x.dtype
        xf = x.astype(jnp.float32)
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + self.eps)
        y = y * params["scale"].astype(jnp.float32)
        if self.use_bias:
            y = y + params["bias"].astype(jnp.float32)
        return y.astype(dtype)


@dataclass(frozen=True)
class BatchNorm:
    """Inference-style BN (running stats folded) + train-mode batch stats.

    Paper §4.5.2: under data parallelism BN is local (unsynchronized) by
    default; under filter/channel parallelism each PE recomputes BN
    redundantly after the Allgather (no communication); under spatial
    parallelism BN is computed on the local spatial shard. ``sync`` enables
    cross-device mean/var via psum when a mesh axis name is given (used for
    tiny local batches, cf. [55] in the paper).
    """

    dim: int
    eps: float = 1e-5
    momentum: float = 0.9
    sync_axis: str | None = None  # physical mesh axis for sync-BN

    def params_spec(self):
        return {
            "scale": param((self.dim,), ("conv_out",), init=ones_init()),
            "bias": param((self.dim,), ("conv_out",), init=zeros_init()),
        }

    def apply(self, params, x, ctx: ShardingCtx = NULL_CTX, train: bool = True):
        dtype = x.dtype
        xf = x.astype(jnp.float32)
        axes = tuple(range(x.ndim - 1))
        mu = jnp.mean(xf, axis=axes)
        var = jnp.mean(xf * xf, axis=axes) - mu * mu
        if self.sync_axis is not None:
            mu = jax.lax.pmean(mu, self.sync_axis)
            var = jax.lax.pmean(var, self.sync_axis)
        y = (xf - mu) * jax.lax.rsqrt(var + self.eps)
        y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
        return y.astype(dtype)


# ---------------------------------------------------------------------------
# Convolutions (channels-last, any spatial rank 1..3)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class Conv:
    """N-D convolution, channels-last: x[N, *spatial, C] -> y[N, *spatial', F].

    The paper's notation: weight w[C, F, K^d]; here stored as [*K^d, C, F]
    (HWIO — TPU native).
    """

    in_channels: int
    out_channels: int
    kernel: tuple[int, ...]
    strides: tuple[int, ...] | None = None
    padding: str | Sequence[tuple[int, int]] = "SAME"
    use_bias: bool = True
    feature_group_count: int = 1
    dtype: Any = None

    def params_spec(self):
        k = tuple(self.kernel)
        spec = {
            "w": param(k + (self.in_channels // self.feature_group_count,
                            self.out_channels),
                       tuple(["conv_k"] + [None] * (len(k) - 1)) + ("conv_in", "conv_out"),
                       init=fan_in_init(tuple(range(len(k) + 1))), dtype=self.dtype)
        }
        if self.use_bias:
            spec["b"] = param((self.out_channels,), ("conv_out",), init=zeros_init(),
                              dtype=self.dtype)
        return spec

    def apply(self, params, x, ctx: ShardingCtx = NULL_CTX):
        nd = len(self.kernel)
        strides = self.strides or (1,) * nd
        spatial = "DHW"[-nd:]
        dn = jax.lax.conv_dimension_numbers(
            x.shape, params["w"].shape,
            (f"N{spatial}C", f"{spatial}IO", f"N{spatial}C"))
        y = jax.lax.conv_general_dilated(
            x, params["w"], window_strides=strides, padding=self.padding,
            dimension_numbers=dn, feature_group_count=self.feature_group_count)
        if self.use_bias:
            y = y + params["b"]
        return y


def max_pool(x, window: tuple[int, ...], strides: tuple[int, ...] | None = None,
             padding: str = "SAME"):
    nd = len(window)
    strides = strides or window
    dims = (1,) + window + (1,)
    strd = (1,) + strides + (1,)
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, dims, strd, padding)


def avg_pool(x, window: tuple[int, ...], strides: tuple[int, ...] | None = None,
             padding: str = "VALID"):
    nd = len(window)
    strides = strides or window
    dims = (1,) + window + (1,)
    strd = (1,) + strides + (1,)
    summed = jax.lax.reduce_window(x.astype(jnp.float32), 0.0, jax.lax.add, dims,
                                   strd, padding)
    return (summed / float(np.prod(window))).astype(x.dtype)


def global_avg_pool(x):
    axes = tuple(range(1, x.ndim - 1))
    return jnp.mean(x, axis=axes)
