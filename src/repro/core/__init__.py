"""ParaDL core — the paper's primary contribution in JAX.

oracle.py (Table-3 projections), sweep.py (vectorized strategy × scale
lattice engine), advisor.py (strategy selection), hardware.py (α–β system
models), cluster.py (ClusterSpec: the first-class machine description —
levels + topology + fitted φ/σ, DESIGN.md §11), layer_stats.py (Table-2
tensor stats), calibration.py (§4.4 empirical parametrization),
validation.py (Fig-3 accuracy harness), hlo_analysis.py + roofline.py
(dry-run cost extraction — beyond-paper, TPU-native). The session facade
over all of it lives one level up in ``repro.api``.
"""
from .cluster import (ClusterSpec, Measurement, Torus, add_cluster_args,
                      parse_phi_table, parse_sigma_table)
from .hardware import (Level, PAPER_V100_CLUSTER, SystemModel, TPU_V5E_POD,
                       cpu_host_model)
from .layer_stats import LayerStat, stats_for
from .oracle import (OracleConfig, Projection, STRATEGY_NAMES, StatTable,
                     TimeModel, precompute, project, project_all)
from .sweep import (SweepResult, all_switch_combos, factor_pairs,
                    parse_p_grid, sweep)
from .advisor import Recommendation, advise, breakdown_table
from .autotune import TunedPlan, autotune, plan_for_arch
from .roofline import V5E, HardwareSpec, Roofline, roofline
from .hlo_analysis import CellCost, Collective, combine, cost_of, parse_collectives
