"""Oracle-in-the-loop auto-tuner (DESIGN.md §8).

The sweep engine (sweep/) computes the full strategy × p1·p2 × memory-switch
lattice; this module turns that into a *deployment decision*: given an
arch × shape × device count, pick the cheapest point that fits memory and
return it as a ``TunedPlan`` — strategy, mesh factorization, memory-model
switches, and the projected bottleneck. ``launch/build.py:build_cell`` (and
the train / serve / dryrun entry points) accept ``strategy="auto"`` and
consume the plan, so the oracle is the decision-maker, not just a report.

Ranking (cheapest-that-fits):
  1. drop points that violate a scaling limit or the per-PE memory cap;
  2. minimize projected step time;
  3. on ties (within ``rtol``): prefer the config's fallback strategy if it
     is among the tied winners, then the fewest memory switches on (each
     switch has unmodeled runtime overhead), then the narrowest model
     width p2, then name order — fully deterministic.
If nothing fits, the fallback strategy's least-memory point is returned
with ``feasible=False`` so callers can still proceed (and warn).

CLI — "what should I run on p GPUs?":

    PYTHONPATH=src python -m repro.core.autotune --model resnet50 --p 64
    PYTHONPATH=src python -m repro.core.autotune --model cosmoflow \
        --p 8,64,1024 --batch-per-pe 0.25
    PYTHONPATH=src python -m repro.core.autotune --smoke
"""
from __future__ import annotations

import argparse
from dataclasses import dataclass

import numpy as np

from ..cluster import ClusterSpec, add_cluster_args
from ..hardware import TPU_V5E_POD
from ..oracle import OracleConfig, TimeModel
from ..sweep import (HYBRID_STRATEGIES, SweepResult, parse_p_grid,
                     switch_label, sweep)

# oracle strategies with an executable deployment path: a rules table in
# parallel/strategies.py, plus the stage schedules (gpipe / 1F1B /
# interleaved) for "pipeline" (parallel/schedules.make_pipeline_train_step;
# models the stage compiler cannot cut are filtered per-arch via
# ``allow_pipeline``).
DEPLOYABLE_STRATEGIES = ("serial", "data", "spatial", "filter", "channel",
                         "df", "ds", "ep", "summa", "pipeline")

# tie-break preference between equal-time strategies: fewest moving parts
# first (no collectives < gradient exchange only < hybrids < layer-wise
# collectives < expert all-to-alls < 2D grids < stage schedules)
_PREF = {s: i for i, s in enumerate(
    ("serial", "data", "ds", "df", "spatial", "filter", "channel", "ep",
     "summa", "pipeline"))}

# executable rules-table name → oracle strategy (for fallback tie-breaks on
# arch configs, whose ``strategy`` fields name rules tables)
ORACLE_OF_EXEC = {
    "data": "data", "spatial": "spatial", "filter": "filter",
    "channel": "channel", "df": "df", "df_zero1": "df", "df_zero3": "df",
    "ds": "ds", "ep_df": "ep", "serve_tp": "df", "serve_seqkv": "ds",
    "pipeline": "pipeline", "summa": "summa",
}


@dataclass(frozen=True)
class TunedPlan:
    """One deployment decision: what to run on p PEs and how."""

    strategy: str            # oracle strategy name (STRATEGY_NAMES)
    p: int
    p1: int                  # data-parallel groups
    p2: int                  # model-parallel width
    remat: bool
    zero1: bool
    zero3: bool
    seq_parallel: bool
    bottleneck: str          # sweep classification at the chosen point
    total_s: float           # projected per-epoch seconds
    iterations: float
    mem_bytes: float
    mem_cap: float | None
    feasible: bool           # False → fallback plan, nothing fit
    source: str              # "sweep" | "fallback"
    segments: int = 8        # microbatch count the projection assumed
                             # (pipeline plans; deploy must run the same S)
    schedule: str = "gpipe"  # pipeline schedule the projection priced
                             # (PIPELINE_SCHEDULES; deploy must run it)
    virtual_stages: int = 2  # v for interleaved plans (chunks per rank)
    p2r: int = 1             # model-grid rows (summa plans: p2 = p2r·p2c)
    p2c: int = 1             # model-grid cols
    kernel_tiles: object = None  # kernels.autotune.KernelTiles — tuned Pallas
                             # block sizes riding with the plan so deploy uses
                             # the blocks the tuner measured (None = kernel
                             # defaults; KernelTiles is frozen/hashable so the
                             # plan stays hashable)

    @property
    def switches(self) -> dict:
        return {"remat": self.remat, "zero1": self.zero1,
                "zero3": self.zero3, "seq_parallel": self.seq_parallel}

    @property
    def mesh_shape(self) -> tuple[int, int]:
        """(data, model) mesh factorization to deploy."""
        return (self.p1, self.p2)

    def mesh_spec(self) -> tuple[tuple[int, ...], tuple[str, ...]]:
        """(shape, axis names) of the mesh this plan deploys on — summa
        plans need the factored (data, model_r, model_c) grid mesh."""
        if self.strategy == "summa":
            return ((self.p1, self.p2r, self.p2c),
                    ("data", "model_r", "model_c"))
        return ((self.p1, self.p2), ("data", "model"))

    @property
    def per_iter_s(self) -> float:
        return self.total_s / max(self.iterations, 1.0)

    @property
    def n_switches_on(self) -> int:
        return sum(self.switches.values())

    def switch_str(self) -> str:
        return switch_label(self.remat, self.zero1, self.zero3,
                            self.seq_parallel)

    def exec_strategy(self, kind: str = "train") -> str:
        """The executable rules-table name (parallel/strategies.py) that
        deploys this plan for a train / prefill / decode cell."""
        if kind in ("prefill", "decode"):
            # serving: no ZeRO (latency-critical); expert plans keep ep rules.
            # pipeline plans also serve as TP — every pipeline schedule
            # (gpipe / 1F1B / interleaved) is a TRAINING schedule (fill/
            # drain over microbatches).
            return "ep_df" if self.strategy == "ep" else "serve_tp"
        table = {"serial": "data", "data": "data", "spatial": "ds",
                 "filter": "filter", "channel": "channel", "ds": "ds",
                 "ep": "ep_df", "pipeline": "pipeline", "summa": "summa"}
        if self.strategy == "df":
            if self.zero3:
                return "df_zero3"
            return "df_zero1" if self.zero1 else "df"
        return table[self.strategy]

    def describe(self) -> str:
        cap = (f"{self.mem_cap / 2**30:.1f}" if self.mem_cap else "∞")
        strat = (f"{self.strategy}:{self.schedule}"
                 if self.strategy == "pipeline" else self.strategy)
        if self.strategy == "summa":
            strat = f"summa:{self.p2r}x{self.p2c}"
        tiles = ""
        if self.kernel_tiles is not None and len(self.kernel_tiles):
            tiles = f", {len(self.kernel_tiles)} tuned kernel tiles"
        return (f"TunedPlan[p={self.p}]: {strat} "
                f"(mesh {self.p1}x{self.p2}, switches {self.switch_str()}) "
                f"→ {self.per_iter_s * 1e3:.2f} ms/iter, "
                f"{self.mem_bytes / 2**30:.2f}/{cap} GiB, "
                f"{self.bottleneck}{tiles}"
                + ("" if self.feasible else "  [FALLBACK: nothing fits]"))


def _plan_of(res: SweepResult, i: int, mem_cap, feasible: bool,
             source: str, segments: int = 8,
             virtual_stages: int = 2) -> TunedPlan:
    sched = str(res.schedule[i])
    return TunedPlan(
        strategy=str(res.strategy[i]), p=int(res.p[i]), p1=int(res.p1[i]),
        p2=int(res.p2[i]), remat=bool(res.remat[i]), zero1=bool(res.zero1[i]),
        zero3=bool(res.zero3[i]), seq_parallel=bool(res.seq_parallel[i]),
        bottleneck=str(res.bottleneck[i]), total_s=float(res.total_s[i]),
        iterations=float(res.iterations[i]),
        mem_bytes=float(res.mem_bytes[i]), mem_cap=mem_cap,
        feasible=feasible, source=source, segments=segments,
        schedule="gpipe" if sched == "-" else sched,
        virtual_stages=virtual_stages,
        p2r=int(res.p2r[i]), p2c=int(res.p2c[i]))


def deployable_switch_mask(res: SweepResult, allow_remat: bool = True):
    """Which lattice points' switch combos the exec path can actually
    realize — a plan must never claim "fits" via a switch that
    ``exec_strategy``/``build_cell`` won't turn on:

    * ``zero1`` — deployable everywhere (``OptimizerConfig(zero1=...)`` +
      ``zero1_rules`` apply to any rules table);
    * ``zero3`` — only the ``df``/``ep`` rules tables shard params over the
      data axis (``df_zero3`` / ``ep_df``);
    * ``seq_parallel`` — only the model-axis tables (``df``/``filter``/
      ``channel``/``ep``) shard the residual stream; ``summa`` is excluded
      from both ZeRO-3 and the seq switch — its residual is already
      sequence-sharded over the grid rows, the extra column-axis pass the
      oracle prices has no exec path;
    * ``remat`` — wire-able only where the model's forward supports it
      (lm / vlm / encdec; CNN forwards have no checkpointing), gated by
      ``allow_remat``;
    * ``pipeline`` — the pipeline step (any schedule) deploys no memory
      switches (its projection is switch-invariant anyway), so only the
      all-off combo stands.
    """
    strat = res.strategy
    m = np.ones(len(res), bool)
    if not allow_remat:
        m &= ~res.remat
    m &= ~res.zero3 | np.isin(strat, ("df", "ep"))
    m &= ~res.seq_parallel | np.isin(strat, ("df", "filter", "channel", "ep"))
    m &= (strat != "pipeline") | (res.n_switches == 0)
    return m


def _segments_resolvable(batch: int, segments: int, multiple_of: int) -> bool:
    """Whether the executor's resolve_segments() would find a microbatch
    count (needed to gate interleaved plans: S must be a multiple of the
    stage count)."""
    import warnings
    from ...parallel.schedules import resolve_segments
    try:
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            resolve_segments(batch, segments, multiple_of=multiple_of)
        return True
    except ValueError:
        return False


def deployable_schedule_mask(res: SweepResult, cfg: OracleConfig,
                             max_stages: int | None = None):
    """Which lattice points' pipeline schedules the executor can actually
    realize. gpipe/1F1B deploy wherever pipeline itself does; interleaved
    additionally needs (a) ``v·p2`` chunks to fit the model's block stack
    and (b) a microbatch count S ≤ ``cfg.segments`` with B % S == 0 and
    S % p2 == 0 (the runtime resolves segments with
    ``multiple_of=n_stages`` and raises otherwise)."""
    m = np.ones(len(res), bool)
    il = np.asarray(res.schedule) == "interleaved"
    if not il.any():
        return m
    v = max(int(cfg.virtual_stages), 1)
    if max_stages is not None:
        m &= ~il | (v * res.p2 <= max_stages)
    for j in np.flatnonzero(il & m):
        if not _segments_resolvable(int(res.B[j]), int(cfg.segments),
                                    int(res.p2[j])):
            m[j] = False
    return m


def autotune(stats, tm: TimeModel, cfg: OracleConfig, p: int, *,
             mem_cap: float | None = None, strategies=None,
             switches="all", schedules="all", fallback: str | None = None,
             allow_remat: bool = True, allow_pipeline: bool = True,
             max_stages: int | None = None, model_width: int | None = None,
             model_widths: "tuple[int, ...] | None" = None,
             model_grid: "tuple[int, int] | None" = None,
             cluster: "ClusterSpec | None" = None,
             rtol: float = 1e-9) -> TunedPlan:
    """Pick the cheapest deployable (strategy, p1·p2, switches, schedule)
    point at p.

    ``fallback``: strategy name (oracle or executable-rules spelling) that
    wins ties and is returned when nothing fits. ``switches``: as in
    ``sweep()`` — default sweeps all 16 memory-switch combinations, then
    masks the ones the exec path cannot realize per strategy
    (``deployable_switch_mask``); ``schedules``: as in ``sweep()`` —
    default prices every pipeline schedule (gpipe / 1F1B / interleaved)
    and lets the cheapest deployable one win, then masks the ones the
    executor cannot realize (``deployable_schedule_mask``);
    ``allow_remat=False`` additionally bars remat (models whose forward
    cannot checkpoint), and ``allow_pipeline=False`` bars the pipeline
    strategy entirely (models the stage compiler cannot cut —
    ``parallel.schedules.pipeline_supported``).
    ``model_width`` constrains hybrid plans to one p2 — pass the mesh's
    model-axis size when the mesh is already shaped and cannot be
    refactorized (summa plans are excluded there: a 1D ("data", "model")
    mesh carries no (model_r, model_c) grid). ``model_widths`` is the
    allowed-SET form of the same constraint — pass the p2 values a mesh
    factory can realize (e.g. the divisors of the device count) to get
    the cheapest plan that tiles, instead of silently dropping the model
    axis when the single winner doesn't. ``model_grid`` is the
    converse: pass the (r, c) extents of an already-shaped grid mesh and
    only summa points on exactly that grid survive.
    ``cluster``: a ClusterSpec whose torus topology prunes
    p1·p2 factorizations the machine cannot physically host (model axis
    must ring within one allowed torus dim — cluster.Torus); pruned points
    are never deployed, they fall out of the lattice like any other
    infeasibility.
    """
    mem_cap = mem_cap if mem_cap is not None else tm.system.mem_capacity
    fallback = ORACLE_OF_EXEC.get(fallback, fallback)
    if strategies is None:
        strategies = tuple(
            s for s in DEPLOYABLE_STRATEGIES
            if (s != "serial" or p == 1)
            and (s != "pipeline" or allow_pipeline))
    elif not allow_pipeline:
        if "pipeline" in strategies and len(set(strategies)) == 1:
            raise ValueError(
                "pipeline was requested but this model cannot deploy it "
                "(no uniform block stack — parallel.pipeline."
                "pipeline_supported)")
        strategies = tuple(s for s in strategies if s != "pipeline")
    res = sweep(stats, tm, cfg, [p], strategies, mem_cap=mem_cap,
                switches=switches, schedules=schedules, cluster=cluster)
    if len(res) == 0:
        raise ValueError(f"no strategy in {strategies} applies to this model")
    keep = deployable_switch_mask(res, allow_remat=allow_remat)
    if model_width is not None:
        # pure strategies ignore the hybrid split — except pipeline, whose
        # stage count IS its p2: it must land on the mesh's model width just
        # like the hybrids, or the deployed stage count won't match the plan
        keep &= (~np.isin(res.strategy, HYBRID_STRATEGIES + ("pipeline",))
                 | (res.p2 == model_width))
        keep &= res.strategy != "summa"
    if model_widths is not None:
        keep &= (~np.isin(res.strategy, HYBRID_STRATEGIES + ("pipeline",))
                 | np.isin(res.p2, tuple(model_widths)))
        keep &= res.strategy != "summa"
    if model_grid is not None:
        r, c = model_grid
        keep &= ((res.strategy == "summa") & (res.p2r == r)
                 & (res.p2c == c))
    if max_stages is not None:
        # the oracle's p <= G bound counts STAT layers; the executor cuts
        # the model's BLOCK stack, which is shorter (attn+ffn share a block)
        keep &= (res.strategy != "pipeline") | (res.p2 <= max_stages)
    keep &= deployable_schedule_mask(res, cfg, max_stages=max_stages)
    res = res.select(keep)
    if len(res) == 0:
        raise ValueError(
            f"every lattice point at p={p} was filtered out (switches="
            f"{switches!r}, allow_remat={allow_remat}, "
            f"model_width={model_width}); relax the constraints")
    nsw = res.n_switches
    ok = res.ok
    if ok.any():
        total = res.total_s
        tied = ok & (total <= total[ok].min() * (1.0 + rtol))
        if fallback is not None and np.any(tied & (res.strategy == fallback)):
            tied &= res.strategy == fallback
        i = min(np.flatnonzero(tied),
                key=lambda j: (int(nsw[j]), int(res.p2[j]),
                               _PREF.get(str(res.strategy[j]), 99),
                               int(res.p1[j])))
        return _plan_of(res, i, mem_cap, feasible=True, source="sweep",
                        segments=cfg.segments,
                        virtual_stages=cfg.virtual_stages)
    # nothing fits: fall back to the requested strategy's least-memory point
    cand = np.flatnonzero(res.strategy == fallback) if fallback else None
    if cand is None or cand.size == 0:
        cand = np.arange(len(res))
    i = min(cand, key=lambda j: (float(res.mem_bytes[j]), int(nsw[j]),
                                 int(res.p2[j]),
                                 _PREF.get(str(res.strategy[j]), 99)))
    return _plan_of(res, i, mem_cap, feasible=False, source="fallback",
                    segments=cfg.segments,
                    virtual_stages=cfg.virtual_stages)


# ---------------------------------------------------------------------------
# Launch-entry-point glue: arch registry → TunedPlan
# ---------------------------------------------------------------------------

def stats_for_model(mc, seq: int | None = None):
    """Per-layer oracle stats for any registered model config (CNN configs
    take no sequence length)."""
    from ...models.cnn import CosmoFlowConfig, ResNetConfig, VGGConfig
    from ..layer_stats import stats_for
    if isinstance(mc, (ResNetConfig, VGGConfig, CosmoFlowConfig)):
        return stats_for(mc)
    return stats_for(mc, seq or 4096)


def plan_for_arch(arch_cfg, shape_name: str, p: int, *,
                  system=None, cluster: "ClusterSpec | None" = None,
                  smoke: bool = False,
                  mem_cap: float | None = None, switches="all",
                  model_width: int | None = None,
                  model_grid: "tuple[int, int] | None" = None,
                  cfg: OracleConfig | None = None,
                  stats=None,
                  allow_pipeline: bool | None = None) -> TunedPlan:
    """Auto-tune a registered arch at one input shape on p PEs.

    ``system`` (a SystemModel or a ClusterSpec) defaults to the TPU-v5e
    deployment target (projection mode); the oracle config is one epoch of
    exactly the shape's global batch, so the plan ranks per-iteration time
    (``cfg`` and ``stats`` override both — the session facade passes its
    own so tune() ranks exactly what project()/sweep() report).
    ``cluster`` supplies the machine description in one argument: α–β
    system, φ/σ tables, and the torus topology that prunes unrealizable
    p1·p2 factorizations. ``model_width``: see ``autotune``.
    ``allow_pipeline``: None (default) lets the model's block structure
    decide; False bars the pipeline strategy even where it is deployable —
    the elastic controller (runtime/elastic.py) passes False because its
    rebind path rebuilds a plain SPMD step, not a stage schedule.
    """
    from ...configs.base import SHAPES
    from ...parallel.pipeline import pipeline_block_count, pipeline_supported
    if isinstance(system, ClusterSpec) and cluster is None:
        cluster = system
    cluster = ClusterSpec.coerce(cluster)
    if cluster is not None:
        system = cluster.system
    mc = arch_cfg.smoke_model if smoke else arch_cfg.model
    shape = SHAPES[shape_name]
    if stats is None:
        stats = stats_for_model(mc, shape.seq_len)
    tm = TimeModel(system or TPU_V5E_POD)
    if cfg is None:
        B = shape.global_batch
        cfg = (cluster.oracle_config(B=B, D=B) if cluster is not None
               else OracleConfig(B=B, D=B))
    can_pipe = (shape.kind == "train" and pipeline_supported(mc) is None
                and allow_pipeline is not False)
    return autotune(stats, tm, cfg, p, mem_cap=mem_cap, switches=switches,
                    fallback=arch_cfg.strategy_for(shape_name),
                    model_width=model_width, model_grid=model_grid,
                    cluster=cluster,
                    allow_remat=arch_cfg.family != "cnn",
                    allow_pipeline=can_pipe,
                    max_stages=pipeline_block_count(mc))


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def _smoke() -> int:
    """Self-check: the tuner's pick must be the sweep's cheapest ok point,
    and (with switches pinned to the config's) must agree with advise()."""
    from ...models.cnn import RESNET50
    from ..advisor import advise
    from ..hardware import PAPER_V100_CLUSTER
    from ..layer_stats import stats_for
    stats = stats_for(RESNET50)
    tm = TimeModel(PAPER_V100_CLUSTER)
    cfg = OracleConfig(B=128, D=12800)
    for p in (8, 64):
        plan = autotune(stats, tm, cfg, p)
        assert plan.feasible and plan.p1 * plan.p2 == p, plan
        res = sweep(stats, tm, cfg, [p], mem_cap=plan.mem_cap,
                    switches="all", schedules="all")
        dep = (res.ok & deployable_switch_mask(res)
               & deployable_schedule_mask(res, cfg))
        assert np.isclose(plan.total_s, res.total_s[dep].min(),
                          rtol=1e-12), (plan, res.total_s[dep].min())
        pinned = autotune(stats, tm, cfg, p, switches=None,
                          strategies=("data", "spatial", "filter", "channel",
                                      "df", "ds", "ep"))
        rec = advise(stats, tm, cfg, p, mem_cap=plan.mem_cap,
                     strategies=("data", "spatial", "filter", "channel",
                                 "df", "ds", "ep"))
        assert rec.best is not None
        assert np.isclose(pinned.total_s, rec.best.total_s, rtol=1e-12)
        print(f"autotune --smoke p={p}: {plan.describe()}")
    return 0


def main(argv=None) -> int:
    from ..sweep import _model_config, _model_stats
    ap = argparse.ArgumentParser(
        prog="python -m repro.core.autotune",
        description="Oracle-in-the-loop auto-tuner: what should I run on "
                    "p PEs? Picks the cheapest deployable (strategy, p1·p2 "
                    "mesh, memory switches) point from the sweep lattice.")
    ap.add_argument("--model", default="resnet50",
                    help="resnet50 | vgg16 | cosmoflow | any configs/ LM name")
    ap.add_argument("--p", default="64",
                    help="PE count(s): '64', '8,64,1024', '1..1024' (pow2)")
    ap.add_argument("--batch", type=int, default=None,
                    help="fixed global batch B (default: weak scaling)")
    ap.add_argument("--batch-per-pe", type=float, default=2.0,
                    help="weak scaling: B = max(round(b·p), 1)")
    ap.add_argument("--dataset", type=int, default=None,
                    help="samples per epoch D (default: per-model)")
    ap.add_argument("--seq", type=int, default=4096, help="LM sequence length")
    ap.add_argument("--mem-cap-gib", type=float, default=None,
                    help="per-PE memory cap (default: system capacity)")
    ap.add_argument("--fallback", default=None,
                    help="strategy that wins ties / absorbs infeasibility")
    ap.add_argument("--strategies", default=None,
                    help="comma-separated subset to tune over (e.g. "
                         "'pipeline' to force a stage-parallel plan)")
    ap.add_argument("--no-switches", action="store_true",
                    help="pin memory switches off instead of sweeping all 16")
    ap.add_argument("--schedule", default="all",
                    help="pipeline schedule axis: 'all' (default) lets the "
                         "cheapest deployable schedule win, or pin one of "
                         "gpipe / one_f_one_b / interleaved")
    ap.add_argument("--virtual-stages", type=int, default=2,
                    help="v for the interleaved schedule (chunks per rank)")
    add_cluster_args(ap, default_system="paper")
    ap.add_argument("--no-overlap", action="store_true",
                    help="rank under the paper's serial comm accounting "
                         "instead of the overlap model (DESIGN.md §10)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny self-check (CI gate)")
    args = ap.parse_args(argv)
    if args.smoke:
        return _smoke()
    cluster = ClusterSpec.from_cli_args(args)

    stats, default_D = _model_stats(args.model, args.seq)
    # the CLI's recommendations must honor the same deployability gates as
    # plan_for_arch/train.py — never print a plan the executor rejects
    from ...parallel.pipeline import pipeline_block_count, pipeline_supported
    mc = _model_config(args.model)
    can_pipe = pipeline_supported(mc) is None
    tm = TimeModel(cluster.system)
    cap = (args.mem_cap_gib * 2 ** 30 if args.mem_cap_gib
           else tm.system.mem_capacity)
    p_grid = parse_p_grid(args.p)
    print(f"# model={args.model} system={tm.system.name} "
          f"mem_cap={cap / 2**30:.1f}GiB switches="
          f"{'off' if args.no_switches else 'all 16 combos'}"
          + (f" topology={cluster.topology}" if cluster.topology else ""))
    print(f"{'p':>6s} {'strategy':16s} {'p1xp2':>11s} {'switches':24s} "
          f"{'ms/iter':>9s} {'mem_GiB':>8s}  bottleneck")
    for p in p_grid:
        B = args.batch or max(int(round(args.batch_per_pe * p)), 1)
        D = max(args.dataset or default_D, B)
        cfg = cluster.oracle_config(
            B=B, D=D, overlap=not args.no_overlap,
            virtual_stages=max(args.virtual_stages, 1))
        plan = autotune(stats, tm, cfg, p, mem_cap=cap,
                        switches=None if args.no_switches else "all",
                        schedules=("all" if args.schedule == "all"
                                   else (args.schedule,)),
                        fallback=args.fallback, cluster=cluster,
                        allow_pipeline=can_pipe,
                        max_stages=pipeline_block_count(mc),
                        strategies=tuple(s for s in
                                         (args.strategies or "").split(",")
                                         if s) or None)
        mark = " " if plan.feasible else "!"
        strat = (f"pipe:{plan.schedule}" if plan.strategy == "pipeline"
                 else plan.strategy)
        print(f"{p:>6d} {strat:16s} "
              f"{plan.p1:>5d}x{plan.p2:<5d} {plan.switch_str():24s} "
              f"{plan.per_iter_s * 1e3:>9.3f} "
              f"{plan.mem_bytes / 2**30:>8.2f} {mark} {plan.bottleneck}")
    return 0
