"""Entry point: ``python -m repro.core.autotune`` (see package docstring)."""
import sys

from . import main

try:
    sys.exit(main())
except BrokenPipeError:     # e.g. `... | head` closing the pipe early
    sys.exit(0)
