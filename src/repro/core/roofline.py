"""Roofline terms from dry-run artifacts (deliverable g).

Hardware model: TPU v5e — 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI (2D torus; ring collectives run along mesh axes).
Inter-pod ("pod" axis) traffic crosses DCI, modeled at 25 GB/s/chip
(documented assumption; the per-axis split comes from the parsed replica
groups).

    compute term    = HLO_FLOPs_per_chip / peak_FLOP/s
    memory term     = HLO_bytes_per_chip / HBM_bw
    collective term = Σ_axis wire_bytes_per_chip(axis) / link_bw(axis)
"""
from __future__ import annotations

from dataclasses import dataclass

from .hlo_analysis import CellCost


@dataclass(frozen=True)
class HardwareSpec:
    name: str = "tpu-v5e"
    peak_bf16: float = 197e12        # FLOP/s per chip
    hbm_bw: float = 819e9            # B/s per chip
    ici_bw: float = 50e9             # B/s per link (per mesh axis)
    dci_bw: float = 25e9             # B/s per chip across pods (assumption)
    hbm_bytes: float = 16e9          # v5e HBM capacity
    vmem_bytes: float = 128 * 2**20  # on-chip VMEM a Pallas program tiles for
    mxu: int = 128                   # systolic array edge (MXU 128×128)

    @classmethod
    def from_cluster(cls, spec) -> "HardwareSpec":
        """Roofline view of a ClusterSpec: intra-pod links from the
        'model' level's β, the cross-pod hop from 'pod' — so dry-run
        rooflines and oracle projections read one machine description.
        VMEM/MXU keep the v5e defaults (ClusterSpec models the machine at
        HBM/interconnect granularity; the kernel autotuner consumes them
        through this view — kernels/autotune/space.py)."""
        return cls(name=spec.name, peak_bf16=spec.peak_flops,
                   hbm_bw=spec.hbm_bw,
                   ici_bw=1.0 / spec.level("model").beta,
                   dci_bw=1.0 / spec.level("pod").beta,
                   hbm_bytes=spec.mem_capacity)


V5E = HardwareSpec()


@dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    collective_by_axis: dict
    model_flops: float              # 6·N·tokens (or 2·N for inference)
    hlo_flops_total: float          # per-chip × chips
    chips: int
    temp_bytes: int
    fits_hbm: bool
    kind: str = "train"
    arg_bytes: int = 0

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — remat/redundancy/dispatch waste."""
        return self.model_flops / self.hlo_flops_total if self.hlo_flops_total else 0.0

    @property
    def serial_s(self) -> float:
        """Fully-serial upper bound: compute + memory + collectives, nothing
        hidden — the paper's accounting."""
        return self.compute_s + self.memory_s + self.collective_s

    @property
    def step_time_s(self) -> float:
        """Full-overlap lower bound: max of the three terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    def overlapped_s(self, sigma: float = 0.8) -> float:
        """Overlap-model estimate between the two bounds (DESIGN.md §10):
        collectives hide under the on-chip work with efficiency σ —
        T = max(T_chip, T_coll) + (1−σ)·min(T_chip, T_coll), where T_chip
        is the compute/HBM bound max(compute_s, memory_s). σ=1 recovers
        ``step_time_s``; σ=0 charges collectives serially."""
        chip = max(self.compute_s, self.memory_s)
        return max(chip, self.collective_s) \
            + (1.0 - sigma) * min(chip, self.collective_s)

    @property
    def ideal_s(self) -> float:
        """Per-kind ideal step time: compute-bound for train/prefill,
        memory-bound (stream params+cache once) for decode."""
        compute_ideal = self.model_flops / self.chips / V5E.peak_bf16
        if self.kind == "decode":
            return max(self.arg_bytes / V5E.hbm_bw, compute_ideal)
        return compute_ideal

    @property
    def roofline_fraction(self) -> float:
        """Achievable bound: ideal step time / bound step time."""
        return self.ideal_s / self.step_time_s if self.step_time_s else 0.0

    def to_json(self, sigma: float = 0.8) -> dict:
        return {
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "collective_by_axis": self.collective_by_axis,
            "dominant": self.dominant,
            "model_flops": self.model_flops,
            "hlo_flops_total": self.hlo_flops_total,
            "useful_ratio": self.useful_ratio,
            "step_time_bound_s": self.step_time_s,
            "serial_s": self.serial_s,
            "overlapped_s": self.overlapped_s(sigma),
            "overlap_sigma": sigma,      # which σ the field above assumed
            "ideal_s": self.ideal_s,
            "roofline_fraction": self.roofline_fraction,
            "temp_bytes": self.temp_bytes,
            "fits_hbm": self.fits_hbm,
            "chips": self.chips,
        }


def roofline(cost: CellCost, chips: int, model_flops: float,
             hw: HardwareSpec = V5E, kind: str = "train") -> Roofline:
    by_axis = {}
    coll_total = 0.0
    for axis in ("pod", "data", "model", "mixed", "none"):
        # native-dtype accounting: fp32 payloads that are CPU-lowering
        # artifacts of bf16 dots count at bf16 width (the TPU reality)
        wire = cost.wire_bytes(axis, native_dtype=True)
        bw = hw.dci_bw if axis == "pod" else hw.ici_bw
        t = wire / bw
        if wire:
            by_axis[axis] = {"wire_bytes": wire, "seconds": t}
        coll_total += t
    state_bytes = cost.arg_bytes  # params + opt state + cache live in HBM
    return Roofline(
        compute_s=cost.flops / hw.peak_bf16,
        memory_s=cost.bytes_accessed / hw.hbm_bw,
        collective_s=coll_total,
        collective_by_axis=by_axis,
        model_flops=model_flops,
        hlo_flops_total=cost.flops * chips,
        chips=chips,
        temp_bytes=cost.temp_bytes,
        kind=kind, arg_bytes=cost.arg_bytes,
        fits_hbm=(cost.temp_bytes + state_bytes) <= hw.hbm_bytes)
