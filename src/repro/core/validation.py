"""Oracle-vs-measured validation harness (paper §5.2, Fig. 3 methodology).

Runs a reduced model under each parallel strategy on the available (virtual)
host devices, measures the iteration time, projects the same point with the
calibrated oracle, and reports the paper's accuracy metric:

    accuracy = 1 − |T_projected − T_measured| / T_measured
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import numpy as np

from ..nn.module import ShardingCtx, tree_init
from ..optim.optimizers import OptimizerConfig
from ..parallel.strategies import make_rules
from ..training.steps import make_train_step, train_state_spec
from .calibration import calibrate_host_system, time_fn
from .layer_stats import stats_for
from .oracle import OracleConfig, TimeModel, project

# oracle-strategy name → executable rules-table name (parallel/strategies.py)
EXEC_STRATEGY = {
    "data": "data",
    "filter": "filter",
    "channel": "channel",
    "spatial": "ds",
    "df": "df",
    "ds": "ds",
    "ep": "ep_df",      # expert parallelism executes as the ep_df hybrid rules
    "summa": "summa",   # 2D tensor grid: measure_step reshapes the devices
                        # into a (data, model_r, model_c) mesh from ``grid``
    "pipeline": "pipeline",  # stage schedule (gpipe / 1F1B / interleaved):
                             # measure_step builds the stage executor
                             # (parallel/schedules), not a plain sharded
                             # train step
}

# oracle strategies with NO executable path, and why (so validate() skips
# them explicitly instead of falling through to an unknown name)
EXEC_SKIP = {
    "serial": "p=1 baseline needs no sharding rules; measure with a plain "
              "jit step instead",
}


@dataclass
class ValidationPoint:
    strategy: str
    p: int
    measured_s: float
    projected_s: float            # overlap model (OracleConfig default)
    projected_serial_s: float = 0.0   # paper accounting (overlap=False)

    def _acc(self, proj: float) -> float:
        if self.measured_s <= 0:
            return 0.0
        return 1.0 - abs(proj - self.measured_s) / self.measured_s

    @property
    def accuracy(self) -> float:
        return self._acc(self.projected_s)

    @property
    def accuracy_serial(self) -> float:
        """Accuracy of the no-overlap (serial-comm) projection."""
        return self._acc(self.projected_serial_s)


def measure_step(model, model_cfg, batch, mesh, strategy: str,
                 seed: int = 0, segments: int = 8,
                 schedule: str = "gpipe", virtual_stages: int = 2,
                 grid: "tuple[int, int] | None" = None) -> float:
    """Measured per-iteration time of a real sharded train step.

    ``pipeline`` measures the stage executor under ``schedule`` (gpipe /
    one_f_one_b / interleaved): all p PEs become stages of a (1, p) pipe
    mesh (the paper's pure "layer" strategy) and the step runs that
    schedule with ``segments`` microbatches.

    ``summa`` reshapes the same devices into a (data, model_r, model_c)
    mesh from ``grid`` = (p2r, p2c) — the strategy's rules table routes
    projections through parallel/summa.py on that mesh.
    """
    if strategy in EXEC_SKIP:
        raise NotImplementedError(
            f"oracle strategy {strategy!r} is not executable: "
            f"{EXEC_SKIP[strategy]}")
    if strategy not in EXEC_STRATEGY:
        raise KeyError(f"no executable mapping for oracle strategy "
                       f"{strategy!r}; known: {sorted(EXEC_STRATEGY)}, "
                       f"skipped: {sorted(EXEC_SKIP)}")
    opt = OptimizerConfig(name="sgd", zero1=False)
    rules = make_rules(EXEC_STRATEGY[strategy])
    if strategy == "pipeline":
        from ..launch.compat import make_mesh
        from ..parallel.pipeline import (make_pipeline_train_step,
                                         pipeline_block_costs)
        p = int(np.prod(list(mesh.shape.values())))
        pipe_mesh = make_mesh((1, p), ("data", "model"),
                              devices=list(np.asarray(mesh.devices).flat))
        ctx = ShardingCtx(pipe_mesh, rules)
        tok = batch["tokens"]
        costs = pipeline_block_costs(
            model, stats_for(model_cfg, tok.shape[1]), attn_impl="plain")
        step = make_pipeline_train_step(
            model, opt, ctx, block_costs=costs, segments=segments,
            schedule=schedule, virtual_stages=virtual_stages,
            attn_impl="plain")
    else:
        if strategy == "summa":
            if grid is None:
                raise ValueError("summa needs grid=(p2r, p2c)")
            from ..launch.compat import make_mesh
            r, c = grid
            p = int(np.prod(list(mesh.shape.values())))
            if p % (r * c):
                raise ValueError(f"grid {r}x{c} does not divide p={p}")
            mesh = make_mesh((p // (r * c), r, c),
                             ("data", "model_r", "model_c"),
                             devices=list(np.asarray(mesh.devices).flat))
        ctx = ShardingCtx(mesh, rules)
        from ..models.transformer import TransformerLM
        from ..models.vlm import VLM
        kw = dict(scan_layers=False, attn_impl="plain") \
            if isinstance(model, (TransformerLM, VLM)) else {}
        step = make_train_step(model, opt, ctx, **kw)
    sspec = train_state_spec(model, opt)
    key = jax.random.PRNGKey(seed)
    state = tree_init(sspec, key)
    jstep = jax.jit(step)
    return time_fn(jstep, state, batch, iters=4, warmup=2)


def validate(model, model_cfg, batch, mesh, strategies, *,
             flops_per_sample: float, B: int, S: int = 128,
             oracle_cfg_kw: dict | None = None,
             cluster=None,
             grid: "tuple[int, int] | None" = None) -> list[ValidationPoint]:
    """Measure + project each strategy at p = mesh size; paper Fig. 3.

    ``cluster``: a (typically fitted) ClusterSpec describing PER-PE
    capability — projections then use its α–β/φ/σ instead of calibrating
    the host in place, closing the calibrate→project loop
    (``Oracle.calibrate`` → ``Oracle.validate``). Without it, the host is
    calibrated here as before.

    ``grid``: (p2r, p2c) for the "summa" strategy — measured on the
    reshaped grid mesh and projected at the matching lattice point.
    """
    import dataclasses
    stats = stats_for(model_cfg, S)
    flops_step = flops_per_sample * B
    p = int(np.prod(list(mesh.shape.values())))
    kw = dict(oracle_cfg_kw or {})
    if cluster is not None:
        sysm = cluster.system
        for k, v in cluster.oracle_kw().items():
            kw.setdefault(k, v)
    else:
        sysm = calibrate_host_system(
            lambda p, b: model.loss_fn(p, b),
            tree_init(model.params_spec(), jax.random.PRNGKey(0)), batch,
            flops_step, mesh=mesh)
        # virtual host devices timeshare ONE core: a PE delivers 1/p of the
        # measured serial throughput. The oracle's system model describes
        # actual per-PE capability (paper §4.4), so divide.
        sysm = dataclasses.replace(sysm, peak_flops=sysm.peak_flops / p)
    cfg = OracleConfig(B=B, D=B, **kw)  # 1 iteration/epoch
    tm = TimeModel(sysm)
    points = []
    for s in strategies:
        if s in EXEC_SKIP:      # explicitly not executable; see EXEC_SKIP
            continue
        cfg_s = cfg
        if s == "pipeline":
            # skip (don't abort the whole run) when the executor cannot
            # realize p stages on this model; project under the segment
            # count it will actually run otherwise
            from ..parallel.pipeline import clip_segments, pipeline_supported
            reason = pipeline_supported(model)
            n_blocks = getattr(getattr(model, "cfg", None), "n_layers", 0)
            if reason is None and p > n_blocks:
                reason = f"p={p} stages exceed the model's {n_blocks} blocks"
            if reason is not None:
                print(f"validate: skipping pipeline — {reason}")
                continue
            cfg_s = dataclasses.replace(cfg, segments=clip_segments(
                B, cfg.segments))
        meas = measure_step(model, model_cfg, batch, mesh, s,
                            segments=cfg_s.segments, grid=grid)
        kw = {}
        if s in ("df", "ds", "ep"):
            kw = dict(p1=mesh.shape.get("data", 1),
                      p2=mesh.shape.get("model", 1))
        elif s == "summa":
            if grid is None:
                raise ValueError("summa needs grid=(p2r, p2c)")
            r, c = grid
            kw = dict(p1=p // (r * c), p2=r * c, p2r=r, p2c=c)
        proj = project(s, stats, tm, cfg_s, p, **kw)
        serial = project(s, stats, tm,
                         dataclasses.replace(cfg_s, overlap=False), p, **kw)
        points.append(ValidationPoint(s, p, meas, proj.total_s,
                                      serial.total_s))
    return points


def measure_serving(model, mesh, strategy: str, serve_cfg, requests, *,
                    params=None, seed: int = 0, warmup: bool = True,
                    honor_arrivals: bool = False):
    """Measured serving replay: the continuous-batching engine under one
    serving rules table on ``mesh``, fed ``requests`` (a trace from
    TrafficModel.trace). Returns the engine's ServeReport — tok/s and
    latency percentiles the serving oracle's ranking is validated against
    (tests/helpers/multidevice_checks.py serving_validation).

    ``warmup`` replays the trace once first so compile time stays out of
    the measured wall clock; ``honor_arrivals=False`` (default) replays
    closed-loop, measuring capacity rather than queueing.
    """
    from ..serve.engine import Engine
    ctx = ShardingCtx(mesh, make_rules(strategy))
    if params is None:
        params = tree_init(model.params_spec(), jax.random.PRNGKey(seed))
    eng = Engine(model, params, ctx, serve_cfg, seed=seed)
    if warmup:
        eng.run(requests, honor_arrivals=False)
        eng.reset()
    return eng.run(requests, honor_arrivals=honor_arrivals)


def measure_schedule_bubble(model, model_cfg, make_batch, mesh, *,
                            schedule: str = "gpipe",
                            virtual_stages: int = 2,
                            S_small: int = 4, S_large: int = 8,
                            microbatch: int = 1, seed: int = 0) -> dict:
    """Measured bubble fraction of one pipeline schedule (paper §5.2
    methodology extended to the schedule axis).

    Runs the stage executor at two microbatch counts with a FIXED
    per-microbatch size (``make_batch(S · microbatch)`` builds the batch),
    fits the step time as t(S) = a·S + b — a is the steady-state
    per-microbatch cost, b the fill/drain (bubble) overhead — and reports
    the bubble fraction b / t(S_large). Schedules with shorter pipelines
    (1F1B's early backward, interleaved's v-fold shorter fill) show a
    smaller b for the same stage cut, which is exactly what the oracle's
    per-schedule bubble terms claim.
    """
    times = {}
    for S in (S_small, S_large):
        batch = make_batch(S * microbatch)
        times[S] = measure_step(model, model_cfg, batch, mesh, "pipeline",
                                seed=seed, segments=S, schedule=schedule,
                                virtual_stages=virtual_stages)
    a = (times[S_large] - times[S_small]) / float(S_large - S_small)
    b = max(times[S_small] - a * S_small, 0.0)
    t = times[S_large]
    return {"schedule": schedule, "S_small": S_small, "S_large": S_large,
            "per_microbatch_s": a, "bubble_s": b,
            "t_small_s": times[S_small], "t_large_s": t,
            "bubble_fraction": b / t if t > 0 else 0.0}


def schedule_winner(stats, tm, cfg, p: int) -> str:
    """The oracle's cheapest pipeline schedule at p — the schedule axis of
    the sweep restricted to the pipeline strategy. Ties break in
    PIPELINE_SCHEDULES order (gpipe first), matching autotune."""
    from .sweep import sweep
    res = sweep(stats, tm, cfg, [p], strategies=("pipeline",),
                schedules="all")
    if len(res) == 0:
        raise ValueError("pipeline does not apply to this layer set")
    keep = res.feasible if res.feasible.any() else np.ones(len(res), bool)
    idx = np.flatnonzero(keep)
    return str(res.schedule[idx[np.argmin(res.total_s[idx])]])


def accuracy_report(points: list[ValidationPoint]) -> str:
    lines = [f"{'strategy':10s} {'measured_ms':>12s} {'projected_ms':>13s} "
             f"{'accuracy':>9s} {'serial_ms':>10s} {'acc_serial':>10s}"]
    for pt in points:
        lines.append(f"{pt.strategy:10s} {pt.measured_s*1e3:12.2f} "
                     f"{pt.projected_s*1e3:13.2f} {pt.accuracy*100:8.1f}% "
                     f"{pt.projected_serial_s*1e3:10.2f} "
                     f"{pt.accuracy_serial*100:9.1f}%")
    mean = np.mean([pt.accuracy for pt in points])
    lines.append(f"{'MEAN':10s} {'':12s} {'':13s} {mean*100:8.1f}%")
    return "\n".join(lines)
