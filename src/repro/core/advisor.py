"""Strategy advisor — the oracle front-end (paper §4.1 use case 1).

Given (model stats, system, batch, PE budget, memory cap), evaluate the
strategies × group splits lattice with the vectorized sweep engine
(sweep.py), drop infeasible points (scaling limits, memory), and rank the
rest by projected per-iteration time. Also emits the breakdown table the
paper's Fig. 3 plots.
"""
from __future__ import annotations

from dataclasses import dataclass

from .layer_stats import LayerStat
from .oracle import OracleConfig, Projection, TimeModel
from .sweep import factor_pairs, sweep


@dataclass
class Recommendation:
    best: Projection | None
    ranked: list[Projection]
    rejected: list[tuple[Projection, str]]


def _split_candidates(p: int):
    """Candidate (p1 data-groups, p2 model-width) factorizations: ALL divisor
    pairs of p (exhaustive — non-pow2 hybrid splits like 12 = 3×4 included)."""
    return factor_pairs(p)


def advise(stats: list[LayerStat], tm: TimeModel, cfg: OracleConfig, p: int,
           mem_cap: float | None = None,
           strategies=("data", "spatial", "pipeline", "filter", "channel",
                       "df", "ds", "ep", "summa"), cluster=None) -> Recommendation:
    """Rank strategies at p. ``cluster`` (a ClusterSpec) additionally
    rejects splits its torus topology cannot host — they land in
    ``rejected`` with the placement reason, like any scaling limit.
    The lattice includes the 2D grid points: "summa" fans over every
    (p1, p2r·p2c) factorization, and the headline ranking keeps its best
    grid like any other strategy's best split."""
    mem_cap = mem_cap or tm.system.mem_capacity
    res = sweep(stats, tm, cfg, [p], strategies, mem_cap=mem_cap,
                cluster=cluster)
    ranked, rejected = [], []
    for i, proj in enumerate(res.to_projections()):
        if not proj.feasible:
            rejected.append((proj, f"scaling limit: {proj.limit}"))
        elif not res.fits[i]:
            rejected.append(
                (proj, f"memory {proj.mem_bytes/2**30:.1f}GiB > "
                       f"cap {mem_cap/2**30:.1f}GiB"))
        else:
            ranked.append(proj)
    ranked.sort(key=lambda r: r.total_s)
    # keep only the best split per strategy in the headline ranking
    seen, dedup = set(), []
    for r in ranked:
        if r.strategy not in seen:
            dedup.append(r)
            seen.add(r.strategy)
    return Recommendation(dedup[0] if dedup else None, dedup, rejected)


@dataclass
class GroupChoice:
    """Per-layer-group winner in a strategy mixture (advisory)."""

    kind: str            # layer_stats kind: conv | fc | attn | ffn | moe | …
    n_layers: int
    strategy: str
    p1: int
    p2: int
    p2r: int             # model-grid factorization (summa winners; 1×1 else)
    p2c: int
    total_s: float       # projected epoch seconds for THIS group alone


def advise_groups(stats: list[LayerStat], tm: TimeModel, cfg: OracleConfig,
                  p: int, mem_cap: float | None = None,
                  strategies=("data", "spatial", "filter", "channel",
                              "df", "ds", "ep", "summa"),
                  cluster=None) -> list[GroupChoice]:
    """Per-layer-group strategy mixture: sweep each group of same-kind
    layers separately and report its winner (Jia et al., arXiv 1802.04924:
    per-layer hidden-dimension splits beat any single global strategy).

    Advisory, not a deployable plan: the resharding collectives at group
    boundaries are not priced, so the mixture's summed time is a lower
    bound. A mixture that beats the global winner by more than the
    boundary-reshard cost is the signal to split the deployment. Pipeline
    is excluded — its schedule spans the whole stack, not one group."""
    groups: dict[str, list[LayerStat]] = {}
    for s in stats:
        groups.setdefault(s.kind, []).append(s)
    out = []
    for kind in sorted(groups):
        gstats = groups[kind]
        try:
            rec = advise(gstats, tm, cfg, p, mem_cap=mem_cap,
                         strategies=strategies, cluster=cluster)
        except ValueError:   # no strategy applies to this group alone
            continue
        b = rec.best
        if b is None:
            continue
        out.append(GroupChoice(kind, len(gstats), b.strategy, b.p1, b.p2,
                               b.p2r, b.p2c, b.total_s))
    return out


def breakdown_table(recs: list[Projection]) -> str:
    """Fig-3-style text table: per-iteration comp/comm per strategy."""
    lines = [f"{'strategy':10s} {'p1xp2':>9s} {'comp_ms':>9s} {'comm_ms':>9s} "
             f"{'total_ms':>9s} {'mem_GiB':>8s}"]
    for r in recs:
        it = r.per_iteration()
        lines.append(
            f"{r.strategy:10s} {r.p1:>4d}x{r.p2:<4d} {it['comp_s']*1e3:9.2f} "
            f"{it['comm_s']*1e3:9.2f} {it['total_s']*1e3:9.2f} "
            f"{r.mem_bytes/2**30:8.2f}")
    return "\n".join(lines)
