"""ParaDL oracle: the paper's Table-3 analytical model, generalized.

Projects per-epoch (and per-iteration) computation time, communication time
and per-PE memory for each parallel strategy, from per-layer stats
(layer_stats.py) + a system model (hardware.py). Every formula carries its
paper provenance; rows marked *beyond-paper* extend the taxonomy (ZeRO,
expert parallelism, sequence-parallel residual streams) with the same α–β
methodology.

Compute times FW_l/BW_l/WU_l come from either
  * projection mode — FLOPs / (peak × efficiency)   (TPU projections), or
  * calibrated mode — a measured per-layer table     (paper §4.4; used by the
    Fig-3 reproduction on host devices).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field

import numpy as np

from .hardware import SystemModel
from .layer_stats import LayerStat

STRATEGY_NAMES = ("serial", "data", "spatial", "pipeline", "filter", "channel",
                  "df", "ds", "ep")


@dataclass(frozen=True)
class TimeModel:
    """Source of FW/BW/WU times (paper §4.4 empirical parametrization)."""

    system: SystemModel
    calibrated: dict | None = None     # name -> (fw_s, bw_s, wu_s) per sample
    wu_bytes_per_param: float = 16.0   # adam: m+v+p fp32 read/write amortized

    def fw(self, st: LayerStat) -> float:
        if self.calibrated and st.name in self.calibrated:
            return self.calibrated[st.name][0]
        return self.system.flops_time(st.flops_fwd)

    def bw(self, st: LayerStat) -> float:
        if self.calibrated and st.name in self.calibrated:
            return self.calibrated[st.name][1]
        return self.system.flops_time(st.flops_bwd)

    def wu(self, st: LayerStat) -> float:
        if self.calibrated and st.name in self.calibrated:
            return self.calibrated[st.name][2]
        return st.w * self.wu_bytes_per_param / self.system.hbm_bw


@dataclass
class Projection:
    """Oracle output for one (strategy, p) point. Times are PER EPOCH;
    ``per_iteration()`` divides by D/B."""

    strategy: str
    p: int
    p1: int                      # data-parallel groups (hybrids)
    p2: int                      # model-parallel width (hybrids)
    comp_s: float
    comm_ge_s: float             # gradient exchange (paper GE)
    comm_fb_s: float             # layer-wise collectives in FB (filter/channel)
    comm_halo_s: float           # spatial halo exchange
    comm_p2p_s: float            # pipeline stage-boundary traffic
    mem_bytes: float
    feasible: bool
    limit: str
    iterations: float
    phases: dict = field(default_factory=dict)

    @property
    def comm_s(self) -> float:
        return self.comm_ge_s + self.comm_fb_s + self.comm_halo_s + self.comm_p2p_s

    @property
    def total_s(self) -> float:
        return self.comp_s + self.comm_s

    def per_iteration(self) -> dict:
        it = max(self.iterations, 1.0)
        return {"comp_s": self.comp_s / it, "comm_s": self.comm_s / it,
                "total_s": self.total_s / it}


@dataclass(frozen=True)
class OracleConfig:
    B: int                        # global mini-batch (weak scaling: B = b·p)
    D: int                        # dataset samples per epoch
    delta: float = 2.0            # bytes per element (bf16)
    gamma: float = 0.6            # memory reuse factor (paper §4.2, [20,28])
    phi_hybrid: float = 2.0       # contention coefficient for df (paper §5.2)
    segments: int = 8             # pipeline micro-batch segments S
    zero1: bool = False           # shard WU across DP ranks ([52], §5.3.3)
    # beyond-paper memory-model extensions (each documented in DESIGN.md):
    remat: bool = False           # activation checkpointing: keep |x_l| only
    zero3: bool = False           # params sharded over DP too (ZeRO-3 / [38])
    seq_parallel: bool = False    # residual stream sharded over model axis
    opt_bytes_per_param: float = 8.0  # adam m+v fp32


def _sum_w(stats):   # total weight elements
    return float(sum(s.w for s in stats))


def _limits(stats, strategy):
    if strategy == "data":
        return "p <= B (micro-batch >= 1 sample)"
    if strategy == "spatial":
        return "p <= min spatial extent; inapplicable to recurrent-seq layers"
    if strategy == "pipeline":
        return "p <= G layers"
    if strategy == "filter":
        return "p <= min F_l"
    if strategy == "channel":
        return "p <= min C_l"
    return ""


def project(strategy: str, stats: list[LayerStat], tm: TimeModel,
            cfg: OracleConfig, p: int, p1: int | None = None,
            p2: int | None = None) -> Projection:
    """One Table-3 row evaluated at p PEs."""
    sysm = tm.system
    B, D, delta, gamma = cfg.B, cfg.D, cfg.delta, cfg.gamma
    iters = D / B
    lvl_model = sysm.level("model")
    lvl_data = sysm.level("data")
    FW = sum(tm.fw(s) for s in stats)
    BW = sum(tm.bw(s) for s in stats)
    WU = sum(tm.wu(s) for s in stats)
    Wbytes = _sum_w(stats) * delta
    bi = sum(getattr(s, "bias", 0) for s in stats)
    feasible, limit = True, _limits(stats, strategy)
    p2_eff = p2 or (p if strategy in ("filter", "channel", "spatial") else 1)

    def mem(act_div=1.0, w_div=1.0, stats_subset=None, dp=1):
        """Per-PE memory. Paper Table-3 expression, extended with remat/
        ZeRO-3/seq-parallel switches and optimizer state (beyond-paper)."""
        ss = stats_subset or stats
        act = sum(B * (s.x if cfg.remat else 2 * (s.x + s.y)) / act_div
                  for s in ss)
        if cfg.seq_parallel and p2_eff > 1:
            act /= p2_eff
        wdiv = w_div * (dp if cfg.zero3 else 1)
        w_elems = sum(s.w for s in ss)
        wmem = 2 * w_elems / wdiv * delta           # params + grads
        opt = w_elems * cfg.opt_bytes_per_param / (
            w_div * (dp if (cfg.zero1 or cfg.zero3) else 1))
        return gamma * delta * act + wmem + opt

    if strategy == "serial":
        return Projection("serial", 1, 1, 1, D * (FW + BW) + iters * WU,
                          0, 0, 0, 0, mem(), True, "p = 1", iters)

    if strategy == "data":
        feasible = p <= B
        comp = D / p * (FW + BW) + iters * WU
        if cfg.zero1:
            comp = D / p * (FW + BW) + iters * WU / p
        ge = iters * lvl_data.allreduce(p, Wbytes)
        return Projection("data", p, p, 1, comp, ge, 0, 0, 0,
                          mem(act_div=p, dp=p), feasible,
                          "p <= B" + ("" if feasible else f" violated (B={B})"),
                          iters)

    if strategy == "spatial":
        sp_min = min((s.spatial for s in stats
                      if s.kind in ("conv", "attn") and s.spatial > 1),
                     default=1)
        feasible = p <= sp_min and not any(s.seq_recurrent for s in stats)
        comp = D / p * (FW + BW) + iters * WU
        ge = iters * lvl_data.allreduce(p, Wbytes)
        halo = iters * sum(
            2 * (2 * lvl_model.alpha + 2 * B * s.halo * delta * lvl_model.beta)
            for s in stats if s.halo)
        return Projection("spatial", p, 1, p, comp, ge, 0, halo, 0,
                          mem(act_div=p), feasible,
                          f"p <= min spatial ({sp_min})"
                          + ("" if feasible else " or recurrent-seq violated"),
                          iters)

    if strategy == "pipeline":
        G = len(stats)
        feasible = p <= G
        S = cfg.segments
        # balanced grouping: max stage ≈ total/p (workload-balancing caveat
        # recorded by the paper §5.3.3)
        fw_max = FW / p
        bw_max = BW / p
        wu_max = WU / p
        comp = D * (p + S - 1) / S * (fw_max + bw_max) + iters * wu_max
        bound_y = max((s.y for s in stats), default=0)
        p2p = 2 * D * (p + S - 2) / B * (lvl_model.alpha
                                         + B / S * bound_y * delta * lvl_model.beta)
        m = gamma * delta * max(
            sum(2 * B * (s.x + s.y) + 2 * s.w for s in stats) / p, 1.0)
        return Projection("pipeline", p, 1, p, comp, 0, 0, 0, p2p, m,
                          feasible, f"p <= G ({G})", iters)

    if strategy in ("filter", "channel"):
        lim = min((s.F if strategy == "filter" else s.C)
                  for s in stats if s.kind in ("conv", "fc", "attn", "ffn",
                                               "moe", "ssm", "rec"))
        feasible = p <= lim
        comp = D / p * (FW + BW) + iters * WU / p
        fb = 3 * iters * sum(
            (p - 1) * (lvl_model.alpha + B * s.y * delta / p * lvl_model.beta)
            for s in stats[:-1])
        return Projection(strategy, p, 1, p, comp, 0, fb, 0, 0,
                          mem(w_div=p), feasible,
                          f"p <= min {'F' if strategy == 'filter' else 'C'}_l "
                          f"({lim})", iters)

    if strategy == "df":
        p1 = p1 or max(p // 16, 1)
        p2 = p2 or p // p1
        lim = min(s.F for s in stats if s.kind in ("conv", "fc", "attn", "ffn",
                                                   "moe", "ssm", "rec"))
        feasible = p1 * p2 == p and p2 <= lim and p1 <= B
        comp = D / p * (FW + BW) + iters * WU / p2
        if cfg.zero1:
            comp = D / p * (FW + BW) + iters * WU / p
        fb = 3 * iters * sum(
            (p2 - 1) * (lvl_model.alpha + B * s.y * delta / p * lvl_model.beta)
            for s in stats[:-1])
        ge = iters * lvl_data.allreduce(p1, Wbytes / p2, phi=cfg.phi_hybrid)
        return Projection("df", p, p1, p2, comp, ge, fb, 0, 0,
                          mem(act_div=p1, w_div=p2, dp=p1),
                          feasible, f"p = p1·p2 <= B·min F ({B}·{lim})", iters)

    if strategy == "ds":
        p1 = p1 or max(p // 16, 1)
        p2 = p2 or p // p1
        sp_min = min((s.spatial for s in stats
                      if s.kind in ("conv", "attn") and s.spatial > 1),
                     default=1)
        feasible = p1 * p2 == p and p2 <= sp_min and p1 <= B and \
            not any(s.seq_recurrent for s in stats)
        comp = D / p * (FW + BW) + iters * WU
        if cfg.zero1:
            comp = D / p * (FW + BW) + iters * WU / p
        halo = iters * sum(
            2 * (2 * lvl_model.alpha
                 + 2 * (B / p1) * s.halo * delta * lvl_model.beta)
            for s in stats if s.halo)
        ge = iters * lvl_data.allreduce(p, Wbytes, phi=cfg.phi_hybrid)
        return Projection("ds", p, p1, p2, comp, ge, 0, halo, 0,
                          mem(act_div=p, dp=p1), feasible,
                          f"p2 <= min spatial ({sp_min}); recurrent-seq blocks",
                          iters)

    if strategy == "ep":  # beyond-paper: expert parallelism for MoE
        p1 = p1 or max(p // 16, 1)
        p2 = p2 or p // p1
        moe_stats = [s for s in stats if s.kind == "moe"]
        if not moe_stats:
            return Projection("ep", p, p1, p2, 0, 0, 0, 0, 0, 0, False,
                              "no MoE layers", iters)
        lim = min(s.F for s in moe_stats)  # experts
        feasible = p2 <= lim and p1 <= B
        comp = D / p * (FW + BW) + iters * WU / p
        # two all-to-alls per MoE layer per direction (dispatch + combine)
        fb = 4 * iters * sum(
            lvl_model.alltoall(p2, B * s.y * delta / p1)
            for s in moe_stats)
        ge = iters * lvl_data.allreduce(p1, Wbytes / p2, phi=cfg.phi_hybrid)
        return Projection("ep", p, p1, p2, comp, ge, fb, 0, 0,
                          mem(act_div=p1, w_div=p2, dp=p1),
                          feasible, f"p2 <= n_experts ({lim})", iters)

    raise ValueError(strategy)


def project_all(stats, tm: TimeModel, cfg: OracleConfig, p: int,
                strategies=STRATEGY_NAMES) -> list[Projection]:
    out = []
    for s in strategies:
        if s == "serial" and p != 1:
            continue
        try:
            out.append(project(s, stats, tm, cfg, p))
        except ValueError:
            pass
    return out
