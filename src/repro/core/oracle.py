"""ParaDL oracle: the paper's Table-3 analytical model, generalized.

Projects per-epoch (and per-iteration) computation time, communication time
and per-PE memory for each parallel strategy, from per-layer stats
(layer_stats.py) + a system model (hardware.py). Every formula carries its
paper provenance; rows marked *beyond-paper* extend the taxonomy (ZeRO,
expert parallelism, sequence-parallel residual streams) with the same α–β
methodology.

Compute times FW_l/BW_l/WU_l come from either
  * projection mode — FLOPs / (peak × efficiency)   (TPU projections), or
  * calibrated mode — a measured per-layer table     (paper §4.4; used by the
    Fig-3 reproduction on host devices).

Structure (see DESIGN.md §1–§2): per-layer quantities are precomputed ONCE
into a dense ``StatTable`` (numpy arrays + the scalar reductions every
Table-3 row consumes), and the Table-3 math itself lives in a single
broadcast-capable evaluator ``_eval``. The per-point ``project()`` below is
a thin wrapper over ``_eval`` at one (strategy, p, p1, p2); the vectorized
sweep engine (sweep.py) calls the SAME evaluator with whole lattices of
points, so scalar and vectorized results agree to machine precision.
"""
from __future__ import annotations

import weakref
from dataclasses import dataclass, field

import numpy as np

from .hardware import SystemModel
from .layer_stats import LayerStat
from .partition import cut_values, min_max_partition, stage_sums

STRATEGY_NAMES = ("serial", "data", "spatial", "pipeline", "filter", "channel",
                  "df", "ds", "ep", "summa")

# the pipeline strategy's schedule axis — must match the executor registry
# (parallel/schedules/runtime.SCHEDULE_NAMES; pinned by a unit test)
PIPELINE_SCHEDULES = ("gpipe", "one_f_one_b", "interleaved")

# layer kinds that expose a filter/channel split dimension (paper Table 2)
SPLIT_KINDS = ("conv", "fc", "attn", "ffn", "moe", "ssm", "rec")

# Default per-interconnect overlap efficiencies σ ∈ [0, 1] (DESIGN.md §10).
# The paper — like its Table 3 — charges every comm term serially; the
# executor does not: the halo exchange runs under the interior convolution
# (parallel/halo.py, after Dryden et al. — near-full hiding, σ ≈ 0.9) and
# the gradient allreduce pipelines under backward compute (σ ≈ 0.8, the
# standard DP bucketing overlap). ``OracleConfig.overlap=False`` restores
# the paper's serial accounting exactly (σ ≡ 0).
SIGMA_DEFAULTS = {"model": 0.9, "data": 0.8}


@dataclass(frozen=True)
class TimeModel:
    """Source of FW/BW/WU times (paper §4.4 empirical parametrization)."""

    system: SystemModel
    calibrated: dict | None = None     # name -> (fw_s, bw_s, wu_s) per sample
    wu_bytes_per_param: float = 16.0   # adam: m+v+p fp32 read/write amortized

    def fw(self, st: LayerStat) -> float:
        if self.calibrated and st.name in self.calibrated:
            return self.calibrated[st.name][0]
        return self.system.flops_time(st.flops_fwd)

    def bw(self, st: LayerStat) -> float:
        if self.calibrated and st.name in self.calibrated:
            return self.calibrated[st.name][1]
        return self.system.flops_time(st.flops_bwd)

    def wu(self, st: LayerStat) -> float:
        if self.calibrated and st.name in self.calibrated:
            return self.calibrated[st.name][2]
        return st.w * self.wu_bytes_per_param / self.system.hbm_bw


@dataclass
class Projection:
    """Oracle output for one (strategy, p) point. Times are PER EPOCH;
    ``per_iteration()`` divides by D/B."""

    strategy: str
    p: int
    p1: int                      # data-parallel groups (hybrids)
    p2: int                      # model-parallel width (hybrids)
    comp_s: float
    comm_ge_s: float             # gradient exchange (paper GE)
    comm_fb_s: float             # layer-wise collectives in FB (filter/channel)
    comm_halo_s: float           # spatial halo exchange
    comm_p2p_s: float            # pipeline stage-boundary traffic
    mem_bytes: float
    feasible: bool
    limit: str
    iterations: float
    phases: dict = field(default_factory=dict)
    p2r: int = 1                 # model-grid rows (summa; p2 = p2r·p2c)
    p2c: int = 1                 # model-grid cols (summa)

    @property
    def comm_s(self) -> float:
        return self.comm_ge_s + self.comm_fb_s + self.comm_halo_s + self.comm_p2p_s

    @property
    def total_s(self) -> float:
        return self.comp_s + self.comm_s

    def per_iteration(self) -> dict:
        it = max(self.iterations, 1.0)
        return {"comp_s": self.comp_s / it, "comm_s": self.comm_s / it,
                "total_s": self.total_s / it}


@dataclass(frozen=True)
class OracleConfig:
    B: int                        # global mini-batch (weak scaling: B = b·p)
    D: int                        # dataset samples per epoch
    delta: float = 2.0            # bytes per element (bf16)
    gamma: float = 0.6            # memory reuse factor (paper §4.2, [20,28])
    phi_hybrid: float = 2.0       # contention coefficient for df (paper §5.2)
    # optional per-interconnect φ table {"data": φ, "model": φ} (dict or
    # tuple of pairs) — calibrated values override the defaults: the hybrid
    # gradient exchange ("data") defaults to phi_hybrid, the model-level
    # FB/halo/P2P terms to 1.0. No term crosses the pod/DCI hop separately
    # yet, so a "pod" entry has nothing to scale (the CLI rejects it).
    phi_levels: "dict | tuple | None" = None
    # comm/compute overlap model (DESIGN.md §10). ``overlap=False``
    # reproduces the paper's serial accounting bit-for-bit; with it on, the
    # halo P2P hides under the halo layers' interior compute (model level)
    # and the gradient exchange under backward compute (data level), each
    # discounted by a per-interconnect efficiency σ — SIGMA_DEFAULTS unless
    # a calibrated ``sigma_levels`` table overrides them. FB collectives
    # (filter/channel allgathers) and pipeline stage P2P stay serial: their
    # consumers data-depend on the transfer.
    overlap: bool = True
    sigma_levels: "dict | tuple | None" = None
    segments: int = 8             # pipeline micro-batch segments S
    # pipeline schedule axis (DESIGN.md §4): which clocking the executor
    # runs — "gpipe" (fill/drain, S microbatches of activations live),
    # "one_f_one_b" (same clock, ≤p in flight) or "interleaved" (v virtual
    # stages per rank: bubble shrinks v×, stage-boundary traffic grows v×).
    schedule: str = "gpipe"
    virtual_stages: int = 2       # interleaved v (ignored by other schedules)
    zero1: bool = False           # shard WU across DP ranks ([52], §5.3.3)
    # beyond-paper memory-model extensions (DESIGN.md §3):
    remat: bool = False           # activation checkpointing: keep |x_l| only
    zero3: bool = False           # params sharded over DP too (ZeRO-3 / [38])
    seq_parallel: bool = False    # residual stream sharded over model axis
    opt_bytes_per_param: float = 8.0  # adam m+v fp32

    def phi_for(self, level: str, default: float = 1.0) -> float:
        """Contention coefficient for one interconnect level. With no
        ``phi_levels`` table the caller's default applies (phi_hybrid for
        the hybrid gradient exchange, 1.0 elsewhere) — current behavior."""
        t = self.phi_levels
        if t is None:
            return default
        items = t.items() if isinstance(t, dict) else t
        for k, v in items:
            if k == level:
                return float(v)
        return default

    def sigma_for(self, level: str) -> float:
        """Overlap efficiency for one interconnect level; 0 (fully serial,
        the paper's model) when ``overlap`` is off. Clamped to [0, 1]."""
        if not self.overlap:
            return 0.0
        t = self.sigma_levels
        if t is not None:
            items = t.items() if isinstance(t, dict) else t
            for k, v in items:
                if k == level:
                    return min(max(float(v), 0.0), 1.0)
        return SIGMA_DEFAULTS.get(level, 0.0)


# ---------------------------------------------------------------------------
# Precomputed per-layer tables (shared by project() and the sweep engine)
# ---------------------------------------------------------------------------

@dataclass(frozen=True, eq=False)
class StatTable:
    """Dense per-layer arrays + the scalar reductions the Table-3 formulas
    consume. Built once per (stats, TimeModel) pair; every quantity here is
    independent of (strategy, p, p1, p2, B)."""

    n: int                       # layer count G
    fw: np.ndarray               # per-layer forward seconds per sample
    bw: np.ndarray
    wu: np.ndarray               # per-layer weight-update seconds per iter
    x: np.ndarray                # |x_l| elements per sample
    y: np.ndarray
    w: np.ndarray                # |w_l| elements
    # scalar reductions
    FW: float
    BW: float
    WU: float
    W: float                     # total weight elements
    x_sum: float
    xy_sum: float                # Σ(|x_l| + |y_l|)
    y_head_sum: float            # Σ_{l < G-1} |y_l| (FB collectives skip last)
    y_max: float                 # pipeline stage-boundary bound
    n_halo: int
    halo_sum: float
    halo_fw_bw: float            # Σ_{l: halo>0} (fw_l + bw_l) — the interior
                                 # compute a spatial halo exchange hides under
    sp_min: int                  # min spatial extent over conv/attn layers
    any_recurrent: bool
    minF: int | None             # over SPLIT_KINDS layers; None = no such layer
    minC: int | None
    n_moe: int
    moe_y_sum: float
    moe_minF: int | None         # experts bound for ep


_TABLES: dict = {}


def _tm_key(tm: TimeModel):
    cal = tuple(sorted(tm.calibrated.items())) if tm.calibrated else None
    return (tm.system, tm.wu_bytes_per_param, cal)


def precompute(stats: list[LayerStat], tm: TimeModel) -> StatTable:
    """Memoized dense-array build; key is pure content (stats are frozen)."""
    key = (tuple(stats), _tm_key(tm))
    tbl = _TABLES.get(key)
    if tbl is None:
        if len(_TABLES) > 64:
            _TABLES.clear()
        tbl = _build_table(stats, tm)
        _TABLES[key] = tbl
    return tbl


def _build_table(stats, tm: TimeModel) -> StatTable:
    fw = np.array([tm.fw(s) for s in stats], np.float64)
    bw = np.array([tm.bw(s) for s in stats], np.float64)
    wu = np.array([tm.wu(s) for s in stats], np.float64)
    x = np.array([s.x for s in stats], np.float64)
    y = np.array([s.y for s in stats], np.float64)
    w = np.array([s.w for s in stats], np.float64)
    halo = np.array([s.halo for s in stats], np.float64)
    F = np.array([s.F for s in stats], np.int64)
    C = np.array([s.C for s in stats], np.int64)
    spatial = np.array([s.spatial for s in stats], np.int64)
    split = np.array([s.kind in SPLIT_KINDS for s in stats], bool)
    conv_attn = np.array([s.kind in ("conv", "attn") for s in stats], bool)
    moe = np.array([s.kind == "moe" for s in stats], bool)
    rec = np.array([s.seq_recurrent for s in stats], bool)
    hm = halo > 0
    sp_cand = spatial[conv_attn & (spatial > 1)]
    return StatTable(
        n=len(stats), fw=fw, bw=bw, wu=wu, x=x, y=y, w=w,
        FW=float(np.sum(fw)), BW=float(np.sum(bw)), WU=float(np.sum(wu)),
        W=float(np.sum(w)), x_sum=float(np.sum(x)),
        xy_sum=float(np.sum(x + y)), y_head_sum=float(np.sum(y[:-1])),
        y_max=float(y.max()) if len(y) else 0.0,
        n_halo=int(hm.sum()), halo_sum=float(halo[hm].sum()),
        halo_fw_bw=float((fw[hm] + bw[hm]).sum()),
        sp_min=int(sp_cand.min()) if sp_cand.size else 1,
        any_recurrent=bool(rec.any()),
        minF=int(F[split].min()) if split.any() else None,
        minC=int(C[split].min()) if split.any() else None,
        n_moe=int(moe.sum()), moe_y_sum=float(y[moe].sum()),
        moe_minF=int(F[moe].min()) if moe.any() else None)


# ---------------------------------------------------------------------------
# Pipeline stage partitions (non-uniform stages; paper §5.3.3 caveat closed)
# ---------------------------------------------------------------------------

# StatTable → {k: (max ΣFW, max ΣBW, max ΣWU, max cut |y|, max Σ(x+y), max Σw)}
_STAGE_TERMS: "weakref.WeakKeyDictionary" = weakref.WeakKeyDictionary()


def pipeline_stage_terms(T: StatTable, k: int) -> tuple:
    """Bottleneck-stage quantities for the optimal contiguous partition of
    T's layers into k stages (DP over per-layer fw+bw, core/partition.py).

    Returns (max stage ΣFW, max stage ΣBW, max stage ΣWU, max boundary |y|,
    max stage Σ(|x|+|y|), max stage Σ|w|). The cut minimizes the fw+bw
    bottleneck — the schedule's pacing term; memory/WU maxima are reported
    at those same cuts (one partition deploys, so one partition is modeled).
    """
    k = int(min(max(k, 1), T.n))
    cache = _STAGE_TERMS.setdefault(T, {})
    hit = cache.get(k)
    if hit is None:
        part = min_max_partition(T.fw + T.bw, k)
        b = part.bounds
        cuts = cut_values(T.y, b)
        hit = (float(stage_sums(T.fw, b).max()),
               float(stage_sums(T.bw, b).max()),
               float(stage_sums(T.wu, b).max()),
               float(cuts.max()) if cuts.size else 0.0,
               float(stage_sums(T.x + T.y, b).max()),
               float(stage_sums(T.w, b).max()))
        cache[k] = hit
    return hit


def _pipeline_terms_bcast(T: StatTable, p, shape) -> tuple:
    """``pipeline_stage_terms`` over a (possibly scalar) lattice of p values,
    broadcast to ``shape``; p is clamped into [1, G] (points outside are
    scale-infeasible anyway, but every lattice row needs defined numbers)."""
    pk = np.clip(np.broadcast_to(np.asarray(p, np.int64), shape), 1, T.n)
    terms = np.array([pipeline_stage_terms(T, int(v)) for v in np.ravel(pk)],
                     np.float64)
    return tuple(terms[:, j].reshape(shape) for j in range(terms.shape[1]))


# ---------------------------------------------------------------------------
# The Table-3 math, once, broadcast-capable
# ---------------------------------------------------------------------------

def _balanced_divisor(n: int) -> int:
    """Largest divisor of n that is ≤ √n — the row extent of the most
    balanced (r, c) grid with r·c = n and r ≤ c."""
    n = int(n)
    r = 1
    for d in range(1, int(n ** 0.5) + 1):
        if n % d == 0:
            r = d
    return r


def _summa_row_level(sysm: SystemModel):
    """Interconnect level pricing the SUMMA row dimension (weight-panel
    ring). A machine description may expose a distinct second model hop as
    a "model2" level (ClusterSpec 2D grids); absent that, both grid dims
    ride the model interconnect. NOTE: ``SystemModel.level`` falls back to
    the LAST (slowest) level for unknown names, so the scan here must be
    explicit — a blind ``level("model2")`` would price the row ring at
    pod/DCI speed."""
    for name, lvl in sysm.levels:
        if name == "model2":
            return lvl
    return sysm.level("model")


def _eval_row(T: StatTable, strategy: str, cfg: OracleConfig,
              sysm: SystemModel, p, p1, p2, p2_eff, B,
              p2r=None, p2c=None) -> dict:
    """Evaluate one strategy's Table-3 row at (p, p1, p2, B).

    Every argument may be a python scalar (per-point ``project()``) or a
    numpy array of lattice points (sweep engine); all arithmetic broadcasts.
    ``p2r``/``p2c`` factor the model width into a (row × col) grid — only
    the "summa" row reads them. Returns per-epoch seconds/bytes arrays:
    comp, ge, fb, halo, p2p, mem, feasible, iters.
    """
    delta, gamma = cfg.delta, cfg.gamma
    D = cfg.D
    p = np.asarray(p, np.float64)
    p1 = np.asarray(p1, np.float64)
    p2 = np.asarray(p2, np.float64)
    p2_eff = np.asarray(p2_eff, np.float64)
    B = np.asarray(B, np.float64)
    shape = np.broadcast(p, p1, p2, B).shape
    zeros = np.zeros(shape)
    iters = D / B
    lvl_model = sysm.level("model")
    lvl_data = sysm.level("data")
    FW, BW, WU = T.FW, T.BW, T.WU
    Wbytes = T.W * delta

    def mem(act_div=1.0, w_div=1.0, dp=1.0):
        """Per-PE memory. Paper Table-3 expression, extended with remat/
        ZeRO-3/seq-parallel switches and optimizer state (DESIGN.md §3)."""
        act = B * (T.x_sum if cfg.remat else 2.0 * T.xy_sum) / act_div
        if cfg.seq_parallel:
            act = np.where(p2_eff > 1, act / p2_eff, act)
        wdiv = w_div * (dp if cfg.zero3 else 1.0)
        wmem = 2.0 * T.W / wdiv * delta              # params + grads
        opt = T.W * cfg.opt_bytes_per_param / (
            w_div * (dp if (cfg.zero1 or cfg.zero3) else 1.0))
        return gamma * delta * act + wmem + opt

    # per-level contention: the hybrid gradient exchange defaults to the
    # paper's φ constant, model-level collectives to 1.0; a calibrated
    # cfg.phi_levels table overrides either (ROADMAP φ-calibration item)
    phi_ge = cfg.phi_for("data", cfg.phi_hybrid)
    phi_m = cfg.phi_for("model", 1.0)

    # comm/compute overlap (DESIGN.md §10): a comm term T with a concurrent
    # compute window W is charged at its EXPOSED cost T − σ·min(W, T), i.e.
    # the step pays max(W', φT) + (1−σ)·min(W', φT) instead of W' + φT over
    # the window. σ = 0 (overlap off) restores the paper's serial sum
    # exactly. The halo exchange hides under the halo layers' interior
    # fw+bw (the overlapped executor, parallel/halo.py); the gradient
    # exchange hides under backward compute (DP bucketing).
    sig_m = cfg.sigma_for("model")
    sig_d = cfg.sigma_for("data")

    def exposed(comm, window, sigma):
        return comm - sigma * np.minimum(window, comm)

    def halo_and_ge(halo_full, ge_full, bw_epoch):
        """Exposed (halo, ge) for the spatial strategies. The halo hides
        under the halo layers' fw+bw; the gradient exchange under backward
        compute — but the halo layers' bw is a subset of BW, so the GE
        window must exclude the compute seconds the halo already consumed
        (one second of backward hides one second of comm, once)."""
        win_halo = D / p * T.halo_fw_bw
        halo_hidden = sig_m * np.minimum(win_halo, halo_full)
        win_ge = np.maximum(bw_epoch - halo_hidden, 0.0)
        return halo_full - halo_hidden, exposed(ge_full, win_ge, sig_d)

    def halo_term(batch):
        # Σ_{l: halo>0} 2·(2α + 2·batch·halo_l·δ·β·φ), closed form
        return iters * (4.0 * lvl_model.alpha * T.n_halo
                        + 4.0 * batch * delta * lvl_model.beta * phi_m
                        * T.halo_sum)

    def fb_term(width):
        # Σ_{l < G-1} 3·(width−1)·(α + B·y_l·δ/p·β·φ), closed form
        return 3.0 * iters * (width - 1) * (
            lvl_model.alpha * (T.n - 1)
            + B * delta * lvl_model.beta * phi_m / p * T.y_head_sum)

    out = dict(comp=zeros, ge=zeros, fb=zeros, halo=zeros, p2p=zeros,
               mem=zeros, feasible=np.ones(shape, bool), iters=iters + zeros)

    if strategy == "serial":
        out["comp"] = (D * (FW + BW) + iters * WU) + zeros
        out["mem"] = mem() + zeros
        return out

    if strategy == "data":
        out["feasible"] = p <= B
        out["comp"] = D / p * (FW + BW) + iters * (WU / p if cfg.zero1 else WU)
        out["ge"] = exposed(iters * lvl_data.allreduce_v(p, Wbytes),
                            D / p * BW, sig_d)
        out["mem"] = mem(act_div=p, dp=p) + zeros
        return out

    if strategy == "spatial":
        out["feasible"] = (p <= T.sp_min) & (not T.any_recurrent)
        out["comp"] = D / p * (FW + BW) + iters * WU
        out["halo"], out["ge"] = halo_and_ge(
            halo_term(B), iters * lvl_data.allreduce_v(p, Wbytes),
            D / p * BW)
        out["mem"] = mem(act_div=p) + zeros
        return out

    if strategy == "pipeline":
        S = cfg.segments
        sched = cfg.schedule
        # non-uniform stages: the DP partitioner (core/partition.py) cuts
        # layers minimizing the bottleneck stage, and the schedule is paced
        # by max FW_Gi + max BW_Gi — not the balanced total/p the paper's
        # §5.3.3 caveat assumed. Boundary traffic uses the activation sizes
        # at the ACTUAL cut points, not the global max layer output.
        mfw, mbw, mwu, ycut, mxy, mw = _pipeline_terms_bcast(T, p, shape)
        if sched in ("gpipe", "one_f_one_b"):
            # identical clock (1F1B's forward schedule IS GPipe's; its
            # backward reordering changes memory, not the critical path):
            # (p+S−1) stage-ticks of the bottleneck stage, bubble (p−1)/S
            out["feasible"] = p <= T.n
            out["comp"] = D * (p + S - 1) / S * (mfw + mbw) + iters * mwu
            out["p2p"] = np.where(p > 1, 2 * D * (p + S - 2) / B * (
                lvl_model.alpha
                + B / S * ycut * delta * lvl_model.beta * phi_m), 0.0)
            # activation residency: GPipe holds all S microbatches'
            # activations between forward and backward; 1F1B's steady state
            # holds at most p (min(p/S, 1) of the batch's worth)
            act = (1.0 if sched == "gpipe"
                   else np.minimum(p / np.maximum(S, 1.0), 1.0))
            out["mem"] = gamma * delta * np.maximum(
                2.0 * B * act * mxy + 2.0 * mw, 1.0)
            return out
        if sched == "interleaved":
            v = max(int(cfg.virtual_stages), 1)
            out["feasible"] = v * p <= T.n
            # v·p chunks round-robin over p ranks: v·S + p − 1 chunk-ticks
            # at the bottleneck CHUNK cost (the v·p-way partition maxima) —
            # the fill/drain bubble shrinks to (p−1)/(v·S). Weight update
            # stays per-rank: a rank owns v chunks ≈ its p-cut stage's
            # layers, so mwu (the p-way partition max) is the right charge.
            cfw, cbw, _cwu, cycut, _cxy, _cw = _pipeline_terms_bcast(
                T, v * p, shape)
            out["comp"] = (D * (v * S + p - 1) / S * (cfw + cbw)
                           + iters * mwu)
            # v× the boundary hops, each shipping the cut activation of the
            # FINER v·p-way partition
            out["p2p"] = np.where(p > 1, 2 * D * (v * S + p - 2) / B * (
                lvl_model.alpha
                + B / S * cycut * delta * lvl_model.beta * phi_m), 0.0)
            # steady-state in-flight microbatches: p + v − 1 (each rank
            # holds one microbatch per virtual slot as groups overlap);
            # weights are the rank's full p-cut share (all v chunks)
            act = np.minimum((p + v - 1.0) / np.maximum(S, 1.0), 1.0)
            out["mem"] = gamma * delta * np.maximum(
                2.0 * B * act * mxy + 2.0 * mw, 1.0)
            return out
        raise ValueError(f"unknown pipeline schedule {sched!r}")

    if strategy in ("filter", "channel"):
        lim = T.minF if strategy == "filter" else T.minC
        if lim is None:
            raise ValueError(f"{strategy}: no splittable layers")
        out["feasible"] = p <= lim
        out["comp"] = D / p * (FW + BW) + iters * WU / p
        out["fb"] = fb_term(p)
        out["mem"] = mem(w_div=p) + zeros
        return out

    if strategy == "df":
        if T.minF is None:
            raise ValueError("df: no splittable layers")
        out["feasible"] = (p1 * p2 == p) & (p2 <= T.minF) & (p1 <= B)
        out["comp"] = D / p * (FW + BW) + iters * (
            WU / p if cfg.zero1 else WU / p2)
        out["fb"] = fb_term(p2)
        out["ge"] = exposed(
            iters * lvl_data.allreduce_v(p1, Wbytes / p2, phi=phi_ge),
            D / p * BW, sig_d)
        out["mem"] = mem(act_div=p1, w_div=p2, dp=p1) + zeros
        return out

    if strategy == "ds":
        out["feasible"] = ((p1 * p2 == p) & (p2 <= T.sp_min) & (p1 <= B)
                           & (not T.any_recurrent))
        out["comp"] = D / p * (FW + BW) + iters * (
            WU / p if cfg.zero1 else WU)
        out["halo"], out["ge"] = halo_and_ge(
            halo_term(B / p1),
            iters * lvl_data.allreduce_v(p, Wbytes, phi=phi_ge),
            D / p * BW)
        out["mem"] = mem(act_div=p, dp=p1) + zeros
        return out

    if strategy == "ep":  # beyond-paper: expert parallelism for MoE
        if T.n_moe == 0:
            out["feasible"] = np.zeros(shape, bool)
            return out
        out["feasible"] = (p2 <= T.moe_minF) & (p1 <= B)
        out["comp"] = D / p * (FW + BW) + iters * WU / p
        # two all-to-alls per MoE layer per direction (dispatch + combine):
        # Σ_moe 4·alltoall(p2, B·y_l·δ/p1), closed form
        out["fb"] = np.where(p2 > 1, 4.0 * iters * (p2 - 1) * (
            lvl_model.alpha * T.n_moe
            + B * delta * lvl_model.beta / (p1 * p2) * T.moe_y_sum), 0.0)
        out["ge"] = exposed(
            iters * lvl_data.allreduce_v(p1, Wbytes / p2, phi=phi_ge),
            D / p * BW, sig_d)
        out["mem"] = mem(act_div=p1, w_div=p2, dp=p1) + zeros
        return out

    if strategy == "summa":  # beyond-paper: 2D (row × col) tensor grid
        if T.minF is None or T.minC is None:
            raise ValueError("summa: no splittable layers")
        r = np.asarray(1 if p2r is None else p2r, np.float64)
        c = np.asarray(1 if p2c is None else p2c, np.float64)
        out["feasible"] = ((p1 * p2 == p) & (r * c == p2)
                           & (c <= T.minF) & (r <= T.minC) & (p1 <= B))
        out["comp"] = D / p * (FW + BW) + iters * (
            WU / p if cfg.zero1 else WU / p2)
        # SUMMA per layer (parallel/summa.py): fw allgathers the activation
        # blocks along the COLUMN ring ((c−1) steps of B·y_l·δ/p each) and
        # circulates the weight panels along the ROW ring ((r−1) steps of
        # w_l·δ/p2 each); backward replays both for dgrad and wgrad — 3
        # passes total, the same 3× as the paper's filter/channel row. At
        # r = 1 this degenerates bit-for-bit to fb_term(p2) plus a zero row
        # term, i.e. the 1D filter split it contains.
        lvl_row = _summa_row_level(sysm)
        act = (lvl_model.alpha * (T.n - 1)
               + B * delta * lvl_model.beta * phi_m / p * T.y_head_sum)
        wgt = (lvl_row.alpha * (T.n - 1)
               + delta * lvl_row.beta * phi_m / np.maximum(p2, 1.0) * T.W)
        out["fb"] = 3.0 * iters * ((c - 1.0) * act + (r - 1.0) * wgt)
        out["ge"] = exposed(
            iters * lvl_data.allreduce_v(p1, Wbytes / p2, phi=phi_ge),
            D / p * BW, sig_d)
        # activations: batch over p1, sequence over r; the column shard of
        # the hidden dim is transient (the fw allgather rematerializes the
        # full hidden block), so the resident residual divides by p1·r only
        # — the seq_parallel switch (p2_eff = c) claims the rest.
        out["mem"] = mem(act_div=p1 * r, w_div=p2, dp=p1) + zeros
        return out

    raise ValueError(strategy)


def _eval(T: StatTable, strategy: str, cfg: OracleConfig, sysm: SystemModel,
          p, p1, p2, p2_eff, B, p2r=None, p2c=None) -> dict:
    """``_eval_row`` plus the cross-cutting sequence-parallel communication
    term (DESIGN.md §14).

    ``seq_parallel`` shards the residual stream over the model width
    p2_eff; that is not free: each sharded block allgathers the residual
    before consuming it and reduce-scatters it back after producing it
    (Korthikanti et al. — the collectives replace, not join, the identity
    pass-through). Per layer l (< G, the head keeps its own collective)
    that is one allgather + one reduce-scatter in forward and the mirrored
    pair in backward — 4 ring collectives of B·y_l·δ/p per step:

        4 · iters · (p2_eff−1) · (α_m·(G−1) + B·δ·β_m·φ_m/p · Σ y_l)

    overlap-discounted by σ(model) against the forward-compute window
    (the gather streams ahead of each block; backward's window is already
    claimed by the gradient exchange). With an ideal interconnect
    (α→0, bandwidth→∞ i.e. β→0) the term vanishes and the old memory-only
    switch behavior is recovered exactly (test_oracle_properties.py).
    """
    out = _eval_row(T, strategy, cfg, sysm, p, p1, p2, p2_eff, B,
                    p2r=p2r, p2c=p2c)
    # serial has no model axis; pipeline's projection is memory-switch-
    # invariant by design (its stage memory model ignores the switches, and
    # the executor deploys none — autotune.deployable_switch_mask)
    if not cfg.seq_parallel or strategy in ("serial", "pipeline"):
        return out
    p_ = np.asarray(p, np.float64)
    pe = np.asarray(p2_eff, np.float64)
    B_ = np.asarray(B, np.float64)
    iters = out["iters"]
    lvl_model = sysm.level("model")
    phi_m = cfg.phi_for("model", 1.0)
    full = np.where(pe > 1, 4.0 * iters * (pe - 1.0) * (
        lvl_model.alpha * (T.n - 1)
        + B_ * cfg.delta * lvl_model.beta * phi_m / p_ * T.y_head_sum), 0.0)
    window = cfg.D / p_ * T.FW
    sig_m = cfg.sigma_for("model")
    out["fb"] = out["fb"] + full - sig_m * np.minimum(window, full)
    return out


def _limit_str(strategy: str, T: StatTable, B, feasible: bool,
               cfg: "OracleConfig | None" = None) -> str:
    """Human-readable scaling-limit description (mirrors the paper's notes)."""
    if strategy == "serial":
        return "p = 1"
    if strategy == "data":
        return "p <= B" + ("" if feasible else f" violated (B={B})")
    if strategy == "spatial":
        return (f"p <= min spatial ({T.sp_min})"
                + ("" if feasible else " or recurrent-seq violated"))
    if strategy == "pipeline":
        if cfg is not None and cfg.schedule == "interleaved":
            return f"v*p <= G ({T.n}), v={max(int(cfg.virtual_stages), 1)}"
        return f"p <= G ({T.n})"
    if strategy in ("filter", "channel"):
        lim = T.minF if strategy == "filter" else T.minC
        return (f"p <= min {'F' if strategy == 'filter' else 'C'}_l ({lim})")
    if strategy == "df":
        return f"p = p1·p2 <= B·min F ({B}·{T.minF})"
    if strategy == "ds":
        return f"p2 <= min spatial ({T.sp_min}); recurrent-seq blocks"
    if strategy == "ep":
        return ("no MoE layers" if T.n_moe == 0
                else f"p2 <= n_experts ({T.moe_minF})")
    if strategy == "summa":
        return (f"p2 = p2r·p2c, p2r <= min C_l ({T.minC}), "
                f"p2c <= min F_l ({T.minF})")
    return ""


def project(strategy: str, stats: list[LayerStat], tm: TimeModel,
            cfg: OracleConfig, p: int, p1: int | None = None,
            p2: int | None = None, p2r: int | None = None,
            p2c: int | None = None) -> Projection:
    """One Table-3 row evaluated at p PEs (thin wrapper over ``_eval``).

    For "summa" the model width additionally factors into a (p2r × p2c)
    grid; unspecified grid dims default to the most balanced factorization
    of p2 (r ≤ c — columns shard the wider hidden/filter dimension)."""
    T = precompute(stats, tm)
    # p2_eff is derived from the CALLER's p2 (before hybrid defaulting), as
    # the seq-parallel memory switch keys on an explicitly requested width.
    p2_eff = p2 or (p if strategy in ("filter", "channel", "spatial") else 1)
    if strategy in ("df", "ds", "ep", "summa"):
        p1 = p1 or max(p // 16, 1)
        p2 = p2 or p // p1
    if strategy == "summa":
        p2r = p2r or (p2 // p2c if p2c else _balanced_divisor(p2))
        p2c = p2c or p2 // p2r
        # the residual stream a seq-parallel switch would shard lives on
        # the COLUMN ring (the row dim already shards the sequence)
        p2_eff = p2c
    if strategy == "serial":
        p, rp1, rp2 = 1, 1, 1
    elif strategy == "data":
        rp1, rp2 = p, 1
    elif strategy in ("spatial", "pipeline", "filter", "channel"):
        rp1, rp2 = 1, p
    else:
        rp1, rp2 = p1, p2
    r = _eval(T, strategy, cfg, tm.system, p, p1 or 1, p2 or 1, p2_eff,
              cfg.B, p2r=p2r, p2c=p2c)
    feasible = bool(r["feasible"])
    return Projection(strategy, int(p), int(rp1), int(rp2),
                      float(r["comp"]), float(r["ge"]), float(r["fb"]),
                      float(r["halo"]), float(r["p2p"]), float(r["mem"]),
                      feasible, _limit_str(strategy, T, cfg.B, feasible),
                      float(r["iters"]),
                      p2r=int(p2r or 1), p2c=int(p2c or 1))


def seq_flops_coeffs(mc, seq: int) -> "tuple[float, float]":
    """Fit per-sample forward FLOPs ≈ a·S + b·S² from two stat evaluations.

    Transformer forward cost is exactly linear-plus-quadratic in sequence
    length (attention scores are the only S² term), so two points pin the
    polynomial: evaluating the layer stats at S and S/2 gives
    b = 2(F(S) − 2·F(S/2))/S² and a = F(S)/S − b·S. The serving oracle
    (serve/oracle.py) differentiates this to price decode — the marginal
    cost of token L is a + 2bL — and integrates it for chunked prefill,
    without a per-length stats rebuild inside the sweep.
    """
    from .layer_stats import stats_for
    S = max(int(seq), 8)
    S += S % 2
    f1 = float(sum(st.flops_fwd for st in stats_for(mc, S)))
    f2 = float(sum(st.flops_fwd for st in stats_for(mc, S // 2)))
    b = 2.0 * (f1 - 2.0 * f2) / (S * S)
    a = f1 / S - b * S
    return a, b


def project_all(stats, tm: TimeModel, cfg: OracleConfig, p: int,
                strategies=STRATEGY_NAMES) -> list[Projection]:
    out = []
    for s in strategies:
        if s == "serial" and p != 1:
            continue
        try:
            out.append(project(s, stats, tm, cfg, p))
        except ValueError:
            pass
    return out
