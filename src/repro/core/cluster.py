"""ClusterSpec — one first-class machine description (DESIGN.md §11).

The paper's oracle is only as good as its machine model, yet until this
module the description was scattered across four loose objects: the α–β
``SystemModel`` (hardware.py, no topology), the φ/σ tables living on
``OracleConfig`` (oracle.py), copy-pasted ``--phi``/``--sigma`` CLI parsing,
and a calibration harness (calibration.py) whose measurements never flowed
back into projections. ``ClusterSpec`` owns all four concerns:

  * interconnect ``Level``s with Hockney α/β, keyed by mesh axis,
  * per-PE compute (peak FLOP/s, HBM bandwidth, memory capacity),
  * the physical **torus topology** — per-dimension extents plus which
    dimensions the model axis may occupy (FlexFlow-style placement
    constraint: a ring collective needs a physical ring, so the model axis
    must embed within ONE torus dimension; a pipeline chain may snake),
  * the contention φ and overlap-efficiency σ tables the oracle's terms
    consume, with ``fitted_from(measurements)`` ingesting the calibration
    harness output (core/calibration.py, benchmarks/bench_fig6_contention)
    so measured runs close the loop back into projections.

Everything here is numpy-only (no jax import) so the ``repro.api`` CLI can
set XLA_FLAGS before any device platform is initialized.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field, replace

import numpy as np

from .hardware import (Level, PAPER_V100_CLUSTER, SystemModel, TPU_V5E_POD,
                       cpu_host_model)

# interconnect levels the oracle's α–β terms consume today (the pod/DCI hop
# is modeled by roofline.py but no Table-3 term crosses it separately yet)
PHI_LEVELS = ("data", "model")


# ---------------------------------------------------------------------------
# CLI table parsing (one home; sweep/autotune re-use it via from_cli_args)
# ---------------------------------------------------------------------------

def _parse_level_table(spec, flag: str):
    """'data=2.0,model=1.2' → ((level, value), ...); None/empty → None.
    Rejects unknown level names — a typo (or a level the α–β terms do not
    yet consume, like the pod/DCI hop) must not silently change nothing."""
    if not spec:
        return None
    out = []
    for part in spec.split(","):
        lvl, _, val = part.partition("=")
        if not val:
            raise ValueError(f"{flag} entry {part!r} is not LEVEL=VALUE")
        lvl = lvl.strip()
        if lvl not in PHI_LEVELS:
            raise ValueError(f"{flag} level {lvl!r} is not consumed by the "
                             f"oracle; known levels: {PHI_LEVELS}")
        out.append((lvl, float(val)))
    return tuple(out)


def parse_phi_table(spec):
    """Contention table for OracleConfig.phi_levels (the paper's single
    phi_hybrid constant applies when absent)."""
    return _parse_level_table(spec, "--phi")


def parse_sigma_table(spec):
    """Overlap-efficiency table for OracleConfig.sigma_levels
    (oracle.SIGMA_DEFAULTS apply when absent)."""
    return _parse_level_table(spec, "--sigma")


# ---------------------------------------------------------------------------
# Topology
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Torus:
    """Physical torus/mesh: per-dimension extents + model-axis placement.

    ``model_dims`` lists the dimension indices the model axis may occupy;
    ``None`` means any single dimension, ``()`` means none (model axis
    confined to width 1 — e.g. every wired dim carries DCI-grade links).

    The embedding rule (documented, deliberately conservative):
      * the model axis runs **ring** collectives (allgather/allreduce/halo),
        so a model width p2 > 1 must embed as a ring within ONE allowed
        dimension: ∃ allowed d with dims[d] % p2 == 0. Spanning two torus
        dimensions would fold two physical rings into one logical ring,
        which the α–β model (one link per hop) does not describe.
      * a 2D model GRID (summa: p2 = p2r·p2c) embeds each grid dimension
        as a ring within its own **distinct** allowed torus dimension:
        ∃ allowed i ≠ j with dims[i] % p2r == 0 and dims[j] % p2c == 0.
        Row and column rings then never share links, so each carries its
        own α/β (ClusterSpec may price the row hop as a "model2" level).
        Degenerate grids (p2r == 1 or p2c == 1) collapse to the 1D rule.
      * the pipeline "model" axis is a **chain** (P2P only); a Hamiltonian
        path snakes across dimensions freely, so pipeline is exempt from
        the one-dimension rule.
      * the machine is tiled by identical (p1, p2) blocks, so p1·p2 must
        divide the torus size.
    """

    dims: tuple
    model_dims: tuple | None = None

    def __post_init__(self):
        object.__setattr__(self, "dims", tuple(int(d) for d in self.dims))
        if any(d < 1 for d in self.dims) or not self.dims:
            raise ValueError(f"torus extents must be >= 1: {self.dims}")
        if self.model_dims is not None:
            md = tuple(sorted(set(int(d) for d in self.model_dims)))
            if any(d < 0 or d >= len(self.dims) for d in md):
                raise ValueError(f"model_dims {md} out of range for "
                                 f"{len(self.dims)}-d torus {self.dims}")
            object.__setattr__(self, "model_dims", md)

    @property
    def size(self) -> int:
        return int(np.prod(self.dims))

    def __str__(self) -> str:
        t = "x".join(str(d) for d in self.dims)
        if self.model_dims is None:
            return f"({t})-torus"
        return f"({t})-torus[model dims {list(self.model_dims)}]"

    def model_widths(self) -> tuple:
        """Feasible model-axis ring widths: divisors of any allowed dim."""
        dims_ok = (range(len(self.dims)) if self.model_dims is None
                   else self.model_dims)
        ws = {1}
        for d in dims_ok:
            e = self.dims[d]
            ws |= {k for k in range(1, e + 1) if e % k == 0}
        return tuple(sorted(ws))

    def grid_pairs(self) -> tuple:
        """Feasible (p2r, p2c) model-grid embeddings (see the class
        docstring): each grid dim rings within its own distinct allowed
        torus dim (ordered pairs — row and column hops may differ in
        speed), plus the degenerate grids the 1D rule already admits."""
        dims_ok = tuple(range(len(self.dims)) if self.model_dims is None
                        else self.model_dims)
        pairs = {(1, 1)}
        for w in self.model_widths():
            pairs |= {(1, w), (w, 1)}
        divs = {d: tuple(k for k in range(1, self.dims[d] + 1)
                         if self.dims[d] % k == 0) for d in dims_ok}
        for i in dims_ok:
            for j in dims_ok:
                if i != j:
                    pairs |= {(r, c) for r in divs[i] for c in divs[j]}
        return tuple(sorted(pairs))

    def split_mask(self, p, p1, p2, strategy: str | None = None,
                   p2r=None, p2c=None):
        """Vectorized feasibility of (p, p1, p2) lattice points (see the
        class docstring for the embedding rule). ``strategy`` exempts
        'pipeline' (chain, not ring) from the one-dimension rule and
        checks 'summa' points against the 2D grid embeddings
        (``grid_pairs``; the (p2r, p2c) lattice columns must be passed)."""
        p = np.asarray(p, np.int64)
        p2 = np.asarray(p2, np.int64)
        fits = (p >= 1) & (self.size % np.maximum(p, 1) == 0)
        if strategy == "pipeline":
            return fits
        if strategy == "summa":
            r = np.asarray(1 if p2r is None else p2r, np.int64)
            c = np.asarray(1 if p2c is None else p2c, np.int64)
            enc = r * np.int64(2 ** 32) + c
            ok = np.array([ri * 2 ** 32 + ci for ri, ci in self.grid_pairs()],
                          np.int64)
            return fits & np.isin(enc, ok)
        ring_ok = np.isin(p2, np.asarray(self.model_widths(), np.int64))
        return fits & ring_ok

    def limit_str(self, strategy: str) -> str:
        if strategy == "pipeline":
            return f"topology: p must tile the {self} ({self.size} PEs)"
        if strategy == "summa":
            return (f"topology: model grid must embed (row, col) rings in "
                    f"two distinct dims of {self}")
        return (f"topology: model axis must ring within one dim of {self} "
                f"(widths {list(self.model_widths())})")

    def to_json(self) -> dict:
        return {"dims": list(self.dims),
                "model_dims": (None if self.model_dims is None
                               else list(self.model_dims))}

    @classmethod
    def from_json(cls, d: dict) -> "Torus":
        md = d.get("model_dims")
        return cls(tuple(d["dims"]), None if md is None else tuple(md))

    @classmethod
    def parse(cls, spec: str, model_dims: str | None = None) -> "Torus":
        """'4x2' (+ optional model-dims '0' / '0,1' / '' for none)."""
        dims = tuple(int(x) for x in spec.lower().split("x"))
        if model_dims is None:
            return cls(dims)
        md = tuple(int(x) for x in model_dims.split(",") if x.strip())
        return cls(dims, md)

    def without_slice(self, dim: int = 0, count: int = 1) -> "Torus":
        """The torus that survives losing ``count`` hyperplanes of ``dim``
        (slice death: every PE with that coordinate is gone, so the extent
        shrinks — the surviving machine is still a torus). Dimensions that
        collapse to extent 1 are dropped and the model-axis placement
        constraint is re-indexed onto the surviving dimensions; a model
        dim that vanished leaves the model axis confined to width 1."""
        if not 0 <= dim < len(self.dims):
            raise ValueError(f"torus has no dim {dim}: {self.dims}")
        extent = self.dims[dim] - count
        if extent < 1:
            raise ValueError(
                f"cannot drop {count} slice(s) from dim {dim} of {self}")
        dims = list(self.dims)
        dims[dim] = extent
        keep = ([i for i, e in enumerate(dims) if e > 1]
                or [int(np.argmax(dims))])
        remap = {old: new for new, old in enumerate(keep)}
        md = self.model_dims
        if md is not None:
            md = tuple(remap[d] for d in md if d in remap)
        return Torus(tuple(dims[i] for i in keep), md)


# ---------------------------------------------------------------------------
# Calibration measurements (what fitted_from ingests)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Measurement:
    """One calibration observation, tagged by interconnect level.

    kind = "collective": timed ring collective at several message sizes —
        fits Hockney α/β (pattern 'ar': T = 2(p−1)(α + m/p·β);
        'ag'/'rs'/'a2a': T = (p−1)(α + m/p·β)).
    kind = "contention": a saturating collective alone vs ``flows``
        concurrent copies sharing the level — fits φ = shared/alone
        (paper §4.3 self-contention; clamped into [1, flows]).
    kind = "overlap": independent compute and comm timed separately and
        fused — fits σ = (comp + comm − both) / min(comp, comm), the
        fraction of the overlap window actually hidden (DESIGN.md §10;
        clamped into [0, 1]).
    """

    level: str
    kind: str
    pattern: str = "ar"
    p: int = 0
    nbytes: tuple = ()
    seconds: tuple = ()
    alone_s: float = 0.0
    shared_s: float = 0.0
    flows: int = 2
    comp_s: float = 0.0
    comm_s: float = 0.0
    both_s: float = 0.0

    def to_json(self) -> dict:
        d = {"level": self.level, "kind": self.kind}
        if self.kind == "collective":
            d.update(pattern=self.pattern, p=self.p,
                     nbytes=list(self.nbytes), seconds=list(self.seconds))
        elif self.kind == "contention":
            d.update(alone_s=self.alone_s, shared_s=self.shared_s,
                     flows=self.flows)
        elif self.kind == "overlap":
            d.update(comp_s=self.comp_s, comm_s=self.comm_s,
                     both_s=self.both_s)
        return d

    @classmethod
    def from_json(cls, d: dict) -> "Measurement":
        d = dict(d)
        if "nbytes" in d:
            d["nbytes"] = tuple(d["nbytes"])
        if "seconds" in d:
            d["seconds"] = tuple(d["seconds"])
        return cls(**d)


def _ring_factor(pattern: str, p: int) -> float:
    return 2.0 * (p - 1) if pattern == "ar" else float(p - 1)


def _fit_alpha_beta(ms: list) -> tuple:
    """Least-squares Hockney fit over 'collective' measurements of one
    level. Returns (alpha, beta, relative rms residual)."""
    rows, ts = [], []
    for m in ms:
        f = _ring_factor(m.pattern, m.p)
        for nbytes, t in zip(m.nbytes, m.seconds):
            rows.append([f, f / m.p * nbytes])
            ts.append(t)
    A, t = np.array(rows, np.float64), np.array(ts, np.float64)
    coef, *_ = np.linalg.lstsq(A, t, rcond=None)
    alpha = float(max(coef[0], 1e-9))
    beta = float(max(coef[1], 1e-12))
    pred = A @ np.array([alpha, beta])
    resid = float(np.linalg.norm(pred - t) / max(np.linalg.norm(t), 1e-30))
    return alpha, beta, resid


# ---------------------------------------------------------------------------
# ClusterSpec
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ClusterSpec:
    """First-class machine description: levels + compute + topology + φ/σ.

    Frozen and hashable (the oracle memoizes per ``SystemModel``), built
    from a ``SystemModel`` (``from_system``), a named preset (``of``), CLI
    flags (``from_cli_args``), a JSON artifact (``from_json``), or measured
    runs (``fitted_from``). ``.system`` projects back down to the α–β
    ``SystemModel`` every legacy entry point consumes, so a ClusterSpec is
    a one-argument swap anywhere a system model went before.
    """

    name: str
    levels: tuple                        # ((axis, Level), ...)
    peak_flops: float
    hbm_bw: float
    mem_capacity: float
    compute_efficiency: float
    topology: Torus | None = None
    phi: tuple | None = None             # ((level, φ), ...) or None
    sigma: tuple | None = None           # ((level, σ), ...) or None
    fit_residuals: tuple = field(default=(), compare=False)

    # -- projections ---------------------------------------------------------

    @property
    def system(self) -> SystemModel:
        """The α–β SystemModel view (equal by value, memo-cache friendly)."""
        return SystemModel(
            name=self.name, peak_flops=self.peak_flops, hbm_bw=self.hbm_bw,
            mem_capacity=self.mem_capacity,
            compute_efficiency=self.compute_efficiency, levels=self.levels)

    def level(self, axis: str) -> Level:
        for name, lvl in self.levels:
            if name == axis:
                return lvl
        return self.levels[-1][1]

    def oracle_kw(self) -> dict:
        """The OracleConfig keywords this cluster owns (φ/σ tables)."""
        kw = {}
        if self.phi is not None:
            kw["phi_levels"] = self.phi
        if self.sigma is not None:
            kw["sigma_levels"] = self.sigma
        return kw

    def oracle_config(self, B: int, D: int | None = None, **kw):
        """An OracleConfig carrying this cluster's φ/σ tables. Explicit
        keywords win over the cluster's tables."""
        from .oracle import OracleConfig
        merged = self.oracle_kw()
        merged.update(kw)
        return OracleConfig(B=B, D=D if D is not None else B, **merged)

    def degraded(self, dim: int = 0, count: int = 1) -> "ClusterSpec":
        """The machine that survives losing ``count`` slices of torus
        ``dim``: same interconnect levels, compute, and φ/σ tables, with
        the topology shrunk via ``Torus.without_slice`` (model-axis
        constraints re-indexed). This is the ClusterSpec the elastic
        controller re-runs the tuner on (runtime/elastic.py). Without a
        topology there is no slice structure to shrink — the spec is
        returned unchanged and the caller shrinks p itself."""
        if self.topology is None:
            return self
        name = (self.name if self.name.endswith("-degraded")
                else f"{self.name}-degraded")
        return replace(self, name=name,
                       topology=self.topology.without_slice(dim, count))

    def describe(self) -> str:
        lv = ", ".join(
            f"{ax}: α={l.alpha:.2e}s β⁻¹={1 / l.beta / 1e9:.1f}GB/s"
            for ax, l in self.levels)
        parts = [f"ClusterSpec[{self.name}]: {lv}"]
        if self.topology is not None:
            parts.append(f"  topology {self.topology}")
        if self.phi:
            parts.append("  φ " + ", ".join(f"{k}={v:.2f}"
                                            for k, v in self.phi))
        if self.sigma:
            parts.append("  σ " + ", ".join(f"{k}={v:.2f}"
                                            for k, v in self.sigma))
        return "\n".join(parts)

    # -- constructors --------------------------------------------------------

    @classmethod
    def from_system(cls, sysm: SystemModel, *, topology: Torus | None = None,
                    phi=None, sigma=None, name: str | None = None
                    ) -> "ClusterSpec":
        return cls(name=name or sysm.name, levels=sysm.levels,
                   peak_flops=sysm.peak_flops, hbm_bw=sysm.hbm_bw,
                   mem_capacity=sysm.mem_capacity,
                   compute_efficiency=sysm.compute_efficiency,
                   topology=topology, phi=phi, sigma=sigma)

    @classmethod
    def of(cls, name: str) -> "ClusterSpec":
        """Named presets mirroring hardware.py. Topology stays None (i.e.
        unconstrained) so legacy projections are bit-identical; pass
        ``topology=`` / ``--topology`` to constrain plan search."""
        try:
            return cls.from_system(_NAMED_SYSTEMS[name])
        except KeyError:
            raise KeyError(f"unknown cluster {name!r}; "
                           f"known: {sorted(_NAMED_SYSTEMS)}") from None

    @classmethod
    def coerce(cls, obj) -> "ClusterSpec | None":
        """None | name | SystemModel | ClusterSpec → ClusterSpec (or None)."""
        if obj is None or isinstance(obj, cls):
            return obj
        if isinstance(obj, str):
            return cls.of(obj)
        if isinstance(obj, SystemModel):
            return cls.from_system(obj)
        raise TypeError(f"cannot build a ClusterSpec from {type(obj)}")

    @classmethod
    def from_cli_args(cls, args) -> "ClusterSpec":
        """Build the ClusterSpec an argparse namespace describes (the flags
        ``add_cluster_args`` attached; missing attributes default sanely).
        This is the one home for the --system/--phi/--sigma/--topology
        wiring both CLIs used to copy-paste."""
        art = getattr(args, "cluster", None)
        if art:
            spec = cls.from_json(art)
        else:
            spec = cls.of(getattr(args, "system", None) or "paper")
        phi = parse_phi_table(getattr(args, "phi", None))
        sigma = parse_sigma_table(getattr(args, "sigma", None))
        topo_s = getattr(args, "topology", None)
        md = getattr(args, "model_dims", None)
        if topo_s:
            topo = Torus.parse(topo_s, md)
        elif md is not None:
            # --model-dims without --topology must not silently change
            # nothing (same rule the level tables enforce for typos); it
            # can however re-constrain a topology the artifact carries
            if spec.topology is None:
                raise ValueError(
                    "--model-dims requires --topology (or a --cluster "
                    "artifact that defines one)")
            topo = Torus.parse("x".join(str(d) for d in spec.topology.dims),
                               md)
        else:
            topo = spec.topology
        return replace(spec, phi=phi if phi is not None else spec.phi,
                       sigma=sigma if sigma is not None else spec.sigma,
                       topology=topo)

    @classmethod
    def fitted_from(cls, measurements, base=None,
                    name: str | None = None) -> "ClusterSpec":
        """Fit per-level α/β (Hockney least squares), φ (contention) and σ
        (overlap efficiency) from calibration measurements — the
        ROADMAP's "fit both per interconnect level from measured runs".

        ``measurements``: iterable of ``Measurement`` (or their dicts).
        ``base``: the spec whose compute/topology fields carry over and
        whose levels stand wherever no measurement covers an axis.
        """
        base = cls.coerce(base) or cls.of("host")
        ms = [Measurement.from_json(m) if isinstance(m, dict) else m
              for m in measurements]
        by = {}
        for m in ms:
            by.setdefault((m.level, m.kind), []).append(m)
        residuals = []
        levels, phi, sigma = dict(base.levels), {}, {}
        for (lvl, kind), grp in sorted(by.items()):
            if kind == "collective":
                a, b, r = _fit_alpha_beta(grp)
                levels[lvl] = Level(f"fit-{lvl}", alpha=a, beta=b)
                residuals.append((f"{lvl}/alpha_beta", r))
            elif kind == "contention":
                vals = [min(max(m.shared_s / max(m.alone_s, 1e-12), 1.0),
                            float(m.flows)) for m in grp]
                phi[lvl] = float(np.median(vals))
                residuals.append((f"{lvl}/phi_spread",
                                  float(np.ptp(vals)) if len(vals) > 1
                                  else 0.0))
            elif kind == "overlap":
                vals = [min(max((m.comp_s + m.comm_s - m.both_s)
                                / max(min(m.comp_s, m.comm_s), 1e-12), 0.0),
                            1.0) for m in grp]
                sigma[lvl] = float(np.median(vals))
                residuals.append((f"{lvl}/sigma_spread",
                                  float(np.ptp(vals)) if len(vals) > 1
                                  else 0.0))
            else:
                raise ValueError(f"unknown measurement kind {kind!r}")
        base_axes = [ax for ax, _ in base.levels]
        extra = [ax for ax in sorted(levels) if ax not in base_axes]
        return replace(
            base, name=name or f"{base.name}-fitted",
            levels=tuple((ax, levels[ax]) for ax in base_axes + extra),
            phi=tuple(sorted(phi.items())) if phi else base.phi,
            sigma=tuple(sorted(sigma.items())) if sigma else base.sigma,
            fit_residuals=tuple(residuals))

    def fingerprint(self) -> str:
        """Short stable digest of the machine description (12 hex chars).

        Keys tuned-kernel artifacts (experiments/kernel_tune.json): a cache
        written under one machine description is invalid under another —
        different VMEM pressure / rooflines move the block-size optimum.
        ``fit_residuals`` is excluded (diagnostic only, ``compare=False``),
        so a re-calibration that lands on the same constants keeps its
        tuned blocks."""
        import hashlib
        d = self.to_json()
        d.pop("fit_residuals", None)
        blob = json.dumps(d, sort_keys=True).encode()
        return hashlib.sha256(blob).hexdigest()[:12]

    # -- JSON artifact -------------------------------------------------------

    def to_json(self) -> dict:
        return {
            "name": self.name,
            "levels": {ax: {"alpha": l.alpha, "beta": l.beta, "name": l.name}
                       for ax, l in self.levels},
            "peak_flops": self.peak_flops, "hbm_bw": self.hbm_bw,
            "mem_capacity": self.mem_capacity,
            "compute_efficiency": self.compute_efficiency,
            "topology": (None if self.topology is None
                         else self.topology.to_json()),
            "phi": dict(self.phi) if self.phi else None,
            "sigma": dict(self.sigma) if self.sigma else None,
            "fit_residuals": dict(self.fit_residuals),
        }

    @classmethod
    def from_json(cls, d) -> "ClusterSpec":
        """Dict, JSON string, or path to a JSON artifact."""
        if isinstance(d, str):
            if d.lstrip().startswith("{"):
                d = json.loads(d)
            else:
                with open(d) as f:
                    d = json.load(f)
        levels = tuple(
            (ax, Level(v.get("name", ax), alpha=v["alpha"], beta=v["beta"]))
            for ax, v in d["levels"].items())
        topo = d.get("topology")
        return cls(
            name=d["name"], levels=levels, peak_flops=d["peak_flops"],
            hbm_bw=d["hbm_bw"], mem_capacity=d["mem_capacity"],
            compute_efficiency=d["compute_efficiency"],
            topology=None if topo is None else Torus.from_json(topo),
            phi=tuple(sorted(d["phi"].items())) if d.get("phi") else None,
            sigma=(tuple(sorted(d["sigma"].items()))
                   if d.get("sigma") else None),
            fit_residuals=tuple(sorted(d.get("fit_residuals", {}).items())))


_NAMED_SYSTEMS = {"paper": PAPER_V100_CLUSTER, "tpu": TPU_V5E_POD,
                  "host": cpu_host_model()}


# ---------------------------------------------------------------------------
# CLI wiring (the one home for the flags sweep/autotune used to copy-paste)
# ---------------------------------------------------------------------------

def add_cluster_args(ap, default_system: str = "paper") -> None:
    """Attach the machine-description flags to an argparse parser; pair
    with ``ClusterSpec.from_cli_args``."""
    g = ap.add_argument_group("cluster (machine description)")
    g.add_argument("--system", default=default_system,
                   choices=sorted(_NAMED_SYSTEMS),
                   help="named cluster preset (hardware.py α–β models)")
    g.add_argument("--cluster", default=None, metavar="JSON",
                   help="fitted ClusterSpec artifact (e.g. experiments/"
                        "cluster_fit.json); overrides --system")
    g.add_argument("--phi", default=None, metavar="LVL=PHI[,LVL=PHI...]",
                   help="per-interconnect contention table, e.g. "
                        "'data=2.0,model=1.2' (default: the paper's single "
                        "phi_hybrid=2.0 on the hybrid gradient exchange)")
    g.add_argument("--sigma", default=None, metavar="LVL=SIG[,LVL=SIG...]",
                   help="per-interconnect overlap efficiency table, e.g. "
                        "'model=0.9,data=0.8' (the defaults)")
    g.add_argument("--topology", default=None, metavar="DxD[xD...]",
                   help="physical torus extents, e.g. '4x2'; hybrid plans "
                        "whose model axis cannot ring within one dim are "
                        "pruned, not silently deployed")
    g.add_argument("--model-dims", default=None, metavar="I[,I...]",
                   help="torus dim indices the model axis may occupy "
                        "(default: any single dim; '' for none)")


