"""Per-layer tensor statistics (paper Table 2 notation) for every model family.

The oracle consumes a list of ``LayerStat`` — per-layer |x|, |y|, |w|, FLOPs
and the split-dimension sizes that bound each parallel strategy (F_l, C_l,
spatial size, halo size). Sizes are ELEMENTS PER SAMPLE (paper convention);
a "sample" is an image for CNNs and a full sequence for LMs.

Extractors are analytic (no tracing): they walk the same config objects the
models are built from, so the oracle stays allocation-free (usable for 671B
configs on this CPU box).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from ..models.cnn import (CosmoFlowConfig, ResNetConfig, VGGConfig,
                          _VGG16_LAYOUT)
from ..models.encdec import EncDecConfig
from ..models.transformer import LMConfig
from ..models.vlm import VLMConfig


@dataclass(frozen=True)
class LayerStat:
    name: str
    kind: str            # conv | fc | attn | ffn | moe | ssm | rec | norm | embed
    x: int               # |x_l| elements per sample
    y: int               # |y_l| elements per sample
    w: int               # |w_l| (+bias) elements
    flops_fwd: float     # FLOPs per sample, forward
    F: int = 0           # output channels / filters / heads (filter-par limit)
    C: int = 0           # input channels (channel-par limit)
    spatial: int = 0     # spatial/sequence extent (spatial-par limit)
    halo: int = 0        # halo elements per spatial boundary (paper halo(|x|))
    seq_recurrent: bool = False  # True → spatial/sequence split inapplicable
    flops_bwd_exact: float = 0.0  # measured/derived backward FLOPs per
                                  # sample when the extractor can compute
                                  # them (conv: dL/dx + dL/dw each cost a
                                  # full conv → 2×fw, plus the fw-shaped
                                  # recompute-free term differs from the
                                  # 2×fw heuristic on strided/1x1 layers);
                                  # 0.0 → unknown, consumers fall back

    @property
    def flops_bwd(self) -> float:
        # the oracle's TimeModel keeps the paper's BW ≈ 2× forward
        # approximation (calibrations and pinned crossovers assume it);
        # stage partitioners that want the exact count read
        # ``flops_bwd_exact`` directly (parallel/schedules/stages.py)
        return 2.0 * self.flops_fwd  # BW_data + BW_weight ≈ 2× forward


# ---------------------------------------------------------------------------
# CNNs
# ---------------------------------------------------------------------------

def _conv_stat(name, cin, cout, k, spatial_in, stride, nd) -> LayerStat:
    sp_out = tuple(max(1, s // stride) for s in spatial_in)
    x = cin * int(np.prod(spatial_in))
    y = cout * int(np.prod(sp_out))
    w = cout * cin * k ** nd
    flops = 2.0 * y * cin * k ** nd
    # halo: K/2 rows on each side of a 1-D split of the first spatial dim
    halo = (k // 2) * cin * int(np.prod(spatial_in[1:])) if k > 1 else 0
    # exact backward: dL/dw correlates x with dy (2·y·cin·k^nd, same as
    # fw) and dL/dx is the transposed conv over the INPUT extent
    # (2·x·cout·k^nd) — on strided layers that is more than fw, so the
    # 2×fw heuristic undercounts
    bwd = 2.0 * x * cout * k ** nd + flops
    return LayerStat(name, "conv", x, y, w, flops, F=cout, C=cin,
                     spatial=int(np.prod(spatial_in)), halo=halo,
                     flops_bwd_exact=bwd), sp_out


def resnet_stats(cfg: ResNetConfig, img: int = 224) -> list[LayerStat]:
    stats = []
    st, sp = _conv_stat("stem", 3, cfg.width, 7, (img, img), 2, 2)
    stats.append(st)
    sp = tuple(s // 2 for s in sp)  # maxpool
    in_ch = cfg.width
    for stage, n in enumerate(cfg.stage_sizes):
        mid = cfg.width * (2 ** stage)
        for b in range(n):
            stride = 2 if (b == 0 and stage > 0) else 1
            st1, _ = _conv_stat(f"s{stage}b{b}c1", in_ch, mid, 1, sp, 1, 2)
            st2, sp2 = _conv_stat(f"s{stage}b{b}c2", mid, mid, 3, sp, stride, 2)
            st3, _ = _conv_stat(f"s{stage}b{b}c3", mid, mid * 4, 1, sp2, 1, 2)
            stats += [st1, st2, st3]
            if stride != 1 or in_ch != mid * 4:
                stp, _ = _conv_stat(f"s{stage}b{b}proj", in_ch, mid * 4, 1, sp,
                                    stride, 2)
                stats.append(stp)
            sp = sp2
            in_ch = mid * 4
    head_in = in_ch
    stats.append(LayerStat("head", "fc", head_in, cfg.n_classes,
                           head_in * cfg.n_classes, 2.0 * head_in * cfg.n_classes,
                           F=cfg.n_classes, C=head_in, spatial=1))
    return stats


def vgg_stats(cfg: VGGConfig) -> list[LayerStat]:
    stats, in_ch, sp = [], 3, (cfg.img, cfg.img)
    i = 0
    for v in _VGG16_LAYOUT:
        if v == "M":
            sp = tuple(s // 2 for s in sp)
            continue
        st, _ = _conv_stat(f"conv{i}", in_ch, v, 3, sp, 1, 2)
        stats.append(st)
        in_ch = v
        i += 1
    flat = in_ch * int(np.prod(sp))
    for j, (fin, fout) in enumerate([(flat, 4096), (4096, 4096),
                                     (4096, cfg.n_classes)]):
        stats.append(LayerStat(f"fc{j}", "fc", fin, fout, fin * fout,
                               2.0 * fin * fout, F=fout, C=fin, spatial=1))
    return stats


def cosmoflow_stats(cfg: CosmoFlowConfig) -> list[LayerStat]:
    stats, in_ch = [], cfg.in_ch
    sp = (cfg.img,) * 3
    for i in range(cfg.n_conv):
        out = cfg.width * (2 ** i)
        st, _ = _conv_stat(f"conv{i}", in_ch, out, 3, sp, 1, 3)
        stats.append(st)
        sp = tuple(s // 2 for s in sp)
        in_ch = out
    flat = in_ch * int(np.prod(sp))
    for j, (fin, fout) in enumerate([(flat, 128), (128, 64),
                                     (64, cfg.n_targets)]):
        stats.append(LayerStat(f"fc{j}", "fc", fin, fout, fin * fout,
                               2.0 * fin * fout, F=fout, C=fin, spatial=1))
    return stats


# ---------------------------------------------------------------------------
# Transformers (per-layer; a "sample" = one sequence of length S)
# ---------------------------------------------------------------------------

def _attn_stat(name, d, Hq, Hkv, hd, S, window=None, bias=False) -> LayerStat:
    w = d * (Hq + 2 * Hkv) * hd + Hq * hd * d + (Hq + 2 * Hkv) * hd * (1 if bias else 0)
    proj_flops = 2.0 * S * (d * (Hq + 2 * Hkv) * hd + Hq * hd * d)
    span = min(window, S) if window else S
    attn_flops = 2.0 * 2.0 * S * span / (1 if window else 2) * Hq * hd
    return LayerStat(name, "attn", S * d, S * d, w,
                     proj_flops + attn_flops, F=Hq, C=Hkv,
                     spatial=S, halo=(window or 0))


def _mla_stat(name, c, S) -> LayerStat:
    w = (c.d_model * c.q_lora_rank + c.q_lora_rank * c.n_heads * c.qk_head_dim
         + c.d_model * (c.kv_lora_rank + c.qk_rope_dim)
         + c.kv_lora_rank * c.n_heads * (c.qk_nope_dim + c.v_head_dim)
         + c.n_heads * c.v_head_dim * c.d_model)
    proj_flops = 2.0 * S * w
    attn_flops = 2.0 * S * (S / 2) * c.n_heads * (c.qk_head_dim + c.v_head_dim)
    return LayerStat(name, "attn", S * c.d_model, S * c.d_model, w,
                     proj_flops + attn_flops, F=c.n_heads, C=c.n_heads,
                     spatial=S)


def _ffn_stat(name, d, ff, S, glu=True) -> LayerStat:
    w = d * ff * (3 if glu else 2)
    return LayerStat(name, "ffn", S * d, S * d, w, 2.0 * S * w, F=ff, C=d,
                     spatial=S)


def _moe_stat(name, mcfg, d, S) -> LayerStat:
    per_exp = d * mcfg.d_ff * (3 if mcfg.glu else 2)
    w = per_exp * mcfg.n_experts + d * mcfg.n_experts
    if mcfg.n_shared:
        w += d * (mcfg.shared_d_ff or mcfg.d_ff) * mcfg.n_shared * (3 if mcfg.glu else 2)
    active = per_exp * mcfg.top_k + (d * (mcfg.shared_d_ff or mcfg.d_ff)
                                     * mcfg.n_shared * (3 if mcfg.glu else 2))
    # dispatch/combine einsums: 2·2·S·E·cap_per_token·d with cap≈topk·cf
    dispatch = 4.0 * S * mcfg.n_experts * d * (mcfg.top_k * mcfg.capacity_factor
                                               / mcfg.n_experts)
    return LayerStat(name, "moe", S * d, S * d, w,
                     2.0 * S * active + dispatch, F=mcfg.n_experts, C=d,
                     spatial=S)


def _ssm_stat(name, c, S) -> LayerStat:
    w = (2 * c.d_inner + 2 * c.bc_dim + c.n_heads) * c.d_model \
        + c.d_conv * (c.d_inner + 2 * c.bc_dim) + c.d_inner * c.d_model \
        + 3 * c.n_heads + c.d_inner
    proj = 2.0 * S * ((2 * c.d_inner + 2 * c.bc_dim + c.n_heads) * c.d_model
                      + c.d_inner * c.d_model)
    Q = c.chunk
    ssd = S / Q * (2.0 * Q * Q * c.n_heads * c.d_state          # scores
                   + 2.0 * Q * Q * c.d_inner                     # intra y
                   + 4.0 * Q * c.d_inner * c.d_state)            # states+inter
    return LayerStat(name, "ssm", S * c.d_model, S * c.d_model, w,
                     proj + ssd, F=c.n_heads, C=c.n_heads, spatial=S,
                     seq_recurrent=True)


def _rec_stat(name, c, S) -> LayerStat:
    nb = c.n_blocks
    w = (2 * c.d_model * c.lru_width + c.d_conv * c.lru_width
         + 2 * nb * (c.lru_width // nb) ** 2 + 3 * c.lru_width
         + c.lru_width * c.d_model)
    flops = 2.0 * S * (2 * c.d_model * c.lru_width + c.lru_width * c.d_model
                       + 2 * c.lru_width ** 2 // nb)
    return LayerStat(name, "rec", S * c.d_model, S * c.lru_width, w, flops,
                     F=c.lru_width, C=c.lru_width, spatial=S,
                     seq_recurrent=True)


def lm_stats(cfg: LMConfig, S: int) -> list[LayerStat]:
    stats = [LayerStat("embed", "embed", S, S * cfg.d_model,
                       cfg.vocab * cfg.d_model, 0.0, F=cfg.d_model,
                       C=cfg.vocab, spatial=S)]
    for i, kind in enumerate(cfg.block_kinds()):
        if kind in ("attn", "local_attn", "moe", "mla"):
            if kind == "mla" or (kind == "moe" and cfg.mla is not None) or \
                    (kind == "attn" and cfg.attn is None):
                stats.append(_mla_stat(f"L{i}.mla", cfg.mla, S))
            else:
                a = cfg.local_attn if kind == "local_attn" else cfg.attn
                stats.append(_attn_stat(f"L{i}.attn", cfg.d_model, a.n_heads,
                                        a.n_kv_heads, a.head_dim, S,
                                        window=a.window, bias=a.use_bias))
            if kind == "moe" and i >= cfg.first_k_dense:
                stats.append(_moe_stat(f"L{i}.moe", cfg.moe, cfg.d_model, S))
            else:
                stats.append(_ffn_stat(f"L{i}.ffn", cfg.d_model, cfg.ffn.d_ff, S,
                                       cfg.ffn.glu))
        elif kind == "ssm":
            stats.append(_ssm_stat(f"L{i}.ssm", cfg.ssm, S))
        elif kind == "rec":
            stats.append(_rec_stat(f"L{i}.rec", cfg.rglru, S))
            stats.append(_ffn_stat(f"L{i}.ffn", cfg.d_model, cfg.ffn.d_ff, S,
                                   cfg.ffn.glu))
    stats.append(LayerStat("head", "fc", S * cfg.d_model, S * cfg.vocab,
                           0 if cfg.tie_embeddings else cfg.d_model * cfg.vocab,
                           2.0 * S * cfg.d_model * cfg.vocab,
                           F=cfg.vocab, C=cfg.d_model, spatial=S))
    return stats


def encdec_stats(cfg: EncDecConfig, S: int, T_enc: int | None = None) -> list[LayerStat]:
    T = T_enc or cfg.max_source_positions
    stats = []
    for i in range(cfg.n_enc_layers):
        stats.append(_attn_stat(f"E{i}.attn", cfg.d_model, cfg.n_heads,
                                cfg.n_heads, cfg.head_dim, T, bias=True))
        stats.append(_ffn_stat(f"E{i}.ffn", cfg.d_model, cfg.d_ff, T, glu=False))
    for i in range(cfg.n_dec_layers):
        stats.append(_attn_stat(f"D{i}.self", cfg.d_model, cfg.n_heads,
                                cfg.n_heads, cfg.head_dim, S, bias=True))
        x_attn = _attn_stat(f"D{i}.cross", cfg.d_model, cfg.n_heads,
                            cfg.n_heads, cfg.head_dim, S, bias=True)
        stats.append(x_attn)
        stats.append(_ffn_stat(f"D{i}.ffn", cfg.d_model, cfg.d_ff, S, glu=False))
    stats.append(LayerStat("head", "fc", S * cfg.d_model, S * cfg.vocab, 0,
                           2.0 * S * cfg.d_model * cfg.vocab, F=cfg.vocab,
                           C=cfg.d_model, spatial=S))
    return stats


def stats_for(model_cfg, S: int = 4096) -> list[LayerStat]:
    if isinstance(model_cfg, LMConfig):
        return lm_stats(model_cfg, S)
    if isinstance(model_cfg, EncDecConfig):
        return encdec_stats(model_cfg, S)
    if isinstance(model_cfg, VLMConfig):
        return lm_stats(model_cfg.lm, S)
    if isinstance(model_cfg, ResNetConfig):
        return resnet_stats(model_cfg)
    if isinstance(model_cfg, VGGConfig):
        return vgg_stats(model_cfg)
    if isinstance(model_cfg, CosmoFlowConfig):
        return cosmoflow_stats(model_cfg)
    raise TypeError(type(model_cfg))
