"""Vectorized oracle sweep engine (DESIGN.md §2).

Evaluates the Table-3 analytical model over an entire
``strategy × p-grid × (p1·p2 factorization)`` lattice in one shot, as numpy
array operations over the precomputed ``StatTable`` — instead of thousands
of scalar ``project()`` calls. The math is the SAME broadcastable evaluator
(oracle._eval) the per-point path uses, so vectorized and scalar results
agree to machine precision.

On top of the raw lattice, ``SweepResult`` provides:
  * per-point feasibility + bottleneck classification (comp-bound, GE-bound,
    FB-bound, halo-bound, p2p-bound, scale-/memory-infeasible),
  * best-split reduction per (strategy, p),
  * Pareto-frontier extraction over (p, time),
  * crossover points — at which p does strategy B overtake strategy A?

CLI (Fig-5-style scaling table):

    PYTHONPATH=src python -m repro.core.sweep --model resnet50 --p 1..1024
    PYTHONPATH=src python -m repro.core.sweep --model cosmoflow \
        --p 4..1024 --batch-per-pe 0.25 --crossover spatial ds
    PYTHONPATH=src python -m repro.core.sweep --smoke
"""
from __future__ import annotations

import argparse
from dataclasses import dataclass, replace

import numpy as np

from ..cluster import ClusterSpec, add_cluster_args
from ..hardware import (PAPER_V100_CLUSTER, SystemModel, TPU_V5E_POD,
                       cpu_host_model)
from ..oracle import (OracleConfig, PIPELINE_SCHEDULES, Projection,
                     STRATEGY_NAMES, StatTable, TimeModel, _eval, _limit_str,
                     precompute)

PURE_STRATEGIES = ("serial", "data", "spatial", "pipeline", "filter",
                   "channel")
HYBRID_STRATEGIES = ("df", "ds", "ep")
# strategies whose model width additionally factors into a (p2r × p2c)
# grid — an extra lattice axis on top of the p1·p2 factorization
GRID_STRATEGIES = ("summa",)

# memory-model switches swept as extra lattice axes (DESIGN.md §3/§8)
SWITCH_NAMES = ("remat", "zero1", "zero3", "seq_parallel")

_BOTTLENECK_OF_TERM = np.array(["comp-bound", "GE-bound", "FB-bound",
                                "halo-bound", "p2p-bound"])


def all_switch_combos() -> list[tuple[bool, bool, bool, bool]]:
    """All 16 (remat, zero1, zero3, seq_parallel) combinations."""
    import itertools
    return list(itertools.product((False, True), repeat=len(SWITCH_NAMES)))


def switch_label(remat: bool, zero1: bool, zero3: bool,
                 seq_parallel: bool) -> str:
    on = [n for n, v in zip(SWITCH_NAMES, (remat, zero1, zero3, seq_parallel))
          if v]
    return "+".join(on) if on else "-"


def factor_pairs(p: int) -> list[tuple[int, int]]:
    """ALL (p1, p2) with p1·p2 = p — exhaustive divisors, not just pow2."""
    out = []
    d = 1
    while d * d <= p:
        if p % d == 0:
            out.append((d, p // d))
            if d != p // d:
                out.append((p // d, d))
        d += 1
    return sorted(out)


def parse_p_grid(spec: str) -> list[int]:
    """'1..1024' → powers of two in range; '1..64:8' → arithmetic step;
    '4,6,12' → explicit list."""
    ps: list[int] = []
    for part in spec.split(","):
        part = part.strip()
        if ".." in part:
            rng, _, step = part.partition(":")
            lo, hi = (int(v) for v in rng.split(".."))
            if step:
                ps.extend(range(lo, hi + 1, int(step)))
            else:
                q = 1
                while q < lo:
                    q *= 2
                while q <= hi:
                    ps.append(q)
                    q *= 2
        elif part:
            ps.append(int(part))
    return sorted(set(ps))


@dataclass(eq=False)
class SweepResult:
    """Columnar table over the evaluated lattice (one row = one point)."""

    strategy: np.ndarray         # str
    p: np.ndarray                # int
    p1: np.ndarray               # int
    p2: np.ndarray               # int
    B: np.ndarray                # int (per-point global batch; weak scaling)
    iterations: np.ndarray
    comp_s: np.ndarray           # per-epoch seconds, as in Projection
    comm_ge_s: np.ndarray
    comm_fb_s: np.ndarray
    comm_halo_s: np.ndarray
    comm_p2p_s: np.ndarray
    mem_bytes: np.ndarray
    feasible: np.ndarray         # bool — scaling limits hold
    fits: np.ndarray             # bool — memory <= cap (True when no cap)
    bottleneck: np.ndarray       # str classification per point
    limit: np.ndarray            # str scaling-limit description per point
    # memory-model switch axes (DESIGN.md §3); constant columns unless the
    # sweep was asked to enumerate switch combos
    remat: np.ndarray = None     # bool
    zero1: np.ndarray = None     # bool
    zero3: np.ndarray = None     # bool
    seq_parallel: np.ndarray = None  # bool
    # pipeline schedule axis (DESIGN.md §4): the schedule each pipeline row
    # was priced under ("-" for non-pipeline rows)
    schedule: np.ndarray = None  # str
    # model-grid factorization axes (GRID_STRATEGIES, DESIGN.md §14):
    # p2 = p2r·p2c on summa rows, 1·1 everywhere else
    p2r: np.ndarray = None       # int
    p2c: np.ndarray = None       # int
    mem_cap: float | None = None

    def __post_init__(self):
        n = len(self.p)
        for name in SWITCH_NAMES:
            if getattr(self, name) is None:
                setattr(self, name, np.zeros(n, bool))
        if self.schedule is None:
            self.schedule = np.full(n, "-", dtype="U12")
        if self.p2r is None:
            self.p2r = np.ones(n, np.int64)
        if self.p2c is None:
            self.p2c = np.ones(n, np.int64)

    def __len__(self) -> int:
        return len(self.p)

    @property
    def n_switches(self) -> np.ndarray:
        """How many memory-model switches are on at each point."""
        return (self.remat.astype(int) + self.zero1.astype(int)
                + self.zero3.astype(int) + self.seq_parallel.astype(int))

    def switch_str(self, i: int) -> str:
        return switch_label(bool(self.remat[i]), bool(self.zero1[i]),
                            bool(self.zero3[i]), bool(self.seq_parallel[i]))

    @property
    def comm_s(self) -> np.ndarray:
        return (self.comm_ge_s + self.comm_fb_s + self.comm_halo_s
                + self.comm_p2p_s)

    @property
    def total_s(self) -> np.ndarray:
        return self.comp_s + self.comm_s

    @property
    def ok(self) -> np.ndarray:
        """Deployable points: scaling-feasible AND under the memory cap."""
        return self.feasible & self.fits

    # -- reductions ---------------------------------------------------------

    def select(self, mask_or_idx) -> "SweepResult":
        i = np.asarray(mask_or_idx)
        return replace(
            self, strategy=self.strategy[i], p=self.p[i], p1=self.p1[i],
            p2=self.p2[i], B=self.B[i], iterations=self.iterations[i],
            comp_s=self.comp_s[i], comm_ge_s=self.comm_ge_s[i],
            comm_fb_s=self.comm_fb_s[i], comm_halo_s=self.comm_halo_s[i],
            comm_p2p_s=self.comm_p2p_s[i], mem_bytes=self.mem_bytes[i],
            feasible=self.feasible[i], fits=self.fits[i],
            bottleneck=self.bottleneck[i], limit=self.limit[i],
            remat=self.remat[i], zero1=self.zero1[i], zero3=self.zero3[i],
            seq_parallel=self.seq_parallel[i], schedule=self.schedule[i],
            p2r=self.p2r[i], p2c=self.p2c[i])

    def for_strategy(self, strategy: str) -> "SweepResult":
        return self.select(self.strategy == strategy)

    def best_per_p(self, strategy: str | None = None,
                   require_ok: bool = True) -> "SweepResult":
        """Fastest point per (strategy, p) — the best p1·p2 split. With
        ``require_ok=False``, infeasible points are kept as fallbacks but a
        deployable split always wins over a faster infeasible one. With
        ``strategy`` given, one row per p for that strategy only."""
        total = self.total_s
        keep = self.ok if require_ok else np.ones(len(self), bool)
        if strategy is not None:
            keep &= self.strategy == strategy
        rank = {}
        for i in np.flatnonzero(keep):
            k = (self.strategy[i], int(self.p[i]))
            r = (not self.ok[i], total[i])
            if k not in rank or r < rank[k][0]:
                rank[k] = (r, i)
        idx = np.array(sorted((i for _, i in rank.values()),
                              key=lambda i: (self.strategy[i], self.p[i])),
                       dtype=int)
        return self.select(idx if idx.size else np.zeros(0, int))

    def pareto(self) -> "SweepResult":
        """Non-dominated deployable points over (p ↓, total_s ↓): a point
        survives iff no other point is at most as big AND at most as slow."""
        cand = self.best_per_p()
        order = np.lexsort((cand.total_s, cand.p))
        idx, best_t = [], np.inf
        for i in order:
            if cand.total_s[i] < best_t:
                idx.append(i)
                best_t = cand.total_s[i]
        return cand.select(np.array(idx, int))

    def crossover(self, base: str, challenger: str) -> int | None:
        """Smallest p in the grid where ``challenger``'s best split is
        strictly faster than ``base``'s (e.g. where df overtakes data)."""
        a = self.best_per_p(base)
        b = self.best_per_p(challenger)
        ta = {int(p): t for p, t in zip(a.p, a.total_s)}
        for p, t in sorted(zip(b.p, b.total_s)):
            if int(p) in ta and t < ta[int(p)]:
                return int(p)
        return None

    # -- interop / rendering ------------------------------------------------

    def to_projections(self) -> list[Projection]:
        """Rows as per-point ``Projection`` objects (advisor compatibility)."""
        return [Projection(str(self.strategy[i]), int(self.p[i]),
                           int(self.p1[i]), int(self.p2[i]),
                           float(self.comp_s[i]), float(self.comm_ge_s[i]),
                           float(self.comm_fb_s[i]), float(self.comm_halo_s[i]),
                           float(self.comm_p2p_s[i]), float(self.mem_bytes[i]),
                           bool(self.feasible[i]), str(self.limit[i]),
                           float(self.iterations[i]),
                           p2r=int(self.p2r[i]), p2c=int(self.p2c[i]))
                for i in range(len(self))]

    def table(self) -> str:
        """Fig-5-style text table: best split per (p, strategy), with the
        per-iteration breakdown and bottleneck classification."""
        best = self.best_per_p(require_ok=False)
        lines = [f"{'p':>6s} {'strategy':10s} {'p1xp2':>11s} {'B':>7s} "
                 f"{'comp_ms':>10s} {'comm_ms':>10s} {'total_ms':>10s} "
                 f"{'mem_GiB':>8s}  {'bottleneck':18s} {'limit'}"]
        short = {"gpipe": "gpipe", "one_f_one_b": "1f1b",
                 "interleaved": "ileav"}
        for p in sorted(set(int(v) for v in best.p)):
            sub = best.select(best.p == p)
            for i in np.argsort(np.where(sub.ok, sub.total_s, np.inf)):
                it = max(float(sub.iterations[i]), 1.0)
                mark = " " if sub.ok[i] else "!"
                sched = str(sub.schedule[i])
                disp = (f"pipe:{short.get(sched, sched)}"
                        if sched != "-" else str(sub.strategy[i]))
                if str(sub.strategy[i]) in GRID_STRATEGIES:
                    disp = f"{disp}:{int(sub.p2r[i])}x{int(sub.p2c[i])}"
                lines.append(
                    f"{p:>6d} {disp:10s} "
                    f"{int(sub.p1[i]):>5d}x{int(sub.p2[i]):<5d} "
                    f"{int(sub.B[i]):>7d} "
                    f"{float(sub.comp_s[i])/it*1e3:>10.3f} "
                    f"{float(sub.comm_s[i])/it*1e3:>10.3f} "
                    f"{float(sub.total_s[i])/it*1e3:>10.3f} "
                    f"{float(sub.mem_bytes[i])/2**30:>8.2f} {mark} "
                    f"{sub.bottleneck[i]:18s} {sub.limit[i]}")
        return "\n".join(lines)


def _lattice(strategy: str, p_grid, batch_of) -> tuple | None:
    """(p, p1, p2, p2r, p2c, B) integer arrays for one strategy's slice of
    the lattice. The grid axes are 1 except for GRID_STRATEGIES, which fan
    each (p1, p2) split over every (p2r, p2c) factorization of p2."""
    if strategy == "serial":
        pts = [(1, 1, 1, 1, 1)] if 1 in p_grid else []
    elif strategy == "data":
        pts = [(p, p, 1, 1, 1) for p in p_grid]
    elif strategy in PURE_STRATEGIES:
        pts = [(p, 1, p, 1, 1) for p in p_grid]
    elif strategy in GRID_STRATEGIES:
        pts = [(p, a, b, r, c) for p in p_grid for a, b in factor_pairs(p)
               for r, c in factor_pairs(b)]
    else:
        pts = [(p, a, b, 1, 1) for p in p_grid for a, b in factor_pairs(p)]
    if not pts:
        return None
    arr = np.array(pts, np.int64)
    B = np.array([batch_of(int(p)) for p in arr[:, 0]], np.int64)
    return arr[:, 0], arr[:, 1], arr[:, 2], arr[:, 3], arr[:, 4], B


def sweep(stats, tm: TimeModel, cfg: OracleConfig, p_grid,
          strategies=STRATEGY_NAMES, *, batch_for_p=None,
          mem_cap: float | None = None, switches=None, schedules=None,
          cluster: "ClusterSpec | None" = None) -> SweepResult:
    """Evaluate the whole (strategy × p × p1·p2 [× switches] [× schedules])
    lattice vectorized.

    ``batch_for_p``: optional callable p → global batch B (weak scaling);
    defaults to the constant ``cfg.B``. ``mem_cap``: per-PE bytes; points
    over it are classified memory-infeasible (but still reported).
    ``switches``: memory-model switch axes (DESIGN.md §3) — ``None``
    evaluates only the combination already set on ``cfg``; ``"all"``
    enumerates all 16 (remat, zero1, zero3, seq_parallel) combinations as a
    16× lattice axis; or pass an explicit iterable of 4-bool tuples.
    ``schedules``: the pipeline strategy's schedule axis (DESIGN.md §4) —
    ``None`` prices pipeline rows only under ``cfg.schedule`` (current
    behavior), ``"all"`` enumerates every executor schedule as extra
    pipeline rows, or pass an explicit iterable of schedule names.
    Non-pipeline strategies are schedule-invariant and carry ``"-"``.
    ``cluster``: a ClusterSpec whose torus topology (if any) additionally
    prunes lattice points whose model axis cannot embed as a physical ring
    (cluster.Torus.split_mask; DESIGN.md §11) — the α–β terms themselves
    still come from ``tm``/``cfg``, so a cluster with ``topology=None``
    changes nothing.
    """
    unknown = set(strategies) - set(STRATEGY_NAMES)
    if unknown:
        raise ValueError(f"unknown strategies {sorted(unknown)}; "
                         f"known: {list(STRATEGY_NAMES)}")
    if switches is None:
        combos = [(cfg.remat, cfg.zero1, cfg.zero3, cfg.seq_parallel)]
    elif switches == "all":
        combos = all_switch_combos()
    else:
        combos = [tuple(bool(v) for v in c) for c in switches]
        if any(len(c) != len(SWITCH_NAMES) for c in combos):
            raise ValueError(f"each switch combo must be a 4-tuple over "
                             f"{SWITCH_NAMES}")
    if schedules is None:
        scheds = (cfg.schedule,)
    elif schedules == "all":
        scheds = PIPELINE_SCHEDULES
    else:
        scheds = tuple(schedules)
        unknown = set(scheds) - set(PIPELINE_SCHEDULES)
        if unknown:
            raise ValueError(f"unknown schedules {sorted(unknown)}; "
                             f"known: {list(PIPELINE_SCHEDULES)}")
    T = precompute(stats, tm)
    p_grid = sorted(set(int(p) for p in p_grid if int(p) >= 1))
    batch_of = batch_for_p or (lambda p: cfg.B)
    cols: dict[str, list] = {k: [] for k in
                             ("strategy", "p", "p1", "p2", "p2r", "p2c",
                              "B", "iters",
                              "comp", "ge", "fb", "halo", "p2p", "mem",
                              "feasible", "limit", "schedule",
                              "remat", "zero1", "zero3", "seq_parallel")}
    for s in strategies:
        lat = _lattice(s, p_grid, batch_of)
        if lat is None:
            continue
        p, p1, p2, p2r, p2c, B = lat
        # the model width the seq-parallel switch shards the residual over:
        # the hybrids' p2, the full p for the pure model splits, and the
        # COLUMN ring for grid strategies (rows already shard the sequence)
        if s in GRID_STRATEGIES:
            p2_eff = p2c
        elif s in HYBRID_STRATEGIES:
            p2_eff = p2
        elif s in ("filter", "channel", "spatial"):
            p2_eff = p
        else:
            p2_eff = np.ones_like(p)
        # only the pipeline strategy has a schedule axis
        for sched in (scheds if s == "pipeline" else ("-",)):
            cfg_s = cfg if sched == "-" else replace(cfg, schedule=sched)
            # the lattice, feasibility and limit strings are switch-
            # invariant (scaling limits never involve the memory model) —
            # build them once per (strategy, schedule), re-evaluate only
            # the time/memory terms per combo
            evals = []
            for combo in combos:
                cfg_c = replace(cfg_s, **dict(zip(SWITCH_NAMES, combo)))
                try:
                    r = _eval(T, s, cfg_c, tm.system, p, p1, p2, p2_eff, B,
                              p2r=p2r, p2c=p2c)
                except ValueError:  # strategy inapplicable to this layer
                    break           # set, independent of the switch combo
                evals.append((combo, r))
            if not evals:
                continue
            n = len(p)
            bcast = (lambda v: np.broadcast_to(np.asarray(v, np.float64),
                                               (n,)).copy())
            feas = np.broadcast_to(np.asarray(evals[0][1]["feasible"], bool),
                                   (n,)).copy()
            topo = None if cluster is None else cluster.topology
            topo_ok = None
            if topo is not None:
                topo_ok = np.broadcast_to(
                    topo.split_mask(p, p1, p2, strategy=s, p2r=p2r, p2c=p2c),
                    (n,)).copy()
                feas &= topo_ok
            memo: dict = {}   # limit strings only vary with (B, feasible)

            def limit_of(Bi: int, fi: bool) -> str:
                k = (Bi, fi)
                if k not in memo:
                    memo[k] = _limit_str(s, T, Bi, fi, cfg_s)
                return memo[k]

            limits = np.array(
                [limit_of(int(Bi), bool(fi)) for Bi, fi in zip(B, feas)],
                dtype=object)
            if topo_ok is not None and not topo_ok.all():
                # topology-pruned points carry the placement reason, not
                # the (possibly satisfied) scaling limit
                limits = np.where(topo_ok, limits,
                                  topo.limit_str(s)).astype(object)
            sched_label = cfg.schedule if s == "pipeline" and sched == "-" \
                else sched
            for combo, r in evals:
                cols["strategy"].append(np.full(n, s, dtype="U8"))
                cols["p"].append(p)
                cols["p1"].append(p1)
                cols["p2"].append(p2)
                cols["p2r"].append(p2r)
                cols["p2c"].append(p2c)
                cols["B"].append(B)
                cols["iters"].append(bcast(r["iters"]))
                for k in ("comp", "ge", "fb", "halo", "p2p", "mem"):
                    cols[k].append(bcast(r[k]))
                for name, v in zip(SWITCH_NAMES, combo):
                    cols[name].append(np.full(n, bool(v)))
                cols["schedule"].append(np.full(n, sched_label, dtype="U12"))
                cols["feasible"].append(feas)
                cols["limit"].append(limits)
    if not cols["p"]:
        e = np.zeros(0)
        z = np.zeros(0, bool)
        return SweepResult(
            strategy=np.zeros(0, "U8"), p=np.zeros(0, int),
            p1=np.zeros(0, int), p2=np.zeros(0, int), B=np.zeros(0, int),
            iterations=e, comp_s=e, comm_ge_s=e, comm_fb_s=e, comm_halo_s=e,
            comm_p2p_s=e, mem_bytes=e, feasible=z, fits=z,
            bottleneck=np.zeros(0, object), limit=np.zeros(0, object),
            remat=z, zero1=z, zero3=z, seq_parallel=z,
            schedule=np.zeros(0, "U12"), p2r=np.zeros(0, int),
            p2c=np.zeros(0, int), mem_cap=mem_cap)
    cat = {k: np.concatenate(v) for k, v in cols.items()}
    fits = (cat["mem"] <= mem_cap if mem_cap is not None
            else np.ones(len(cat["p"]), bool))
    terms = np.stack([cat["comp"], cat["ge"], cat["fb"], cat["halo"],
                      cat["p2p"]])
    bottleneck = _BOTTLENECK_OF_TERM[np.argmax(terms, axis=0)].astype(object)
    bottleneck[~fits] = "memory-infeasible"
    bottleneck[~cat["feasible"]] = "scale-infeasible"
    return SweepResult(
        strategy=cat["strategy"], p=cat["p"], p1=cat["p1"], p2=cat["p2"],
        B=cat["B"], iterations=cat["iters"], comp_s=cat["comp"],
        comm_ge_s=cat["ge"], comm_fb_s=cat["fb"], comm_halo_s=cat["halo"],
        comm_p2p_s=cat["p2p"], mem_bytes=cat["mem"],
        feasible=cat["feasible"], fits=fits, bottleneck=bottleneck,
        limit=cat["limit"], remat=cat["remat"], zero1=cat["zero1"],
        zero3=cat["zero3"], seq_parallel=cat["seq_parallel"],
        schedule=cat["schedule"], p2r=cat["p2r"], p2c=cat["p2c"],
        mem_cap=mem_cap)


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

# legacy alias — the preset table now lives in cluster.py (one source);
# kept because external callers imported it from here
from ..cluster import _NAMED_SYSTEMS as _SYSTEMS  # noqa: E402
_CNN_DATASETS = {"resnet50": 1_281_167, "vgg16": 1_281_167,
                 "cosmoflow": 1584}


def _model_config(name: str):
    """The model config object behind a CLI --model name."""
    from ...models.cnn import RESNET50, CosmoFlowConfig, VGGConfig
    cnn = {"resnet50": RESNET50, "vgg16": VGGConfig(),
           "cosmoflow": CosmoFlowConfig(img=128)}
    if name in cnn:
        return cnn[name]
    from ...configs import get_config
    return get_config(name).model


def _model_stats(name: str, seq: int):
    from ..layer_stats import stats_for
    mc = _model_config(name)
    if name in _CNN_DATASETS:
        return stats_for(mc), _CNN_DATASETS[name]
    return stats_for(mc, seq), 100_000


def _smoke() -> int:
    """Tiny self-check for CI: lattice vs scalar project() parity."""
    from ..oracle import project
    from ...models.cnn import RESNET50
    from ..layer_stats import stats_for
    stats = stats_for(RESNET50)
    tm = TimeModel(PAPER_V100_CLUSTER)
    cfg = OracleConfig(B=64, D=6400)
    res = sweep(stats, tm, cfg, [1, 2, 4, 8, 12, 16], mem_cap=16e9,
                schedules="all")
    worst = 0.0
    for i in range(len(res)):
        sched = str(res.schedule[i])
        cfg_i = cfg if sched == "-" else replace(cfg, schedule=sched)
        pr = project(str(res.strategy[i]), stats, tm, cfg_i, int(res.p[i]),
                     p1=int(res.p1[i]), p2=int(res.p2[i]),
                     p2r=int(res.p2r[i]), p2c=int(res.p2c[i]))
        ref = pr.total_s
        worst = max(worst, abs(res.total_s[i] - ref) / max(abs(ref), 1e-30))
    assert worst < 1e-9, f"sweep/scalar mismatch: {worst:.2e}"
    assert res.crossover("data", "df") is None or res.crossover("data", "df") > 0
    n_sched = len(set(str(s) for s in
                      res.select(res.strategy == "pipeline").schedule))
    assert n_sched == len(PIPELINE_SCHEDULES), \
        f"expected {len(PIPELINE_SCHEDULES)} pipeline schedules, got {n_sched}"
    print(f"sweep --smoke OK: {len(res)} lattice points "
          f"({n_sched} pipeline schedules), "
          f"max rel err vs project() = {worst:.2e}")
    return 0


def _resolve_strategies(names) -> tuple:
    """Map CLI --strategies names onto oracle strategy names.

    Accepts both the oracle spellings (STRATEGY_NAMES) and the executable
    rules-table spellings (``parallel.strategies.list_strategies()``, e.g.
    ``df_zero3`` → ``df`` via ``autotune.ORACLE_OF_EXEC``). Unknown names
    raise with BOTH valid sets — previously a typo fell through to
    ``sweep()``'s lattice loop and could silently price an empty/partial
    lattice. The executable namespace is imported lazily: strategies.py
    pulls in jax, and this module must stay importable with numpy only.
    """
    exec_names: tuple = ()
    oracle_of_exec: dict = {}
    if any(n not in STRATEGY_NAMES for n in names):
        try:
            from ..autotune import ORACLE_OF_EXEC as oracle_of_exec
            from ...parallel.strategies import list_strategies
            exec_names = tuple(list_strategies())
        except Exception:  # no jax runtime: oracle spellings only
            pass
    out, unknown = [], []
    for n in names:
        if n in STRATEGY_NAMES:
            out.append(n)
        elif n in oracle_of_exec:
            out.append(oracle_of_exec[n])
        else:
            unknown.append(n)
    if unknown:
        valid = sorted(set(STRATEGY_NAMES) | set(exec_names))
        raise ValueError(
            f"unknown strategy name(s) {unknown}; valid names: {valid}")
    seen: set = set()
    return tuple(n for n in out if not (n in seen or seen.add(n)))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.core.sweep",
        description="Vectorized oracle sweep: Fig-5-style strategy × scale "
                    "table from the Table-3 analytical model.")
    ap.add_argument("--model", default="resnet50",
                    help="resnet50 | vgg16 | cosmoflow | any configs/ LM name")
    ap.add_argument("--p", default="1..1024",
                    help="p grid: '1..1024' (pow2), '4..64:4' (step), '4,6,12'")
    ap.add_argument("--batch", type=int, default=None,
                    help="fixed global batch B (default: weak scaling)")
    ap.add_argument("--batch-per-pe", type=float, default=2.0,
                    help="weak scaling: B = max(round(b·p), 1)")
    ap.add_argument("--dataset", type=int, default=None,
                    help="samples per epoch D (default: per-model)")
    ap.add_argument("--seq", type=int, default=4096, help="LM sequence length")
    ap.add_argument("--mem-cap-gib", type=float, default=None,
                    help="per-PE memory cap (default: system capacity)")
    for flag in ("remat", "zero1", "zero3", "seq-parallel"):
        ap.add_argument(f"--{flag}", action="store_true",
                        help=f"memory-model switch (DESIGN.md §3)")
    add_cluster_args(ap, default_system="paper")
    ap.add_argument("--no-overlap", action="store_true",
                    help="charge every comm term serially — the paper's "
                         "original accounting (default: halo P2P and the "
                         "gradient exchange hide under compute, DESIGN.md "
                         "§10)")
    ap.add_argument("--strategies", default=",".join(STRATEGY_NAMES),
                    help="comma-separated strategy subset; oracle names "
                         f"({'/'.join(STRATEGY_NAMES)}) or executable "
                         "rules-table names (parallel/strategies.py); "
                         "unknown names are rejected with the valid set")
    ap.add_argument("--schedule", default="all",
                    help="pipeline schedule axis: 'all' (default) sweeps "
                         f"{'/'.join(PIPELINE_SCHEDULES)} as extra pipeline "
                         "rows, or name one")
    ap.add_argument("--virtual-stages", type=int, default=2,
                    help="v for the interleaved schedule (chunks per rank)")
    ap.add_argument("--crossover", nargs=2, metavar=("BASE", "CHALLENGER"),
                    default=("data", "df"),
                    help="report smallest p where CHALLENGER beats BASE")
    ap.add_argument("--csv", action="store_true", help="raw per-point CSV")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny self-check sweep (CI gate)")
    args = ap.parse_args(argv)
    if args.smoke:
        return _smoke()

    stats, default_D = _model_stats(args.model, args.seq)
    cluster = ClusterSpec.from_cli_args(args)
    tm = TimeModel(cluster.system)
    p_grid = parse_p_grid(args.p)
    D = args.dataset or default_D
    if args.batch is not None:
        batch_of = lambda p: args.batch          # noqa: E731
    else:
        batch_of = lambda p: max(int(round(args.batch_per_pe * p)), 1)  # noqa: E731
    cfg = cluster.oracle_config(
        B=batch_of(max(p_grid)), D=max(D, batch_of(max(p_grid))),
        remat=args.remat, zero1=args.zero1, zero3=args.zero3,
        seq_parallel=args.seq_parallel, overlap=not args.no_overlap,
        virtual_stages=max(args.virtual_stages, 1))
    cap = (args.mem_cap_gib * 2 ** 30 if args.mem_cap_gib
           else tm.system.mem_capacity)
    try:
        strategies = _resolve_strategies(
            tuple(s for s in args.strategies.split(",") if s))
    except ValueError as e:
        ap.error(str(e))
    res = sweep(stats, tm, cfg, p_grid, strategies, batch_for_p=batch_of,
                mem_cap=cap, cluster=cluster,
                schedules="all" if args.schedule == "all" else (args.schedule,))

    if args.csv:
        print("strategy,schedule,p,p1,p2,B,comp_s,comm_ge_s,comm_fb_s,"
              "comm_halo_s,comm_p2p_s,mem_bytes,feasible,fits,bottleneck")
        for i in range(len(res)):
            print(f"{res.strategy[i]},{res.schedule[i]},"
                  f"{res.p[i]},{res.p1[i]},{res.p2[i]},"
                  f"{res.B[i]},{res.comp_s[i]:.9g},{res.comm_ge_s[i]:.9g},"
                  f"{res.comm_fb_s[i]:.9g},{res.comm_halo_s[i]:.9g},"
                  f"{res.comm_p2p_s[i]:.9g},{res.mem_bytes[i]:.9g},"
                  f"{int(res.feasible[i])},{int(res.fits[i])},"
                  f"{res.bottleneck[i]}")
        return 0

    print(f"# model={args.model} system={tm.system.name} "
          f"D={cfg.D} mem_cap={cap/2**30:.1f}GiB "
          f"B={'fixed %d' % args.batch if args.batch else 'weak %.3g/PE' % args.batch_per_pe} "
          f"overlap={'off (serial comm, paper model)' if args.no_overlap else 'on'}")
    print(f"# lattice: {len(res)} points "
          f"({len(p_grid)} p-values × strategies × exhaustive p1·p2 splits); "
          f"'!' rows are infeasible at that p")
    print(res.table())
    base, chal = args.crossover
    x = res.crossover(base, chal)
    print(f"# crossover: {chal} overtakes {base} at p={x}" if x else
          f"# crossover: {chal} never overtakes {base} on this grid")
    front = res.pareto()
    if len(front):
        pts = ", ".join(f"p={int(p)}:{s}({int(a)}x{int(b)})"
                        for p, s, a, b in zip(front.p, front.strategy,
                                              front.p1, front.p2))
        print(f"# pareto frontier (p vs time): {pts}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
