"""Entry point: ``python -m repro.core.sweep`` (see package docstring)."""
import sys

from . import main

try:
    sys.exit(main())
except BrokenPipeError:     # e.g. `... --csv | head` closing the pipe early
    sys.exit(0)
