"""Pipeline stage partitioner — contiguous min-max DP over per-layer costs.

The paper's Table-3 "Layer" row assumes balanced stages (max stage ≈ total/p,
the §5.3.3 workload-balancing caveat). Real CNN layer tables are heavily
skewed (early convs dominate FLOPs, late FCs dominate weights), so this
module computes the *optimal contiguous partition*: split G layers into k
stages minimizing the bottleneck stage's cost. Both Dryden et al. and Jia et
al. show this load imbalance dominates layer-partitioned CNN training.

Used by
  * ``oracle._eval`` — the pipeline row's ``max FW_Gi + max BW_Gi`` terms and
    the stage-boundary activation sizes come from the DP cut points instead
    of ``total/p`` and ``max_l |y_l|``;
  * ``parallel/pipeline.make_pipeline_train_step`` — the executable GPipe
    schedule cuts its stages with the same partitioner (padded + masked
    stage scans realize unequal layer counts under SPMD).

Pure numpy, no jax: usable from the allocation-free oracle path.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class StagePartition:
    """A contiguous partition of ``n`` layers into ``k`` non-empty stages.

    ``bounds`` has k+1 entries: stage i owns layers [bounds[i], bounds[i+1]).
    ``max_cost`` is the bottleneck stage's total cost under the partitioning
    cost vector (the quantity the DP minimized).
    """

    bounds: tuple[int, ...]
    max_cost: float

    @property
    def k(self) -> int:
        return len(self.bounds) - 1

    def counts(self) -> tuple[int, ...]:
        return tuple(self.bounds[i + 1] - self.bounds[i]
                     for i in range(self.k))


def balanced_partition(n: int, k: int) -> StagePartition:
    """Equal-layer-COUNT split (the naive 'balanced' baseline the oracle
    previously assumed): stage sizes differ by at most one layer."""
    if not 1 <= k <= n:
        raise ValueError(f"need 1 <= k={k} <= n={n}")
    base, extra = divmod(n, k)
    bounds, at = [0], 0
    for i in range(k):
        at += base + (1 if i < extra else 0)
        bounds.append(at)
    return StagePartition(tuple(bounds), float("nan"))


def min_max_partition(costs, k: int) -> StagePartition:
    """Optimal contiguous split of ``costs`` into ``k`` non-empty stages
    minimizing the max stage sum (classic linear-partition DP, O(k·n²) with
    prefix sums — layer tables are ≤ a few hundred entries).

    Ties break toward the earliest cut points, so the result is
    deterministic and matches a left-to-right brute-force enumeration.
    """
    c = np.asarray(costs, np.float64)
    n = int(c.size)
    if not 1 <= k <= n:
        raise ValueError(f"need 1 <= k={k} <= n={n} layers")
    if np.any(c < 0):
        raise ValueError("stage costs must be non-negative")
    prefix = np.concatenate([[0.0], np.cumsum(c)])
    if k == 1:
        return StagePartition((0, n), float(prefix[n]))
    # f[i] = min over partitions of layers [0, i) into the current number of
    # stages of the max stage sum; cut[j][i] = argmin split point
    f = prefix[1:].copy()                      # 1 stage over [0, i)
    cuts = np.zeros((k, n + 1), np.int64)
    for j in range(2, k + 1):
        g = np.full(n + 1, np.inf)
        # stage j spans [m, i); need m >= j-1 (non-empty earlier stages)
        for i in range(j, n + 1):
            best, arg = np.inf, j - 1
            for m in range(j - 1, i):
                cand = max(f[m - 1], prefix[i] - prefix[m])
                if cand < best - 1e-18:
                    best, arg = cand, m
            g[i] = best
            cuts[j - 1, i] = arg
        f = g[1:]
    bounds = [n]
    for j in range(k, 1, -1):
        bounds.append(int(cuts[j - 1, bounds[-1]]))
    bounds.append(0)
    bounds = tuple(reversed(bounds))
    return StagePartition(bounds, float(f[n - 1]))


def stage_sums(values, bounds) -> np.ndarray:
    """Per-stage sums of ``values`` under ``bounds`` (length k array)."""
    v = np.asarray(values, np.float64)
    prefix = np.concatenate([[0.0], np.cumsum(v)])
    b = np.asarray(bounds, np.int64)
    return prefix[b[1:]] - prefix[b[:-1]]


def cut_values(values, bounds) -> np.ndarray:
    """``values`` at the stage-boundary layers: the activation leaving stage
    i is the output of its LAST layer (index bounds[i+1]-1), for every
    internal boundary. Empty for a single stage."""
    v = np.asarray(values, np.float64)
    b = np.asarray(bounds, np.int64)
    if len(b) <= 2:
        return np.zeros(0)
    return v[b[1:-1] - 1]
