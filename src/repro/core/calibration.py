"""Empirical parametrization (paper §4.4).

Measured ingredients feed the oracle:
  * compute: serial per-sample step time → an effective ``compute_efficiency``
    for the host SystemModel (the paper profiles FW_l/BW_l per layer on V100;
    on this box we calibrate the aggregate and apportion by FLOPs, which is
    equivalent for every Table-3 row — they only use Σ or max over balanced
    groups),
  * communication: timed Allreduce/Allgather at several message sizes across
    the available (virtual) devices, least-squares fit of the ring formulas
    to recover α and β,
  * contention φ and overlap efficiency σ per interconnect level
    (``measure_contention`` / ``measure_overlap``): the raw observations are
    emitted as ``cluster.Measurement`` records and fitted by
    ``ClusterSpec.fitted_from`` — ``calibrate_cluster`` runs the whole
    harness and closes the loop back into projections (DESIGN.md §11).
"""
from __future__ import annotations

import time
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from .cluster import ClusterSpec, Measurement
from .hardware import Level, SystemModel, cpu_host_model


def time_fn(fn, *args, iters: int = 5, warmup: int = 2) -> float:
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def _collective_fn(mesh, axis: str, pattern: str):
    """A jitted ring collective over one mesh axis (ar: allreduce-shaped,
    ag: allgather-shaped replication)."""
    sharding = NamedSharding(mesh, P(axis, None))
    if pattern == "ar":
        @jax.jit
        def coll(x):
            return jax.lax.with_sharding_constraint(
                jnp.broadcast_to(jnp.sum(x, axis=0, keepdims=True),
                                 x.shape), sharding)
    else:
        rep = NamedSharding(mesh, P(None, None))

        @jax.jit
        def coll(x):
            return jax.lax.with_sharding_constraint(x, rep)
    return coll, sharding


def measure_collective(mesh, axis: str = "data",
                       sizes=(1 << 12, 1 << 16, 1 << 20, 1 << 23),
                       pattern: str = "ar") -> Measurement:
    """Time one ring collective at several message sizes; the raw
    observations (not a fit) — ``ClusterSpec.fitted_from`` recovers α/β."""
    p = mesh.shape[axis]
    coll, sharding = _collective_fn(mesh, axis, pattern)
    ts = []
    for nbytes in sizes:
        x = jax.device_put(jnp.zeros((p, nbytes // 4), jnp.float32), sharding)
        ts.append(time_fn(coll, x))
    return Measurement(level=axis, kind="collective", pattern=pattern,
                       p=p, nbytes=tuple(sizes), seconds=tuple(ts))


def measure_alpha_beta(mesh, axis: str = "data",
                       sizes=(1 << 12, 1 << 16, 1 << 20, 1 << 23),
                       pattern: str = "ar") -> Level:
    """Fit ring-model α/β over measured collectives.

    pattern "ar": T = 2(p−1)(α + m/p·β);  "ag": T = (p−1)(α + m/p·β).
    (Thin wrapper: one ``measure_collective`` run through the shared
    Hockney fit in cluster.py.)
    """
    m = measure_collective(mesh, axis, sizes, pattern)
    spec = ClusterSpec.fitted_from([m], base=cpu_host_model())
    lvl = spec.level(axis)
    return Level(f"measured-{axis}-{pattern}", alpha=lvl.alpha, beta=lvl.beta)


def measure_contention(mesh, axis: str = "data", nbytes: int = 1 << 20,
                       flows: int = 2) -> Measurement:
    """Self-contention φ (paper §4.3): one saturating collective alone vs
    ``flows`` independent copies dispatched in a single jitted program —
    sharing the level's links. φ = wall(shared) / wall(alone), clamped to
    [1, flows] by the fit (1 = perfectly concurrent, flows = serialized)."""
    p = mesh.shape[axis]
    coll, sharding = _collective_fn(mesh, axis, "ar")
    xs = [jax.device_put(jnp.full((p, nbytes // 4), float(i + 1),
                                  jnp.float32), sharding)
          for i in range(flows)]

    @jax.jit
    def many(*arrs):
        return [jax.lax.with_sharding_constraint(
            jnp.broadcast_to(jnp.sum(a, axis=0, keepdims=True), a.shape),
            sharding) for a in arrs]

    alone = time_fn(coll, xs[0])
    shared = time_fn(many, *xs)
    return Measurement(level=axis, kind="contention", alone_s=alone,
                       shared_s=shared, flows=flows)


def measure_overlap(mesh, axis: str = "data", nbytes: int = 1 << 21,
                    matmul_dim: int = 256, matmul_iters: int = 8
                    ) -> Measurement:
    """Overlap efficiency σ (DESIGN.md §10): independent compute and comm
    timed separately and fused into one program whose comm result does NOT
    feed the compute — everything the runtime hides shows up as
    both < comp + comm. σ = (comp + comm − both)/min(comp, comm)."""
    p = mesh.shape[axis]
    coll, sharding = _collective_fn(mesh, axis, "ar")
    x = jax.device_put(jnp.ones((p, nbytes // 4), jnp.float32), sharding)
    a = jax.device_put(
        jnp.ones((p, matmul_dim, matmul_dim), jnp.float32) * 1e-3,
        NamedSharding(mesh, P(axis, None, None)))

    @jax.jit
    def comp(a):
        y = a
        for _ in range(matmul_iters):
            y = jnp.einsum("pij,pjk->pik", y, a)
        return y

    @jax.jit
    def both(a, x):
        y = a
        for _ in range(matmul_iters):
            y = jnp.einsum("pij,pjk->pik", y, a)
        return y, coll(x)

    t_comp = time_fn(comp, a)
    t_comm = time_fn(coll, x)
    t_both = time_fn(both, a, x)
    return Measurement(level=axis, kind="overlap", comp_s=t_comp,
                       comm_s=t_comm, both_s=t_both)


def calibrate_cluster(mesh, *, base: ClusterSpec | None = None,
                      loss_fn=None, params=None, batch=None,
                      flops_per_step: float | None = None,
                      sizes=(1 << 12, 1 << 16, 1 << 20, 1 << 23),
                      per_pe_compute: bool = True
                      ) -> tuple[ClusterSpec, list]:
    """Run the full measurement harness on a mesh and fit a ClusterSpec.

    Per mesh axis with extent > 1: α/β (allreduce + allgather patterns),
    contention φ, and overlap σ. With ``loss_fn``/``params``/``batch``/
    ``flops_per_step`` given, also calibrates compute; virtual host devices
    timeshare one core, so ``per_pe_compute`` divides the measured
    throughput by the device count (per-PE capability, paper §4.4).

    Returns ``(fitted ClusterSpec, raw measurements)`` — the measurements
    serialize into the ``experiments/cluster_fit.json`` artifact and
    round-trip through ``ClusterSpec.fitted_from``.
    """
    base = ClusterSpec.coerce(base) or ClusterSpec.of("host")
    if loss_fn is not None:
        sysm = calibrate_compute(loss_fn, params, batch, flops_per_step,
                                 base=base.system)
        if per_pe_compute:
            p = int(np.prod(list(mesh.shape.values())))
            sysm = replace(sysm, peak_flops=sysm.peak_flops / max(p, 1))
        base = replace(base, peak_flops=sysm.peak_flops,
                       compute_efficiency=sysm.compute_efficiency)
    ms: list[Measurement] = []
    for axis in mesh.shape:
        if mesh.shape[axis] <= 1:
            continue
        ms.append(measure_collective(mesh, axis, sizes, "ar"))
        ms.append(measure_collective(mesh, axis, sizes, "ag"))
        ms.append(measure_contention(mesh, axis))
        ms.append(measure_overlap(mesh, axis))
    return ClusterSpec.fitted_from(ms, base=base), ms


def calibrate_compute(loss_fn, params, batch, flops_per_step: float,
                      base: SystemModel | None = None) -> SystemModel:
    """Measure a serial train step and back out compute efficiency."""
    step = jax.jit(jax.value_and_grad(lambda p, b: loss_fn(p, b)[0]))
    t = time_fn(step, params, batch)
    base = base or cpu_host_model()
    eff_flops = flops_per_step * 3.0 / t  # fwd+bwd ≈ 3× fwd flops
    return replace(base, peak_flops=eff_flops, compute_efficiency=1.0)


def calibrate_host_system(loss_fn, params, batch, flops_per_step: float,
                          mesh=None) -> SystemModel:
    """Full host calibration: compute + α/β per mesh axis."""
    sysm = calibrate_compute(loss_fn, params, batch, flops_per_step)
    if mesh is not None and len(jax.devices()) > 1:
        levels = []
        for axis in mesh.shape:
            if mesh.shape[axis] > 1:
                ar = measure_alpha_beta(mesh, axis, pattern="ar")
                ag = measure_alpha_beta(mesh, axis, pattern="ag")
                # host-backend allgathers can be far slower than the ring
                # model (a framework bottleneck ParaDL is built to expose);
                # take the slower fit so FB-collective terms are honest
                lvl = ar if ar.beta >= ag.beta else ag
                levels.append((axis, lvl))
            else:
                levels.append((axis, sysm.level(axis)))
        sysm = replace(sysm, levels=tuple(levels))
    return sysm
