"""Empirical parametrization (paper §4.4).

Two measured ingredients feed the oracle:
  * compute: serial per-sample step time → an effective ``compute_efficiency``
    for the host SystemModel (the paper profiles FW_l/BW_l per layer on V100;
    on this box we calibrate the aggregate and apportion by FLOPs, which is
    equivalent for every Table-3 row — they only use Σ or max over balanced
    groups),
  * communication: timed Allreduce/Allgather at several message sizes across
    the available (virtual) devices, least-squares fit of the ring formulas
    to recover α and β.
"""
from __future__ import annotations

import time
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from .hardware import Level, SystemModel, cpu_host_model


def time_fn(fn, *args, iters: int = 5, warmup: int = 2) -> float:
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def measure_alpha_beta(mesh, axis: str = "data",
                       sizes=(1 << 12, 1 << 16, 1 << 20, 1 << 23),
                       pattern: str = "ar") -> Level:
    """Fit ring-model α/β over measured collectives.

    pattern "ar": T = 2(p−1)(α + m/p·β);  "ag": T = (p−1)(α + m/p·β).
    """
    p = mesh.shape[axis]
    rows, ts = [], []
    for nbytes in sizes:
        n = nbytes // 4
        x = jnp.zeros((p, n), jnp.float32)
        sharding = NamedSharding(mesh, P(axis, None))
        x = jax.device_put(x, sharding)
        if pattern == "ar":
            @jax.jit
            def coll(x):
                return jax.lax.with_sharding_constraint(
                    jnp.broadcast_to(jnp.sum(x, axis=0, keepdims=True),
                                     x.shape), sharding)
            factor = 2 * (p - 1)
        else:
            rep = NamedSharding(mesh, P(None, None))

            @jax.jit
            def coll(x):
                return jax.lax.with_sharding_constraint(x, rep)
            factor = (p - 1)

        t = time_fn(coll, x)
        rows.append([factor, factor / p * nbytes])
        ts.append(t)
    A = np.array(rows)
    coef, *_ = np.linalg.lstsq(A, np.array(ts), rcond=None)
    alpha, beta = float(max(coef[0], 1e-9)), float(max(coef[1], 1e-12))
    return Level(f"measured-{axis}-{pattern}", alpha=alpha, beta=beta)


def calibrate_compute(loss_fn, params, batch, flops_per_step: float,
                      base: SystemModel | None = None) -> SystemModel:
    """Measure a serial train step and back out compute efficiency."""
    step = jax.jit(jax.value_and_grad(lambda p, b: loss_fn(p, b)[0]))
    t = time_fn(step, params, batch)
    base = base or cpu_host_model()
    eff_flops = flops_per_step * 3.0 / t  # fwd+bwd ≈ 3× fwd flops
    return replace(base, peak_flops=eff_flops, compute_efficiency=1.0)


def calibrate_host_system(loss_fn, params, batch, flops_per_step: float,
                          mesh=None) -> SystemModel:
    """Full host calibration: compute + α/β per mesh axis."""
    sysm = calibrate_compute(loss_fn, params, batch, flops_per_step)
    if mesh is not None and len(jax.devices()) > 1:
        levels = []
        for axis in mesh.shape:
            if mesh.shape[axis] > 1:
                ar = measure_alpha_beta(mesh, axis, pattern="ar")
                ag = measure_alpha_beta(mesh, axis, pattern="ag")
                # host-backend allgathers can be far slower than the ring
                # model (a framework bottleneck ParaDL is built to expose);
                # take the slower fit so FB-collective terms are honest
                lvl = ar if ar.beta >= ag.beta else ag
                levels.append((axis, lvl))
            else:
                levels.append((axis, sysm.level(axis)))
        sysm = replace(sysm, levels=tuple(levels))
    return sysm
