"""Compiled-HLO analysis: collective inventory, cost extraction, extrapolation.

XLA's ``cost_analysis()`` counts a ``while`` (scan) body once, not
× trip-count (established empirically — EXPERIMENTS.md §Dry-run). The
dry-run therefore compiles each cell twice more with 1 and 2 unrolled layer
groups under identical shardings; the delta is the exact per-group HLO cost
and  ``total = full_scan + (n_groups - 1) × delta``.

Collectives are parsed from the compiled HLO text with their shapes and
replica groups; per-chip wire bytes follow the ring model the paper uses
(§4.3): all-reduce 2m(g−1)/g, all-gather/reduce-scatter/all-to-all m(g−1)/g,
collective-permute m.
"""
from __future__ import annotations

import re
from collections import Counter
from dataclasses import dataclass


_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_RE = re.compile(
    r"(?P<kind>all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start|-done)?"
    r"(?:\.\d+)?\s*\(")

_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(?P<otype>\([^=]*?\)|[\w\[\],{}\s]+?)\s*"
    r"(?P<kind>all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?(?:\.\d+)?\(",
    re.M)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\]")
_SRC_TGT_RE = re.compile(r"source_target_pairs=\{\{(\d+),(\d+)\}")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class Collective:
    kind: str
    out_bytes: int
    group_size: int
    axis: str  # inferred mesh axis ("model"/"data"/"pod"/"mixed")
    count: int = 1
    f32: bool = False  # True when the payload is fp32 (see adjusted accounting)

    @property
    def wire_bytes_per_chip(self) -> float:
        g, m = self.group_size, self.out_bytes
        if g <= 1:
            return 0.0
        if self.kind == "all-reduce":
            return 2 * m * (g - 1) / g
        if self.kind == "all-gather":
            return m * (g - 1) / g          # m = gathered (output) size
        if self.kind == "reduce-scatter":
            return m * (g - 1)              # m = output (scattered) shard
        if self.kind == "all-to-all":
            return m * (g - 1) / g
        if self.kind == "collective-permute":
            return m
        return 0.0


def _infer_axis(first_group: list[int], mesh_shape: dict[str, int]) -> str:
    """Infer which mesh axis a replica group spans from its id stride."""
    if len(first_group) < 2:
        return "none"
    stride = first_group[1] - first_group[0]
    # mesh is laid out row-major over (pod, data, model)
    axes = list(mesh_shape.items())  # ordered
    sizes = [s for _, s in axes]
    strides = {}
    acc = 1
    for name, size in reversed(axes):
        strides[name] = acc
        acc *= size
    for name, size in axes:
        if stride == strides[name] and len(first_group) <= size:
            return name
    return "mixed"


def parse_collectives(hlo_text: str, mesh_shape: dict[str, int]) -> list[Collective]:
    """Inventory of collectives with byte sizes and inferred mesh axes."""
    out: dict[tuple, Collective] = {}
    for m in _OP_RE.finditer(hlo_text):
        kind = m.group("kind")
        otype = m.group("otype")
        nbytes = _shape_bytes(otype)
        is_f32 = "f32[" in otype and "bf16[" not in otype
        # find replica groups within this op's line
        line_end = hlo_text.find("\n", m.end())
        line = hlo_text[m.start():line_end if line_end > 0 else None]
        gsize, axis = 1, "none"
        gm = _GROUPS_RE.search(line)
        if gm:
            ids = [int(x) for x in gm.group(1).split(",")]
            gsize = len(ids)
            axis = _infer_axis(ids, mesh_shape)
        else:
            im = _GROUPS_IOTA_RE.search(line)
            if im:
                n_groups, gsize = int(im.group(1)), int(im.group(2))
                # iota groups: contiguous by construction along the last dims
                axis = "model" if gsize <= mesh_shape.get("model", 0) else "mixed"
        if kind == "collective-permute":
            gsize = max(gsize, 2)
            axis = axis if axis != "none" else "model"
        key = (kind, nbytes, gsize, axis, is_f32)
        if key in out:
            out[key].count += 1
        else:
            out[key] = Collective(kind, nbytes, gsize, axis, f32=is_f32)
    return list(out.values())


@dataclass
class CellCost:
    """Per-device HLO-derived cost of one compiled cell."""

    flops: float
    bytes_accessed: float
    collectives: list[Collective]
    temp_bytes: int = 0
    arg_bytes: int = 0
    out_bytes: int = 0

    def wire_bytes(self, axis: str | None = None,
                   native_dtype: bool = False) -> float:
        """native_dtype=True halves fp32 collectives: the CPU backend
        promotes every bf16 dot to f32 and drags the converts into the
        gathers/reduces; on the TPU target those payloads are bf16
        (EXPERIMENTS.md §Dry-run, artifact note)."""
        total = 0.0
        for c in self.collectives:
            if axis is not None and c.axis != axis:
                continue
            w = c.wire_bytes_per_chip * c.count
            if native_dtype and c.f32:
                w *= 0.5
            total += w
        return total

    def to_json(self) -> dict:
        return {
            "flops": self.flops, "bytes_accessed": self.bytes_accessed,
            "temp_bytes": self.temp_bytes, "arg_bytes": self.arg_bytes,
            "out_bytes": self.out_bytes,
            "wire_bytes_total": self.wire_bytes(),
            "wire_bytes_native_dtype": self.wire_bytes(native_dtype=True),
            "wire_bytes_by_axis": {
                ax: self.wire_bytes(ax)
                for ax in ("pod", "data", "model", "mixed")},
            "collectives": [
                {"kind": c.kind, "bytes": c.out_bytes, "group": c.group_size,
                 "axis": c.axis, "count": c.count, "f32": c.f32}
                for c in sorted(self.collectives,
                                key=lambda c: -c.wire_bytes_per_chip * c.count)],
        }


def cost_of(compiled, mesh_shape: dict[str, int]) -> CellCost:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):   # older jax wraps the dict in a list
        ca = ca[0] if ca else {}
    ma = compiled.memory_analysis()
    colls = parse_collectives(compiled.as_text(), mesh_shape)
    return CellCost(
        flops=float(ca.get("flops", 0.0)),
        bytes_accessed=float(ca.get("bytes accessed", 0.0)),
        collectives=colls,
        temp_bytes=ma.temp_size_in_bytes,
        arg_bytes=ma.argument_size_in_bytes,
        out_bytes=ma.output_size_in_bytes)


def combine(full: CellCost, g1: CellCost, g2: CellCost,
            n_groups: int) -> CellCost:
    """total = full_scan + (n_groups − 1) × (g2 − g1)."""
    extra = max(n_groups - 1, 0)
    d_flops = max(g2.flops - g1.flops, 0.0)
    d_bytes = max(g2.bytes_accessed - g1.bytes_accessed, 0.0)
    # collective deltas bucketed by (kind, bytes, group, axis)
    def bucket(colls):
        d = Counter()
        for c in colls:
            d[(c.kind, c.out_bytes, c.group_size, c.axis, c.f32)] += c.count
        return d

    b_full, b1, b2 = bucket(full.collectives), bucket(g1.collectives), \
        bucket(g2.collectives)
    total = Counter(b_full)
    for key in set(b2) | set(b1):
        delta = b2.get(key, 0) - b1.get(key, 0)
        if delta > 0:
            total[key] += delta * extra
    colls = [Collective(k, nb, g, ax, cnt, f32=f32)
             for (k, nb, g, ax, f32), cnt in total.items() if cnt > 0]
    return CellCost(
        flops=full.flops + extra * d_flops,
        bytes_accessed=full.bytes_accessed + extra * d_bytes,
        collectives=colls,
        temp_bytes=full.temp_bytes, arg_bytes=full.arg_bytes,
        out_bytes=full.out_bytes)
