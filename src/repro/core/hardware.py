"""System models for the oracle (paper §4.2–4.4).

The paper parametrizes a cluster by Hockney α–β per interconnect level plus
per-PE compute throughput; levels here map to the TPU reality (ICI axes
intra-pod, DCI across pods) or to the CPU host used for the measured
validation runs. ``contention``(φ) divides a level's bandwidth by the number
of logical flows sharing it (paper §4.3 contention modeling, self-contention
only).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class Level:
    """One interconnect level with Hockney parameters."""

    name: str
    alpha: float          # startup seconds per message
    beta: float           # seconds per byte (1 / bandwidth)

    def p2p(self, nbytes: float, phi: float = 1.0) -> float:
        return self.alpha + nbytes * self.beta * phi

    def allreduce_ring(self, p: int, nbytes: float, phi: float = 1.0) -> float:
        """T_ar = 2(p−1)(α + (m/p)·δβ·φ) — paper §4.3."""
        if p <= 1:
            return 0.0
        return 2 * (p - 1) * (self.alpha + nbytes / p * self.beta * phi)

    def allgather_ring(self, p: int, nbytes: float, phi: float = 1.0) -> float:
        """T_ag = (p−1)(α + (m/p)·δβ·φ); m = full gathered size."""
        if p <= 1:
            return 0.0
        return (p - 1) * (self.alpha + nbytes / p * self.beta * phi)

    def reduce_scatter_ring(self, p: int, nbytes: float, phi: float = 1.0) -> float:
        if p <= 1:
            return 0.0
        return (p - 1) * (self.alpha + nbytes / p * self.beta * phi)

    def alltoall(self, p: int, nbytes: float, phi: float = 1.0) -> float:
        if p <= 1:
            return 0.0
        return (p - 1) * (self.alpha + nbytes / p * self.beta * phi)

    def allreduce_tree(self, p: int, nbytes: float, k: int = 4,
                       phi: float = 1.0) -> float:
        """Small-message tree: 2(log p + k)(α + m/2k·β) — paper footnote 4."""
        import math
        if p <= 1:
            return 0.0
        return 2 * (math.log2(p) + k) * (self.alpha + nbytes / (2 * k) * self.beta * phi)

    def allreduce(self, p: int, nbytes: float, phi: float = 1.0) -> float:
        """Ring for large messages, tree for small (NCCL/ICI practice)."""
        if nbytes < 65536:
            return min(self.allreduce_tree(p, nbytes, phi=phi),
                       self.allreduce_ring(p, nbytes, phi))
        return self.allreduce_ring(p, nbytes, phi)

    # -- vectorized variants (oracle sweep engine; p/nbytes may be arrays) --

    def allreduce_v(self, p, nbytes, phi: float = 1.0, k: int = 4):
        """``allreduce`` over numpy arrays of (p, nbytes); broadcasts."""
        p = np.asarray(p, np.float64)
        m = np.asarray(nbytes, np.float64)
        safe_p = np.where(p > 0, p, 1.0)
        ring = 2.0 * (p - 1) * (self.alpha + m / safe_p * self.beta * phi)
        tree = 2.0 * (np.log2(np.where(p > 1, p, 2.0)) + k) * (
            self.alpha + m / (2 * k) * self.beta * phi)
        out = np.where(m < 65536, np.minimum(tree, ring), ring)
        return np.where(p <= 1, 0.0, out)


@dataclass(frozen=True)
class SystemModel:
    """A machine: per-PE compute + interconnect levels keyed by mesh axis."""

    name: str
    peak_flops: float               # per-PE peak (bf16 for TPU)
    hbm_bw: float                   # per-PE memory bandwidth
    mem_capacity: float             # per-PE memory bytes
    compute_efficiency: float       # fraction of peak for dense matmul
    levels: tuple                   # ((axis_name, Level), ...)

    def level(self, axis: str) -> Level:
        for name, lvl in self.levels:
            if name == axis:
                return lvl
        # default to the slowest level
        return self.levels[-1][1]

    def flops_time(self, flops: float) -> float:
        return flops / (self.peak_flops * self.compute_efficiency)


# TPU v5e pod: ICI 2D torus ~50 GB/s/link per axis, DCI between pods.
TPU_V5E_POD = SystemModel(
    name="tpu-v5e-256",
    peak_flops=197e12, hbm_bw=819e9, mem_capacity=16e9,
    compute_efficiency=0.55,
    levels=(
        ("model", Level("ici-x", alpha=1e-6, beta=1 / 45e9)),
        ("data", Level("ici-y", alpha=1e-6, beta=1 / 45e9)),
        ("pod", Level("dci", alpha=10e-6, beta=1 / 25e9)),
    ))

# The paper's own system (ABCI-like: V100s, NVLink intra-node, IB inter-node)
PAPER_V100_CLUSTER = SystemModel(
    name="v100-abci",
    peak_flops=125e12, hbm_bw=900e9, mem_capacity=16e9,
    compute_efficiency=0.35,
    levels=(
        ("model", Level("nvlink", alpha=5e-6, beta=1 / 20e9)),
        ("data", Level("ib-edr", alpha=15e-6, beta=1 / 12.5e9)),
        ("pod", Level("ib-rack", alpha=25e-6, beta=1 / 4.2e9)),
    ))


def cpu_host_model(alpha: float = 3e-5, beta: float = 1 / 8e9,
                   flops: float = 5e10, efficiency: float = 1.0) -> SystemModel:
    """The measured-validation target: virtual host devices on this CPU.

    Defaults are placeholders — core/calibration.py measures the real values
    (paper §4.4 empirical parametrization).
    """
    lvl = Level("shm", alpha=alpha, beta=beta)
    return SystemModel(
        name="cpu-host", peak_flops=flops, hbm_bw=30e9, mem_capacity=8e9,
        compute_efficiency=efficiency,
        levels=(("model", lvl), ("data", lvl), ("pod", lvl)))
