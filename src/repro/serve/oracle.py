"""Oracle rows for serving: price TTFT / latency percentiles / tok/s.

Same move as the training oracle (paper §4, arXiv 2104.09075) — analytic
compute + α–β communication from the machine description — but the
quantity priced is request latency under traffic, not step time:

  * per-token decode cost comes from differentiating the fitted
    per-sample FLOPs polynomial a·S + b·S² (core/oracle.seq_flops_coeffs):
    token at context L costs a + 2bL FLOPs, roofline'd against weight +
    KV reads from HBM (decode is bandwidth-bound at small batch);
  * prefill integrates the same polynomial over the prompt
    (compute-bound);
  * each replica of ``p2`` model-parallel PEs is an M/D/1 queue serving
    ``max_batch`` requests concurrently: deterministic service time
    T = t_prefill + gen_len·t_decode, arrival rate λ/p1, utilization
    ρ = λT/(p1·max_batch), mean wait Wq = ρ/(2μ(1−ρ)) with an
    exponential-tail read-off for percentiles (p50 = ln2·Wq,
    p99 = ln100·Wq).

Strategies price the two serving rules tables (parallel/strategies.py):
``serve_tp`` (Megatron-style tensor parallel, 2 collectives/layer, KV
sharded over heads) and ``serve_seqkv`` (sequence-sharded KV /
flash-decoding, 3 collectives/layer for the extra LSE merge, KV sharded
over the cache span). ``serve_tune`` sweeps (strategy, p1·p2, kv_shards,
max_batch) and picks the highest-throughput plan meeting the p99 SLO.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

__all__ = ["SERVE_STRATEGIES", "ServeProjection", "ServePlan",
           "kv_bytes_per_token", "price_serving", "serve_sweep",
           "serve_tune"]

SERVE_STRATEGIES = ("serve_tp", "serve_seqkv")

# collectives per transformer layer per token-batch (fw only — no grads)
_COLLS = {"serve_tp": 2, "serve_seqkv": 3}

_LN2, _LN100 = math.log(2.0), math.log(100.0)


def kv_bytes_per_token(mc, dtype_bytes: int = 2) -> int:
    """Analytic K+V bytes one token pins in the cache, summed over layers.

    Mirrors what ``serve.kv_cache.cache_geometry`` measures on the real
    cache tree, but from the config alone (the oracle sweep must stay
    jax-free). Only attention layers are paged-servable, matching the
    engine's geometry gate.
    """
    pattern = getattr(mc, "pattern", None) or ("attn",)
    n_layers = getattr(mc, "n_layers", 0)
    total = 0
    for i in range(n_layers):
        kind = pattern[i % len(pattern)]
        ac = None
        if kind == "attn":
            ac = getattr(mc, "attn", None)
        elif kind == "local":
            ac = getattr(mc, "local_attn", None) or getattr(mc, "attn", None)
        if ac is None:
            raise ValueError(
                f"layer kind {kind!r} has no pageable KV cache — the "
                "serving oracle prices attention-only models")
        total += 2 * ac.n_kv_heads * ac.head_dim * dtype_bytes
    return total


@dataclass(frozen=True)
class ServeProjection:
    """One priced serving configuration (one row of the serve sweep)."""

    strategy: str
    p1: int                 # data-parallel replicas
    p2: int                 # model-parallel width per replica
    kv_shards: int          # cache span shards (1 | p2)
    max_batch: int          # continuous-batch width per replica
    t_prefill: float        # s, one mean prompt through one replica
    t_decode: float         # s, one decode step of the full batch
    rho: float              # replica utilization (λ·T / (p1·max_batch))
    ttft_p50: float
    ttft_p99: float
    latency_p50: float
    latency_p99: float
    tok_per_s: float        # deployment decode-token capacity
    mem_bytes: float        # per-PE weights + KV footprint
    feasible: bool
    limit: str = ""         # why not, when infeasible

    def meets(self, slo_p99: float) -> bool:
        return self.feasible and self.latency_p99 <= slo_p99

    def describe(self) -> str:
        if not self.feasible:
            return (f"{self.strategy:<11} p1={self.p1:<3} p2={self.p2:<3} "
                    f"kv={self.kv_shards:<3} B={self.max_batch:<3} "
                    f"infeasible ({self.limit})")
        return (f"{self.strategy:<11} p1={self.p1:<3} p2={self.p2:<3} "
                f"kv={self.kv_shards:<3} B={self.max_batch:<3} "
                f"rho={self.rho:5.2f} ttft_p50={self.ttft_p50 * 1e3:8.2f}ms "
                f"p99={self.latency_p99 * 1e3:9.2f}ms "
                f"tok/s={self.tok_per_s:10.1f}")


def price_serving(mc, system, strategy: str, p1: int, p2: int,
                  kv_shards: int, max_batch: int, traffic, *,
                  max_len: int | None = None, dtype_bytes: int = 2,
                  prefill_chunk: int = 32) -> ServeProjection:
    """Price one (strategy, p1, p2, kv_shards, max_batch) configuration
    under ``traffic`` (a TrafficModel). ``system``: SystemModel or
    ClusterSpec."""
    from ..core.oracle import seq_flops_coeffs
    sysm = getattr(system, "system", system)
    max_len = max_len or _round_up(traffic.prompt_len + traffic.gen_len, 64)

    def bail(why):
        return ServeProjection(strategy, p1, p2, kv_shards, max_batch,
                               0.0, 0.0, float("inf"), float("inf"),
                               float("inf"), float("inf"), float("inf"),
                               0.0, 0.0, False, why)

    # -- structural feasibility of the rules table on this width ----------
    ac = getattr(mc, "attn", None)
    if ac is None:
        return bail("no attention config")
    if strategy == "serve_tp":
        if kv_shards != 1:
            return bail("serve_tp shards KV over heads; kv_shards must be 1")
        if ac.n_kv_heads % p2 or ac.n_heads % p2:
            return bail(f"heads ({ac.n_heads}/{ac.n_kv_heads}) % p2 != 0")
    elif strategy == "serve_seqkv":
        if kv_shards != p2:
            return bail("serve_seqkv shards the cache span; kv_shards == p2")
        if max_len % p2:
            return bail(f"max_len {max_len} % p2 != 0")
    else:
        raise ValueError(f"unknown serving strategy {strategy!r}")

    a, b = seq_flops_coeffs(mc, max_len)
    kv_tok = kv_bytes_per_token(mc, dtype_bytes)
    w_bytes = _weight_bytes(mc, max_len, dtype_bytes)
    lp, lg = traffic.prompt_len, traffic.gen_len
    mean_ctx = traffic.mean_context
    d = mc.d_model
    n_layers = mc.n_layers
    level = sysm.level("model")
    eff = sysm.peak_flops * sysm.compute_efficiency

    # KV divides across the replica iff the strategy actually shards it
    kv_div = p2 if (strategy == "serve_seqkv"
                    or (strategy == "serve_tp" and p2 > 1)) else 1

    # -- memory gate -------------------------------------------------------
    mem = (w_bytes / p2
           + max_batch * max_len * kv_tok / kv_div)
    if mem > sysm.mem_capacity:
        return bail(f"per-PE mem {mem / 1e9:.2f} GB > "
                    f"{sysm.mem_capacity / 1e9:.2f} GB")

    # -- prefill: compute-bound pass over the prompt -----------------------
    flops_pf = a * lp + b * lp * lp
    chunks = max(-(-lp // prefill_chunk), 1)
    comm_pf = (_COLLS[strategy] * n_layers
               * level.allreduce(p2, lp * d * dtype_bytes))
    t_pf = max(flops_pf / (p2 * eff),
               chunks * (w_bytes / p2) / sysm.hbm_bw) + comm_pf

    # -- decode: roofline of marginal FLOPs vs weight + KV reads -----------
    flops_dec = max_batch * (a + 2 * b * mean_ctx)
    bytes_dec = (w_bytes / p2
                 + max_batch * mean_ctx * kv_tok / kv_div)
    comm_dec = (_COLLS[strategy] * n_layers
                * level.allreduce(p2, max_batch * d * dtype_bytes))
    t_dec = max(flops_dec / (p2 * eff), bytes_dec / sysm.hbm_bw) + comm_dec

    # -- M/D/1 queue per replica ------------------------------------------
    t_req = t_pf + lg * t_dec                  # deterministic service time
    mu = max_batch / t_req                     # replica service rate, req/s
    lam = traffic.rate / p1
    rho = lam / mu
    cap_tok = p1 * max_batch * lg / t_req      # deployment token capacity
    if rho >= 1.0:
        return ServeProjection(strategy, p1, p2, kv_shards, max_batch,
                               t_pf, t_dec, rho, float("inf"), float("inf"),
                               float("inf"), float("inf"), cap_tok,
                               mem, False, f"overloaded (rho={rho:.2f})")
    wq = rho / (2 * mu * (1 - rho))            # M/D/1 mean queue wait
    return ServeProjection(
        strategy, p1, p2, kv_shards, max_batch, t_pf, t_dec, rho,
        ttft_p50=_LN2 * wq + t_pf, ttft_p99=_LN100 * wq + t_pf,
        latency_p50=_LN2 * wq + t_req, latency_p99=_LN100 * wq + t_req,
        tok_per_s=cap_tok, mem_bytes=mem, feasible=True)


def serve_sweep(mc, system, p: int, traffic, *,
                strategies=SERVE_STRATEGIES,
                max_batches=(1, 2, 4, 8, 16, 32),
                max_len: int | None = None,
                dtype_bytes: int = 2) -> "list[ServeProjection]":
    """Every (strategy, p1·p2 = p, kv_shards, max_batch) row priced."""
    rows = []
    for p2 in _divisors(p):
        p1 = p // p2
        for strat in strategies:
            kv = 1 if strat == "serve_tp" else p2
            for mb in max_batches:
                rows.append(price_serving(
                    mc, system, strat, p1, p2, kv, mb, traffic,
                    max_len=max_len, dtype_bytes=dtype_bytes))
    return rows


@dataclass(frozen=True)
class ServePlan:
    """serve_tune's answer: the winning row + the best alternative."""

    winner: ServeProjection
    runner_up: "ServeProjection | None"
    slo_p99: float
    meets_slo: bool
    rows: tuple                    # full priced sweep, ranked

    def describe(self) -> str:
        head = ("plan meets p99 SLO" if self.meets_slo else
                "NO plan meets the p99 SLO — least-bad row")
        lines = [f"{head} ({self.slo_p99 * 1e3:.0f} ms):",
                 "  " + self.winner.describe()]
        if self.runner_up is not None:
            lines.append("  runner-up:")
            lines.append("  " + self.runner_up.describe())
        return "\n".join(lines)


def _rank_key(r: ServeProjection):
    # max tok/s, then tightest p99, then narrowest replica, serve_tp first
    return (-r.tok_per_s, r.latency_p99, r.p2,
            0 if r.strategy == "serve_tp" else 1, r.p1)


def serve_tune(mc, system, p: int, traffic, slo_p99: float,
               **sweep_kw) -> ServePlan:
    """Highest-throughput feasible plan meeting the p99 latency SLO.

    Falls back to the minimum-p99 feasible row (flagged ``meets_slo=False``)
    when nothing meets the SLO, so callers always get a deployable plan
    plus the evidence of the miss.
    """
    rows = serve_sweep(mc, system, p, traffic, **sweep_kw)
    ok = sorted((r for r in rows if r.meets(slo_p99)), key=_rank_key)
    if ok:
        return ServePlan(ok[0], ok[1] if len(ok) > 1 else None,
                         slo_p99, True, tuple(ok))
    feas = sorted((r for r in rows if r.feasible),
                  key=lambda r: (r.latency_p99, -r.tok_per_s))
    if not feas:
        raise ValueError(
            f"no feasible serving configuration at p={p} for {traffic} "
            f"(every row: memory-gated or overloaded)")
    return ServePlan(feas[0], feas[1] if len(feas) > 1 else None,
                     slo_p99, False, tuple(feas))


# ---------------------------------------------------------------------------
def _divisors(p: int) -> "list[int]":
    return [k for k in range(1, p + 1) if p % k == 0]


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def _weight_bytes(mc, seq: int, dtype_bytes: int) -> float:
    from ..core.autotune import stats_for_model
    return float(sum(st.w for st in stats_for_model(mc, seq))) * dtype_bytes
