"""SLO-aware serving engine: continuous batching over a paged KV cache.

One ``Engine`` owns a shared block pool (serve/kv_cache.py), a FIFO
request queue with admission control, and two jitted cells:

  * ``prefill``: one ``prefill_chunk``-token chunk of ONE sequence per
    engine step — long prompts prefill across several steps, interleaved
    with decode, so a new arrival never stalls in-flight decodes for its
    whole prompt (the phase separation vLLM-style engines use);
  * ``decode``: one token for EVERY live sequence at once — sequences
    join/leave the shared batch per step (continuous batching), each at
    its own depth via the per-sequence ``pos`` vector the generalized
    ``Attention.decode`` accepts.

Both cells gather the paged pool into the dense view the existing
attention path consumes, run ``model.decode_step``, and scatter back only
the touched blocks; the pool is donated (``donate_argnums``) so XLA
updates it in place instead of copying the full cache every token.

Batch membership is invisible to the math: every per-token op (embed,
norms, FFN, per-row attention against the row's own cache view) touches
one batch row, so a sequence decoded alongside strangers emits bit-exact
the tokens it emits alone — pinned by tests/test_serve.py.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field, replace

import numpy as np

from . import kv_cache as kvc

__all__ = ["ServeConfig", "Request", "RequestStats", "ServeReport",
           "Engine"]


@dataclass(frozen=True)
class ServeConfig:
    """Engine shape knobs (all static — they pick the compiled cells)."""

    max_len: int                 # per-sequence capacity (prompt + gen)
    max_batch: int = 4           # decode slots (continuous-batch width)
    block_tokens: int = 16       # paged-cache allocation granularity
    num_blocks: int | None = None  # pool size; None → every slot can fill
    prefill_chunk: int = 32      # prompt tokens prefilled per engine step
    kv_shards: int = 1           # cache layout (1 | mesh model size)
    dtype: object = None         # cache dtype; None → bfloat16


@dataclass(frozen=True)
class Request:
    rid: int
    prompt: np.ndarray           # (L,) int32 token ids
    max_new: int
    arrival: float = 0.0         # trace time (seconds from replay start)


@dataclass
class RequestStats:
    rid: int
    arrival: float
    prompt_len: int
    max_new: int
    admitted: float = 0.0
    first_token: float = 0.0     # engine-clock time of token 1 (TTFT ref)
    finished: float = 0.0
    tokens: list = field(default_factory=list)

    @property
    def ttft(self) -> float:
        return self.first_token - self.arrival

    @property
    def latency(self) -> float:
        return self.finished - self.arrival


@dataclass
class ServeReport:
    requests: list
    wall_s: float

    @property
    def n_tokens(self) -> int:
        return sum(len(r.tokens) for r in self.requests)

    @property
    def tok_per_s(self) -> float:
        return self.n_tokens / max(self.wall_s, 1e-9)

    def percentile(self, q: float, what: str = "latency") -> float:
        vals = [getattr(r, what) for r in self.requests]
        return float(np.percentile(vals, q)) if vals else 0.0

    def summary(self) -> dict:
        return {
            "requests": len(self.requests),
            "tokens": self.n_tokens,
            "wall_s": self.wall_s,
            "tok_per_s": self.tok_per_s,
            "ttft_p50_s": self.percentile(50, "ttft"),
            "ttft_p99_s": self.percentile(99, "ttft"),
            "latency_p50_s": self.percentile(50),
            "latency_p99_s": self.percentile(99),
        }


class _Seq:
    """One live sequence: its slot, block ownership and progress."""

    __slots__ = ("req", "stats", "blocks", "prompt_pad", "cursor", "pos",
                 "last_token", "phase")

    def __init__(self, req, stats, blocks, prompt_pad):
        self.req = req
        self.stats = stats
        self.blocks = blocks
        self.prompt_pad = prompt_pad   # (Lp_pad,) chunk-padded prompt
        self.cursor = 0                # prefill progress (tokens)
        self.pos = 0                   # next write position
        self.last_token = 0
        self.phase = "prefill"


class Engine:
    """Continuous-batching engine over one (model × params × ctx) cell."""

    def __init__(self, model, params, ctx, cfg: ServeConfig, *, seed: int = 0):
        import jax
        import jax.numpy as jnp
        self._jnp = jnp
        dtype = cfg.dtype or jnp.bfloat16
        self.model, self.params, self.ctx, self.cfg = model, params, ctx, cfg
        if not (hasattr(model, "decode_step") and hasattr(model, "prefill")):
            raise ValueError(f"{type(model).__name__} has no decode path")
        geo = kvc.cache_geometry(model, cfg.max_len, shards=cfg.kv_shards,
                                 block_tokens=cfg.block_tokens, dtype=dtype)
        C = cfg.prefill_chunk
        if C % geo.bspan or geo.span % C:
            raise ValueError(
                f"prefill_chunk={C} must be a multiple of the block span "
                f"{geo.bspan} and divide the cache span {geo.span}")
        self.geo = geo
        num_blocks = cfg.num_blocks or cfg.max_batch * geo.n_blk + 1
        self.alloc = kvc.BlockAllocator(num_blocks)
        # zeros come straight from the spec — one materialization per buffer
        from ..nn.module import tree_init
        self.pool = tree_init(kvc.pool_spec(model, geo, num_blocks, dtype),
                              jax.random.PRNGKey(seed))
        self.tables = np.full((cfg.max_batch, geo.n_blk), kvc.NULL_BLOCK,
                              np.int32)
        self.slots: list = [None] * cfg.max_batch
        self.queue: deque = deque()
        self.finished: list = []
        self._t0 = time.perf_counter()

        def prefill_cell(params, tokens, pool, table_row, p0):
            dense = kvc.gather_view(pool, table_row)
            logits, dense = model.decode_step(params, tokens, dense, p0, ctx)
            j0 = (p0 % geo.span) // geo.bspan
            jidx = j0[:, None] + jnp.arange(C // geo.bspan)[None]
            return logits, kvc.scatter_blocks(pool, table_row, dense, jidx)

        def decode_cell(params, tokens, pool, tables, pos):
            dense = kvc.gather_view(pool, tables)
            logits, dense = model.decode_step(params, tokens, dense, pos,
                                              ctx)
            jidx = ((pos % geo.span) // geo.bspan)[:, None]
            pool = kvc.scatter_blocks(pool, tables, dense, jidx)
            return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32), pool

        # donate the pool: in-place block updates instead of a full copy
        self._prefill = jax.jit(prefill_cell, donate_argnums=(2,))
        self._decode = jax.jit(decode_cell, donate_argnums=(2,))

    def reset(self) -> None:
        """Forget every request — fresh replay on the same compiled cells
        (measurement warm-up). Pool contents become garbage until
        rewritten, which the attention valid mask already never exposes."""
        self.alloc = kvc.BlockAllocator(self.alloc.num_blocks)
        self.tables[:] = kvc.NULL_BLOCK
        self.slots = [None] * self.cfg.max_batch
        self.queue.clear()
        self.finished = []
        self._t0 = time.perf_counter()

    # -- bookkeeping -------------------------------------------------------
    def _now(self) -> float:
        return time.perf_counter() - self._t0

    @property
    def n_live(self) -> int:
        return sum(s is not None for s in self.slots)

    @property
    def idle(self) -> bool:
        return not self.queue and self.n_live == 0

    def submit(self, req: Request) -> None:
        Lp = len(req.prompt)
        if Lp < 1 or req.max_new < 1:
            raise ValueError("empty prompt / zero generation")
        C = self.cfg.prefill_chunk
        lp_pad = -(-Lp // C) * C
        if lp_pad + req.max_new > self.cfg.max_len:
            raise ValueError(
                f"request {req.rid} needs {lp_pad}+{req.max_new} tokens "
                f"(prompt chunk-padded) > max_len={self.cfg.max_len}")
        if self.geo.blocks_for(lp_pad + req.max_new) > self.alloc.capacity:
            raise ValueError(
                f"request {req.rid} can never fit: needs "
                f"{self.geo.blocks_for(lp_pad + req.max_new)} blocks, pool "
                f"holds {self.alloc.capacity}")
        self.queue.append(req)

    def _try_admit(self) -> None:
        """FIFO admission: a request enters when a decode slot is free AND
        the pool can cover its whole footprint (prompt + generation) —
        admitted sequences can then never deadlock on blocks."""
        while self.queue:
            free_slots = [i for i, s in enumerate(self.slots) if s is None]
            if not free_slots:
                return
            req = self.queue[0]
            C = self.cfg.prefill_chunk
            lp_pad = -(-len(req.prompt) // C) * C
            ids = self.alloc.alloc(self.geo.blocks_for(lp_pad + req.max_new))
            if ids is None:
                return                      # head-of-line waits for evicts
            self.queue.popleft()
            slot = free_slots[0]
            pad = np.zeros(lp_pad, np.int32)
            pad[:len(req.prompt)] = np.asarray(req.prompt, np.int32)
            stats = RequestStats(req.rid, req.arrival, len(req.prompt),
                                 req.max_new, admitted=self._now())
            self.slots[slot] = _Seq(req, stats, ids, pad)
            self.tables[slot] = kvc.NULL_BLOCK
            self.tables[slot, :len(ids)] = ids


    def _evict(self, slot: int) -> None:
        seq = self.slots[slot]
        seq.stats.finished = self._now()
        self.finished.append(seq.stats)
        self.alloc.free(seq.blocks)
        self.tables[slot] = kvc.NULL_BLOCK
        self.slots[slot] = None

    # -- the engine step ---------------------------------------------------
    def step(self) -> int:
        """One iteration: admit → one prefill chunk → one decode batch
        step. Returns the number of tokens emitted."""
        jnp = self._jnp
        self._try_admit()
        emitted = 0

        # prefill: one chunk of the oldest prefilling sequence
        pf = next((i for i, s in enumerate(self.slots)
                   if s is not None and s.phase == "prefill"), None)
        if pf is not None:
            seq = self.slots[pf]
            C = self.cfg.prefill_chunk
            chunk = seq.prompt_pad[seq.cursor:seq.cursor + C]
            logits, self.pool = self._prefill(
                self.params, jnp.asarray(chunk[None]), self.pool,
                jnp.asarray(self.tables[pf:pf + 1]),
                jnp.asarray([seq.cursor], jnp.int32))
            seq.cursor += C
            if seq.cursor >= len(seq.prompt_pad):
                last = seq.stats.prompt_len - 1 - (seq.cursor - C)
                tok = int(np.argmax(np.asarray(logits[0, last])))
                seq.stats.tokens.append(tok)
                seq.stats.first_token = self._now()
                seq.last_token = tok
                seq.pos = seq.stats.prompt_len
                seq.phase = "decode"
                emitted += 1
                if len(seq.stats.tokens) >= seq.req.max_new:
                    self._evict(pf)

        # decode: one token for every live decoding sequence
        live = [i for i, s in enumerate(self.slots)
                if s is not None and s.phase == "decode"]
        if live:
            tokens = np.zeros((self.cfg.max_batch, 1), np.int32)
            pos = np.zeros(self.cfg.max_batch, np.int32)
            # rows not decoding this step (free, or mid-prefill) are pointed
            # at the null block so their placeholder write can't land in a
            # real block — a mid-prefill row's real table would otherwise
            # get its chunk-1 K/V clobbered at block 0
            dtab = np.full_like(self.tables, kvc.NULL_BLOCK)
            for i in live:
                tokens[i, 0] = self.slots[i].last_token
                pos[i] = self.slots[i].pos
                dtab[i] = self.tables[i]
            toks, self.pool = self._decode(
                self.params, jnp.asarray(tokens), self.pool,
                jnp.asarray(dtab), jnp.asarray(pos))
            toks = np.asarray(toks)
            for i in live:
                seq = self.slots[i]
                tok = int(toks[i])
                seq.stats.tokens.append(tok)
                seq.last_token = tok
                seq.pos += 1
                emitted += 1
                if len(seq.stats.tokens) >= seq.req.max_new:
                    self._evict(i)
        return emitted

    # -- trace replay ------------------------------------------------------
    def run(self, requests, *, honor_arrivals: bool = True) -> ServeReport:
        """Replay a trace to completion. With ``honor_arrivals`` a request
        becomes visible only once the engine clock passes its arrival
        time (open-loop load, how the SLO validation drives it); without,
        everything is enqueued up front (closed-loop max throughput)."""
        pending = deque(sorted(requests, key=lambda r: r.arrival))
        self._t0 = time.perf_counter()
        while pending or not self.idle:
            t = self._now()
            while pending and (not honor_arrivals
                               or pending[0].arrival <= t):
                req = pending.popleft()
                if not honor_arrivals:
                    # closed-loop: latency counts from submission, not from
                    # the trace's (ignored) arrival stamps
                    req = replace(req, arrival=t)
                self.submit(req)
            if self.step() == 0 and self.n_live == 0 and not self.queue:
                if pending:
                    # nothing runnable yet: park until the next arrival
                    time.sleep(
                        max(pending[0].arrival - self._now(), 0.0))
        wall = self._now()
        done = sorted(self.finished, key=lambda s: s.rid)
        return ServeReport(requests=done, wall_s=wall)
