"""Paged/blocked KV cache for the serving engine (DESIGN.md §15).

The dense cache the attention path consumes is laid out per sequence as
``(B, shards, span, KV, HD)`` (nn/attention.py ``cache_spec``): flat token
``t`` lives at ``(shard t//span, slot t%span)``. Paging keeps the SAME
layout but chops the ``span`` dim into fixed ``bspan``-slot blocks held in
a shared pool:

    pool leaf:  (num_blocks, shards, bspan, KV, HD)     lead/tail layers
                (G, num_blocks, shards, bspan, KV, HD)  scanned stacks

Block ``j`` of a sequence covers slots ``[j·bspan, (j+1)·bspan)`` in EVERY
shard, i.e. ``block_tokens = shards·bspan`` tokens of capacity — so a
sequence of ``L`` tokens owns ``ceil(min(L, span)/bspan)`` blocks and the
rest of the pool is free for other sequences (the memory win vs a dense
``max_batch × max_len`` preallocation).

The pool's logical axes mirror the dense cache's (blocks replicated, the
``seq``-shards and ``act_kv`` dims keep their names), so the ``serve_tp``
and ``serve_seqkv`` rules tables shard the POOL exactly as they shard the
dense cache — and ``gather_view`` (a take over the replicated blocks axis)
reconstructs a dense view the existing ``Attention.decode`` consumes
unchanged. Exactness vs the dense path is gated by ``max_abs_diff`` /
tests/test_serve.py.

Allocation is host-side and O(1): a free-list ``BlockAllocator`` with
block 0 reserved as the null block — unallocated block-table entries point
at it, and writes landing there (inactive engine slots) are never read
back as valid positions (the attention valid mask covers them).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

import jax

from ..nn.module import ParamSpec, param

NULL_BLOCK = 0


# ---------------------------------------------------------------------------
# Host-side free-list allocator
# ---------------------------------------------------------------------------
class BlockAllocator:
    """Fixed pool of ``num_blocks`` blocks; block 0 is the reserved null
    block and is never handed out. ``alloc`` returns None on OOM (the
    engine's admission control backs off instead of crashing)."""

    def __init__(self, num_blocks: int):
        if num_blocks < 2:
            raise ValueError("need >= 2 blocks (block 0 is the null block)")
        self.num_blocks = num_blocks
        # pop() from the end hands out ascending ids first — deterministic
        # layouts for tests and reproducible traces
        self._free = list(range(num_blocks - 1, 0, -1))

    @property
    def capacity(self) -> int:
        return self.num_blocks - 1

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    def alloc(self, n: int) -> "list[int] | None":
        if n > len(self._free):
            return None
        out = [self._free.pop() for _ in range(n)]
        return out

    def free(self, ids) -> None:
        for i in ids:
            i = int(i)
            if not 0 < i < self.num_blocks:
                raise ValueError(f"block id {i} out of range")
            if i in self._free:
                raise ValueError(f"double free of block {i}")
            self._free.append(i)


# ---------------------------------------------------------------------------
# Geometry
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class CacheGeometry:
    """Shared shape facts of every attention cache leaf in the model."""

    shards: int        # cache shard dim (1 or the mesh model size)
    span: int          # slots per shard (max_len // shards)
    bspan: int         # slots per shard per block
    n_blk: int         # blocks per sequence (span // bspan)
    kv_bytes_per_token: int  # summed over layers, at shards' dtype

    @property
    def block_tokens(self) -> int:
        """Allocation granularity in tokens."""
        return self.shards * self.bspan

    @property
    def max_len(self) -> int:
        return self.shards * self.span

    def blocks_for(self, n_tokens: int) -> int:
        """Blocks a sequence of ``n_tokens`` (prompt+gen) occupies."""
        used = min(max(n_tokens, 1), self.span)
        return -(-used // self.bspan)


def _leaf_dims(ps: ParamSpec):
    """(batch_axis, shards, span, tail) of one dense cache leaf spec;
    raises for non-attention cache layouts (MLA latents, SSM states …)."""
    if len(ps.shape) == 5:
        b_ax = 0
    elif len(ps.shape) == 6 and ps.axes[0] == "layers":
        b_ax = 1
    else:
        raise ValueError(
            f"unsupported cache leaf {ps.shape} {ps.axes}: the paged pool "
            "serves GQA attention caches (B, shards, span, KV, HD) only")
    if ps.axes[b_ax:b_ax + 2] != ("batch", "seq"):
        raise ValueError(f"unexpected cache leaf axes {ps.axes}")
    return b_ax, ps.shape[b_ax + 1], ps.shape[b_ax + 2], ps.shape[b_ax + 3:]


def cache_geometry(model, max_len: int, *, shards: int = 1,
                   block_tokens: int = 16,
                   dtype=jnp.bfloat16) -> CacheGeometry:
    """Validate the model's cache tree for paging and derive the geometry.

    Every leaf must share (shards, span): windowed layers whose span was
    clamped below ``max_len`` (and non-attention caches) are rejected here —
    the single reason the serving engine gates on attention-only models.
    """
    spec = model.cache_spec(1, max_len, shards=shards, dtype=dtype)
    leaves = jax.tree.leaves(
        spec, is_leaf=lambda x: isinstance(x, ParamSpec))
    if not leaves:
        raise ValueError("model has an empty cache spec")
    geo = None
    kv_bytes = 0
    for ps in leaves:
        b_ax, sh, span, tail = _leaf_dims(ps)
        if geo is None:
            geo = (sh, span)
        elif geo != (sh, span):
            raise ValueError(
                f"non-uniform cache geometry {geo} vs {(sh, span)}: paged "
                "serving needs every layer's cache to share (shards, span) "
                "— windowed/local attention spans below max_len don't")
        n_layers = ps.shape[0] if b_ax == 1 else 1
        per_slot = int(np.prod(tail)) * jnp.dtype(dtype).itemsize
        kv_bytes += n_layers * sh * span * per_slot
    sh, span = geo
    if sh * span != max_len:
        raise ValueError(f"cache covers {sh * span} slots, want {max_len}")
    if block_tokens % sh:
        raise ValueError(f"block_tokens={block_tokens} must be a multiple "
                         f"of kv_shards={sh}")
    bspan = block_tokens // sh
    if span % bspan:
        raise ValueError(f"block span {bspan} must divide the cache span "
                         f"{span} (max_len/kv_shards)")
    return CacheGeometry(shards=sh, span=span, bspan=bspan,
                         n_blk=span // bspan,
                         kv_bytes_per_token=kv_bytes // max_len)


# ---------------------------------------------------------------------------
# Pool spec + gather/scatter views
# ---------------------------------------------------------------------------
def pool_spec(model, geo: CacheGeometry, num_blocks: int,
              dtype=jnp.bfloat16):
    """ParamSpec tree of the shared block pool — zeros-initializing, so
    ``tree_init`` materializes each buffer exactly once."""
    spec = model.cache_spec(1, geo.max_len, shards=geo.shards, dtype=dtype)

    def one(ps: ParamSpec) -> ParamSpec:
        b_ax, sh, _, tail = _leaf_dims(ps)
        lead = ps.shape[:b_ax]
        shape = lead + (num_blocks, sh, geo.bspan) + tail
        axes = ps.axes[:b_ax] + (None,) + ps.axes[b_ax + 1:]
        return param(shape, axes, init=lambda k, s, d: jnp.zeros(s, d),
                     dtype=ps.dtype)

    return jax.tree.map(one, spec,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


def gather_view(pool, tables: jnp.ndarray):
    """Dense cache view of the sequences in ``tables`` (B, n_blk) int32.

    A take over the pool's replicated blocks axis followed by a reshape —
    the sharded dims (seq shards, kv heads) pass through untouched, so the
    view carries the same layout the rules tables expect. Null-block
    entries materialize garbage at positions the attention valid mask
    (kpos <= pos) never exposes.
    """
    def one(leaf):
        if leaf.ndim == 5:                      # (NB, sh, bspan, KV, HD)
            g = jnp.take(leaf, tables, axis=0)  # (B, nblk, sh, bspan, ...)
            B, nblk, sh, bspan = g.shape[:4]
            return g.transpose(0, 2, 1, 3, 4, 5).reshape(
                B, sh, nblk * bspan, *g.shape[4:])
        g = jnp.take(leaf, tables, axis=1)      # (G, B, nblk, sh, bspan, .)
        G, B, nblk, sh, bspan = g.shape[:5]
        return g.transpose(0, 1, 3, 2, 4, 5, 6).reshape(
            G, B, sh, nblk * bspan, *g.shape[5:])

    return jax.tree.map(one, pool)


def scatter_blocks(pool, tables: jnp.ndarray, dense, jidx: jnp.ndarray):
    """Write blocks ``jidx`` (B, nj) of the dense view back into the pool.

    The decode step touches exactly one block per sequence, a prefill
    chunk a static range — so per step the pool write is O(touched
    blocks), not O(max_len). Rows parked on the null block (inactive
    engine slots) scatter garbage into block 0, which is never read back
    as a valid position.
    """
    nj = jidx.shape[1]
    ids = jnp.take_along_axis(tables, jidx, axis=1)      # (B, nj)

    def one(leaf, dl):
        if leaf.ndim == 5:
            B, sh, span = dl.shape[:3]
            nblk = tables.shape[1]
            bspan = span // nblk
            blocks = dl.reshape(B, sh, nblk, bspan, *dl.shape[3:])
            blocks = blocks.transpose(0, 2, 1, 3, 4, 5)  # (B,nblk,sh,...)
            idx = jidx.reshape(jidx.shape + (1,) * (blocks.ndim - 2))
            sel = jnp.take_along_axis(blocks, idx, axis=1)   # (B,nj,...)
            return leaf.at[ids.reshape(-1)].set(
                sel.reshape(-1, *sel.shape[2:]).astype(leaf.dtype))
        G, B, sh, span = dl.shape[:4]
        nblk = tables.shape[1]
        bspan = span // nblk
        blocks = dl.reshape(G, B, sh, nblk, bspan, *dl.shape[4:])
        blocks = blocks.transpose(0, 1, 3, 2, 4, 5, 6)   # (G,B,nblk,sh,...)
        idx = jidx.reshape((1,) + jidx.shape + (1,) * (blocks.ndim - 3))
        sel = jnp.take_along_axis(blocks, idx, axis=2)   # (G,B,nj,...)
        return leaf.at[:, ids.reshape(-1)].set(
            sel.reshape(G, -1, *sel.shape[3:]).astype(leaf.dtype))

    return jax.tree.map(one, pool, dense)


def max_abs_diff(pool, tables, dense, geo: CacheGeometry,
                 length: int) -> float:
    """Exactness gate: largest |paged − dense| over the first ``length``
    token positions of sequence rows in ``tables`` vs a dense reference
    cache. 0.0 ⇔ bit-exact (same dtype both sides)."""
    view = gather_view(pool, tables)
    worst = 0.0
    slot = np.arange(geo.max_len).reshape(geo.shards, geo.span)
    mask = slot < length                                  # (shards, span)

    def one(a, b):
        nonlocal worst
        a = np.asarray(jax.device_get(a), np.float32)
        b = np.asarray(jax.device_get(b), np.float32)
        sh_ax = a.ndim - 4                                # shards dim index
        m = mask.reshape((1,) * sh_ax + mask.shape + (1, 1))
        worst = max(worst, float(np.max(np.abs((a - b) * m))))

    jax.tree.map(one, view, dense)
    return worst
