"""Traffic model for serving: arrival rate × sequence-length distribution.

One ``TrafficModel`` is both the analytic input to the serving oracle
(``serve/oracle.py`` prices TTFT / latency percentiles under it) and a
synthetic trace generator for the engine (``trace()`` draws Poisson
arrivals with jittered prompt/generation lengths), so the oracle and the
measured replay consume literally the same workload description.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .engine import Request

__all__ = ["TrafficModel"]


@dataclass(frozen=True)
class TrafficModel:
    """Open-loop request stream against the whole deployment.

    ``rate``: mean arrivals per second (Poisson). ``prompt_len`` /
    ``gen_len``: mean lengths; ``spread`` jitters prompts uniformly over
    [mean·(1−spread), mean·(1+spread)] (generation lengths stay fixed so
    token counts — and thus measured tok/s — are deterministic per trace
    size).
    """

    rate: float
    prompt_len: int
    gen_len: int
    spread: float = 0.5

    def __post_init__(self):
        if self.rate <= 0 or self.prompt_len < 1 or self.gen_len < 1:
            raise ValueError(f"degenerate traffic model {self}")
        if not 0 <= self.spread < 1:
            raise ValueError(f"spread must be in [0, 1), got {self.spread}")

    @property
    def mean_context(self) -> float:
        """Average decode context length (prompt + half the generation)."""
        return self.prompt_len + self.gen_len / 2

    def trace(self, n: int, vocab: int, seed: int = 0) -> "list[Request]":
        """``n`` requests with Poisson arrivals at ``rate`` req/s."""
        rng = np.random.default_rng(seed)
        arrivals = np.cumsum(rng.exponential(1.0 / self.rate, size=n))
        lo = max(1, int(round(self.prompt_len * (1 - self.spread))))
        hi = max(lo, int(round(self.prompt_len * (1 + self.spread))))
        lens = rng.integers(lo, hi + 1, size=n)
        return [
            Request(rid=i,
                    prompt=rng.integers(1, vocab, size=int(lens[i]),
                                        dtype=np.int32),
                    max_new=self.gen_len,
                    arrival=float(arrivals[i]))
            for i in range(n)
        ]
