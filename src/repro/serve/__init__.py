"""SLO-aware serving: paged KV cache, continuous batching, serving oracle."""
from .engine import Engine, Request, RequestStats, ServeConfig, ServeReport
from .kv_cache import (NULL_BLOCK, BlockAllocator, CacheGeometry,
                       cache_geometry, gather_view, max_abs_diff, pool_spec,
                       scatter_blocks)
from .oracle import (SERVE_STRATEGIES, ServePlan, ServeProjection,
                     kv_bytes_per_token, price_serving, serve_sweep,
                     serve_tune)
from .traffic import TrafficModel

__all__ = [
    "Engine", "Request", "RequestStats", "ServeConfig", "ServeReport",
    "NULL_BLOCK", "BlockAllocator", "CacheGeometry", "cache_geometry",
    "gather_view", "max_abs_diff", "pool_spec", "scatter_blocks",
    "SERVE_STRATEGIES", "ServePlan", "ServeProjection",
    "kv_bytes_per_token", "price_serving", "serve_sweep", "serve_tune",
    "TrafficModel",
]
