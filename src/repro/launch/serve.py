"""Batched serving driver: prefill + greedy decode loop with KV caches.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-32b --smoke \
        --batch 4 --prompt-len 32 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config
from ..nn.module import ShardingCtx, tree_init
from ..parallel.strategies import make_rules
from ..training.steps import make_decode_step, make_prefill_step
from .build import build_model
from .mesh import make_host_mesh


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--strategy", default="serve_tp",
                    help="rules-table name, or 'auto' to let the oracle "
                         "auto-tuner pick the serving layout")
    ap.add_argument("--kv-shards", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    # machine description for --strategy auto (ClusterSpec flags)
    from ..core.cluster import add_cluster_args
    add_cluster_args(ap, default_system="host")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if cfg.family not in ("lm", "vlm"):
        raise SystemExit(f"serving demo supports lm/vlm archs, not {cfg.family}")
    model = build_model(cfg, smoke=args.smoke)
    mc = cfg.smoke_model if args.smoke else cfg.model
    lm_cfg = mc.lm if cfg.family == "vlm" else mc
    strategy = args.strategy
    if strategy == "auto":
        # the tuner picks the hybrid split; serving deploys its model width
        from ..core.autotune import autotune, stats_for_model
        from ..core.cluster import ClusterSpec
        from ..core.oracle import TimeModel
        n = len(jax.devices())
        B = args.batch
        cluster = ClusterSpec.from_cli_args(args)
        # switches=None: the serving exec path deploys no memory switches
        # (no optimizer to ZeRO-shard, no backward to remat), so the plan
        # must not claim feasibility through them
        # allow_pipeline=False: every pipeline schedule (gpipe / 1F1B /
        # interleaved) is a training schedule (fill/drain over
        # microbatches) — serving must never rank them
        plan = autotune(stats_for_model(mc, args.prompt_len + args.gen),
                        TimeModel(cluster.system),
                        cluster.oracle_config(B=B, D=B), n,
                        fallback="serve_tp", cluster=cluster,
                        switches=None, allow_pipeline=False)
        print(plan.describe())
        strategy = plan.exec_strategy("decode")
        mesh = make_host_mesh(model=plan.p2 if n % plan.p2 == 0 else None)
    else:
        mesh = make_host_mesh()
    ctx = ShardingCtx(mesh, make_rules(strategy))

    key = jax.random.PRNGKey(args.seed)
    params = tree_init(model.params_spec(), key)
    max_len = args.prompt_len + args.gen
    cache = jax.tree.map(
        jnp.zeros_like,
        tree_init(model.cache_spec(args.batch, max_len, shards=args.kv_shards),
                  key))
    prompt = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                lm_cfg.vocab)

    prefill = jax.jit(make_prefill_step(model, ctx, scan_layers=True,
                                        q_chunk=min(256, args.prompt_len)))
    decode = jax.jit(make_decode_step(model, ctx, scan_layers=True))

    t0 = time.time()
    if cfg.family == "vlm":
        patches = jax.random.normal(
            key, (args.batch, mc.n_patches, mc.d_vision))
        logits, cache = prefill(params, {"patches": patches, "tokens": prompt},
                                cache)
        pos0 = mc.n_patches + args.prompt_len
    else:
        logits, cache = prefill(params, {"tokens": prompt}, cache)
        pos0 = args.prompt_len
    t_prefill = time.time() - t0

    toks = [jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)]
    t0 = time.time()
    for i in range(args.gen - 1):
        lg, cache = decode(params, toks[-1][:, None], cache,
                           jnp.int32(pos0 + i))
        toks.append(jnp.argmax(lg[:, -1], axis=-1).astype(jnp.int32))
    jax.block_until_ready(toks[-1])
    t_decode = time.time() - t0
    out = jnp.stack(toks, axis=1)
    print(f"prefill {args.batch}x{args.prompt_len} in {t_prefill*1e3:.1f}ms; "
          f"decode {args.gen-1} steps in {t_decode*1e3:.1f}ms "
          f"({(args.gen-1)*args.batch/max(t_decode,1e-9):.1f} tok/s)")
    print("generated token ids (first row):", np.asarray(out[0]))


if __name__ == "__main__":
    main()
