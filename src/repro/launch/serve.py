"""Serving CLI: the continuous-batching engine behind a traffic replay.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-32b --smoke \
        --rate 4 --prompt-len 32 --gen 16 --requests 8

Thin glue only — the engine (repro/serve/engine.py) owns the request
queue, the paged KV pool and the jitted prefill/decode cells (donated
cache, zeros allocated straight from the pool spec); this file resolves
the arch + strategy, shapes the mesh, generates the trace and prints the
report. ``--strategy auto`` asks the training auto-tuner for the serving
layout and, when the winner's model width cannot tile the device count,
falls back to the best plan over widths that can (``model_widths``) —
with a warning, never by silently dropping the model axis.
"""
from __future__ import annotations

import argparse
import json
import time
import warnings

import jax
import numpy as np

from ..configs import get_config
from ..nn.module import ShardingCtx, tree_init
from ..parallel.strategies import make_rules
from ..serve import Engine, ServeConfig, TrafficModel
from .build import build_model
from .mesh import make_host_mesh


def resolve_auto_strategy(mc, args, n: int):
    """Tuner-picked serving layout: (strategy name, model width).

    Re-tunes over the divisors of ``n`` when the unconstrained winner's
    p2 cannot tile the mesh — the runner-up that tiles replaces it.
    """
    from ..core.autotune import autotune, stats_for_model
    from ..core.cluster import ClusterSpec
    from ..core.oracle import TimeModel
    cluster = ClusterSpec.from_cli_args(args)
    stats = stats_for_model(mc, args.prompt_len + args.gen)
    B = args.max_batch
    # switches=None: the serving exec path deploys no memory switches
    # (no optimizer to ZeRO-shard, no backward to remat), so the plan
    # must not claim feasibility through them.
    # allow_pipeline=False: every pipeline schedule is a training
    # schedule (fill/drain over microbatches) — serving never ranks them.
    kw = dict(fallback="serve_tp", cluster=cluster, switches=None,
              allow_pipeline=False)
    plan = autotune(stats, TimeModel(cluster.system),
                    cluster.oracle_config(B=B, D=B), n, **kw)
    if n % plan.p2:
        tiling = tuple(k for k in range(1, n + 1) if n % k == 0)
        warnings.warn(
            f"tuned model width p2={plan.p2} cannot tile {n} devices; "
            f"re-tuning over widths {tiling} for the best plan that does",
            stacklevel=2)
        plan = autotune(stats, TimeModel(cluster.system),
                        cluster.oracle_config(B=B, D=B), n,
                        model_widths=tiling, **kw)
    print(plan.describe())
    return plan.exec_strategy("decode"), plan.p2


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--strategy", default="serve_tp",
                    help="serve_tp | serve_seqkv | a rules-table name | "
                         "'auto' (oracle auto-tuner picks the layout)")
    ap.add_argument("--max-batch", type=int, default=4,
                    help="continuous-batch width (decode slots)")
    ap.add_argument("--max-len", type=int, default=None,
                    help="per-sequence KV capacity "
                         "(default: padded prompt + gen)")
    ap.add_argument("--block-tokens", type=int, default=16,
                    help="paged-cache allocation granularity")
    ap.add_argument("--prefill-chunk", type=int, default=16,
                    help="prompt tokens prefilled per engine step")
    ap.add_argument("--kv-shards", type=int, default=None,
                    help="cache span shards (default: mesh model size "
                         "for serve_seqkv, else 1)")
    # traffic
    ap.add_argument("--rate", type=float, default=8.0,
                    help="request arrival rate (req/s); the trace replays "
                         "open-loop against it")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--closed-loop", action="store_true",
                    help="enqueue the whole trace up front (max-throughput "
                         "mode, ignores arrival times)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json-out", default=None,
                    help="write the report summary as JSON")
    from ..core.cluster import add_cluster_args
    add_cluster_args(ap, default_system="host")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if cfg.family != "lm":
        raise SystemExit(
            f"the serving engine decodes lm archs, not {cfg.family}")
    model = build_model(cfg, smoke=args.smoke)
    mc = cfg.smoke_model if args.smoke else cfg.model
    n = len(jax.devices())

    strategy, width = args.strategy, n
    if strategy == "auto":
        strategy, width = resolve_auto_strategy(mc, args, n)
    mesh = make_host_mesh(model=width)
    ctx = ShardingCtx(mesh, make_rules(strategy))
    kv_shards = args.kv_shards if args.kv_shards is not None else (
        int(mesh.shape.get("model", 1)) if strategy == "serve_seqkv" else 1)

    traffic = TrafficModel(rate=args.rate, prompt_len=args.prompt_len,
                           gen_len=args.gen)
    trace = traffic.trace(args.requests, mc.vocab, seed=args.seed)
    chunk = args.prefill_chunk
    max_prompt = max(len(r.prompt) for r in trace)
    max_len = args.max_len or (-(-max_prompt // chunk) * chunk + args.gen)
    # geometry alignment: the per-shard span must be a multiple of both the
    # block span and the prefill chunk — a multiple of chunk·shards covers
    # both (chunk is itself a whole number of block spans)
    align = chunk * kv_shards
    max_len = -(-max_len // align) * align

    scfg = ServeConfig(max_len=max_len, max_batch=args.max_batch,
                       block_tokens=args.block_tokens, prefill_chunk=chunk,
                       kv_shards=kv_shards)
    params = tree_init(model.params_spec(), jax.random.PRNGKey(args.seed))
    t0 = time.time()
    eng = Engine(model, params, ctx, scfg, seed=args.seed)
    print(f"engine up in {time.time() - t0:.1f}s: {eng.geo}, "
          f"{eng.alloc.capacity} blocks, strategy={strategy}, "
          f"mesh={dict(mesh.shape)}")

    report = eng.run(trace, honor_arrivals=not args.closed_loop)
    summary = report.summary()
    print(json.dumps(summary, indent=1))
    first = report.requests[0] if report.requests else None
    if first is not None:
        print(f"first request's tokens: {np.asarray(first.tokens)}")
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump({"strategy": strategy, "mesh": dict(mesh.shape),
                       "config": {"max_batch": scfg.max_batch,
                                  "max_len": scfg.max_len,
                                  "block_tokens": scfg.block_tokens,
                                  "prefill_chunk": scfg.prefill_chunk,
                                  "kv_shards": scfg.kv_shards},
                       **summary}, f, indent=1)
        print(f"wrote {args.json_out}")


if __name__ == "__main__":
    main()
