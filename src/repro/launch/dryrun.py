import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e).

For every (architecture × input shape) and both production meshes
(16×16 single-pod; 2×16×16 multi-pod), this driver:

  1. lowers + compiles the full step with scan-over-layers
     (proves sharding coherence; prints memory_analysis + cost_analysis),
  2. compiles 1-group and 2-group unrolled variants under identical
     shardings (exact per-layer-group HLO cost),
  3. combines them (core/hlo_analysis.combine) and derives the roofline
     terms (core/roofline), writing one JSON artifact per cell.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import numpy as np

from ..configs import ASSIGNED_ARCHS, SHAPES, get_config
from ..core.hlo_analysis import combine, cost_of
from ..core.roofline import roofline
from ..models.transformer import TransformerLM
from ..models.vlm import VLM
from ..models.encdec import EncDecLM
from ..nn.module import tree_num_params
from .build import build_cell
from .compat import make_mesh
from .mesh import make_production_mesh


def default_strategy(cfg, shape_name: str) -> str:
    kind = SHAPES[shape_name].kind
    if shape_name in cfg.shape_strategy:
        return cfg.shape_strategy[shape_name]
    if kind in ("decode", "prefill"):
        return "ep_df" if cfg.strategy == "ep_df" else "serve_tp"
    return cfg.strategy


def _pattern_period(model) -> int:
    if isinstance(model, TransformerLM):
        return len(model.cfg.pattern)
    if isinstance(model, VLM):
        return len(model.cfg.lm.pattern)
    return 1


def model_flops_of(model, shape, kind: str) -> float:
    """MODEL_FLOPS = 6·N·D (train), 2·N·D (prefill), 2·N·B (decode);
    N = active params for MoE. Enc-dec models don't fit the 6·N·D shorthand
    (the encoder sees T_enc=1500 frames, not the 32k decoder positions), so
    they use the oracle's per-layer stats instead."""
    if isinstance(model, EncDecLM):
        from ..core.layer_stats import encdec_stats
        S = shape.seq_len if kind != "decode" else 1
        S = min(S, model.cfg.max_target_positions) if kind == "train" else S
        stats = encdec_stats(model.cfg, S if kind != "prefill" else 1)
        fwd = sum(s.flops_fwd for s in stats)
        B = shape.global_batch
        return B * fwd * (3.0 if kind == "train" else 1.0)
    n = tree_num_params(model.params_spec())
    lm_cfg = getattr(model, "cfg", None)
    moe = getattr(lm_cfg, "moe", None)
    if moe is None and hasattr(lm_cfg, "lm"):
        moe = lm_cfg.lm.moe
    if moe is not None:
        # subtract the inactive routed-expert fraction
        expert_params = 0
        per_expert = moe.d_ff * moe.d_model * (3 if moe.glu else 2)
        n_moe_layers = 0
        if isinstance(model, TransformerLM):
            n_moe_layers = sum(1 for k in model.cfg.block_kinds() if k == "moe")
        routed = per_expert * moe.n_experts * n_moe_layers
        active = per_expert * moe.top_k * n_moe_layers
        n = n - routed + active
    B, S = shape.global_batch, shape.seq_len
    if kind == "train":
        return 6.0 * n * B * S
    if kind == "prefill":
        return 2.0 * n * B * S
    return 2.0 * n * B  # decode: one token per sequence


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: Path,
             strategy: str | None = None, kv_shards: int | None = None,
             tag: str = "", verbose: bool = True,
             mesh_shape: str | None = None, cluster=None) -> dict:
    """``cluster``: optional ClusterSpec the auto-tuner plans against
    (α–β/φ/σ + torus placement constraints); default stays the TPU-v5e
    deployment target."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    strategy = strategy or default_strategy(cfg, shape_name)
    plan = None
    if strategy == "auto":
        # oracle-in-the-loop: the auto-tuner picks (strategy, p1·p2 split,
        # memory switches) for the chip count of the mesh that will actually
        # be built, then (absent an explicit override) the mesh is
        # refactorized to the plan's split — multi-pod keeps its leading
        # DCI axis of 2, so the plan's p1 must split across it
        from .build import mesh_device_count
        from ..core.autotune import plan_for_arch
        if mesh_shape:
            chips_planned = int(np.prod([int(x) for x in mesh_shape.split("x")]))
        else:
            chips_planned = mesh_device_count(
                make_production_mesh(multi_pod=multi_pod))
        plan = plan_for_arch(cfg, shape_name, chips_planned, cluster=cluster)
        strategy = plan.exec_strategy(shape.kind)
        if mesh_shape is None:
            if not multi_pod:
                mesh_shape = f"{plan.p1}x{plan.p2}"
            elif plan.p1 % 2 == 0:
                mesh_shape = f"2x{plan.p1 // 2}x{plan.p2}"
            # else: production mesh stands; only the plan's strategy and
            # switches deploy (the p1·p2 split is unrealizable across DCI)
        print(f"[{arch} × {shape_name}] {plan.describe()}")
    if mesh_shape:
        # oracle-guided logical refactorization of the same 256-chip pod
        # (e.g. "64x4": DP=64 x TP=4) — §Perf optimized variants only;
        # the required table uses the fixed production meshes.
        dims = tuple(int(x) for x in mesh_shape.split("x"))
        names = ("data", "model") if len(dims) == 2 else ("pod", "data", "model")
        mesh = make_mesh(dims, names)
        mesh_name = f"pod{mesh_shape}"
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
        mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    chips = int(np.prod(list(mesh.shape.values())))
    if kv_shards is None:
        kv_shards = cfg.serve_kv_shards if shape.kind in ("decode", "prefill") \
            else 1
    t0 = time.time()
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "strategy": strategy, "kv_shards": kv_shards, "tag": tag,
           "chips": chips}
    if plan is not None:
        # did the built mesh actually realize the plan's factorization?
        # (False under an explicit --mesh-shape override, or multi-pod with
        # a p1 that can't split across the DCI axis) — the report's
        # cross-check must not attribute the plan's split to this mesh then
        ms = dict(mesh.shape)
        deployed = (ms.get("model", 1) == plan.p2
                    and chips // ms.get("model", 1) == plan.p1)
        rec["plan"] = {"strategy": plan.strategy, "p1": plan.p1,
                       "p2": plan.p2, "split_deployed": deployed,
                       "switches": plan.switch_str(),
                       **plan.switches,     # the four booleans, by name
                       "schedule": plan.schedule,
                       "per_iter_s": plan.per_iter_s,
                       "bottleneck": plan.bottleneck,
                       "feasible": plan.feasible}

    # 1. full scanned step ---------------------------------------------------
    cell = build_cell(cfg, shape_name, mesh, strategy, scan_layers=True,
                      kv_shards=kv_shards, plan=plan)
    # decode/prefill donate the cache (in-place KV update — serving reality);
    # train donates the train state.
    donate = {"train": (0,), "prefill": (2,), "decode": (2,)}[cell.kind]
    lowered = jax.jit(cell.step_fn, donate_argnums=donate).lower(*cell.args)
    compiled = lowered.compile()
    full = cost_of(compiled, dict(mesh.shape))
    ma = compiled.memory_analysis()
    if verbose:
        print(f"[{arch} × {shape_name} × {mesh_name}] strategy={strategy}")
        print(f"  memory_analysis: args={ma.argument_size_in_bytes/2**30:.2f}GiB "
              f"temp={ma.temp_size_in_bytes/2**30:.2f}GiB "
              f"out={ma.output_size_in_bytes/2**30:.2f}GiB")
        print(f"  cost_analysis(full-scan): flops/chip={full.flops:.3e} "
              f"bytes/chip={full.bytes_accessed:.3e}")

    # 2. 1-group / 2-group unrolled variants ---------------------------------
    period = _pattern_period(cell.model)
    n_groups = cell.n_scan_groups
    if strategy == "pipeline":
        # the pipeline step owns the whole stack (stages = mesh model
        # axis, any schedule); a 1-layer override cannot cut into the same
        # stage count, so the full-scan cost stands un-extrapolated
        total = full
    elif n_groups > 1:
        g_cells = []
        for k in (1, 2):
            c = build_cell(cfg, shape_name, mesh, strategy, scan_layers=False,
                           unroll_attn=True, kv_shards=kv_shards,
                           override_layers=k * period, plan=plan)
            g_cells.append(cost_of(jax.jit(c.step_fn).lower(*c.args).compile(),
                                   dict(mesh.shape)))
        total = combine(full, g_cells[0], g_cells[1], n_groups)
    else:
        total = full

    # 3. roofline -------------------------------------------------------------
    mf = model_flops_of(cell.model, shape, cell.kind)
    rl = roofline(total, chips, mf, kind=cell.kind)
    rec.update(
        kind=cell.kind,
        n_params=tree_num_params(cell.model.params_spec()),
        compile_s=round(time.time() - t0, 1),
        memory={"args_gib": ma.argument_size_in_bytes / 2**30,
                "temp_gib": ma.temp_size_in_bytes / 2**30,
                "out_gib": ma.output_size_in_bytes / 2**30},
        cost=total.to_json(),
        cost_full_scan_only=full.to_json(),
        roofline=rl.to_json())
    if verbose:
        print(f"  roofline: compute={rl.compute_s*1e3:.2f}ms "
              f"memory={rl.memory_s*1e3:.2f}ms "
              f"collective={rl.collective_s*1e3:.2f}ms "
              f"dominant={rl.dominant} useful={rl.useful_ratio:.2f} "
              f"frac={rl.roofline_fraction:.3f}  ({rec['compile_s']}s)")
    out_dir.mkdir(parents=True, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    path = out_dir / f"{arch}__{shape_name}__{mesh_name}{suffix}.json"
    path.write_text(json.dumps(rec, indent=1))
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--strategy", default=None)
    ap.add_argument("--kv-shards", type=int, default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--mesh-shape", default=None,
                    help="override mesh, e.g. 64x4 (oracle-guided variants)")
    from ..core.cluster import add_cluster_args
    add_cluster_args(ap, default_system="tpu")
    args = ap.parse_args()
    from ..core.cluster import ClusterSpec
    cluster = ClusterSpec.from_cli_args(args)
    out = Path(args.out)

    cells = []
    archs = [args.arch] if args.arch else list(ASSIGNED_ARCHS)
    for arch in archs:
        cfg = get_config(arch)
        shapes = [args.shape] if args.shape else cfg.shapes()
        for shape in shapes:
            if shape in cfg.skipped_shapes():
                print(f"SKIP {arch} × {shape}: {cfg.skipped_shapes()[shape]}")
                continue
            meshes = [args.multi_pod]
            if args.both_meshes or args.all:
                meshes = [False, True]
            for mp in meshes:
                cells.append((arch, shape, mp))

    failures = []
    for arch, shape, mp in cells:
        mesh_name = "pod2x16x16" if mp else "pod16x16"
        suffix = f"__{args.tag}" if args.tag else ""
        if args.skip_existing and \
                (out / f"{arch}__{shape}__{mesh_name}{suffix}.json").exists():
            continue
        try:
            run_cell(arch, shape, mp, out, strategy=args.strategy,
                     kv_shards=args.kv_shards, tag=args.tag,
                     mesh_shape=args.mesh_shape, cluster=cluster)
        except Exception as e:  # noqa: BLE001 — report, continue, fail at end
            failures.append((arch, shape, mp, repr(e)))
            print(f"FAIL {arch} × {shape} multi_pod={mp}: {e}")
            traceback.print_exc(limit=3)
    print(f"\n{len(cells) - len(failures)}/{len(cells)} cells passed")
    if failures:
        for f in failures:
            print("  FAILED:", f[:3])
        raise SystemExit(1)


if __name__ == "__main__":
    main()
