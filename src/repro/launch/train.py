"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-32b --smoke \
        --steps 200 --batch 16 --seq 128 --strategy df

Builds the (smoke or full) model, a deterministic sharded loader, the jitted
train step under the chosen strategy's rules, and runs the fault-tolerant
loop (checkpoint/restart, straggler watch). On this CPU box use --smoke; on
a real pod the same driver runs the full configs.
"""
from __future__ import annotations

import argparse
import time

import jax

from ..configs import get_config
from ..checkpoint.checkpointing import Checkpointer, config_hash
from ..data.pipeline import DataConfig, ShardedLoader
from ..models.cnn import CosmoFlowConfig, ResNetConfig, VGGConfig
from ..models.encdec import EncDecConfig
from ..models.transformer import LMConfig
from ..models.vlm import VLMConfig
from ..nn.module import ShardingCtx, tree_init
from ..optim.optimizers import OptimizerConfig
from ..parallel.strategies import make_rules
from ..runtime.fault_tolerance import run_with_recovery
from ..training.steps import make_train_step, train_state_spec
from .build import build_model
from .mesh import make_host_mesh


def data_config_for(mc, batch: int, seq: int, seed: int = 0) -> DataConfig:
    if isinstance(mc, LMConfig):
        return DataConfig("lm", batch, seq_len=seq, vocab=mc.vocab, seed=seed)
    if isinstance(mc, EncDecConfig):
        return DataConfig("encdec", batch, seq_len=min(seq, mc.max_target_positions),
                          vocab=mc.vocab, frames=mc.max_source_positions,
                          d_frames=mc.d_model, seed=seed)
    if isinstance(mc, VLMConfig):
        return DataConfig("vlm", batch, seq_len=seq, vocab=mc.lm.vocab,
                          n_patches=mc.n_patches, d_vision=mc.d_vision,
                          seed=seed)
    if isinstance(mc, (ResNetConfig, VGGConfig)):
        img = getattr(mc, "img", 224)
        return DataConfig("image", batch, image=img, classes=mc.n_classes,
                          seed=seed)
    if isinstance(mc, CosmoFlowConfig):
        return DataConfig("volume", batch, image=mc.img, channels=mc.in_ch,
                          n_targets=mc.n_targets, seed=seed)
    raise TypeError(type(mc))


def _main_elastic(args, cfg, mc, model) -> None:
    """--elastic: the oracle-guided elastic loop (runtime/elastic.py).

    The Oracle session owns the machine description the cluster flags
    build; the controller tunes for the live device count, and on
    SliceLost (slice death, or ``--straggler-patience`` consecutive
    straggler alerts) it degrades the ClusterSpec, re-tunes, reshards the
    checkpoint plan-to-plan, and resumes."""
    from ..api import Oracle
    from ..core.cluster import ClusterSpec
    from ..runtime.elastic import run_elastic
    ses = Oracle(cfg, "train_4k", ClusterSpec.from_cli_args(args),
                 smoke=args.smoke, batch=args.batch, seq=args.seq)
    fwd_kw = {}
    if cfg.family in ("lm", "vlm"):
        fwd_kw = dict(scan_layers=args.scan_layers, attn_impl="chunked",
                      q_chunk=min(256, args.seq))
    opt = OptimizerConfig(lr=args.lr)     # zero1 follows each plan's switch
    ckpt = Checkpointer(f"{args.ckpt_dir}/{args.arch}",
                        config_tag=config_hash((args.arch, args.smoke)))
    dcfg = data_config_for(mc, args.batch, args.seq, args.seed)

    t_start = time.time()
    losses = []

    def on_metrics(s, m):
        losses.append(float(m["loss"]))
        if s % args.log_every == 0:
            print(f"step {s:5d} loss {float(m['loss']):.4f} "
                  f"grad_norm {float(m['grad_norm']):.3f} "
                  f"({(time.time()-t_start):.1f}s)", flush=True)

    state, final, events = run_elastic(
        ses, dcfg, ckpt, n_steps=args.steps, model=model, opt=opt,
        ckpt_every=args.ckpt_every, seed=args.seed, fwd_kw=fwd_kw,
        straggler_patience=args.straggler_patience, on_metrics=on_metrics)
    for ev in events:
        print(f"elastic event @ step {ev.step}: {ev.cause}, "
              f"p {ev.p_before}→{ev.p_after}, re-tuned {ev.strategy} "
              f"(mesh {ev.mesh_shape[0]}x{ev.mesh_shape[1]}), resumed "
              f"from step {ev.resumed_from}")
    if losses:
        print(f"done at step {final}; loss {losses[0]:.4f} → "
              f"{losses[-1]:.4f} ({len(events)} elastic event(s))")
    else:
        print(f"done at step {final}; no new steps "
              f"(checkpoint already at --steps)")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--strategy", default="df",
                    help="rules-table name, or 'auto' to let the oracle "
                         "auto-tuner pick strategy/mesh/memory switches")
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--schedule", default="auto",
                    help="pipeline schedule: gpipe | one_f_one_b | "
                         "interleaved; 'auto' follows the tuned plan "
                         "(--strategy auto) or gpipe otherwise")
    ap.add_argument("--virtual-stages", type=int, default=2,
                    help="v for the interleaved schedule (chunks per rank)")
    ap.add_argument("--segments", type=int, default=None,
                    help="requested microbatch count S for pipeline "
                         "schedules (default: the tuned plan's, else 8); "
                         "the step resolves the largest deployable S <= "
                         "this and reports it in metrics")
    ap.add_argument("--ckpt-dir", default="checkpoints")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--scan-layers", action="store_true", default=True)
    ap.add_argument("--elastic", action="store_true",
                    help="oracle-guided elastic loop (runtime/elastic.py): "
                         "tune for the current devices; on slice loss or "
                         "repeated stragglers, re-tune on the surviving "
                         "ClusterSpec, reshard the checkpoint plan-to-plan "
                         "and resume (DESIGN.md §12)")
    ap.add_argument("--straggler-patience", type=int, default=3,
                    help="--elastic: consecutive StragglerAlerts before the "
                         "loop checkpoints and remeshes around the slow host")
    # machine description for --strategy auto (default: the host box;
    # --cluster takes a fitted experiments/cluster_fit.json artifact)
    from ..core.cluster import add_cluster_args
    add_cluster_args(ap, default_system="host")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    mc = cfg.smoke_model if args.smoke else cfg.model
    model = build_model(cfg, smoke=args.smoke)
    if args.elastic:
        return _main_elastic(args, cfg, mc, model)
    strategy, plan = args.strategy, None
    if strategy == "auto":
        # oracle-in-the-loop: tune (strategy, mesh split, memory switches)
        # for the machine the cluster flags describe (default: this box),
        # then deploy the plan (DESIGN.md §8/§11)
        from ..core.autotune import autotune, stats_for_model
        from ..core.cluster import ClusterSpec
        from ..core.oracle import TimeModel
        from ..parallel.pipeline import (pipeline_block_count,
                                         pipeline_supported)
        n = len(jax.devices())
        cluster = ClusterSpec.from_cli_args(args)
        plan = autotune(stats_for_model(mc, args.seq),
                        TimeModel(cluster.system),
                        cluster.oracle_config(
                            B=args.batch, D=args.batch,
                            virtual_stages=max(args.virtual_stages, 1)), n,
                        schedules=("all" if args.schedule == "auto"
                                   else (args.schedule,)),
                        fallback=cfg.strategy, cluster=cluster,
                        allow_remat=cfg.family != "cnn",
                        allow_pipeline=pipeline_supported(mc) is None,
                        max_stages=pipeline_block_count(mc))
        print(plan.describe())
        strategy = plan.exec_strategy("train")
        mesh = make_host_mesh(model=plan.p2 if n % plan.p2 == 0 else None)
        opt = OptimizerConfig(lr=args.lr, zero1=plan.zero1)
    else:
        mesh = make_host_mesh()
        opt = OptimizerConfig(lr=args.lr, zero1=strategy != "pipeline")
    rules = make_rules(strategy)
    ctx = ShardingCtx(mesh, rules)

    fwd_kw = {}
    if cfg.family in ("lm", "vlm"):
        fwd_kw = dict(scan_layers=args.scan_layers, attn_impl="chunked",
                      q_chunk=min(256, args.seq))
    if plan is not None and cfg.family in ("lm", "vlm", "encdec"):
        fwd_kw["remat"] = plan.remat    # deploy the plan's remat switch
    if strategy == "pipeline":
        # stage schedule (gpipe / 1F1B / interleaved) over the mesh's model
        # axis; S = what the plan's projection assumed (default 8) — the
        # step resolves the largest deployable S <= requested and surfaces
        # it in metrics (pipeline_segments); stage cuts = the DP
        # partitioner over per-block costs
        from ..core.autotune import stats_for_model
        from ..parallel.pipeline import (make_pipeline_train_step,
                                         pipeline_block_costs)
        if args.accum != 1:
            raise SystemExit("--accum > 1 is not supported with "
                             "--strategy pipeline (the pipeline "
                             "microbatches are the accumulation schedule)")
        seg = args.segments or (plan.segments if plan is not None else 8)
        schedule = args.schedule
        virtual = max(args.virtual_stages, 1)
        if plan is not None:
            schedule = plan.schedule if schedule == "auto" else schedule
            virtual = plan.virtual_stages
        elif schedule == "auto":
            schedule = "gpipe"
        costs = pipeline_block_costs(model, stats_for_model(mc, args.seq),
                                     **fwd_kw)
        print(f"pipeline schedule={schedule}"
              + (f" v={virtual}" if schedule == "interleaved" else "")
              + f" segments<={seg}")
        step = jax.jit(make_pipeline_train_step(
            model, opt, ctx, block_costs=costs, segments=seg,
            schedule=schedule, virtual_stages=virtual,
            **fwd_kw), donate_argnums=(0,))
    else:
        step = jax.jit(make_train_step(model, opt, ctx, accum=args.accum,
                                       **fwd_kw), donate_argnums=(0,))
    sspec = train_state_spec(model, opt)
    state = tree_init(sspec, jax.random.PRNGKey(args.seed))

    dcfg = data_config_for(mc, args.batch, args.seq, args.seed)
    loader = ShardedLoader(dcfg, mesh)
    ckpt = Checkpointer(f"{args.ckpt_dir}/{args.arch}",
                        config_tag=config_hash((args.arch, args.smoke)))

    t_start = time.time()
    losses = []

    def on_metrics(s, m):
        losses.append(float(m["loss"]))
        if s % args.log_every == 0:
            print(f"step {s:5d} loss {float(m['loss']):.4f} "
                  f"grad_norm {float(m['grad_norm']):.3f} "
                  f"({(time.time()-t_start):.1f}s)", flush=True)

    start = ckpt.latest_step() or 0
    if start:
        state, start = ckpt.restore(state)
        print(f"resumed from step {start}")
    state, final = run_with_recovery(
        step, state, loader, ckpt, n_steps=args.steps, start_step=start,
        ckpt_every=args.ckpt_every, on_metrics=on_metrics)
    if losses:
        print(f"done at step {final}; loss {losses[0]:.4f} → {losses[-1]:.4f}")
    else:   # resumed at/past --steps: zero new steps this run
        print(f"done at step {final}; no new steps "
              f"(checkpoint already at --steps)")


if __name__ == "__main__":
    main()
