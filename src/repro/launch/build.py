"""Cell builder: (arch × shape × mesh × strategy) → step fn + abstract inputs.

Shared by the dry-run, the trainer, the server and the benchmarks — one
source of truth for how a cell is assembled. ``input_specs`` produces
ShapeDtypeStruct stand-ins (weak-type-correct, shardable, zero allocation).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from ..configs.base import SHAPES, ArchConfig, ShapeSpec
from ..models.cnn import CosmoFlow, CosmoFlowConfig, ResNet, ResNetConfig, VGG, VGGConfig
from ..models.encdec import EncDecConfig, EncDecLM
from ..models.transformer import LMConfig, TransformerLM
from ..models.vlm import VLM, VLMConfig
from ..nn.module import Rules, ShardingCtx, spec_to_pspec, tree_abstract
from ..optim.optimizers import OptimizerConfig, zero1_rules
from ..parallel.strategies import make_rules
from ..training.steps import (make_decode_step, make_prefill_step,
                              make_train_step, train_state_spec)


def build_model(cfg: ArchConfig, smoke: bool = False):
    mc = cfg.smoke_model if smoke else cfg.model
    if isinstance(mc, LMConfig):
        return TransformerLM(mc)
    if isinstance(mc, EncDecConfig):
        return EncDecLM(mc)
    if isinstance(mc, VLMConfig):
        return VLM(mc)
    if isinstance(mc, ResNetConfig):
        return ResNet(mc)
    if isinstance(mc, VGGConfig):
        return VGG(mc)
    if isinstance(mc, CosmoFlowConfig):
        return CosmoFlow(mc)
    raise TypeError(type(mc))


def _shard(mesh, rules, shape, axes, dtype):
    if mesh is None:
        return jax.ShapeDtypeStruct(shape, dtype)
    pspec = spec_to_pspec(axes, rules, mesh, shape)
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, pspec))


def batch_specs(cfg: ArchConfig, shape: ShapeSpec, mesh, rules: Rules,
                smoke: bool = False) -> dict:
    """Abstract training/prefill batch for this arch family."""
    mc = cfg.smoke_model if smoke else cfg.model
    B, S = shape.global_batch, shape.seq_len
    tok = lambda s: _shard(mesh, rules, s, ("batch", None), jnp.int32)
    if cfg.family == "lm":
        return {"tokens": tok((B, S))}
    if cfg.family == "encdec":
        frames = _shard(mesh, rules, (B, mc.max_source_positions, mc.d_model),
                        ("batch", None, None), jnp.float32)
        return {"frames": frames,
                "tokens": tok((B, min(S, mc.max_target_positions)))}
    if cfg.family == "vlm":
        patches = _shard(mesh, rules, (B, mc.n_patches, mc.d_vision),
                         ("batch", None, None), jnp.float32)
        return {"patches": patches, "tokens": tok((B, S - mc.n_patches))}
    raise ValueError(f"batch_specs for family {cfg.family}")


def cnn_batch_specs(cfg: ArchConfig, global_batch: int, mesh, rules: Rules,
                    smoke: bool = False) -> dict:
    mc = cfg.smoke_model if smoke else cfg.model
    if isinstance(mc, CosmoFlowConfig):
        img = _shard(mesh, rules, (global_batch, mc.img, mc.img, mc.img, mc.in_ch),
                     ("batch", "spatial", None, None, None), jnp.float32)
        tgt = _shard(mesh, rules, (global_batch, mc.n_targets),
                     ("batch", None), jnp.float32)
        return {"images": img, "targets": tgt}
    img_size = getattr(mc, "img", 224)
    img = _shard(mesh, rules, (global_batch, img_size, img_size, 3),
                 ("batch", "spatial", None, None), jnp.float32)
    lab = _shard(mesh, rules, (global_batch,), ("batch",), jnp.int32)
    return {"images": img, "labels": lab}


@dataclass
class BuiltCell:
    arch: str
    shape: str
    strategy: str
    model: Any
    ctx: ShardingCtx
    step_fn: Any          # jittable
    args: tuple           # abstract (or concrete) arguments for step_fn
    kind: str             # train | prefill | decode
    n_scan_groups: int    # for HLO cost extrapolation
    meta: dict


def _scan_groups(model) -> int:
    if isinstance(model, TransformerLM):
        _, g, _ = model._groups()
        return g
    if isinstance(model, EncDecLM):
        return model.cfg.n_enc_layers  # == n_dec_layers for whisper
    if isinstance(model, VLM):
        _, g, _ = TransformerLM(model.cfg.lm)._groups()
        return g
    return 0


def mesh_device_count(mesh) -> int:
    """Total PEs a (possibly absent) mesh spans."""
    return 1 if mesh is None else int(mesh.size)


def build_cell(cfg: ArchConfig, shape_name: str, mesh, strategy: str | None = None,
               *, smoke: bool = False, scan_layers: bool = True,
               unroll_attn: bool = False, kv_shards: int = 1,
               q_chunk: int = 1024, kv_chunk: int = 1024,
               opt: OptimizerConfig | None = None, accum: int = 1,
               override_layers: int | None = None, plan=None,
               system=None, use_pallas: bool = False,
               kernel_tiles=None) -> BuiltCell:
    """Assemble one (arch × shape) cell under a strategy on a mesh.

    ``use_pallas`` routes CNN convolutions through the implicit-GEMM Pallas
    kernel (interpret-mode fallback off-TPU) — see ShardingCtx.use_pallas.

    ``kernel_tiles`` pins tuned Pallas block sizes (kernels.autotune).
    Resolution order when ``use_pallas``: explicit argument → the plan's
    ``kernel_tiles`` → the committed experiments/kernel_tune.json (validated
    against ``system``'s fingerprint when ``system`` is a ClusterSpec; a
    stale artifact warns and deploys kernel defaults).

    ``strategy="auto"`` asks the oracle: the sweep-driven auto-tuner
    (core/autotune.py) picks the cheapest feasible (strategy, p1·p2 split,
    memory switches) for this arch × shape at the mesh's device count, and
    the cell deploys that ``TunedPlan`` (executable rules table + ZeRO-1
    optimizer setting derived from the plan's switches — never from
    substring-matching the strategy name). Pass ``plan`` to reuse a plan
    already computed (e.g. by a launch driver that also shaped the mesh
    from it); ``system`` overrides the tuner's machine model — a
    SystemModel or a ClusterSpec (whose torus topology then prunes splits
    the machine cannot host). The session facade (``repro.api.Oracle``)
    calls this with its own plan; prefer ``Oracle(...).build(mesh)`` in new
    code.
    """
    shape = SHAPES[shape_name]
    strategy = strategy or cfg.strategy_for(shape_name)
    if strategy == "auto" and plan is None:
        # the mesh is already shaped, so hybrid plans are constrained to the
        # model width this mesh can realize — the plan's split (and its
        # memory claim) always matches what the rules will actually deploy
        from ..core.autotune import plan_for_arch
        grid = (None if mesh is None or "model_r" not in mesh.shape
                else (mesh.shape["model_r"], mesh.shape["model_c"]))
        plan = plan_for_arch(
            cfg, shape_name, mesh_device_count(mesh), system=system,
            smoke=smoke,
            model_width=None if mesh is None else mesh.shape.get("model"),
            model_grid=grid)
    if plan is not None:
        strategy = plan.exec_strategy(shape.kind)
        if opt is None:
            opt = OptimizerConfig(zero1=plan.zero1)
    rules = make_rules(strategy)
    opt = opt or OptimizerConfig(zero1="zero1" in strategy)
    mc = cfg.smoke_model if smoke else cfg.model
    if override_layers is not None:
        mc = _with_layers(mc, override_layers)
        cfg = dataclasses.replace(cfg, model=mc, smoke_model=mc)
    model = build_model(cfg, smoke=smoke)
    if use_pallas and kernel_tiles is None:
        if plan is not None and getattr(plan, "kernel_tiles", None) is not None:
            kernel_tiles = plan.kernel_tiles
        else:
            from ..kernels.autotune import load_tiles
            cluster = system if hasattr(system, "fingerprint") else None
            tiles = load_tiles(cluster=cluster)
            kernel_tiles = tiles if len(tiles) else None
    ctx = ShardingCtx(mesh, rules, use_pallas=use_pallas,
                      kernel_tiles=kernel_tiles)
    kw = {} if cfg.family == "cnn" else dict(scan_layers=scan_layers)
    if cfg.family in ("lm", "vlm"):
        kw.update(q_chunk=q_chunk, kv_chunk=kv_chunk)
        if unroll_attn:
            kw.update(unroll_attn=True)
    if plan is not None and cfg.family in ("lm", "vlm", "encdec"):
        # deploy the plan's remat switch (CNN forwards can't checkpoint;
        # the tuner never selects remat for them — deployable_switch_mask)
        kw["remat"] = plan.remat
    meta = {"strategy": strategy, "family": cfg.family, "opt": opt}
    if plan is not None:
        meta["plan"] = plan

    if shape.kind == "train":
        if cfg.family in ("lm", "vlm") and unroll_attn:
            kw["attn_impl"] = "chunked"
        if strategy == "pipeline":
            # stage schedule (gpipe / 1F1B / interleaved — the plan says
            # which) over the mesh's model axis, cuts = the DP partitioner
            # over the oracle's per-block costs, microbatch segments = what
            # the plan's projection assumed (the step resolves the largest
            # deployable S <= that and reports it in metrics)
            from ..core.autotune import stats_for_model
            from ..parallel.pipeline import (make_pipeline_train_step,
                                             pipeline_block_costs)
            if accum != 1:
                raise NotImplementedError(
                    "pipeline microbatches ARE the accumulation schedule; "
                    "sequential grad accumulation (accum > 1) is not wired "
                    "through the pipeline step")
            seg = plan.segments if plan is not None else 8
            schedule = plan.schedule if plan is not None else "gpipe"
            virtual = plan.virtual_stages if plan is not None else 2
            costs = pipeline_block_costs(
                model, stats_for_model(mc, shape.seq_len), **kw)
            step = make_pipeline_train_step(
                model, opt, ctx, block_costs=costs, segments=seg,
                schedule=schedule, virtual_stages=virtual, **kw)
        else:
            step = make_train_step(model, opt, ctx, accum=accum, **kw)
        state_rules = zero1_rules(rules) if opt.zero1 else rules
        sspec = train_state_spec(model, opt)
        state = {
            "params": tree_abstract(sspec["params"], mesh=mesh, rules=rules),
            "opt": tree_abstract(sspec["opt"], mesh=mesh, rules=state_rules),
            "step": tree_abstract(sspec["step"], mesh=mesh, rules=rules),
        }
        batch = (cnn_batch_specs(cfg, shape.global_batch, mesh, rules, smoke)
                 if cfg.family == "cnn"
                 else batch_specs(cfg, shape, mesh, rules, smoke))
        return BuiltCell(cfg.name, shape_name, strategy, model, ctx, step,
                         (state, batch), "train", _scan_groups(model), meta)

    # serving cells ---------------------------------------------------------
    if strategy == "pipeline":
        raise NotImplementedError(
            "the pipeline schedules (gpipe / 1F1B / interleaved) are "
            "training schedules (fill/drain over microbatches); serve "
            "cells deploy serve_tp instead — TunedPlan.exec_strategy does "
            "this automatically")
    params = tree_abstract(model.params_spec(), mesh=mesh, rules=rules)
    B, S = shape.global_batch, shape.seq_len
    serve_kw = {k: v for k, v in kw.items() if k != "remat"}
    if shape.kind == "prefill":
        cache = tree_abstract(model.cache_spec(B, S, shards=kv_shards),
                              mesh=mesh, rules=rules)
        if cfg.family == "encdec":
            serve_kw.pop("q_chunk", None)
            serve_kw.pop("kv_chunk", None)
        step = make_prefill_step(model, ctx, **serve_kw)
        batch = batch_specs(cfg, shape, mesh, rules, smoke)
        return BuiltCell(cfg.name, shape_name, strategy, model, ctx, step,
                         (params, batch, cache), "prefill",
                         _scan_groups(model), meta)

    if shape.kind == "decode":
        cache = tree_abstract(model.cache_spec(B, S, shards=kv_shards),
                              mesh=mesh, rules=rules)
        serve_kw2 = {"scan_layers": scan_layers}
        step = make_decode_step(model, ctx, **serve_kw2)
        rules_tok = rules
        token = _shard(mesh, rules_tok, (B, 1), ("batch", None), jnp.int32)
        pos = jax.ShapeDtypeStruct((), jnp.int32)
        return BuiltCell(cfg.name, shape_name, strategy, model, ctx, step,
                         (params, token, cache, pos), "decode",
                         _scan_groups(model), meta)

    raise ValueError(shape.kind)


def _with_layers(mc, n: int):
    """Clone a model config with a different layer count (cost extrapolation)."""
    if isinstance(mc, LMConfig):
        return dataclasses.replace(mc, n_layers=n, first_k_dense=0, mtp_heads=0)
    if isinstance(mc, EncDecConfig):
        return dataclasses.replace(mc, n_enc_layers=n, n_dec_layers=n)
    if isinstance(mc, VLMConfig):
        return dataclasses.replace(
            mc, lm=dataclasses.replace(mc.lm, n_layers=n, first_k_dense=0,
                                       mtp_heads=0))
    raise TypeError(type(mc))
