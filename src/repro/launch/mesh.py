"""Production mesh construction (deliverable e.1).

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module touches no jax device state. The dry-run entry point
(dryrun.py) sets XLA_FLAGS before any jax import; real launches rely on the
actual TPU topology.
"""
from __future__ import annotations

import jax

from .compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(n_devices: int | None = None,
                   model: int | None = None) -> jax.sharding.Mesh:
    """Small mesh over the available (possibly virtual) host devices —
    used by measured benchmarks, tests and the CPU training examples."""
    n = n_devices or len(jax.devices())
    model = model or (2 if n % 2 == 0 and n > 1 else 1)
    data = n // model
    return make_mesh((data, model), ("data", "model"))


def make_grid_mesh(p1: int, p2r: int, p2c: int) -> jax.sharding.Mesh:
    """(data, model_r, model_c) mesh for the 2D SUMMA strategy
    (parallel/summa.py)."""
    return make_mesh((p1, p2r, p2c), ("data", "model_r", "model_c"))


def mesh_for_plan(plan) -> jax.sharding.Mesh:
    """Shape the mesh a TunedPlan deploys on — the factored grid mesh for
    summa plans, the usual (data, model) mesh otherwise."""
    shape, axes = plan.mesh_spec()
    return make_mesh(shape, axes)
