"""jax version-compat shims (mesh construction, shard_map).

Newer jax exposes ``jax.sharding.AxisType`` and ``jax.make_mesh(...,
axis_types=...)``; the pinned CPU image (jax 0.4.37) has neither. Every
mesh in this repo wants plain ``Auto`` axes, so the shim passes
``axis_types=(AxisType.Auto, ...)`` exactly when the running jax defines
``AxisType`` and builds an identical Auto-axis mesh otherwise (pre-AxisType
jax has no explicit/auto distinction — Auto is the only behaviour).
Similarly ``jax.shard_map`` (with ``check_vma``) only exists on newer jax;
older versions spell it ``jax.experimental.shard_map.shard_map`` (with
``check_rep``).

Use ``compat.make_mesh(shape, axes)`` / ``compat.shard_map(...)``
everywhere instead of calling the jax originals with version-specific
arguments.
"""
from __future__ import annotations

from typing import Sequence

import jax


def axis_type_kwargs(n_axes: int) -> dict:
    """``{"axis_types": (AxisType.Auto,) * n}`` when this jax has AxisType,
    ``{}`` otherwise (older jax: every axis is implicitly Auto)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_mesh(shape: Sequence[int], axes: Sequence[str],
              *, devices=None) -> jax.sharding.Mesh:
    """``jax.make_mesh`` with Auto axis types on any jax version."""
    kwargs = axis_type_kwargs(len(axes))
    if devices is not None:
        kwargs["devices"] = devices
    return jax.make_mesh(tuple(shape), tuple(axes), **kwargs)


def axis_size(axis_name: str):
    """``jax.lax.axis_size`` on any jax version (old spelling: psum(1, axis),
    which jax folds to a static value inside shard_map/pmap)."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool | None = None):
    """``jax.shard_map`` on any jax version.

    Newer jax: top-level ``jax.shard_map`` with ``check_vma``. Older jax:
    ``jax.experimental.shard_map.shard_map`` where the same knob is named
    ``check_rep``.
    """
    if hasattr(jax, "shard_map"):
        kwargs = {} if check_vma is None else {"check_vma": check_vma}
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kwargs)
    from jax.experimental.shard_map import shard_map as _shard_map
    kwargs = {} if check_vma is None else {"check_rep": check_vma}
    return _shard_map(f, mesh, in_specs=in_specs, out_specs=out_specs,
                      **kwargs)
