"""Oracle-guided elastic training: failure is a planning event (DESIGN.md §12).

The paper's oracle targets runs of up to 1024 GPUs — a scale where slice
loss and stragglers are routine, and where the interesting part of recovery
is not the restart but the *re-plan*: the surviving machine is a different
``ClusterSpec`` (a torus with one dimension shrunk, the model-axis ring
constraint re-indexed), so the plan that was cheapest on the full machine
may be infeasible — or merely slow — on what is left. This module closes
that loop:

    failure / repeated stragglers  →  SliceLost
      → derive the surviving ClusterSpec       (ClusterSpec.degraded)
      → re-run the tuner on the degraded spec  (Oracle session .tune)
      → reshard the checkpoint plan-to-plan    (Checkpointer.restore with
        the NEW plan's shardings; remesh_state for in-memory trees)
      → rebuild the jitted step on the surviving mesh and resume.

The inner loop is ``run_with_recovery`` unchanged: transient faults
restore-and-replay on the same mesh; only ``SliceLost`` — abrupt slice
death, or the patience-exceeded straggler escalation (which checkpoints
first) — surfaces here and triggers a rebind.

Recovery contract (what tests/test_chaos.py pins, bit for bit): resuming
on the degraded machine is indistinguishable from having *planned* the
degraded run from that checkpoint — same loader stream (the data pipeline
is (seed, step)-addressable and mesh-independent in content), same state
bits (remesh is pure data movement), same step math under the new plan.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Any

import jax

from ..checkpoint.checkpointing import Checkpointer
from ..data.pipeline import DataConfig, ShardedLoader
from ..launch.compat import make_mesh
from ..nn.module import ShardingCtx, tree_init, tree_shardings
from ..optim.optimizers import OptimizerConfig, zero1_rules
from ..parallel.strategies import make_rules
from ..training.steps import make_train_step, train_state_spec
from .fault_tolerance import (SliceLost, StepTimer, remesh_state,
                              run_with_recovery)


def state_shardings(model, opt: OptimizerConfig, mesh, rules):
    """Per-leaf NamedShardings for a full train state under one plan: the
    same split launch/build.py deploys — params and step on the strategy
    rules, optimizer state on ``zero1_rules`` when ZeRO-1 is on."""
    sspec = train_state_spec(model, opt)
    srules = zero1_rules(rules) if opt.zero1 else rules
    return {"params": tree_shardings(sspec["params"], mesh, rules),
            "opt": tree_shardings(sspec["opt"], mesh, srules),
            "step": tree_shardings(sspec["step"], mesh, rules)}


@dataclass(frozen=True)
class ElasticEvent:
    """One recovery: what died, what the tuner chose, where we resumed."""

    step: int            # step at which the loss surfaced
    cause: str           # "failure" | "straggler"
    p_before: int
    p_after: int
    strategy: str        # re-tuned plan's oracle strategy
    mesh_shape: tuple    # (p1, p2) deployed on the survivors
    resumed_from: int    # checkpoint step the run resumed at
    cluster: str         # surviving ClusterSpec name


@dataclass
class Binding:
    """One deployed plan: everything the loop needs on the current mesh."""

    ses: Any             # Oracle session bound to the current ClusterSpec
    plan: Any            # TunedPlan
    mesh: Any
    rules: Any
    step_fn: Any
    loader: ShardedLoader
    shardings: Any       # full-state sharding tree (state_shardings)


def bind_plan(ses, devices, data_cfg: DataConfig, model,
              opt: OptimizerConfig, fwd_kw: dict | None = None, *,
              allow_pipeline: bool = False) -> Binding:
    """Tune for ``len(devices)`` PEs and deploy the plan: mesh on exactly
    those devices, rules table, jitted step, loader, state shardings.

    The plan's ZeRO-1 switch is applied to the optimizer config — safe
    across rebinds because ZeRO-1 changes only *shardings*, never the
    state tree structure, so a checkpoint written under one plan restores
    under any other. Pipeline plans are barred by default: the rebind path
    rebuilds a plain SPMD step, not the GPipe stage schedule (deploy that
    via launch.build.build_cell instead).
    """
    p = len(devices)
    plan = ses.tune(p, allow_pipeline=allow_pipeline)
    if plan.exec_strategy("train") == "pipeline":
        raise NotImplementedError(
            "elastic rebinding of the GPipe stage schedule is not wired; "
            "keep allow_pipeline=False or deploy via build_cell")
    mesh = make_mesh(plan.mesh_shape, ("data", "model"),
                     devices=list(devices)[:p])
    rules = make_rules(plan.exec_strategy("train"))
    opt = replace(opt, zero1=plan.zero1)
    step_fn = jax.jit(make_train_step(model, opt, ShardingCtx(mesh, rules),
                                      **(fwd_kw or {})))
    return Binding(ses, plan, mesh, rules, step_fn,
                   ShardedLoader(data_cfg, mesh),
                   state_shardings(model, opt, mesh, rules))


def _survivors(ses, devices, e: SliceLost):
    """The (session, devices) that outlive ``e``: degrade the cluster's
    torus along the lost dimension, or halve p when no topology is
    described (no slice structure to consult)."""
    if ses.cluster.topology is not None:
        degraded = ses.cluster.degraded(dim=e.dim, count=e.count)
        p_new = min(degraded.topology.size, len(devices))
        return ses.with_cluster(degraded), list(devices)[:p_new]
    return ses, list(devices)[:max(len(devices) // 2, 1)]


def run_elastic(ses, data_cfg: DataConfig, ckpt: Checkpointer, *,
                n_steps: int, model=None, opt: OptimizerConfig | None = None,
                devices=None, start_step: int = 0, ckpt_every: int = 10,
                async_ckpt: bool = False, max_restarts: int = 3,
                straggler_patience: int | None = 2, max_reshapes: int = 8,
                timer: StepTimer | None = None, inject=None,
                on_metrics=None, on_event=None, fwd_kw: dict | None = None,
                allow_pipeline: bool = False, seed: int = 0):
    """Elastic train loop: tune → run → on SliceLost shrink, re-tune,
    reshard, resume. Returns ``(state, step, events)``.

    ``ses`` is an ``Oracle`` session (repro.api) — its ClusterSpec is the
    machine being degraded; ``inject`` is the fault hook forwarded to
    ``run_with_recovery`` (tests/helpers/fault_plan.py builds these).
    Transient faults never surface here: the inner loop's restart budget
    (which resets on forward progress) absorbs them on the same mesh.
    """
    from ..launch.build import build_model
    devices = list(devices if devices is not None else jax.devices())
    model = model if model is not None else build_model(ses.arch_cfg,
                                                        smoke=ses.smoke)
    opt = opt if opt is not None else OptimizerConfig()
    timer = timer if timer is not None else StepTimer()
    sspec = train_state_spec(model, opt)
    events: list[ElasticEvent] = []

    b = bind_plan(ses, devices, data_cfg, model, opt, fwd_kw,
                  allow_pipeline=allow_pipeline)
    if ckpt.latest_step() is not None:
        state, step = ckpt.restore(sspec, shardings=b.shardings)
    else:
        state = remesh_state(tree_init(sspec, jax.random.PRNGKey(seed)),
                             shardings=b.shardings)
        step = start_step
    reshapes = 0
    while step < n_steps:
        try:
            state, step = run_with_recovery(
                b.step_fn, state, b.loader, ckpt, n_steps=n_steps,
                start_step=step, ckpt_every=ckpt_every,
                async_ckpt=async_ckpt, max_restarts=max_restarts,
                timer=timer, inject=inject, on_metrics=on_metrics,
                straggler_patience=straggler_patience,
                skeleton=sspec, restore_shardings=b.shardings)
        except SliceLost as e:
            reshapes += 1
            if reshapes > max_reshapes:
                raise
            ckpt.wait()
            p_before = len(devices)
            ses2, devices = _survivors(b.ses, devices, e)
            timer.reset()   # new plan, new step-time baseline (fresh compile)
            b = bind_plan(ses2, devices, data_cfg, model, opt, fwd_kw,
                          allow_pipeline=allow_pipeline)
            if ckpt.latest_step() is not None:
                # plan-to-plan reshard: the old plan's layout is in the
                # checkpoint, the new plan's shardings land it on the
                # surviving mesh — restore IS the remesh
                state, step = ckpt.restore(sspec, shardings=b.shardings)
            else:
                state = remesh_state(
                    tree_init(sspec, jax.random.PRNGKey(seed)),
                    shardings=b.shardings)
                step = start_step
            ev = ElasticEvent(
                step=e.step, cause=e.cause, p_before=p_before,
                p_after=len(devices), strategy=b.plan.strategy,
                mesh_shape=(b.plan.p1, b.plan.p2), resumed_from=step,
                cluster=b.ses.cluster.name)
            events.append(ev)
            print(f"[elastic] {e} → p {p_before}→{len(devices)}, re-tuned "
                  f"{b.plan.strategy} (mesh {b.plan.p1}x{b.plan.p2}), "
                  f"resumed from step {step}")
            if on_event:
                on_event(ev)
    return state, step, events
