"""Fault tolerance & elasticity for the training runtime.

Three mechanisms (scaled-out designs documented inline; all are exercised by
tests on virtual devices):

* **checkpoint/restart** — ``run_with_recovery`` drives the train loop with
  periodic (optionally async) checkpoints; any step-time exception triggers
  restore-from-latest and replay. The data pipeline is (seed, step)-
  addressable so the resumed stream is identical.
* **straggler mitigation** — ``StepTimer`` keeps a ring buffer of step times;
  a step slower than ``threshold × median`` raises a StragglerAlert. In a
  synchronous SPMD job the remedy at scale is checkpoint-and-remesh around
  the slow host (the alert carries enough context to automate that); on a
  single host we surface and log it.
* **elastic re-mesh** — ``remesh_state`` re-shards a checkpointed state onto
  a smaller/larger mesh (device failure → shrink; capacity return → grow),
  reusing the same Rules table so only the device axis sizes change.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass

import jax
import numpy as np

from ..checkpoint.checkpointing import Checkpointer
from ..nn.module import Rules, tree_shardings


class StragglerAlert(RuntimeError):
    def __init__(self, step: int, step_s: float, median_s: float):
        self.step, self.step_s, self.median_s = step, step_s, median_s
        super().__init__(
            f"step {step} took {step_s:.3f}s vs median {median_s:.3f}s")


@dataclass
class StepTimer:
    window: int = 32
    threshold: float = 3.0
    _times: deque = None

    def __post_init__(self):
        self._times = deque(maxlen=self.window)

    def observe(self, step: int, step_s: float):
        if len(self._times) >= 8:
            med = float(np.median(self._times))
            if step_s > self.threshold * med:
                self._times.append(step_s)
                raise StragglerAlert(step, step_s, med)
        self._times.append(step_s)

    @property
    def median(self) -> float:
        return float(np.median(self._times)) if self._times else 0.0


def remesh_state(state, spec_tree, new_mesh, rules: Rules):
    """Re-shard a (host-side or addressable) state onto a new mesh."""
    sh = tree_shardings(spec_tree, new_mesh, rules)
    return jax.tree.map(lambda x, s: jax.device_put(jax.device_get(x), s),
                        state, sh)


def run_with_recovery(step_fn, state, loader, ckpt: Checkpointer, *,
                      n_steps: int, start_step: int = 0,
                      ckpt_every: int = 50, async_ckpt: bool = True,
                      max_restarts: int = 3, timer: StepTimer | None = None,
                      inject_failure_at: int | None = None,
                      on_metrics=None):
    """Fault-tolerant train loop: checkpoint, detect, restore, replay.

    ``inject_failure_at`` simulates a node failure at a given step (used by
    the integration tests to prove the restart path end-to-end).
    """
    timer = timer or StepTimer()
    step = start_step
    restarts = 0
    injected = False
    while step < n_steps:
        try:
            t0 = time.perf_counter()
            batch = loader.batch_at(step)
            if inject_failure_at is not None and step == inject_failure_at \
                    and not injected:
                injected = True
                raise RuntimeError(f"injected node failure at step {step}")
            state, metrics = step_fn(state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            try:
                timer.observe(step, dt)
            except StragglerAlert as e:
                # synchronous SPMD: log-and-continue; at scale this triggers
                # checkpoint-and-remesh around the slow host
                print(f"[straggler] {e}")
            if on_metrics:
                on_metrics(step, metrics)
            step += 1
            if step % ckpt_every == 0:
                ckpt.save(state, step, blocking=not async_ckpt)
        except StragglerAlert:
            raise
        except Exception as e:  # noqa: BLE001 — restart path
            restarts += 1
            if restarts > max_restarts:
                raise
            latest = ckpt.latest_step()
            print(f"[recovery] {e!r} → restoring from "
                  f"{'step ' + str(latest) if latest is not None else 'init'}")
            if latest is not None:
                state, step = ckpt.restore(state)
            else:
                step = start_step
    ckpt.wait()
    ckpt.save(state, step)
    return state, step
