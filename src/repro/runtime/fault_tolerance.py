"""Fault tolerance & elasticity for the training runtime.

Three mechanisms (scaled-out designs documented inline; all are exercised by
tests on virtual devices — tests/test_train_integration.py for the loop,
tests/test_chaos.py for the full elastic path):

* **checkpoint/restart** — ``run_with_recovery`` drives the train loop with
  periodic (optionally async) checkpoints; a transient step-time exception
  triggers restore-from-latest and replay. The data pipeline is (seed, step)-
  addressable so the resumed stream is identical. The restart budget counts
  *consecutive* failures: forward progress (a checkpoint newer than the one
  seen at the previous failure) resets it, so spaced transient faults over a
  long run never exhaust it while a crash loop still aborts.
* **straggler mitigation** — ``StepTimer`` keeps a ring buffer of step times;
  a step slower than ``threshold × median`` raises a StragglerAlert (the
  outlier sample stays OUT of the window, so one slow step cannot inflate
  the median and mask the next). In a synchronous SPMD job the remedy at
  scale is checkpoint-and-remesh around the slow host: after
  ``straggler_patience`` consecutive alerts the loop checkpoints and raises
  ``SliceLost(cause="straggler")`` for runtime/elastic.py to handle.
* **elastic re-mesh** — ``remesh_state`` re-shards a state pytree from ANY
  source placement onto a target (mesh, Rules) pair — plan-to-plan: leaves
  round-trip through the host, so arbitrary source→target mesh shapes and
  any strategy pair the Rules tables cover work, bit-exactly (pinned by
  tests/test_remesh_properties.py). ``SliceLost`` is the event that drives
  it: runtime/elastic.py derives the surviving ClusterSpec, re-runs the
  tuner, and resumes from the checkpoint under the new plan's shardings.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass

import jax
import numpy as np

from ..checkpoint.checkpointing import Checkpointer
from ..nn.module import Rules, tree_shardings


class StragglerAlert(RuntimeError):
    def __init__(self, step: int, step_s: float, median_s: float):
        self.step, self.step_s, self.median_s = step, step_s, median_s
        super().__init__(
            f"step {step} took {step_s:.3f}s vs median {median_s:.3f}s")


class SliceLost(RuntimeError):
    """A device slice is gone — the surviving machine is a *different*
    ClusterSpec, so recovery is a planning problem, not just a restart.

    Raised by fault injection (standing in for the device watchdog) on
    slice death, and by ``run_with_recovery`` itself when stragglers exceed
    the patience budget (``cause="straggler"`` — graceful: the state was
    checkpointed first). ``dim``/``count`` name the torus dimension that
    lost ``count`` hyperplanes, feeding ``ClusterSpec.degraded``.
    """

    def __init__(self, step: int, *, dim: int = 0, count: int = 1,
                 cause: str = "failure", reason: str | None = None):
        self.step, self.dim, self.count, self.cause = step, dim, count, cause
        self.reason = reason or f"slice lost (torus dim {dim})"
        super().__init__(f"step {step}: {self.reason}")


@dataclass
class StepTimer:
    window: int = 32
    threshold: float = 3.0
    _times: deque = None

    def __post_init__(self):
        self._times = deque(maxlen=self.window)

    def observe(self, step: int, step_s: float):
        if len(self._times) >= 8:
            med = float(np.median(self._times))
            if step_s > self.threshold * med:
                # the straggler sample must NOT enter the window: appended,
                # a run of slow steps would drag the median up until the
                # detector stops firing on the very condition it watches
                raise StragglerAlert(step, step_s, med)
        self._times.append(step_s)

    def reset(self):
        """Drop the baseline — after an elastic re-mesh the plan (and its
        step time, including a fresh compile) has nothing in common with
        the old window."""
        self._times.clear()

    @property
    def median(self) -> float:
        return float(np.median(self._times)) if self._times else 0.0


def remesh_state(state, spec_tree=None, new_mesh=None, rules: Rules | None = None,
                 *, shardings=None):
    """Re-shard a state pytree plan-to-plan: any source placement (sharded
    on some mesh, single-device, or host numpy) → a target described either
    by ``(spec_tree, new_mesh, rules)`` or by a precomputed per-leaf
    ``shardings`` tree (e.g. the split params/opt/step shardings of
    runtime/elastic.py, where ZeRO-1 optimizer state rides its own rules).

    Arbitrary source→target mesh pairs work because every leaf round-trips
    through the host: ``device_get`` reassembles the full array from
    whatever sharding it had, ``device_put`` lays it out under the new one.
    Pure data movement — bit-exact per leaf (tests/test_remesh_properties).
    """
    if shardings is None:
        shardings = tree_shardings(spec_tree, new_mesh, rules)
    return jax.tree.map(
        lambda x, s: jax.device_put(np.asarray(jax.device_get(x)), s),
        state, shardings)


def run_with_recovery(step_fn, state, loader, ckpt: Checkpointer, *,
                      n_steps: int, start_step: int = 0,
                      ckpt_every: int = 50, async_ckpt: bool = True,
                      max_restarts: int = 3, timer: StepTimer | None = None,
                      inject_failure_at=None, inject=None,
                      straggler_patience: int | None = None,
                      skeleton=None, restore_shardings=None,
                      on_metrics=None):
    """Fault-tolerant train loop: checkpoint, detect, restore, replay.

    ``inject_failure_at`` simulates node failures (an int or an iterable of
    steps; each fires once) — the restart path end-to-end. ``inject``, when
    given, is called with the step index before it executes and may raise
    (``SliceLost`` propagates to the elastic controller, anything else
    takes the restart path) or return a simulated step duration in seconds
    for the straggler timer (tests/helpers/fault_plan.py builds these).

    ``straggler_patience``: after that many consecutive StragglerAlerts the
    loop checkpoints the (healthy, just slow) state and raises
    ``SliceLost(cause="straggler")`` — the checkpoint-and-remesh-around-
    the-slow-host escalation runtime/elastic.py drives. None (default):
    log-and-continue, the single-host behavior.

    ``skeleton``/``restore_shardings`` shape the restore: elastic restarts
    restore onto a NEW mesh, so they pass the state spec tree and the
    re-tuned plan's shardings; by default the live state is the skeleton
    and leaves land wherever ``device_put`` defaults.
    """
    timer = timer or StepTimer()
    step = start_step
    restarts = 0
    seen_failure = False
    budget_anchor = None     # ckpt.latest_step() at the previous failure
    fail_steps = ({int(inject_failure_at)}
                  if isinstance(inject_failure_at, int)
                  else set(int(s) for s in inject_failure_at or ()))
    fired: set[int] = set()
    strikes = 0
    while step < n_steps:
        try:
            fake_dt = inject(step) if inject is not None else None
            t0 = time.perf_counter()
            batch = loader.batch_at(step)
            if step in fail_steps and step not in fired:
                fired.add(step)
                raise RuntimeError(f"injected node failure at step {step}")
            state, metrics = step_fn(state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = fake_dt if fake_dt is not None else time.perf_counter() - t0
            escalate = None
            try:
                timer.observe(step, dt)
                strikes = 0
            except StragglerAlert as e:
                # synchronous SPMD: log-and-continue; repeated alerts
                # escalate to checkpoint-and-remesh around the slow host
                print(f"[straggler] {e}")
                strikes += 1
                if straggler_patience is not None \
                        and strikes >= straggler_patience:
                    escalate = e
            if on_metrics:
                on_metrics(step, metrics)
            step += 1
            if escalate is not None:
                # graceful: the state is intact, persist it before leaving
                ckpt.wait()
                ckpt.save(state, step)
                raise SliceLost(
                    step, cause="straggler",
                    reason=f"{strikes} consecutive stragglers "
                           f"(last: {escalate})")
            if step % ckpt_every == 0:
                ckpt.save(state, step, blocking=not async_ckpt)
        except (StragglerAlert, SliceLost):
            raise
        except Exception as e:  # noqa: BLE001 — restart path
            ckpt.wait()          # an in-flight async save may still commit
            latest = ckpt.latest_step()
            key = -1 if latest is None else latest
            if seen_failure and key > (budget_anchor
                                       if budget_anchor is not None else -1):
                restarts = 0     # forward progress since the last failure
            seen_failure, budget_anchor = True, latest
            restarts += 1
            if restarts > max_restarts:
                raise
            print(f"[recovery] {e!r} → restoring from "
                  f"{'step ' + str(latest) if latest is not None else 'init'}")
            if latest is not None:
                state, step = ckpt.restore(
                    skeleton if skeleton is not None else state,
                    shardings=restore_shardings)
            else:
                step = start_step
    ckpt.wait()
    ckpt.save(state, step)
    return state, step
