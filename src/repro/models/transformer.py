"""Decoder-only LM covering all assigned families via a layer *pattern*.

A model is ``n_layers`` arranged as repetitions of a pattern of sub-block
kinds, e.g.::

    dense GQA   : ("attn",)                      qwen3 / command-r / deepseek-67b
    MoE         : ("moe",)                        grok-1; deepseek-v3 adds
                                                  ``first_k_dense`` dense layers
    SSM         : ("ssm",)                        mamba2 (attention-free)
    hybrid      : ("rec", "rec", "attn")          recurrentgemma 1:2

Layers within one pattern position are *stacked* and evaluated with
``lax.scan`` (small HLO, exact memory analysis) or unrolled (exact
``cost_analysis`` FLOPs) — the dry-run uses both, see DESIGN.md §5.

Three step kinds are exposed as pure functions over (params, batch):
``loss_fn`` (training forward), ``prefill`` (build KV caches + logits) and
``decode_step`` (one token, cache in/out).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..nn.attention import Attention, AttentionConfig, MLAttention, MLAConfig
from ..nn.ffn import FFN, FFNConfig, MoE, MoEConfig
from ..nn.layers import Embedding, LayerNorm, RMSNorm
from ..nn.module import (NULL_CTX, ShardingCtx, fan_in_init, param, tree_num_params)
from ..nn.rglru import RecurrentBlock, RGLRUConfig
from ..nn.ssm import SSDBlock, SSMConfig

KINDS = ("attn", "local_attn", "mla", "moe", "ssm", "rec")


@dataclass(frozen=True)
class LMConfig:
    name: str
    vocab: int
    d_model: int
    n_layers: int
    pattern: tuple[str, ...] = ("attn",)
    attn: AttentionConfig | None = None
    local_attn: AttentionConfig | None = None
    mla: MLAConfig | None = None
    ffn: FFNConfig | None = None
    moe: MoEConfig | None = None
    ssm: SSMConfig | None = None
    rglru: RGLRUConfig | None = None
    first_k_dense: int = 0           # deepseek-v3: leading dense layers
    norm: str = "rmsnorm"
    tie_embeddings: bool = False
    pos_embedding: str | None = None  # "learned" → whisper/absolute
    max_position: int = 8192          # only for learned positions
    final_logit_softcap: float | None = None
    mtp_heads: int = 0               # deepseek-v3 multi-token prediction
    embed_scale: bool = False        # gemma-style sqrt(d) embedding scaling
    dtype: Any = jnp.bfloat16

    def block_kinds(self) -> list[str]:
        """Resolved per-layer kind list of length n_layers."""
        kinds = []
        for i in range(self.n_layers):
            k = self.pattern[i % len(self.pattern)]
            if k == "moe" and i < self.first_k_dense:
                k = "attn"  # dense replacement uses the ffn config
            kinds.append(k)
        return kinds


def _norm(cfg: LMConfig):
    if cfg.norm == "rmsnorm":
        return RMSNorm(cfg.d_model)
    if cfg.norm == "layernorm_nobias":
        return LayerNorm(cfg.d_model, use_bias=False)
    return LayerNorm(cfg.d_model)


# ---------------------------------------------------------------------------
# One block (pre-norm residual around a mixer and optionally an FFN/MoE)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class Block:
    cfg: LMConfig
    kind: str

    def _mixer(self):
        c = self.cfg
        if self.kind in ("attn",):
            # "attn" is also the dense-replacement kind for first_k_dense
            # layers of MoE models; those may be MLA-based (deepseek-v3).
            return Attention(c.attn) if c.attn is not None else MLAttention(c.mla)
        if self.kind == "local_attn":
            return Attention(c.local_attn)
        if self.kind == "mla":
            return MLAttention(c.mla)
        if self.kind == "ssm":
            return SSDBlock(c.ssm)
        if self.kind == "rec":
            return RecurrentBlock(c.rglru)
        if self.kind == "moe":
            return MLAttention(c.mla) if c.mla else Attention(c.attn)
        raise ValueError(self.kind)

    def _ffn(self):
        c = self.cfg
        if self.kind == "ssm":
            return None  # mamba2 blocks have no separate FFN (d_ff = 0)
        if self.kind == "moe":
            return MoE(c.moe)
        return FFN(c.ffn)

    def params_spec(self):
        c = self.cfg
        spec = {"norm1": _norm(c).params_spec(), "mixer": self._mixer().params_spec()}
        ffn = self._ffn()
        if ffn is not None:
            spec["norm2"] = _norm(c).params_spec()
            spec["ffn"] = ffn.params_spec()
        return spec

    def apply(self, params, h, ctx: ShardingCtx, attn_impl="chunked",
              q_chunk=1024, kv_chunk=1024, unroll=False):
        c = self.cfg
        norm = _norm(c)
        mixer = self._mixer()
        aux = jnp.zeros((), jnp.float32)
        x = norm.apply(params["norm1"], h)
        if self.kind in ("attn", "local_attn", "mla", "moe"):
            kw = dict(impl=attn_impl, q_chunk=q_chunk, kv_chunk=kv_chunk,
                      unroll=unroll)
            h = h + mixer.apply(params["mixer"], x, ctx, **kw)
        else:
            h = h + mixer.apply(params["mixer"], x, ctx)
        ffn = self._ffn()
        if ffn is not None:
            x = norm.apply(params["norm2"], h)
            if self.kind == "moe":
                y, aux = ffn.apply(params["ffn"], x, ctx)
            else:
                y = ffn.apply(params["ffn"], x, ctx)
            h = h + y
        h = ctx.constrain(h, ("batch", "seq", "act_embed"))
        from ..nn.module import grad_barrier
        h = grad_barrier(h)
        return h, aux

    # -- caches -----------------------------------------------------------
    def cache_spec(self, batch, max_len, shards=1, dtype=jnp.bfloat16):
        c = self.cfg
        if self.kind == "ssm":
            return SSDBlock(c.ssm).cache_spec(batch, dtype=jnp.float32)
        if self.kind == "rec":
            return RecurrentBlock(c.rglru).cache_spec(batch, dtype=jnp.float32)
        if self.kind in ("mla", "moe", "attn") and c.mla is not None \
                and (self.kind == "mla" or c.attn is None):
            return MLAttention(c.mla).cache_spec(batch, max_len, dtype=dtype)
        acfg = c.local_attn if self.kind == "local_attn" else c.attn
        att = Attention(acfg)
        span = min(max_len, acfg.window) if acfg.window else max_len
        span = max(span, 1)
        sh = shards if span % max(shards, 1) == 0 else 1
        return att.cache_spec(batch, span, shards=sh, dtype=dtype)

    def decode(self, params, h, cache, pos, ctx: ShardingCtx):
        c = self.cfg
        norm = _norm(c)
        mixer = self._mixer()
        x = norm.apply(params["norm1"], h)
        y, cache = mixer.decode(params["mixer"], x, cache, pos, ctx)
        h = h + y
        ffn = self._ffn()
        if ffn is not None:
            x = norm.apply(params["norm2"], h)
            if self.kind == "moe":
                y, _ = ffn.apply(params["ffn"], x, ctx)
            else:
                y = ffn.apply(params["ffn"], x, ctx)
            h = h + y
        return h, cache

    def prefill(self, params, h, cache, ctx: ShardingCtx, attn_impl="chunked",
                q_chunk=1024, kv_chunk=1024, unroll=False):
        """Forward over the full prompt, filling the cache."""
        c = self.cfg
        norm = _norm(c)
        x = norm.apply(params["norm1"], h)
        mixer = self._mixer()
        if self.kind in ("ssm", "rec"):
            # recompute final state via the chunked path: cheapest correct way
            # is decode-free state extraction; we reuse apply + a state pass.
            y, cache = _recurrent_prefill(mixer, params["mixer"], x, cache, ctx)
            h = h + y
        else:
            y, cache = _attn_prefill(mixer, params["mixer"], x, cache, ctx,
                                     attn_impl, q_chunk, kv_chunk, unroll)
            h = h + y
        ffn = self._ffn()
        if ffn is not None:
            x2 = norm.apply(params["norm2"], h)
            if self.kind == "moe":
                y2, _ = ffn.apply(params["ffn"], x2, ctx)
            else:
                y2 = ffn.apply(params["ffn"], x2, ctx)
            h = h + y2
        h = ctx.constrain(h, ("batch", "seq", "act_embed"))
        return h, cache


def _attn_prefill(mixer, params, x, cache, ctx, attn_impl, q_chunk, kv_chunk,
                  unroll=False):
    """Attention prefill: run full attention AND write K/V (or latents) to cache."""
    from ..nn.attention import Attention, MLAttention
    B, S, _ = x.shape
    if isinstance(mixer, MLAttention):
        c = mixer.cfg
        positions = jnp.arange(S)[None, :]
        q_nope, q_rope, c_kv, k_rope = mixer._project(params, x, positions)
        T = cache["c_kv"].shape[1]
        cache = {
            "c_kv": jax.lax.dynamic_update_slice(
                cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), (0, 0, 0)),
            "k_rope": jax.lax.dynamic_update_slice(
                cache["k_rope"], k_rope.astype(cache["k_rope"].dtype), (0, 0, 0)),
        }
        y = mixer.apply(params, x, ctx, impl=attn_impl, q_chunk=q_chunk,
                        kv_chunk=kv_chunk, unroll=unroll)
        return y, cache
    c = mixer.cfg
    positions = jnp.arange(S)[None, :]
    q, k, v = mixer._qkv(params, x, positions, ctx)
    shards, span = cache["k"].shape[1], cache["k"].shape[2]
    total = shards * span

    if c.window is not None and S >= total:
        # ring layout: slot s holds token (S - total) + ((s - S) % total)
        start = S - total
        slots = jnp.arange(total)
        tok = start + ((slots - start) % total)
        k_w = jnp.take(k, tok, axis=1).reshape(k.shape[0], shards, span, *k.shape[2:])
        v_w = jnp.take(v, tok, axis=1).reshape(v.shape[0], shards, span, *v.shape[2:])
        cache = {"k": k_w.astype(cache["k"].dtype), "v": v_w.astype(cache["v"].dtype)}
    else:
        kr = k.reshape(k.shape[0], -1, span, *k.shape[2:]) if S % span == 0 and S // span <= shards \
            else None
        if kr is not None:
            nsh = S // span
            cache = {
                "k": jax.lax.dynamic_update_slice(
                    cache["k"], kr.astype(cache["k"].dtype), (0, 0, 0, 0, 0)),
                "v": jax.lax.dynamic_update_slice(
                    cache["v"], v.reshape(v.shape[0], nsh, span, *v.shape[2:]
                                          ).astype(cache["v"].dtype), (0, 0, 0, 0, 0)),
            }
        else:
            flat_k = cache["k"].reshape(cache["k"].shape[0], total, *cache["k"].shape[3:])
            flat_v = cache["v"].reshape(cache["v"].shape[0], total, *cache["v"].shape[3:])
            flat_k = jax.lax.dynamic_update_slice(
                flat_k, k.astype(flat_k.dtype), (0, 0, 0, 0))
            flat_v = jax.lax.dynamic_update_slice(
                flat_v, v.astype(flat_v.dtype), (0, 0, 0, 0))
            cache = {"k": flat_k.reshape(cache["k"].shape),
                     "v": flat_v.reshape(cache["v"].shape)}
    y = mixer.apply(params, x, ctx, impl=attn_impl, q_chunk=q_chunk,
                    kv_chunk=kv_chunk, unroll=unroll)
    return y, cache


def _recurrent_prefill(mixer, params, x, cache, ctx):
    """SSM / RG-LRU prefill: forward + final-state extraction."""
    from ..nn.rglru import RecurrentBlock
    from ..nn.ssm import SSDBlock
    if isinstance(mixer, SSDBlock):
        c = mixer.cfg
        B_, S, _ = x.shape
        z, xs, Bm, Cm, dt = mixer._project(params, x, ctx)
        tail = slice(S - (c.d_conv - 1), S)
        conv_x, conv_B, conv_C = xs[:, tail], Bm[:, tail], Cm[:, tail]
        xs = mixer._causal_conv(xs, params["conv_x"], params["conv_b_x"])
        Bm = mixer._causal_conv(Bm, params["conv_B"], params["conv_b_B"])
        Cm = mixer._causal_conv(Cm, params["conv_C"], params["conv_b_C"])
        xs = xs.reshape(B_, S, c.n_heads, c.head_dim)
        Bm = Bm.reshape(B_, S, c.n_groups, c.d_state)
        Cm = Cm.reshape(B_, S, c.n_groups, c.d_state)
        dtf = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
        A = -jnp.exp(params["a_log"])
        y, final = mixer._ssd(xs.astype(jnp.float32), dtf, A,
                              Bm.astype(jnp.float32), Cm.astype(jnp.float32),
                              init_state=cache["state"].astype(jnp.float32))
        y = y + xs.astype(jnp.float32) * params["d_skip"][None, None, :, None]
        y = y.reshape(B_, S, c.d_inner).astype(x.dtype)
        y = y * jax.nn.silu(z)
        from ..nn.layers import RMSNorm
        y = RMSNorm(c.d_inner, axis_name="mlp").apply(params["norm"], y)
        y = y @ params["out_proj"]
        cache = {"state": final.astype(cache["state"].dtype),
                 "conv_x": conv_x.astype(cache["conv_x"].dtype),
                 "conv_B": conv_B.astype(cache["conv_B"].dtype),
                 "conv_C": conv_C.astype(cache["conv_C"].dtype)}
        return ctx.constrain(y, ("batch", "seq", "act_embed")), cache
    if isinstance(mixer, RecurrentBlock):
        c = mixer.cfg
        xr = x @ params["w_rec"]
        conv_tail = xr[:, x.shape[1] - (c.d_conv - 1):, :]
        xr = mixer._conv(params, xr)
        a, gated = mixer._gates(params, xr)

        def assoc(p, q):
            ap, hp = p
            aq, hq = q
            return ap * aq, hq + hp * aq

        a_c, h = jax.lax.associative_scan(assoc, (a, gated), axis=1)
        h = h + a_c * cache["h"].astype(a_c.dtype)[:, None, :]
        gate = jax.nn.gelu(x @ params["w_gate_branch"])
        y = (h.astype(x.dtype) * gate) @ params["w_out"]
        cache = {"h": h[:, -1].astype(cache["h"].dtype),
                 "conv": conv_tail.astype(cache["conv"].dtype)}
        return ctx.constrain(y, ("batch", "seq", "act_embed")), cache
    raise TypeError(type(mixer))


# ---------------------------------------------------------------------------
# The LM
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class TransformerLM:
    cfg: LMConfig

    # -- structure ---------------------------------------------------------
    def _groups(self):
        """(period, n_groups, remainder_kinds). Layers = groups×pattern + rem."""
        c = self.cfg
        period = len(c.pattern)
        main = c.n_layers - c.first_k_dense
        n_groups = main // period
        rem = main - n_groups * period
        return period, n_groups, list(c.pattern[:rem])

    def blocks(self):
        return {k: Block(self.cfg, k) for k in set(self.cfg.block_kinds())}

    def params_spec(self):
        c = self.cfg
        period, n_groups, rem = self._groups()
        spec: dict = {
            "embed": Embedding(c.vocab, c.d_model, dtype=c.dtype).params_spec(),
            "final_norm": _norm(c).params_spec(),
        }
        if not c.tie_embeddings:
            spec["head"] = param((c.d_model, c.vocab), ("embed", "vocab"),
                                 init=fan_in_init((0,)), dtype=c.dtype)
        if c.pos_embedding == "learned":
            spec["pos"] = param((c.max_position, c.d_model), (None, "embed"),
                                init=fan_in_init((1,)), dtype=c.dtype)
        # leading dense layers (deepseek-v3 style), unstacked
        if c.first_k_dense:
            dense_block = Block(dataclasses.replace(c), "attn")
            spec["lead"] = [dense_block.params_spec() for _ in range(c.first_k_dense)]
        # pattern-position stacks: each is a ParamSpec tree with a "layers" axis
        stacks = []
        for pos_i, kind in enumerate(self.cfg.pattern):
            bspec = Block(c, kind).params_spec()
            stacks.append(_stack_spec(bspec, n_groups))
        spec["stacks"] = stacks
        if rem:
            spec["tail"] = [Block(c, k).params_spec() for k in rem]
        if c.mtp_heads:
            spec["mtp"] = {
                "proj": param((2 * c.d_model, c.d_model), ("mlp", "embed"),
                              init=fan_in_init((0,)), dtype=c.dtype),
                "block": Block(c, c.pattern[-1]).params_spec(),
                "norm": _norm(c).params_spec(),
            }
        return spec

    # -- forward -----------------------------------------------------------
    def _embed(self, params, tokens, ctx, embeddings=None):
        c = self.cfg
        emb = Embedding(c.vocab, c.d_model, dtype=c.dtype)
        h = emb.apply(params["embed"], tokens) if embeddings is None else embeddings
        if c.embed_scale:
            h = h * np.sqrt(c.d_model)
        if c.pos_embedding == "learned":
            S = h.shape[1]
            h = h + params["pos"][:S][None]
        return ctx.constrain(h.astype(c.dtype), ("batch", "seq", "act_embed"))

    def _logits(self, params, h, ctx):
        c = self.cfg
        h = _norm(c).apply(params["final_norm"], h)
        if c.tie_embeddings:
            logits = jnp.einsum("bsd,vd->bsv", h, params["embed"]["table"],
                                preferred_element_type=jnp.float32)
        else:
            # fp32 ACCUMULATION with bf16 operands: a plain bf16 matmul
            # followed by .astype(f32) lets XLA fold the convert into the
            # dot, all-gathering an fp32-converted weight (2x wire bytes) —
            # EXPERIMENTS.md §Perf qwen3 iteration 2.
            logits = jnp.einsum("bsd,dv->bsv", h, params["head"],
                                preferred_element_type=jnp.float32)
        if c.final_logit_softcap:
            logits = c.final_logit_softcap * jnp.tanh(
                logits / c.final_logit_softcap)
        return ctx.constrain(logits, ("batch", "seq", "vocab"))

    def apply(self, params, tokens, ctx: ShardingCtx = NULL_CTX, **kw):
        """Full forward → (logits, aux_loss)."""
        h, aux = self._forward(params, tokens, ctx, **kw)
        return self._logits(params, h, ctx), aux

    def _forward(self, params, tokens, ctx: ShardingCtx = NULL_CTX,
                 embeddings=None, attn_impl="chunked", q_chunk=1024,
                 kv_chunk=1024, scan_layers=True, remat=True,
                 unroll_attn=False):
        """Body forward → (hidden (B,S,D), aux_loss)."""
        c = self.cfg
        period, n_groups, rem = self._groups()
        h = self._embed(params, tokens, ctx, embeddings)
        aux_total = jnp.zeros((), jnp.float32)
        kw = dict(attn_impl=attn_impl, q_chunk=q_chunk, kv_chunk=kv_chunk,
                  unroll=unroll_attn)

        def run_block(kind):
            blk = Block(c, kind)

            def run(bp, hh):
                return blk.apply(bp, hh, ctx, **kw)

            return jax.checkpoint(run) if remat else run

        for i in range(c.first_k_dense):
            h, aux = run_block("attn")(params["lead"][i], h)
            aux_total += aux

        def group_apply(h, group_params):
            aux = jnp.zeros((), jnp.float32)
            for pos_i, kind in enumerate(c.pattern):
                h, a = run_block(kind)(group_params[pos_i], h)
                aux += a
            return h, aux

        if scan_layers and n_groups > 0:
            def body(h, gp):
                h, aux = group_apply(h, gp)
                return h, aux
            h, auxs = jax.lax.scan(body, h, params["stacks"])
            aux_total += jnp.sum(auxs)
        else:
            for g in range(n_groups):
                gp = [jax.tree.map(lambda x: x[g], params["stacks"][pos_i])
                      for pos_i in range(period)]
                h, aux = group_apply(h, gp)
                aux_total += aux
        for j, kind in enumerate(rem):
            blk = Block(c, kind)
            h, aux = blk.apply(params["tail"][j], h, ctx, **kw)
            aux_total += aux
        return h, aux_total

    # -- loss ---------------------------------------------------------------
    def loss_fn(self, params, batch, ctx: ShardingCtx = NULL_CTX,
                mtp_weight: float = 0.3, **kw):
        """batch: dict(tokens (B,S) int32, optional embeddings/targets/mask)."""
        c = self.cfg
        tokens = batch["tokens"]
        targets = batch.get("targets")
        if targets is None:
            targets = jnp.pad(tokens[:, 1:], ((0, 0), (0, 1)))
        h, aux = self._forward(params, tokens, ctx,
                               embeddings=batch.get("embeddings"), **kw)
        logits = self._logits(params, h, ctx)
        mask = batch.get("mask", jnp.ones(tokens.shape, jnp.float32))
        ce = _xent(logits, targets)
        loss = jnp.sum(ce * mask) / jnp.maximum(jnp.sum(mask), 1.0)
        metrics = {"ce": loss, "aux": aux}
        if c.mtp_heads:
            # DeepSeek-V3 multi-token prediction (depth 1): combine the trunk
            # hidden at position i with the embedding of token i+1, run one
            # extra block, predict token i+2 with the shared head.
            norm = _norm(c)
            nh = norm.apply(params["mtp"]["norm"], h)
            emb_next = self._embed(params, jnp.pad(tokens[:, 1:], ((0, 0), (0, 1))),
                                   ctx)
            hm = jnp.concatenate([nh, emb_next], axis=-1) @ params["mtp"]["proj"]
            blk = Block(c, c.pattern[-1])
            hm, aux2 = blk.apply(params["mtp"]["block"], hm, ctx)
            mtp_logits = self._logits(params, hm, ctx)
            mtp_targets = jnp.pad(targets[:, 1:], ((0, 0), (0, 1)))
            mtp_mask = mask * jnp.pad(mask[:, 1:], ((0, 0), (0, 1)))
            mtp_ce = jnp.sum(_xent(mtp_logits, mtp_targets) * mtp_mask) / \
                jnp.maximum(jnp.sum(mtp_mask), 1.0)
            loss = loss + mtp_weight * mtp_ce
            aux = aux + aux2
            metrics["mtp_ce"] = mtp_ce
        return loss + aux, metrics

    # -- caches / serving ---------------------------------------------------
    def cache_spec(self, batch, max_len, shards=1, dtype=jnp.bfloat16):
        c = self.cfg
        period, n_groups, rem = self._groups()
        spec = {}
        if c.first_k_dense:
            spec["lead"] = [Block(c, "attn").cache_spec(batch, max_len, shards, dtype)
                            for _ in range(c.first_k_dense)]
        spec["stacks"] = [
            _stack_spec(Block(c, kind).cache_spec(batch, max_len, shards, dtype),
                        n_groups)
            for kind in c.pattern]
        if rem:
            spec["tail"] = [Block(c, k).cache_spec(batch, max_len, shards, dtype)
                            for k in rem]
        return spec

    def prefill(self, params, tokens, cache, ctx: ShardingCtx = NULL_CTX,
                embeddings=None, attn_impl="chunked", q_chunk=1024,
                kv_chunk=1024, scan_layers=True, unroll_attn=False):
        """Prompt pass: returns (last-position logits, filled cache)."""
        c = self.cfg
        period, n_groups, rem = self._groups()
        h = self._embed(params, tokens, ctx, embeddings)
        kw = dict(attn_impl=attn_impl, q_chunk=q_chunk, kv_chunk=kv_chunk,
                  unroll=unroll_attn)
        new_cache = {"stacks": None}
        if c.first_k_dense:
            lead = []
            for i in range(c.first_k_dense):
                h, ci = Block(c, "attn").prefill(
                    params["lead"][i], h, cache["lead"][i], ctx, **kw)
                lead.append(ci)
            new_cache["lead"] = lead

        def group_prefill(h, gp, gc):
            new = []
            for pos_i, kind in enumerate(c.pattern):
                h, ci = Block(c, kind).prefill(gp[pos_i], h, gc[pos_i], ctx, **kw)
                new.append(ci)
            return h, new

        if scan_layers and n_groups > 0:
            def body(h, xs):
                gp, gc = xs
                h, new = group_prefill(h, gp, gc)
                return h, new
            h, stacks = jax.lax.scan(body, h, (params["stacks"], cache["stacks"]))
            new_cache["stacks"] = stacks
        else:
            outs = []
            for g in range(n_groups):
                gp = [jax.tree.map(lambda x: x[g], params["stacks"][p])
                      for p in range(period)]
                gc = [jax.tree.map(lambda x: x[g], cache["stacks"][p])
                      for p in range(period)]
                h, new = group_prefill(h, gp, gc)
                outs.append(new)
            new_cache["stacks"] = [
                jax.tree.map(lambda *xs: jnp.stack(xs), *[o[p] for o in outs])
                for p in range(period)]
        if rem:
            tail = []
            for j, kind in enumerate(rem):
                h, ci = Block(c, kind).prefill(params["tail"][j], h,
                                               cache["tail"][j], ctx, **kw)
                tail.append(ci)
            new_cache["tail"] = tail
        logits = self._logits(params, h[:, -1:], ctx)
        return logits, new_cache

    def decode_step(self, params, token, cache, pos, ctx: ShardingCtx = NULL_CTX,
                    embeddings=None, scan_layers=True):
        """token: (B, C) int32 (C=1 classic decode, C>1 a chunked-prefill
        step); pos: scalar or (B,) int32 — each sequence's first new index
        (attention-kind blocks only accept the vector/chunk forms; the
        serving engine gates on that). Returns (logits (B,C,V), cache)."""
        c = self.cfg
        period, n_groups, rem = self._groups()
        h = self._embed(params, token, ctx, embeddings)
        new_cache = dict(cache)
        if c.first_k_dense:
            lead = []
            for i in range(c.first_k_dense):
                h, ci = Block(c, "attn").decode(params["lead"][i], h,
                                                cache["lead"][i], pos, ctx)
                lead.append(ci)
            new_cache["lead"] = lead

        def group_decode(h, gp, gc):
            new = []
            for pos_i, kind in enumerate(c.pattern):
                h, ci = Block(c, kind).decode(gp[pos_i], h, gc[pos_i], pos, ctx)
                new.append(ci)
            return h, new

        if scan_layers and n_groups > 0:
            def body(h, xs):
                gp, gc = xs
                h, new = group_decode(h, gp, gc)
                return h, new
            h, stacks = jax.lax.scan(body, h, (params["stacks"], cache["stacks"]))
            new_cache["stacks"] = stacks
        else:
            outs = []
            for g in range(n_groups):
                gp = [jax.tree.map(lambda x: x[g], params["stacks"][p])
                      for p in range(period)]
                gc = [jax.tree.map(lambda x: x[g], cache["stacks"][p])
                      for p in range(period)]
                h, new = group_decode(h, gp, gc)
                outs.append(new)
            new_cache["stacks"] = [
                jax.tree.map(lambda *xs: jnp.stack(xs), *[o[p] for o in outs])
                for p in range(period)]
        if rem:
            tail = []
            for j, kind in enumerate(rem):
                h, ci = Block(c, kind).decode(params["tail"][j], h,
                                              cache["tail"][j], pos, ctx)
                tail.append(ci)
            new_cache["tail"] = tail
        return self._logits(params, h, ctx), new_cache

    def num_params(self) -> int:
        return tree_num_params(self.params_spec())


def _stack_spec(spec_tree, n: int):
    """Prepend a 'layers' axis of size n to every ParamSpec in the tree."""
    from ..nn.module import ParamSpec

    def one(s: ParamSpec):
        init = s.init

        def stacked_init(key, shape, dtype):
            base = init or fan_in_init()
            keys = jax.random.split(key, shape[0])
            return jnp.stack([base(k, shape[1:], dtype) for k in keys])

        return ParamSpec((n,) + s.shape, ("layers",) + s.axes,
                         stacked_init, s.dtype)

    return jax.tree.map(one, spec_tree, is_leaf=lambda x: isinstance(x, ParamSpec))


def _xent(logits, targets):
    """Token cross-entropy in fp32. logits: (B,S,V); targets: (B,S)."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return lse - picked
