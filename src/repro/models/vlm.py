"""PaliGemma-style VLM: stub SigLIP frontend + Gemma LM backbone.

Per the assignment the vision tower is a STUB: ``input_specs()`` provides
precomputed patch embeddings (B, n_patches, d_vision). The real parts are the
multimodal projector and the LM (prefix = projected patches, suffix = text,
loss on text only).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp

from ..nn.module import (NULL_CTX, ShardingCtx, fan_in_init, param,
                         tree_num_params)
from .transformer import LMConfig, TransformerLM, _xent


@dataclass(frozen=True)
class VLMConfig:
    lm: LMConfig
    d_vision: int = 1152          # SigLIP-So400m width
    n_patches: int = 256          # 224px / patch 14 → 16×16


@dataclass(frozen=True)
class VLM:
    cfg: VLMConfig

    def params_spec(self):
        c = self.cfg
        return {
            "proj": param((c.d_vision, c.lm.d_model), ("mlp", "embed"),
                          init=fan_in_init((0,)), dtype=c.lm.dtype),
            "lm": TransformerLM(c.lm).params_spec(),
        }

    def _embeddings(self, params, patches, tokens, ctx):
        c = self.cfg
        lm = TransformerLM(c.lm)
        vis = (patches.astype(c.lm.dtype) @ params["proj"])
        txt = lm._embed(params["lm"], tokens, ctx)
        if c.lm.embed_scale:
            # _embed already scales text; scale vision identically
            vis = vis * jnp.sqrt(jnp.asarray(c.lm.d_model, jnp.float32)).astype(vis.dtype)
        return jnp.concatenate([vis, txt], axis=1)

    def loss_fn(self, params, batch, ctx: ShardingCtx = NULL_CTX, **kw):
        """batch: patches (B, P, d_vision), tokens (B, S_text)."""
        c = self.cfg
        lm = TransformerLM(c.lm)
        patches, tokens = batch["patches"], batch["tokens"]
        B, S_txt = tokens.shape
        emb = self._embeddings(params, patches, tokens, ctx)
        full_tokens = jnp.concatenate(
            [jnp.zeros((B, c.n_patches), tokens.dtype), tokens], axis=1)
        logits, aux = lm.apply(params["lm"], full_tokens, ctx,
                               embeddings=emb, **kw)
        # predict next text token; mask out image positions
        targets = jnp.pad(full_tokens[:, 1:], ((0, 0), (0, 1)))
        mask = jnp.concatenate(
            [jnp.zeros((B, c.n_patches), jnp.float32),
             jnp.ones((B, S_txt), jnp.float32)], axis=1)
        mask = mask.at[:, -1].set(0.0)
        ce = jnp.sum(_xent(logits, targets) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
        return ce + aux, {"ce": ce, "aux": aux}

    def cache_spec(self, batch, max_len, shards=1, dtype=jnp.bfloat16):
        return TransformerLM(self.cfg.lm).cache_spec(batch, max_len, shards, dtype)

    def prefill(self, params, batch, cache, ctx: ShardingCtx = NULL_CTX, **kw):
        c = self.cfg
        lm = TransformerLM(c.lm)
        emb = self._embeddings(params, batch["patches"], batch["tokens"], ctx)
        B = batch["tokens"].shape[0]
        full_tokens = jnp.concatenate(
            [jnp.zeros((B, c.n_patches), batch["tokens"].dtype), batch["tokens"]],
            axis=1)
        return lm.prefill(params["lm"], full_tokens, cache, ctx,
                          embeddings=emb, **kw)

    def decode_step(self, params, token, cache, pos, ctx: ShardingCtx = NULL_CTX,
                    **kw):
        return TransformerLM(self.cfg.lm).decode_step(params["lm"], token, cache,
                                                      pos, ctx, **kw)

    def num_params(self):
        return tree_num_params(self.params_spec())
