"""Whisper-style encoder-decoder backbone.

The audio conv frontend is a STUB per the assignment: ``input_specs()``
supplies precomputed frame embeddings (B, T_frames, d_model); everything
after that (encoder stack, decoder stack with cross-attention, serve path)
is real. Whisper idioms: pre-LN with biases, learned absolute positions,
GELU FFN (non-GLU), MHA (kv == heads).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from ..nn.attention import Attention, AttentionConfig
from ..nn.ffn import FFN, FFNConfig
from ..nn.layers import Embedding, LayerNorm
from ..nn.module import (NULL_CTX, ShardingCtx, fan_in_init, param,
                         tree_num_params)
from .transformer import _stack_spec, _xent


@dataclass(frozen=True)
class EncDecConfig:
    name: str
    vocab: int
    d_model: int
    n_enc_layers: int
    n_dec_layers: int
    n_heads: int
    d_ff: int
    max_source_positions: int = 1500
    max_target_positions: int = 448
    dtype: Any = jnp.bfloat16

    @property
    def head_dim(self):
        return self.d_model // self.n_heads

    def attn_cfg(self, causal: bool) -> AttentionConfig:
        return AttentionConfig(
            d_model=self.d_model, n_heads=self.n_heads, n_kv_heads=self.n_heads,
            head_dim=self.head_dim, use_bias=True, out_bias=True, rope=False,
            causal=causal, dtype=self.dtype)

    def ffn_cfg(self) -> FFNConfig:
        return FFNConfig(self.d_model, self.d_ff, activation="gelu", glu=False,
                         use_bias=True, dtype=self.dtype)


@dataclass(frozen=True)
class EncDecLM:
    cfg: EncDecConfig

    # ------------------------------------------------------------------
    def _enc_block_spec(self):
        c = self.cfg
        return {
            "ln1": LayerNorm(c.d_model).params_spec(),
            "attn": Attention(c.attn_cfg(causal=False)).params_spec(),
            "ln2": LayerNorm(c.d_model).params_spec(),
            "ffn": FFN(c.ffn_cfg()).params_spec(),
        }

    def _dec_block_spec(self):
        c = self.cfg
        return {
            "ln1": LayerNorm(c.d_model).params_spec(),
            "self_attn": Attention(c.attn_cfg(causal=True)).params_spec(),
            "ln_x": LayerNorm(c.d_model).params_spec(),
            "cross_attn": Attention(c.attn_cfg(causal=False)).params_spec(),
            "ln2": LayerNorm(c.d_model).params_spec(),
            "ffn": FFN(c.ffn_cfg()).params_spec(),
        }

    def params_spec(self):
        c = self.cfg
        return {
            "enc_pos": param((c.max_source_positions, c.d_model), (None, "embed"),
                             init=fan_in_init((1,)), dtype=c.dtype),
            "enc_stack": _stack_spec(self._enc_block_spec(), c.n_enc_layers),
            "enc_ln": LayerNorm(c.d_model).params_spec(),
            "embed": Embedding(c.vocab, c.d_model, dtype=c.dtype).params_spec(),
            "dec_pos": param((c.max_target_positions, c.d_model), (None, "embed"),
                             init=fan_in_init((1,)), dtype=c.dtype),
            "dec_stack": _stack_spec(self._dec_block_spec(), c.n_dec_layers),
            "dec_ln": LayerNorm(c.d_model).params_spec(),
        }

    # ------------------------------------------------------------------
    def encode(self, params, frames, ctx: ShardingCtx = NULL_CTX,
               attn_impl="chunked", scan_layers=True, remat=True):
        """frames: (B, T, d_model) stub embeddings → encoder output."""
        c = self.cfg
        ln = LayerNorm(c.d_model)
        att = Attention(c.attn_cfg(causal=False))
        ffn = FFN(c.ffn_cfg())
        T = frames.shape[1]
        h = frames.astype(c.dtype) + params["enc_pos"][:T][None]
        h = ctx.constrain(h, ("batch", "seq", "act_embed"))

        def block(h, w):
            h = h + att.apply(w["attn"], ln.apply(w["ln1"], h), ctx,
                              impl=attn_impl)
            h = h + ffn.apply(w["ffn"], ln.apply(w["ln2"], h), ctx)
            return ctx.constrain(h, ("batch", "seq", "act_embed"))

        if scan_layers:
            def body(h, w):
                fn = jax.checkpoint(block) if remat else block
                return fn(h, w), ()
            h, _ = jax.lax.scan(body, h, params["enc_stack"])
        else:
            for i in range(c.n_enc_layers):
                h = block(h, jax.tree.map(lambda x: x[i], params["enc_stack"]))
        return ln.apply(params["enc_ln"], h)

    def decode_train(self, params, tokens, enc_out, ctx: ShardingCtx = NULL_CTX,
                     attn_impl="chunked", scan_layers=True, remat=True):
        """Teacher-forced decoder forward → logits."""
        c = self.cfg
        ln = LayerNorm(c.d_model)
        satt = Attention(c.attn_cfg(causal=True))
        xatt = Attention(c.attn_cfg(causal=False))
        ffn = FFN(c.ffn_cfg())
        emb = Embedding(c.vocab, c.d_model, dtype=c.dtype)
        S = tokens.shape[1]
        h = emb.apply(params["embed"], tokens) + params["dec_pos"][:S][None]
        h = ctx.constrain(h.astype(c.dtype), ("batch", "seq", "act_embed"))

        def block(h, w):
            h = h + satt.apply(w["self_attn"], ln.apply(w["ln1"], h), ctx,
                               impl=attn_impl)
            k, v = xatt.kv(w["cross_attn"], enc_out, ctx)
            h = h + xatt.apply_cross(w["cross_attn"], ln.apply(w["ln_x"], h),
                                     k, v, ctx, impl=attn_impl)
            h = h + ffn.apply(w["ffn"], ln.apply(w["ln2"], h), ctx)
            return ctx.constrain(h, ("batch", "seq", "act_embed"))

        if scan_layers:
            def body(h, w):
                fn = jax.checkpoint(block) if remat else block
                return fn(h, w), ()
            h, _ = jax.lax.scan(body, h, params["dec_stack"])
        else:
            for i in range(c.n_dec_layers):
                h = block(h, jax.tree.map(lambda x: x[i], params["dec_stack"]))
        h = ln.apply(params["dec_ln"], h)
        logits = emb.attend(params["embed"], h)  # tied head (whisper)
        return ctx.constrain(logits, ("batch", "seq", "vocab"))

    def loss_fn(self, params, batch, ctx: ShardingCtx = NULL_CTX, **kw):
        """batch: frames (B,T,D), tokens (B,S)."""
        enc = self.encode(params, batch["frames"], ctx, **kw)
        logits = self.decode_train(params, batch["tokens"], enc, ctx, **kw)
        targets = batch.get("targets")
        if targets is None:
            targets = jnp.pad(batch["tokens"][:, 1:], ((0, 0), (0, 1)))
        ce = jnp.mean(_xent(logits, targets))
        return ce, {"ce": ce}

    # -- serving -----------------------------------------------------------
    def cache_spec(self, batch, max_len, shards=1, dtype=jnp.bfloat16):
        c = self.cfg
        att = Attention(c.attn_cfg(causal=True))
        self_spec = _stack_spec(att.cache_spec(batch, max_len, shards, dtype),
                                c.n_dec_layers)
        # cross K/V: (L, B, T_enc, H, hd) computed at prefill
        xkv = {
            "k": param((c.n_dec_layers, batch, c.max_source_positions, c.n_heads,
                        c.head_dim), ("layers", "batch", "seq", "act_kv", None),
                       init=lambda k, s, d: jnp.zeros(s, d), dtype=dtype),
            "v": param((c.n_dec_layers, batch, c.max_source_positions, c.n_heads,
                        c.head_dim), ("layers", "batch", "seq", "act_kv", None),
                       init=lambda k, s, d: jnp.zeros(s, d), dtype=dtype),
        }
        return {"self": self_spec, "cross": xkv}

    def prefill(self, params, frames, cache, ctx: ShardingCtx = NULL_CTX,
                scan_layers=True):
        """Encode audio and precompute cross K/V. Returns (enc_out, cache)."""
        c = self.cfg
        enc = self.encode(params, frames, ctx, scan_layers=scan_layers,
                          remat=False)
        xatt = Attention(c.attn_cfg(causal=False))

        def per_layer(w):
            return xatt.kv(w["cross_attn"], enc, ctx)

        if scan_layers:
            def body(_, w):
                return (), per_layer(w)
            _, (ks, vs) = jax.lax.scan(body, (), params["dec_stack"])
        else:
            kvs = [per_layer(jax.tree.map(lambda x: x[i], params["dec_stack"]))
                   for i in range(c.n_dec_layers)]
            ks = jnp.stack([k for k, _ in kvs])
            vs = jnp.stack([v for _, v in kvs])
        cache = dict(cache)
        cache["cross"] = {"k": ks.astype(cache["cross"]["k"].dtype),
                          "v": vs.astype(cache["cross"]["v"].dtype)}
        return enc, cache

    def decode_step(self, params, token, cache, pos, ctx: ShardingCtx = NULL_CTX,
                    scan_layers=True):
        c = self.cfg
        ln = LayerNorm(c.d_model)
        satt = Attention(c.attn_cfg(causal=True))
        xatt = Attention(c.attn_cfg(causal=False))
        ffn = FFN(c.ffn_cfg())
        emb = Embedding(c.vocab, c.d_model, dtype=c.dtype)
        # clamp learned position at the table edge for long-decode stress shapes
        p = jnp.minimum(pos, c.max_target_positions - 1)
        h = emb.apply(params["embed"], token) + params["dec_pos"][p][None, None]
        h = h.astype(c.dtype)

        def block(h, w, sc, xk, xv):
            y, sc = satt.decode(w["self_attn"], ln.apply(w["ln1"], h), sc, pos, ctx)
            h = h + y
            h = h + xatt.apply_cross(w["cross_attn"], ln.apply(w["ln_x"], h),
                                     xk, xv, ctx)
            h = h + ffn.apply(w["ffn"], ln.apply(w["ln2"], h), ctx)
            return h, sc

        new_cache = dict(cache)
        if scan_layers:
            def body(h, xs):
                w, sc, xk, xv = xs
                h, sc = block(h, w, sc, xk, xv)
                return h, sc
            h, self_new = jax.lax.scan(
                body, h, (params["dec_stack"], cache["self"],
                          cache["cross"]["k"], cache["cross"]["v"]))
        else:
            outs = []
            for i in range(c.n_dec_layers):
                w = jax.tree.map(lambda x: x[i], params["dec_stack"])
                sc = jax.tree.map(lambda x: x[i], cache["self"])
                h, sc = block(h, w, sc, cache["cross"]["k"][i],
                              cache["cross"]["v"][i])
                outs.append(sc)
            self_new = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
        new_cache["self"] = self_new
        h = ln.apply(params["dec_ln"], h)
        return emb.attend(params["embed"], h), new_cache

    def num_params(self):
        return tree_num_params(self.params_spec())
