"""The paper's evaluation CNNs in JAX: ResNet-50/152, VGG16, CosmoFlow (3-D).

These are the models the ParaDL oracle was validated on (paper Table 5) and
the substrate for the spatial/filter/channel parallel strategies. Layouts are
channels-last. BatchNorm follows paper §4.5.2 (local per-PE by default).

Each model exposes ``params_spec()``, ``apply(params, x, ctx, train)`` and
``loss_fn`` (softmax CE for classification, MSE for CosmoFlow regression),
plus ``layer_table()`` — the per-layer tensor-shape table (|x|,|y|,|w|,FLOPs)
that feeds the oracle's analytical model (paper Table 2 notation).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from ..nn.layers import BatchNorm, Conv, Dense, global_avg_pool, max_pool
from ..nn.module import NULL_CTX, ShardingCtx, tree_num_params
from ..parallel.halo import HaloConv


# ---------------------------------------------------------------------------
# ResNet
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ResNetConfig:
    name: str
    stage_sizes: tuple[int, ...]      # (3,4,6,3) → ResNet-50; (3,8,36,3) → 152
    n_classes: int = 1000
    width: int = 64
    dtype: Any = jnp.float32


RESNET50 = ResNetConfig("resnet50", (3, 4, 6, 3))
RESNET152 = ResNetConfig("resnet152", (3, 8, 36, 3))


@dataclass(frozen=True)
class Bottleneck:
    in_ch: int
    mid_ch: int
    stride: int
    dtype: Any

    @property
    def out_ch(self):
        return self.mid_ch * 4

    def convs(self):
        # the 3×3 is the spatial hot spot: HaloConv runs it as the
        # overlapped halo pipeline under spatial/ds sharding (stride-2
        # bottleneck entries fall back to the plain path automatically)
        return {
            "conv1": Conv(self.in_ch, self.mid_ch, (1, 1), use_bias=False,
                          dtype=self.dtype),
            "conv2": HaloConv(self.mid_ch, self.mid_ch, (3, 3),
                              strides=(self.stride, self.stride),
                              use_bias=False, dtype=self.dtype),
            "conv3": Conv(self.mid_ch, self.out_ch, (1, 1), use_bias=False,
                          dtype=self.dtype),
        }

    def params_spec(self):
        spec = {k: c.params_spec() for k, c in self.convs().items()}
        spec["bn1"] = BatchNorm(self.mid_ch).params_spec()
        spec["bn2"] = BatchNorm(self.mid_ch).params_spec()
        spec["bn3"] = BatchNorm(self.out_ch).params_spec()
        if self.stride != 1 or self.in_ch != self.out_ch:
            spec["proj"] = Conv(self.in_ch, self.out_ch, (1, 1),
                                strides=(self.stride, self.stride),
                                use_bias=False, dtype=self.dtype).params_spec()
            spec["bn_proj"] = BatchNorm(self.out_ch).params_spec()
        return spec

    def apply(self, params, x, ctx: ShardingCtx = NULL_CTX, train=True):
        convs = self.convs()
        y = convs["conv1"].apply(params["conv1"], x, ctx)
        y = jax.nn.relu(BatchNorm(self.mid_ch).apply(params["bn1"], y, ctx, train))
        y = ctx.constrain(y, ("batch", "spatial", None, "conv_out"))
        y = convs["conv2"].apply(params["conv2"], y, ctx)
        y = jax.nn.relu(BatchNorm(self.mid_ch).apply(params["bn2"], y, ctx, train))
        y = convs["conv3"].apply(params["conv3"], y, ctx)
        y = BatchNorm(self.out_ch).apply(params["bn3"], y, ctx, train)
        if "proj" in params:
            sc = Conv(self.in_ch, self.out_ch, (1, 1),
                      strides=(self.stride, self.stride), use_bias=False,
                      dtype=self.dtype).apply(params["proj"], x, ctx)
            sc = BatchNorm(self.out_ch).apply(params["bn_proj"], sc, ctx, train)
        else:
            sc = x
        y = jax.nn.relu(y + sc)
        return ctx.constrain(y, ("batch", "spatial", None, "conv_out"))


@dataclass(frozen=True)
class ResNet:
    cfg: ResNetConfig

    def _blocks(self):
        c = self.cfg
        blocks = []
        in_ch = c.width
        for stage, n in enumerate(c.stage_sizes):
            mid = c.width * (2 ** stage)
            for b in range(n):
                stride = 2 if (b == 0 and stage > 0) else 1
                blocks.append(Bottleneck(in_ch, mid, stride, c.dtype))
                in_ch = mid * 4
        return blocks

    def params_spec(self):
        c = self.cfg
        spec = {
            "stem": HaloConv(3, c.width, (7, 7), strides=(2, 2),
                             use_bias=False, dtype=c.dtype).params_spec(),
            "bn_stem": BatchNorm(c.width).params_spec(),
            "blocks": [b.params_spec() for b in self._blocks()],
            "head": Dense(512 * 4, c.n_classes, use_bias=True, in_axis="mlp",
                          out_axis="vocab", dtype=c.dtype).params_spec(),
        }
        return spec

    def apply(self, params, x, ctx: ShardingCtx = NULL_CTX, train=True):
        c = self.cfg
        h = HaloConv(3, c.width, (7, 7), strides=(2, 2), use_bias=False,
                     dtype=c.dtype).apply(params["stem"], x, ctx)
        h = jax.nn.relu(BatchNorm(c.width).apply(params["bn_stem"], h, ctx, train))
        h = max_pool(h, (3, 3), (2, 2), "SAME")
        for i, b in enumerate(self._blocks()):
            h = b.apply(params["blocks"][i], h, ctx, train)
        h = global_avg_pool(h)
        return Dense(512 * 4, c.n_classes, use_bias=True, in_axis="mlp",
                     out_axis="vocab", dtype=c.dtype).apply(params["head"], h, ctx)

    def loss_fn(self, params, batch, ctx: ShardingCtx = NULL_CTX, train=True):
        logits = self.apply(params, batch["images"], ctx, train)
        ce = _softmax_xent(logits, batch["labels"])
        return ce, {"ce": ce}

    def num_params(self):
        return tree_num_params(self.params_spec())


# ---------------------------------------------------------------------------
# VGG16
# ---------------------------------------------------------------------------
_VGG16_LAYOUT = (64, 64, "M", 128, 128, "M", 256, 256, 256, "M",
                 512, 512, 512, "M", 512, 512, 512, "M")


@dataclass(frozen=True)
class VGGConfig:
    name: str = "vgg16"
    n_classes: int = 1000
    img: int = 224
    dtype: Any = jnp.float32


@dataclass(frozen=True)
class VGG:
    cfg: VGGConfig

    def _convs(self):
        convs, in_ch = [], 3
        for v in _VGG16_LAYOUT:
            if v == "M":
                convs.append("M")
            else:
                convs.append(HaloConv(in_ch, v, (3, 3), dtype=self.cfg.dtype))
                in_ch = v
        return convs

    def params_spec(self):
        c = self.cfg
        feat = c.img // 32
        spec = {"convs": [x.params_spec() for x in self._convs() if x != "M"]}
        spec["fc1"] = Dense(512 * feat * feat, 4096, use_bias=True,
                            in_axis="mlp", out_axis="embed",
                            dtype=c.dtype).params_spec()
        spec["fc2"] = Dense(4096, 4096, use_bias=True, in_axis="embed",
                            out_axis="mlp", dtype=c.dtype).params_spec()
        spec["fc3"] = Dense(4096, c.n_classes, use_bias=True, in_axis="mlp",
                            out_axis="vocab", dtype=c.dtype).params_spec()
        return spec

    def apply(self, params, x, ctx: ShardingCtx = NULL_CTX, train=True):
        c = self.cfg
        h, i = x, 0
        for layer in self._convs():
            if layer == "M":
                h = max_pool(h, (2, 2), (2, 2), "VALID")
            else:
                h = jax.nn.relu(layer.apply(params["convs"][i], h, ctx))
                h = ctx.constrain(h, ("batch", "spatial", None, "conv_out"))
                i += 1
        h = h.reshape(h.shape[0], -1)
        feat = c.img // 32
        h = jax.nn.relu(Dense(512 * feat * feat, 4096, use_bias=True,
                              in_axis="mlp", out_axis="embed",
                              dtype=c.dtype).apply(params["fc1"], h, ctx))
        h = jax.nn.relu(Dense(4096, 4096, use_bias=True, in_axis="embed",
                              out_axis="mlp", dtype=c.dtype).apply(
                                  params["fc2"], h, ctx))
        return Dense(4096, c.n_classes, use_bias=True, in_axis="mlp",
                     out_axis="vocab", dtype=c.dtype).apply(params["fc3"], h, ctx)

    def loss_fn(self, params, batch, ctx: ShardingCtx = NULL_CTX, train=True):
        logits = self.apply(params, batch["images"], ctx, train)
        ce = _softmax_xent(logits, batch["labels"])
        return ce, {"ce": ce}

    def num_params(self):
        return tree_num_params(self.params_spec())


# ---------------------------------------------------------------------------
# CosmoFlow (3-D CNN, regression) — the paper's ds-hybrid flagship
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class CosmoFlowConfig:
    name: str = "cosmoflow"
    img: int = 128               # cube edge (paper uses 256³/512³; smoke uses less)
    in_ch: int = 4
    n_targets: int = 4
    width: int = 16
    n_conv: int = 5
    dtype: Any = jnp.float32


@dataclass(frozen=True)
class CosmoFlow:
    cfg: CosmoFlowConfig

    def _convs(self):
        c = self.cfg
        convs, in_ch = [], c.in_ch
        for i in range(c.n_conv):
            out = c.width * (2 ** i)
            convs.append(HaloConv(in_ch, out, (3, 3, 3), dtype=c.dtype))
            in_ch = out
        return convs

    def _flat_dim(self):
        c = self.cfg
        edge = c.img // (2 ** c.n_conv)
        return (c.width * 2 ** (c.n_conv - 1)) * edge ** 3

    def params_spec(self):
        spec = {"convs": [x.params_spec() for x in self._convs()]}
        spec["fc1"] = Dense(self._flat_dim(), 128, use_bias=True, in_axis="mlp",
                            out_axis="embed", dtype=self.cfg.dtype).params_spec()
        spec["fc2"] = Dense(128, 64, use_bias=True, in_axis="embed",
                            out_axis="mlp", dtype=self.cfg.dtype).params_spec()
        spec["out"] = Dense(64, self.cfg.n_targets, use_bias=True, in_axis="mlp",
                            out_axis=None, dtype=self.cfg.dtype).params_spec()
        return spec

    def apply(self, params, x, ctx: ShardingCtx = NULL_CTX, train=True):
        c = self.cfg
        h = x
        for i, conv in enumerate(self._convs()):
            h = jax.nn.leaky_relu(conv.apply(params["convs"][i], h, ctx))
            h = ctx.constrain(h, ("batch", "spatial", None, None, "conv_out"))
            h = max_pool(h, (2, 2, 2), (2, 2, 2), "VALID")
        h = h.reshape(h.shape[0], -1)
        h = jax.nn.leaky_relu(Dense(self._flat_dim(), 128, use_bias=True,
                                    in_axis="mlp", out_axis="embed",
                                    dtype=c.dtype).apply(params["fc1"], h, ctx))
        h = jax.nn.leaky_relu(Dense(128, 64, use_bias=True, in_axis="embed",
                                    out_axis="mlp", dtype=c.dtype).apply(
                                        params["fc2"], h, ctx))
        return Dense(64, c.n_targets, use_bias=True, in_axis="mlp",
                     out_axis=None, dtype=c.dtype).apply(params["out"], h, ctx)

    def loss_fn(self, params, batch, ctx: ShardingCtx = NULL_CTX, train=True):
        pred = self.apply(params, batch["images"], ctx, train)
        mse = jnp.mean((pred - batch["targets"]) ** 2)
        return mse, {"mse": mse}

    def num_params(self):
        return tree_num_params(self.params_spec())


def _softmax_xent(logits, labels):
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(lse - picked)
