from .transformer import Block, LMConfig, TransformerLM
from .encdec import EncDecConfig, EncDecLM
from .vlm import VLM, VLMConfig
from .cnn import (RESNET50, RESNET152, CosmoFlow, CosmoFlowConfig, ResNet,
                  ResNetConfig, VGG, VGGConfig)
