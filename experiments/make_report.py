"""Regenerate EXPERIMENTS.md: dry-run roofline + oracle sweep/tuner tables.

Four sections are (re)generated in place, each delimited by its own heading:
  * "### Baseline cells" / "### Hillclimb" — from launch/dryrun JSON
    artifacts in experiments/dryrun/ (empty tables when none exist yet),
  * "### Oracle sweep" — projected straight from the vectorized sweep
    engine (core/sweep.py): best strategy per scale for the paper's models,
    with bottleneck classification and the data→df crossover point,
  * "### Auto-tuner decisions" — what `strategy="auto"` deploys per
    (model, p): the cheapest feasible (strategy, p1·p2, memory switches)
    point from core/autotune.py, with the executable rules table,
  * "### Oracle vs HLO cross-check" — every train-kind dry-run cell's
    compiled-HLO roofline bound compared against the oracle projection for
    the same (strategy, mesh); rows off by more than {TOL}× either way are
    flagged instead of silently diverging.

Later sections (overlap / pipeline / schedule / cluster-fit / kernel-tune)
render the validation artifacts scripts/check.sh and the CLI entry points
write under experiments/.

Usage: PYTHONPATH=src python experiments/make_report.py
"""
import json
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).parent.parent / "src"))

HDR = ("| arch | shape | mesh | strategy | comp ms | mem ms | coll ms | dom |"
       " useful | frac | args GiB | temp GiB |\n"
       "|---|---|---|---|---|---|---|---|---|---|---|---|")

SWEEP_HDR = ("| model | p | strategy | p1×p2 | total ms/iter | mem GiB |"
             " bottleneck |\n|---|---|---|---|---|---|---|")

TUNER_HDR = ("| model | p | strategy | p1×p2 | switches | exec rules |"
             " ms/iter | mem GiB | bottleneck |\n"
             "|---|---|---|---|---|---|---|---|---|")

XCHECK_HDR = ("| arch | shape | mesh | strategy | HLO bound ms | oracle ms |"
              " ratio | verdict |\n|---|---|---|---|---|---|---|---|")

PIPE_HDR = ("| strategy | p | measured ms | projected ms | accuracy |\n"
            "|---|---|---|---|---|")

SCHED_HDR = ("| schedule | t(S_small) ms | t(S_large) ms | per-µbatch ms |"
             " bubble ms | bubble fraction |\n|---|---|---|---|---|---|")

TENSOR2D_HDR = ("| plan | p1×p2r×p2c | projected ms | measured ms |\n"
                "|---|---|---|---|")

CLUSTER_HDR = ("| level | α (µs) | β⁻¹ (GB/s) | φ | σ | fit residual |\n"
               "|---|---|---|---|---|---|")

KT_HDR = ("| kernel (bucket) | blocks | predicted µs | measured µs |"
          " vs default | |\n|---|---|---|---|---|---|")

# oracle-vs-HLO tolerance: both are coarse bounds (no-overlap roofline vs
# α–β analytical model), so only order-of-magnitude drift is flagged
TOL = 3.0

SKELETON = """# EXPERIMENTS

Auto-generated tables — run `PYTHONPATH=src python experiments/make_report.py`.

### Baseline cells (required matrix)

### Hillclimb / variant cells (tagged)

### Oracle sweep (vectorized strategy × scale projections)

### Auto-tuner decisions (what strategy="auto" deploys)

### Oracle vs HLO cross-check (dry-run cells)

### Pipeline validation (oracle vs measured)

### Schedule validation (measured bubble per schedule, oracle-picked winner)

### Cluster calibration

### Kernel autotune (prune → measure → cache)

### Per-cell observations

(hand-written notes go here; everything above the marker is regenerated)
"""


def row(r):
    rl = r["roofline"]
    return (f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['strategy']}"
            f"{('/' + r['tag']) if r.get('tag') else ''} | "
            f"{rl['compute_s']*1e3:,.1f} | {rl['memory_s']*1e3:,.1f} | "
            f"{rl['collective_s']*1e3:,.1f} | {rl['dominant'][:4]} | "
            f"{rl['useful_ratio']:.2f} | {rl['roofline_fraction']:.3f} | "
            f"{r['memory']['args_gib']:.1f} | {r['memory']['temp_gib']:.1f} |")


def load_dryrun(here: pathlib.Path) -> list:
    return [json.loads(f.read_text())
            for f in sorted((here / "dryrun").glob("*.json"))]


def dryrun_sections(recs: list) -> tuple[str, int, int]:
    base = [r for r in recs if not r.get("tag")]
    opt = [r for r in recs if r.get("tag")]
    out = ["### Baseline cells (required matrix)", "", HDR]
    out += [row(r) for r in base] or ["| _no dry-run artifacts yet_ |" + " |" * 11]
    out += ["", "### Hillclimb / variant cells (tagged)", "", HDR]
    out += [row(r) for r in opt] or ["| _no dry-run artifacts yet_ |" + " |" * 11]
    return "\n".join(out), len(base), len(opt)


def sweep_section() -> str:
    from repro.api import Oracle

    grid = [2 ** k for k in range(11)]
    out = ["### Oracle sweep (vectorized strategy × scale projections)", "",
           "Best deployable split per (model, p) on the paper's V100 "
           "cluster model, weak scaling 2 samples/PE; from the `Oracle` "
           "session facade (= `python -m repro.core.sweep`). Pipeline rows "
           "are excluded here: the pipeline story has its own schedule "
           "axis now (gpipe / 1F1B / interleaved, DESIGN.md §9) — the "
           "auto-tuner table ranks pipeline against these splits where "
           "deployable, and the 'Schedule validation' section measures it.",
           "", SWEEP_HDR]
    models = {"resnet50": 1_281_167, "vgg16": 1_281_167, "cosmoflow": 1584}
    for name, D in models.items():
        batch_of = lambda p: max(2 * p, 4)            # noqa: E731
        ses = Oracle(name, "train_4k", "paper", batch=batch_of(grid[-1]),
                     dataset=max(D, batch_of(grid[-1])))
        res = ses.sweep(grid, batch_for_p=batch_of,
                        mem_cap=ses.tm.system.mem_capacity)
        res = res.select(res.strategy != "pipeline")
        best = res.best_per_p()
        for p in grid:
            sub = best.select(best.p == p)
            if not len(sub):
                continue
            i = int(sub.total_s.argmin())
            it = max(float(sub.iterations[i]), 1.0)
            out.append(f"| {name} | {p} | {sub.strategy[i]} | "
                       f"{int(sub.p1[i])}×{int(sub.p2[i])} | "
                       f"{float(sub.total_s[i])/it*1e3:,.2f} | "
                       f"{float(sub.mem_bytes[i])/2**30:.2f} | "
                       f"{sub.bottleneck[i]} |")
        x = res.crossover("data", "df")
        out.append(f"\ndata→df crossover for {name}: "
                   f"{'p=%d' % x if x else 'not on this grid'}\n")
    return "\n".join(out)


def tuner_section() -> str:
    """What ``strategy="auto"`` actually deploys, per (model, p)."""
    from repro.api import Oracle

    out = ["### Auto-tuner decisions (what strategy=\"auto\" deploys)", "",
           "Cheapest feasible (strategy, p1·p2 split, memory switches) per "
           "(model, p) on the paper's V100 cluster model, weak scaling "
           "2 samples/PE; ties go to the arch config's registered strategy. "
           "From `Oracle(model, shape, \"paper\").tune(p)` "
           "(= `python -m repro.core.autotune`).", "", TUNER_HDR]
    models = {"resnet50": 1_281_167, "vgg16": 1_281_167, "cosmoflow": 1584}
    for name, D in models.items():
        for p in (8, 64, 512, 1024):
            B = max(2 * p, 4)
            # all three models are CNNs: the session's tune() derives
            # allow_remat=False (no checkpointing in CNN forwards) from
            # the arch registry; since ISSUE 7 their trunks CAN pipeline
            # (per-stage program specialization), so pipeline plans are
            # ranked — with stage counts bounded by the block count
            plan = Oracle(name, "train_4k", "paper", batch=B,
                          dataset=max(D, B)).tune(p)
            mark = "" if plan.feasible else " (fallback!)"
            out.append(f"| {name} | {p} | {plan.strategy}{mark} | "
                       f"{plan.p1}×{plan.p2} | {plan.switch_str()} | "
                       f"`{plan.exec_strategy('train')}` | "
                       f"{plan.per_iter_s * 1e3:,.2f} | "
                       f"{plan.mem_bytes / 2**30:.2f} | {plan.bottleneck} |")
    return "\n".join(out)


def crosscheck_section(recs: list) -> str:
    """Dry-run HLO roofline bound vs oracle projection for the same cell.

    Flags per-mesh disagreements > {TOL}× either way so the two models can't
    silently diverge (ROADMAP item 6).
    """
    from repro.configs import SHAPES, get_config
    from repro.core import OracleConfig, TPU_V5E_POD, TimeModel, project
    from repro.core.autotune import ORACLE_OF_EXEC, stats_for_model

    out = ["### Oracle vs HLO cross-check (dry-run cells)", "",
           f"Per train-kind dry-run cell: compiled-HLO no-overlap roofline "
           f"bound vs the oracle's α–β projection for the same (strategy, "
           f"mesh). Both are coarse bounds; rows off by > {TOL}× either way "
           f"are flagged `⚠ mismatch`.", "", XCHECK_HDR]
    rows, n_flagged = [], 0
    for r in recs:
        if r.get("kind") != "train":
            continue
        pl = r.get("plan") or {}
        strat = pl.get("strategy") or ORACLE_OF_EXEC.get(r["strategy"])
        if strat is None:
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                        f"{r['strategy']} | — | — | — | no oracle mapping |")
            continue
        try:
            cfg = get_config(r["arch"])
            shape = SHAPES[r["shape"]]
            dims = [int(x) for x in r["mesh"].removeprefix("pod").split("x")]
            # trust the recorded plan's split only when the cell's mesh
            # actually realized it; otherwise project for the built mesh
            if pl.get("split_deployed"):
                p2, p1 = int(pl["p2"]), int(pl["p1"])
            else:
                p2 = dims[-1]
                p1 = max(r["chips"] // p2, 1)
            stats = stats_for_model(cfg.model, shape.seq_len)
            # project under the memory model the cell actually deployed:
            # the recorded TunedPlan switches when the cell was auto-tuned,
            # else what the rules-table name implies
            ocfg = OracleConfig(
                B=shape.global_batch, D=shape.global_batch,
                remat=bool(pl.get("remat", False)),
                zero1=bool(pl.get("zero1", "zero1" in r["strategy"])),
                zero3=bool(pl.get("zero3", "zero3" in r["strategy"])),
                seq_parallel=bool(pl.get("seq_parallel", False)))
            proj = project(strat, stats, TimeModel(TPU_V5E_POD), ocfg,
                           r["chips"], p1=p1, p2=p2)
            oracle_s = proj.per_iteration()["total_s"]
            hlo_s = r["roofline"]["step_time_bound_s"]
            ratio = oracle_s / hlo_s if hlo_s else float("inf")
            flagged = not (1.0 / TOL <= ratio <= TOL)
            n_flagged += flagged
            rows.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                f"{r['strategy']} | {hlo_s * 1e3:,.1f} | {oracle_s * 1e3:,.1f} | "
                f"{ratio:.2f} | {'⚠ mismatch' if flagged else 'ok'} |")
        except Exception as e:  # noqa: BLE001 — report the row, keep going
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                        f"{r['strategy']} | — | — | — | error: {e} |")
    out += rows or ["| _no train-kind dry-run artifacts yet_ |" + " |" * 7]
    if n_flagged:
        out.append(f"\n**{n_flagged} cell(s) flagged** — oracle and HLO "
                   f"disagree by more than {TOL}×; recalibrate or investigate.")
    return "\n".join(out)


OVERLAP_HDR = ("| strategy | p | measured ms | overlap model ms | accuracy |"
               " serial model ms | accuracy |\n|---|---|---|---|---|---|---|")


def overlap_section(here: pathlib.Path) -> str:
    """Overlap-model vs serial-model accuracy on the measured ds step.

    Reads the artifact written by the overlap smoke
    (``python tests/helpers/multidevice_checks.py
    spatial_overlap_validation --write
    experiments/spatial_overlap_validation.json`` — scripts/check.sh runs
    it).
    """
    out = ["### Overlap validation (oracle-with-overlap vs serial model "
           "vs measured)", "",
           "ISSUE 4: the oracle charges *exposed* communication — halo P2P "
           "hides under interior conv compute (σ_model=0.9 by default), "
           "the gradient exchange under backward compute (σ_data=0.8); "
           "`--no-overlap` restores the paper's serial accounting. σ is a "
           "per-system empirical parameter like α–β (ROADMAP φ/σ fitting), "
           "so the host check follows the paper's calibrate-then-validate "
           "methodology: one calibration, σ̂ fitted on the measured B=2 "
           "spatial (`ds`) step, validated against the serial model on the "
           "held-out B=4 step (`spatial_overlap_validation` multidevice "
           "check).", ""]
    art = here / "spatial_overlap_validation.json"
    if not art.exists():
        out.append("_no overlap validation artifact yet — run "
                   "`scripts/check.sh` (or the `spatial_overlap_validation` "
                   "multidevice check with `--write`)_")
        return "\n".join(out)
    rec = json.loads(art.read_text())
    mesh = "×".join(str(v) for v in rec["mesh"].values())
    sig = rec.get("sigma_fitted")
    out += [f"Model `{rec['model']}` (GE-dominated comm), mesh {mesh}, "
            f"held-out B={rec['B']}"
            + (f", fitted σ̂={sig:.2f}" if sig is not None else "")
            + ":", "", OVERLAP_HDR]
    for pt in rec["points"]:
        out.append(f"| {pt['strategy']} | {pt['p']} | "
                   f"{pt['measured_s'] * 1e3:,.1f} | "
                   f"{pt['projected_s'] * 1e3:,.1f} | "
                   f"**{pt['accuracy'] * 100:.1f}%** | "
                   f"{pt['projected_serial_s'] * 1e3:,.1f} | "
                   f"{pt['accuracy_serial'] * 100:.1f}% |")
    out += ["",
            "Projection-side shift at scale (paper V100 model, CosmoFlow "
            "0.25 samples/PE weak scaling): the spatial→ds crossover moves "
            "from p=64 (serial accounting) to p=128 (overlap on) — pure "
            "spatial stays ahead while its halo exchange is hidden; the "
            "resnet50 data→df crossover stays at p=512 (GE overlap "
            "discounts both sides alike)."]
    return "\n".join(out)


def pipeline_section(here: pathlib.Path) -> str:
    """Measured GPipe runs vs the oracle's non-uniform pipeline row.

    Reads the artifact written by the pipeline deploy+validate smoke
    (``python tests/helpers/multidevice_checks.py pipeline_validation
    --write experiments/pipeline_validation.json`` — scripts/check.sh runs
    it); reports the paper's Fig-3 accuracy metric per strategy.
    """
    out = ["### Pipeline validation (oracle vs measured)", "",
           "The last Table-3 strategy measured (ISSUE 3): the GPipe stage "
           "executor (`parallel/pipeline.py`) runs on virtual host devices "
           "and is compared against the oracle's DP-partitioned pipeline "
           "row. Accuracy = 1 − |proj − meas| / meas (paper §5.2).", ""]
    art = here / "pipeline_validation.json"
    if not art.exists():
        out.append("_no pipeline validation artifact yet — run "
                   "`scripts/check.sh` (or the `pipeline_validation` "
                   "multidevice check with `--write`)_")
        return "\n".join(out)
    rec = json.loads(art.read_text())
    mesh = "x".join(str(v) for v in rec["mesh"].values())
    out += [f"Model `{rec['model']}`, mesh {mesh}, B={rec['B']}, "
            f"S={rec['S']}:", "", PIPE_HDR]
    for pt in rec["points"]:
        out.append(f"| {pt['strategy']} | {pt['p']} | "
                   f"{pt['measured_s'] * 1e3:,.1f} | "
                   f"{pt['projected_s'] * 1e3:,.1f} | "
                   f"{pt['accuracy'] * 100:.1f}% |")
    return "\n".join(out)


def schedule_section(here: pathlib.Path) -> str:
    """Measured bubble per pipeline schedule + oracle-vs-measured winner.

    Reads the artifact written by the schedule smoke
    (``python tests/helpers/multidevice_checks.py schedule_validation
    --write experiments/schedule_validation.json`` — scripts/check.sh runs
    it with retries).
    """
    out = ["### Schedule validation (measured bubble per schedule, "
           "oracle-picked winner)", "",
           "ISSUE 7: the stage executor clocks gpipe / 1F1B / interleaved "
           "over the same stage cut; the step time is fitted as "
           "t(S) = a·S + b at fixed per-microbatch size, so b IS the "
           "fill/drain (bubble) overhead. The check asserts the 1F1B and "
           "interleaved bubbles land under GPipe's at equal S, and that "
           "`schedule_winner` (the oracle's schedule axis on the "
           "calibrated host) names the measured-fastest schedule. Note "
           "the executor's 1F1B realizes ≤p in-flight via windowed remat: "
           "the recompute rides the per-microbatch slope, which is why "
           "its a exceeds GPipe's while its bubble shrinks.", ""]
    art = here / "schedule_validation.json"
    if not art.exists():
        out.append("_no schedule validation artifact yet — run "
                   "`scripts/check.sh` (or the `schedule_validation` "
                   "multidevice check with `--write`)_")
        return "\n".join(out)
    rec = json.loads(art.read_text())
    out += [f"Model `{rec['model']}`, p={rec['p']} stages, "
            f"S∈{{{rec['S_small']}, {rec['S_large']}}}:", "", SCHED_HDR]
    for name, b in rec["schedules"].items():
        out.append(f"| {name} | {b['t_small_s'] * 1e3:,.1f} | "
                   f"{b['t_large_s'] * 1e3:,.1f} | "
                   f"{b['per_microbatch_s'] * 1e3:,.2f} | "
                   f"{b['bubble_s'] * 1e3:,.1f} | "
                   f"**{b['bubble_fraction'] * 100:.1f}%** |")
    out += ["", f"Oracle winner: **{rec['oracle_winner']}** — measured "
            f"winner: **{rec['measured_winner']}**. (On the paper's V100 "
            "cluster the oracle instead picks gpipe: interleaved's v× P2P "
            "launches outweigh its bubble savings there — the winner is a "
            "per-(model, p, cluster) call, which is the point of pricing "
            "schedules in the oracle.)"]
    return "\n".join(out)


def tensor2d_section(here: pathlib.Path) -> str:
    """Tuned 2D SUMMA point vs best data-parallel plan, oracle vs clock.

    Reads the artifact written by the 2D tensor smoke
    (``python tests/helpers/multidevice_checks.py tensor2d_validation
    --write experiments/tensor2d_validation.json`` — scripts/check.sh runs
    it with retries).
    """
    out = ["### 2D tensor validation (SUMMA lattice point, oracle winner "
           "vs measured winner)", "",
           "ISSUE 9: the sweep lattice fans the model width over "
           "(p2r, p2c) grids and the `summa` rules deploy the 2D "
           "(row × col) SUMMA matmul path (`parallel/summa.py`, DESIGN.md "
           "§14). On a weight-heavy / batch-light LM, 8-way DP moves the "
           "full gradient every step while SUMMA moves (r−1)/r weight "
           "panels over one grid ring plus tiny activation gathers (the "
           "σ-overlapped seq-parallel comm term) over the other — so the "
           "tuner should pick a 2D point and the clock should agree "
           "(`tensor2d_validation` multidevice check).", ""]
    art = here / "tensor2d_validation.json"
    if not art.exists():
        out.append("_no 2D tensor validation artifact yet — run "
                   "`scripts/check.sh` (or the `tensor2d_validation` "
                   "multidevice check with `--write`)_")
        return "\n".join(out)
    rec = json.loads(art.read_text())
    pl, alt, ms = rec["plan"], rec["alt"], rec["measured"]
    out += [f"Model `{rec['model']}`, p={rec['p']}, B={rec['B']}, "
            f"S={rec['S']}:", "", TENSOR2D_HDR,
            f"| {pl['strategy']}:{pl['p2r']}x{pl['p2c']} | "
            f"{pl['p1']}×{pl['p2r']}×{pl['p2c']} | "
            f"{pl['projected_s'] * 1e3:,.1f} | "
            f"{ms['summa_s'] * 1e3:,.1f} |",
            f"| {alt['strategy']} | {alt['p1']}×{alt['p2']} | "
            f"{alt['projected_s'] * 1e3:,.1f} | "
            f"{ms['data_s'] * 1e3:,.1f} |", "",
            f"Oracle winner: **{rec['oracle_winner']}** — measured "
            f"winner: **{rec['measured_winner']}**."]
    return "\n".join(out)


SERVE_HDR = ("| strategy | kv shards | oracle tok/s | oracle p99 ms |"
             " measured tok/s | measured p50 ms |\n|---|---|---|---|---|---|")


def serving_section(here: pathlib.Path) -> str:
    """Serving oracle rows vs the measured continuous-batching engine.

    Reads the artifact written by the serving smoke
    (``python tests/helpers/multidevice_checks.py serving_validation
    --write experiments/serving_validation.json`` — scripts/check.sh runs
    it with retries).
    """
    out = ["### Serving validation (oracle winner vs measured winner)", "",
           "ISSUE 10: the continuous-batching engine (`serve/engine.py`) "
           "replays one Poisson trace through the paged KV cache under "
           "both serving rules tables on a 2-device host mesh — `serve_tp` "
           "(KV sharded over heads, 2 collectives/layer) vs `serve_seqkv` "
           "(KV sharded over the cache span, 3 collectives/layer for the "
           "LSE merge). The check pins two things: every request's tokens "
           "are bit-exact vs a dense single-device greedy reference (the "
           "paged gather/scatter and batch joins/evictions are invisible "
           "to the math), and the serving oracle's throughput winner "
           "(`serve/oracle.py`, M/D/1 on priced prefill/decode steps) is "
           "the measured winner. Absolute tok/s differ wildly — the "
           "oracle prices the machine description, the measurement eats "
           "host dispatch overhead — but the RANKING is the oracle's "
           "product, same as the training validations above.", ""]
    art = here / "serving_validation.json"
    if not art.exists():
        out.append("_no serving validation artifact yet — run "
                   "`scripts/check.sh` (or the `serving_validation` "
                   "multidevice check with `--write`)_")
        return "\n".join(out)
    rec = json.loads(art.read_text())
    tr = rec["traffic"]
    out += [f"Model `{rec['model']}`, p2={rec['p2']}, "
            f"max_len={rec['max_len']}, traffic λ={tr['rate']}/s, "
            f"prompt={tr['prompt_len']}, gen={tr['gen_len']}, "
            f"{tr['requests']} requests:", "", SERVE_HDR]
    for s, orc in rec["oracle"].items():
        ms = rec["measured"][s]
        kv = rec["p2"] if s == "serve_seqkv" else 1
        out.append(f"| {s} | {kv} | {orc['tok_per_s']:,.0f} | "
                   f"{orc['latency_p99_s'] * 1e3:,.2f} | "
                   f"{ms['tok_per_s']:,.1f} | "
                   f"{ms['latency_p50_s'] * 1e3:,.1f} |")
    out += ["", f"Oracle winner: **{rec['oracle_winner']}** — measured "
            f"winner: **{rec['measured_winner']}**; tokens bit-exact vs "
            f"dense reference: **{rec['tokens_bit_exact_vs_dense']}**."]
    return "\n".join(out)


def cluster_section(here: pathlib.Path) -> str:
    """Fitted ClusterSpec (α/β, φ, σ per interconnect level + residuals).

    Reads the artifact written by the calibration harness
    (``python -m repro.api --calibrate --out experiments/cluster_fit.json``)
    — the measured machine description ``ClusterSpec.from_json`` loads and
    any entry point consumes via ``--cluster experiments/cluster_fit.json``.
    """
    out = ["### Cluster calibration (fitted ClusterSpec)", "",
           "ISSUE 5 / ROADMAP φ–σ fitting: the measurement harness "
           "(`core/calibration.calibrate_cluster`) times ring collectives "
           "at several sizes (Hockney α/β least squares), concurrent "
           "flows (contention φ, §4.3) and independent compute+comm "
           "programs (overlap σ, DESIGN.md §10) per mesh axis, and "
           "`ClusterSpec.fitted_from` turns the raw measurements into a "
           "deployable machine description. Reload it anywhere with "
           "`--cluster experiments/cluster_fit.json` or "
           "`ClusterSpec.from_json(...)`.", ""]
    art = here / "cluster_fit.json"
    if not art.exists():
        out.append("_no fitted-cluster artifact yet — run "
                   "`PYTHONPATH=src python -m repro.api --calibrate "
                   "--out experiments/cluster_fit.json`_")
        return "\n".join(out)
    rec = json.loads(art.read_text())
    meta = rec.get("meta", {})
    mesh = "×".join(str(v) for v in meta.get("mesh", {}).values()) or "?"
    out += [f"`{rec['name']}` — mesh {mesh} "
            f"({meta.get('devices', '?')} virtual host devices, "
            f"jax {meta.get('jax', '?')}); peak "
            f"{rec['peak_flops'] / 1e9:.1f} GFLOP/s/PE measured:", "",
            CLUSTER_HDR]
    phi = rec.get("phi") or {}
    sigma = rec.get("sigma") or {}
    resid = rec.get("fit_residuals", {})
    for ax, lv in rec["levels"].items():
        r = resid.get(f"{ax}/alpha_beta")
        fitted = f"{ax}/alpha_beta" in resid
        out.append(
            f"| {ax} | {lv['alpha'] * 1e6:,.1f} | "
            f"{1 / lv['beta'] / 1e9:.2f} | "
            + (f"{phi[ax]:.2f}" if ax in phi else "—") + " | "
            + (f"{sigma[ax]:.2f}" if ax in sigma else "—") + " | "
            + (f"{r:.3f}" if r is not None else "(defaults)") + " |"
            + ("" if fitted else "  _not measured (axis absent or "
                                 "extent 1 on the calibration mesh)_"))
    n_ms = len(rec.get("measurements", []))
    out += ["", f"{n_ms} raw measurements are embedded in the artifact "
            "(collective timings, contention pairs, overlap triples) — "
            "`ClusterSpec.fitted_from(rec['measurements'])` reproduces "
            "the fit. φ > 1 is real self-contention on the timeshared "
            "host core; σ is what XLA actually hid when compute and an "
            "independent collective shared one program."]
    return "\n".join(out)


def kernel_tune_section(here: pathlib.Path) -> str:
    """Predicted-vs-measured block-size table from the kernel autotuner.

    Reads the artifact written by the tune loop
    (``PYTHONPATH=src python -m repro.api --tune-kernels`` — full shapes;
    scripts/check.sh runs the smoke variant into a scratch file).
    """
    out = ["### Kernel autotune (prune → measure → cache)", "",
           "ISSUE 8: per (kernel, shape-bucket), the analytic pruner "
           "(VMEM capacity + roofline knee from "
           "`HardwareSpec.from_cluster`) kills infeasible block sizes, the "
           "survivors are *measured* (interpret mode on this CPU box), and "
           "the measured winner is cached under the cluster fingerprint "
           "(DESIGN.md §13). The predicted column is the TPU-roofline "
           "model the pruner ranks by; the measured column is interpret-"
           "mode wall time — when they disagree on ordering (they do for "
           "rmsnorm below) the measurement wins, which is exactly why the "
           "tuner measures instead of trusting the model.", ""]
    art = here / "kernel_tune.json"
    if not art.exists():
        out.append("_no kernel tune artifact yet — run "
                   "`PYTHONPATH=src python -m repro.api --tune-kernels`_")
        return "\n".join(out)
    rec = json.loads(art.read_text())
    out += [f"Cluster `{rec.get('cluster', '?')}` (fingerprint "
            f"`{rec.get('fingerprint', '?')}`), backend "
            f"`{rec.get('backend', '?')}`:", "", KT_HDR]
    for e in rec.get("entries", {}).values():
        cands = e.get("candidates") or [
            {"blocks": e["blocks"], "predicted_us": e["predicted_us"],
             "measured_us": e["measured_us"], "is_default": True}]
        d_us = e["default_us"] or 1.0
        for i, c in enumerate(cands):
            blocks = ";".join(f"{k}={v}"
                              for k, v in sorted(c["blocks"].items()))
            tag = ("winner" if c["measured_us"] == e["measured_us"] else "") \
                + (" (default)" if c["is_default"] else "")
            out.append(
                f"| {(e['kernel'] + ' (' + e['bucket'] + ')') if i == 0 else ''} "
                f"| {blocks} | {c['predicted_us']:,.1f} "
                f"| {c['measured_us']:,.1f} "
                f"| {c['measured_us'] / d_us:.2f}x | {tag.strip()} |")
    out += ["",
            "`vs default` < 1 is a real interpret-mode win the TPU model "
            "did not predict (rmsnorm: fewer, larger grid programs halve "
            "the per-program emulation overhead). Investigating the "
            "committed `kernels/conv2d/gemm_interpret` ref_ratio≈1.4x: "
            "tuned `block_f` does **not** close it — the winner *is* the "
            "default (block_f=128), and the only other survivor "
            "(block_f=64) measures slower, agreeing with the predicted "
            "ordering. The gap is per-program dispatch/emulation overhead "
            "of interpret mode itself (the kernel launches a B×(F/block_f) "
            "grid of emulated programs where the jnp reference is one "
            "fused XLA conv op), not a "
            "tiling problem — on TPU the same table predicts block_f=128 "
            "stays optimal at 13.4µs/call."]
    return "\n".join(out)


def replace_between(text: str, start_marker: str, end_marker: str,
                    new: str) -> str:
    start = text.index(start_marker)
    end = text.index(end_marker)
    return text[:start] + new + "\n\n" + text[end:]


def ensure_marker(text: str, marker: str, before: str) -> str:
    """Insert an (empty) generated section heading if an older EXPERIMENTS.md
    predates it, so replace_between always finds its delimiters."""
    if marker in text:
        return text
    at = text.index(before)
    return text[:at] + marker + "\n\n" + text[at:]


def main():
    here = pathlib.Path(__file__).parent
    exp = here.parent / "EXPERIMENTS.md"
    if not exp.exists():
        exp.write_text(SKELETON)
    t = exp.read_text()
    t = ensure_marker(t, "### Auto-tuner decisions",
                      "### Per-cell observations")
    t = ensure_marker(t, "### Oracle vs HLO cross-check",
                      "### Per-cell observations")
    # order matters: "### Pipeline validation" must exist before it can
    # anchor the overlap marker (legacy files predate both)
    t = ensure_marker(t, "### Pipeline validation",
                      "### Per-cell observations")
    t = ensure_marker(t, "### Overlap validation",
                      "### Pipeline validation")
    t = ensure_marker(t, "### Cluster calibration",
                      "### Per-cell observations")
    t = ensure_marker(t, "### Schedule validation",
                      "### Cluster calibration")
    t = ensure_marker(t, "### 2D tensor validation",
                      "### Cluster calibration")
    t = ensure_marker(t, "### Serving validation",
                      "### Cluster calibration")
    t = ensure_marker(t, "### Kernel autotune",
                      "### Per-cell observations")
    recs = load_dryrun(here)
    dry, n_base, n_opt = dryrun_sections(recs)
    t = replace_between(t, "### Baseline cells",
                        "### Oracle sweep", dry)
    t = replace_between(t, "### Oracle sweep",
                        "### Auto-tuner decisions", sweep_section())
    t = replace_between(t, "### Auto-tuner decisions",
                        "### Oracle vs HLO cross-check", tuner_section())
    t = replace_between(t, "### Oracle vs HLO cross-check",
                        "### Overlap validation", crosscheck_section(recs))
    t = replace_between(t, "### Overlap validation",
                        "### Pipeline validation", overlap_section(here))
    t = replace_between(t, "### Pipeline validation",
                        "### Schedule validation", pipeline_section(here))
    t = replace_between(t, "### Schedule validation",
                        "### 2D tensor validation", schedule_section(here))
    t = replace_between(t, "### 2D tensor validation",
                        "### Serving validation", tensor2d_section(here))
    t = replace_between(t, "### Serving validation",
                        "### Cluster calibration", serving_section(here))
    t = replace_between(t, "### Cluster calibration",
                        "### Kernel autotune", cluster_section(here))
    t = replace_between(t, "### Kernel autotune",
                        "### Per-cell observations", kernel_tune_section(here))
    exp.write_text(t)
    print(f"refreshed: {n_base} baseline + {n_opt} variant dry-run cells "
          f"+ oracle sweep / auto-tuner / cross-check / overlap / pipeline "
          f"/ schedule / serving / cluster-fit / kernel-tune tables")


if __name__ == "__main__":
    main()
