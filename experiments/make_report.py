"""Regenerate the EXPERIMENTS.md roofline tables from dry-run artifacts.

Usage: PYTHONPATH=src python experiments/make_report.py
"""
import json
import pathlib

HDR = ("| arch | shape | mesh | strategy | comp ms | mem ms | coll ms | dom |"
       " useful | frac | args GiB | temp GiB |\n"
       "|---|---|---|---|---|---|---|---|---|---|---|---|")


def row(r):
    rl = r["roofline"]
    return (f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['strategy']}"
            f"{('/' + r['tag']) if r.get('tag') else ''} | "
            f"{rl['compute_s']*1e3:,.1f} | {rl['memory_s']*1e3:,.1f} | "
            f"{rl['collective_s']*1e3:,.1f} | {rl['dominant'][:4]} | "
            f"{rl['useful_ratio']:.2f} | {rl['roofline_fraction']:.3f} | "
            f"{r['memory']['args_gib']:.1f} | {r['memory']['temp_gib']:.1f} |")


def main():
    here = pathlib.Path(__file__).parent
    recs = [json.loads(f.read_text()) for f in sorted((here / "dryrun").glob("*.json"))]
    base = [r for r in recs if not r.get("tag")]
    opt = [r for r in recs if r.get("tag")]
    out = ["### Baseline cells (required matrix)", "", HDR]
    out += [row(r) for r in base]
    out += ["", "### Hillclimb / variant cells (tagged)", "", HDR]
    out += [row(r) for r in opt]
    table = "\n".join(out)

    exp = here.parent / "EXPERIMENTS.md"
    t = exp.read_text()
    start = t.index("### Baseline cells (required matrix)")
    end = t.index("\n### Per-cell observations")
    exp.write_text(t[:start] + table + t[end:])
    print(f"refreshed: {len(base)} baseline + {len(opt)} variant cells")


if __name__ == "__main__":
    main()
